//! Noise study (extension §10): what NISQ noise does to DQuLearn, and
//! what the noise-aware co-Manager recovers.
//!
//! 1. Accuracy-vs-noise curve: train the classifier on progressively
//!    noisier simulated backends.
//! 2. Mixed pool: ideal + noisy workers; paper's CRU-only scheduling vs
//!    the noise-aware policy (`ManagerConfig::noise_aware_alpha`).
//! 3. Checkpoint round-trip of the best model.
//!
//! ```bash
//! cargo run --release --example noise_study
//! ```

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::InProcCluster;
use dqulearn::coordinator::ManagerConfig;
use dqulearn::data::Dataset;
use dqulearn::model::checkpoint;
use dqulearn::model::optimizer::Optimizer;
use dqulearn::model::quclassi::LossKind;
use dqulearn::model::{QuClassiModel, TrainConfig, Trainer};
use dqulearn::qsim::NoiseModel;
use dqulearn::util::Rng;

fn train_on(cluster: &InProcCluster, seed: u64) -> Result<(QuClassiModel, f64), String> {
    let cfg = QuClassiConfig::new(5, 1)?;
    let ds = Dataset::binary_pair(None, 3, 9, 16, 42);
    let mut model = QuClassiModel::new(cfg, &mut Rng::new(seed));
    let report = Trainer::new(TrainConfig {
        epochs: 10,
        optimizer: Optimizer::adam(0.05),
        train_classical: true,
        classical_lr_scale: 0.1,
        seed: 7,
        early_stop_acc: None,
        loss: LossKind::Generative,
    })
    .train(&mut model, &ds, cluster)?;
    Ok((model, report.test_accuracy))
}

fn main() -> Result<(), String> {
    // --- 1. accuracy vs noise level (mean over 3 model seeds: finite-
    //        shot-style gradient noise makes single runs high-variance) ---
    println!("== accuracy vs backend noise (q5l1, 3-vs-9, generative loss, 3 seeds) ==");
    println!("{:>22} {:>10}", "noise (p1/p2/readout)", "mean acc");
    for (label, noise) in [
        ("ideal", None),
        ("0.001/0.01/0.02", Some(NoiseModel::nisq())),
        ("0.005/0.05/0.05", Some(NoiseModel { p1: 0.005, p2: 0.05, readout: 0.05 })),
        ("0.02/0.20/0.10", Some(NoiseModel { p1: 0.02, p2: 0.20, readout: 0.10 })),
    ] {
        let mut acc_sum = 0.0;
        for seed in [42u64, 43, 44] {
            let mut builder = InProcCluster::builder().workers(&[5, 5]);
            if let Some(nm) = noise {
                builder = builder.noise(nm);
            }
            let cluster = builder.build()?;
            let (_m, acc) = train_on(&cluster, seed)?;
            cluster.shutdown();
            acc_sum += acc;
        }
        println!("{label:>22} {:>10.2}", acc_sum / 3.0);
    }
    println!("(small-sample accuracies are coarse — {:.2} steps — but ideal backends sit at the top;\n  gradient corruption from gate noise is the impact the paper's Discussion anticipates)", 1.0/6.0);

    // --- 2. mixed pool: CRU-only vs noise-aware scheduling ---
    println!("\n== mixed pool (2 ideal + 2 noisy workers): scheduling policy ==");
    let heavy = NoiseModel { p1: 0.01, p2: 0.10, readout: 0.08 };
    let profiles: [(usize, Option<NoiseModel>); 4] =
        [(5, None), (5, None), (5, Some(heavy)), (5, Some(heavy))];
    let mut best: Option<(QuClassiModel, f64)> = None;
    for (label, alpha) in [("CRU-only (paper)", None), ("noise-aware α=1.0", Some(1.0))] {
        let cluster = InProcCluster::builder()
            .workers_with_noise(&profiles)
            // steal=false: the comparison is about *placement*, so an
            // idle noisy worker must not steal a clean worker's batches
            .manager_config(ManagerConfig {
                noise_aware_alpha: alpha,
                steal: false,
                ..Default::default()
            })
            .build()?;
        let (model, acc) = train_on(&cluster, 42)?;
        cluster.shutdown();
        println!("{label:>22} test acc {acc:.2}");
        if best.as_ref().map(|(_, b)| acc > *b).unwrap_or(true) {
            best = Some((model, acc));
        }
    }

    // --- 3. checkpoint the best model ---
    let (model, acc) = best.unwrap();
    let path = std::env::temp_dir().join("dqulearn_noise_study.ckpt.json");
    checkpoint::save(&model, &path)?;
    let restored = checkpoint::load(&path)?;
    assert_eq!(model.theta[0], restored.theta[0]);
    println!("\ncheckpointed best model (acc {acc:.2}) to {} and verified reload", path.display());
    let _ = std::fs::remove_file(&path);
    Ok(())
}
