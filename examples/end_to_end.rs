//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the stack on a real small workload, proving
//! they compose:
//!
//!   L1/L2  AOT JAX/Pallas artifacts executed via PJRT from Rust,
//!   L3     the co-Manager + workers over REAL TCP RPC (separate threads,
//!          real sockets, heartbeats, Algorithm-2 scheduling),
//!   model  Algorithm-1 training of the QuClassi classifier on the
//!          3-vs-9 task, logging the loss curve,
//!   plus a cross-check that PJRT and the Rust simulator agree.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::{serve_manager, RemoteClient};
use dqulearn::coordinator::{Manager, ManagerConfig};
use dqulearn::data::Dataset;
use dqulearn::model::exec::{CircuitExecutor, QsimExecutor};
use dqulearn::model::optimizer::Optimizer;
use dqulearn::model::quclassi::LossKind;
use dqulearn::model::{QuClassiModel, TrainConfig, Trainer};
use dqulearn::util::Rng;
use dqulearn::worker::{WorkerHandle, WorkerOptions};

fn main() -> Result<(), String> {
    let artifacts = std::path::Path::new("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();
    println!(
        "== DQuLearn end-to-end driver ==\nbackend: {}",
        if have_artifacts { "PJRT (AOT jax/pallas artifacts)" } else { "qsim fallback" }
    );

    // --- 1. the co-Manager, served over real TCP ---
    let manager = Manager::new(ManagerConfig { heartbeat_period: 1.0, ..Default::default() });
    let server = serve_manager(manager.clone(), "127.0.0.1:0").map_err(|e| e.to_string())?;
    let addr = server.local_addr().to_string();
    println!("co-manager on {addr}");

    // --- 2. two quantum workers, real processes-on-threads with RPC ---
    let worker_opts = |mq: usize| WorkerOptions {
        max_qubits: mq,
        artifact_dir: artifacts.to_path_buf(),
        heartbeat_period: 0.5,
        listen: "127.0.0.1:0".to_string(),
        threads: 0, // auto-detect: the backend pools circuits across cores
    };
    let w1 = WorkerHandle::start(&addr, worker_opts(5))?;
    let w2 = WorkerHandle::start(&addr, worker_opts(10))?;
    println!("workers w{} (5q) and w{} (10q) registered", w1.worker_id, w2.worker_id);

    // --- 3. cross-check: PJRT results == Rust simulator results ---
    // The remote client hands out typed sessions; each session owns a
    // tenant id and submits through BankHandle futures.
    let client = RemoteClient::connect(&addr)?;
    let session = client.session()?;
    let cfg = QuClassiConfig::new(5, 2)?;
    let mut rng = Rng::new(1);
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..32)
        .map(|_| {
            (
                (0..cfg.n_params()).map(|_| rng.f32() * 2.0).collect(),
                (0..cfg.n_features()).map(|_| rng.f32() * 2.0).collect(),
            )
        })
        .collect();
    let handle = session.submit(cfg, &pairs)?;
    println!(
        "bank {} submitted ({} circuits) — polling while it runs",
        handle.id(),
        handle.total()
    );
    let via_cluster = handle.wait()?;
    let via_qsim = QsimExecutor.execute_bank(&cfg, &pairs)?;
    let max_err = via_cluster
        .iter()
        .zip(via_qsim.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("distributed-vs-simulator cross-check: max |Δfid| = {max_err:.2e}");
    assert!(max_err < 1e-4, "backends disagree");

    // --- 4. Algorithm-1 training over the distributed cluster ---
    let dataset = Dataset::binary_pair(None, 3, 9, 24, 42);
    println!(
        "training 3-vs-9: {} train / {} test examples",
        dataset.train.len(),
        dataset.test.len()
    );
    let mut model = QuClassiModel::new(cfg, &mut Rng::new(42));
    let trainer = Trainer::new(TrainConfig {
        epochs: 10,
        optimizer: Optimizer::adam(0.05),
        train_classical: true,
        classical_lr_scale: 0.1,
        seed: 7,
        early_stop_acc: None,
            loss: LossKind::Generative,
    });
    let t0 = std::time::Instant::now();
    let report = trainer.train(&mut model, &dataset, &session)?;
    println!("loss curve:");
    for e in &report.epochs {
        println!(
            "  epoch {:>2}: loss {:.4}  train-acc {:.2}  circuits {:>5}  {:.2}s",
            e.epoch, e.mean_loss, e.train_accuracy, e.circuits, e.wall_seconds
        );
    }
    println!(
        "test accuracy {:.2}; {} circuits in {:.1}s -> {:.0} circuits/s end-to-end",
        report.test_accuracy,
        report.total_circuits,
        t0.elapsed().as_secs_f64(),
        report.circuits_per_second()
    );

    // --- 5. manager-side accounting sanity ---
    let stats = client.manager_stats()?;
    println!(
        "manager stats: submitted={} completed={} dispatches={} workers={}",
        stats.req_u64("submitted")?,
        stats.req_u64("completed")?,
        stats.req_u64("dispatches")?,
        stats.req_u64("workers")?
    );

    drop(w1);
    drop(w2);
    manager.shutdown();
    println!("end-to-end OK");
    Ok(())
}
