//! Quickstart: train a small distributed quantum classifier in-process.
//!
//! ```bash
//! make artifacts            # AOT-compile the JAX/Pallas circuits (once)
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a 2-worker in-process cluster (PJRT artifact backends when
//! `artifacts/` exists, Rust simulator otherwise), trains a 3-vs-9
//! QuClassi classifier for a few epochs, and prints the learning curve.

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::InProcCluster;
use dqulearn::data::Dataset;
use dqulearn::model::exec::CircuitExecutor;
use dqulearn::model::optimizer::Optimizer;
use dqulearn::model::quclassi::LossKind;
use dqulearn::model::{QuClassiModel, TrainConfig, Trainer};
use dqulearn::util::Rng;

fn main() -> Result<(), String> {
    // 1. A (qubits=5, layers=1) circuit configuration: 1 swap-test
    //    ancilla + 2 variational "class state" qubits + 2 data qubits.
    let config = QuClassiConfig::new(5, 1)?;

    // 2. The dataset: MNIST pair 3-vs-9 (synthetic stand-in when the IDX
    //    files are absent), cleaned + split by the data pipeline.
    let dataset = Dataset::binary_pair(None, 3, 9, 20, 42);
    println!("dataset: {} train / {} test", dataset.train.len(), dataset.test.len());

    // 3. A 2-worker cluster in this process. The co-Manager schedules
    //    every parameter-shift circuit across the workers (Algorithm 2).
    let mut builder = InProcCluster::builder().workers(&[5, 5]);
    if std::path::Path::new("artifacts/manifest.json").exists() {
        builder = builder.artifacts("artifacts"); // PJRT: AOT JAX/Pallas
    }
    let cluster = builder.build()?;
    // A typed session owns this tenant's client id; it implements
    // CircuitExecutor, so the trainer runs on the session API directly.
    let session = cluster.session();
    println!("executor: {} (via {})", cluster.describe(), session.describe());

    // 4. Train (Algorithm 1): parameter-shift circuit banks per sample,
    //    submitted through the session, gradients assembled, Adam updates.
    //    DQ_QUICKSTART_EPOCHS overrides the epoch count (CI smoke runs
    //    set it to 1 so example drift is caught without a full train).
    let epochs = std::env::var("DQ_QUICKSTART_EPOCHS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(8);
    let mut model = QuClassiModel::new(config, &mut Rng::new(42));
    let trainer = Trainer::new(TrainConfig {
        epochs,
        optimizer: Optimizer::adam(0.08),
        train_classical: true,
        classical_lr_scale: 0.1,
        seed: 7,
        early_stop_acc: None,
        loss: LossKind::Discriminative,
    });
    let report = trainer.train(&mut model, &dataset, &session)?;

    for e in &report.epochs {
        println!(
            "epoch {}: loss {:.4}  train-acc {:.2}  ({} circuits, {:.2}s)",
            e.epoch, e.mean_loss, e.train_accuracy, e.circuits, e.wall_seconds
        );
    }
    println!(
        "test accuracy {:.2} — {} circuits total at {:.0} circuits/s",
        report.test_accuracy,
        report.total_circuits,
        report.circuits_per_second()
    );
    cluster.shutdown();
    Ok(())
}
