//! Uncontrolled-cloud study (the paper's IBM-Q §IV-C1, via the DES).
//!
//! Regenerates Figures 3 and 4 — runtime per epoch and circuits/sec on
//! jittery, FIFO, shared cloud backends — and demonstrates the effect of
//! the co-Manager's CRU-aware selection by comparing against a
//! round-robin ablation.
//!
//! ```bash
//! cargo run --release --example uncontrolled_cloud
//! ```

use dqulearn::benchlib::Table;
use dqulearn::circuit::QuClassiConfig;
use dqulearn::env::scenarios::{epoch_circuits, ibmq_figure, round_bank_size};
use dqulearn::env::{sim, Calibration, ClientJob, EnvParams, SimConfig, SimWorkerSpec, Tenancy};

fn main() {
    let calib = Calibration::qiskit_like();

    for qubits in [5usize, 7] {
        let fig = if qubits == 5 { 3 } else { 4 };
        println!("\n== Figure {fig}: {qubits}-qubit IBM-Q backends (uncontrolled) ==");
        let rows = ibmq_figure(qubits, &calib, 7);
        let mut table = Table::new(&["layers", "workers", "circuits", "runtime(s)", "circ/s"]);
        for r in &rows {
            table.row(&[
                r.layers.to_string(),
                r.workers.to_string(),
                r.circuits.to_string(),
                format!("{:.1}", r.runtime),
                format!("{:.2}", r.cps),
            ]);
        }
        print!("{}", table.render());
    }

    // Ablation: CRU-aware selection vs "blind" selection under skewed
    // worker speeds. With heterogeneous backends (one worker 3x slower —
    // common on shared clouds), balancing by CRU avoids queueing on the
    // slow machine.
    println!("\n== ablation: CRU-aware vs speed-skewed pool (5Q/2L, 4 workers) ==");
    let config = QuClassiConfig::new(5, 2).unwrap();
    let jobs = vec![ClientJob {
        client: 0,
        config,
        n_circuits: epoch_circuits(5, 2),
        bank_size: round_bank_size(&config),
    }];
    let skewed = |seed: u64| SimConfig {
        workers: vec![
            SimWorkerSpec { max_qubits: 64, speed: 0.33 }, // slow shared backend
            SimWorkerSpec { max_qubits: 64, speed: 1.0 },
            SimWorkerSpec { max_qubits: 64, speed: 1.0 },
            SimWorkerSpec { max_qubits: 64, speed: 1.0 },
        ],
        env: EnvParams::ibmq_uncontrolled(),
        calib: calib.clone(),
        heartbeat_period: 5.0,
        tenancy: Tenancy::MultiTenant,
        // steal off for the heartbeat ablation: backlog stealing would
        // mask the CRU-freshness effect these rows isolate
        steal: false,
        seed,
    };
    // CRU-aware (the real scheduler): queue depth feeds CRU, so the slow
    // worker accumulates load signal and receives fewer circuits.
    let aware = sim::simulate(&skewed(11), &jobs);
    // Faster heartbeats sharpen the signal: ablate the heartbeat period.
    let mut cfg_fast = skewed(11);
    cfg_fast.heartbeat_period = 1.0;
    let aware_fast = sim::simulate(&cfg_fast, &jobs);
    let mut cfg_slow = skewed(11);
    cfg_slow.heartbeat_period = 30.0;
    let aware_slow = sim::simulate(&cfg_slow, &jobs);
    println!("heartbeat 5s (paper): runtime {:.1}s ({:.2} circ/s)", aware.makespan, aware.cps);
    println!("heartbeat 1s        : runtime {:.1}s ({:.2} circ/s)", aware_fast.makespan, aware_fast.cps);
    println!("heartbeat 30s       : runtime {:.1}s ({:.2} circ/s)", aware_slow.makespan, aware_slow.cps);
    println!(
        "\n(trend check: fresher CRU -> better balancing on skewed pools; \
         the paper's 5s period sits between the extremes)"
    );

    // Second ablation: work stealing between worker backlogs. Stale CRU
    // binds circuits to the slow backend between heartbeats; an idle
    // fast worker stealing the slow worker's bound-but-unstarted
    // circuits recovers most of what fresher heartbeats would have
    // bought (DESIGN.md §14).
    let mut cfg_steal = skewed(11);
    cfg_steal.steal = true;
    let steal_on = sim::simulate(&cfg_steal, &jobs);
    println!("\n== ablation: backlog work stealing (same skewed pool, 5s heartbeats) ==");
    println!("steal off          : runtime {:.1}s ({:.2} circ/s)", aware.makespan, aware.cps);
    println!("steal on           : runtime {:.1}s ({:.2} circ/s)", steal_on.makespan, steal_on.cps);
}
