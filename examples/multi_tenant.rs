//! Multi-tenant scenario: four concurrent clients share a heterogeneous
//! worker pool (the paper's §IV-C2 "Multi Clients Multiple Circuits").
//!
//! Four clients submit different workloads (5Q/1L, 5Q/2L, 7Q/1L, 7Q/2L)
//! at the same time; the co-Manager packs their circuits onto four
//! workers with 5/10/15/20 qubits according to Algorithm 2 (candidates by
//! available qubits, selection by lowest CRU). A 20-qubit worker hosts
//! four 5-qubit circuits — or two 7-qubit ones — concurrently.
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use std::sync::Arc;

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::InProcCluster;
use dqulearn::metrics::ThroughputMeter;
use dqulearn::util::Rng;

fn main() -> Result<(), String> {
    // Heterogeneous pool: 5, 10, 15, 20 qubits (the paper's Fig. 6 setup).
    // worker_threads(0) sizes each worker's internal circuit pool to the
    // host — results are bitwise identical to serial, only faster.
    let mut builder = InProcCluster::builder().workers(&[5, 10, 15, 20]).worker_threads(0);
    if std::path::Path::new("artifacts/manifest.json").exists() {
        builder = builder.artifacts("artifacts");
    }
    let cluster = Arc::new(builder.build()?);
    println!("pool: workers with 5/10/15/20 qubits");

    let jobs = [(5usize, 1usize, 240usize), (5, 2, 240), (7, 1, 160), (7, 2, 160)];
    let meter = Arc::new(ThroughputMeter::start());

    let threads: Vec<_> = jobs
        .iter()
        .enumerate()
        .map(|(i, &(q, l, n))| {
            let cluster = cluster.clone();
            let meter = meter.clone();
            std::thread::spawn(move || -> Result<(usize, f64, usize), String> {
                let config = QuClassiConfig::new(q, l)?;
                // Each tenant is a typed session (owns its client id).
                let session = cluster.session();
                let mut rng = Rng::new(100 + i as u64);
                let t0 = std::time::Instant::now();
                // Submit in banks of 32, like a training loop would. The
                // BankHandle future lets the tenant overlap classical
                // work with the in-flight quantum batch: here we stream
                // progress through try_poll() before blocking on wait().
                let mut done = 0usize;
                while done < n {
                    let bank = 32.min(n - done);
                    let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..bank)
                        .map(|_| {
                            (
                                (0..config.n_params()).map(|_| rng.f32() * 2.0).collect(),
                                (0..config.n_features()).map(|_| rng.f32() * 2.0).collect(),
                            )
                        })
                        .collect();
                    let handle = session.submit(config, &pairs)?;
                    let mut streamed = 0usize;
                    loop {
                        let status = handle.try_poll()?;
                        // partial fidelities arrive while the bank runs
                        streamed = streamed.max(status.completed);
                        if !status.pending {
                            break;
                        }
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    let fids = handle.wait()?;
                    assert_eq!(fids.len(), bank);
                    assert!(streamed <= bank);
                    meter.add(bank as u64);
                    done += bank;
                }
                Ok((i, t0.elapsed().as_secs_f64(), n))
            })
        })
        .collect();

    println!("{:<10} {:>10} {:>12} {:>14}", "client", "circuits", "runtime(s)", "circuits/s");
    for t in threads {
        let (i, secs, n) = t.join().expect("client thread")?;
        let (q, l, _) = jobs[i];
        println!("{:<10} {:>10} {:>12.2} {:>14.1}", format!("{q}Q/{l}L"), n, secs, n as f64 / secs);
    }
    println!(
        "aggregate: {} circuits at {:.1} circuits/s across all tenants",
        meter.circuits(),
        meter.cps()
    );
    let stats = cluster.manager.stats();
    println!(
        "co-manager: {} dispatches, {} completed, {} requeues",
        stats.dispatches, stats.completed, stats.requeues
    );
    cluster.shutdown();
    Ok(())
}
