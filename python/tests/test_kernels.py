"""L1 correctness: every Pallas gate kernel against the pure-jnp oracle.

Hypothesis sweeps qubit counts, target qubits, and angles; every gate the
QuClassi circuit uses is exercised standalone through its own pallas_call
so a failure localizes to one kernel, not the fused circuit.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import statevector as sv

SETTINGS = dict(max_examples=25, deadline=None)


def random_state(rng, batch, nq):
    """A normalized random complex state as (complex, re, im)."""
    re = rng.standard_normal((batch, 2**nq)).astype(np.float32)
    im = rng.standard_normal((batch, 2**nq)).astype(np.float32)
    norm = np.sqrt(np.sum(re * re + im * im, axis=1, keepdims=True))
    re, im = re / norm, im / norm
    return re + 1j * im, jnp.asarray(re), jnp.asarray(im)


def assert_close(state_c, re, im, atol=1e-5):
    np.testing.assert_allclose(np.real(state_c), np.asarray(re), atol=atol)
    np.testing.assert_allclose(np.imag(state_c), np.asarray(im), atol=atol)


@st.composite
def gate_case(draw, two_qubit=False):
    nq = draw(st.integers(min_value=2 if not two_qubit else 3, max_value=6))
    batch = draw(st.integers(min_value=1, max_value=4))
    theta = draw(
        st.lists(
            st.floats(min_value=-6.25, max_value=6.25, width=32),
            min_size=batch,
            max_size=batch,
        )
    )
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    if two_qubit:
        q0 = draw(st.integers(min_value=0, max_value=nq - 2))
        q1 = draw(st.integers(min_value=q0 + 1, max_value=nq - 1))
        return nq, batch, np.asarray(theta, np.float32), seed, q0, q1
    q = draw(st.integers(min_value=0, max_value=nq - 1))
    return nq, batch, np.asarray(theta, np.float32), seed, q


class TestSingleQubitKernels:
    @settings(**SETTINGS)
    @given(gate_case())
    def test_ry(self, case):
        nq, b, theta, seed, q = case
        sc, re, im = random_state(np.random.default_rng(seed), b, nq)
        want = ref.apply_ry(jnp.asarray(sc), jnp.asarray(theta), q, nq)
        got_re, got_im = sv.pallas_apply_1q("ry", re, im, jnp.asarray(theta), q, nq)
        assert_close(np.asarray(want), got_re, got_im)

    @settings(**SETTINGS)
    @given(gate_case())
    def test_rz(self, case):
        nq, b, theta, seed, q = case
        sc, re, im = random_state(np.random.default_rng(seed), b, nq)
        want = ref.apply_rz(jnp.asarray(sc), jnp.asarray(theta), q, nq)
        got_re, got_im = sv.pallas_apply_1q("rz", re, im, jnp.asarray(theta), q, nq)
        assert_close(np.asarray(want), got_re, got_im)

    @settings(**SETTINGS)
    @given(gate_case())
    def test_hadamard(self, case):
        nq, b, _theta, seed, q = case
        sc, re, im = random_state(np.random.default_rng(seed), b, nq)
        want = ref.apply_h(jnp.asarray(sc), q, nq)
        got_re, got_im = sv.pallas_apply_h(re, im, q, nq)
        assert_close(np.asarray(want), got_re, got_im)


class TestTwoQubitKernels:
    @settings(**SETTINGS)
    @given(gate_case(two_qubit=True))
    def test_ryy(self, case):
        nq, b, theta, seed, q0, q1 = case
        sc, re, im = random_state(np.random.default_rng(seed), b, nq)
        want = ref.apply_ryy(jnp.asarray(sc), jnp.asarray(theta), q0, q1, nq)
        got_re, got_im = sv.pallas_apply_2q("ryy", re, im, jnp.asarray(theta), q0, q1, nq)
        assert_close(np.asarray(want), got_re, got_im)

    @settings(**SETTINGS)
    @given(gate_case(two_qubit=True))
    def test_rzz(self, case):
        nq, b, theta, seed, q0, q1 = case
        sc, re, im = random_state(np.random.default_rng(seed), b, nq)
        want = ref.apply_rzz(jnp.asarray(sc), jnp.asarray(theta), q0, q1, nq)
        got_re, got_im = sv.pallas_apply_2q("rzz", re, im, jnp.asarray(theta), q0, q1, nq)
        assert_close(np.asarray(want), got_re, got_im)

    @settings(**SETTINGS)
    @given(gate_case(two_qubit=True))
    def test_cry(self, case):
        nq, b, theta, seed, q0, q1 = case
        sc, re, im = random_state(np.random.default_rng(seed), b, nq)
        want = ref.apply_cry(jnp.asarray(sc), jnp.asarray(theta), q0, q1, nq)
        got_re, got_im = sv.pallas_apply_2q("cry", re, im, jnp.asarray(theta), q0, q1, nq)
        assert_close(np.asarray(want), got_re, got_im)

    @settings(**SETTINGS)
    @given(gate_case(two_qubit=True))
    def test_cry_reversed_control(self, case):
        """Control index above target exercises the other branch."""
        nq, b, theta, seed, q0, q1 = case
        sc, re, im = random_state(np.random.default_rng(seed), b, nq)
        want = ref.apply_cry(jnp.asarray(sc), jnp.asarray(theta), q1, q0, nq)
        got_re, got_im = sv.pallas_apply_2q("cry", re, im, jnp.asarray(theta), q1, q0, nq)
        assert_close(np.asarray(want), got_re, got_im)

    @settings(**SETTINGS)
    @given(gate_case(two_qubit=True))
    def test_crz(self, case):
        nq, b, theta, seed, q0, q1 = case
        sc, re, im = random_state(np.random.default_rng(seed), b, nq)
        want = ref.apply_crz(jnp.asarray(sc), jnp.asarray(theta), q0, q1, nq)
        got_re, got_im = sv.pallas_apply_2q("crz", re, im, jnp.asarray(theta), q0, q1, nq)
        assert_close(np.asarray(want), got_re, got_im)

    @settings(**SETTINGS)
    @given(gate_case(two_qubit=True))
    def test_crz_reversed_control(self, case):
        nq, b, theta, seed, q0, q1 = case
        sc, re, im = random_state(np.random.default_rng(seed), b, nq)
        want = ref.apply_crz(jnp.asarray(sc), jnp.asarray(theta), q1, q0, nq)
        got_re, got_im = sv.pallas_apply_2q("crz", re, im, jnp.asarray(theta), q1, q0, nq)
        assert_close(np.asarray(want), got_re, got_im)


class TestCswap:
    @settings(**SETTINGS)
    @given(st.integers(min_value=3, max_value=7), st.integers(min_value=0, max_value=2**31 - 1))
    def test_cswap_matches_ref(self, nq, seed):
        rng = np.random.default_rng(seed)
        a = int(rng.integers(1, nq - 1))
        b = int(rng.integers(a + 1, nq))
        sc, re, im = random_state(rng, 2, nq)
        want = ref.apply_cswap(jnp.asarray(sc), 0, a, b, nq)
        got_re, got_im = sv.pallas_apply_cswap(re, im, 0, a, b, nq)
        assert_close(np.asarray(want), got_re, got_im)

    def test_cswap_is_involution(self):
        rng = np.random.default_rng(7)
        _, re, im = random_state(rng, 3, 5)
        r1, i1 = sv.pallas_apply_cswap(re, im, 0, 1, 3, 5)
        r2, i2 = sv.pallas_apply_cswap(r1, i1, 0, 1, 3, 5)
        np.testing.assert_allclose(np.asarray(r2), np.asarray(re), atol=1e-6)
        np.testing.assert_allclose(np.asarray(i2), np.asarray(im), atol=1e-6)

    def test_cswap_noop_when_control_zero(self):
        """|0> ancilla leaves the state untouched."""
        nq = 5
        re = jnp.zeros((1, 2**nq), jnp.float32).at[0, 0b01010].set(1.0)
        im = jnp.zeros((1, 2**nq), jnp.float32)
        got_re, got_im = sv.pallas_apply_cswap(re, im, 0, 1, 2, nq)
        np.testing.assert_allclose(np.asarray(got_re), np.asarray(re))
        np.testing.assert_allclose(np.asarray(got_im), np.asarray(im))


class TestUnitarity:
    """Gates must preserve the 2-norm of the state."""

    @settings(**SETTINGS)
    @given(gate_case(two_qubit=True))
    def test_norm_preserved(self, case):
        nq, b, theta, seed, q0, q1 = case
        rng = np.random.default_rng(seed)
        _, re, im = random_state(rng, b, nq)
        for name in ("ryy", "rzz", "cry", "crz"):
            r, i = sv.pallas_apply_2q(name, re, im, jnp.asarray(theta), q0, q1, nq)
            norm = np.sum(np.asarray(r) ** 2 + np.asarray(i) ** 2, axis=1)
            np.testing.assert_allclose(norm, 1.0, atol=1e-5)

    def test_prob0_on_basis_states(self):
        nq = 4
        # |0000> -> p0 = 1; |1000> -> p0 = 0
        re = jnp.zeros((2, 2**nq), jnp.float32).at[0, 0].set(1.0).at[1, 2 ** (nq - 1)].set(1.0)
        im = jnp.zeros((2, 2**nq), jnp.float32)
        p = sv.prob0(re, im, nq)
        np.testing.assert_allclose(np.asarray(p), [1.0, 0.0], atol=1e-7)


class TestGateAlgebra:
    """Known closed-form identities."""

    def test_ry_pi_is_y_flip(self):
        # Ry(pi)|0> = |1>
        nq = 1
        re = jnp.zeros((1, 2), jnp.float32).at[0, 0].set(1.0)
        im = jnp.zeros((1, 2), jnp.float32)
        r, i = sv.pallas_apply_1q("ry", re, im, jnp.asarray([np.pi], np.float32), 0, nq)
        np.testing.assert_allclose(np.asarray(r)[0], [0.0, 1.0], atol=1e-6)
        np.testing.assert_allclose(np.asarray(i)[0], [0.0, 0.0], atol=1e-6)

    def test_rz_on_zero_is_global_phase(self):
        nq = 1
        re = jnp.zeros((1, 2), jnp.float32).at[0, 0].set(1.0)
        im = jnp.zeros((1, 2), jnp.float32)
        th = np.float32(1.1)
        r, i = sv.pallas_apply_1q("rz", re, im, jnp.asarray([th]), 0, nq)
        np.testing.assert_allclose(np.asarray(r)[0, 0], np.cos(th / 2), atol=1e-6)
        np.testing.assert_allclose(np.asarray(i)[0, 0], -np.sin(th / 2), atol=1e-6)

    def test_two_hadamards_identity(self):
        rng = np.random.default_rng(3)
        _, re, im = random_state(rng, 2, 4)
        r, i = sv.pallas_apply_h(re, im, 2, 4)
        r, i = sv.pallas_apply_h(r, i, 2, 4)
        np.testing.assert_allclose(np.asarray(r), np.asarray(re), atol=1e-5)
        np.testing.assert_allclose(np.asarray(i), np.asarray(im), atol=1e-5)

    @pytest.mark.parametrize("name", ["ryy", "rzz", "cry", "crz"])
    def test_zero_angle_is_identity(self, name):
        rng = np.random.default_rng(11)
        _, re, im = random_state(rng, 2, 4)
        zero = jnp.zeros((2,), jnp.float32)
        r, i = sv.pallas_apply_2q(name, re, im, zero, 1, 3, 4)
        np.testing.assert_allclose(np.asarray(r), np.asarray(re), atol=1e-6)
        np.testing.assert_allclose(np.asarray(i), np.asarray(im), atol=1e-6)
