"""AOT artifact structure: HLO text well-formedness, manifest, determinism."""

import json
import os

import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out))
    return str(out), manifest


class TestHloText:
    def test_entry_computation_present(self, built):
        out, manifest = built
        for meta in manifest["artifacts"]:
            text = open(os.path.join(out, meta["path"])).read()
            assert "ENTRY" in text, meta["name"]
            assert "HloModule" in text

    def test_io_shapes(self, built):
        out, manifest = built
        for meta in manifest["artifacts"]:
            text = open(os.path.join(out, meta["path"])).read()
            b, p, d = meta["batch"], meta["n_params"], meta["n_features"]
            assert f"f32[{b},{p}]" in text, f"{meta['name']}: thetas param shape"
            assert f"f32[{b},{d}]" in text, f"{meta['name']}: data param shape"
            # tuple-wrapped scalar-vector output
            assert f"(f32[{b}]" in text, f"{meta['name']}: output shape"

    def test_grad_artifact_shapes(self, built):
        out, manifest = built
        for meta in manifest["artifacts"]:
            text = open(os.path.join(out, meta["grad_path"])).read()
            p, d = meta["n_params"], meta["n_features"]
            gb = meta["grad_data_batch"]
            assert f"f32[{p}]" in text
            assert f"f32[{gb},{d}]" in text

    def test_no_custom_calls(self, built):
        """interpret=True must lower to plain HLO the CPU client can run."""
        out, manifest = built
        for meta in manifest["artifacts"]:
            text = open(os.path.join(out, meta["path"])).read()
            assert "custom-call" not in text.lower(), meta["name"]


class TestManifest:
    def test_covers_all_configs(self, built):
        _, manifest = built
        names = {m["name"] for m in manifest["artifacts"]}
        assert names == {f"quclassi_q{q}_l{l}" for q, l in model.CONFIGS}

    def test_counts_consistent(self, built):
        _, manifest = built
        for meta in manifest["artifacts"]:
            assert meta["n_params"] == ref.n_params(meta["qubits"], meta["layers"])
            assert meta["n_features"] == ref.n_features(meta["qubits"])

    def test_sha_matches_files(self, built):
        import hashlib

        out, manifest = built
        for meta in manifest["artifacts"]:
            text = open(os.path.join(out, meta["path"])).read()
            assert hashlib.sha256(text.encode()).hexdigest() == meta["sha256"]

    def test_manifest_json_round_trip(self, built):
        out, manifest = built
        on_disk = json.load(open(os.path.join(out, "manifest.json")))
        assert on_disk == manifest


class TestDeterminism:
    def test_lowering_is_deterministic(self):
        a = aot.lower_fidelity(5, 1)
        b = aot.lower_fidelity(5, 1)
        assert a == b
