"""L2 correctness: fused Pallas circuit vs oracle, gradients, invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels import statevector as sv

SETTINGS = dict(max_examples=15, deadline=None)


def _rand(rng, shape):
    return jnp.asarray(rng.uniform(-np.pi, np.pi, shape).astype(np.float32))


class TestFusedCircuit:
    @pytest.mark.parametrize("q,l", model.CONFIGS)
    def test_matches_oracle(self, q, l):
        rng = np.random.default_rng(q * 10 + l)
        p, d = ref.n_params(q, l), ref.n_features(q)
        th, da = _rand(rng, (16, p)), _rand(rng, (16, d))
        want = np.asarray(ref.fidelity_batch(th, da, q, l))
        got = np.asarray(sv.fused_fidelity(th, da, q, l))
        np.testing.assert_allclose(got, want, atol=1e-5)

    @pytest.mark.parametrize("q,l", model.CONFIGS)
    def test_blocked_grid_matches_single_block(self, q, l):
        """Grid over the batch must not change results at block seams."""
        rng = np.random.default_rng(q + l)
        p, d = ref.n_params(q, l), ref.n_features(q)
        th, da = _rand(rng, (32, p)), _rand(rng, (32, d))
        whole = np.asarray(sv.fused_fidelity(th, da, q, l, block=32))
        blocked = np.asarray(sv.fused_fidelity(th, da, q, l, block=8))
        np.testing.assert_allclose(blocked, whole, atol=1e-6)

    @settings(**SETTINGS)
    @given(
        st.sampled_from(model.CONFIGS),
        st.integers(min_value=0, max_value=2**31 - 1),
        st.sampled_from([1, 2, 4, 8]),
    )
    def test_hypothesis_sweep(self, cfg, seed, batch):
        q, l = cfg
        rng = np.random.default_rng(seed)
        p, d = ref.n_params(q, l), ref.n_features(q)
        th, da = _rand(rng, (batch, p)), _rand(rng, (batch, d))
        want = np.asarray(ref.fidelity_batch(th, da, q, l))
        got = np.asarray(sv.fused_fidelity(th, da, q, l))
        np.testing.assert_allclose(got, want, atol=1e-5)

    @settings(**SETTINGS)
    @given(st.sampled_from(model.CONFIGS), st.integers(min_value=0, max_value=2**31 - 1))
    def test_fidelity_in_unit_interval(self, cfg, seed):
        """Swap-test estimate = |<a|b>|^2 must lie in [0, 1]."""
        q, l = cfg
        rng = np.random.default_rng(seed)
        p, d = ref.n_params(q, l), ref.n_features(q)
        fid = np.asarray(sv.fused_fidelity(_rand(rng, (8, p)), _rand(rng, (8, d)), q, l))
        assert np.all(fid >= -1e-5) and np.all(fid <= 1.0 + 1e-5)

    @pytest.mark.parametrize("q", [5, 7])
    def test_layer1_self_fidelity_is_one(self, q):
        """With one layer, state prep == data encoding, so fid(x, x) = 1."""
        rng = np.random.default_rng(0)
        p = ref.n_params(q, 1)
        th = _rand(rng, (8, p))
        fid = np.asarray(sv.fused_fidelity(th, th, q, 1))
        np.testing.assert_allclose(fid, 1.0, atol=1e-5)

    @pytest.mark.parametrize("q", [5, 7])
    def test_layer1_symmetry(self, q):
        """fid(theta, x) == fid(x, theta) for the single-qubit-unitary layer."""
        rng = np.random.default_rng(1)
        p = ref.n_params(q, 1)
        a, b = _rand(rng, (8, p)), _rand(rng, (8, p))
        f_ab = np.asarray(sv.fused_fidelity(a, b, q, 1))
        f_ba = np.asarray(sv.fused_fidelity(b, a, q, 1))
        np.testing.assert_allclose(f_ab, f_ba, atol=1e-5)


class TestGradBank:
    @pytest.mark.parametrize("q,l", model.CONFIGS)
    def test_param_shift_matches_finite_difference(self, q, l):
        rng = np.random.default_rng(q * 7 + l)
        p, d = ref.n_params(q, l), ref.n_features(q)
        theta = _rand(rng, (p,))
        data = _rand(rng, (3, d))
        fid, grads = model.make_grad_bank_fn(q, l)(theta, data)
        # unshifted fidelity agrees with the oracle
        want = np.asarray(ref.fidelity_batch(jnp.tile(theta, (3, 1)), data, q, l))
        np.testing.assert_allclose(np.asarray(fid), want, atol=1e-5)
        eps = 1e-3
        for pi in range(p):
            tp, tm = theta.at[pi].add(eps), theta.at[pi].add(-eps)
            fd = (
                np.asarray(ref.fidelity_batch(jnp.tile(tp, (3, 1)), data, q, l))
                - np.asarray(ref.fidelity_batch(jnp.tile(tm, (3, 1)), data, q, l))
            ) / (2 * eps)
            np.testing.assert_allclose(np.asarray(grads)[:, pi], fd, atol=5e-3)

    def test_gradient_zero_at_optimum(self):
        """At fid = 1 (layer 1, theta == data) the gradient vanishes."""
        q = 5
        p = ref.n_params(q, 1)
        theta = jnp.asarray(np.linspace(0.1, 1.0, p), jnp.float32)
        data = jnp.tile(theta, (2, 1))
        fid, grads = model.make_grad_bank_fn(q, 1)(theta, data)
        np.testing.assert_allclose(np.asarray(fid), 1.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(grads), 0.0, atol=1e-4)


class TestConfigMeta:
    def test_param_counts_match_paper_structure(self):
        # S=2: layer1 -> 4, +layer2 -> +2, +layer3 -> +2
        assert ref.n_params(5, 1) == 4
        assert ref.n_params(5, 2) == 6
        assert ref.n_params(5, 3) == 8
        # S=3: layer1 -> 6, +layer2 -> +4, +layer3 -> +4
        assert ref.n_params(7, 1) == 6
        assert ref.n_params(7, 2) == 10
        assert ref.n_params(7, 3) == 14

    def test_feature_counts(self):
        assert ref.n_features(5) == 4
        assert ref.n_features(7) == 6

    def test_meta_record(self):
        m = model.config_meta(7, 3)
        assert m["name"] == "quclassi_q7_l3"
        assert m["n_params"] == 14 and m["n_features"] == 6
        assert m["batch"] == model.BATCH

    def test_layout(self):
        s, state_qs, data_qs = ref.quclassi_layout(5)
        assert s == 2 and state_qs == [1, 2] and data_qs == [3, 4]
        s, state_qs, data_qs = ref.quclassi_layout(7)
        assert s == 3 and state_qs == [1, 2, 3] and data_qs == [4, 5, 6]
