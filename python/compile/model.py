"""L2 — the QuClassi variational fidelity model (build-time JAX).

One jitted function per (qubits, layers) configuration:

    fidelity_batch(thetas: f32[B, P], data: f32[B, D]) -> (fid: f32[B],)

It is the *circuit-bank evaluator*: the Rust coordinator packs up to B
independent parameter-shift circuits (possibly from different clients —
this is what multi-tenant batching executes) into one call. The function
body delegates the statevector evolution to the fused L1 Pallas kernel.

A second entry point, ``grad_bank``, fuses the parameter-shift rule
on-device: given ONE parameter vector and a batch of data points it
evaluates the unshifted fidelity and all 2P shifted fidelities in a single
XLA program, returning fidelities and gradients. This is the L2
optimization documented in EXPERIMENTS.md §Perf (it removes the O(P)
host-side bank round-trips for the common "one theta, many data" case).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref
from .kernels import statevector as sv

# AOT batch size: the Rust runtime pads every bank to a multiple of this.
BATCH = 32

# The six paper configurations: qC in {5, 7} x nL in {1, 2, 3}.
CONFIGS = [(q, l) for q in (5, 7) for l in (1, 2, 3)]


def make_fidelity_fn(n_qubits: int, n_layers: int, use_pallas: bool = True, block=None):
    """Build the circuit-bank evaluator for one configuration.

    Returns ``fn(thetas[B, P], data[B, D]) -> (fid[B],)`` — a 1-tuple, the
    calling convention of the AOT artifact (``return_tuple=True``).
    """

    def fn(thetas, data):
        if use_pallas:
            fid = sv.fused_fidelity(thetas, data, n_qubits, n_layers, block=block)
        else:
            fid = ref.fidelity_batch(thetas, data, n_qubits, n_layers)
        return (fid,)

    return fn


def make_grad_bank_fn(n_qubits: int, n_layers: int, use_pallas: bool = True):
    """Build the fused parameter-shift gradient evaluator.

    ``fn(theta[P], data[B, D]) -> (fid[B], grads[B, P])``

    Internally expands to a bank of B * (4P + 1) circuits evaluated by the
    same fused kernel. Plain rotations (Ry/Rz/Ryy/Rzz, frequency gap 1)
    use the textbook two-term rule
    ``dfid/dθ = (fid(+π/2) − fid(−π/2)) / 2``; controlled rotations
    (CRY/CRZ, generator eigenvalues {0, ±1/2}) need the exact four-term
    rule ``c₊·[f(θ+π/2)−f(θ−π/2)] − c₋·[f(θ+3π/2)−f(θ−3π/2)]`` with
    ``c± = (√2 ± 1)/(4√2)``. The bank keeps a uniform 4P+1 layout (both
    shift families for every param) so shapes stay static; the per-param
    coefficients select the right rule.
    """
    n_p = ref.n_params(n_qubits, n_layers)
    ctrl = jnp.asarray(ref.controlled_param_mask(n_qubits, n_layers))
    sqrt2 = 2.0**0.5
    c_plus = jnp.where(ctrl, (sqrt2 + 1.0) / (4.0 * sqrt2), 0.5).astype(jnp.float32)
    c_minus = jnp.where(ctrl, (sqrt2 - 1.0) / (4.0 * sqrt2), 0.0).astype(jnp.float32)

    def fn(theta, data):
        b = data.shape[0]
        eye1 = jnp.eye(n_p, dtype=jnp.float32) * (jnp.pi / 2)
        eye3 = jnp.eye(n_p, dtype=jnp.float32) * (3 * jnp.pi / 2)
        # bank of parameter vectors: [4P + 1, P]
        bank = jnp.concatenate(
            [
                theta[None, :],
                theta[None, :] + eye1,
                theta[None, :] - eye1,
                theta[None, :] + eye3,
                theta[None, :] - eye3,
            ],
            axis=0,
        )
        k = bank.shape[0]  # 4P + 1
        # tile over data: every data point sees every shifted vector
        thetas = jnp.tile(bank, (b, 1))  # [B*(4P+1), P]
        datas = jnp.repeat(data, k, axis=0)  # [B*(4P+1), D]
        if use_pallas:
            # Single grid step (block = whole bank): the multi-step grid
            # lowers to an HLO while-loop that xla_extension 0.5.1
            # miscompiles for the q7/l3 shape (grads silently zero) —
            # one step sidesteps it and is faster anyway (DESIGN.md §9).
            fids = sv.fused_fidelity(thetas, datas, n_qubits, n_layers, block=b * k)
        else:
            fids = ref.fidelity_batch(thetas, datas, n_qubits, n_layers)
        fids = fids.reshape(b, k)
        fid0 = fids[:, 0]
        p1 = fids[:, 1 : 1 + n_p]
        m1 = fids[:, 1 + n_p : 1 + 2 * n_p]
        p3 = fids[:, 1 + 2 * n_p : 1 + 3 * n_p]
        m3 = fids[:, 1 + 3 * n_p :]
        grads = c_plus[None, :] * (p1 - m1) - c_minus[None, :] * (p3 - m3)
        return (fid0, grads)

    return fn


def config_meta(n_qubits: int, n_layers: int) -> dict:
    """Manifest record for one configuration (consumed by the Rust runtime)."""
    return {
        "name": f"quclassi_q{n_qubits}_l{n_layers}",
        "qubits": n_qubits,
        "layers": n_layers,
        "n_params": ref.n_params(n_qubits, n_layers),
        "n_features": ref.n_features(n_qubits),
        "batch": BATCH,
    }
