"""AOT lowering: JAX/Pallas model -> HLO text artifacts for the Rust runtime.

HLO *text* (NOT ``lowered.compile()`` / ``.serialize()``) is the
interchange format: jax >= 0.5 serializes HloModuleProto with 64-bit
instruction ids, which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Outputs, per (qubits, layers) configuration:

    artifacts/quclassi_q{q}_l{l}.hlo.txt      — circuit-bank evaluator
    artifacts/quclassi_q{q}_l{l}.grad.hlo.txt — fused param-shift gradient
    artifacts/manifest.json                   — machine-readable index

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

GRAD_DATA_BATCH = 8  # data points per fused-gradient call


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text.

    ``as_hlo_text(True)`` = print_large_constants: the default printer
    elides constants above ~10 elements as ``{...}``, which the text
    parser silently reads back as zeros (observed as all-zero gradients
    for the q7/l3 artifact, whose shift-coefficient vector has 14
    entries).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def lower_fidelity(n_qubits: int, n_layers: int) -> str:
    fn = model.make_fidelity_fn(n_qubits, n_layers, use_pallas=True)
    n_p = ref.n_params(n_qubits, n_layers)
    n_d = ref.n_features(n_qubits)
    thetas = jax.ShapeDtypeStruct((model.BATCH, n_p), jnp.float32)
    data = jax.ShapeDtypeStruct((model.BATCH, n_d), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(thetas, data))


def lower_grad_bank(n_qubits: int, n_layers: int) -> str:
    fn = model.make_grad_bank_fn(n_qubits, n_layers, use_pallas=True)
    n_p = ref.n_params(n_qubits, n_layers)
    n_d = ref.n_features(n_qubits)
    theta = jax.ShapeDtypeStruct((n_p,), jnp.float32)
    data = jax.ShapeDtypeStruct((GRAD_DATA_BATCH, n_d), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(theta, data))


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "batch": model.BATCH, "grad_data_batch": GRAD_DATA_BATCH,
                "artifacts": []}
    for q, l in model.CONFIGS:
        meta = model.config_meta(q, l)

        text = lower_fidelity(q, l)
        path = os.path.join(out_dir, meta["name"] + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["path"] = os.path.basename(path)
        meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()

        gtext = lower_grad_bank(q, l)
        gpath = os.path.join(out_dir, meta["name"] + ".grad.hlo.txt")
        with open(gpath, "w") as f:
            f.write(gtext)
        meta["grad_path"] = os.path.basename(gpath)
        meta["grad_data_batch"] = GRAD_DATA_BATCH
        meta["grad_sha256"] = hashlib.sha256(gtext.encode()).hexdigest()

        manifest["artifacts"].append(meta)
        print(f"lowered {meta['name']}: P={meta['n_params']} D={meta['n_features']} "
              f"fid={len(text)}B grad={len(gtext)}B")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} configs)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
