"""L1 — Pallas statevector kernels for the QuClassi circuit.

The compute hot-spot of DQuLearn is evaluating *banks* of parameter-shift
circuits: thousands of independent (theta, data) pairs pushed through the
same fixed gate sequence. We express that as ONE fused Pallas kernel per
(qubits, layers) configuration: a block of the batch is loaded into VMEM,
the entire circuit (data encoding -> variational layers -> swap test) is
applied while the statevector stays resident, and only the scalar fidelity
leaves the core. This mirrors what a threadblock-persistent CUDA kernel
would do on GPU (see DESIGN.md §4 Hardware adaptation):

  * BlockSpec blocks over the batch dimension — one block =
    ``block × 2 × 2**q × 4`` bytes of statevector (re/im planes), far under
    the ~16 MiB VMEM budget at q <= 7 (1 KiB per sample at q = 7).
  * Gate application is real arithmetic on (re, im) planes — rotations are
    2x2/4x4 contractions on the sublane axis, vectorized over lanes.
  * HBM traffic per circuit evaluation: read thetas + data, write fid —
    the O(2**q) state never round-trips.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO so the AOT artifact runs
on the Rust PJRT CPU client. Correctness is pinned against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

# ---------------------------------------------------------------------------
# real-arithmetic gate helpers on (re, im) planes of shape [B, 2**q]
#
# Each helper returns new (re, im). Angles are per-batch vectors [B].
# These run *inside* the Pallas kernel (and are unit-tested standalone
# through thin pallas_call wrappers below).
# ---------------------------------------------------------------------------


def _split1(re, im, qubit, nq):
    """Reshape planes to [B, left, 2, right] for a single-qubit target."""
    b = re.shape[0]
    left = 2**qubit
    return re.reshape(b, left, 2, -1), im.reshape(b, left, 2, -1)


def _bcast1(v):
    return v[:, None, None]


def ry(re, im, theta, qubit, nq):
    """Ry(theta) — real rotation, applied identically to both planes."""
    c, s = _bcast1(jnp.cos(theta / 2)), _bcast1(jnp.sin(theta / 2))
    r, i = _split1(re, im, qubit, nq)
    r0, r1 = r[:, :, 0, :], r[:, :, 1, :]
    i0, i1 = i[:, :, 0, :], i[:, :, 1, :]
    nr = jnp.stack([c * r0 - s * r1, s * r0 + c * r1], axis=2)
    ni = jnp.stack([c * i0 - s * i1, s * i0 + c * i1], axis=2)
    return nr.reshape(re.shape), ni.reshape(im.shape)


def rz(re, im, theta, qubit, nq):
    """Rz(theta) = diag(e^{-it/2}, e^{+it/2})."""
    c, s = _bcast1(jnp.cos(theta / 2)), _bcast1(jnp.sin(theta / 2))
    r, i = _split1(re, im, qubit, nq)
    r0, r1 = r[:, :, 0, :], r[:, :, 1, :]
    i0, i1 = i[:, :, 0, :], i[:, :, 1, :]
    # amplitude0 *= (c - i s); amplitude1 *= (c + i s)
    nr = jnp.stack([r0 * c + i0 * s, r1 * c - i1 * s], axis=2)
    ni = jnp.stack([i0 * c - r0 * s, i1 * c + r1 * s], axis=2)
    return nr.reshape(re.shape), ni.reshape(im.shape)


def hadamard(re, im, qubit, nq):
    inv = ref.INV_SQRT2
    r, i = _split1(re, im, qubit, nq)
    r0, r1 = r[:, :, 0, :], r[:, :, 1, :]
    i0, i1 = i[:, :, 0, :], i[:, :, 1, :]
    nr = jnp.stack([(r0 + r1) * inv, (r0 - r1) * inv], axis=2)
    ni = jnp.stack([(i0 + i1) * inv, (i0 - i1) * inv], axis=2)
    return nr.reshape(re.shape), ni.reshape(im.shape)


def _split2(re, im, q0, q1, nq):
    """Reshape planes to [B, a, 2, m, 2, r] for targets q0 < q1."""
    b = re.shape[0]
    a = 2**q0
    m = 2 ** (q1 - q0 - 1)
    return re.reshape(b, a, 2, m, 2, -1), im.reshape(b, a, 2, m, 2, -1)


def _bcast2(v):
    return v[:, None, None, None]


def _pack2(p00, p01, p10, p11, axis2=2, axis4=4):
    """Stack the four (q0,q1) components back into [B, a, 2, m, 2, r]."""
    c0 = jnp.stack([p00, p01], axis=3)  # -> [B, a, m, 2, r]
    c1 = jnp.stack([p10, p11], axis=3)
    return jnp.stack([c0, c1], axis=2)  # -> [B, a, 2, m, 2, r]


def ryy(re, im, theta, q0, q1, nq):
    """Ryy(theta) = cos(t/2) I - i sin(t/2) (Y⊗Y)."""
    c, s = _bcast2(jnp.cos(theta / 2)), _bcast2(jnp.sin(theta / 2))
    r, i = _split2(re, im, q0, q1, nq)
    r00, r01, r10, r11 = r[:, :, 0, :, 0], r[:, :, 0, :, 1], r[:, :, 1, :, 0], r[:, :, 1, :, 1]
    i00, i01, i10, i11 = i[:, :, 0, :, 0], i[:, :, 0, :, 1], i[:, :, 1, :, 0], i[:, :, 1, :, 1]
    # |00> <- c A00 + i s A11 ; |11> <- c A11 + i s A00
    # |01> <- c A01 - i s A10 ; |10> <- c A10 - i s A01
    nr00, ni00 = c * r00 - s * i11, c * i00 + s * r11
    nr11, ni11 = c * r11 - s * i00, c * i11 + s * r00
    nr01, ni01 = c * r01 + s * i10, c * i01 - s * r10
    nr10, ni10 = c * r10 + s * i01, c * i10 - s * r01
    nr = _pack2(nr00, nr01, nr10, nr11)
    ni = _pack2(ni00, ni01, ni10, ni11)
    return nr.reshape(re.shape), ni.reshape(im.shape)


def rzz(re, im, theta, q0, q1, nq):
    """Rzz(theta) = diag(e^{-it/2}, e^{+it/2}, e^{+it/2}, e^{-it/2})."""
    c, s = _bcast2(jnp.cos(theta / 2)), _bcast2(jnp.sin(theta / 2))
    r, i = _split2(re, im, q0, q1, nq)
    r00, r01, r10, r11 = r[:, :, 0, :, 0], r[:, :, 0, :, 1], r[:, :, 1, :, 0], r[:, :, 1, :, 1]
    i00, i01, i10, i11 = i[:, :, 0, :, 0], i[:, :, 0, :, 1], i[:, :, 1, :, 0], i[:, :, 1, :, 1]
    # parity 0 (00, 11): * (c - i s); parity 1 (01, 10): * (c + i s)
    nr00, ni00 = r00 * c + i00 * s, i00 * c - r00 * s
    nr11, ni11 = r11 * c + i11 * s, i11 * c - r11 * s
    nr01, ni01 = r01 * c - i01 * s, i01 * c + r01 * s
    nr10, ni10 = r10 * c - i10 * s, i10 * c + r10 * s
    nr = _pack2(nr00, nr01, nr10, nr11)
    ni = _pack2(ni00, ni01, ni10, ni11)
    return nr.reshape(re.shape), ni.reshape(im.shape)


def cry(re, im, theta, control, target, nq):
    """Controlled-Ry; control and target may be in either order."""
    q0, q1 = (control, target) if control < target else (target, control)
    ctrl_first = control < target
    c, s = _bcast2(jnp.cos(theta / 2)), _bcast2(jnp.sin(theta / 2))
    r, i = _split2(re, im, q0, q1, nq)
    r00, r01, r10, r11 = r[:, :, 0, :, 0], r[:, :, 0, :, 1], r[:, :, 1, :, 0], r[:, :, 1, :, 1]
    i00, i01, i10, i11 = i[:, :, 0, :, 0], i[:, :, 0, :, 1], i[:, :, 1, :, 0], i[:, :, 1, :, 1]
    if ctrl_first:
        # control = q0 bit: rotate (A10, A11)
        nr10, nr11 = c * r10 - s * r11, s * r10 + c * r11
        ni10, ni11 = c * i10 - s * i11, s * i10 + c * i11
        nr00, nr01, ni00, ni01 = r00, r01, i00, i01
    else:
        # control = q1 bit: rotate (A01, A11)
        nr01, nr11 = c * r01 - s * r11, s * r01 + c * r11
        ni01, ni11 = c * i01 - s * i11, s * i01 + c * i11
        nr00, nr10, ni00, ni10 = r00, r10, i00, i10
    nr = _pack2(nr00, nr01, nr10, nr11)
    ni = _pack2(ni00, ni01, ni10, ni11)
    return nr.reshape(re.shape), ni.reshape(im.shape)


def crz(re, im, theta, control, target, nq):
    """Controlled-Rz; control and target may be in either order."""
    q0, q1 = (control, target) if control < target else (target, control)
    ctrl_first = control < target
    c, s = _bcast2(jnp.cos(theta / 2)), _bcast2(jnp.sin(theta / 2))
    r, i = _split2(re, im, q0, q1, nq)
    r00, r01, r10, r11 = r[:, :, 0, :, 0], r[:, :, 0, :, 1], r[:, :, 1, :, 0], r[:, :, 1, :, 1]
    i00, i01, i10, i11 = i[:, :, 0, :, 0], i[:, :, 0, :, 1], i[:, :, 1, :, 0], i[:, :, 1, :, 1]
    if ctrl_first:
        # target-bit 0 of controlled subspace (A10): * (c - i s); A11: * (c + i s)
        nr10, ni10 = r10 * c + i10 * s, i10 * c - r10 * s
        nr11, ni11 = r11 * c - i11 * s, i11 * c + r11 * s
        nr00, nr01, ni00, ni01 = r00, r01, i00, i01
    else:
        nr01, ni01 = r01 * c + i01 * s, i01 * c - r01 * s
        nr11, ni11 = r11 * c - i11 * s, i11 * c + r11 * s
        nr00, nr10, ni00, ni10 = r00, r10, i00, i10
    nr = _pack2(nr00, nr01, nr10, nr11)
    ni = _pack2(ni00, ni01, ni10, ni11)
    return nr.reshape(re.shape), ni.reshape(im.shape)


def cswap(re, im, control, a, b, nq):
    """Fredkin gate with the ancilla (qubit 0) as control.

    Because qubit 0 is the most significant index bit, the controlled
    subspace is the contiguous upper half of the amplitude vector; the
    swap of qubits (a, b) inside it is a pure axis transpose — no gather,
    no captured constants, Pallas-friendly.
    """
    assert control == 0 and 1 <= a < b < nq, "cswap expects ancilla control"
    bsz = re.shape[0]
    am = 2 ** (a - 1)  # qubits strictly between control and a
    m = 2 ** (b - a - 1)

    def half_swap(x):
        x2 = x.reshape(bsz, 2, -1)
        lo, hi = x2[:, 0, :], x2[:, 1, :]
        hi = (
            hi.reshape(bsz, am, 2, m, 2, -1)
            .transpose(0, 1, 4, 3, 2, 5)
            .reshape(bsz, -1)
        )
        return jnp.stack([lo, hi], axis=1).reshape(x.shape)

    return half_swap(re), half_swap(im)


def prob0(re, im, nq):
    """P(qubit 0 = |0>): sum |amp|^2 over the low half of the index space."""
    b = re.shape[0]
    half = 2 ** (nq - 1)
    r = re.reshape(b, 2, half)[:, 0, :]
    i = im.reshape(b, 2, half)[:, 0, :]
    return jnp.sum(r * r + i * i, axis=-1)


# ---------------------------------------------------------------------------
# the full QuClassi circuit on (re, im) planes — shared by the fused
# Pallas kernel and by direct (non-pallas) evaluation in tests
# ---------------------------------------------------------------------------


def circuit_planes(thetas, data, n_qubits: int, n_layers: int):
    """Apply the full QuClassi circuit; returns fidelity f32[B].

    thetas: f32[B, P], data: f32[B, D]. Pure real arithmetic on planes.
    """
    b = thetas.shape[0]
    n = 2**n_qubits
    s, state_qs, data_qs = ref.quclassi_layout(n_qubits)

    re = jnp.zeros((b, n), dtype=jnp.float32).at[:, 0].set(1.0)
    im = jnp.zeros((b, n), dtype=jnp.float32)

    for i, q in enumerate(data_qs):
        re, im = ry(re, im, data[:, 2 * i], q, n_qubits)
        re, im = rz(re, im, data[:, 2 * i + 1], q, n_qubits)

    p = 0
    for q in state_qs:
        re, im = ry(re, im, thetas[:, p], q, n_qubits)
        re, im = rz(re, im, thetas[:, p + 1], q, n_qubits)
        p += 2
    if n_layers >= 2:
        for i in range(s - 1):
            q0, q1 = state_qs[i], state_qs[i + 1]
            re, im = ryy(re, im, thetas[:, p], q0, q1, n_qubits)
            re, im = rzz(re, im, thetas[:, p + 1], q0, q1, n_qubits)
            p += 2
    if n_layers >= 3:
        for i in range(s - 1):
            q0, q1 = state_qs[i], state_qs[i + 1]
            re, im = cry(re, im, thetas[:, p], q0, q1, n_qubits)
            re, im = crz(re, im, thetas[:, p + 1], q0, q1, n_qubits)
            p += 2

    re, im = hadamard(re, im, 0, n_qubits)
    for sq, dq in zip(state_qs, data_qs):
        re, im = cswap(re, im, 0, sq, dq, n_qubits)
    re, im = hadamard(re, im, 0, n_qubits)
    return 2.0 * prob0(re, im, n_qubits) - 1.0


# ---------------------------------------------------------------------------
# fused Pallas kernel: whole circuit bank, blocked over the batch
# ---------------------------------------------------------------------------


def fused_fidelity(thetas, data, n_qubits: int, n_layers: int, block: int | None = None):
    """Evaluate the circuit bank with the fused Pallas kernel.

    thetas f32[B, P], data f32[B, D] -> fid f32[B]. ``B`` must be a
    multiple of ``block`` (default: min(B, 128)).
    """
    bsz, n_p = thetas.shape
    n_d = data.shape[1]
    if block is None:
        block = min(bsz, 128)
    assert bsz % block == 0, f"batch {bsz} not divisible by block {block}"

    def kernel(thetas_ref, data_ref, fid_ref):
        fid_ref[...] = circuit_planes(thetas_ref[...], data_ref[...], n_qubits, n_layers)

    grid = (bsz // block,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, n_p), lambda i: (i, 0)),
            pl.BlockSpec((block, n_d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
        interpret=True,
    )(thetas, data)


# ---------------------------------------------------------------------------
# standalone single-gate Pallas kernels (unit-test surface for the helpers)
# ---------------------------------------------------------------------------

_GATE_1Q = {"ry": ry, "rz": rz}
_GATE_2Q = {"ryy": ryy, "rzz": rzz, "cry": cry, "crz": crz}


def pallas_apply_1q(name: str, re, im, theta, qubit: int, n_qubits: int):
    """Apply a named single-qubit rotation as its own Pallas kernel."""
    fn = _GATE_1Q[name]

    def kernel(re_ref, im_ref, th_ref, ore_ref, oim_ref):
        nr, ni = fn(re_ref[...], im_ref[...], th_ref[...], qubit, n_qubits)
        ore_ref[...] = nr
        oim_ref[...] = ni

    shape = jax.ShapeDtypeStruct(re.shape, jnp.float32)
    return pl.pallas_call(kernel, out_shape=(shape, shape), interpret=True)(re, im, theta)


def pallas_apply_2q(name: str, re, im, theta, q0: int, q1: int, n_qubits: int):
    """Apply a named two-qubit rotation as its own Pallas kernel."""
    fn = _GATE_2Q[name]

    def kernel(re_ref, im_ref, th_ref, ore_ref, oim_ref):
        nr, ni = fn(re_ref[...], im_ref[...], th_ref[...], q0, q1, n_qubits)
        ore_ref[...] = nr
        oim_ref[...] = ni

    shape = jax.ShapeDtypeStruct(re.shape, jnp.float32)
    return pl.pallas_call(kernel, out_shape=(shape, shape), interpret=True)(re, im, theta)


def pallas_apply_h(re, im, qubit: int, n_qubits: int):
    def kernel(re_ref, im_ref, ore_ref, oim_ref):
        nr, ni = hadamard(re_ref[...], im_ref[...], qubit, n_qubits)
        ore_ref[...] = nr
        oim_ref[...] = ni

    shape = jax.ShapeDtypeStruct(re.shape, jnp.float32)
    return pl.pallas_call(kernel, out_shape=(shape, shape), interpret=True)(re, im)


def pallas_apply_cswap(re, im, control: int, a: int, b: int, n_qubits: int):
    def kernel(re_ref, im_ref, ore_ref, oim_ref):
        nr, ni = cswap(re_ref[...], im_ref[...], control, a, b, n_qubits)
        ore_ref[...] = nr
        oim_ref[...] = ni

    shape = jax.ShapeDtypeStruct(re.shape, jnp.float32)
    return pl.pallas_call(kernel, out_shape=(shape, shape), interpret=True)(re, im)
