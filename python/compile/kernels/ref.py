"""Pure-jnp batched statevector oracle.

This module is the correctness reference (the "oracle") for the Pallas
kernels in ``statevector.py`` and for the Rust ``qsim`` simulator. It is
deliberately written in the most transparent style possible: complex64
statevectors of shape ``[B, 2**q]`` and explicit einsum contractions.

Qubit convention (shared by every layer of the stack, including Rust):
**big-endian** — qubit 0 is the most significant bit of the state index.
The amplitude index of basis state ``|b_0 b_1 ... b_{q-1}>`` is
``sum_k b_k * 2**(q-1-k)``.

QuClassi register layout for a ``q``-qubit configuration (q odd):

    qubit 0                  : ancilla (swap test)
    qubits 1 .. S            : variational "class state" register
    qubits S+1 .. 2S         : data register
    with S = (q - 1) // 2

All gates accept *batched* angles ``theta: f32[B]`` so that a whole
parameter-shift circuit bank evaluates in a single call.
"""

from __future__ import annotations

import jax.numpy as jnp

INV_SQRT2 = 0.7071067811865476


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------


def zero_state(batch: int, n_qubits: int) -> jnp.ndarray:
    """|0...0> for every batch element: complex64[B, 2**q]."""
    n = 2**n_qubits
    state = jnp.zeros((batch, n), dtype=jnp.complex64)
    return state.at[:, 0].set(1.0 + 0.0j)


# ---------------------------------------------------------------------------
# generic gate application
# ---------------------------------------------------------------------------


def apply_1q(state: jnp.ndarray, gate: jnp.ndarray, qubit: int, n_qubits: int) -> jnp.ndarray:
    """Apply a (possibly batched) single-qubit gate.

    ``gate`` is ``complex[2, 2]`` or ``complex[B, 2, 2]``.
    """
    b = state.shape[0]
    left = 2**qubit
    st = state.reshape(b, left, 2, -1)
    if gate.ndim == 2:
        out = jnp.einsum("ij,bljr->blir", gate, st)
    else:
        out = jnp.einsum("bij,bljr->blir", gate, st)
    return out.reshape(b, 2**n_qubits)


def apply_2q(
    state: jnp.ndarray, gate: jnp.ndarray, q0: int, q1: int, n_qubits: int
) -> jnp.ndarray:
    """Apply a (possibly batched) two-qubit gate to qubits (q0, q1), q0 < q1.

    ``gate`` is ``complex[4, 4]`` or ``complex[B, 4, 4]`` acting on the
    ordered pair (q0, q1): row/col index = 2*b(q0) + b(q1).
    """
    assert q0 < q1, "apply_2q expects q0 < q1"
    b = state.shape[0]
    a = 2**q0
    m = 2 ** (q1 - q0 - 1)
    st = state.reshape(b, a, 2, m, 2, -1)
    g = gate.reshape(*gate.shape[:-2], 2, 2, 2, 2)  # [.., i0, i1, j0, j1]
    if gate.ndim == 2:
        out = jnp.einsum("ikjl,bajmlr->baimkr", g, st)
    else:
        out = jnp.einsum("bikjl,bajmlr->baimkr", g, st)
    return out.reshape(b, 2**n_qubits)


# ---------------------------------------------------------------------------
# concrete gates (batched angles)
# ---------------------------------------------------------------------------


def _c(x):
    return x.astype(jnp.complex64)


def ry_matrix(theta: jnp.ndarray) -> jnp.ndarray:
    """Ry(theta): f32[B] -> complex64[B, 2, 2]."""
    c = jnp.cos(theta / 2)
    s = jnp.sin(theta / 2)
    row0 = jnp.stack([c, -s], axis=-1)
    row1 = jnp.stack([s, c], axis=-1)
    return _c(jnp.stack([row0, row1], axis=-2))


def rz_matrix(theta: jnp.ndarray) -> jnp.ndarray:
    """Rz(theta) = diag(e^{-i t/2}, e^{i t/2})."""
    half = theta / 2
    e_m = jnp.cos(half) - 1j * jnp.sin(half)
    e_p = jnp.cos(half) + 1j * jnp.sin(half)
    z = jnp.zeros_like(e_m)
    row0 = jnp.stack([e_m, z], axis=-1)
    row1 = jnp.stack([z, e_p], axis=-1)
    return jnp.stack([row0, row1], axis=-2).astype(jnp.complex64)


def ryy_matrix(theta: jnp.ndarray) -> jnp.ndarray:
    """Ryy(theta) = exp(-i theta/2 Y(x)Y)."""
    c = _c(jnp.cos(theta / 2))
    is_ = 1j * jnp.sin(theta / 2).astype(jnp.complex64)
    z = jnp.zeros_like(c)
    rows = [
        jnp.stack([c, z, z, is_], axis=-1),
        jnp.stack([z, c, -is_, z], axis=-1),
        jnp.stack([z, -is_, c, z], axis=-1),
        jnp.stack([is_, z, z, c], axis=-1),
    ]
    return jnp.stack(rows, axis=-2)


def rzz_matrix(theta: jnp.ndarray) -> jnp.ndarray:
    """Rzz(theta) = diag(e^{-it/2}, e^{it/2}, e^{it/2}, e^{-it/2})."""
    half = theta / 2
    e_m = jnp.cos(half) - 1j * jnp.sin(half)
    e_p = jnp.cos(half) + 1j * jnp.sin(half)
    z = jnp.zeros_like(e_m)
    rows = [
        jnp.stack([e_m, z, z, z], axis=-1),
        jnp.stack([z, e_p, z, z], axis=-1),
        jnp.stack([z, z, e_p, z], axis=-1),
        jnp.stack([z, z, z, e_m], axis=-1),
    ]
    return jnp.stack(rows, axis=-2).astype(jnp.complex64)


def cry_matrix(theta: jnp.ndarray) -> jnp.ndarray:
    """CRY: control = first qubit of the pair."""
    c = _c(jnp.cos(theta / 2))
    s = _c(jnp.sin(theta / 2))
    one = jnp.ones_like(c)
    z = jnp.zeros_like(c)
    rows = [
        jnp.stack([one, z, z, z], axis=-1),
        jnp.stack([z, one, z, z], axis=-1),
        jnp.stack([z, z, c, -s], axis=-1),
        jnp.stack([z, z, s, c], axis=-1),
    ]
    return jnp.stack(rows, axis=-2)


def crz_matrix(theta: jnp.ndarray) -> jnp.ndarray:
    """CRZ: control = first qubit of the pair."""
    half = theta / 2
    e_m = jnp.cos(half) - 1j * jnp.sin(half)
    e_p = jnp.cos(half) + 1j * jnp.sin(half)
    one = jnp.ones_like(e_m)
    z = jnp.zeros_like(e_m)
    rows = [
        jnp.stack([one, z, z, z], axis=-1),
        jnp.stack([z, one, z, z], axis=-1),
        jnp.stack([z, z, e_m, z], axis=-1),
        jnp.stack([z, z, z, e_p], axis=-1),
    ]
    return jnp.stack(rows, axis=-2).astype(jnp.complex64)


H_MATRIX = jnp.array(
    [[INV_SQRT2, INV_SQRT2], [INV_SQRT2, -INV_SQRT2]], dtype=jnp.complex64
)


def apply_h(state: jnp.ndarray, qubit: int, n_qubits: int) -> jnp.ndarray:
    return apply_1q(state, H_MATRIX, qubit, n_qubits)


def apply_ry(state, theta, qubit, n_qubits):
    return apply_1q(state, ry_matrix(theta), qubit, n_qubits)


def apply_rz(state, theta, qubit, n_qubits):
    return apply_1q(state, rz_matrix(theta), qubit, n_qubits)


def apply_ryy(state, theta, q0, q1, n_qubits):
    return apply_2q(state, ryy_matrix(theta), q0, q1, n_qubits)


def apply_rzz(state, theta, q0, q1, n_qubits):
    return apply_2q(state, rzz_matrix(theta), q0, q1, n_qubits)


def _swap_pair_order(g: jnp.ndarray) -> jnp.ndarray:
    """Reorder a 4x4 two-qubit gate from pair (a, b) to pair (b, a)."""
    perm = jnp.array([0, 2, 1, 3])
    return g[..., perm, :][..., :, perm]


def apply_cry(state, theta, control, target, n_qubits):
    if control < target:
        return apply_2q(state, cry_matrix(theta), control, target, n_qubits)
    return apply_2q(state, _swap_pair_order(cry_matrix(theta)), target, control, n_qubits)


def apply_crz(state, theta, control, target, n_qubits):
    if control < target:
        return apply_2q(state, crz_matrix(theta), control, target, n_qubits)
    return apply_2q(state, _swap_pair_order(crz_matrix(theta)), target, control, n_qubits)


def apply_cswap(state: jnp.ndarray, control: int, a: int, b: int, n_qubits: int) -> jnp.ndarray:
    """Fredkin gate: swap qubits (a, b) where ``control`` is |1>.

    Implemented as an amplitude-index permutation — exact and cheap.
    """
    bsz = state.shape[0]
    n = 2**n_qubits
    idx = jnp.arange(n)
    cb = n_qubits - 1 - control
    ab = n_qubits - 1 - a
    bb = n_qubits - 1 - b
    c_set = (idx >> cb) & 1
    bit_a = (idx >> ab) & 1
    bit_b = (idx >> bb) & 1
    swapped = idx ^ ((bit_a ^ bit_b) * ((1 << ab) | (1 << bb)))
    src = jnp.where(c_set == 1, swapped, idx)
    return state[:, src].reshape(bsz, n)


def prob_qubit0_zero(state: jnp.ndarray, n_qubits: int) -> jnp.ndarray:
    """P(qubit 0 = |0>) per batch element."""
    b = state.shape[0]
    st = state.reshape(b, 2, 2 ** (n_qubits - 1))
    return jnp.sum(jnp.abs(st[:, 0, :]) ** 2, axis=-1)


# ---------------------------------------------------------------------------
# QuClassi circuit (reference implementation of the L2 model)
# ---------------------------------------------------------------------------


def quclassi_layout(n_qubits: int):
    """Return (S, state_qubits, data_qubits) for the register layout."""
    assert n_qubits % 2 == 1 and n_qubits >= 3, "need odd qubit count >= 3"
    s = (n_qubits - 1) // 2
    return s, list(range(1, s + 1)), list(range(s + 1, 2 * s + 1))


def n_params(n_qubits: int, n_layers: int) -> int:
    """Trainable parameter count for a (q, l) configuration."""
    s = (n_qubits - 1) // 2
    total = 2 * s  # layer 1: Ry + Rz on each state qubit
    if n_layers >= 2:
        total += 2 * (s - 1)  # Ryy + Rzz on adjacent pairs
    if n_layers >= 3:
        total += 2 * (s - 1)  # CRY + CRZ on adjacent pairs
    return total


def n_features(n_qubits: int) -> int:
    """Classical features consumed by the data encoder (2 per data qubit)."""
    return n_qubits - 1  # == 2 * S


def controlled_param_mask(n_qubits: int, n_layers: int):
    """Boolean mask over the parameter vector: True for CRY/CRZ params.

    Controlled rotations have generator eigenvalues {0, ±1/2} (frequency
    gaps 1/2 AND 1), so the two-term ±π/2 parameter-shift rule is *biased*
    for them; the exact gradient needs the four-term rule
    ``c+·[f(θ+π/2)−f(θ−π/2)] − c−·[f(θ+3π/2)−f(θ−3π/2)]`` with
    ``c± = (√2 ± 1)/(4√2)``. Plain rotations (Ry/Rz/Ryy/Rzz) have gap 1
    only and keep the textbook two-term rule.
    """
    s = (n_qubits - 1) // 2
    mask = [False] * n_params(n_qubits, n_layers)
    if n_layers >= 3:
        for k in range(2 * (s - 1)):
            mask[2 * s + 2 * (s - 1) + k] = True
    return mask


def fidelity_batch(thetas: jnp.ndarray, data: jnp.ndarray, n_qubits: int, n_layers: int):
    """Reference QuClassi swap-test fidelity.

    thetas: f32[B, P]   (P = n_params(q, l))
    data:   f32[B, D]   (D = n_features(q) — encoder angles)
    returns f32[B]      fidelity estimate = 2*P(anc=0) - 1
    """
    b = thetas.shape[0]
    s, state_qs, data_qs = quclassi_layout(n_qubits)
    st = zero_state(b, n_qubits)

    # --- data encoding: Ry(x_{2i}) Rz(x_{2i+1}) on data qubit i ---
    for i, q in enumerate(data_qs):
        st = apply_ry(st, data[:, 2 * i], q, n_qubits)
        st = apply_rz(st, data[:, 2 * i + 1], q, n_qubits)

    # --- variational layers on the state register ---
    p = 0
    for q in state_qs:  # layer 1: single-qubit unitary
        st = apply_ry(st, thetas[:, p], q, n_qubits)
        st = apply_rz(st, thetas[:, p + 1], q, n_qubits)
        p += 2
    if n_layers >= 2:  # layer 2: dual-qubit unitary
        for i in range(s - 1):
            q0, q1 = state_qs[i], state_qs[i + 1]
            st = apply_ryy(st, thetas[:, p], q0, q1, n_qubits)
            st = apply_rzz(st, thetas[:, p + 1], q0, q1, n_qubits)
            p += 2
    if n_layers >= 3:  # layer 3: entanglement unitary
        for i in range(s - 1):
            q0, q1 = state_qs[i], state_qs[i + 1]
            st = apply_cry(st, thetas[:, p], q0, q1, n_qubits)
            st = apply_crz(st, thetas[:, p + 1], q0, q1, n_qubits)
            p += 2
    assert p == n_params(n_qubits, n_layers)

    # --- swap test ---
    st = apply_h(st, 0, n_qubits)
    for sq, dq in zip(state_qs, data_qs):
        st = apply_cswap(st, 0, sq, dq, n_qubits)
    st = apply_h(st, 0, n_qubits)
    p0 = prob_qubit0_zero(st, n_qubits)
    return 2.0 * p0 - 1.0
