//! Regenerates **Figure 3** (paper §IV-C1): one client training a
//! 5-qubit QuClassi on IBM-Q cloud backends (uncontrolled environment),
//! sweeping 1/2/3 variational layers × 1/2/4 workers. Prints runtime per
//! epoch (Fig 3a) and circuits per second (Fig 3b), side by side with
//! the paper's reported values and normalized speedups.
//!
//! ```bash
//! cargo bench --bench fig3_ibmq_5q
//! ```

mod fig_common;

use dqulearn::env::scenarios::ibmq_figure;
use dqulearn::env::Calibration;
use fig_common::{assert_trends, render_comparison, PaperPoint};

/// Paper Fig. 3 values (read from §IV-C1's prose).
const PAPER: &[PaperPoint] = &[
    (1, 1, Some(94.7), Some(15.2)),
    (1, 2, None, Some(16.9)),
    (1, 4, Some(73.1), Some(19.7)),
    (2, 1, Some(467.9), Some(6.2)),
    (2, 2, None, Some(6.4)),
    (2, 4, Some(418.6), Some(6.6)),
    (3, 1, Some(749.8), Some(5.9)),
    (3, 2, Some(651.7), Some(6.6)),
    (3, 4, Some(569.8), Some(7.6)),
];

fn main() {
    let calib = Calibration::qiskit_like();
    let rows = ibmq_figure(5, &calib, 7);
    println!(
        "{}",
        render_comparison(
            "Figure 3: 5-qubit IBM-Q backends, uncontrolled environment (DES)",
            &rows,
            PAPER
        )
    );
    assert_trends(&rows);
    println!("trend check passed: more workers -> lower runtime, higher circuits/sec\n");

    // Variance across seeds (the environment is 'uncontrolled'): report
    // the spread the jitter model produces for the densest point.
    let spreads: Vec<f64> = (0..5)
        .map(|s| {
            ibmq_figure(5, &calib, 100 + s)
                .iter()
                .find(|r| r.layers == 3 && r.workers == 4)
                .unwrap()
                .runtime
        })
        .collect();
    let mean = spreads.iter().sum::<f64>() / spreads.len() as f64;
    let max_dev = spreads.iter().map(|x| (x - mean).abs()).fold(0.0, f64::max);
    println!(
        "uncontrolled-variance check (3L/4W, 5 seeds): mean {:.1}s, max dev {:.1}s ({:.1}%)",
        mean,
        max_dev,
        100.0 * max_dev / mean
    );
}
