//! Regenerates the paper's **accuracy comparison** (§IV-B): QuClassi
//! classification accuracy on the four MNIST pairs, distributed
//! (2 workers) vs non-distributed, with the paper's reported accuracies
//! alongside. The paper's claim is a delta under 2%; in this stack the
//! distributed execution is bitwise-identical to local execution, so the
//! delta is exactly 0 when seeds match (asserted), and we also report a
//! cross-seed run where only the *model init* differs.
//!
//! ```bash
//! cargo bench --bench accuracy_table
//! ```

use dqulearn::benchlib::Table;
use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::InProcCluster;
use dqulearn::data::Dataset;
use dqulearn::model::exec::QsimExecutor;
use dqulearn::model::optimizer::Optimizer;
use dqulearn::model::quclassi::LossKind;
use dqulearn::model::{QuClassiModel, TrainConfig, Trainer};
use dqulearn::util::Rng;

const PAPER: &[((u8, u8), f64)] =
    &[((3, 9), 97.5), ((3, 8), 96.2), ((3, 6), 98.1), ((1, 5), 98.6)];

fn train_once(
    pair: (u8, u8),
    distributed: bool,
    model_seed: u64,
) -> Result<f64, String> {
    let config = QuClassiConfig::new(5, 1)?;
    let dataset = Dataset::binary_pair(None, pair.0, pair.1, 24, 42);
    let tc = TrainConfig {
        epochs: 14,
        optimizer: Optimizer::adam(0.05),
        train_classical: true,
        classical_lr_scale: 0.1,
        seed: 7,
        early_stop_acc: None,
        loss: LossKind::Discriminative,
    };
    let mut model = QuClassiModel::new(config, &mut Rng::new(model_seed));
    let report = if distributed {
        let cluster = InProcCluster::builder().workers(&[5, 5]).build()?;
        let r = Trainer::new(tc).train(&mut model, &dataset, &cluster)?;
        cluster.shutdown();
        r
    } else {
        Trainer::new(tc).train(&mut model, &dataset, &QsimExecutor)?
    };
    Ok(report.test_accuracy * 100.0)
}

fn main() {
    println!("== Accuracy comparison (paper §IV-B): distributed vs non-distributed ==");
    let mut table = Table::new(&[
        "pair", "distributed %", "baseline %", "delta %", "paper dist. %", "cross-seed dist. %",
    ]);
    for &((a, b), paper_acc) in PAPER {
        let dist = train_once((a, b), true, 21).expect("distributed run");
        let base = train_once((a, b), false, 21).expect("baseline run");
        // same data/trainer seeds, different model init — the residual
        // variation a real redeployment would see
        let cross = train_once((a, b), true, 77).expect("cross-seed run");
        let delta = (dist - base).abs();
        table.row(&[
            format!("{a}/{b}"),
            format!("{dist:.1}"),
            format!("{base:.1}"),
            format!("{delta:.2}"),
            format!("{paper_acc:.1}"),
            format!("{cross:.1}"),
        ]);
        assert!(delta < 2.0, "pair {a}/{b}: delta {delta:.2}% exceeds the paper's 2% bound");
        assert!(dist >= 75.0, "pair {a}/{b}: distributed accuracy {dist:.1}% too low to be credible");
    }
    print!("{}", table.render());
    println!("\nall pairs: |distributed - baseline| < 2% (paper's claim), high absolute accuracy");
    println!("(absolute accuracies differ from the paper's: synthetic MNIST stand-in, 24 samples/class — see DESIGN.md §3)");
}
