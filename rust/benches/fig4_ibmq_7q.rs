//! Regenerates **Figure 4** (paper §IV-C1): the 7-qubit IBM-Q
//! (uncontrolled) experiments — same sweep as Figure 3 at the wider
//! circuit configuration (2016/4032/6048 circuits per epoch).
//!
//! ```bash
//! cargo bench --bench fig4_ibmq_7q
//! ```

mod fig_common;

use dqulearn::env::scenarios::ibmq_figure;
use dqulearn::env::Calibration;
use fig_common::{assert_trends, render_comparison, PaperPoint};

/// Paper Fig. 4 values (§IV-C1 prose).
const PAPER: &[PaperPoint] = &[
    (1, 1, Some(163.0), Some(12.4)),
    (1, 2, None, Some(13.5)),
    (1, 4, Some(134.3), Some(15.0)),
    (2, 1, Some(566.5), Some(7.1)),
    (2, 2, None, Some(7.2)),
    (2, 4, Some(510.8), Some(7.9)),
    (3, 1, Some(1366.1), Some(4.4)),
    (3, 2, Some(1303.9), Some(4.6)),
    (3, 4, Some(1246.5), Some(4.8)),
];

fn main() {
    let calib = Calibration::qiskit_like();
    let rows = ibmq_figure(7, &calib, 11);
    println!(
        "{}",
        render_comparison(
            "Figure 4: 7-qubit IBM-Q backends, uncontrolled environment (DES)",
            &rows,
            PAPER
        )
    );
    assert_trends(&rows);
    println!("trend check passed: more workers -> lower runtime, higher circuits/sec\n");

    // Cross-figure check the paper highlights: 7-qubit circuits are
    // slower per circuit than 5-qubit ones at equal depth.
    let five = ibmq_figure(5, &calib, 11);
    for layers in [1usize, 2, 3] {
        let cps5 = five.iter().find(|r| r.layers == layers && r.workers == 1).unwrap().cps;
        let cps7 = rows.iter().find(|r| r.layers == layers && r.workers == 1).unwrap().cps;
        assert!(
            cps7 < cps5,
            "layers {layers}: 7q should be slower per circuit than 5q ({cps7} !< {cps5})"
        );
        println!("width check L{layers}: 5Q {cps5:.2} c/s vs 7Q {cps7:.2} c/s ✓");
    }
}
