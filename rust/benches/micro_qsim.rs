//! Microbenchmarks for the Rust statevector simulator and the
//! compiled-circuit pipeline (DESIGN.md §15), plus the qsim perf gate.
//!
//! Series:
//!
//! * single-gate kernels across register widths, including the blocked
//!   vs masked `apply_2q` ablation (the cache-blocked kernel rewrite);
//! * per paper config, the four circuit paths: **seed**
//!   (`simulate_fidelity`: gate-list build + serial walk), **fused**
//!   (per-circuit pairwise fusion), **compiled cold** (template build +
//!   plan + bind each iteration) and **compiled cached** (plan reused,
//!   parameters rebound into a reused bound program, scratch state reset
//!   — the executor hot loop);
//! * the 3-qubit-block ablation (`max_block` 1/2/3) on q7 l3;
//! * one-off costs (fusion pass, plan compile, gate-list build);
//! * the shot-pool scaling table (DESIGN.md §11).
//!
//! Results are serialized via `wire/json` to `BENCH_qsim.json` (override
//! with `DQ_BENCH_OUT`). Two gates fail the run:
//!
//! * compiled+cached throughput below **2x** the seed path on the
//!   largest paper config (q7 l3) — the plan-cache speedup claim;
//! * any config's compiled+cached circuits/sec below **half** the floor
//!   recorded under `qsim.circuits` in the committed baseline
//!   (`DQ_BENCH_BASELINE`, default `../bench/baseline.json`) — the same
//!   >2x-regression rule as `bench_coordinator_scale`.
//!
//! ```bash
//! cargo bench --bench micro_qsim
//! DQ_BENCH_FAST=1 cargo bench --bench micro_qsim   # CI smoke window
//! ```

use dqulearn::benchlib::{BenchConfig, Bencher, Table};
use dqulearn::circuit::{
    build_quclassi,
    builder::{self, simulate_fidelity, simulate_fidelity_fused},
    QuClassiConfig,
};
use dqulearn::qsim::{fusion, gates, shots, CompiledProgram, PlanStats, State};
use dqulearn::util::Rng;
use dqulearn::wire::{json, Value};

/// Measured circuit throughputs for one paper configuration.
struct CircuitRow {
    cfg: QuClassiConfig,
    stats: PlanStats,
    seed_cps: f64,
    fused_cps: f64,
    cold_cps: f64,
    cached_cps: f64,
}

impl CircuitRow {
    fn speedup(&self) -> f64 {
        self.cached_cps / self.seed_cps
    }
}

/// Blocked vs masked `apply_2q` timings at one register width.
struct KernelRow {
    n_qubits: usize,
    blocked_ns: f64,
    masked_ns: f64,
}

fn circuits_to_wire(rows: &[CircuitRow]) -> Vec<Value> {
    rows.iter()
        .map(|r| {
            Value::obj()
                .with("qubits", r.cfg.qubits)
                .with("layers", r.cfg.layers)
                .with("gates", r.stats.gates_in)
                .with("plan_ops", r.stats.ops_out)
                .with("blocks3", r.stats.blocks3)
                .with("seed_cps", r.seed_cps)
                .with("fused_cps", r.fused_cps)
                .with("compiled_cold_cps", r.cold_cps)
                .with("compiled_cps", r.cached_cps)
                .with("speedup", r.speedup())
        })
        .collect()
}

fn kernel_to_wire(rows: &[KernelRow]) -> Vec<Value> {
    rows.iter()
        .map(|k| {
            Value::obj()
                .with("n_qubits", k.n_qubits)
                .with("blocked_ns", k.blocked_ns)
                .with("masked_ns", k.masked_ns)
                .with("masked_over_blocked", k.masked_ns / k.blocked_ns)
        })
        .collect()
}

fn ablation_to_wire(cells: &[(usize, f64)]) -> Vec<Value> {
    cells
        .iter()
        .map(|&(mb, cps)| Value::obj().with("max_block", mb).with("cps", cps))
        .collect()
}

/// Baseline gate: a config fails when its compiled+cached throughput
/// drops below half the committed `qsim.circuits` floor (>2x
/// regression, matching the coordinator bench's rule).
fn qsim_regressions(rows: &[CircuitRow], baseline: &Value) -> Vec<String> {
    let mut failures = Vec::new();
    let Some(base) = baseline
        .get("qsim")
        .and_then(|q| q.get("circuits"))
        .and_then(Value::as_arr)
    else {
        return failures;
    };
    for b in base {
        let (Some(q), Some(l), Some(thr)) = (
            b.get("qubits").and_then(Value::as_usize),
            b.get("layers").and_then(Value::as_usize),
            b.get("throughput").and_then(Value::as_f64),
        ) else {
            continue;
        };
        if let Some(r) = rows.iter().find(|r| r.cfg.qubits == q && r.cfg.layers == l) {
            if r.cached_cps < thr / 2.0 {
                failures.push(format!(
                    "compiled q{q} l{l}: {:.0} c/s < half of qsim floor {thr:.0} c/s",
                    r.cached_cps
                ));
            }
        }
    }
    failures
}

fn main() {
    let mut b = Bencher::new(BenchConfig::from_env());
    let fast = std::env::var_os("DQ_BENCH_FAST").is_some();
    let mode = if fast { "fast" } else { "full" };
    let mut rng = Rng::new(1);

    // single gates across widths
    for nq in [5usize, 7, 10, 14] {
        let mut st = State::zero(nq);
        st.apply_h(0);
        b.bench(&format!("ry gate q={nq}"), || {
            st.apply_ry(0.3, nq / 2);
        });
        b.bench(&format!("rz gate q={nq}"), || {
            st.apply_rz(0.3, nq / 2);
        });
        b.bench(&format!("cswap gate q={nq}"), || {
            st.apply_cswap(0, 1, nq - 1);
        });
    }

    // blocked vs masked apply_2q: the kernel ablation behind the
    // cache-blocked rewrite (apply_2q_masked is the seed scan, kept as
    // the oracle). Both apply the same unitary, so the state stays
    // normalized across iterations.
    let mut kernel_rows = Vec::new();
    for nq in [10usize, 14] {
        let m = gates::ryy_matrix(0.3);
        let mut st = State::zero(nq);
        st.apply_h(0);
        let blocked_ns = b
            .bench(&format!("apply_2q blocked q={nq}"), || {
                st.apply_2q(&m, 2, nq - 3);
            })
            .mean_ns();
        let masked_ns = b
            .bench(&format!("apply_2q masked q={nq}"), || {
                st.apply_2q_masked(&m, 2, nq - 3);
            })
            .mean_ns();
        kernel_rows.push(KernelRow { n_qubits: nq, blocked_ns, masked_ns });
    }

    // full QuClassi circuits (the per-circuit cost the DES calibrates):
    // seed serial walk, per-circuit fusion, and the compiled pipeline
    // cold vs cached (DESIGN.md §15).
    let mut rows = Vec::new();
    for cfg in QuClassiConfig::paper_configs() {
        let thetas: Vec<f32> = (0..cfg.n_params()).map(|_| rng.f32()).collect();
        let data: Vec<f32> = (0..cfg.n_features()).map(|_| rng.f32()).collect();
        let tag = format!("q={} l={}", cfg.qubits, cfg.layers);
        let seed_cps = b
            .bench(&format!("seed circuit {tag}"), || {
                std::hint::black_box(simulate_fidelity(&cfg, &thetas, &data));
            })
            .throughput_per_sec();
        let fused_cps = b
            .bench(&format!("fused circuit {tag}"), || {
                std::hint::black_box(simulate_fidelity_fused(&cfg, &thetas, &data));
            })
            .throughput_per_sec();
        let cold_cps = b
            .bench(&format!("compiled cold {tag}"), || {
                let program = CompiledProgram::compile(builder::build_quclassi_template(&cfg));
                std::hint::black_box(program.bind(&thetas, &data).fidelity());
            })
            .throughput_per_sec();
        let program = builder::compile_quclassi(&cfg);
        let mut bound = program.bind_skeleton();
        let mut scratch = State::zero(cfg.qubits);
        let cached_cps = b
            .bench(&format!("compiled cached {tag}"), || {
                program.rebind(&mut bound, &thetas, &data);
                std::hint::black_box(bound.fidelity_into(&mut scratch));
            })
            .throughput_per_sec();
        rows.push(CircuitRow {
            cfg,
            stats: program.stats(),
            seed_cps,
            fused_cps,
            cold_cps,
            cached_cps,
        });
    }

    // 3-qubit-block ablation on the largest config: same cached rebind
    // loop, plan compiled with max_block 1 (singles/pairs kept apart),
    // 2 (pairwise fusion parity) and 3 (8x8 blocks).
    let cfg7 = QuClassiConfig::new(7, 3).unwrap();
    let thetas7: Vec<f32> = (0..cfg7.n_params()).map(|_| rng.f32()).collect();
    let data7: Vec<f32> = (0..cfg7.n_features()).map(|_| rng.f32()).collect();
    let mut ablation = Vec::new();
    for mb in [1usize, 2, 3] {
        let program = CompiledProgram::compile_with(builder::build_quclassi_template(&cfg7), mb);
        let mut bound = program.bind_skeleton();
        let mut scratch = State::zero(cfg7.qubits);
        let cps = b
            .bench(&format!("compiled cached q=7 l=3 max_block={mb}"), || {
                program.rebind(&mut bound, &thetas7, &data7);
                std::hint::black_box(bound.fidelity_into(&mut scratch));
            })
            .throughput_per_sec();
        ablation.push((mb, cps));
    }

    // one-off costs: the per-circuit fusion pass the compiled pipeline
    // amortizes away, plan compilation (paid once per config via the
    // plan cache), and gate-list construction (the seed path's
    // per-circuit allocation).
    let gates7 = build_quclassi(&cfg7, &thetas7, &data7);
    {
        let fprog = fusion::fuse(&gates7);
        println!(
            "fusion q=7 l=3: {} gates -> {} fused ops ({} eliminated)",
            gates7.len(),
            fprog.len(),
            fprog.fused_away()
        );
    }
    b.bench("fusion pass q=7 l=3", || {
        std::hint::black_box(fusion::fuse(&gates7));
    });
    b.bench("plan compile q=7 l=3", || {
        std::hint::black_box(CompiledProgram::compile(builder::build_quclassi_template(&cfg7)));
    });
    b.bench("gate-list build q=7 l=3", || {
        std::hint::black_box(build_quclassi(&cfg7, &thetas7, &data7));
    });

    print!("{}", b.report());

    // plan shapes: gates in -> ops out, and how many 8x8 blocks formed
    println!("\ncompiled plan shapes:");
    let mut shapes = Table::new(&["config", "gates", "plan ops", "8x8 blocks"]);
    for r in &rows {
        shapes.row(&[
            format!("q={} l={}", r.cfg.qubits, r.cfg.layers),
            r.stats.gates_in.to_string(),
            r.stats.ops_out.to_string(),
            r.stats.blocks3.to_string(),
        ]);
    }
    print!("{}", shapes.render());

    // circuits/sec summary for the DES calibration table
    println!("\nsingle-core circuit throughput (circuits/s):");
    let mut thr =
        Table::new(&["config", "seed", "fused", "compiled cold", "compiled cached", "speedup"]);
    for r in &rows {
        thr.row(&[
            format!("q={} l={}", r.cfg.qubits, r.cfg.layers),
            format!("{:.0}", r.seed_cps),
            format!("{:.0}", r.fused_cps),
            format!("{:.0}", r.cold_cps),
            format!("{:.0}", r.cached_cps),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    print!("{}", thr.render());

    // shot-pool scaling: the acceptance target for the parallel engine is
    // >= 2x shot throughput at 4 threads vs the serial path (DESIGN.md §11)
    println!("\nshot-pool scaling (q=7 l=3, {SHOT_WORKLOAD} shots):");
    let mut table = Table::new(&["threads", "wall(s)", "shots/s", "speedup vs serial"]);
    let serial_secs = time_shots(&cfg7, &gates7, 1);
    for threads in [1usize, 2, 4] {
        let secs = if threads == 1 { serial_secs } else { time_shots(&cfg7, &gates7, threads) };
        table.row(&[
            threads.to_string(),
            format!("{secs:.3}"),
            format!("{:.0}", SHOT_WORKLOAD as f64 / secs),
            format!("{:.2}x", serial_secs / secs),
        ]);
    }
    print!("{}", table.render());

    // Serialize the trajectory point.
    let out_default = "BENCH_qsim.json".to_string();
    let out_path = std::env::var("DQ_BENCH_OUT").unwrap_or(out_default);
    let payload = json::to_string_pretty(
        &Value::obj()
            .with("bench", "qsim")
            .with("mode", mode)
            .with("circuits", circuits_to_wire(&rows))
            .with("kernel_2q", kernel_to_wire(&kernel_rows))
            .with("ablation_q7_l3", ablation_to_wire(&ablation)),
    );
    std::fs::write(&out_path, payload).expect("write BENCH_qsim.json");
    println!("\nwrote {out_path}");

    // Speedup gate: on the largest paper config the cached compiled
    // path must beat the seed gate-walk by >= 2x (ISSUE 6 acceptance).
    let largest = rows
        .iter()
        .find(|r| r.cfg.qubits == 7 && r.cfg.layers == 3)
        .expect("paper_configs must include q7 l3");
    if largest.speedup() < 2.0 {
        eprintln!(
            "compiled-path regression: q7 l3 cached {:.0} c/s is {:.2}x seed {:.0} c/s (need 2x)",
            largest.cached_cps,
            largest.speedup(),
            largest.seed_cps
        );
        std::process::exit(1);
    }

    // Regression gate against the committed baseline, if present.
    let baseline_default = "../bench/baseline.json".to_string();
    let baseline_path = std::env::var("DQ_BENCH_BASELINE").unwrap_or(baseline_default);
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => match json::parse(&text) {
            Ok(baseline) => {
                let failures = qsim_regressions(&rows, &baseline);
                if failures.is_empty() {
                    println!("baseline check OK ({baseline_path})");
                } else {
                    eprintln!("perf regression vs {baseline_path}:");
                    for f in &failures {
                        eprintln!("  {f}");
                    }
                    std::process::exit(1);
                }
            }
            Err(e) => {
                eprintln!("baseline {baseline_path} unparseable: {e:?}");
                std::process::exit(1);
            }
        },
        Err(_) => println!("no baseline at {baseline_path}; skipping regression gate"),
    }
}

const SHOT_WORKLOAD: usize = 400_000;

fn time_shots(cfg: &QuClassiConfig, gates: &[gates::Gate], threads: usize) -> f64 {
    // one warmup draw, then the timed run
    std::hint::black_box(shots::run_shots(cfg.qubits, gates, 10_000, threads, 3));
    let t = std::time::Instant::now();
    std::hint::black_box(shots::run_shots(cfg.qubits, gates, SHOT_WORKLOAD, threads, 7));
    t.elapsed().as_secs_f64()
}
