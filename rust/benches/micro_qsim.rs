//! Microbenchmarks for the Rust statevector simulator (the worker's
//! fallback backend and the PJRT cross-check oracle).
//!
//! ```bash
//! cargo bench --bench micro_qsim
//! ```

use dqulearn::benchlib::{BenchConfig, Bencher};
use dqulearn::circuit::{build_quclassi, builder::simulate_fidelity, QuClassiConfig};
use dqulearn::qsim::State;
use dqulearn::util::Rng;

fn main() {
    let mut b = Bencher::new(BenchConfig::default());
    let mut rng = Rng::new(1);

    // single gates across widths
    for nq in [5usize, 7, 10, 14] {
        let mut st = State::zero(nq);
        st.apply_h(0);
        b.bench(&format!("ry gate q={nq}"), || {
            st.apply_ry(0.3, nq / 2);
        });
        b.bench(&format!("rz gate q={nq}"), || {
            st.apply_rz(0.3, nq / 2);
        });
        b.bench(&format!("cswap gate q={nq}"), || {
            st.apply_cswap(0, 1, nq - 1);
        });
    }

    // full QuClassi circuits (the per-circuit cost the DES calibrates)
    for cfg in QuClassiConfig::paper_configs() {
        let thetas: Vec<f32> = (0..cfg.n_params()).map(|_| rng.f32()).collect();
        let data: Vec<f32> = (0..cfg.n_features()).map(|_| rng.f32()).collect();
        b.bench(&format!("full circuit q={} l={}", cfg.qubits, cfg.layers), || {
            std::hint::black_box(simulate_fidelity(&cfg, &thetas, &data));
        });
    }

    // gate-list construction alone (allocation cost on the worker path)
    let cfg = QuClassiConfig::new(7, 3).unwrap();
    let thetas: Vec<f32> = (0..cfg.n_params()).map(|_| rng.f32()).collect();
    let data: Vec<f32> = (0..cfg.n_features()).map(|_| rng.f32()).collect();
    b.bench("gate-list build q=7 l=3", || {
        std::hint::black_box(build_quclassi(&cfg, &thetas, &data));
    });

    print!("{}", b.report());
    // circuits/sec summary for the DES calibration table
    println!("\nimplied single-core circuit throughput:");
    for r in b.results().iter().filter(|r| r.name.starts_with("full circuit")) {
        println!("  {:<28} {:>10.0} circuits/s", r.name, r.throughput_per_sec());
    }
}
