//! Microbenchmarks for the Rust statevector simulator (the worker's
//! fallback backend and the PJRT cross-check oracle).
//!
//! ```bash
//! cargo bench --bench micro_qsim
//! ```

use dqulearn::benchlib::{BenchConfig, Bencher, Table};
use dqulearn::circuit::{
    build_quclassi,
    builder::{simulate_fidelity, simulate_fidelity_fused},
    QuClassiConfig,
};
use dqulearn::qsim::{fusion, shots, State};
use dqulearn::util::Rng;

fn main() {
    let mut b = Bencher::new(BenchConfig::default());
    let mut rng = Rng::new(1);

    // single gates across widths
    for nq in [5usize, 7, 10, 14] {
        let mut st = State::zero(nq);
        st.apply_h(0);
        b.bench(&format!("ry gate q={nq}"), || {
            st.apply_ry(0.3, nq / 2);
        });
        b.bench(&format!("rz gate q={nq}"), || {
            st.apply_rz(0.3, nq / 2);
        });
        b.bench(&format!("cswap gate q={nq}"), || {
            st.apply_cswap(0, 1, nq - 1);
        });
    }

    // full QuClassi circuits (the per-circuit cost the DES calibrates),
    // serial gate walk vs the gate-fusion pipeline
    for cfg in QuClassiConfig::paper_configs() {
        let thetas: Vec<f32> = (0..cfg.n_params()).map(|_| rng.f32()).collect();
        let data: Vec<f32> = (0..cfg.n_features()).map(|_| rng.f32()).collect();
        b.bench(&format!("full circuit q={} l={}", cfg.qubits, cfg.layers), || {
            std::hint::black_box(simulate_fidelity(&cfg, &thetas, &data));
        });
        b.bench(&format!("fused circuit q={} l={}", cfg.qubits, cfg.layers), || {
            std::hint::black_box(simulate_fidelity_fused(&cfg, &thetas, &data));
        });
    }

    // the fusion pass itself (amortized once per circuit shape)
    {
        let cfg = QuClassiConfig::new(7, 3).unwrap();
        let thetas: Vec<f32> = (0..cfg.n_params()).map(|_| rng.f32()).collect();
        let data: Vec<f32> = (0..cfg.n_features()).map(|_| rng.f32()).collect();
        let gates = build_quclassi(&cfg, &thetas, &data);
        let program = fusion::fuse(&gates);
        println!(
            "fusion q=7 l=3: {} gates -> {} fused ops ({} eliminated)",
            gates.len(),
            program.len(),
            program.fused_away()
        );
        b.bench("fusion pass q=7 l=3", || {
            std::hint::black_box(fusion::fuse(&gates));
        });
    }

    // gate-list construction alone (allocation cost on the worker path)
    let cfg = QuClassiConfig::new(7, 3).unwrap();
    let thetas: Vec<f32> = (0..cfg.n_params()).map(|_| rng.f32()).collect();
    let data: Vec<f32> = (0..cfg.n_features()).map(|_| rng.f32()).collect();
    b.bench("gate-list build q=7 l=3", || {
        std::hint::black_box(build_quclassi(&cfg, &thetas, &data));
    });

    print!("{}", b.report());
    // circuits/sec summary for the DES calibration table
    println!("\nimplied single-core circuit throughput:");
    for r in b.results().iter().filter(|r| r.name.starts_with("full circuit")) {
        println!("  {:<28} {:>10.0} circuits/s", r.name, r.throughput_per_sec());
    }

    // shot-pool scaling: the acceptance target for the parallel engine is
    // >= 2x shot throughput at 4 threads vs the serial path (DESIGN.md §11)
    println!("\nshot-pool scaling (q=7 l=3, {} shots):", SHOT_WORKLOAD);
    let cfg = QuClassiConfig::new(7, 3).unwrap();
    let thetas: Vec<f32> = (0..cfg.n_params()).map(|_| rng.f32()).collect();
    let data: Vec<f32> = (0..cfg.n_features()).map(|_| rng.f32()).collect();
    let gates = build_quclassi(&cfg, &thetas, &data);
    let mut table = Table::new(&["threads", "wall(s)", "shots/s", "speedup vs serial"]);
    let serial_secs = time_shots(&cfg, &gates, 1);
    for threads in [1usize, 2, 4] {
        let secs = if threads == 1 { serial_secs } else { time_shots(&cfg, &gates, threads) };
        table.row(&[
            threads.to_string(),
            format!("{secs:.3}"),
            format!("{:.0}", SHOT_WORKLOAD as f64 / secs),
            format!("{:.2}x", serial_secs / secs),
        ]);
    }
    print!("{}", table.render());
}

const SHOT_WORKLOAD: usize = 400_000;

fn time_shots(cfg: &QuClassiConfig, gates: &[dqulearn::qsim::gates::Gate], threads: usize) -> f64 {
    // one warmup draw, then the timed run
    std::hint::black_box(shots::run_shots(cfg.qubits, gates, 10_000, threads, 3));
    let t = std::time::Instant::now();
    std::hint::black_box(shots::run_shots(cfg.qubits, gates, SHOT_WORKLOAD, threads, 7));
    t.elapsed().as_secs_f64()
}
