//! Macro-benchmark: co-Manager dispatch throughput across a worker ×
//! tenant grid — the perf gate for the event-driven dispatch path —
//! plus a skewed-load case (one slow worker + three fast) run with
//! work stealing on and off.
//!
//! Every cell builds a fresh manager, registers `W` instant
//! `MockChannel` workers, and runs `T` tenant threads that each submit
//! banks through the session API until their circuit budget is spent.
//! The channel does no quantum work, so the measured circuits/second is
//! pure coordination cost: admission, Algorithm-2 selection, outbox
//! hand-off, completion routing, and wakeups. The skewed case swaps in
//! one 2 ms-per-batch worker whose low CRU attracts bindings — the
//! binding-time skew `Manager::steal_for` exists to fix (DESIGN.md
//! §14) — and is gated on steal-on throughput staying at or above
//! steal-off.
//!
//! A third series measures the durability tax (DESIGN.md §16): the same
//! 4 worker x 4 tenant load with the write-ahead bank journal off, at
//! `sync=batch`, and at `sync=always`, hard-gated on batch-fsync
//! journaling keeping at least 0.8x of the journal-off throughput. An
//! `always16` row repeats `sync=always` with 16 concurrent submitters;
//! its `fsyncs` column sitting far below the record count is the
//! group-commit amortization at work (DESIGN.md §16/§17).
//!
//! A fourth series is the mux soak (DESIGN.md §17): 256 remote workers,
//! each a real TCP connection through one shared [`Mux`] into one
//! [`MuxServer`] park, driven by 4 tenant threads. The cell hard-fails
//! if the transport ever needs more than 3 OS threads
//! (`transport_thread_count`) — the whole point of the plane.
//!
//! Two self-healing cells ride the soak (DESIGN.md §19): the
//! *reconnect storm* re-runs an 8-worker soak through a severing proxy
//! that hard-closes every worker link every 50 ms — in-place revival
//! must absorb every flap with zero coordinator requeues/evictions and
//! the same 3-thread transport budget — and the *client park* drives
//! the manager's dual-codec listener with 256 binary clients on one
//! shared client mux, hard-failing unless the whole plane still fits
//! in 3 transport threads (pre-park, that was one server thread per
//! client).
//!
//! A fifth series is the shard scale (DESIGN.md §18): one-shot tenant
//! churn (fresh session → one small bank → gone, 100k tenants in the
//! full window) through a [`ShardManager`] at 1/2/4 shards over a
//! constant 4-worker pool, on 16 driver threads. The contended resource
//! is the per-shard manager lock, so churn throughput must scale with
//! shard count: the run hard-fails unless the 4-shard cell at least
//! doubles the 1-shard cell.
//!
//! Results are serialized via `wire/json` to `BENCH_coordinator.json`
//! (override with `DQ_BENCH_OUT`) with `skewed` (steal-on/off),
//! `journal` (off/batch/always/always16), `mux_soak` and `shard_scale`
//! series, seeding the repo's perf trajectory. When a committed baseline exists
//! (`DQ_BENCH_BASELINE`, default `../bench/baseline.json` relative to
//! the crate root), any cell whose throughput falls below **half** the
//! baseline value fails the run — the CI `bench-smoke` regression gate,
//! with the 2x factor absorbing shared-runner noise.
//!
//! ```bash
//! cargo bench --bench bench_coordinator_scale          # full window
//! DQ_BENCH_FAST=1 cargo bench --bench bench_coordinator_scale
//! ```

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dqulearn::benchlib::{BenchConfig, Table};
use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::{serve_manager, MuxWorkerChannel, SubmitRequest};
use dqulearn::coordinator::{
    JournalConfig, Manager, ManagerConfig, ShardConfig, ShardManager, SyncPolicy, WorkerChannel,
    WorkerProfile,
};
use dqulearn::error::DqError;
use dqulearn::model::exec::CircuitPair;
use dqulearn::net::mux::transport_thread_count;
use dqulearn::net::{Mux, MuxConfig, MuxServer};
use dqulearn::wire::{bin, json, Value};

/// Instant worker: returns a constant fidelity per circuit, so the
/// bench measures coordination, not simulation.
struct MockChannel;

impl WorkerChannel for MockChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        Ok(vec![0.5; pairs.len()])
    }
}

/// Fixed per-batch service time: the skewed-load case's slow worker.
struct SlowChannel {
    delay: Duration,
}

impl WorkerChannel for SlowChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        std::thread::sleep(self.delay);
        Ok(vec![0.5; pairs.len()])
    }
}

struct Cell {
    workers: usize,
    tenants: usize,
    circuits: usize,
    secs: f64,
    throughput: f64,
    dispatches: u64,
}

fn run_cell(workers: usize, tenants: usize, circuits_per_tenant: usize, bank: usize) -> Cell {
    let manager = Manager::new(ManagerConfig { max_batch: 8, ..Default::default() });
    for _ in 0..workers {
        manager.register(WorkerProfile::new(5), Arc::new(MockChannel));
    }
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs: Vec<CircuitPair> = (0..bank)
        .map(|_| (vec![0.1; cfg.n_params()], vec![0.2; cfg.n_features()]))
        .collect();

    let start = Instant::now();
    let handles: Vec<_> = (0..tenants)
        .map(|_| {
            let m = manager.clone();
            let pairs = pairs.clone();
            std::thread::spawn(move || {
                let session = m.session();
                let mut left = circuits_per_tenant;
                while left > 0 {
                    let n = left.min(pairs.len());
                    let fids = session.execute(cfg, &pairs[..n]).expect("bench bank failed");
                    assert_eq!(fids.len(), n);
                    left -= n;
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("tenant thread panicked");
    }
    let secs = start.elapsed().as_secs_f64();
    let stats = manager.stats();
    manager.shutdown();

    let circuits = tenants * circuits_per_tenant;
    Cell {
        workers,
        tenants,
        circuits,
        secs,
        throughput: circuits as f64 / secs.max(1e-9),
        dispatches: stats.dispatches,
    }
}

/// One skewed-load measurement (steal on or off).
struct SkewCell {
    steal: bool,
    circuits: usize,
    secs: f64,
    throughput: f64,
    steals: u64,
}

/// Skewed pool: one 20-qubit worker at 2 ms/batch whose CRU 0.0 makes
/// Algorithm 2 prefer it, three instant 20-qubit workers at CRU 0.1.
/// Without stealing, every bank's first batches serialize on the slow
/// worker's outbox; with stealing, the idle fast workers drain them.
fn run_skewed_cell(steal: bool, circuits_per_tenant: usize, bank: usize) -> SkewCell {
    let manager = Manager::new(ManagerConfig { max_batch: 8, steal, ..Default::default() });
    manager.register(
        WorkerProfile::new(20).cru(0.0),
        Arc::new(SlowChannel { delay: Duration::from_millis(2) }),
    );
    for _ in 0..3 {
        manager.register(WorkerProfile::new(20).cru(0.1), Arc::new(MockChannel));
    }
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs: Vec<CircuitPair> = (0..bank)
        .map(|_| (vec![0.1; cfg.n_params()], vec![0.2; cfg.n_features()]))
        .collect();

    let start = Instant::now();
    let handles: Vec<_> = (0..2)
        .map(|_| {
            let m = manager.clone();
            let pairs = pairs.clone();
            std::thread::spawn(move || {
                let session = m.session();
                let mut left = circuits_per_tenant;
                while left > 0 {
                    let n = left.min(pairs.len());
                    let fids = session.execute(cfg, &pairs[..n]).expect("skewed bank failed");
                    assert_eq!(fids.len(), n);
                    left -= n;
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("tenant thread panicked");
    }
    let secs = start.elapsed().as_secs_f64();
    let stats = manager.stats();
    manager.shutdown();

    let circuits = 2 * circuits_per_tenant;
    SkewCell {
        steal,
        circuits,
        secs,
        throughput: circuits as f64 / secs.max(1e-9),
        steals: stats.steals,
    }
}

/// One journal-overhead measurement (4 workers, `tenants` submitters).
struct JournalCell {
    sync: String,
    circuits: usize,
    secs: f64,
    throughput: f64,
    journal_bytes: u64,
    fsyncs: u64,
}

/// The `run_cell` shape at the 4-worker grid point with the write-ahead
/// bank journal off / batch-fsync / fsync-per-append, measuring the
/// durability tax on pure coordination throughput (DESIGN.md §16). The
/// 4-tenant rows keep their historical labels; other tenant counts get
/// the count appended (`always16` = 16 concurrent submitters, the
/// group-commit amortization row).
fn run_journal_cell(
    sync: Option<SyncPolicy>,
    tenants: usize,
    circuits_per_tenant: usize,
    bank: usize,
) -> JournalCell {
    let label = match sync {
        None => "off",
        Some(SyncPolicy::Never) => "never",
        Some(SyncPolicy::Batch) => "batch",
        Some(SyncPolicy::Always) => "always",
    };
    let sync_label = if tenants == 4 { label.to_string() } else { format!("{label}{tenants}") };
    let name = format!("dq_bench_journal_{}_{sync_label}.log", std::process::id());
    let path = std::env::temp_dir().join(name);
    let journal = sync.map(|s| JournalConfig::new(&path).sync(s));
    let manager = Manager::new(ManagerConfig { max_batch: 8, journal, ..Default::default() });
    for _ in 0..4 {
        manager.register(WorkerProfile::new(5), Arc::new(MockChannel));
    }
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs: Vec<CircuitPair> = (0..bank)
        .map(|_| (vec![0.1; cfg.n_params()], vec![0.2; cfg.n_features()]))
        .collect();

    let start = Instant::now();
    let handles: Vec<_> = (0..tenants)
        .map(|_| {
            let m = manager.clone();
            let pairs = pairs.clone();
            std::thread::spawn(move || {
                let session = m.session();
                let mut left = circuits_per_tenant;
                while left > 0 {
                    let n = left.min(pairs.len());
                    let fids = session.execute(cfg, &pairs[..n]).expect("journal bank failed");
                    assert_eq!(fids.len(), n);
                    left -= n;
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("tenant thread panicked");
    }
    let secs = start.elapsed().as_secs_f64();
    let fsyncs = manager.journal_syncs().unwrap_or(0);
    manager.shutdown();
    let journal_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let _ = std::fs::remove_file(&path);

    let circuits = tenants * circuits_per_tenant;
    JournalCell {
        sync: sync_label,
        circuits,
        secs,
        throughput: circuits as f64 / secs.max(1e-9),
        journal_bytes,
        fsyncs,
    }
}

/// The mux soak (DESIGN.md §17): `workers` real TCP endpoints served by
/// one [`MuxServer`] park, all dialed through one shared [`Mux`], with
/// the manager's outbox dispatchers on the enqueue-and-notify async
/// path. Measures coordination + transport throughput and records the
/// peak transport-thread count mid-run.
struct SoakCell {
    workers: usize,
    circuits: usize,
    secs: f64,
    throughput: f64,
    transport_threads: usize,
}

fn run_mux_soak(workers: usize, circuits_per_tenant: usize, bank: usize) -> SoakCell {
    let service = Arc::new(|op: u32, payload: &[u8]| -> Result<Vec<u8>, DqError> {
        if op != bin::OP_EXECUTE {
            return Err(DqError::Protocol(format!("soak: unknown op {op}")));
        }
        let jobs = bin::decode_jobs(payload)?;
        Ok(bin::encode_fids(&vec![0.5; jobs.len()]))
    });
    let mut server = MuxServer::serve("127.0.0.1:0", service).expect("bind soak server");
    let mux = Mux::new(MuxConfig::default());
    // No heartbeats in this cell: a huge period keeps the evictor out
    // of the measurement.
    let manager = Manager::new(ManagerConfig {
        max_batch: 8,
        heartbeat_period: 3600.0,
        ..Default::default()
    });
    for _ in 0..workers {
        let conn = mux.connect(server.local_addr()).expect("soak connect");
        let channel = Arc::new(MuxWorkerChannel::new(mux.clone(), conn.id));
        manager.register(WorkerProfile::new(5), channel);
    }
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs: Vec<CircuitPair> = (0..bank)
        .map(|_| (vec![0.1; cfg.n_params()], vec![0.2; cfg.n_features()]))
        .collect();

    let start = Instant::now();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let m = manager.clone();
            let pairs = pairs.clone();
            std::thread::spawn(move || {
                let session = m.session();
                let mut left = circuits_per_tenant;
                while left > 0 {
                    let n = left.min(pairs.len());
                    let fids = session.execute(cfg, &pairs[..n]).expect("soak bank failed");
                    assert_eq!(fids.len(), n);
                    left -= n;
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("tenant thread panicked");
    }
    let secs = start.elapsed().as_secs_f64();
    // Sampled while the plane is still up: one event loop, one
    // completion runner, one server park.
    let transport_threads = transport_thread_count();
    manager.shutdown();
    mux.shutdown();
    server.shutdown();

    let circuits = 4 * circuits_per_tenant;
    SoakCell {
        workers,
        circuits,
        secs,
        throughput: circuits as f64 / secs.max(1e-9),
        transport_threads,
    }
}

/// A TCP proxy with a kill switch: `sever` hard-closes every live
/// proxied socket pair while the listener keeps accepting, so a
/// redialing mux reconnects through the same address. The bench-side
/// twin of the reconnect suite's flaky link (`tests/mux_plane.rs`).
struct SeverProxy {
    addr: SocketAddr,
    live: Arc<Mutex<Vec<TcpStream>>>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

fn proxy_pump(mut from: TcpStream, mut to: TcpStream) {
    let mut buf = [0u8; 16 * 1024];
    loop {
        match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    let _ = to.shutdown(Shutdown::Both);
    let _ = from.shutdown(Shutdown::Both);
}

impl SeverProxy {
    fn start(upstream: SocketAddr) -> SeverProxy {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind proxy");
        let addr = listener.local_addr().expect("proxy addr");
        listener.set_nonblocking(true).expect("proxy nonblocking");
        let live: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let (live2, stop2) = (live.clone(), stop.clone());
        let thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((down, _)) => {
                        let Ok(up) = TcpStream::connect(upstream) else { continue };
                        let _ = down.set_nodelay(true);
                        let _ = up.set_nodelay(true);
                        let (Ok(d2), Ok(u2)) = (down.try_clone(), up.try_clone()) else {
                            continue;
                        };
                        {
                            let mut g = live2.lock().unwrap_or_else(|e| e.into_inner());
                            if let (Ok(d3), Ok(u3)) = (down.try_clone(), up.try_clone()) {
                                g.push(d3);
                                g.push(u3);
                            }
                        }
                        std::thread::spawn(move || proxy_pump(down, u2));
                        std::thread::spawn(move || proxy_pump(up, d2));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        SeverProxy { addr, live, stop, thread: Some(thread) }
    }

    fn sever(&self) {
        let mut g = self.live.lock().unwrap_or_else(|e| e.into_inner());
        for s in g.drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for SeverProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.sever();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// The reconnect storm (DESIGN.md §19): the soak topology dialed
/// through a severing proxy whose flapper thread hard-closes every
/// worker link at a fixed cadence mid-run. In-place revival must
/// absorb every flap — all banks complete, zero requeues/evictions at
/// the coordinator — and the measured throughput (the price of the
/// redial/replay churn) is gated against the committed baseline.
struct ReconnectCell {
    workers: usize,
    circuits: usize,
    flaps: usize,
    secs: f64,
    throughput: f64,
    transport_threads: usize,
    requeues: u64,
    evictions: u64,
}

fn run_mux_reconnect(
    workers: usize,
    circuits_per_tenant: usize,
    bank: usize,
    flap_ms: u64,
) -> ReconnectCell {
    let service = Arc::new(|op: u32, payload: &[u8]| -> Result<Vec<u8>, DqError> {
        if op != bin::OP_EXECUTE {
            return Err(DqError::Protocol(format!("reconnect: unknown op {op}")));
        }
        let jobs = bin::decode_jobs(payload)?;
        Ok(bin::encode_fids(&vec![0.5; jobs.len()]))
    });
    let mut server = MuxServer::serve("127.0.0.1:0", service).expect("bind reconnect server");
    let proxy = SeverProxy::start(server.local_addr());
    let mux = Mux::new(MuxConfig::default());
    let manager = Manager::new(ManagerConfig {
        max_batch: 8,
        heartbeat_period: 3600.0,
        ..Default::default()
    });
    for _ in 0..workers {
        let conn = mux.connect(proxy.addr).expect("reconnect connect");
        let channel = Arc::new(MuxWorkerChannel::new(mux.clone(), conn.id));
        manager.register(WorkerProfile::new(5), channel);
    }
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs: Vec<CircuitPair> = (0..bank)
        .map(|_| (vec![0.1; cfg.n_params()], vec![0.2; cfg.n_features()]))
        .collect();

    // Flapper: first sever lands 5 ms in — while the opening banks are
    // in flight — then every `flap_ms` until the tenants drain.
    let running = Arc::new(AtomicBool::new(true));
    let flapper = {
        let running = running.clone();
        let live = proxy.live.clone();
        std::thread::spawn(move || {
            let mut flaps = 0usize;
            std::thread::sleep(Duration::from_millis(5));
            while running.load(Ordering::Relaxed) {
                {
                    let mut g = live.lock().unwrap_or_else(|e| e.into_inner());
                    for s in g.drain(..) {
                        let _ = s.shutdown(Shutdown::Both);
                    }
                }
                flaps += 1;
                std::thread::sleep(Duration::from_millis(flap_ms));
            }
            flaps
        })
    };

    let start = Instant::now();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let m = manager.clone();
            let pairs = pairs.clone();
            std::thread::spawn(move || {
                let session = m.session();
                let mut left = circuits_per_tenant;
                while left > 0 {
                    let n = left.min(pairs.len());
                    let fids =
                        session.execute(cfg, &pairs[..n]).expect("reconnect bank failed");
                    assert_eq!(fids.len(), n);
                    left -= n;
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("tenant thread panicked");
    }
    let secs = start.elapsed().as_secs_f64();
    running.store(false, Ordering::SeqCst);
    let flaps = flapper.join().expect("flapper panicked");
    let transport_threads = transport_thread_count();
    let stats = manager.stats();
    manager.shutdown();
    mux.shutdown();
    server.shutdown();

    let circuits = 4 * circuits_per_tenant;
    ReconnectCell {
        workers,
        circuits,
        flaps,
        secs,
        throughput: circuits as f64 / secs.max(1e-9),
        transport_threads,
        requeues: stats.requeues,
        evictions: stats.evictions,
    }
}

/// The server-side park (DESIGN.md §19): `clients` binary clients —
/// one shared [`Mux`], one connection each — drive the manager's
/// dual-codec listener with raw `new_client`/`submit_bank`/`wait_bank`
/// frames. Pre-park, 256 clients meant 256 server threads; the cell
/// hard-fails unless the whole plane (client event loop + completion
/// runner + server park) still fits in 3 transport threads.
struct ParkCell {
    clients: usize,
    circuits: usize,
    secs: f64,
    throughput: f64,
    transport_threads: usize,
}

fn run_client_park(clients: usize, circuits_per_client: usize, bank: usize) -> ParkCell {
    let manager = Manager::new(ManagerConfig {
        max_batch: 8,
        heartbeat_period: 3600.0,
        ..Default::default()
    });
    for _ in 0..4 {
        manager.register(WorkerProfile::new(5), Arc::new(MockChannel));
    }
    let server = serve_manager(manager.clone(), "127.0.0.1:0").expect("bind manager");
    let addr = server.local_addr();
    let mux = Mux::new(MuxConfig::default());
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs: Vec<CircuitPair> = (0..bank)
        .map(|_| (vec![0.1; cfg.n_params()], vec![0.2; cfg.n_features()]))
        .collect();

    let start = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let mux = mux.clone();
            let pairs = pairs.clone();
            std::thread::spawn(move || {
                let conn = mux.connect(addr).expect("park connect");
                let client = bin::decode_u64(
                    &mux.call(conn.id, bin::OP_NEW_CLIENT, Vec::new()).expect("new_client"),
                )
                .expect("client id");
                let mut left = circuits_per_client;
                while left > 0 {
                    let n = left.min(pairs.len());
                    let req = SubmitRequest {
                        client,
                        config: cfg,
                        pairs: pairs[..n].to_vec(),
                    };
                    let resp = mux
                        .call(conn.id, bin::OP_SUBMIT_BANK, bin::encode_submit_request(&req))
                        .expect("submit_bank");
                    let bank_id = bin::decode_submit_response(&resp).expect("submit resp").bank;
                    let fids = bin::decode_fids(
                        &mux.call(
                            conn.id,
                            bin::OP_WAIT_BANK,
                            bin::encode_wait_request(bank_id, None),
                        )
                        .expect("wait_bank"),
                    )
                    .expect("fids");
                    assert_eq!(fids.len(), n);
                    left -= n;
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let secs = start.elapsed().as_secs_f64();
    // Sampled with the plane still up: client event loop + completion
    // runner + the manager's adoptive server park.
    let transport_threads = transport_thread_count();
    mux.shutdown();
    manager.shutdown();
    drop(server);

    let circuits = clients * circuits_per_client;
    ParkCell {
        clients,
        circuits,
        secs,
        throughput: circuits as f64 / secs.max(1e-9),
        transport_threads,
    }
}

/// One shard-scale measurement: `tenants` one-shot tenants churn
/// through a sharded pool (fresh session → one small bank → gone) on
/// 16 driver threads over a constant 4-worker pool (least-populated
/// registration spreads it across the shards). With instant workers,
/// the contended resource is the per-shard manager lock — the series
/// measures whether sharding actually buys dispatch parallelism.
struct ShardScaleCell {
    shards: usize,
    tenants: usize,
    circuits: usize,
    secs: f64,
    /// One-shot tenants (sessions) per second.
    throughput: f64,
    cross_steals: u64,
}

fn run_shard_scale_cell(shards: usize, tenants: usize, bank: usize) -> ShardScaleCell {
    let sm = ShardManager::new(ShardConfig {
        shards,
        manager: ManagerConfig { max_batch: 8, ..Default::default() },
        ..ShardConfig::default()
    });
    for _ in 0..4 {
        sm.register(WorkerProfile::new(5), Arc::new(MockChannel));
    }
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs: Vec<CircuitPair> = (0..bank)
        .map(|_| (vec![0.1; cfg.n_params()], vec![0.2; cfg.n_features()]))
        .collect();

    let threads = 16usize;
    let start = Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let sm = sm.clone();
            let pairs = pairs.clone();
            let quota = tenants / threads + usize::from(t < tenants % threads);
            std::thread::spawn(move || {
                for _ in 0..quota {
                    let session = sm.session();
                    let fids = session.execute(cfg, &pairs).expect("shard-scale bank failed");
                    assert_eq!(fids.len(), pairs.len());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("tenant thread panicked");
    }
    let secs = start.elapsed().as_secs_f64();
    let cross_steals = sm.cross_steals();
    sm.shutdown();

    ShardScaleCell {
        shards,
        tenants,
        circuits: tenants * bank,
        secs,
        throughput: tenants as f64 / secs.max(1e-9),
        cross_steals,
    }
}

fn shard_scale_to_wire(cells: &[ShardScaleCell]) -> Vec<Value> {
    cells
        .iter()
        .map(|c| {
            Value::obj()
                .with("shards", c.shards)
                .with("tenants", c.tenants)
                .with("circuits", c.circuits)
                .with("secs", c.secs)
                .with("throughput", c.throughput)
                .with("cross_steals", c.cross_steals)
        })
        .collect()
}

/// Baseline gate for the shard-scale series (half-the-floor rule,
/// matched by shard count; throughput is one-shot tenants per second).
fn shard_scale_regressions(cells: &[ShardScaleCell], baseline: &Value) -> Vec<String> {
    let mut failures = Vec::new();
    let Some(base) = baseline.get("shard_scale").and_then(Value::as_arr) else {
        return failures;
    };
    for b in base {
        let (Some(shards), Some(thr)) = (
            b.get("shards").and_then(Value::as_usize),
            b.get("throughput").and_then(Value::as_f64),
        ) else {
            continue;
        };
        if let Some(c) = cells.iter().find(|c| c.shards == shards) {
            if c.throughput < thr / 2.0 {
                failures.push(format!(
                    "shard_scale shards={shards}: {:.0} tenants/s < half of baseline {thr:.0}",
                    c.throughput
                ));
            }
        }
    }
    failures
}

fn journal_to_wire(cells: &[JournalCell]) -> Vec<Value> {
    cells
        .iter()
        .map(|c| {
            Value::obj()
                .with("sync", c.sync.as_str())
                .with("circuits", c.circuits)
                .with("secs", c.secs)
                .with("throughput", c.throughput)
                .with("journal_bytes", c.journal_bytes)
                .with("fsyncs", c.fsyncs)
        })
        .collect()
}

/// Baseline gate for the journal series (half-the-floor rule, matched
/// by the sync label).
fn journal_regressions(cells: &[JournalCell], baseline: &Value) -> Vec<String> {
    let mut failures = Vec::new();
    let Some(base) = baseline.get("journal").and_then(Value::as_arr) else {
        return failures;
    };
    for b in base {
        let (Some(sync), Some(thr)) = (
            b.get("sync").and_then(Value::as_str),
            b.get("throughput").and_then(Value::as_f64),
        ) else {
            continue;
        };
        if let Some(c) = cells.iter().find(|c| c.sync == sync) {
            if c.throughput < thr / 2.0 {
                failures.push(format!(
                    "journal sync={sync}: {:.0} c/s < half of baseline {thr:.0} c/s",
                    c.throughput
                ));
            }
        }
    }
    failures
}

fn skew_to_wire(cells: &[SkewCell]) -> Vec<Value> {
    cells
        .iter()
        .map(|c| {
            Value::obj()
                .with("steal", c.steal)
                .with("circuits", c.circuits)
                .with("secs", c.secs)
                .with("throughput", c.throughput)
                .with("steals", c.steals)
        })
        .collect()
}

fn cells_to_wire(mode: &str, cells: &[Cell]) -> Value {
    let rows: Vec<Value> = cells
        .iter()
        .map(|c| {
            Value::obj()
                .with("workers", c.workers)
                .with("tenants", c.tenants)
                .with("circuits", c.circuits)
                .with("secs", c.secs)
                .with("throughput", c.throughput)
                .with("dispatches", c.dispatches)
        })
        .collect();
    Value::obj()
        .with("bench", "coordinator_scale")
        .with("mode", mode)
        .with("cells", rows)
}

/// Baseline gate for the skewed steal series (same half-the-floor rule
/// as the grid cells, matched by the steal flag).
fn skew_regressions(cells: &[SkewCell], baseline: &Value) -> Vec<String> {
    let mut failures = Vec::new();
    let Some(base) = baseline.get("skewed").and_then(Value::as_arr) else {
        return failures;
    };
    for b in base {
        let (Some(steal), Some(thr)) = (
            b.get("steal").and_then(Value::as_bool),
            b.get("throughput").and_then(Value::as_f64),
        ) else {
            continue;
        };
        if let Some(c) = cells.iter().find(|c| c.steal == steal) {
            if c.throughput < thr / 2.0 {
                failures.push(format!(
                    "skewed steal={steal}: {:.0} c/s < half of baseline {thr:.0} c/s",
                    c.throughput
                ));
            }
        }
    }
    failures
}

/// Baseline gate for the mux soak (half-the-floor rule on throughput).
fn soak_regressions(soak: &SoakCell, baseline: &Value) -> Vec<String> {
    let mut failures = Vec::new();
    let thr = baseline
        .get("mux_soak")
        .and_then(|s| s.get("throughput"))
        .and_then(Value::as_f64);
    if let Some(thr) = thr {
        if soak.throughput < thr / 2.0 {
            failures.push(format!(
                "mux_soak: {:.0} c/s < half of baseline {thr:.0} c/s",
                soak.throughput
            ));
        }
    }
    failures
}

/// Baseline gate for the reconnect storm (half-the-floor rule on
/// throughput).
fn reconnect_regressions(cell: &ReconnectCell, baseline: &Value) -> Vec<String> {
    let mut failures = Vec::new();
    let thr = baseline
        .get("mux_reconnect")
        .and_then(|s| s.get("throughput"))
        .and_then(Value::as_f64);
    if let Some(thr) = thr {
        if cell.throughput < thr / 2.0 {
            failures.push(format!(
                "mux_reconnect: {:.0} c/s < half of baseline {thr:.0} c/s",
                cell.throughput
            ));
        }
    }
    failures
}

/// Baseline gate for the client park (half-the-floor rule on
/// throughput).
fn park_regressions(cell: &ParkCell, baseline: &Value) -> Vec<String> {
    let mut failures = Vec::new();
    let thr = baseline
        .get("client_park")
        .and_then(|s| s.get("throughput"))
        .and_then(Value::as_f64);
    if let Some(thr) = thr {
        if cell.throughput < thr / 2.0 {
            failures.push(format!(
                "client_park: {:.0} c/s < half of baseline {thr:.0} c/s",
                cell.throughput
            ));
        }
    }
    failures
}

/// Compare against the committed baseline; returns the failing cells.
fn regressions(cells: &[Cell], baseline: &Value) -> Vec<String> {
    let mut failures = Vec::new();
    let Some(base_cells) = baseline.get("cells").and_then(Value::as_arr) else {
        return failures;
    };
    for b in base_cells {
        let (Some(w), Some(t), Some(thr)) = (
            b.get("workers").and_then(Value::as_usize),
            b.get("tenants").and_then(Value::as_usize),
            b.get("throughput").and_then(Value::as_f64),
        ) else {
            continue;
        };
        if let Some(c) = cells.iter().find(|c| c.workers == w && c.tenants == t) {
            // >2x regression gate: generous for shared CI runners.
            if c.throughput < thr / 2.0 {
                failures.push(format!(
                    "{w}w x {t}t: {:.0} c/s < half of baseline {thr:.0} c/s",
                    c.throughput
                ));
            }
        }
    }
    failures
}

fn main() {
    let bench_cfg = BenchConfig::from_env();
    let fast = std::env::var_os("DQ_BENCH_FAST").is_some();
    let mode = if fast { "fast" } else { "full" };
    // Scale the per-tenant budget off the configured window so fast mode
    // really is fast on shared runners.
    let circuits_per_tenant = bench_cfg.max_samples * 20; // 600 fast / 4000 full
    let bank = 50;

    let grid = [1usize, 4, 16];
    let mut cells = Vec::new();
    for &workers in &grid {
        for &tenants in &grid {
            cells.push(run_cell(workers, tenants, circuits_per_tenant, bank));
        }
    }

    let mut table =
        Table::new(&["workers", "tenants", "circuits", "secs", "circuits/s", "dispatches"]);
    for c in &cells {
        table.row(&[
            c.workers.to_string(),
            c.tenants.to_string(),
            c.circuits.to_string(),
            format!("{:.3}", c.secs),
            format!("{:.0}", c.throughput),
            c.dispatches.to_string(),
        ]);
    }
    print!("{}", table.render());

    // Skewed load: 1 slow + 3 fast workers, steal off vs on. A smaller
    // circuit budget keeps the steal-off case (bottlenecked on the slow
    // worker) inside the smoke window.
    let skew_budget = circuits_per_tenant / 2;
    let skew_cells = vec![
        run_skewed_cell(false, skew_budget, bank),
        run_skewed_cell(true, skew_budget, bank),
    ];
    let mut skew_table = Table::new(&["steal", "circuits", "secs", "circuits/s", "steals"]);
    for c in &skew_cells {
        skew_table.row(&[
            c.steal.to_string(),
            c.circuits.to_string(),
            format!("{:.3}", c.secs),
            format!("{:.0}", c.throughput),
            c.steals.to_string(),
        ]);
    }
    println!("\nskewed load (1 slow + 3 fast workers):");
    print!("{}", skew_table.render());

    // Journal overhead: the 4-worker grid point with the write-ahead
    // bank journal off, batch-fsynced, and fsynced per append — plus
    // the 16-submitter fsync-per-append row, whose fsync count shows
    // the group commit coalescing concurrent appends.
    let journal_cells = vec![
        run_journal_cell(None, 4, skew_budget, bank),
        run_journal_cell(Some(SyncPolicy::Batch), 4, skew_budget, bank),
        run_journal_cell(Some(SyncPolicy::Always), 4, skew_budget, bank),
        run_journal_cell(Some(SyncPolicy::Always), 16, skew_budget / 4, bank),
    ];
    let mut journal_table =
        Table::new(&["journal", "circuits", "secs", "circuits/s", "log bytes", "fsyncs"]);
    for c in &journal_cells {
        journal_table.row(&[
            c.sync.to_string(),
            c.circuits.to_string(),
            format!("{:.3}", c.secs),
            format!("{:.0}", c.throughput),
            c.journal_bytes.to_string(),
            c.fsyncs.to_string(),
        ]);
    }
    println!("\njournal overhead (4 workers):");
    print!("{}", journal_table.render());

    // Mux soak: 256 remote workers on one shared transport plane.
    let soak_workers = 256;
    let soak = run_mux_soak(soak_workers, skew_budget, bank);
    println!(
        "\nmux soak: {} workers, {} circuits in {:.3}s ({:.0} c/s), {} transport threads",
        soak.workers, soak.circuits, soak.secs, soak.throughput, soak.transport_threads
    );

    // Reconnect storm: the soak topology through a severing proxy that
    // hard-closes every worker link every 50 ms (DESIGN.md §19).
    let reconnect = run_mux_reconnect(8, skew_budget / 2, bank, 50);
    println!(
        "mux reconnect: {} workers, {} circuits across {} flaps in {:.3}s ({:.0} c/s), \
         {} transport threads, {} requeues, {} evictions",
        reconnect.workers,
        reconnect.circuits,
        reconnect.flaps,
        reconnect.secs,
        reconnect.throughput,
        reconnect.transport_threads,
        reconnect.requeues,
        reconnect.evictions
    );

    // Client park: 256 binary clients on the manager's server mux.
    let park = run_client_park(256, 16, 8);
    println!(
        "client park: {} clients, {} circuits in {:.3}s ({:.0} c/s), {} transport threads",
        park.clients, park.circuits, park.secs, park.throughput, park.transport_threads
    );

    // Shard scale: one-shot tenant churn through the sharded co-Manager
    // at 1/2/4 shards over a constant 4-worker pool (DESIGN.md §18).
    let churn_tenants = bench_cfg.max_samples * 500; // 15k fast / 100k full
    let shard_cells: Vec<ShardScaleCell> = [1usize, 2, 4]
        .iter()
        .map(|&s| run_shard_scale_cell(s, churn_tenants, 2))
        .collect();
    let mut shard_table =
        Table::new(&["shards", "tenants", "circuits", "secs", "tenants/s", "cross steals"]);
    for c in &shard_cells {
        shard_table.row(&[
            c.shards.to_string(),
            c.tenants.to_string(),
            c.circuits.to_string(),
            format!("{:.3}", c.secs),
            format!("{:.0}", c.throughput),
            c.cross_steals.to_string(),
        ]);
    }
    println!("\nshard scale ({churn_tenants} one-shot tenants, 4 workers):");
    print!("{}", shard_table.render());

    // Serialize the trajectory point (grid + skewed steal + journal +
    // mux soak + shard scale series).
    let out_default = "BENCH_coordinator.json".to_string();
    let out_path = std::env::var("DQ_BENCH_OUT").unwrap_or(out_default);
    let soak_wire = Value::obj()
        .with("workers", soak.workers)
        .with("circuits", soak.circuits)
        .with("secs", soak.secs)
        .with("throughput", soak.throughput)
        .with("transport_threads", soak.transport_threads);
    let reconnect_wire = Value::obj()
        .with("workers", reconnect.workers)
        .with("circuits", reconnect.circuits)
        .with("flaps", reconnect.flaps)
        .with("secs", reconnect.secs)
        .with("throughput", reconnect.throughput)
        .with("transport_threads", reconnect.transport_threads)
        .with("requeues", reconnect.requeues)
        .with("evictions", reconnect.evictions);
    let park_wire = Value::obj()
        .with("clients", park.clients)
        .with("circuits", park.circuits)
        .with("secs", park.secs)
        .with("throughput", park.throughput)
        .with("transport_threads", park.transport_threads);
    let payload = json::to_string_pretty(
        &cells_to_wire(mode, &cells)
            .with("skewed", skew_to_wire(&skew_cells))
            .with("journal", journal_to_wire(&journal_cells))
            .with("mux_soak", soak_wire)
            .with("mux_reconnect", reconnect_wire)
            .with("client_park", park_wire)
            .with("shard_scale", shard_scale_to_wire(&shard_cells)),
    );
    std::fs::write(&out_path, payload).expect("write BENCH_coordinator.json");
    println!("\nwrote {out_path}");

    // Mux gate: the soak must never need more than the fixed transport
    // trio (event loop + completion runner + server park) no matter how
    // many workers are connected — the plane's entire reason to exist.
    if soak.transport_threads > 3 {
        eprintln!(
            "mux soak used {} transport threads for {} workers (budget: 3)",
            soak.transport_threads, soak.workers
        );
        std::process::exit(1);
    }

    // Reconnect gate: every flap must heal in place — invisible to the
    // coordinator (no requeues, no evictions) and inside the same
    // transport budget (transient redialers are not transport threads).
    if reconnect.flaps == 0 {
        eprintln!("reconnect storm produced zero flaps; the scenario no longer exercises revival");
        std::process::exit(1);
    }
    if reconnect.requeues != 0 || reconnect.evictions != 0 {
        eprintln!(
            "reconnect storm leaked into the coordinator: {} requeues, {} evictions \
             (in-place revival must be invisible)",
            reconnect.requeues, reconnect.evictions
        );
        std::process::exit(1);
    }
    if reconnect.transport_threads > 3 {
        eprintln!(
            "reconnect storm used {} transport threads (budget: 3)",
            reconnect.transport_threads
        );
        std::process::exit(1);
    }

    // Park gate: 256 binary clients on the manager's server mux must
    // still fit the fixed transport trio — the server half of the
    // thread-budget claim (the soak covers the worker half).
    if park.transport_threads > 3 {
        eprintln!(
            "client park used {} transport threads for {} clients (budget: 3)",
            park.transport_threads, park.clients
        );
        std::process::exit(1);
    }

    // Steal gate: on the skewed pool, stealing must not lose throughput
    // (expected: a multiple; the 0.9 factor absorbs runner noise).
    let off = skew_cells[0].throughput;
    let on = skew_cells[1].throughput;
    if on < off * 0.9 {
        eprintln!("steal regression: steal-on {on:.0} c/s < steal-off {off:.0} c/s");
        std::process::exit(1);
    }
    if skew_cells[1].steals == 0 {
        eprintln!("skewed-load case produced zero steals; the scenario no longer exercises stealing");
        std::process::exit(1);
    }

    // Journal gate: batch-fsync journaling must keep at least 0.8x of
    // the journal-off throughput — the durability-tax budget the
    // default `SyncPolicy::Batch` is designed to fit (DESIGN.md §16).
    let j_off = journal_cells[0].throughput;
    let j_batch = journal_cells[1].throughput;
    if j_batch < j_off * 0.8 {
        eprintln!(
            "journal regression: sync=batch {j_batch:.0} c/s < 0.8x journal-off {j_off:.0} c/s"
        );
        std::process::exit(1);
    }

    // Shard gate: churn throughput must actually scale with shard count
    // — the tentpole claim of the sharded co-Manager. The contended
    // resource is the per-shard lock, so 4 shards must at least double
    // the single-shard (single-lock) cell.
    let t1 = shard_cells[0].throughput;
    let t4 = shard_cells[2].throughput;
    if t4 < 2.0 * t1 {
        eprintln!(
            "shard scaling regression: 4 shards {t4:.0} tenants/s < 2x 1 shard {t1:.0} tenants/s"
        );
        std::process::exit(1);
    }

    // Regression gate against the committed baseline, if present.
    let baseline_default = "../bench/baseline.json".to_string();
    let baseline_path = std::env::var("DQ_BENCH_BASELINE").unwrap_or(baseline_default);
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => match json::parse(&text) {
            Ok(baseline) => {
                let mut failures = regressions(&cells, &baseline);
                failures.extend(skew_regressions(&skew_cells, &baseline));
                failures.extend(journal_regressions(&journal_cells, &baseline));
                failures.extend(soak_regressions(&soak, &baseline));
                failures.extend(reconnect_regressions(&reconnect, &baseline));
                failures.extend(park_regressions(&park, &baseline));
                failures.extend(shard_scale_regressions(&shard_cells, &baseline));
                if failures.is_empty() {
                    println!("baseline check OK ({baseline_path})");
                } else {
                    eprintln!("perf regression vs {baseline_path}:");
                    for f in &failures {
                        eprintln!("  {f}");
                    }
                    std::process::exit(1);
                }
            }
            Err(e) => {
                eprintln!("baseline {baseline_path} unparseable: {e:?}");
                std::process::exit(1);
            }
        },
        Err(_) => println!("no baseline at {baseline_path}; skipping regression gate"),
    }
}
