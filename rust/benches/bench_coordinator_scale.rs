//! Macro-benchmark: co-Manager dispatch throughput across a worker ×
//! tenant grid — the perf gate for the event-driven dispatch path.
//!
//! Every cell builds a fresh manager, registers `W` instant
//! `MockChannel` workers, and runs `T` tenant threads that each submit
//! banks through the session API until their circuit budget is spent.
//! The channel does no quantum work, so the measured circuits/second is
//! pure coordination cost: admission, Algorithm-2 selection, outbox
//! hand-off, completion routing, and wakeups.
//!
//! Results are serialized via `wire/json` to `BENCH_coordinator.json`
//! (override with `DQ_BENCH_OUT`), seeding the repo's perf trajectory.
//! When a committed baseline exists (`DQ_BENCH_BASELINE`, default
//! `../bench/baseline.json` relative to the crate root), any cell whose
//! throughput falls below **half** the baseline value fails the run —
//! the CI `bench-smoke` regression gate, with the 2x factor absorbing
//! shared-runner noise.
//!
//! ```bash
//! cargo bench --bench bench_coordinator_scale          # full window
//! DQ_BENCH_FAST=1 cargo bench --bench bench_coordinator_scale
//! ```

use std::sync::Arc;
use std::time::Instant;

use dqulearn::benchlib::{BenchConfig, Table};
use dqulearn::circuit::QuClassiConfig;
use dqulearn::coordinator::{Manager, ManagerConfig, WorkerChannel, WorkerProfile};
use dqulearn::error::DqError;
use dqulearn::model::exec::CircuitPair;
use dqulearn::wire::{json, Value};

/// Instant worker: returns a constant fidelity per circuit, so the
/// bench measures coordination, not simulation.
struct MockChannel;

impl WorkerChannel for MockChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        Ok(vec![0.5; pairs.len()])
    }
}

struct Cell {
    workers: usize,
    tenants: usize,
    circuits: usize,
    secs: f64,
    throughput: f64,
    dispatches: u64,
}

fn run_cell(workers: usize, tenants: usize, circuits_per_tenant: usize, bank: usize) -> Cell {
    let manager = Manager::new(ManagerConfig { max_batch: 8, ..Default::default() });
    for _ in 0..workers {
        manager.register(WorkerProfile::new(5), Arc::new(MockChannel));
    }
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs: Vec<CircuitPair> = (0..bank)
        .map(|_| (vec![0.1; cfg.n_params()], vec![0.2; cfg.n_features()]))
        .collect();

    let start = Instant::now();
    let handles: Vec<_> = (0..tenants)
        .map(|_| {
            let m = manager.clone();
            let pairs = pairs.clone();
            std::thread::spawn(move || {
                let session = m.session();
                let mut left = circuits_per_tenant;
                while left > 0 {
                    let n = left.min(pairs.len());
                    let fids = session.execute(cfg, &pairs[..n]).expect("bench bank failed");
                    assert_eq!(fids.len(), n);
                    left -= n;
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("tenant thread panicked");
    }
    let secs = start.elapsed().as_secs_f64();
    let stats = manager.stats();
    manager.shutdown();

    let circuits = tenants * circuits_per_tenant;
    Cell {
        workers,
        tenants,
        circuits,
        secs,
        throughput: circuits as f64 / secs.max(1e-9),
        dispatches: stats.dispatches,
    }
}

fn cells_to_wire(mode: &str, cells: &[Cell]) -> Value {
    let rows: Vec<Value> = cells
        .iter()
        .map(|c| {
            Value::obj()
                .with("workers", c.workers)
                .with("tenants", c.tenants)
                .with("circuits", c.circuits)
                .with("secs", c.secs)
                .with("throughput", c.throughput)
                .with("dispatches", c.dispatches)
        })
        .collect();
    Value::obj()
        .with("bench", "coordinator_scale")
        .with("mode", mode)
        .with("cells", rows)
}

/// Compare against the committed baseline; returns the failing cells.
fn regressions(cells: &[Cell], baseline: &Value) -> Vec<String> {
    let mut failures = Vec::new();
    let Some(base_cells) = baseline.get("cells").and_then(Value::as_arr) else {
        return failures;
    };
    for b in base_cells {
        let (Some(w), Some(t), Some(thr)) = (
            b.get("workers").and_then(Value::as_usize),
            b.get("tenants").and_then(Value::as_usize),
            b.get("throughput").and_then(Value::as_f64),
        ) else {
            continue;
        };
        if let Some(c) = cells.iter().find(|c| c.workers == w && c.tenants == t) {
            // >2x regression gate: generous for shared CI runners.
            if c.throughput < thr / 2.0 {
                failures.push(format!(
                    "{w}w x {t}t: {:.0} c/s < half of baseline {thr:.0} c/s",
                    c.throughput
                ));
            }
        }
    }
    failures
}

fn main() {
    let bench_cfg = BenchConfig::from_env();
    let fast = std::env::var_os("DQ_BENCH_FAST").is_some();
    let mode = if fast { "fast" } else { "full" };
    // Scale the per-tenant budget off the configured window so fast mode
    // really is fast on shared runners.
    let circuits_per_tenant = bench_cfg.max_samples * 20; // 600 fast / 4000 full
    let bank = 50;

    let grid = [1usize, 4, 16];
    let mut cells = Vec::new();
    for &workers in &grid {
        for &tenants in &grid {
            cells.push(run_cell(workers, tenants, circuits_per_tenant, bank));
        }
    }

    let mut table =
        Table::new(&["workers", "tenants", "circuits", "secs", "circuits/s", "dispatches"]);
    for c in &cells {
        table.row(&[
            c.workers.to_string(),
            c.tenants.to_string(),
            c.circuits.to_string(),
            format!("{:.3}", c.secs),
            format!("{:.0}", c.throughput),
            c.dispatches.to_string(),
        ]);
    }
    print!("{}", table.render());

    // Serialize the trajectory point.
    let out_default = "BENCH_coordinator.json".to_string();
    let out_path = std::env::var("DQ_BENCH_OUT").unwrap_or(out_default);
    let payload = json::to_string_pretty(&cells_to_wire(mode, &cells));
    std::fs::write(&out_path, payload).expect("write BENCH_coordinator.json");
    println!("\nwrote {out_path}");

    // Regression gate against the committed baseline, if present.
    let baseline_default = "../bench/baseline.json".to_string();
    let baseline_path = std::env::var("DQ_BENCH_BASELINE").unwrap_or(baseline_default);
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => match json::parse(&text) {
            Ok(baseline) => {
                let failures = regressions(&cells, &baseline);
                if failures.is_empty() {
                    println!("baseline check OK ({baseline_path})");
                } else {
                    eprintln!("perf regression vs {baseline_path}:");
                    for f in &failures {
                        eprintln!("  {f}");
                    }
                    std::process::exit(1);
                }
            }
            Err(e) => {
                eprintln!("baseline {baseline_path} unparseable: {e:?}");
                std::process::exit(1);
            }
        },
        Err(_) => println!("no baseline at {baseline_path}; skipping regression gate"),
    }
}
