//! Microbenchmarks for the wire substrate: JSON encode/decode and frame
//! round-trips — the per-message cost of the manager↔worker RPC.
//!
//! ```bash
//! cargo bench --bench micro_wire
//! ```

use dqulearn::benchlib::{BenchConfig, Bencher};
use dqulearn::circuit::QuClassiConfig;
use dqulearn::coordinator::job::CircuitJob;
use dqulearn::net::frame::{read_frame, write_frame};
use dqulearn::wire::{self, Value};

fn sample_job(i: u64) -> CircuitJob {
    let config = QuClassiConfig::new(7, 3).unwrap();
    CircuitJob {
        id: i,
        client: 1,
        bank: 2,
        index: i as usize,
        config,
        thetas: (0..config.n_params()).map(|p| p as f32 * 0.1).collect(),
        data: (0..config.n_features()).map(|d| d as f32 * 0.2).collect(),
    }
}

fn main() {
    let mut b = Bencher::new(BenchConfig::default());

    // single-job encode/decode
    let job = sample_job(1);
    b.bench("job -> wire Value", || {
        std::hint::black_box(job.to_wire());
    });
    let encoded = job.to_wire();
    b.bench("wire Value -> json string", || {
        std::hint::black_box(wire::to_string(&encoded));
    });
    let json = wire::to_string(&encoded);
    b.bench("json parse", || {
        std::hint::black_box(wire::parse(&json).unwrap());
    });
    b.bench("wire Value -> job", || {
        std::hint::black_box(CircuitJob::from_wire(&encoded).unwrap());
    });

    // a full 32-circuit execute request (the dispatch unit)
    let batch: Vec<Value> = (0..32).map(|i| sample_job(i).to_wire()).collect();
    let request = Value::obj().with("op", "execute").with("circuits", batch);
    let request_json = wire::to_string(&request);
    println!("32-circuit execute request: {} bytes as json\n", request_json.len());
    b.bench("encode 32-circuit request", || {
        std::hint::black_box(wire::to_string(&request));
    });
    b.bench("parse 32-circuit request", || {
        std::hint::black_box(wire::parse(&request_json).unwrap());
    });

    // framed round trip through a buffer (what the socket sees)
    b.bench("frame write+read 32-circuit request", || {
        let mut buf = Vec::with_capacity(request_json.len() + 4);
        write_frame(&mut buf, &request).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        std::hint::black_box(read_frame(&mut cur).unwrap());
    });

    print!("{}", b.report());
}
