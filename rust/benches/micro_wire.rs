//! Microbenchmarks for the wire substrate: JSON encode/decode and frame
//! round-trips — the per-message cost of the manager↔worker RPC — plus
//! the manager `stats` payload (per-tenant wait histograms included).
//!
//! This file is both a `harness = false` bench target and a harnessed
//! test target (`micro_wire_tests` in Cargo.toml), so the round-trip
//! assertions in the test module run under `cargo test`; in the test
//! build, `main` and its bench-only imports are intentionally unused.
//!
//! ```bash
//! cargo bench --bench micro_wire
//! ```
#![cfg_attr(test, allow(dead_code, unused_imports))]

use dqulearn::benchlib::{BenchConfig, Bencher};
use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::proto;
use dqulearn::coordinator::job::CircuitJob;
use dqulearn::coordinator::{ManagerStats, TenantStats};
use dqulearn::net::frame::{read_frame, write_frame};
use dqulearn::util::WaitHistogram;
use dqulearn::wire::{self, Value};

fn sample_job(i: u64) -> CircuitJob {
    let config = QuClassiConfig::new(7, 3).unwrap();
    CircuitJob {
        id: i,
        client: 1,
        bank: 2,
        index: i as usize,
        config,
        thetas: (0..config.n_params()).map(|p| p as f32 * 0.1).collect(),
        data: (0..config.n_features()).map(|d| d as f32 * 0.2).collect(),
    }
}

fn main() {
    let mut b = Bencher::new(BenchConfig::default());

    // single-job encode/decode
    let job = sample_job(1);
    b.bench("job -> wire Value", || {
        std::hint::black_box(job.to_wire());
    });
    let encoded = job.to_wire();
    b.bench("wire Value -> json string", || {
        std::hint::black_box(wire::to_string(&encoded));
    });
    let json = wire::to_string(&encoded);
    b.bench("json parse", || {
        std::hint::black_box(wire::parse(&json).unwrap());
    });
    b.bench("wire Value -> job", || {
        std::hint::black_box(CircuitJob::from_wire(&encoded).unwrap());
    });

    // a full 32-circuit execute request (the dispatch unit)
    let batch: Vec<Value> = (0..32).map(|i| sample_job(i).to_wire()).collect();
    let request = Value::obj().with("op", "execute").with("circuits", batch);
    let request_json = wire::to_string(&request);
    println!("32-circuit execute request: {} bytes as json\n", request_json.len());
    b.bench("encode 32-circuit request", || {
        std::hint::black_box(wire::to_string(&request));
    });
    b.bench("parse 32-circuit request", || {
        std::hint::black_box(wire::parse(&request_json).unwrap());
    });

    // framed round trip through a buffer (what the socket sees)
    b.bench("frame write+read 32-circuit request", || {
        let mut buf = Vec::with_capacity(request_json.len() + 4);
        write_frame(&mut buf, &request).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        std::hint::black_box(read_frame(&mut cur).unwrap());
    });

    // the manager `stats` payload at the retention cap's scale: 64
    // tenants, each with a populated wait histogram
    let stats = sample_stats(64);
    let stats_wire = proto::manager_stats_to_wire(&stats);
    let stats_json = wire::to_string(&stats_wire);
    println!("\n64-tenant stats payload: {} bytes as json\n", stats_json.len());
    b.bench("encode 64-tenant stats", || {
        std::hint::black_box(wire::to_string(&proto::manager_stats_to_wire(&stats)));
    });
    b.bench("parse+decode 64-tenant stats", || {
        let parsed = wire::parse(&stats_json).unwrap();
        std::hint::black_box(proto::manager_stats_from_wire(&parsed).unwrap());
    });

    print!("{}", b.report());
}

/// A stats snapshot with `tenants` retained tenants, all counters and
/// histogram buckets populated.
fn sample_stats(tenants: u64) -> ManagerStats {
    let mut stats = ManagerStats {
        submitted: 10_000,
        completed: 9_900,
        dispatches: 1_200,
        requeues: 3,
        evictions: 1,
        cancelled: 2,
        steals: 40,
        pruned_tenants: 100,
        ..Default::default()
    };
    for client in 1..=tenants {
        let mut wait_hist = WaitHistogram::new();
        for i in 0..8u32 {
            for _ in 0..=i {
                wait_hist.record(10f64.powi(i as i32 - 4));
            }
        }
        stats.per_tenant.insert(
            client,
            TenantStats {
                submitted: 100 + client,
                dispatched: 100 + client,
                completed: 100,
                lost: client % 3,
                stolen: client % 5,
                wait_total_s: 0.5 * client as f64,
                wait_max_s: 0.9,
                wait_hist,
            },
        );
    }
    let retired = stats.per_tenant[&1].clone();
    stats.retired = retired;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The `stats` op payload round-trips bit-exactly through the JSON
    /// wire — histograms included — so manager-reported p50/p90 are the
    /// numbers a remote operator actually reads.
    #[test]
    fn stats_payload_round_trips() {
        let stats = sample_stats(8);
        let json = wire::to_string(&proto::manager_stats_to_wire(&stats));
        let back = proto::manager_stats_from_wire(&wire::parse(&json).unwrap()).unwrap();
        assert_eq!(back.per_tenant.len(), 8);
        assert_eq!(back.steals, stats.steals);
        for (client, t) in &stats.per_tenant {
            let b = &back.per_tenant[client];
            assert_eq!(b.wait_hist, t.wait_hist);
            assert_eq!(b.wait_hist.p90(), t.wait_hist.p90());
            assert_eq!((b.submitted, b.stolen), (t.submitted, t.stolen));
        }
        assert_eq!(back.retired.wait_hist, stats.retired.wait_hist);
    }
}
