//! Microbenchmarks for the wire substrate: JSON encode/decode and frame
//! round-trips — the per-message cost of the manager↔worker RPC — plus
//! the manager `stats` payload (per-tenant wait histograms included)
//! and the `wire/bin` binary plane measured against the same payloads.
//!
//! The binary series is a perf *gate*, not just a report: for the two
//! hot payloads (the 32-circuit execute request and the fidelity batch
//! result) the typed→bytes and bytes→typed costs through `wire/bin`
//! must stay at or below half the JSON cost, with the ratio ceilings
//! read from `bench/baseline.json` (`wire` section) when present.
//! Results are serialized to `BENCH_wire.json` (`DQ_BENCH_OUT`
//! overrides) for the CI artifact trail.
//!
//! This file is both a `harness = false` bench target and a harnessed
//! test target (`micro_wire_tests` in Cargo.toml), so the round-trip
//! assertions in the test module run under `cargo test`; in the test
//! build, `main` and its bench-only imports are intentionally unused.
//!
//! ```bash
//! cargo bench --bench micro_wire
//! DQ_BENCH_FAST=1 cargo bench --bench micro_wire
//! ```
#![cfg_attr(test, allow(dead_code, unused_imports))]

use dqulearn::benchlib::{BenchConfig, Bencher};
use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::proto;
use dqulearn::coordinator::job::CircuitJob;
use dqulearn::coordinator::{ManagerStats, TenantStats};
use dqulearn::net::frame::{read_frame, write_frame};
use dqulearn::util::WaitHistogram;
use dqulearn::wire::{self, bin, json, Value};

fn sample_job(i: u64) -> CircuitJob {
    let config = QuClassiConfig::new(7, 3).unwrap();
    CircuitJob {
        id: i,
        client: 1,
        bank: 2,
        index: i as usize,
        config,
        thetas: (0..config.n_params()).map(|p| p as f32 * 0.1).collect(),
        data: (0..config.n_features()).map(|d| d as f32 * 0.2).collect(),
    }
}

fn main() {
    let mut b = Bencher::new(BenchConfig::from_env());

    // single-job encode/decode
    let job = sample_job(1);
    b.bench("job -> wire Value", || {
        std::hint::black_box(job.to_wire());
    });
    let encoded = job.to_wire();
    b.bench("wire Value -> json string", || {
        std::hint::black_box(wire::to_string(&encoded));
    });
    let json = wire::to_string(&encoded);
    b.bench("json parse", || {
        std::hint::black_box(wire::parse(&json).unwrap());
    });
    b.bench("wire Value -> job", || {
        std::hint::black_box(CircuitJob::from_wire(&encoded).unwrap());
    });

    // a full 32-circuit execute request (the dispatch unit)
    let batch: Vec<Value> = (0..32).map(|i| sample_job(i).to_wire()).collect();
    let request = Value::obj().with("op", "execute").with("circuits", batch);
    let request_json = wire::to_string(&request);
    println!("32-circuit execute request: {} bytes as json\n", request_json.len());
    b.bench("encode 32-circuit request", || {
        std::hint::black_box(wire::to_string(&request));
    });
    b.bench("parse 32-circuit request", || {
        std::hint::black_box(wire::parse(&request_json).unwrap());
    });

    // framed round trip through a buffer (what the socket sees)
    b.bench("frame write+read 32-circuit request", || {
        let mut buf = Vec::with_capacity(request_json.len() + 4);
        write_frame(&mut buf, &request).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        std::hint::black_box(read_frame(&mut cur).unwrap());
    });

    // the manager `stats` payload at the retention cap's scale: 64
    // tenants, each with a populated wait histogram
    let stats = sample_stats(64);
    let stats_wire = proto::manager_stats_to_wire(&stats);
    let stats_json = wire::to_string(&stats_wire);
    println!("\n64-tenant stats payload: {} bytes as json\n", stats_json.len());
    b.bench("encode 64-tenant stats", || {
        std::hint::black_box(wire::to_string(&proto::manager_stats_to_wire(&stats)));
    });
    b.bench("parse+decode 64-tenant stats", || {
        let parsed = wire::parse(&stats_json).unwrap();
        std::hint::black_box(proto::manager_stats_from_wire(&parsed).unwrap());
    });

    // -----------------------------------------------------------------
    // binary plane (wire/bin) vs JSON on the two hot payloads, measured
    // as the full typed→bytes / bytes→typed path either plane pays
    // -----------------------------------------------------------------

    let jobs: Vec<CircuitJob> = (0..32).map(sample_job).collect();
    let bin_request = bin::encode_jobs(&jobs);
    let json_request = wire::to_string(
        &Value::obj().with("circuits", jobs.iter().map(CircuitJob::to_wire).collect::<Vec<_>>()),
    );
    println!(
        "\n32-circuit execute request: {} bytes as json, {} bytes as wire/bin",
        json_request.len(),
        bin_request.len()
    );
    let submit_json_enc = b
        .bench("typed->bytes 32-circuit request (json)", || {
            let circuits: Vec<Value> = jobs.iter().map(CircuitJob::to_wire).collect();
            std::hint::black_box(wire::to_string(&Value::obj().with("circuits", circuits)));
        })
        .mean_ns();
    let submit_bin_enc = b
        .bench("typed->bytes 32-circuit request (bin)", || {
            std::hint::black_box(bin::encode_jobs(&jobs));
        })
        .mean_ns();
    let submit_json_dec = b
        .bench("bytes->typed 32-circuit request (json)", || {
            let parsed = wire::parse(&json_request).unwrap();
            let circuits = parsed.req_arr("circuits").unwrap();
            let jobs: Vec<CircuitJob> =
                circuits.iter().map(|c| CircuitJob::from_wire(c).unwrap()).collect();
            std::hint::black_box(jobs);
        })
        .mean_ns();
    let submit_bin_dec = b
        .bench("bytes->typed 32-circuit request (bin)", || {
            std::hint::black_box(bin::decode_jobs(&bin_request).unwrap());
        })
        .mean_ns();

    let fids: Vec<f32> = (0..512).map(|i| i as f32 / 512.0).collect();
    let bin_fids = bin::encode_fids(&fids);
    let json_fids = wire::to_string(&Value::obj().with("fids", fids.as_slice()));
    println!(
        "512-fid result: {} bytes as json, {} bytes as wire/bin\n",
        json_fids.len(),
        bin_fids.len()
    );
    let fids_json_enc = b
        .bench("typed->bytes 512-fid result (json)", || {
            std::hint::black_box(wire::to_string(&Value::obj().with("fids", fids.as_slice())));
        })
        .mean_ns();
    let fids_bin_enc = b
        .bench("typed->bytes 512-fid result (bin)", || {
            std::hint::black_box(bin::encode_fids(&fids));
        })
        .mean_ns();
    let fids_json_dec = b
        .bench("bytes->typed 512-fid result (json)", || {
            let parsed = wire::parse(&json_fids).unwrap();
            std::hint::black_box(parsed.req_f32_vec("fids").unwrap());
        })
        .mean_ns();
    let fids_bin_dec = b
        .bench("bytes->typed 512-fid result (bin)", || {
            std::hint::black_box(bin::decode_fids(&bin_fids).unwrap());
        })
        .mean_ns();

    print!("{}", b.report());

    let ratios = [
        ("submit encode", submit_bin_enc / submit_json_enc),
        ("submit decode", submit_bin_dec / submit_json_dec),
        ("fids encode", fids_bin_enc / fids_json_enc),
        ("fids decode", fids_bin_dec / fids_json_dec),
    ];
    println!("\nwire/bin cost as a fraction of json:");
    for (name, r) in &ratios {
        println!("  {name}: {r:.3}x");
    }

    // Serialize the trajectory point.
    let out_path =
        std::env::var("DQ_BENCH_OUT").unwrap_or_else(|_| "BENCH_wire.json".to_string());
    let ratio_rows: Vec<Value> =
        ratios.iter().map(|(n, r)| Value::obj().with("name", *n).with("ratio", *r)).collect();
    let payload = json::to_string_pretty(
        &Value::obj()
            .with("bench", "wire")
            .with("submit_bytes_json", json_request.len())
            .with("submit_bytes_bin", bin_request.len())
            .with("fids_bytes_json", json_fids.len())
            .with("fids_bytes_bin", bin_fids.len())
            .with("ratios", ratio_rows),
    );
    std::fs::write(&out_path, payload).expect("write BENCH_wire.json");
    println!("\nwrote {out_path}");

    // Gate: the binary plane must beat JSON by at least 2x on the hot
    // payloads (ceilings overridable via baseline.json's wire section).
    let (submit_cap, fids_cap) = wire_ratio_caps();
    let mut failed = false;
    for (name, ratio, cap) in [
        ("submit encode", ratios[0].1, submit_cap),
        ("submit decode", ratios[1].1, submit_cap),
        ("fids encode", ratios[2].1, fids_cap),
        ("fids decode", ratios[3].1, fids_cap),
    ] {
        if ratio > cap {
            eprintln!("wire/bin regression: {name} costs {ratio:.3}x of json (cap {cap})");
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
    println!("wire/bin vs json gate OK (submit cap {submit_cap}, fids cap {fids_cap})");
}

/// Ratio ceilings for the binary-vs-JSON gate, from the committed
/// baseline's `wire` section when present (default: half the JSON cost).
fn wire_ratio_caps() -> (f64, f64) {
    let path = std::env::var("DQ_BENCH_BASELINE")
        .unwrap_or_else(|_| "../bench/baseline.json".to_string());
    let caps = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| json::parse(&text).ok())
        .and_then(|base| {
            let wire = base.get("wire")?.clone();
            Some((
                wire.get("submit_max_ratio").and_then(Value::as_f64)?,
                wire.get("fids_max_ratio").and_then(Value::as_f64)?,
            ))
        });
    caps.unwrap_or((0.5, 0.5))
}

/// A stats snapshot with `tenants` retained tenants, all counters and
/// histogram buckets populated.
fn sample_stats(tenants: u64) -> ManagerStats {
    let mut stats = ManagerStats {
        submitted: 10_000,
        completed: 9_900,
        dispatches: 1_200,
        requeues: 3,
        evictions: 1,
        cancelled: 2,
        steals: 40,
        pruned_tenants: 100,
        ..Default::default()
    };
    for client in 1..=tenants {
        let mut wait_hist = WaitHistogram::new();
        for i in 0..8u32 {
            for _ in 0..=i {
                wait_hist.record(10f64.powi(i as i32 - 4));
            }
        }
        stats.per_tenant.insert(
            client,
            TenantStats {
                submitted: 100 + client,
                dispatched: 100 + client,
                completed: 100,
                lost: client % 3,
                stolen: client % 5,
                wait_total_s: 0.5 * client as f64,
                wait_max_s: 0.9,
                wait_hist,
            },
        );
    }
    let retired = stats.per_tenant[&1].clone();
    stats.retired = retired;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The `stats` op payload round-trips bit-exactly through the JSON
    /// wire — histograms included — so manager-reported p50/p90 are the
    /// numbers a remote operator actually reads.
    #[test]
    fn stats_payload_round_trips() {
        let stats = sample_stats(8);
        let json = wire::to_string(&proto::manager_stats_to_wire(&stats));
        let back = proto::manager_stats_from_wire(&wire::parse(&json).unwrap()).unwrap();
        assert_eq!(back.per_tenant.len(), 8);
        assert_eq!(back.steals, stats.steals);
        for (client, t) in &stats.per_tenant {
            let b = &back.per_tenant[client];
            assert_eq!(b.wait_hist, t.wait_hist);
            assert_eq!(b.wait_hist.p90(), t.wait_hist.p90());
            assert_eq!((b.submitted, b.stolen), (t.submitted, t.stolen));
        }
        assert_eq!(back.retired.wait_hist, stats.retired.wait_hist);
    }
}
