//! Microbenchmarks for Algorithm 2's workload assignment — the
//! co-Manager hot path — including the linear-scan vs binary-heap
//! ablation (DESIGN.md §10).
//!
//! ```bash
//! cargo bench --bench micro_scheduler
//! ```

use dqulearn::benchlib::{BenchConfig, Bencher};
use dqulearn::coordinator::registry::Registry;
use dqulearn::coordinator::scheduler::{self, SchedulerKind};
use dqulearn::util::Rng;

fn registry_of(n: usize, seed: u64) -> Registry {
    let mut rng = Rng::new(seed);
    let mut reg = Registry::new(5.0);
    for _ in 0..n {
        let mq = [5, 7, 10, 15, 20][rng.index(5)];
        let id = reg.register(mq, rng.f64(), 0.0);
        // random occupancy
        let occ = rng.index(mq);
        if occ > 0 {
            let _ = reg.reserve(id, id, occ);
        }
    }
    reg
}

fn main() {
    let mut b = Bencher::new(BenchConfig::from_env());

    for n in [4usize, 16, 64, 256, 1024] {
        let reg = registry_of(n, 3);
        b.bench(&format!("select linear-scan W={n}"), || {
            std::hint::black_box(scheduler::select_with(SchedulerKind::LinearScan, &reg, 5));
        });
        b.bench(&format!("select heap        W={n}"), || {
            std::hint::black_box(scheduler::select_with(SchedulerKind::Heap, &reg, 5));
        });
    }

    // full assign/release cycle (what one circuit costs the manager)
    let mut reg = registry_of(16, 5);
    let mut job = 10_000u64;
    b.bench("assign+release cycle W=16", || {
        if let Some(w) = scheduler::select(&reg, 5) {
            reg.reserve(w, job, 5).unwrap();
            reg.release(w, job);
            job += 1;
        }
    });

    // heartbeat processing cost
    let mut reg2 = registry_of(64, 7);
    let ids: Vec<u64> = reg2.workers().map(|w| w.id).collect();
    let mut i = 0;
    b.bench("heartbeat update W=64", || {
        let id = ids[i % ids.len()];
        let _ = reg2.heartbeat(id, 0.4, 1.0);
        i += 1;
    });

    print!("{}", b.report());
    println!("\n(the paper's pool sizes are W <= 4: linear scan is optimal there;\n the heap variant only matters past hundreds of workers)");
}
