//! Ablation: the co-Manager's dispatch batching policy (EXPERIMENTS.md
//! §Perf L3). `max_batch = 1` reproduces the paper's per-circuit
//! assignment; larger batches amortize dispatch/RPC/PJRT-padding costs
//! against scheduling granularity.
//!
//! ```bash
//! cargo bench --bench micro_batching
//! ```

use std::time::Instant;

use dqulearn::benchlib::Table;
use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::InProcCluster;
use dqulearn::coordinator::ManagerConfig;
use dqulearn::model::exec::CircuitExecutor;
use dqulearn::util::Rng;

fn run_with_batch(max_batch: usize, use_pjrt: bool, n: usize) -> (f64, u64) {
    let mut builder = InProcCluster::builder()
        .workers(&[5, 5])
        .manager_config(ManagerConfig { max_batch, ..Default::default() });
    if use_pjrt && std::path::Path::new("artifacts/manifest.json").exists() {
        builder = builder.artifacts("artifacts");
    }
    let cluster = builder.build().expect("cluster");
    let cfg = QuClassiConfig::new(5, 2).unwrap();
    let mut rng = Rng::new(9);
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
        .map(|_| {
            (
                (0..cfg.n_params()).map(|_| rng.f32()).collect(),
                (0..cfg.n_features()).map(|_| rng.f32()).collect(),
            )
        })
        .collect();
    // warmup (compile caches etc.)
    let _ = cluster.execute_bank(&cfg, &pairs[..32.min(n)]).unwrap();
    let t0 = Instant::now();
    let fids = cluster.execute_bank(&cfg, &pairs).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    assert_eq!(fids.len(), n);
    let dispatches = cluster.manager.stats().dispatches;
    cluster.shutdown();
    (n as f64 / secs, dispatches)
}

fn main() {
    let n = 2048;
    let have_pjrt = std::path::Path::new("artifacts/manifest.json").exists();
    println!("== dispatch batching ablation (2 workers, q5l2, {n} circuits) ==");
    let mut table = Table::new(&["max_batch", "backend", "circuits/s", "dispatches"]);
    let mut best = (0usize, 0.0f64);
    for &mb in &[1usize, 4, 8, 16, 32, 64] {
        let (cps, disp) = run_with_batch(mb, have_pjrt, n);
        if cps > best.1 {
            best = (mb, cps);
        }
        table.row(&[
            mb.to_string(),
            if have_pjrt { "pjrt" } else { "qsim" }.to_string(),
            format!("{cps:.0}"),
            disp.to_string(),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nbest batch: {} ({:.0} circuits/s). max_batch=1 is the paper's per-circuit \
         assignment; the adopted default is 32 (the artifact batch).",
        best.0, best.1
    );
}
