//! Ablation: noise-aware scheduling (extension §10 — the paper's
//! Discussion names noise-unawareness as a limitation: "quantum noise
//! has a significant impact on state fidelities").
//!
//! Setup: a 4-worker pool where two backends are ideal and two have
//! NISQ-grade depolarizing + readout noise. A client evaluates circuit
//! banks; we compare the fidelity error (vs exact simulation) under the
//! paper's CRU-only rule (noise-blind, spreads circuits everywhere)
//! against the noise-aware rule at several alpha weights.
//!
//! ```bash
//! cargo bench --bench ablation_noise
//! ```

use dqulearn::benchlib::Table;
use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::InProcCluster;
use dqulearn::coordinator::ManagerConfig;
use dqulearn::model::exec::{CircuitExecutor, QsimExecutor};
use dqulearn::qsim::NoiseModel;
use dqulearn::util::Rng;

fn mean_abs_error(alpha: Option<f64>, steal: bool, n: usize) -> (f64, f64, f64) {
    let noisy = NoiseModel { p1: 0.004, p2: 0.04, readout: 0.03 };
    let cluster = InProcCluster::builder()
        .workers_with_noise(&[
            (10, None),
            (10, None),
            (10, Some(noisy)),
            (10, Some(noisy)),
        ])
        // `steal` is a row parameter: `steal_for` applies the same
        // noise-compatibility predicate as placement (DESIGN.md §14),
        // so the steal-on α=1.0 row must match the steal-off row — an
        // idle noisy worker cannot blur the ablation by lifting a clean
        // worker's queued batches.
        .manager_config(ManagerConfig {
            noise_aware_alpha: alpha,
            steal,
            ..Default::default()
        })
        .build()
        .expect("cluster");
    let cfg = QuClassiConfig::new(5, 2).unwrap();
    let mut rng = Rng::new(77);
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
        .map(|_| {
            (
                (0..cfg.n_params()).map(|_| rng.f32() * 2.0).collect(),
                (0..cfg.n_features()).map(|_| rng.f32() * 2.0).collect(),
            )
        })
        .collect();
    let exact = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
    let t0 = std::time::Instant::now();
    let got = cluster.execute_bank(&cfg, &pairs).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let errs: Vec<f64> = got
        .iter()
        .zip(exact.iter())
        .map(|(a, b)| (a - b).abs() as f64)
        .collect();
    let mean = errs.iter().sum::<f64>() / errs.len() as f64;
    let max = errs.iter().cloned().fold(0.0, f64::max);
    cluster.shutdown();
    (mean, max, n as f64 / secs)
}

fn main() {
    let n = 512;
    println!("== noise-aware scheduling ablation (2 ideal + 2 noisy workers, q5l2, {n} circuits) ==");
    let mut table = Table::new(&["policy", "mean |Δfid|", "max |Δfid|", "circuits/s"]);
    let mut results = Vec::new();
    for (label, alpha, steal) in [
        ("CRU-only (paper)", None, false),
        ("noise-aware α=0.5", Some(0.5), false),
        ("noise-aware α=1.0", Some(1.0), false),
        ("noise-aware α=1.0 + steal", Some(1.0), true),
    ] {
        let (mean, max, cps) = mean_abs_error(alpha, steal, n);
        results.push((label, mean, cps));
        table.row(&[
            label.to_string(),
            format!("{mean:.4}"),
            format!("{max:.4}"),
            format!("{cps:.0}"),
        ]);
    }
    print!("{}", table.render());

    let blind = results[0].1;
    let aware = results[2].1;
    assert!(
        aware < blind * 0.25,
        "noise-aware routing should cut fidelity error substantially: {aware:.4} vs {blind:.4}"
    );
    let aware_steal = results[3].1;
    assert!(
        aware_steal < blind * 0.25,
        "steal-gated routing must hold the noise line: {aware_steal:.4} vs {blind:.4}"
    );
    println!(
        "\nnoise-aware (α=1.0) eliminates the fidelity error (mean {blind:.4} -> {aware:.4}) \
         by holding circuits for ideal backends; throughput here is {:.0} vs {:.0} circuits/s \
         (on this pool avoiding noisy backends costs nothing — with fewer ideal workers the \
         trade-off inverts, which is why α is a tunable).",
        results[2].2,
        results[0].2
    );
}
