//! Shared helpers for the figure-regeneration benches: the paper's
//! reported numbers and the side-by-side comparison renderer.
//!
//! We are NOT expected to match absolute seconds (the substrate is a
//! calibrated DES, not the authors' IBM-Q/GCP testbed); what must hold is
//! the *shape*: who wins, roughly by how much, and where the effect
//! saturates. Each bench prints paper-vs-ours with speedup ratios so the
//! comparison is mechanical.

use dqulearn::benchlib::Table;
use dqulearn::env::scenarios::FigureRow;

/// One paper datapoint: (layers, workers, runtime_s, circuits_per_sec).
/// `None` where the paper does not state the number.
pub type PaperPoint = (usize, usize, Option<f64>, Option<f64>);

/// Render ours-vs-paper, plus normalized speedups (runtime(W)/runtime(1))
/// which are the shape-preserving quantity.
pub fn render_comparison(title: &str, ours: &[FigureRow], paper: &[PaperPoint]) -> String {
    let mut out = format!("== {title} ==\n");
    let mut table = Table::new(&[
        "layers", "workers", "circuits", "ours runtime(s)", "ours c/s", "paper runtime(s)",
        "paper c/s", "ours rt/W1", "paper rt/W1",
    ]);
    for r in ours {
        let p = paper
            .iter()
            .find(|(l, w, _, _)| *l == r.layers && *w == r.workers)
            .copied()
            .unwrap_or((r.layers, r.workers, None, None));
        let ours_w1 = ours
            .iter()
            .find(|o| o.layers == r.layers && o.workers == 1)
            .map(|o| o.runtime)
            .unwrap_or(r.runtime);
        let paper_w1 = paper
            .iter()
            .find(|(l, w, rt, _)| *l == r.layers && *w == 1 && rt.is_some())
            .and_then(|(_, _, rt, _)| *rt);
        let fmt_opt = |x: Option<f64>| x.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into());
        let paper_ratio = match (p.2, paper_w1) {
            (Some(rt), Some(w1)) => format!("{:.2}", rt / w1),
            _ => "-".into(),
        };
        table.row(&[
            r.layers.to_string(),
            r.workers.to_string(),
            r.circuits.to_string(),
            format!("{:.1}", r.runtime),
            format!("{:.2}", r.cps),
            fmt_opt(p.2),
            fmt_opt(p.3),
            format!("{:.2}", r.runtime / ours_w1),
            paper_ratio,
        ]);
    }
    out.push_str(&table.render());
    out
}

/// Shape assertions shared by Figs 3-5: runtime monotonically decreasing
/// and throughput increasing in the worker count, per layer series.
pub fn assert_trends(ours: &[FigureRow]) {
    for layers in [1usize, 2, 3] {
        let series: Vec<&FigureRow> = ours.iter().filter(|r| r.layers == layers).collect();
        for pair in series.windows(2) {
            assert!(
                pair[1].runtime < pair[0].runtime,
                "layers {layers}: runtime did not improve {} -> {} workers",
                pair[0].workers,
                pair[1].workers
            );
            assert!(
                pair[1].cps > pair[0].cps,
                "layers {layers}: throughput did not improve {} -> {} workers",
                pair[0].workers,
                pair[1].workers
            );
        }
    }
}
