//! Microbenchmarks for the PJRT artifact runtime — per-execution latency,
//! batched throughput per configuration, and the fused-gradient path.
//! These numbers calibrate the DES (`Calibration::from_measured`) and are
//! the L1/L2 perf baseline recorded in EXPERIMENTS.md §Perf.
//!
//! ```bash
//! make artifacts && cargo bench --bench micro_runtime
//! ```

use dqulearn::benchlib::{BenchConfig, Bencher, Table};
use dqulearn::circuit::QuClassiConfig;
use dqulearn::model::exec::{CircuitExecutor, QsimExecutor};
use dqulearn::runtime::PjrtEngine;
use dqulearn::util::Rng;

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ not built; run `make artifacts` first. skipping.");
        return;
    }
    let engine = PjrtEngine::load(dir).expect("engine load");
    let mut b = Bencher::new(BenchConfig::default());
    let mut rng = Rng::new(2);

    let mut calib = Table::new(&["config", "pjrt us/circuit (batch 32)", "qsim us/circuit", "ratio"]);
    for cfg in QuClassiConfig::paper_configs() {
        let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..32)
            .map(|_| {
                (
                    (0..cfg.n_params()).map(|_| rng.f32()).collect(),
                    (0..cfg.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect();
        let name = format!("q{}l{}", cfg.qubits, cfg.layers);
        let r_pjrt = b
            .bench(&format!("pjrt execute 32x {name}"), || {
                std::hint::black_box(engine.execute(&cfg, &pairs).unwrap());
            })
            .clone();
        let r_qsim = b
            .bench(&format!("qsim execute 32x {name}"), || {
                std::hint::black_box(QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
            })
            .clone();
        let pjrt_us = r_pjrt.summary.mean * 1e6 / 32.0;
        let qsim_us = r_qsim.summary.mean * 1e6 / 32.0;
        calib.row(&[
            name,
            format!("{pjrt_us:.1}"),
            format!("{qsim_us:.1}"),
            format!("{:.2}x", pjrt_us / qsim_us),
        ]);
    }

    // single-circuit latency (the interactive path)
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let single = vec![(vec![0.3f32; 4], vec![0.7f32; 4])];
    b.bench("pjrt execute 1x q5l1 (padded to 32)", || {
        std::hint::black_box(engine.execute(&cfg, &single).unwrap());
    });

    // fused on-device gradient vs host-assembled bank
    let cfg = QuClassiConfig::new(5, 2).unwrap();
    let theta: Vec<f32> = (0..cfg.n_params()).map(|_| rng.f32()).collect();
    let data: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..cfg.n_features()).map(|_| rng.f32()).collect())
        .collect();
    b.bench("pjrt fused grad (8 samples, q5l2)", || {
        std::hint::black_box(engine.execute_grad(&cfg, &theta, &data).unwrap());
    });
    let bank = dqulearn::circuit::CircuitBank::new(cfg, &theta);
    b.bench("pjrt host-assembled grad (8 samples, q5l2)", || {
        for d in &data {
            let pairs: Vec<(Vec<f32>, Vec<f32>)> =
                bank.entries().iter().map(|e| (e.thetas.clone(), d.clone())).collect();
            let fids = engine.execute(&cfg, &pairs).unwrap();
            std::hint::black_box(bank.assemble(&fids));
        }
    });

    print!("{}", b.report());
    println!("\nDES calibration table (per-circuit cost on this machine):");
    print!("{}", calib.render());
    let stats = engine.stats();
    println!(
        "\nengine totals: {} executions, {} circuits ({} padded)",
        stats.executions, stats.circuits, stats.padded_circuits
    );
    engine.shutdown();
}
