//! Regenerates **Figure 5** (paper §IV-C2): one client on the controlled
//! (GCP e2-medium) environment, 5-qubit workers, 1/2/3 layers × 1/2/4
//! workers. The paper's headline percentages — 4-worker vs 1-/2-worker
//! improvements of 27.1/18.9% (1L), 37.3/31.5% (2L), 43.2/30.0% (3L) —
//! are recomputed from our runs and compared.
//!
//! ```bash
//! cargo bench --bench fig5_controlled
//! ```

mod fig_common;

use dqulearn::env::scenarios::gcp_one_client_figure;
use dqulearn::env::Calibration;
use fig_common::{assert_trends, render_comparison, PaperPoint};

/// Paper Fig. 5b circuits/sec (runtime is given as relative improvements).
const PAPER: &[PaperPoint] = &[
    (1, 1, None, Some(3.8)),
    (1, 2, None, Some(4.2)),
    (1, 4, None, Some(5.2)),
    (3, 1, None, Some(2.4)),
    (3, 2, None, Some(3.1)),
    (3, 4, None, Some(4.4)),
];

/// Paper's 4-worker improvement over (1-worker, 2-worker), percent.
const PAPER_IMPROVEMENTS: &[(usize, f64, f64)] =
    &[(1, 27.1, 18.9), (2, 37.3, 31.5), (3, 43.2, 30.0)];

fn main() {
    let calib = Calibration::qiskit_like();
    let rows = gcp_one_client_figure(5, &calib, 3);
    println!(
        "{}",
        render_comparison("Figure 5: 5-qubit controlled environment, one client (DES)", &rows, PAPER)
    );
    assert_trends(&rows);

    println!("4-worker improvement over 1-/2-worker (runtime reduction %):");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12}",
        "layers", "ours vs 1W", "paper vs 1W", "ours vs 2W", "paper vs 2W"
    );
    for &(layers, paper_vs1, paper_vs2) in PAPER_IMPROVEMENTS {
        let rt = |w: usize| {
            rows.iter().find(|r| r.layers == layers && r.workers == w).unwrap().runtime
        };
        let ours_vs1 = (1.0 - rt(4) / rt(1)) * 100.0;
        let ours_vs2 = (1.0 - rt(4) / rt(2)) * 100.0;
        println!(
            "{layers:>6} {ours_vs1:>11.1}% {paper_vs1:>11.1}% {ours_vs2:>11.1}% {paper_vs2:>11.1}%"
        );
        // Shape: the improvement grows with depth (compute-bound circuits
        // parallelize better) — the paper's central Fig-5 observation.
    }
    let imp = |layers: usize| {
        let rt = |w: usize| {
            rows.iter().find(|r| r.layers == layers && r.workers == w).unwrap().runtime
        };
        1.0 - rt(4) / rt(1)
    };
    assert!(imp(3) > imp(1), "deeper circuits must gain more from workers");
    println!("\nshape check passed: deeper circuits gain more from added workers");
}
