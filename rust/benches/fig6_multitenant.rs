//! Regenerates **Figure 6** (paper §IV-C2): four concurrent clients
//! (multi-tenant) on four workers with 5/10/15/20 qubits, single-tenant
//! vs multi-tenant. Paper headlines: up to 68.7% runtime reduction and a
//! 3.9x circuits/sec gain for the small 5Q/1L job; only 8.2% for the
//! congested 7Q/2L job.
//!
//! ```bash
//! cargo bench --bench fig6_multitenant
//! ```

use dqulearn::benchlib::Table;
use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::InProcCluster;
use dqulearn::env::scenarios::multi_tenant_figure;
use dqulearn::env::Calibration;
use dqulearn::model::exec::CircuitExecutor;
use dqulearn::util::Rng;

/// Paper-reported per-client effects (where stated).
const PAPER_REDUCTION: &[(&str, f64)] = &[("5Q/1L", 68.7), ("7Q/2L", 8.2)];
const PAPER_CPS_GAIN: &[(&str, f64)] = &[("5Q/1L", 3.9)];

fn main() {
    let calib = Calibration::qiskit_like();
    let rows = multi_tenant_figure(&calib, 7);

    println!("== Figure 6: multi-tenant system (4 clients, workers 5/10/15/20 qubits, DES) ==");
    let mut table = Table::new(&[
        "job", "circuits", "single(s)", "multi(s)", "ours red.%", "paper red.%", "ours cps gain",
        "paper cps gain",
    ]);
    for r in &rows {
        let paper_red = PAPER_REDUCTION
            .iter()
            .find(|(l, _)| *l == r.label)
            .map(|(_, v)| format!("{v:.1}"))
            .unwrap_or_else(|| "-".into());
        let paper_gain = PAPER_CPS_GAIN
            .iter()
            .find(|(l, _)| *l == r.label)
            .map(|(_, v)| format!("{v:.1}x"))
            .unwrap_or_else(|| "-".into());
        table.row(&[
            r.label.clone(),
            r.circuits.to_string(),
            format!("{:.1}", r.single_runtime),
            format!("{:.1}", r.multi_runtime),
            format!("{:.1}", r.runtime_reduction_pct()),
            paper_red,
            format!("{:.2}x", r.cps_gain()),
            paper_gain,
        ]);
    }
    print!("{}", table.render());

    // Shape checks (the paper's Fig-6 narrative):
    let small = rows.iter().find(|r| r.label == "5Q/1L").expect("5Q/1L row");
    assert!(
        small.runtime_reduction_pct() > 30.0,
        "small job must gain large runtime reduction, got {:.1}%",
        small.runtime_reduction_pct()
    );
    assert!(small.cps_gain() > 1.5, "small job must gain multi-x cps");
    for r in &rows {
        assert!(
            small.cps_gain() >= r.cps_gain() - 1e-9,
            "the small 5Q/1L job must gain the most (vs {})",
            r.label
        );
    }
    println!(
        "\nshape checks passed: 5Q/1L gains the most ({:.1}% runtime reduction, {:.2}x cps — \
         paper: 68.7%, 3.9x); congested jobs change least",
        small.runtime_reduction_pct(),
        small.cps_gain()
    );

    // Seed-robustness: the headline survives different jitter draws.
    for seed in [21u64, 33, 55] {
        let r = multi_tenant_figure(&calib, seed);
        let s = r.iter().find(|x| x.label == "5Q/1L").unwrap();
        assert!(s.cps_gain() > 1.5, "seed {seed}: headline vanished");
    }
    println!("seed-robustness check passed (3 extra seeds)");

    live_worker_parallelism();
}

/// Live (non-DES) counterpart: the same 5/10/15/20-qubit pool executing
/// real statevector circuits, with serial vs pooled worker backends
/// (DESIGN.md §11). Results are bitwise identical across the two runs;
/// only the wall clock moves.
fn live_worker_parallelism() {
    const CIRCUITS: usize = 512;
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let mut rng = Rng::new(6);
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..CIRCUITS)
        .map(|_| {
            (
                (0..cfg.n_params()).map(|_| rng.f32() * 2.0).collect(),
                (0..cfg.n_features()).map(|_| rng.f32() * 2.0).collect(),
            )
        })
        .collect();

    let run = |threads: usize| -> (f64, Vec<f32>) {
        let cluster = InProcCluster::builder()
            .workers(&[5, 10, 15, 20])
            .worker_threads(threads)
            .build()
            .expect("cluster");
        let t = std::time::Instant::now();
        let fids = cluster.execute_bank(&cfg, &pairs).expect("bank");
        let secs = t.elapsed().as_secs_f64();
        cluster.shutdown();
        (secs, fids)
    };

    println!("\n== live pool: {CIRCUITS} x 5Q/1L circuits, serial vs pooled workers ==");
    let (serial_secs, serial_fids) = run(1);
    let mut table = Table::new(&["worker threads", "wall(s)", "circuits/s", "gain"]);
    for threads in [1usize, 2, 4] {
        let (secs, fids) = if threads == 1 { (serial_secs, serial_fids.clone()) } else { run(threads) };
        assert_eq!(fids, serial_fids, "parallel workers changed results");
        table.row(&[
            threads.to_string(),
            format!("{secs:.3}"),
            format!("{:.0}", CIRCUITS as f64 / secs),
            format!("{:.2}x", serial_secs / secs),
        ]);
    }
    print!("{}", table.render());
}
