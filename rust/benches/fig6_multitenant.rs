//! Regenerates **Figure 6** (paper §IV-C2): four concurrent clients
//! (multi-tenant) on four workers with 5/10/15/20 qubits, single-tenant
//! vs multi-tenant. Paper headlines: up to 68.7% runtime reduction and a
//! 3.9x circuits/sec gain for the small 5Q/1L job; only 8.2% for the
//! congested 7Q/2L job.
//!
//! ```bash
//! cargo bench --bench fig6_multitenant
//! ```

use dqulearn::benchlib::Table;
use dqulearn::env::scenarios::multi_tenant_figure;
use dqulearn::env::Calibration;

/// Paper-reported per-client effects (where stated).
const PAPER_REDUCTION: &[(&str, f64)] = &[("5Q/1L", 68.7), ("7Q/2L", 8.2)];
const PAPER_CPS_GAIN: &[(&str, f64)] = &[("5Q/1L", 3.9)];

fn main() {
    let calib = Calibration::qiskit_like();
    let rows = multi_tenant_figure(&calib, 7);

    println!("== Figure 6: multi-tenant system (4 clients, workers 5/10/15/20 qubits, DES) ==");
    let mut table = Table::new(&[
        "job", "circuits", "single(s)", "multi(s)", "ours red.%", "paper red.%", "ours cps gain",
        "paper cps gain",
    ]);
    for r in &rows {
        let paper_red = PAPER_REDUCTION
            .iter()
            .find(|(l, _)| *l == r.label)
            .map(|(_, v)| format!("{v:.1}"))
            .unwrap_or_else(|| "-".into());
        let paper_gain = PAPER_CPS_GAIN
            .iter()
            .find(|(l, _)| *l == r.label)
            .map(|(_, v)| format!("{v:.1}x"))
            .unwrap_or_else(|| "-".into());
        table.row(&[
            r.label.clone(),
            r.circuits.to_string(),
            format!("{:.1}", r.single_runtime),
            format!("{:.1}", r.multi_runtime),
            format!("{:.1}", r.runtime_reduction_pct()),
            paper_red,
            format!("{:.2}x", r.cps_gain()),
            paper_gain,
        ]);
    }
    print!("{}", table.render());

    // Shape checks (the paper's Fig-6 narrative):
    let small = rows.iter().find(|r| r.label == "5Q/1L").expect("5Q/1L row");
    assert!(
        small.runtime_reduction_pct() > 30.0,
        "small job must gain large runtime reduction, got {:.1}%",
        small.runtime_reduction_pct()
    );
    assert!(small.cps_gain() > 1.5, "small job must gain multi-x cps");
    for r in &rows {
        assert!(
            small.cps_gain() >= r.cps_gain() - 1e-9,
            "the small 5Q/1L job must gain the most (vs {})",
            r.label
        );
    }
    println!(
        "\nshape checks passed: 5Q/1L gains the most ({:.1}% runtime reduction, {:.2}x cps — \
         paper: 68.7%, 3.9x); congested jobs change least",
        small.runtime_reduction_pct(),
        small.cps_gain()
    );

    // Seed-robustness: the headline survives different jitter draws.
    for seed in [21u64, 33, 55] {
        let r = multi_tenant_figure(&calib, seed);
        let s = r.iter().find(|x| x.label == "5Q/1L").unwrap();
        assert!(s.cps_gain() > 1.5, "seed {seed}: headline vanished");
    }
    println!("seed-robustness check passed (3 extra seeds)");
}
