//! Microbenchmark for same-config batch packing on the pending queue —
//! the co-Manager's per-assignment scan.
//!
//! The old packer called `VecDeque::remove(scanned)` inside a scan loop:
//! each removal shifts the tail, so packing a batch out of a queue with
//! `n` pending circuits cost O(n²) element moves when tenants interleave.
//! The admission queue now takes the contiguous same-config prefix
//! directly and falls back to a single drain/partition pass — O(n) total,
//! and since PR 4 the scan is bounded by one *tenant's* backlog rather
//! than the global queue (`coordinator/admission.rs`). This bench shows
//! the gap at 10k pending circuits (and the scaling trend).
//!
//! ```bash
//! cargo bench --bench micro_queue
//! ```

use std::collections::VecDeque;

use dqulearn::benchlib::{BenchConfig, Bencher};
use dqulearn::circuit::QuClassiConfig;
use dqulearn::coordinator::CircuitJob;

/// A queue of `n` pending circuits from two interleaved tenants with
/// different configs — the worst case for head-config batch packing.
fn interleaved_queue(n: usize) -> (VecDeque<CircuitJob>, QuClassiConfig) {
    let cfg_a = QuClassiConfig::new(5, 1).unwrap();
    let cfg_b = QuClassiConfig::new(7, 1).unwrap();
    let q = (0..n)
        .map(|i| {
            let config = if i % 2 == 0 { cfg_a } else { cfg_b };
            CircuitJob {
                id: i as u64,
                client: (i % 2) as u64,
                bank: (i % 2) as u64,
                index: i / 2,
                config,
                thetas: vec![0.1; config.n_params()],
                data: vec![0.2; config.n_features()],
            }
        })
        .collect();
    (q, cfg_a)
}

/// The pre-redesign packer: scan with in-place `remove` (O(n²)).
fn pack_remove_in_scan(
    q: &mut VecDeque<CircuitJob>,
    config: QuClassiConfig,
    limit: usize,
) -> Vec<CircuitJob> {
    let mut jobs = Vec::new();
    let mut scanned = 0;
    while scanned < q.len() && jobs.len() < limit {
        if q[scanned].config == config {
            jobs.push(q.remove(scanned).unwrap());
        } else {
            scanned += 1;
        }
    }
    jobs
}

/// The current packer: contiguous prefix + one drain/partition pass (O(n)).
fn pack_partition(
    q: &mut VecDeque<CircuitJob>,
    config: QuClassiConfig,
    limit: usize,
) -> Vec<CircuitJob> {
    let mut jobs = Vec::with_capacity(limit.min(q.len()));
    while jobs.len() < limit && q.front().is_some_and(|j| j.config == config) {
        jobs.push(q.pop_front().unwrap());
    }
    if jobs.len() < limit && q.iter().any(|j| j.config == config) {
        let mut rest = VecDeque::with_capacity(q.len());
        while let Some(job) = q.pop_front() {
            if jobs.len() < limit && job.config == config {
                jobs.push(job);
            } else {
                rest.push_back(job);
            }
        }
        *q = rest;
    }
    jobs
}

fn main() {
    let mut b = Bencher::new(BenchConfig::from_env());
    const BATCH: usize = 32;

    for n in [1_000usize, 10_000] {
        let (template, cfg) = interleaved_queue(n);

        // sanity: both strategies pick the identical batch
        {
            let mut q1 = template.clone();
            let mut q2 = template.clone();
            let a = pack_remove_in_scan(&mut q1, cfg, BATCH);
            let b2 = pack_partition(&mut q2, cfg, BATCH);
            assert_eq!(
                a.iter().map(|j| j.id).collect::<Vec<_>>(),
                b2.iter().map(|j| j.id).collect::<Vec<_>>()
            );
            assert_eq!(
                q1.iter().map(|j| j.id).collect::<Vec<_>>(),
                q2.iter().map(|j| j.id).collect::<Vec<_>>()
            );
        }

        let t = template.clone();
        b.bench(&format!("pack remove-in-scan n={n}"), || {
            let mut q = t.clone();
            std::hint::black_box(pack_remove_in_scan(&mut q, cfg, BATCH));
        });
        let t = template.clone();
        b.bench(&format!("pack drain/partition n={n}"), || {
            let mut q = t.clone();
            std::hint::black_box(pack_partition(&mut q, cfg, BATCH));
        });
        // the common case: a homogeneous run at the head (single tenant)
        let (homo, hcfg) = {
            let cfg = QuClassiConfig::new(5, 1).unwrap();
            let q: VecDeque<CircuitJob> = (0..n)
                .map(|i| CircuitJob {
                    id: i as u64,
                    client: 0,
                    bank: 0,
                    index: i,
                    config: cfg,
                    thetas: vec![0.1; cfg.n_params()],
                    data: vec![0.2; cfg.n_features()],
                })
                .collect();
            (q, cfg)
        };
        b.bench(&format!("pack homogeneous prefix n={n}"), || {
            let mut q = homo.clone();
            std::hint::black_box(pack_partition(&mut q, hcfg, BATCH));
        });
    }

    print!("{}", b.report());
}
