//! Multi-tenant fairness and dispatch-latency integration tests for the
//! event-driven co-Manager (DESIGN.md §13).
//!
//! * Starvation: a greedy tenant flooding 10k circuits must not delay
//!   small tenants' banks — weighted round-robin admission bounds their
//!   queue wait structurally, not emergently.
//! * Latency: with an idle worker pool, submit→dispatch→complete must
//!   not wait on the 20 ms liveness tick; dispatch is woken by the
//!   submit event itself.
//! * Observability: starvation bounds are asserted against the
//!   *manager-reported* per-tenant wait histograms (`TenantStats::
//!   wait_hist`), not test-side percentile math — the same numbers an
//!   operator reads over the TCP `stats` op.
//! * Composition: noise-aware selection and WRR admission hold
//!   simultaneously (with `steal: false` isolating the placement
//!   policy), and per-tenant stats stay bounded under client churn.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dqulearn::circuit::QuClassiConfig;
use dqulearn::coordinator::{Manager, ManagerConfig, WorkerChannel, WorkerProfile};
use dqulearn::error::DqError;
use dqulearn::model::exec::CircuitPair;

/// Instant worker channel (pure coordination cost).
struct InstantChannel;

impl WorkerChannel for InstantChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        Ok(vec![0.5; pairs.len()])
    }
}

/// Worker channel with a fixed per-batch service time.
struct PacedChannel {
    delay: Duration,
}

impl WorkerChannel for PacedChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        std::thread::sleep(self.delay);
        Ok(vec![0.5; pairs.len()])
    }
}

/// Counting channel with a fixed per-batch service time: tracks which
/// worker pool (clean/noisy) executed how many circuits.
struct CountingChannel {
    count: Arc<AtomicUsize>,
    delay: Duration,
}

impl WorkerChannel for CountingChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.count.fetch_add(pairs.len(), Ordering::SeqCst);
        Ok(vec![0.5; pairs.len()])
    }
}

fn pairs_for(config: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
    (0..n)
        .map(|_| (vec![0.1; config.n_params()], vec![0.2; config.n_features()]))
        .collect()
}

/// One greedy tenant floods 10k circuits; three small tenants submitting
/// after it must see bounded bank latency (WRR admission) instead of
/// queueing behind the whole flood (the old single-FIFO pathology, where
/// each small bank would wait for the greedy backlog to drain: >1 s
/// here).
#[test]
fn greedy_tenant_cannot_starve_small_tenants() {
    let manager = Manager::new(ManagerConfig { max_batch: 8, ..Default::default() });
    manager.register(
        WorkerProfile::new(5),
        Arc::new(PacedChannel { delay: Duration::from_millis(1) }),
    );
    let cfg = QuClassiConfig::new(5, 1).unwrap();

    // Greedy tenant: one 10k-circuit bank (~1250 batches x 1 ms).
    let greedy = manager.session();
    let greedy_bank = greedy.submit(cfg, &pairs_for(&cfg, 10_000)).unwrap();

    // Three small tenants, each submitting 10 sequential 4-circuit banks.
    let handles: Vec<_> = (0..3)
        .map(|_| {
            let m = manager.clone();
            std::thread::spawn(move || {
                let session = m.session();
                let cfg = QuClassiConfig::new(5, 1).unwrap();
                for _ in 0..10 {
                    let fids = session.execute(cfg, &pairs_for(&cfg, 4)).unwrap();
                    assert_eq!(fids.len(), 4);
                }
                session.id()
            })
        })
        .collect();
    let small_ids: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // The greedy flood must still be running — otherwise the small
    // tenants never actually competed with it.
    let st = greedy_bank.try_poll().unwrap();
    assert!(st.pending, "flood finished too early; fairness was not exercised");
    assert!(st.completed < st.total);

    // Starvation bound from the *manager-reported* wait histograms: the
    // p50/p90 an operator reads over the `stats` op, not test-side
    // percentile math over client-measured latencies.
    let stats = manager.stats();
    for id in &small_ids {
        let t = &stats.per_tenant[id];
        assert_eq!(t.dispatched, 40, "tenant {id} dispatched {}", t.dispatched);
        assert_eq!(t.completed, 40);
        assert_eq!(t.wait_hist.total(), 40, "every dispatched circuit is histogrammed");
        // Histogram quantiles are conservative bucket upper bounds
        // (..., 0.1, 0.3162, 1.0, inf), so bound at a bucket edge: a
        // p90 above 1 s means the tenant genuinely starved. wait_max_s
        // below keeps the tighter exact bound.
        let (p50, p90) = (t.wait_hist.p50(), t.wait_hist.p90());
        assert!(
            p90 <= 1.0,
            "tenant {id} p90 queue wait bound {p90:.3}s: starved behind the greedy flood"
        );
        assert!(p50 <= p90, "tenant {id}: p50 {p50} > p90 {p90}");
        assert!(
            t.wait_max_s < 0.5,
            "tenant {id} max queue wait {:.3}s: starved",
            t.wait_max_s
        );
    }
    let g = &stats.per_tenant[&greedy.id()];
    assert_eq!(g.submitted, 10_000);
    assert!(g.dispatched > 0);

    // Drain the flood quickly and shut down.
    greedy_bank.cancel().unwrap();
    manager.shutdown();
}

/// With an idle pool, a submitted circuit is dispatched by the submit
/// event itself, never by the liveness timer. The eviction tick is
/// cranked to 5 s, so if any dispatch step still waited on it, not even
/// one of the 20 sequential banks could complete inside the 2 s budget
/// (tick-driven dispatch would need >= 100 s); event-driven dispatch
/// finishes in milliseconds.
#[test]
fn idle_pool_dispatch_does_not_wait_for_tick() {
    let manager = Manager::new(ManagerConfig {
        eviction_tick: Duration::from_secs(5),
        ..Default::default()
    });
    manager.register(WorkerProfile::new(5), Arc::new(InstantChannel));
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let session = manager.session();
    let pair = pairs_for(&cfg, 1);

    let start = Instant::now();
    for _ in 0..20 {
        let handle = session.submit(cfg, &pair).unwrap();
        let fids = handle.wait_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(fids.len(), 1);
    }
    let elapsed = start.elapsed();
    assert!(
        elapsed < Duration::from_secs(2),
        "20 idle-pool round trips took {elapsed:?}: dispatch is waiting on the timer"
    );
    assert_eq!(manager.stats().completed, 20);
    manager.shutdown();
}

/// Tenant weights bias the round-robin without starving anyone: with
/// equal backlogs and a weight-4 tenant, the heavy tenant finishes
/// first, but the light tenant still completes everything.
#[test]
fn tenant_weights_bias_service_order() {
    let manager = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
    manager.register(
        WorkerProfile::new(5),
        Arc::new(PacedChannel { delay: Duration::from_micros(500) }),
    );
    let cfg = QuClassiConfig::new(5, 1).unwrap();

    let heavy = manager.session();
    let light = manager.session();
    manager.set_tenant_weight(heavy.id(), 4);

    let heavy_bank = heavy.submit(cfg, &pairs_for(&cfg, 200)).unwrap();
    let light_bank = light.submit(cfg, &pairs_for(&cfg, 200)).unwrap();
    let heavy_fids = heavy_bank.wait().unwrap();
    let light_fids = light_bank.wait().unwrap();
    assert_eq!((heavy_fids.len(), light_fids.len()), (200, 200));

    let stats = manager.stats();
    let h = &stats.per_tenant[&heavy.id()];
    let l = &stats.per_tenant[&light.id()];
    assert_eq!((h.completed, l.completed), (200, 200));
    // The weight-4 tenant's circuits spent less time queued on average.
    let h_mean = h.wait_total_s / h.dispatched.max(1) as f64;
    let l_mean = l.wait_total_s / l.dispatched.max(1) as f64;
    assert!(
        h_mean <= l_mean * 1.5,
        "weighted tenant queued longer than the unweighted one: {h_mean:.4}s vs {l_mean:.4}s"
    );
    manager.shutdown();
}

/// Noise-aware selection and WRR admission compose (the ROADMAP's open
/// interaction): with `alpha = 1.0` only least-noise workers are
/// eligible, so every circuit of every tenant lands on a clean worker —
/// even though the noisy workers are idle and instant — while the
/// per-tenant p90 queue wait stays inside the fairness bound. The
/// second half turns stealing on and asserts the same no-leak
/// invariant: `steal_for` now applies the noise-compatibility predicate
/// before lifting a batch, so an idle noisy worker can no longer bypass
/// selection by stealing a clean worker's surplus.
#[test]
fn noise_aware_selection_composes_with_wrr_fairness() {
    let run = |steal: bool| -> (usize, usize, Manager, Vec<u64>) {
        let manager = Manager::new(ManagerConfig {
            max_batch: 4,
            noise_aware_alpha: Some(1.0),
            steal,
            ..Default::default()
        });
        let clean = Arc::new(AtomicUsize::new(0));
        let noisy = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            // Clean but paced: the "right" choice is the slower one.
            manager.register(
                WorkerProfile::new(10).noise(0.0),
                Arc::new(CountingChannel {
                    count: clean.clone(),
                    delay: Duration::from_micros(500),
                }),
            );
            // Noisy but instant and idle: the tempting wrong choice.
            manager.register(
                WorkerProfile::new(10).noise(0.2),
                Arc::new(CountingChannel { count: noisy.clone(), delay: Duration::ZERO }),
            );
        }
        let tenants: Vec<_> = (0..3)
            .map(|_| {
                let m = manager.clone();
                std::thread::spawn(move || {
                    let session = m.session();
                    let cfg = QuClassiConfig::new(5, 1).unwrap();
                    for _ in 0..10 {
                        let fids = session.execute(cfg, &pairs_for(&cfg, 8)).unwrap();
                        assert_eq!(fids.len(), 8);
                    }
                    session.id()
                })
            })
            .collect();
        let ids: Vec<u64> = tenants.into_iter().map(|h| h.join().unwrap()).collect();
        (clean.load(Ordering::SeqCst), noisy.load(Ordering::SeqCst), manager, ids)
    };

    // steal off: placement policy holds absolutely, fairness holds too
    let (clean, noisy, manager, ids) = run(false);
    assert_eq!(noisy, 0, "noise-aware selection leaked {noisy} circuits to noisy workers");
    assert_eq!(clean, 240);
    let stats = manager.stats();
    assert_eq!(stats.steals, 0);
    for id in &ids {
        let t = &stats.per_tenant[id];
        assert_eq!(t.completed, 80);
        // bucket-edge bound (quantiles report bucket upper bounds)
        let p90 = t.wait_hist.p90();
        assert!(p90 <= 1.0, "tenant {id} p90 wait bound {p90:.3}s under noise-aware selection");
    }
    manager.shutdown();

    // steal on: the noise gate in `steal_for` keeps idle noisy workers
    // out of the steal path, so placement still holds absolutely. (No
    // `steals > 0` assertion: with only noisy workers idle there is
    // nothing legal to steal, and that is the point.)
    let (clean_on, noisy_on, manager_on, _) = run(true);
    assert_eq!(
        noisy_on, 0,
        "work stealing leaked {noisy_on} circuits past noise-aware selection"
    );
    assert_eq!(clean_on, 240);
    manager_on.shutdown();
}

/// Bounded per-tenant stats retention: 10k one-shot clients churn
/// through, and the per-tenant map stays at the configured cap with the
/// pruned tenants' counters folded — losslessly — into the `retired`
/// aggregate.
#[test]
fn per_tenant_stats_stay_bounded_under_client_churn() {
    let manager = Manager::new(ManagerConfig { max_tenant_stats: 64, ..Default::default() });
    manager.register(WorkerProfile::new(5), Arc::new(InstantChannel));
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pair = pairs_for(&cfg, 1);
    for _ in 0..10_000 {
        let session = manager.session();
        let fids = session.execute(cfg, &pair).unwrap();
        assert_eq!(fids.len(), 1);
    }
    let stats = manager.stats();
    // The prune pass uses hysteresis (engages at 1.5x the cap, prunes
    // back to the cap), so the hard bound is cap + cap/2.
    assert!(
        stats.per_tenant.len() <= 96,
        "per-tenant map grew to {} entries despite the 64-entry cap",
        stats.per_tenant.len()
    );
    assert_eq!(stats.completed, 10_000);
    let retained: u64 = stats.per_tenant.values().map(|t| t.submitted).sum();
    assert_eq!(
        retained + stats.retired.submitted,
        10_000,
        "pruning lost counts: {} retained + {} retired",
        retained,
        stats.retired.submitted
    );
    assert!(stats.pruned_tenants >= 10_000 - 96);
    assert_eq!(stats.retired.completed, stats.retired.submitted, "only quiescent tenants prune");
    assert_eq!(stats.retired.wait_hist.total(), stats.retired.dispatched);
    manager.shutdown();
}
