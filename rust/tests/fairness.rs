//! Multi-tenant fairness and dispatch-latency integration tests for the
//! event-driven co-Manager (DESIGN.md §13).
//!
//! * Starvation: a greedy tenant flooding 10k circuits must not delay
//!   small tenants' banks — weighted round-robin admission bounds their
//!   queue wait structurally, not emergently.
//! * Latency: with an idle worker pool, submit→dispatch→complete must
//!   not wait on the 20 ms liveness tick; dispatch is woken by the
//!   submit event itself.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dqulearn::circuit::QuClassiConfig;
use dqulearn::coordinator::{Manager, ManagerConfig, WorkerChannel, WorkerProfile};
use dqulearn::error::DqError;
use dqulearn::model::exec::CircuitPair;

/// Instant worker channel (pure coordination cost).
struct InstantChannel;

impl WorkerChannel for InstantChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        Ok(vec![0.5; pairs.len()])
    }
}

/// Worker channel with a fixed per-batch service time.
struct PacedChannel {
    delay: Duration,
}

impl WorkerChannel for PacedChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        std::thread::sleep(self.delay);
        Ok(vec![0.5; pairs.len()])
    }
}

fn pairs_for(config: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
    (0..n)
        .map(|_| (vec![0.1; config.n_params()], vec![0.2; config.n_features()]))
        .collect()
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One greedy tenant floods 10k circuits; three small tenants submitting
/// after it must see bounded bank latency (WRR admission) instead of
/// queueing behind the whole flood (the old single-FIFO pathology, where
/// each small bank would wait for the greedy backlog to drain: >1 s
/// here).
#[test]
fn greedy_tenant_cannot_starve_small_tenants() {
    let manager = Manager::new(ManagerConfig { max_batch: 8, ..Default::default() });
    manager.register(
        WorkerProfile::new(5),
        Arc::new(PacedChannel { delay: Duration::from_millis(1) }),
    );
    let cfg = QuClassiConfig::new(5, 1).unwrap();

    // Greedy tenant: one 10k-circuit bank (~1250 batches x 1 ms).
    let greedy = manager.session();
    let greedy_bank = greedy.submit(cfg, &pairs_for(&cfg, 10_000)).unwrap();

    // Three small tenants, each submitting 10 sequential 4-circuit banks.
    let mut latencies_s: Vec<f64> = Vec::new();
    let handles: Vec<_> = (0..3)
        .map(|_| {
            let m = manager.clone();
            std::thread::spawn(move || {
                let session = m.session();
                let cfg = QuClassiConfig::new(5, 1).unwrap();
                let mut waits = Vec::with_capacity(10);
                for _ in 0..10 {
                    let t = Instant::now();
                    let fids = session.execute(cfg, &pairs_for(&cfg, 4)).unwrap();
                    assert_eq!(fids.len(), 4);
                    waits.push(t.elapsed().as_secs_f64());
                }
                (session.id(), waits)
            })
        })
        .collect();
    let mut small_ids = Vec::new();
    for h in handles {
        let (id, waits) = h.join().unwrap();
        small_ids.push(id);
        latencies_s.extend(waits);
    }

    // The greedy flood must still be running — otherwise the small
    // tenants never actually competed with it.
    let st = greedy_bank.try_poll().unwrap();
    assert!(st.pending, "flood finished too early; fairness was not exercised");
    assert!(st.completed < st.total);

    latencies_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p90 = percentile(&latencies_s, 0.90);
    assert!(
        p90 < 0.5,
        "small-tenant p90 bank latency {p90:.3}s: starved behind the greedy flood"
    );

    // Per-tenant counters corroborate: every small tenant dispatched all
    // its circuits with a bounded max queue wait.
    let stats = manager.stats();
    for id in &small_ids {
        let t = &stats.per_tenant[id];
        assert_eq!(t.dispatched, 40, "tenant {id} dispatched {}", t.dispatched);
        assert_eq!(t.completed, 40);
        assert!(
            t.wait_max_s < 0.5,
            "tenant {id} max queue wait {:.3}s: starved",
            t.wait_max_s
        );
    }
    let g = &stats.per_tenant[&greedy.id()];
    assert_eq!(g.submitted, 10_000);
    assert!(g.dispatched > 0);

    // Drain the flood quickly and shut down.
    greedy_bank.cancel().unwrap();
    manager.shutdown();
}

/// With an idle pool, a submitted circuit is dispatched by the submit
/// event itself, never by the liveness timer. The eviction tick is
/// cranked to 5 s, so if any dispatch step still waited on it, not even
/// one of the 20 sequential banks could complete inside the 2 s budget
/// (tick-driven dispatch would need >= 100 s); event-driven dispatch
/// finishes in milliseconds.
#[test]
fn idle_pool_dispatch_does_not_wait_for_tick() {
    let manager = Manager::new(ManagerConfig {
        eviction_tick: Duration::from_secs(5),
        ..Default::default()
    });
    manager.register(WorkerProfile::new(5), Arc::new(InstantChannel));
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let session = manager.session();
    let pair = pairs_for(&cfg, 1);

    let start = Instant::now();
    for _ in 0..20 {
        let handle = session.submit(cfg, &pair).unwrap();
        let fids = handle.wait_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(fids.len(), 1);
    }
    let elapsed = start.elapsed();
    assert!(
        elapsed < Duration::from_secs(2),
        "20 idle-pool round trips took {elapsed:?}: dispatch is waiting on the timer"
    );
    assert_eq!(manager.stats().completed, 20);
    manager.shutdown();
}

/// Tenant weights bias the round-robin without starving anyone: with
/// equal backlogs and a weight-4 tenant, the heavy tenant finishes
/// first, but the light tenant still completes everything.
#[test]
fn tenant_weights_bias_service_order() {
    let manager = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
    manager.register(
        WorkerProfile::new(5),
        Arc::new(PacedChannel { delay: Duration::from_micros(500) }),
    );
    let cfg = QuClassiConfig::new(5, 1).unwrap();

    let heavy = manager.session();
    let light = manager.session();
    manager.set_tenant_weight(heavy.id(), 4);

    let heavy_bank = heavy.submit(cfg, &pairs_for(&cfg, 200)).unwrap();
    let light_bank = light.submit(cfg, &pairs_for(&cfg, 200)).unwrap();
    let heavy_fids = heavy_bank.wait().unwrap();
    let light_fids = light_bank.wait().unwrap();
    assert_eq!((heavy_fids.len(), light_fids.len()), (200, 200));

    let stats = manager.stats();
    let h = &stats.per_tenant[&heavy.id()];
    let l = &stats.per_tenant[&light.id()];
    assert_eq!((h.completed, l.completed), (200, 200));
    // The weight-4 tenant's circuits spent less time queued on average.
    let h_mean = h.wait_total_s / h.dispatched.max(1) as f64;
    let l_mean = l.wait_total_s / l.dispatched.max(1) as f64;
    assert!(
        h_mean <= l_mean * 1.5,
        "weighted tenant queued longer than the unweighted one: {h_mean:.4}s vs {l_mean:.4}s"
    );
    manager.shutdown();
}
