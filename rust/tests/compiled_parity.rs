//! Compiled-circuit pipeline parity (DESIGN.md §15).
//!
//! The plan cache and cache-blocked kernels are pure perf machinery:
//! they must not move a single observable bit relative to the paths
//! they replace. This suite pins that down from four angles:
//!
//! * the compiled+cached executor agrees with the seed
//!   `simulate_fidelity` gate-walk within 1e-6 (f32 rounding of the
//!   ~1e-15 f64 re-association drift) on every paper config;
//! * fidelities are **bitwise** invariant across executor thread
//!   counts, because `bind == bind_skeleton + rebind` is one code path;
//! * rebinding parameters into a cache-hit plan is bitwise identical to
//!   a cold compile+bind;
//! * a property test over random gate lists — CSWAPs acting as fusion
//!   barriers, chains that collapse into 3-qubit blocks — checks the
//!   compiled program against the serial gate walk at every
//!   `max_block` setting.

use std::sync::Arc;

use dqulearn::circuit::{builder, QuClassiConfig};
use dqulearn::model::exec::{CircuitExecutor, CircuitPair, ParallelQsimExecutor, QsimExecutor};
use dqulearn::qsim::gates::Gate;
use dqulearn::qsim::{CircuitTemplate, CompiledProgram, State};
use dqulearn::testlib;
use dqulearn::util::Rng;

fn random_pairs(cfg: &QuClassiConfig, n: usize, seed: u64) -> Vec<CircuitPair> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (
                (0..cfg.n_params()).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
                (0..cfg.n_features()).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
            )
        })
        .collect()
}

#[test]
fn compiled_executor_matches_seed_fidelity_on_all_paper_configs() {
    for cfg in QuClassiConfig::paper_configs() {
        let pairs = random_pairs(&cfg, 6, 0xC0FFEE ^ cfg.layers as u64);
        let fids = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
        for (i, (thetas, data)) in pairs.iter().enumerate() {
            let want = builder::simulate_fidelity(&cfg, thetas, data);
            assert!(
                (fids[i] - want).abs() < 1e-6,
                "q={} l={} pair {i}: compiled {} vs seed {}",
                cfg.qubits,
                cfg.layers,
                fids[i],
                want
            );
            // the one-shot helper rides the same global plan cache and
            // the same bind path, so it is bitwise identical
            assert_eq!(builder::simulate_fidelity_compiled(&cfg, thetas, data), fids[i]);
        }
    }
}

#[test]
fn fidelities_are_bitwise_invariant_across_thread_counts() {
    let cfg = QuClassiConfig::new(7, 3).unwrap();
    let pairs = random_pairs(&cfg, 17, 9);
    let serial = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
    for threads in [1usize, 2, 3, 8] {
        let parallel = ParallelQsimExecutor::new(threads).execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(serial, parallel, "threads={threads} diverged from serial");
    }
}

#[test]
fn cache_hit_rebinding_is_bitwise_identical_to_cold_compile() {
    let cfg = QuClassiConfig::new(7, 2).unwrap();
    let pairs = random_pairs(&cfg, 2, 31);
    let (thetas, data) = &pairs[0];
    let (alt_t, alt_d) = &pairs[1];

    // cold: fresh template -> fresh plan -> bind
    let cold = CompiledProgram::compile(builder::build_quclassi_template(&cfg))
        .bind(thetas, data)
        .fidelity();

    // cached: the process-wide cache must serve one shared plan...
    let first = builder::compile_quclassi(&cfg);
    let hit = builder::compile_quclassi(&cfg);
    assert!(Arc::ptr_eq(&first, &hit), "repeat config must hit the plan cache");

    // ...and rebinding into it — including after binding *other*
    // parameters — reproduces the cold result bit for bit.
    let mut bound = hit.bind_skeleton();
    hit.rebind(&mut bound, thetas, data);
    assert_eq!(bound.fidelity(), cold);
    hit.rebind(&mut bound, alt_t, alt_d);
    hit.rebind(&mut bound, thetas, data);
    assert_eq!(bound.fidelity(), cold, "stale state leaked through rebind");
}

/// A random gate drawn from the builder's vocabulary (plus CX), with
/// qubit operands chosen so multi-qubit gates get distinct qubits.
fn random_gate(rng: &mut Rng, nq: usize) -> Gate {
    let distinct = |rng: &mut Rng, a: usize| loop {
        let q = rng.index(nq);
        if q != a {
            break q;
        }
    };
    let q = rng.index(nq);
    let theta = rng.range_f64(-3.0, 3.0);
    match rng.index(8) {
        0 => Gate::H { q },
        1 => Gate::Ry { q, theta },
        2 => Gate::Rz { q, theta },
        3 => Gate::Ryy { q0: q, q1: distinct(rng, q), theta },
        4 => Gate::Rzz { q0: q, q1: distinct(rng, q), theta },
        5 => Gate::Cry { control: q, target: distinct(rng, q), theta },
        6 => Gate::Cx { control: q, target: distinct(rng, q) },
        _ => {
            let a = distinct(rng, q);
            let b = loop {
                let c = rng.index(nq);
                if c != q && c != a {
                    break c;
                }
            };
            Gate::Cswap { control: q, a, b }
        }
    }
}

/// Serial oracle vs the compiled program at every block width.
fn check_compiled_parity(nq: usize, gate_list: &[Gate]) -> Result<(), String> {
    let mut oracle = State::zero(nq);
    oracle.run(gate_list);
    for max_block in [1usize, 2, 3] {
        let program =
            CompiledProgram::compile_with(CircuitTemplate::from_gates(nq, gate_list), max_block);
        let mut st = State::zero(nq);
        program.bind(&[], &[]).apply(&mut st);
        for (i, (a, b)) in oracle.amps().iter().zip(st.amps().iter()).enumerate() {
            let err = ((a.re - b.re).powi(2) + (a.im - b.im).powi(2)).sqrt();
            if err > 1e-9 {
                return Err(format!(
                    "max_block={max_block} amp {i}: ({}, {}) vs ({}, {}), err {err:e}",
                    a.re, a.im, b.re, b.im
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn property_random_circuits_compile_to_the_same_state() {
    let gen = |rng: &mut Rng| {
        let nq = 3 + rng.index(3); // 3..=5 qubits
        let n_gates = 4 + rng.index(24);
        let gate_list: Vec<Gate> = (0..n_gates).map(|_| random_gate(rng, nq)).collect();
        (nq, gate_list)
    };
    testlib::forall(
        "compiled program == serial gate walk",
        0xD15C0,
        testlib::DEFAULT_CASES,
        gen,
        |(nq, gate_list)| check_compiled_parity(*nq, gate_list),
    );
}

#[test]
fn cswap_barriers_and_3q_blocks_directed_case() {
    // A chain that must collapse into an 8x8 block on each side of a
    // CSWAP, which no fused op may absorb or cross.
    let gate_list = vec![
        Gate::Ry { q: 0, theta: 0.4 },
        Gate::Ryy { q0: 0, q1: 1, theta: 0.7 },
        Gate::Rzz { q0: 1, q1: 2, theta: -0.9 },
        Gate::Cswap { control: 3, a: 0, b: 2 },
        Gate::Cry { control: 2, target: 1, theta: 1.3 },
        Gate::Ryy { q0: 0, q1: 1, theta: 0.2 },
        Gate::H { q: 3 },
    ];
    check_compiled_parity(4, &gate_list).unwrap();
    let program = CompiledProgram::compile(CircuitTemplate::from_gates(4, &gate_list));
    let stats = program.stats();
    assert!(stats.blocks3 >= 1, "expected an 8x8 block, got {stats:?}");
    assert!(stats.ops_out < gate_list.len(), "no fusion happened: {stats:?}");
}
