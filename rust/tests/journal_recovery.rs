//! Kill-and-replay crash-recovery suite for the durable bank journal
//! (DESIGN.md §16).
//!
//! The chaos harness simulates a manager crash without killing the
//! process: it freezes every worker channel (no execution — and no
//! marker logging — can happen after the freeze), snapshots the live
//! journal file mid-flight with `fs::copy` (so the copy may end in a
//! torn record, exactly like a real crash image), and recovers a second
//! manager from the copy. The audit then holds the journal's contract
//! across the "restart":
//!
//!  * no bank is lost — every unconsumed, uncancelled bank is resident
//!    after recovery, flagged `recovered`, and resolves to either its
//!    exact results or [`DqError::WorkerLost`];
//!  * no circuit executes twice — each circuit carries a unique marker
//!    (`data[0]`, echoed back as its fidelity) and the global execution
//!    log across both incarnations never sees a marker twice;
//!  * cancelled ids stay tombstoned, consumed banks stay gone.
//!
//! Directed tests pin the format itself: round-trip of every record
//! variant, checksum rejection, torn-tail truncation at *every* byte
//! offset, and recover-idempotency across three restarts.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use dqulearn::circuit::QuClassiConfig;
use dqulearn::coordinator::journal::{payload_digest, CircuitState, Record, SnapBank, Snapshot};
use dqulearn::coordinator::{
    Journal, JournalConfig, Manager, ManagerConfig, SessionOps, SyncPolicy, WorkerChannel,
    WorkerProfile,
};
use dqulearn::error::DqError;
use dqulearn::model::exec::CircuitPair;
use dqulearn::testlib::{forall, usize_in};
use dqulearn::util::Rng;

/// Fresh temp path namespaced by pid and test name (tests in one binary
/// run concurrently; names must not collide).
fn tpath(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("dq_jrec_{}_{name}.log", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Execution-audit channel: logs each circuit's marker (`data[0]`) and
/// echoes it back as the fidelity, so a bank's result vector identifies
/// exactly which executions produced it. Once `frozen` flips, every
/// execute fails *before* logging — the freeze models the instant of a
/// crash: work whose dispatch the journal copy never saw cannot have
/// logged a marker (the `Dispatched` record is appended before the
/// channel call, and the copy starts only after the freeze).
struct AuditChannel {
    frozen: Arc<AtomicBool>,
    log: Arc<Mutex<Vec<u32>>>,
}

impl WorkerChannel for AuditChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        if self.frozen.load(Ordering::SeqCst) {
            return Err(DqError::Io("worker frozen by crash harness".to_string()));
        }
        let mut log = self.log.lock().unwrap();
        let mut fids = Vec::with_capacity(pairs.len());
        for (_, data) in pairs {
            log.push(data[0] as u32);
            fids.push(data[0]);
        }
        Ok(fids)
    }
}

/// `n` circuit pairs whose markers continue from `*next_marker`.
fn marked_pairs(config: &QuClassiConfig, n: usize, next_marker: &mut u32) -> Vec<CircuitPair> {
    (0..n)
        .map(|_| {
            let marker = *next_marker;
            *next_marker += 1;
            let mut data = vec![0.25f32; config.n_features()];
            data[0] = marker as f32;
            (vec![0.1; config.n_params()], data)
        })
        .collect()
}

/// What the harness knows about one pre-crash bank.
struct BankExp {
    bank: u64,
    start: u32,
    size: usize,
    cancelled: bool,
    /// A pre-crash wait resolved (consumed) the bank — it must be gone
    /// after recovery.
    consumed: bool,
}

/// One randomized kill-and-replay case; every seed is a distinct crash
/// point (sync policy, compaction pressure, bank/cancel/consume
/// schedule, and freeze/copy timing all derive from it).
fn run_kill_and_replay(seed: u64) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let live = tpath("chaos_live");
    let copy = tpath("chaos_copy");
    let sync = [SyncPolicy::Never, SyncPolicy::Batch, SyncPolicy::Always][rng.index(3)];
    let mut jc = JournalConfig::new(&live).sync(sync);
    if rng.index(3) == 0 {
        // Tiny threshold + fast tick: compaction races the crash copy.
        jc = jc.compact_bytes(256 + rng.index(4096) as u64);
    }
    let manager = Manager::new(ManagerConfig {
        eviction_tick: Duration::from_millis(2),
        max_batch: 1 + rng.index(4),
        journal: Some(jc),
        ..Default::default()
    });
    let frozen = Arc::new(AtomicBool::new(false));
    let log: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(Vec::new()));
    for _ in 0..2 {
        manager.register(
            WorkerProfile::new(10).cru(rng.f64()),
            Arc::new(AuditChannel { frozen: frozen.clone(), log: log.clone() }),
        );
    }

    let client = manager.new_client();
    let config = QuClassiConfig::new(5, 1).unwrap();
    let mut next_marker: u32 = 0;
    let mut banks: Vec<BankExp> = Vec::new();
    for _ in 0..2 + rng.index(5) {
        match rng.index(4) {
            0 => std::thread::sleep(Duration::from_millis(rng.index(3) as u64)),
            1 => {
                if !banks.is_empty() {
                    let i = rng.index(banks.len());
                    if !banks[i].cancelled && !banks[i].consumed {
                        manager.cancel_bank(banks[i].bank);
                        banks[i].cancelled = true;
                    }
                }
            }
            2 => {
                // Consume a bank pre-crash (non-timeout outcomes remove
                // it from the store — and, durably, from the journal).
                if !banks.is_empty() {
                    let i = rng.index(banks.len());
                    let bank = banks[i].bank;
                    if !banks[i].consumed {
                        match manager.wait_bank_timeout(bank, Duration::from_millis(100)) {
                            Err(DqError::Timeout(_)) => {}
                            Ok(_) if banks[i].cancelled => {
                                return Err(format!(
                                    "bank {bank}: cancelled bank completed Ok pre-crash"
                                ));
                            }
                            _ => banks[i].consumed = true,
                        }
                    }
                }
            }
            _ => {}
        }
        let size = 1 + rng.index(8);
        let start = next_marker;
        let pairs = marked_pairs(&config, size, &mut next_marker);
        let bank = manager
            .submit_bank(client, config, &pairs)
            .map_err(|e| format!("submit failed: {e}"))?;
        banks.push(BankExp { bank, start, size, cancelled: false, consumed: false });
    }

    // Optional racer: a submit in flight while the crash lands. Its bank
    // has no deterministic pre-crash state, so only the loose outcome
    // set and the exactly-once marker audit apply to it.
    let racer = if rng.index(2) == 0 {
        let m = manager.clone();
        let start = next_marker;
        let pairs = marked_pairs(&config, 4, &mut next_marker);
        Some((start, std::thread::spawn(move || m.submit_bank(client, config, &pairs).ok())))
    } else {
        None
    };

    // Crash: freeze the workers, let the journal churn a little longer
    // (requeue/dispatch records keep landing), then snapshot the file.
    // Everything appended before the freeze is fully inside the copy;
    // the copy's tail may be torn — both exactly as in a real crash.
    if rng.index(2) == 0 {
        std::thread::sleep(Duration::from_millis(rng.index(3) as u64));
    }
    frozen.store(true, Ordering::SeqCst);
    std::thread::sleep(Duration::from_millis(rng.index(3) as u64));
    std::fs::copy(&live, &copy).map_err(|e| format!("crash copy: {e}"))?;
    manager.shutdown();
    let racer = match racer {
        Some((start, h)) => h.join().expect("racer thread").map(|bank| (start, bank)),
        None => None,
    };
    drop(manager);

    // Restart from the crash image. Workers are not durable: they
    // re-register (fresh, unfrozen) against the new incarnation.
    let (m2, report) = Manager::recover(ManagerConfig {
        journal: Some(JournalConfig::new(&copy).sync(sync)),
        ..Default::default()
    })
    .map_err(|e| format!("recover: {e}"))?;
    for _ in 0..2 {
        let unfrozen = Arc::new(AtomicBool::new(false));
        m2.register(
            WorkerProfile::new(10).cru(rng.f64()),
            Arc::new(AuditChannel { frozen: unfrozen, log: log.clone() }),
        );
    }

    let mut ok_ranges: Vec<(u32, u32)> = Vec::new();
    for b in &banks {
        if b.consumed {
            if m2.bank_status(b.bank).is_some() {
                return Err(format!("bank {}: consumed pre-crash but resident after", b.bank));
            }
            continue;
        }
        if b.cancelled {
            if !m2.bank_cancelled(b.bank) {
                return Err(format!("bank {}: cancel tombstone lost in recovery", b.bank));
            }
            match m2.wait_bank_timeout(b.bank, Duration::from_secs(10)) {
                Err(DqError::Cancelled(_)) => {}
                Ok(_) => return Err(format!("bank {}: cancelled bank resolved Ok", b.bank)),
                Err(e) => return Err(format!("bank {}: cancelled bank failed {e}", b.bank)),
            }
            continue;
        }
        // Live bank: must be resident, flagged recovered, right-sized.
        match m2.bank_status(b.bank) {
            Some(st) => {
                if !st.recovered {
                    return Err(format!("bank {}: restored without recovered flag", b.bank));
                }
                if st.total != b.size {
                    return Err(format!(
                        "bank {}: restored with {} circuits, submitted {}",
                        b.bank, st.total, b.size
                    ));
                }
            }
            None => return Err(format!("bank {}: lost across the crash", b.bank)),
        }
        match m2.wait_bank_timeout(b.bank, Duration::from_secs(10)) {
            Ok(fids) => {
                let end = b.start + b.size as u32;
                let want: Vec<f32> = (b.start..end).map(|m| m as f32).collect();
                if fids != want {
                    return Err(format!("bank {}: wrong fids {fids:?} != {want:?}", b.bank));
                }
                ok_ranges.push((b.start, b.start + b.size as u32));
            }
            Err(DqError::WorkerLost(_)) => {}
            Err(e) => return Err(format!("bank {}: unexpected post-recovery error {e}", b.bank)),
        }
    }
    // The racer's bank may have missed the crash image entirely (its
    // Submitted record raced the copy); any *consistent* fate is legal.
    if let Some((start, bank)) = racer {
        match m2.wait_bank_timeout(bank, Duration::from_secs(10)) {
            Ok(fids) => {
                let want: Vec<f32> = (start..start + 4).map(|m| m as f32).collect();
                if fids != want {
                    return Err(format!("racer bank {bank}: wrong fids {fids:?}"));
                }
                ok_ranges.push((start, start + 4));
            }
            Err(DqError::WorkerLost(_) | DqError::Protocol(_) | DqError::Cancelled(_)) => {}
            Err(e) => return Err(format!("racer bank {bank}: unexpected error {e}")),
        }
    }
    m2.shutdown();

    // Global exactly-once audit across both incarnations.
    let log = log.lock().unwrap();
    let mut counts: HashMap<u32, usize> = HashMap::new();
    for &marker in log.iter() {
        *counts.entry(marker).or_insert(0) += 1;
    }
    for (&marker, &count) in &counts {
        if count > 1 {
            return Err(format!("circuit {marker} executed {count} times across the crash"));
        }
    }
    for (lo, hi) in ok_ranges {
        for marker in lo..hi {
            if counts.get(&marker).copied().unwrap_or(0) != 1 {
                return Err(format!("circuit {marker} of an Ok bank never executed"));
            }
        }
    }
    drop(log);
    let report_sane = report.banks_restored >= report.banks_failed;
    if !report_sane {
        return Err(format!("inconsistent recovery report: {report:?}"));
    }
    let _ = std::fs::remove_file(&live);
    let _ = std::fs::remove_file(&copy);
    Ok(())
}

#[test]
fn kill_and_replay_random_crash_points() {
    // >= 100 randomized crash points (acceptance floor for this suite).
    forall(
        "kill-and-replay",
        0xC4A5,
        120,
        usize_in(0, u32::MAX as usize),
        |&seed| run_kill_and_replay(seed as u64),
    );
}

// ---------------------------------------------------------------------------
// journal format: round-trip, corruption, torn tails, idempotency
// ---------------------------------------------------------------------------

fn sample_pairs() -> Vec<CircuitPair> {
    vec![(vec![0.1, -0.2, 0.3], vec![1.0, 2.0]), (vec![], vec![0.5])]
}

#[test]
fn record_codec_round_trips_every_variant() {
    let pairs = sample_pairs();
    let mut records = vec![
        Record::Submitted {
            bank: 7,
            client: 3,
            qubits: 5,
            layers: 2,
            digest: payload_digest(&pairs),
            pairs: pairs.clone(),
        },
        Record::Dispatched { members: vec![(7, 0), (7, 1)] },
        Record::Completed { results: vec![(7, 0, 0.25), (7, 1, 1.0)] },
        Record::Requeued { members: vec![(7, 1)] },
        Record::Cancelled { bank: 9 },
        Record::Resolved { bank: 7 },
        Record::Snapshot(Snapshot {
            next_bank: 10,
            next_client: 4,
            cancelled: vec![2, 9],
            banks: vec![
                SnapBank {
                    bank: 7,
                    client: 3,
                    qubits: 5,
                    layers: 2,
                    recovered: true,
                    failed: Some(DqError::WorkerLost("crash".into())),
                    circuits: vec![
                        CircuitState::Done(0.75),
                        CircuitState::Pending((vec![0.1], vec![0.2])),
                        CircuitState::InFlight((vec![], vec![1.5])),
                        CircuitState::Gone,
                    ],
                },
                SnapBank {
                    bank: 8,
                    client: 1,
                    qubits: 7,
                    layers: 1,
                    recovered: false,
                    failed: None,
                    circuits: vec![],
                },
            ],
        }),
    ];
    // Failed must round-trip every error kind (the kind string is the
    // wire tag; an unknown kind degrades to Protocol by design).
    for err in [
        DqError::Unschedulable("u".into()),
        DqError::WorkerLost("w".into()),
        DqError::Timeout("t".into()),
        DqError::Cancelled("c".into()),
        DqError::Protocol("p".into()),
        DqError::Arity("a".into()),
        DqError::Io("i".into()),
    ] {
        records.push(Record::Failed { bank: 11, error: err });
    }
    for rec in records {
        let payload = rec.encode();
        let back = Record::decode(&payload).expect("decode");
        assert_eq!(back, rec);
    }
}

#[test]
fn decode_rejects_structural_corruption() {
    // empty payload
    assert!(Record::decode(&[]).is_err());
    // unknown tag
    assert!(Record::decode(&[42]).is_err());
    // trailing garbage after a valid record
    let mut payload = Record::Cancelled { bank: 1 }.encode();
    payload.push(0);
    assert!(Record::decode(&payload).is_err());
    // short payload (truncated mid-field)
    let full = Record::Resolved { bank: 1 }.encode();
    assert!(Record::decode(&full[..full.len() - 1]).is_err());
    // payload digest mismatch: CRC-clean bytes that lie about content
    let pairs = sample_pairs();
    let lying = Record::Submitted {
        bank: 1,
        client: 1,
        qubits: 5,
        layers: 1,
        digest: payload_digest(&pairs) ^ 1,
        pairs,
    };
    assert!(Record::decode(&lying.encode()).is_err());
}

/// Write a small journal, then recover from *every* byte-length prefix
/// of it: replay must keep exactly the fully-framed records, report the
/// rest as truncated, and leave the file appendable.
#[test]
fn torn_tails_truncate_at_every_chop_offset() {
    let src = tpath("chop_src");
    let cfg = JournalConfig::new(&src).sync(SyncPolicy::Never);
    let mut j = Journal::create(&cfg).unwrap();
    let pairs = vec![(vec![0.5f32], vec![1.5f32])];
    j.append(&Record::Submitted {
        bank: 1,
        client: 2,
        qubits: 5,
        layers: 1,
        digest: payload_digest(&pairs),
        pairs,
    })
    .unwrap();
    j.append(&Record::Dispatched { members: vec![(1, 0)] }).unwrap();
    j.append(&Record::Completed { results: vec![(1, 0, 0.75)] }).unwrap();
    j.append(&Record::Cancelled { bank: 2 }).unwrap();
    j.flush().unwrap();
    drop(j);
    let full = std::fs::read(&src).unwrap();
    // Frame boundaries from the length prefixes: [8, end1, end2, ...].
    let mut ends = vec![8usize];
    let mut at = 8usize;
    while at < full.len() {
        let len = u32::from_le_bytes(full[at..at + 4].try_into().unwrap()) as usize;
        at += 8 + len;
        ends.push(at);
    }
    assert_eq!(at, full.len(), "frame walk must cover the file");
    assert_eq!(ends.len(), 5, "magic + four frames");

    let cut_path = tpath("chop_cut");
    let cut_cfg = JournalConfig::new(&cut_path).sync(SyncPolicy::Never);
    for cut in 0..=full.len() {
        std::fs::write(&cut_path, &full[..cut]).unwrap();
        let (mut j, state) = Journal::recover(&cut_cfg).unwrap();
        let frames = ends.iter().filter(|&&e| e > 8 && e <= cut).count() as u64;
        assert_eq!(state.records, frames, "records at cut {cut}");
        // A sub-header prefix re-initializes; otherwise replay keeps the
        // longest fully-framed prefix and truncates the rest.
        let good = if cut < 8 {
            0
        } else {
            *ends.iter().filter(|&&e| e <= cut).max().unwrap()
        };
        assert_eq!(state.truncated_bytes, (cut - good) as u64, "truncated at cut {cut}");
        // The truncated journal must accept appends and replay them.
        j.append(&Record::Resolved { bank: 1 }).unwrap();
        j.flush().unwrap();
        drop(j);
        let (_j2, state2) = Journal::recover(&cut_cfg).unwrap();
        assert_eq!(state2.records, frames + 1, "re-recover at cut {cut}");
        assert_eq!(state2.truncated_bytes, 0, "re-recover clean at cut {cut}");
    }
    let _ = std::fs::remove_file(&src);
    let _ = std::fs::remove_file(&cut_path);
}

#[test]
fn checksum_failure_is_a_truncate_point() {
    let path = tpath("badcrc");
    let cfg = JournalConfig::new(&path).sync(SyncPolicy::Never);
    let mut j = Journal::create(&cfg).unwrap();
    j.append(&Record::Cancelled { bank: 10 }).unwrap();
    j.append(&Record::Cancelled { bank: 11 }).unwrap();
    j.append(&Record::Cancelled { bank: 12 }).unwrap();
    j.flush().unwrap();
    drop(j);
    let mut bytes = std::fs::read(&path).unwrap();
    let len0 = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let frame1 = 8 + 8 + len0;
    bytes[frame1 + 4] ^= 0xFF; // corrupt the second frame's stored CRC
    std::fs::write(&path, &bytes).unwrap();
    let (mut j, state) = Journal::recover(&cfg).unwrap();
    assert_eq!(state.records, 1, "replay stops at the bad checksum");
    assert_eq!(state.truncated_bytes, (bytes.len() - frame1) as u64);
    assert!(state.cancelled.contains(&10));
    assert!(!state.cancelled.contains(&11), "corrupt record must not replay");
    // the journal stays usable after truncation
    j.append(&Record::Cancelled { bank: 13 }).unwrap();
    j.flush().unwrap();
    drop(j);
    let (_j2, state2) = Journal::recover(&cfg).unwrap();
    assert_eq!(state2.records, 2);
    assert!(state2.cancelled.contains(&10) && state2.cancelled.contains(&13));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn triple_recover_is_idempotent() {
    let path = tpath("triple");
    let cfg = JournalConfig::new(&path).sync(SyncPolicy::Never);
    let mut j = Journal::create(&cfg).unwrap();
    let pairs = sample_pairs();
    j.append(&Record::Submitted {
        bank: 1,
        client: 1,
        qubits: 5,
        layers: 1,
        digest: payload_digest(&pairs),
        pairs,
    })
    .unwrap();
    j.append(&Record::Dispatched { members: vec![(1, 0)] }).unwrap();
    j.append(&Record::Cancelled { bank: 2 }).unwrap();
    j.append(&Record::Completed { results: vec![(1, 0, 0.5)] }).unwrap();
    j.flush().unwrap();
    drop(j);
    // a torn half-header at the tail, as a crash would leave it
    let mut bytes = std::fs::read(&path).unwrap();
    let clean_len = bytes.len();
    bytes.extend_from_slice(&[7, 0, 0, 0]);
    std::fs::write(&path, &bytes).unwrap();

    let (j1, s1) = Journal::recover(&cfg).unwrap();
    drop(j1);
    let (j2, s2) = Journal::recover(&cfg).unwrap();
    drop(j2);
    let (j3, s3) = Journal::recover(&cfg).unwrap();
    drop(j3);
    assert_eq!(s1.truncated_bytes, 4, "first recover chops the torn tail");
    assert_eq!(std::fs::metadata(&path).unwrap().len(), clean_len as u64);
    assert_eq!(s2.truncated_bytes, 0, "recovery appends nothing of its own");
    let mut s1_clean = s1.clone();
    s1_clean.truncated_bytes = 0;
    assert_eq!(s1_clean, s2, "recover is idempotent modulo the chopped tail");
    assert_eq!(s2, s3);
    assert_eq!(s2.records, 4);
    let circuits = &s2.banks[&1].circuits;
    assert_eq!(circuits.len(), 2);
    assert_eq!(circuits[0], CircuitState::Done(0.5));
    assert!(matches!(circuits[1], CircuitState::Pending(_)));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn recover_requires_a_journal_config() {
    let res = Manager::recover(ManagerConfig::default());
    assert!(matches!(res, Err(DqError::Protocol(_))));
}

// ---------------------------------------------------------------------------
// manager-level recovery semantics (the PR's satellite regressions)
// ---------------------------------------------------------------------------

/// Satellite: cancel tombstones survive journal compaction AND a
/// restart — a late `try_poll`/wait after recovery still observes
/// `Cancelled`, never "unknown bank". Also pins that a completed-but-
/// unconsumed bank survives a clean restart with its results intact.
#[test]
fn cancel_tombstone_survives_compaction_and_restart() {
    let path = tpath("tombstone");
    let jc = JournalConfig::new(&path);
    let m1 = Manager::new(ManagerConfig { journal: Some(jc.clone()), ..Default::default() });
    let frozen = Arc::new(AtomicBool::new(false));
    let log = Arc::new(Mutex::new(Vec::new()));
    m1.register(
        WorkerProfile::new(10),
        Arc::new(AuditChannel { frozen: frozen.clone(), log: log.clone() }),
    );
    let client = m1.new_client();
    let config = QuClassiConfig::new(5, 1).unwrap();
    let mut next = 0u32;
    let a = m1.submit_bank(client, config, &marked_pairs(&config, 2, &mut next)).unwrap();
    let b = m1.submit_bank(client, config, &marked_pairs(&config, 2, &mut next)).unwrap();
    m1.cancel_bank(b);
    // Let A complete fully without consuming it (status, not wait).
    let t0 = std::time::Instant::now();
    loop {
        let st = m1.bank_status(a).expect("bank A resident");
        if st.completed == st.total {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "bank A never completed");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(m1.compact_journal(), "compaction must succeed");
    m1.shutdown();
    drop(m1);

    let (m2, report) =
        Manager::recover(ManagerConfig { journal: Some(jc), ..Default::default() }).unwrap();
    assert!(report.cancelled_ids >= 1, "tombstone id must survive: {report:?}");
    assert!(m2.bank_cancelled(b), "cancel tombstone lost across compaction + restart");
    // the satellite's regression shape: a late poll via the session ops
    assert!(matches!(SessionOps::status(&m2, b), Err(DqError::Cancelled(_))));
    let late = m2.wait_bank_timeout(b, Duration::from_secs(1));
    assert!(matches!(late, Err(DqError::Cancelled(_))));
    // the completed-unconsumed bank kept its results for the late waiter
    let st = m2.bank_status(a).expect("completed bank must survive a clean restart");
    assert!(st.recovered, "restored bank must be flagged recovered");
    assert_eq!(m2.wait_bank_timeout(a, Duration::from_secs(1)).unwrap(), vec![0.0, 1.0]);
    m2.shutdown();
    let _ = std::fs::remove_file(&path);
}

/// Satellite: `Manager::shutdown` resolves every pending bank in the
/// journal (and fsyncs) before failing them in memory, so a clean
/// shutdown + recover re-admits nothing.
#[test]
fn clean_shutdown_resolves_pending_banks_so_recovery_readmits_nothing() {
    let path = tpath("clean_shutdown");
    let jc = JournalConfig::new(&path);
    let m1 = Manager::new(ManagerConfig { journal: Some(jc.clone()), ..Default::default() });
    let client = m1.new_client();
    let config = QuClassiConfig::new(5, 1).unwrap();
    let mut next = 0u32;
    // No workers registered: both banks sit pending, never dispatched.
    let a = m1.submit_bank(client, config, &marked_pairs(&config, 3, &mut next)).unwrap();
    let b = m1.submit_bank(client, config, &marked_pairs(&config, 2, &mut next)).unwrap();
    m1.shutdown();
    drop(m1);

    let (m2, report) =
        Manager::recover(ManagerConfig { journal: Some(jc), ..Default::default() }).unwrap();
    assert_eq!(report.banks_restored, 0, "clean shutdown left banks behind: {report:?}");
    assert_eq!(report.circuits_readmitted, 0);
    assert_eq!(m2.queue_len(), 0);
    assert!(m2.bank_status(a).is_none());
    assert!(m2.bank_status(b).is_none());
    m2.shutdown();
    let _ = std::fs::remove_file(&path);
}

/// A crash image taken before anything dispatched re-admits every
/// circuit; work resumes as soon as a worker re-registers, and the
/// restored bank is flagged `recovered` end to end.
#[test]
fn undispatched_banks_readmit_and_resume_after_recovery() {
    let live = tpath("resume_live");
    let copy = tpath("resume_copy");
    let m1 = Manager::new(ManagerConfig {
        journal: Some(JournalConfig::new(&live)),
        ..Default::default()
    });
    let client = m1.new_client();
    let config = QuClassiConfig::new(5, 1).unwrap();
    let mut next = 0u32;
    // No workers on m1: the bank cannot dispatch before the "crash".
    let bank = m1.submit_bank(client, config, &marked_pairs(&config, 3, &mut next)).unwrap();
    std::fs::copy(&live, &copy).unwrap();
    m1.shutdown();
    drop(m1);

    let (m2, report) = Manager::recover(ManagerConfig {
        journal: Some(JournalConfig::new(&copy)),
        ..Default::default()
    })
    .unwrap();
    assert_eq!(report.records, 1, "one Submitted record: {report:?}");
    assert_eq!(report.banks_restored, 1);
    assert_eq!(report.circuits_readmitted, 3);
    assert_eq!(report.banks_failed, 0);
    let st = m2.bank_status(bank).expect("bank resident after recovery");
    assert!(st.recovered && st.pending);
    assert_eq!((st.completed, st.total), (0, 3));
    // Workers re-register against the new incarnation; work resumes.
    let log = Arc::new(Mutex::new(Vec::new()));
    m2.register(
        WorkerProfile::new(10),
        Arc::new(AuditChannel { frozen: Arc::new(AtomicBool::new(false)), log: log.clone() }),
    );
    let fids = m2.wait_bank_timeout(bank, Duration::from_secs(10)).unwrap();
    assert_eq!(fids, vec![0.0, 1.0, 2.0]);
    assert_eq!(*log.lock().unwrap(), vec![0, 1, 2]);
    m2.shutdown();
    let _ = std::fs::remove_file(&live);
    let _ = std::fs::remove_file(&copy);
}
