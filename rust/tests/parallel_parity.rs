//! Parity and determinism guarantees for the parallel execution engine
//! (DESIGN.md §11).
//!
//! * Gate fusion must act identically to the serial gate walk on full
//!   statevectors, for every paper configuration, with and without the
//!   peephole transpiler in front.
//! * Multi-threaded shot execution must return the exact outcome
//!   sequence of the serial path for a fixed seed (thread-count
//!   invariance), and its measurement distribution must track the exact
//!   statevector probabilities.
//! * The parallel bank executor must be bitwise identical to the serial
//!   executor.
//! * Scheduler selection must be deterministic under ties
//!   (`select_worker` / `select_worker_relaxed`).

use dqulearn::circuit::{build_quclassi, builder, optimize, QuClassiConfig};
use dqulearn::coordinator::registry::Registry;
use dqulearn::coordinator::scheduler;
use dqulearn::model::exec::{CircuitExecutor, ParallelQsimExecutor, QsimExecutor};
use dqulearn::qsim::shots::{self, run_shots};
use dqulearn::qsim::{fusion, State};
use dqulearn::util::Rng;

fn random_angles(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.range_f64(-3.1, 3.1) as f32).collect()
}

#[test]
fn fused_statevectors_match_serial_on_all_paper_configs() {
    let mut rng = Rng::new(101);
    for cfg in QuClassiConfig::paper_configs() {
        for _ in 0..3 {
            let thetas = random_angles(&mut rng, cfg.n_params());
            let data = random_angles(&mut rng, cfg.n_features());
            let gates = build_quclassi(&cfg, &thetas, &data);

            let mut serial = State::zero(cfg.qubits);
            serial.run(&gates);

            let program = fusion::fuse(&gates);
            assert!(program.fused_away() > 0, "{cfg:?}: nothing fused");
            let mut fused = State::zero(cfg.qubits);
            program.apply(&mut fused);

            for (i, (a, b)) in serial.amps().iter().zip(fused.amps().iter()).enumerate() {
                assert!(
                    (a.re - b.re).abs() < 1e-9 && (a.im - b.im).abs() < 1e-9,
                    "{cfg:?} amp {i}: {a:?} vs {b:?}"
                );
            }
        }
    }
}

#[test]
fn fused_fidelity_matches_serial_fidelity() {
    let mut rng = Rng::new(103);
    for cfg in QuClassiConfig::paper_configs() {
        for _ in 0..5 {
            let thetas = random_angles(&mut rng, cfg.n_params());
            let data = random_angles(&mut rng, cfg.n_features());
            let serial = builder::simulate_fidelity(&cfg, &thetas, &data);
            let fused = builder::simulate_fidelity_fused(&cfg, &thetas, &data);
            assert!(
                (serial - fused).abs() < 1e-6,
                "{cfg:?}: serial {serial} vs fused {fused}"
            );
        }
    }
}

#[test]
fn fusion_composes_with_peephole_transpile() {
    // transpile (merge/cancel) then fuse: still equivalent to the raw walk.
    let mut rng = Rng::new(107);
    let cfg = QuClassiConfig::new(7, 3).unwrap();
    let thetas = random_angles(&mut rng, cfg.n_params());
    let data = random_angles(&mut rng, cfg.n_features());
    let gates = build_quclassi(&cfg, &thetas, &data);
    let optimized = optimize(&gates);
    let program = fusion::fuse(&optimized);

    let mut serial = State::zero(cfg.qubits);
    serial.run(&gates);
    let mut piped = State::zero(cfg.qubits);
    program.apply(&mut piped);
    for (a, b) in serial.amps().iter().zip(piped.amps().iter()) {
        assert!((a.re - b.re).abs() < 1e-9 && (a.im - b.im).abs() < 1e-9);
    }
}

#[test]
fn shot_pool_is_thread_count_invariant() {
    let cfg = QuClassiConfig::new(5, 2).unwrap();
    let mut rng = Rng::new(109);
    let thetas = random_angles(&mut rng, cfg.n_params());
    let data = random_angles(&mut rng, cfg.n_features());
    let gates = build_quclassi(&cfg, &thetas, &data);

    // Crosses several chunk boundaries with a ragged tail.
    let n_shots = 3 * shots::SHOT_CHUNK + 411;
    let serial = run_shots(cfg.qubits, &gates, n_shots, 1, 2024);
    assert_eq!(serial.len(), n_shots);
    for threads in [2usize, 4, 7] {
        let pooled = run_shots(cfg.qubits, &gates, n_shots, threads, 2024);
        assert_eq!(serial, pooled, "threads={threads} changed the outcome stream");
    }
}

#[test]
fn shot_distribution_tracks_exact_probabilities() {
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let mut rng = Rng::new(113);
    let thetas = random_angles(&mut rng, cfg.n_params());
    let data = random_angles(&mut rng, cfg.n_features());
    let gates = build_quclassi(&cfg, &thetas, &data);

    let mut st = State::zero(cfg.qubits);
    st.run(&gates);
    let exact_p0 = st.prob_zero(0);

    let n_shots = 200_000;
    let outcomes = run_shots(cfg.qubits, &gates, n_shots, 4, 31);
    let est_p0 = shots::prob_zero_estimate(&outcomes, cfg.qubits, 0);
    assert!(
        (est_p0 - exact_p0).abs() < 0.01,
        "ancilla P0: sampled {est_p0} vs exact {exact_p0}"
    );

    // The shot-sampled swap-test fidelity tracks the exact expectation.
    let exact_fid = 2.0 * exact_p0 - 1.0;
    let est_fid = 2.0 * est_p0 - 1.0;
    assert!((est_fid - exact_fid).abs() < 0.02);
}

#[test]
fn parallel_bank_executor_is_bitwise_identical() {
    let cfg = QuClassiConfig::new(7, 3).unwrap();
    let mut rng = Rng::new(127);
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..33)
        .map(|_| {
            (random_angles(&mut rng, cfg.n_params()), random_angles(&mut rng, cfg.n_features()))
        })
        .collect();
    let serial = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
    for threads in [2usize, 4, 8] {
        let pooled = ParallelQsimExecutor::new(threads).execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(serial, pooled, "threads={threads}");
    }
}

// ---------------------------------------------------------------------------
// scheduler determinism (Algorithm 2 tie-breaking)
// ---------------------------------------------------------------------------

#[test]
fn select_worker_tie_breaks_deterministically() {
    // Three identical workers: equal CRU, equal availability. The strict
    // and relaxed rules must both pick the lowest id, every time.
    let mut r = Registry::new(5.0);
    let ids: Vec<_> = (0..3).map(|_| r.register(10, 0.4, 0.0)).collect();
    for _ in 0..100 {
        assert_eq!(scheduler::select_worker(&r, 5), Some(ids[0]));
        assert_eq!(scheduler::select_worker_relaxed(&r, 5), Some(ids[0]));
        assert_eq!(scheduler::select(&r, 5), Some(ids[0]));
    }
}

#[test]
fn relaxed_tie_break_prefers_capacity_then_id() {
    // Equal CRU but different availability: more available qubits wins;
    // equal availability falls back to the lower id.
    let mut r = Registry::new(5.0);
    let small = r.register(10, 0.4, 0.0);
    let big = r.register(20, 0.4, 0.0);
    assert_eq!(scheduler::select_worker(&r, 5), Some(big));
    assert_eq!(scheduler::select_worker_relaxed(&r, 5), Some(big));
    // Occupy the big worker down to the same availability as the small
    // one: the tie then resolves to the lower id.
    r.reserve(big, 1, 10).unwrap();
    assert_eq!(r.get(big).unwrap().available(), r.get(small).unwrap().available());
    for _ in 0..50 {
        assert_eq!(scheduler::select_worker(&r, 5), Some(small));
        assert_eq!(scheduler::select_worker_relaxed(&r, 5), Some(small));
    }
}

#[test]
fn heap_scheduler_agrees_with_linear_scan() {
    // The Heap ablation must produce the same selection as the paper's
    // linear scan, including under exact ties (DESIGN.md §10).
    let mut rng = Rng::new(131);
    for _case in 0..50 {
        let mut r = Registry::new(5.0);
        let n = 1 + rng.index(6);
        for _ in 0..n {
            let mq = [5, 7, 10, 15, 20][rng.index(5)];
            // Quantized CRUs make exact ties common.
            let cru = (rng.index(4) as f64) * 0.25;
            r.register(mq, cru, 0.0);
        }
        for demand in [5usize, 7] {
            let linear = scheduler::select_with(scheduler::SchedulerKind::LinearScan, &r, demand);
            let heap = scheduler::select_with(scheduler::SchedulerKind::Heap, &r, demand);
            assert_eq!(linear, heap, "demand {demand} on {n} workers");
        }
    }
}
