//! Integration: full distributed training runs across deployment modes.

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::{serve_manager, InProcCluster, RemoteClient};
use dqulearn::coordinator::{Manager, ManagerConfig};
use dqulearn::data::Dataset;
use dqulearn::model::exec::QsimExecutor;
use dqulearn::model::optimizer::Optimizer;
use dqulearn::model::quclassi::LossKind;
use dqulearn::model::{QuClassiModel, TrainConfig, Trainer};
use dqulearn::util::Rng;
use dqulearn::worker::{WorkerHandle, WorkerOptions};

fn tc(epochs: usize, loss: LossKind) -> TrainConfig {
    TrainConfig {
        epochs,
        optimizer: Optimizer::adam(0.05),
        train_classical: true,
        classical_lr_scale: 0.1,
        seed: 7,
        early_stop_acc: None,
        loss,
    }
}

/// Paper §IV-B: distributed and non-distributed training agree. Ours are
/// bitwise-identical computations, so given the same seeds the accuracies
/// agree exactly (a delta of 0 < the paper's < 2%).
#[test]
fn accuracy_parity_across_all_pairs() {
    for (a, b) in [(3u8, 9u8), (3, 8), (3, 6), (1, 5)] {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let ds = Dataset::binary_pair(None, a, b, 14, 42);
        let mut m_base = QuClassiModel::new(cfg, &mut Rng::new(21));
        let base = Trainer::new(tc(6, LossKind::Discriminative))
            .train(&mut m_base, &ds, &QsimExecutor)
            .unwrap();

        let cluster = InProcCluster::builder().workers(&[5, 5]).build().unwrap();
        let mut m_dist = QuClassiModel::new(cfg, &mut Rng::new(21));
        let dist = Trainer::new(tc(6, LossKind::Discriminative))
            .train(&mut m_dist, &ds, &cluster)
            .unwrap();
        cluster.shutdown();

        let delta = (base.test_accuracy - dist.test_accuracy).abs();
        assert!(delta < 0.02, "pair {a}/{b}: accuracy delta {delta}");
        assert!(
            dist.final_train_accuracy() >= 0.75,
            "pair {a}/{b}: distributed training failed to learn ({})",
            dist.final_train_accuracy()
        );
    }
}

/// Generative (QuClassi-native) loss learns every pair robustly.
#[test]
fn generative_loss_learns_all_pairs() {
    for (a, b) in [(3u8, 9u8), (3, 8), (3, 6), (1, 5)] {
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let ds = Dataset::binary_pair(None, a, b, 16, 11);
        let mut model = QuClassiModel::new(cfg, &mut Rng::new(5));
        let report = Trainer::new(tc(16, LossKind::Generative))
            .train(&mut model, &ds, &QsimExecutor)
            .unwrap();
        assert!(
            report.final_train_accuracy() >= 0.8,
            "pair {a}/{b}: generative acc {}",
            report.final_train_accuracy()
        );
    }
}

/// The whole TCP stack (manager server + RPC workers + remote client)
/// trains a model end-to-end.
#[test]
fn tcp_distributed_training() {
    let manager = Manager::new(ManagerConfig { heartbeat_period: 0.5, ..Default::default() });
    let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let _w1 = WorkerHandle::start(
        &addr,
        WorkerOptions {
            max_qubits: 5,
            artifact_dir: "/nonexistent".into(),
            heartbeat_period: 0.2,
            listen: "127.0.0.1:0".into(),
            threads: 2,
        },
    )
    .unwrap();
    let _w2 = WorkerHandle::start(
        &addr,
        WorkerOptions {
            max_qubits: 5,
            artifact_dir: "/nonexistent".into(),
            heartbeat_period: 0.2,
            listen: "127.0.0.1:0".into(),
            threads: 1,
        },
    )
    .unwrap();

    let client = RemoteClient::connect(&addr).unwrap();
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let ds = Dataset::binary_pair(None, 1, 5, 10, 3);
    let mut model = QuClassiModel::new(cfg, &mut Rng::new(9));
    let report = Trainer::new(tc(4, LossKind::Generative))
        .train(&mut model, &ds, &client)
        .unwrap();
    assert!(report.final_train_accuracy() > 0.6);
    assert!(report.total_circuits > 0);
    manager.shutdown();
}

/// Paper workload mix: four concurrent tenants against a heterogeneous
/// pool; results must be exactly what local simulation produces.
#[test]
fn four_tenants_heterogeneous_pool() {
    use dqulearn::model::exec::CircuitExecutor;
    let cluster = InProcCluster::builder().workers(&[5, 10, 15, 20]).build().unwrap();
    let specs = [(5usize, 1usize), (5, 2), (7, 1), (7, 2)];
    let threads: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, &(q, l))| {
            let manager = cluster.manager.clone();
            std::thread::spawn(move || {
                let cfg = QuClassiConfig::new(q, l).unwrap();
                let client = manager.new_client();
                let mut rng = Rng::new(50 + i as u64);
                let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..40)
                    .map(|_| {
                        (
                            (0..cfg.n_params()).map(|_| rng.f32() * 3.0).collect(),
                            (0..cfg.n_features()).map(|_| rng.f32() * 3.0).collect(),
                        )
                    })
                    .collect();
                let got = manager.execute_bank(client, cfg, &pairs).unwrap();
                let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
                assert_eq!(got, want, "tenant {i} ({q}Q/{l}L) results corrupted");
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let stats = cluster.manager.stats();
    assert_eq!(stats.completed, 160);
    cluster.shutdown();
}
