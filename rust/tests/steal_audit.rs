//! Deterministic audit of work stealing between outboxes (DESIGN.md
//! §14): stealing is the kind of feature that is easy to make fast and
//! wrong, so each of the three concurrent structures a steal crosses —
//! outbox queues, registry reservations, in-flight accounting — gets a
//! test that pins its invariant:
//!
//! * a stalled worker's queued batches drain via siblings (liveness);
//! * every circuit executes exactly once under a steal racing the
//!   victim's eviction, looped >= 100 times (safety);
//! * a stolen batch's wait/dispatch counters land on the owning tenant
//!   (accounting);
//! * qubit reservations conserve — `occupied <= max_qubits` on every
//!   worker at every instant — across steals (capacity);
//! * `ManagerConfig::steal = false` really pins batches to their
//!   assigned worker (the policy-isolation knob).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use dqulearn::circuit::QuClassiConfig;
use dqulearn::coordinator::{Manager, ManagerConfig, WorkerChannel, WorkerProfile};
use dqulearn::error::DqError;
use dqulearn::model::exec::CircuitPair;
use dqulearn::util::VirtualClock;

/// A shared on/off latch channels park on.
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait_open(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Stalled-but-alive worker: every execute parks on the gate, then
/// completes normally. `entered` counts batches that reached the
/// channel, `executed` counts circuits that actually ran.
struct GateChannel {
    gate: Arc<Gate>,
    entered: Arc<AtomicUsize>,
    executed: Arc<AtomicUsize>,
}

impl WorkerChannel for GateChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        self.gate.wait_open();
        self.executed.fetch_add(pairs.len(), Ordering::SeqCst);
        Ok(vec![0.5; pairs.len()])
    }
}

/// Dead worker: parks on the gate, then *fails* — it never executes a
/// circuit, so anything routed to it must complete elsewhere (steal or
/// eviction re-queue) for its bank to resolve.
struct DeadChannel {
    gate: Arc<Gate>,
}

impl WorkerChannel for DeadChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        _pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        self.gate.wait_open();
        Err(DqError::WorkerLost("dead worker".to_string()))
    }
}

/// Instant worker that logs each circuit's marker (`data[0]`) and
/// counts batches — the execution audit trail.
struct RecordChannel {
    log: Arc<Mutex<Vec<u32>>>,
    batches: Arc<AtomicUsize>,
}

impl WorkerChannel for RecordChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        self.batches.fetch_add(1, Ordering::SeqCst);
        let mut log = self.log.lock().unwrap();
        for (_, data) in pairs {
            log.push(data[0] as u32);
        }
        Ok(vec![0.5; pairs.len()])
    }
}

/// Instant worker with a fixed per-batch service time (skew generator).
struct PacedChannel {
    delay: Duration,
}

impl WorkerChannel for PacedChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        std::thread::sleep(self.delay);
        Ok(vec![0.5; pairs.len()])
    }
}

fn cfg5() -> QuClassiConfig {
    QuClassiConfig::new(5, 1).unwrap()
}

fn plain_pairs(config: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
    (0..n)
        .map(|_| (vec![0.1; config.n_params()], vec![0.2; config.n_features()]))
        .collect()
}

/// Pairs whose `data[0]` carries a unique marker (`base + index`), so a
/// recording channel can prove exactly-once execution.
fn marked_pairs(config: &QuClassiConfig, n: usize, base: u32) -> Vec<CircuitPair> {
    (0..n)
        .map(|i| {
            let mut data = vec![0.2f32; config.n_features()];
            data[0] = (base + i as u32) as f32;
            (vec![0.1; config.n_params()], data)
        })
        .collect()
}

/// Poll `cond` until true or `timeout` elapses; returns the final state.
fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    loop {
        if cond() {
            return true;
        }
        if start.elapsed() >= timeout {
            return cond();
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// A 20-qubit stalled worker accumulates four 5-qubit batches: one
/// stuck in its channel (unstealable — results could arrive), three
/// queued in its outbox. A late-joining idle sibling must drain all
/// three queued batches via steals while the victim stays wedged.
#[test]
fn stalled_workers_queued_batches_drain_via_siblings() {
    let manager = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
    let gate = Gate::new();
    let entered = Arc::new(AtomicUsize::new(0));
    let executed = Arc::new(AtomicUsize::new(0));
    manager.register(
        WorkerProfile::new(20).cru(0.0),
        Arc::new(GateChannel {
            gate: gate.clone(),
            entered: entered.clone(),
            executed: executed.clone(),
        }),
    );
    let session = manager.session();
    let handle = session.submit(cfg5(), &plain_pairs(&cfg5(), 16)).unwrap();

    // All 16 circuits bind to the only worker: 4 batches x 5 qubits fill
    // its 20-qubit capacity; one batch reaches the (stalled) channel.
    assert!(
        wait_until(Duration::from_secs(5), || manager.queue_len() == 0
            && entered.load(Ordering::SeqCst) == 1),
        "work never bound to the stalled worker"
    );
    {
        let states = manager.worker_states();
        assert_eq!(states.len(), 1);
        assert_eq!(states[0].occupied, 20, "4 batches x 5 qubits reserved");
    }

    // An idle 5-qubit sibling joins and steals the three queued batches
    // (each fits exactly: relaxed AR >= demand, like the scheduler).
    let log = Arc::new(Mutex::new(Vec::new()));
    let thief_batches = Arc::new(AtomicUsize::new(0));
    manager.register(
        WorkerProfile::new(5).cru(0.9),
        Arc::new(RecordChannel { log: log.clone(), batches: thief_batches.clone() }),
    );
    assert!(
        wait_until(Duration::from_secs(5), || manager.stats().completed >= 12),
        "queued batches did not drain via the sibling: stats = {:?}",
        manager.stats()
    );
    let stats = manager.stats();
    assert_eq!(stats.steals, 3, "exactly the three queued batches are stealable");
    assert_eq!(thief_batches.load(Ordering::SeqCst), 3);
    assert_eq!(executed.load(Ordering::SeqCst), 0, "the stalled worker ran nothing");
    // Reservations moved with the batches: victim holds only its
    // in-channel batch, and nobody exceeds capacity.
    for w in manager.worker_states() {
        assert!(w.occupied <= w.max_qubits, "w{} overcommitted: {:?}", w.id, w);
    }
    let victim = &manager.worker_states()[0];
    assert_eq!(victim.occupied, 5, "only the in-channel batch remains on the victim");

    // Un-wedge the victim: its one in-channel batch completes and the
    // bank resolves with every fidelity present.
    gate.release();
    let fids = handle.wait_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(fids, vec![0.5; 16]);
    assert_eq!(manager.stats().completed, 16);
    manager.shutdown();
}

/// Race a thief's steals against the victim's eviction >= 100 times:
/// whichever path claims each batch, every circuit must execute exactly
/// once (the victim's channel is dead, so its circuits can only
/// complete via a steal or the evictor's re-queue — a double-claim
/// would show up as a duplicate marker, a lost batch as a hang).
#[test]
fn exactly_once_under_steal_vs_evict_race() {
    for iter in 0..100u32 {
        let clock = Arc::new(VirtualClock::new());
        let manager = Manager::with_clock(
            ManagerConfig {
                max_batch: 4,
                eviction_tick: Duration::from_millis(1),
                ..Default::default()
            },
            clock.clone(),
        );
        let gate = Gate::new();
        manager.register(
            WorkerProfile::new(20).cru(0.0),
            Arc::new(DeadChannel { gate: gate.clone() }),
        );
        let session = manager.session();
        let base = iter * 1000;
        let handle = session.submit(cfg5(), &marked_pairs(&cfg5(), 16, base)).unwrap();
        assert!(
            wait_until(Duration::from_secs(5), || manager.queue_len() == 0),
            "iter {iter}: batches never bound to the victim"
        );

        // Make the victim stale (3 x 5 s heartbeat deadline), then
        // register the thief. The 1 ms liveness tick and the thief's
        // steal loop now race for the victim's batches; the interleaving
        // varies run to run, and both paths must be exact-once.
        clock.advance(100.0);
        let log = Arc::new(Mutex::new(Vec::new()));
        let thief_batches = Arc::new(AtomicUsize::new(0));
        manager.register(
            WorkerProfile::new(20).cru(0.5),
            Arc::new(RecordChannel { log: log.clone(), batches: thief_batches.clone() }),
        );

        let fids = handle
            .wait_timeout(Duration::from_secs(10))
            .unwrap_or_else(|e| panic!("iter {iter}: bank failed: {e}"));
        assert_eq!(fids.len(), 16);

        // Exactly-once audit: 16 unique markers, each exactly once.
        {
            let log = log.lock().unwrap();
            let mut counts: HashMap<u32, usize> = HashMap::new();
            for &m in log.iter() {
                *counts.entry(m).or_insert(0) += 1;
            }
            for marker in base..base + 16 {
                assert_eq!(
                    counts.get(&marker).copied().unwrap_or(0),
                    1,
                    "iter {iter}: circuit {marker} execution count wrong (log len {})",
                    log.len()
                );
            }
            assert_eq!(log.len(), 16, "iter {iter}: stray executions");
        }
        for w in manager.worker_states() {
            assert!(w.occupied <= w.max_qubits, "iter {iter}: w{} overcommitted", w.id);
        }
        gate.release(); // un-park the dead channel so its thread exits
        manager.shutdown();
    }
}

/// A stolen batch's dispatch/wait/steal counters land on the tenant
/// that submitted it — never on the thief's other tenants — and the
/// manager-reported wait histogram counts every circuit.
#[test]
fn stolen_batch_counters_land_on_owning_tenant() {
    let manager = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
    let gate = Gate::new();
    let entered = Arc::new(AtomicUsize::new(0));
    let executed = Arc::new(AtomicUsize::new(0));
    let victim = manager.register(
        WorkerProfile::new(20).cru(0.0),
        Arc::new(GateChannel {
            gate: gate.clone(),
            entered: entered.clone(),
            executed: executed.clone(),
        }),
    );
    let owner = manager.session();
    let other = manager.session();
    let owner_bank = owner.submit(cfg5(), &plain_pairs(&cfg5(), 16)).unwrap();
    assert!(wait_until(Duration::from_secs(5), || manager.queue_len() == 0
        && entered.load(Ordering::SeqCst) == 1));

    // Thief joins; the three queued batches (12 circuits) move to it.
    let log = Arc::new(Mutex::new(Vec::new()));
    let thief_batches = Arc::new(AtomicUsize::new(0));
    manager.register(
        WorkerProfile::new(20).cru(0.9),
        Arc::new(RecordChannel { log, batches: thief_batches.clone() }),
    );
    assert!(wait_until(Duration::from_secs(5), || manager.stats().steals == 3));

    // A second tenant's bank lands directly on the idle thief — age the
    // stalled victim's CRU past the thief's first so Algorithm 2 stops
    // preferring it, keeping this bank steal-free.
    manager.heartbeat(victim, 0.99).unwrap();
    let other_fids = other.execute(cfg5(), &plain_pairs(&cfg5(), 4)).unwrap();
    assert_eq!(other_fids.len(), 4);

    gate.release();
    let owner_fids = owner_bank.wait_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(owner_fids.len(), 16);

    let stats = manager.stats();
    let t_owner = &stats.per_tenant[&owner.id()];
    let t_other = &stats.per_tenant[&other.id()];
    assert_eq!(t_owner.stolen, 12, "three stolen 4-circuit batches belong to the owner");
    assert_eq!(t_owner.submitted, 16);
    assert_eq!(t_owner.dispatched, 16, "every owner circuit reached a channel once");
    assert_eq!(t_owner.completed, 16);
    assert_eq!(
        t_owner.wait_hist.total(),
        16,
        "the wait histogram counts every dispatched circuit, stolen or not"
    );
    assert!(t_owner.wait_total_s >= 0.0 && t_owner.wait_max_s >= 0.0);
    assert_eq!((t_other.stolen, t_other.completed), (0, 4));
    assert_eq!(stats.steals, 3);
    manager.shutdown();
}

/// Capacity audit under a churny steal-heavy workload: a background
/// poller snapshots every worker's occupancy while three tenants hammer
/// a mixed pool with a slow (steal-victim) big worker — `occupied <=
/// max_qubits` must hold on every snapshot, and everything must drain
/// to zero at the end.
#[test]
fn reservations_conserve_across_steals() {
    let manager = Manager::new(ManagerConfig { max_batch: 2, ..Default::default() });
    manager.register(
        WorkerProfile::new(20).cru(0.0),
        Arc::new(PacedChannel { delay: Duration::from_millis(2) }),
    );
    manager.register(WorkerProfile::new(5).cru(0.1), Arc::new(PacedChannel {
        delay: Duration::from_micros(50),
    }));
    manager.register(WorkerProfile::new(10).cru(0.1), Arc::new(PacedChannel {
        delay: Duration::from_micros(50),
    }));

    let stop = Arc::new(AtomicBool::new(false));
    let violated = Arc::new(Mutex::new(None::<String>));
    let poller = {
        let manager = manager.clone();
        let stop = stop.clone();
        let violated = violated.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                for w in manager.worker_states() {
                    if w.occupied > w.max_qubits {
                        *violated.lock().unwrap() = Some(format!(
                            "w{} occupied {} > max {}",
                            w.id, w.occupied, w.max_qubits
                        ));
                    }
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        })
    };

    let tenants: Vec<_> = (0..3)
        .map(|_| {
            let m = manager.clone();
            std::thread::spawn(move || {
                let session = m.session();
                let pairs = plain_pairs(&cfg5(), 20);
                for _ in 0..10 {
                    let fids = session.execute(cfg5(), &pairs).unwrap();
                    assert_eq!(fids.len(), 20);
                }
            })
        })
        .collect();
    for t in tenants {
        t.join().unwrap();
    }

    // Quiesce: every reservation released once the workload drains.
    assert!(
        wait_until(Duration::from_secs(5), || {
            manager.worker_states().iter().map(|w| w.occupied).sum::<usize>() == 0
        }),
        "reservations leaked: {:?}",
        manager.worker_states()
    );
    stop.store(true, Ordering::SeqCst);
    poller.join().unwrap();
    assert!(violated.lock().unwrap().is_none(), "{:?}", violated.lock().unwrap());

    let stats = manager.stats();
    assert_eq!(stats.completed, 600);
    assert!(
        stats.steals > 0,
        "slow-big-worker skew should have produced at least one steal: {stats:?}"
    );
    manager.shutdown();
}

/// `ManagerConfig::steal = false` pins batches to their assigned
/// worker: a stalled worker's queued batches wait for *it*, even while
/// an idle sibling sits next to them — the knob that lets placement
/// policies (and tests) rule out load-balancing interference.
#[test]
fn steal_knob_disables_stealing() {
    let manager =
        Manager::new(ManagerConfig { max_batch: 4, steal: false, ..Default::default() });
    let gate = Gate::new();
    let entered = Arc::new(AtomicUsize::new(0));
    let executed = Arc::new(AtomicUsize::new(0));
    manager.register(
        WorkerProfile::new(20).cru(0.0),
        Arc::new(GateChannel {
            gate: gate.clone(),
            entered: entered.clone(),
            executed: executed.clone(),
        }),
    );
    let session = manager.session();
    let handle = session.submit(cfg5(), &plain_pairs(&cfg5(), 16)).unwrap();
    assert!(wait_until(Duration::from_secs(5), || manager.queue_len() == 0
        && entered.load(Ordering::SeqCst) == 1));

    let log = Arc::new(Mutex::new(Vec::new()));
    let thief_batches = Arc::new(AtomicUsize::new(0));
    manager.register(
        WorkerProfile::new(20).cru(0.9),
        Arc::new(RecordChannel { log, batches: thief_batches.clone() }),
    );
    // Give would-be thieves ample time (covers the 100 ms steal retry).
    std::thread::sleep(Duration::from_millis(300));
    let stats = manager.stats();
    assert_eq!(stats.steals, 0, "steal=false must never move a batch");
    assert_eq!(stats.completed, 0);
    assert_eq!(thief_batches.load(Ordering::SeqCst), 0);

    // The pinned batches still complete on their own worker.
    gate.release();
    let fids = handle.wait_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(fids, vec![0.5; 16]);
    assert_eq!(executed.load(Ordering::SeqCst), 16);
    manager.shutdown();
}
