//! Cross-backend parity: the AOT JAX/Pallas artifacts executed via PJRT
//! must agree with the from-scratch Rust statevector simulator on every
//! paper configuration — the strongest end-to-end correctness signal in
//! the repository (two independent implementations, one in Python/XLA,
//! one in Rust, agreeing to float32 precision).
//!
//! Skipped gracefully when `artifacts/` has not been built yet.

use dqulearn::circuit::QuClassiConfig;
use dqulearn::model::exec::{CircuitExecutor, QsimExecutor};
use dqulearn::runtime::PjrtEngine;
use dqulearn::util::Rng;

fn engine() -> Option<PjrtEngine> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtEngine::load(dir).expect("artifacts present but engine failed to load"))
}

fn random_pairs(cfg: &QuClassiConfig, n: usize, seed: u64) -> Vec<(Vec<f32>, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (
                (0..cfg.n_params()).map(|_| rng.range_f64(-6.3, 6.3) as f32).collect(),
                (0..cfg.n_features()).map(|_| rng.range_f64(-6.3, 6.3) as f32).collect(),
            )
        })
        .collect()
}

#[test]
fn all_configs_match_to_float_precision() {
    let Some(engine) = engine() else { return };
    for cfg in QuClassiConfig::paper_configs() {
        let pairs = random_pairs(&cfg, 64, cfg.qubits as u64 * 100 + cfg.layers as u64);
        let pjrt = engine.execute(&cfg, &pairs).unwrap();
        let qsim = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
        let mut max_err = 0.0f32;
        for (a, b) in pjrt.iter().zip(qsim.iter()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 5e-5, "{cfg:?}: max |Δfid| = {max_err}");
    }
    engine.shutdown();
}

#[test]
fn batching_is_transparent() {
    // Banks larger and smaller than the artifact batch (32) must give the
    // same answers as one-at-a-time execution (padding correctness).
    let Some(engine) = engine() else { return };
    let cfg = QuClassiConfig::new(5, 3).unwrap();
    let pairs = random_pairs(&cfg, 71, 9); // 71 = 2*32 + 7 exercises the padded tail
    let all = engine.execute(&cfg, &pairs).unwrap();
    for (i, p) in pairs.iter().enumerate().step_by(17) {
        let single = engine.execute(&cfg, std::slice::from_ref(p)).unwrap();
        assert!((single[0] - all[i]).abs() < 1e-6, "index {i}");
    }
    let stats = engine.stats();
    assert!(stats.executions >= 3);
    assert!(stats.padded_circuits > 0, "tail chunk must have been padded");
    engine.shutdown();
}

#[test]
fn grad_artifact_matches_bank_assembly() {
    // The fused on-device gradient (L2 perf path) must equal the
    // host-assembled parameter-shift gradients from individual circuits.
    let Some(engine) = engine() else { return };
    for cfg in [QuClassiConfig::new(5, 2).unwrap(), QuClassiConfig::new(7, 3).unwrap()] {
        let mut rng = Rng::new(31);
        let theta: Vec<f32> = (0..cfg.n_params()).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect();
        let data: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..cfg.n_features()).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect())
            .collect();
        let (fids, grads) = engine.execute_grad(&cfg, &theta, &data).unwrap();

        let bank = dqulearn::circuit::CircuitBank::new(cfg, &theta);
        for (i, d) in data.iter().enumerate() {
            let pairs: Vec<(Vec<f32>, Vec<f32>)> =
                bank.entries().iter().map(|e| (e.thetas.clone(), d.clone())).collect();
            let bank_fids = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
            let (fid0, g) = bank.assemble(&bank_fids);
            assert!((fids[i] - fid0).abs() < 5e-5, "{cfg:?} fid sample {i}");
            for p in 0..cfg.n_params() {
                assert!(
                    (grads[i][p] - g[p]).abs() < 5e-4,
                    "{cfg:?} grad sample {i} param {p}: {} vs {}",
                    grads[i][p],
                    g[p]
                );
            }
        }
    }
    engine.shutdown();
}

#[test]
fn engine_rejects_arity_mismatches() {
    let Some(engine) = engine() else { return };
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let bad = vec![(vec![0.0f32; 3], vec![0.0f32; 4])]; // theta too short
    assert!(engine.execute(&cfg, &bad).is_err());
    engine.shutdown();
}

#[test]
fn engine_is_shareable_across_threads() {
    let Some(engine) = engine() else { return };
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let e = engine.clone();
            std::thread::spawn(move || {
                let pairs = random_pairs(&cfg, 10, t);
                let got = e.execute(&cfg, &pairs).unwrap();
                let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
                for (a, b) in got.iter().zip(want.iter()) {
                    assert!((a - b).abs() < 5e-5);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    engine.shutdown();
}
