//! Property-based tests on the co-Manager's invariants (Algorithm 2),
//! driven by the in-house `testlib` generators.
//!
//! Invariants:
//!  * capacity: `OR <= MR` and `AR + OR == MR` at every step
//!  * selection: the chosen worker is always a least-CRU candidate
//!  * conservation: every submitted circuit completes exactly once, even
//!    under random worker joins/evictions (requeue path)
//!  * determinism: the DES produces identical results for a seed

use dqulearn::circuit::QuClassiConfig;
use dqulearn::coordinator::registry::Registry;
use dqulearn::coordinator::scheduler;
use dqulearn::env::{scenarios, sim, Calibration, ClientJob, EnvParams, SimConfig, SimWorkerSpec, Tenancy};
use dqulearn::testlib::{forall, usize_in, vec_of};
use dqulearn::util::Rng;

/// Random (max_qubits, cru, demand-sequence) fixture.
fn fixture(seed: u64) -> (Registry, Vec<u64>, Rng) {
    let mut rng = Rng::new(seed);
    let mut reg = Registry::new(5.0);
    let n_workers = 1 + rng.index(6);
    let ids = (0..n_workers)
        .map(|_| {
            let mq = [5, 7, 10, 15, 20][rng.index(5)];
            reg.register(mq, rng.f64(), 0.0)
        })
        .collect();
    (reg, ids, rng)
}

#[test]
fn capacity_invariants_under_random_ops() {
    forall(
        "capacity-invariants",
        0xC0FFEE,
        96,
        usize_in(0, u32::MAX as usize),
        |&seed| {
            let (mut reg, ids, mut rng) = fixture(seed as u64);
            let mut live: Vec<(u64, u64, usize)> = Vec::new(); // (worker, job, demand)
            let mut next_job = 0u64;
            for _step in 0..200 {
                match rng.index(3) {
                    0 => {
                        // try to place a circuit
                        let demand = [5usize, 7][rng.index(2)];
                        if let Some(w) = scheduler::select(&reg, demand) {
                            reg.reserve(w, next_job, demand)
                                .map_err(|e| format!("reserve after select failed: {e}"))?;
                            live.push((w, next_job, demand));
                            next_job += 1;
                        }
                    }
                    1 => {
                        // complete a random in-flight circuit
                        if !live.is_empty() {
                            let (w, job, _) = live.swap_remove(rng.index(live.len()));
                            reg.release(w, job);
                        }
                    }
                    _ => {
                        // heartbeat with fresh CRU
                        let id = ids[rng.index(ids.len())];
                        let _ = reg.heartbeat(id, rng.f64(), 0.0);
                    }
                }
                for w in reg.workers() {
                    if w.occupied > w.max_qubits {
                        return Err(format!("worker {} overcommitted: {} > {}", w.id, w.occupied, w.max_qubits));
                    }
                    if w.available() + w.occupied != w.max_qubits {
                        return Err("AR + OR != MR".to_string());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn selection_is_min_cru_candidate() {
    forall(
        "min-cru-selection",
        0xBEEF,
        96,
        usize_in(0, u32::MAX as usize),
        |&seed| {
            let (mut reg, _ids, mut rng) = fixture(seed as u64);
            // random occupancy
            let snapshot: Vec<u64> = reg.workers().map(|w| w.id).collect();
            for (i, id) in snapshot.iter().enumerate() {
                let mq = reg.get(*id).unwrap().max_qubits;
                let occ = rng.index(mq + 1);
                if occ > 0 {
                    let _ = reg.reserve(*id, 1000 + i as u64, occ);
                }
            }
            let demand = [5usize, 7][rng.index(2)];
            if let Some(chosen) = scheduler::select_worker(&reg, demand) {
                let chosen_cru = reg.get(chosen).unwrap().cru;
                for w in reg.workers() {
                    if w.available() > demand && w.cru < chosen_cru - 1e-12 {
                        return Err(format!(
                            "worker {} (cru {}) beat chosen {} (cru {})",
                            w.id, w.cru, chosen, chosen_cru
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn des_conserves_circuits_across_workloads() {
    // Random multi-client workloads: every circuit completes exactly once
    // (sim::simulate asserts conservation internally) and per-client
    // finish times are positive and ordered sanely.
    forall(
        "des-conservation",
        0xDE5,
        48,
        vec_of(usize_in(8, 120), 1, 4),
        |sizes| {
            let jobs: Vec<ClientJob> = sizes
                .iter()
                .enumerate()
                .map(|(i, &n)| {
                    let config = QuClassiConfig::new([5, 7][i % 2], 1 + i % 3).unwrap();
                    ClientJob {
                        client: i,
                        config,
                        n_circuits: n,
                        bank_size: scenarios::round_bank_size(&config),
                    }
                })
                .collect();
            let cfg = SimConfig {
                workers: vec![
                    SimWorkerSpec { max_qubits: 10, speed: 1.0 },
                    SimWorkerSpec { max_qubits: 20, speed: 1.0 },
                ],
                env: EnvParams::gcp_controlled(),
                calib: Calibration::qiskit_like(),
                heartbeat_period: 5.0,
                tenancy: Tenancy::MultiTenant,
                seed: sizes.iter().sum::<usize>() as u64,
            };
            let result = sim::simulate(&cfg, &jobs);
            if result.total_circuits != sizes.iter().sum::<usize>() {
                return Err("lost circuits".to_string());
            }
            for c in &result.per_client {
                if c.finish <= 0.0 || c.finish > result.makespan + 1e-9 {
                    return Err(format!("client {} finish {} out of range", c.client, c.finish));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn single_tenant_never_faster_overall() {
    // Exclusive occupancy can never beat work-conserving sharing on
    // total makespan (it is a restriction of the same schedule space).
    forall(
        "tenancy-dominance",
        0x7E4A,
        24,
        usize_in(1, 10_000),
        |&seed| {
            let jobs: Vec<ClientJob> = (0..3)
                .map(|i| {
                    let config = QuClassiConfig::new(5, 1 + i % 3).unwrap();
                    ClientJob {
                        client: i,
                        config,
                        n_circuits: 60,
                        bank_size: scenarios::round_bank_size(&config),
                    }
                })
                .collect();
            let mk = |tenancy: Tenancy| SimConfig {
                workers: vec![SimWorkerSpec { max_qubits: 10, speed: 1.0 }; 3],
                env: EnvParams::gcp_controlled(),
                calib: Calibration::qiskit_like(),
                heartbeat_period: 5.0,
                tenancy,
                seed: seed as u64,
            };
            let single = sim::simulate(&mk(Tenancy::SingleTenant), &jobs);
            let multi = sim::simulate(&mk(Tenancy::MultiTenant), &jobs);
            // allow small tolerance: jitter draws differ by schedule order
            if multi.makespan > single.makespan * 1.10 {
                return Err(format!(
                    "multi {} much slower than single {}",
                    multi.makespan, single.makespan
                ));
            }
            Ok(())
        },
    );
}
