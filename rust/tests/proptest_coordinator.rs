//! Property-based tests on the co-Manager's invariants (Algorithm 2),
//! driven by the in-house `testlib` generators.
//!
//! Invariants:
//!  * capacity: `OR <= MR` and `AR + OR == MR` at every step
//!  * selection: the chosen worker is always a least-CRU candidate
//!  * conservation: every submitted circuit completes exactly once, even
//!    under random worker joins/evictions (requeue path)
//!  * determinism: the DES produces identical results for a seed
//!  * exactly-once under chaos: arbitrary steal/evict/cancel
//!    interleavings on the *live* manager never execute a circuit twice
//!    and never lose one (completed + failed == submitted)
//!  * crash conservation: with the bank journal on, freezing the workers
//!    mid-flight and recovering a second incarnation from a copy of the
//!    journal still resolves every submitted circuit exactly once
//!    (completed + lost == submitted across both incarnations, no marker
//!    executes twice — DESIGN.md §16, `tests/journal_recovery.rs`)

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use dqulearn::circuit::QuClassiConfig;
use dqulearn::coordinator::registry::Registry;
use dqulearn::coordinator::scheduler;
use dqulearn::coordinator::{
    JournalConfig, Manager, ManagerConfig, ShardConfig, ShardManager, WorkerChannel, WorkerProfile,
};
use dqulearn::env::{scenarios, sim, Calibration, ClientJob, EnvParams, SimConfig, SimWorkerSpec, Tenancy};
use dqulearn::error::DqError;
use dqulearn::model::exec::CircuitPair;
use dqulearn::testlib::{forall, usize_in, vec_of};
use dqulearn::util::{Rng, VirtualClock};

/// Random (max_qubits, cru, demand-sequence) fixture.
fn fixture(seed: u64) -> (Registry, Vec<u64>, Rng) {
    let mut rng = Rng::new(seed);
    let mut reg = Registry::new(5.0);
    let n_workers = 1 + rng.index(6);
    let ids = (0..n_workers)
        .map(|_| {
            let mq = [5, 7, 10, 15, 20][rng.index(5)];
            reg.register(mq, rng.f64(), 0.0)
        })
        .collect();
    (reg, ids, rng)
}

#[test]
fn capacity_invariants_under_random_ops() {
    forall(
        "capacity-invariants",
        0xC0FFEE,
        96,
        usize_in(0, u32::MAX as usize),
        |&seed| {
            let (mut reg, ids, mut rng) = fixture(seed as u64);
            let mut live: Vec<(u64, u64, usize)> = Vec::new(); // (worker, job, demand)
            let mut next_job = 0u64;
            for _step in 0..200 {
                match rng.index(4) {
                    0 => {
                        // try to place a circuit
                        let demand = [5usize, 7][rng.index(2)];
                        if let Some(w) = scheduler::select(&reg, demand) {
                            reg.reserve(w, next_job, demand)
                                .map_err(|e| format!("reserve after select failed: {e}"))?;
                            live.push((w, next_job, demand));
                            next_job += 1;
                        }
                    }
                    1 => {
                        // complete a random in-flight circuit
                        if !live.is_empty() {
                            let (w, job, _) = live.swap_remove(rng.index(live.len()));
                            reg.release(w, job);
                        }
                    }
                    2 => {
                        // steal: transfer a random reservation to a random
                        // worker; success updates the books, failure must
                        // leave them untouched (checked below either way)
                        if !live.is_empty() {
                            let i = rng.index(live.len());
                            let (from, job, demand) = live[i];
                            let to = ids[rng.index(ids.len())];
                            if to != from && reg.transfer(from, to, job, demand).is_ok() {
                                live[i].0 = to;
                            }
                        }
                    }
                    _ => {
                        // heartbeat with fresh CRU
                        let id = ids[rng.index(ids.len())];
                        let _ = reg.heartbeat(id, rng.f64(), 0.0);
                    }
                }
                for w in reg.workers() {
                    if w.occupied > w.max_qubits {
                        return Err(format!("worker {} overcommitted: {} > {}", w.id, w.occupied, w.max_qubits));
                    }
                    if w.available() + w.occupied != w.max_qubits {
                        return Err("AR + OR != MR".to_string());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn selection_is_min_cru_candidate() {
    forall(
        "min-cru-selection",
        0xBEEF,
        96,
        usize_in(0, u32::MAX as usize),
        |&seed| {
            let (mut reg, _ids, mut rng) = fixture(seed as u64);
            // random occupancy
            let snapshot: Vec<u64> = reg.workers().map(|w| w.id).collect();
            for (i, id) in snapshot.iter().enumerate() {
                let mq = reg.get(*id).unwrap().max_qubits;
                let occ = rng.index(mq + 1);
                if occ > 0 {
                    let _ = reg.reserve(*id, 1000 + i as u64, occ);
                }
            }
            let demand = [5usize, 7][rng.index(2)];
            if let Some(chosen) = scheduler::select_worker(&reg, demand) {
                let chosen_cru = reg.get(chosen).unwrap().cru;
                for w in reg.workers() {
                    if w.available() > demand && w.cru < chosen_cru - 1e-12 {
                        return Err(format!(
                            "worker {} (cru {}) beat chosen {} (cru {})",
                            w.id, w.cru, chosen, chosen_cru
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn des_conserves_circuits_across_workloads() {
    // Random multi-client workloads: every circuit completes exactly once
    // (sim::simulate asserts conservation internally) and per-client
    // finish times are positive and ordered sanely.
    forall(
        "des-conservation",
        0xDE5,
        48,
        vec_of(usize_in(8, 120), 1, 4),
        |sizes| {
            let jobs: Vec<ClientJob> = sizes
                .iter()
                .enumerate()
                .map(|(i, &n)| {
                    let config = QuClassiConfig::new([5, 7][i % 2], 1 + i % 3).unwrap();
                    ClientJob {
                        client: i,
                        config,
                        n_circuits: n,
                        bank_size: scenarios::round_bank_size(&config),
                    }
                })
                .collect();
            let cfg = SimConfig {
                workers: vec![
                    SimWorkerSpec { max_qubits: 10, speed: 1.0, noise: 0.0 },
                    SimWorkerSpec { max_qubits: 20, speed: 1.0, noise: 0.0 },
                ],
                env: EnvParams::gcp_controlled(),
                calib: Calibration::qiskit_like(),
                heartbeat_period: 5.0,
                tenancy: Tenancy::MultiTenant,
                steal: true,
                // alternate sharded and unsharded pools: conservation
                // (asserted inside `simulate`) must hold across shard
                // routing and cross-shard steals too
                shards: 1 + sizes.len() % 2,
                noise_aware_alpha: None,
                seed: sizes.iter().sum::<usize>() as u64,
            };
            let result = sim::simulate(&cfg, &jobs);
            if result.total_circuits != sizes.iter().sum::<usize>() {
                return Err("lost circuits".to_string());
            }
            for c in &result.per_client {
                if c.finish <= 0.0 || c.finish > result.makespan + 1e-9 {
                    return Err(format!("client {} finish {} out of range", c.client, c.finish));
                }
            }
            Ok(())
        },
    );
}

/// Execution-audit channel for the chaos property. Reliable workers log
/// each circuit's marker (`data[0]`) and answer instantly; doomed
/// workers park every execute on a shared gate until the test releases
/// it, then fail — so a doomed worker *never* executes anything, and the
/// only way its circuits complete is a steal or an eviction re-queue.
struct AuditChannel {
    doomed: bool,
    gate: Arc<(Mutex<bool>, Condvar)>,
    log: Arc<Mutex<Vec<u32>>>,
}

impl WorkerChannel for AuditChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        if self.doomed {
            let (lock, cv) = &*self.gate;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            return Err(DqError::WorkerLost("doomed worker".to_string()));
        }
        let mut log = self.log.lock().unwrap();
        for (_, data) in pairs {
            log.push(data[0] as u32);
        }
        Ok(vec![0.5; pairs.len()])
    }
}

/// One chaos run: random worker profiles (some doomed to stall and be
/// evicted), random bank sizes across random tenants, random cancels,
/// and virtual-time eviction passes racing the steal path. Returns an
/// error string describing the first violated invariant.
fn run_steal_evict_cancel(seed: u64) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let clock = Arc::new(VirtualClock::new());
    let manager = Manager::with_clock(
        ManagerConfig {
            eviction_tick: Duration::from_millis(1),
            max_batch: 4,
            steal: rng.index(2) == 0,
            ..Default::default()
        },
        clock.clone(),
    );
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let log: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(Vec::new()));

    // One always-live 20-qubit rescue worker (every demand fits), plus a
    // random mix of extra reliable and doomed workers.
    let mut reliable = vec![manager.register(
        WorkerProfile::new(20).cru(rng.f64()),
        Arc::new(AuditChannel { doomed: false, gate: gate.clone(), log: log.clone() }),
    )];
    for _ in 0..rng.index(3) {
        reliable.push(manager.register(
            WorkerProfile::new([5, 7, 10, 20][rng.index(4)])
                .cru(rng.f64())
                .threads(1 + rng.index(2)),
            Arc::new(AuditChannel { doomed: false, gate: gate.clone(), log: log.clone() }),
        ));
    }
    for _ in 0..1 + rng.index(3) {
        manager.register(
            WorkerProfile::new([5, 10, 20][rng.index(3)]).cru(rng.f64()),
            Arc::new(AuditChannel { doomed: true, gate: gate.clone(), log: log.clone() }),
        );
    }

    // Advance virtual time in sub-deadline steps (heartbeat deadline is
    // 3 x 5 s): reliables are re-heartbeated inside every step, so only
    // the doomed workers ever cross the eviction line — even if the
    // 1 ms liveness tick fires mid-step.
    let step = |manager: &Manager| {
        clock.advance(10.0);
        for &w in &reliable {
            let _ = manager.heartbeat(w, 0.1);
        }
    };

    let sessions: Vec<_> = (0..1 + rng.index(3)).map(|_| manager.session()).collect();
    let mut next_marker: u32 = 0;
    // (handle, size, first marker, cancelled)
    let mut banks = Vec::new();
    for _ in 0..2 + rng.index(4) {
        match rng.index(4) {
            0 => step(&manager),
            1 => std::thread::sleep(Duration::from_millis(1)),
            2 => {
                if !banks.is_empty() {
                    let i = rng.index(banks.len());
                    if !banks[i].3 {
                        banks[i].0.cancel().map_err(|e| format!("cancel: {e}"))?;
                        banks[i].3 = true;
                    }
                }
            }
            _ => {}
        }
        let session = &sessions[rng.index(sessions.len())];
        let config = QuClassiConfig::new([5, 7][rng.index(2)], 1).unwrap();
        let size = 1 + rng.index(40);
        let start = next_marker;
        let pairs: Vec<CircuitPair> = (0..size)
            .map(|_| {
                let marker = next_marker;
                next_marker += 1;
                let mut data = vec![0.25f32; config.n_features()];
                data[0] = marker as f32;
                (vec![0.1; config.n_params()], data)
            })
            .collect();
        let handle = session.submit(config, &pairs).map_err(|e| format!("submit: {e}"))?;
        banks.push((handle, size, start, false));
    }

    // Evict every doomed worker (three more sub-deadline steps push
    // anything not heartbeating past the line), then open the gate so
    // parked doomed executions fail out and release their reservations.
    for _ in 0..3 {
        step(&manager);
    }
    {
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    let mut ok_ranges: Vec<(u32, u32)> = Vec::new();
    let (mut completed, mut failed, mut submitted) = (0usize, 0usize, 0usize);
    for (handle, size, start, cancelled) in banks {
        submitted += size;
        match handle.wait_timeout(Duration::from_secs(10)) {
            Ok(fids) => {
                if fids.len() != size {
                    return Err(format!("bank returned {} fids for {size} circuits", fids.len()));
                }
                if cancelled {
                    return Err("cancelled bank completed as Ok".to_string());
                }
                completed += size;
                ok_ranges.push((start, start + size as u32));
            }
            Err(DqError::Cancelled(_)) if cancelled => failed += size,
            Err(e) => return Err(format!("bank failed unexpectedly: {e} (cancelled={cancelled})")),
        }
    }
    if completed + failed != submitted {
        return Err(format!("conservation: {completed} + {failed} != {submitted}"));
    }

    // Quiesce: every reservation must drain (a leak here means a steal
    // or eviction lost track of a batch), then audit the execution log.
    let t0 = std::time::Instant::now();
    while manager.worker_states().iter().map(|w| w.occupied).sum::<usize>() > 0 {
        if t0.elapsed() > Duration::from_secs(5) {
            return Err("qubit reservations leaked after all banks resolved".to_string());
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let log = log.lock().unwrap();
    let mut counts: HashMap<u32, usize> = HashMap::new();
    for &marker in log.iter() {
        *counts.entry(marker).or_insert(0) += 1;
    }
    for (&marker, &count) in &counts {
        if count > 1 {
            return Err(format!("circuit {marker} executed {count} times"));
        }
    }
    for (lo, hi) in ok_ranges {
        for marker in lo..hi {
            if counts.get(&marker).copied().unwrap_or(0) != 1 {
                return Err(format!("circuit {marker} of a completed bank never executed"));
            }
        }
    }
    drop(log);
    manager.shutdown();
    Ok(())
}

#[test]
fn steal_evict_cancel_interleavings_conserve_circuits() {
    forall(
        "steal-evict-cancel",
        0x57EA1,
        16,
        usize_in(0, u32::MAX as usize),
        |&seed| run_steal_evict_cancel(seed as u64),
    );
}

/// Sharded-pool chaos arm (DESIGN.md §18): random shard counts, one-shot
/// tenant churn (a fresh session per bank walks the round-robin shard
/// router), random cancels, and both steal planes live — intra-shard
/// backlog stealing plus the cross-shard broker. Conservation must hold
/// pool-wide: completed + cancelled == submitted, no marker executes
/// twice, and no qubit reservation leaks, regardless of which shard
/// bound, stole, or imported a batch.
fn run_sharded_churn_steal_cancel(seed: u64) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let shards = 2 + rng.index(3);
    let sm = ShardManager::new(ShardConfig {
        shards,
        manager: ManagerConfig {
            max_batch: 1 + rng.index(4),
            steal: rng.index(2) == 0,
            ..Default::default()
        },
        ..Default::default()
    });
    let gate = Arc::new((Mutex::new(true), Condvar::new()));
    let log: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(Vec::new()));
    // At least one worker per shard (registration is least-populated, so
    // the first `shards` registrations land one per shard); every demand
    // fits on every worker, so home-shard binding always succeeds.
    for _ in 0..shards + rng.index(3) {
        sm.register(
            WorkerProfile::new([10, 20][rng.index(2)]).cru(rng.f64()),
            Arc::new(AuditChannel { doomed: false, gate: gate.clone(), log: log.clone() }),
        );
    }

    let mut next_marker: u32 = 0;
    // (handle, size, first marker, cancelled)
    let mut banks = Vec::new();
    for _ in 0..4 + rng.index(6) {
        let session = sm.session();
        let config = QuClassiConfig::new([5, 7][rng.index(2)], 1).unwrap();
        let size = 1 + rng.index(24);
        let start = next_marker;
        let pairs: Vec<CircuitPair> = (0..size)
            .map(|_| {
                let marker = next_marker;
                next_marker += 1;
                let mut data = vec![0.25f32; config.n_features()];
                data[0] = marker as f32;
                (vec![0.1; config.n_params()], data)
            })
            .collect();
        let handle = session.submit(config, &pairs).map_err(|e| format!("submit: {e}"))?;
        let mut cancelled = false;
        if rng.index(4) == 0 {
            handle.cancel().map_err(|e| format!("cancel: {e}"))?;
            cancelled = true;
        }
        banks.push((handle, size, start, cancelled));
    }

    let mut ok_ranges: Vec<(u32, u32)> = Vec::new();
    let (mut completed, mut failed, mut submitted) = (0usize, 0usize, 0usize);
    for (handle, size, start, cancelled) in banks {
        submitted += size;
        match handle.wait_timeout(Duration::from_secs(10)) {
            Ok(fids) => {
                if fids.len() != size {
                    return Err(format!("bank returned {} fids for {size} circuits", fids.len()));
                }
                if cancelled {
                    return Err("cancelled bank completed as Ok".to_string());
                }
                completed += size;
                ok_ranges.push((start, start + size as u32));
            }
            Err(DqError::Cancelled(_)) if cancelled => failed += size,
            Err(e) => return Err(format!("bank failed unexpectedly: {e} (cancelled={cancelled})")),
        }
    }
    if completed + failed != submitted {
        return Err(format!("conservation: {completed} + {failed} != {submitted}"));
    }

    // Quiesce: every reservation on every shard must drain (a leak here
    // means an intra- or cross-shard steal lost track of a batch).
    let t0 = std::time::Instant::now();
    while sm.worker_states().iter().map(|w| w.occupied).sum::<usize>() > 0 {
        if t0.elapsed() > Duration::from_secs(5) {
            return Err("qubit reservations leaked across shards".to_string());
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let log = log.lock().unwrap();
    let mut counts: HashMap<u32, usize> = HashMap::new();
    for &marker in log.iter() {
        *counts.entry(marker).or_insert(0) += 1;
    }
    for (&marker, &count) in &counts {
        if count > 1 {
            return Err(format!("circuit {marker} executed {count} times"));
        }
    }
    for (lo, hi) in ok_ranges {
        for marker in lo..hi {
            if counts.get(&marker).copied().unwrap_or(0) != 1 {
                return Err(format!("circuit {marker} of a completed bank never executed"));
            }
        }
    }
    drop(log);
    sm.shutdown();
    Ok(())
}

#[test]
fn sharded_churn_steal_cancel_conserves_circuits() {
    forall(
        "sharded-churn-steal-cancel",
        0x5AA4D,
        16,
        usize_in(0, u32::MAX as usize),
        |&seed| run_sharded_churn_steal_cancel(seed as u64),
    );
}

/// Journal-backed variant of [`AuditChannel`]: logs markers until the
/// crash harness freezes it; a frozen execute fails *before* logging, so
/// anything in the log provably dispatched (and journaled) pre-freeze.
struct FreezeChannel {
    frozen: Arc<AtomicBool>,
    log: Arc<Mutex<Vec<u32>>>,
}

impl WorkerChannel for FreezeChannel {
    fn execute(
        &self,
        _config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        if self.frozen.load(Ordering::SeqCst) {
            return Err(DqError::Io("frozen".to_string()));
        }
        let mut log = self.log.lock().unwrap();
        for (_, data) in pairs {
            log.push(data[0] as u32);
        }
        Ok(vec![0.5; pairs.len()])
    }
}

/// Crash/recover chaos arm (the durable-journal counterpart of the
/// steal/evict/cancel property): random submits, cancels, and consuming
/// waits race a simulated crash — workers freeze, the journal file is
/// snapshotted mid-flight, and a second incarnation recovers from the
/// copy. Quiescence must hold across both incarnations: every submitted
/// circuit either completes (exactly once) or is lost to a cancel/crash
/// failure, and no execution marker repeats.
fn run_crash_recover_conservation(seed: u64) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let dir = std::env::temp_dir();
    let live = dir.join(format!("dq_prop_crash_{}_{seed}.log", std::process::id()));
    let copy = dir.join(format!("dq_prop_crash_{}_{seed}.copy", std::process::id()));
    let manager = Manager::new(ManagerConfig {
        max_batch: 1 + rng.index(4),
        journal: Some(JournalConfig::new(&live)),
        ..Default::default()
    });
    let frozen = Arc::new(AtomicBool::new(false));
    let log: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(Vec::new()));
    for _ in 0..1 + rng.index(2) {
        manager.register(
            WorkerProfile::new(10).cru(rng.f64()),
            Arc::new(FreezeChannel { frozen: frozen.clone(), log: log.clone() }),
        );
    }
    let client = manager.new_client();
    let config = QuClassiConfig::new(5, 1).unwrap();
    let mut marker: u32 = 0;
    // (bank, size, pre-crash resolution: Some(completed?), cancelled)
    let mut banks: Vec<(u64, usize, Option<bool>, bool)> = Vec::new();
    for _ in 0..2 + rng.index(4) {
        let size = 1 + rng.index(6);
        let pairs: Vec<CircuitPair> = (0..size)
            .map(|_| {
                let m = marker;
                marker += 1;
                let mut data = vec![0.25f32; config.n_features()];
                data[0] = m as f32;
                (vec![0.1; config.n_params()], data)
            })
            .collect();
        let bank = manager
            .submit_bank(client, config, &pairs)
            .map_err(|e| format!("submit: {e}"))?;
        banks.push((bank, size, None, false));
        match rng.index(3) {
            0 => {
                let i = rng.index(banks.len());
                if banks[i].2.is_none() && !banks[i].3 {
                    manager.cancel_bank(banks[i].0);
                    banks[i].3 = true;
                }
            }
            1 => {
                let i = rng.index(banks.len());
                if banks[i].2.is_none() {
                    match manager.wait_bank_timeout(banks[i].0, Duration::from_millis(50)) {
                        Err(DqError::Timeout(_)) => {}
                        Ok(_) => banks[i].2 = Some(true),
                        Err(_) => banks[i].2 = Some(false),
                    }
                }
            }
            _ => std::thread::sleep(Duration::from_millis(rng.index(2) as u64)),
        }
    }
    // Crash: freeze executions, snapshot the journal mid-flight (the
    // copy's tail may be torn), drop the first incarnation.
    frozen.store(true, Ordering::SeqCst);
    std::thread::sleep(Duration::from_millis(rng.index(2) as u64));
    std::fs::copy(&live, &copy).map_err(|e| format!("crash copy: {e}"))?;
    manager.shutdown();
    drop(manager);

    let (m2, _report) = Manager::recover(ManagerConfig {
        journal: Some(JournalConfig::new(&copy)),
        ..Default::default()
    })
    .map_err(|e| format!("recover: {e}"))?;
    m2.register(
        WorkerProfile::new(10).cru(rng.f64()),
        Arc::new(FreezeChannel { frozen: Arc::new(AtomicBool::new(false)), log: log.clone() }),
    );
    let (mut submitted, mut completed, mut lost) = (0usize, 0usize, 0usize);
    for (bank, size, pre, _) in &banks {
        submitted += *size;
        match pre {
            Some(true) => completed += *size,
            Some(false) => lost += *size,
            None => match m2.wait_bank_timeout(*bank, Duration::from_secs(10)) {
                Ok(fids) => {
                    if fids.len() != *size {
                        return Err(format!("bank {bank}: {} fids for {size}", fids.len()));
                    }
                    completed += *size;
                }
                Err(DqError::Cancelled(_) | DqError::WorkerLost(_)) => lost += *size,
                Err(e) => return Err(format!("bank {bank}: unexpected outcome {e}")),
            },
        }
    }
    if completed + lost != submitted {
        return Err(format!("conservation: {completed} + {lost} != {submitted}"));
    }
    m2.shutdown();
    let log = log.lock().unwrap();
    let mut counts: HashMap<u32, usize> = HashMap::new();
    for &m in log.iter() {
        *counts.entry(m).or_insert(0) += 1;
    }
    for (&m, &c) in &counts {
        if c > 1 {
            return Err(format!("circuit {m} executed {c} times across the crash"));
        }
    }
    drop(log);
    let _ = std::fs::remove_file(&live);
    let _ = std::fs::remove_file(&copy);
    Ok(())
}

#[test]
fn crash_recover_interleavings_conserve_circuits() {
    forall(
        "crash-recover",
        0xC4A54,
        12,
        usize_in(0, u32::MAX as usize),
        |&seed| run_crash_recover_conservation(seed as u64),
    );
}

#[test]
fn single_tenant_never_faster_overall() {
    // Exclusive occupancy can never beat work-conserving sharing on
    // total makespan (it is a restriction of the same schedule space).
    forall(
        "tenancy-dominance",
        0x7E4A,
        24,
        usize_in(1, 10_000),
        |&seed| {
            let jobs: Vec<ClientJob> = (0..3)
                .map(|i| {
                    let config = QuClassiConfig::new(5, 1 + i % 3).unwrap();
                    ClientJob {
                        client: i,
                        config,
                        n_circuits: 60,
                        bank_size: scenarios::round_bank_size(&config),
                    }
                })
                .collect();
            let mk = |tenancy: Tenancy| SimConfig {
                workers: vec![SimWorkerSpec { max_qubits: 10, speed: 1.0, noise: 0.0 }; 3],
                env: EnvParams::gcp_controlled(),
                calib: Calibration::qiskit_like(),
                heartbeat_period: 5.0,
                tenancy,
                steal: true,
                shards: 1,
                noise_aware_alpha: None,
                seed: seed as u64,
            };
            let single = sim::simulate(&mk(Tenancy::SingleTenant), &jobs);
            let multi = sim::simulate(&mk(Tenancy::MultiTenant), &jobs);
            // allow small tolerance: jitter draws differ by schedule order
            if multi.makespan > single.makespan * 1.10 {
                return Err(format!(
                    "multi {} much slower than single {}",
                    multi.makespan, single.makespan
                ));
            }
            Ok(())
        },
    );
}
