//! Integration tests for the multiplexed cluster plane (DESIGN.md §17):
//! many in-flight requests on a fixed transport-thread budget, liveness
//! teardown, backpressure, and JSON↔binary cross-version interop in
//! both directions (old JSON worker × new manager, old JSON manager ×
//! new worker).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::{serve_manager, MuxWorkerChannel, RemoteClient};
use dqulearn::coordinator::{Manager, ManagerConfig, WorkerChannel};
use dqulearn::model::exec::{CircuitExecutor, CircuitPair, QsimExecutor};
use dqulearn::net::mux::transport_thread_count;
use dqulearn::net::{Mux, MuxConfig, MuxServer, MuxService, RpcClient, RpcServer};
use dqulearn::wire::{bin, Value};
use dqulearn::worker::{WorkerHandle, WorkerOptions};
use dqulearn::DqError;

/// The transport-thread gauge is process-wide, so tests that create mux
/// planes serialize on this lock to keep the arithmetic honest.
static GAUGE_LOCK: Mutex<()> = Mutex::new(());

fn gauge_guard() -> std::sync::MutexGuard<'static, ()> {
    GAUGE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// A peer that completes the mux handshake and then swallows every
/// byte without ever answering — the shape of a hung remote worker.
fn silent_mux_peer() -> (SocketAddr, JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let thread = std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            let mut hello = [0u8; 6];
            if s.read_exact(&mut hello).is_err() {
                return;
            }
            let reply = [
                dqulearn::net::mux::MAGIC[0],
                dqulearn::net::mux::MAGIC[1],
                dqulearn::net::mux::MAGIC[2],
                dqulearn::net::mux::MAGIC[3],
                bin::BIN_VERSION,
                bin::FEAT_BIN_EXECUTE,
            ];
            let _ = s.write_all(&reply);
            let mut sink = [0u8; 4096];
            while matches!(s.read(&mut sink), Ok(n) if n > 0) {}
        }
    });
    (addr, thread)
}

/// Stand-in manager endpoint so a real [`WorkerHandle`] can register.
fn fake_manager() -> RpcServer {
    let handler = |op: &str, _params: &Value| -> Result<Value, DqError> {
        match op {
            "register" => Ok(Value::obj().with("worker_id", 1u64)),
            "heartbeat" => Ok(Value::obj()),
            other => Err(DqError::Protocol(format!("unexpected {other}"))),
        }
    };
    RpcServer::serve("127.0.0.1:0", Arc::new(handler)).unwrap()
}

fn qsim_worker(manager_addr: &str) -> WorkerHandle {
    WorkerHandle::start(
        manager_addr,
        WorkerOptions {
            max_qubits: 5,
            artifact_dir: "/nonexistent".into(), // force the qsim backend
            heartbeat_period: 0.5,
            listen: "127.0.0.1:0".to_string(),
            threads: 1,
        },
    )
    .unwrap()
}

fn sample_pairs(cfg: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
    (0..n)
        .map(|i| {
            let x = 0.1 + 0.05 * i as f32;
            (vec![x; cfg.n_params()], vec![1.0 - x; cfg.n_features()])
        })
        .collect()
}

#[test]
fn hundreds_of_inflight_requests_share_three_transport_threads() {
    let _serial = gauge_guard();
    let base = transport_thread_count();

    // Echo service: varint(op) then the payload back.
    let service = Arc::new(|op: u32, payload: &[u8]| -> Result<Vec<u8>, DqError> {
        let mut out = Vec::with_capacity(payload.len() + 5);
        bin::put_varint(&mut out, u64::from(op));
        out.extend_from_slice(payload);
        Ok(out)
    });
    let mut server = MuxServer::serve("127.0.0.1:0", service).unwrap();
    let mux = Mux::new(MuxConfig::default());

    let conns: Vec<u64> = (0..8)
        .map(|_| {
            let conn = mux.connect(server.local_addr()).unwrap();
            assert_eq!(conn.negotiated.version, bin::BIN_VERSION);
            assert_eq!(conn.negotiated.features, bin::FEAT_ALL);
            conn.id
        })
        .collect();

    const N: usize = 400;
    let (tx, rx) = mpsc::channel::<(usize, Result<Vec<u8>, DqError>)>();
    for i in 0..N {
        let op = (i % 9 + 1) as u32;
        let payload = (i as u64).to_le_bytes().to_vec();
        let tx = tx.clone();
        mux.request(
            conns[i % conns.len()],
            op,
            payload,
            Box::new(move |res| {
                let _ = tx.send((i, res));
            }),
        );
    }
    drop(tx);

    let mut seen = vec![false; N];
    for _ in 0..N {
        let (i, res) = rx.recv_timeout(Duration::from_secs(20)).unwrap();
        let bytes = res.unwrap();
        let mut c = bin::Cur::new(&bytes);
        assert_eq!(c.take_varint().unwrap(), (i % 9 + 1) as u64);
        assert_eq!(c.take(8).unwrap(), (i as u64).to_le_bytes());
        c.done().unwrap();
        assert!(!seen[i], "duplicate completion for request {i}");
        seen[i] = true;
    }

    // 400 in-flight requests over 8 connections cost exactly one event
    // loop + one completion runner + one server park — never a thread
    // per connection or per request.
    assert!(
        transport_thread_count() <= base + 3,
        "transport grew past 3 threads: {} -> {}",
        base,
        transport_thread_count()
    );

    mux.shutdown();
    server.shutdown();
    assert_eq!(transport_thread_count(), base, "transport threads leaked");
}

#[test]
fn idle_timeout_fails_pending_and_marks_the_connection_dead() {
    let _serial = gauge_guard();
    let (addr, peer) = silent_mux_peer();
    let mux = Mux::new(MuxConfig {
        ping_interval: Duration::from_millis(20),
        idle_timeout: Duration::from_millis(300),
        ..MuxConfig::default()
    });
    let conn = mux.connect(addr).unwrap();

    let (tx, rx) = mpsc::channel();
    mux.request(
        conn.id,
        bin::OP_EXECUTE,
        b"never answered".to_vec(),
        Box::new(move |res| {
            let _ = tx.send(res);
        }),
    );
    // The peer swallows the request (and the pings) without replying,
    // so the idle timer is the only way out.
    let res = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    match res {
        Err(DqError::WorkerLost(msg)) => assert!(msg.contains("idle"), "unexpected msg: {msg}"),
        other => panic!("expected WorkerLost(idle), got {other:?}"),
    }
    assert!(mux.is_dead(conn.id));

    // Requests after teardown fail fast, without touching the network.
    let err = mux.call(conn.id, bin::OP_EXECUTE, Vec::new()).unwrap_err();
    assert!(matches!(err, DqError::WorkerLost(_)), "{err}");

    mux.shutdown();
    let _ = peer.join();
}

#[test]
fn backpressure_rejects_the_request_over_the_inflight_cap() {
    let _serial = gauge_guard();
    let (addr, peer) = silent_mux_peer();
    let mux = Mux::new(MuxConfig {
        max_inflight: 4,
        ping_interval: Duration::from_secs(30),
        idle_timeout: Duration::from_secs(60),
        ..MuxConfig::default()
    });
    let conn = mux.connect(addr).unwrap();

    // Five requests against a cap of four: the peer never answers, so
    // pending never drains and the fifth must bounce immediately.
    let (tx, rx) = mpsc::channel::<(usize, Result<Vec<u8>, DqError>)>();
    for i in 0..5 {
        let tx = tx.clone();
        mux.request(
            conn.id,
            bin::OP_EXECUTE,
            vec![0u8; 64],
            Box::new(move |res| {
                let _ = tx.send((i, res));
            }),
        );
    }
    let (i, res) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(i, 4, "only the over-cap request may complete");
    match res {
        Err(DqError::Io(msg)) => assert!(msg.contains("backpressure"), "unexpected msg: {msg}"),
        other => panic!("expected Io(backpressure), got {other:?}"),
    }

    mux.shutdown();
    let _ = peer.join();
}

#[test]
fn mux_worker_channel_executes_against_a_real_worker() {
    let _serial = gauge_guard();
    let mgr = fake_manager();
    let mut worker = qsim_worker(&mgr.local_addr().to_string());

    let mux = Mux::new(MuxConfig::default());
    let conn = mux.connect(worker.listen_addr).unwrap();
    let channel = MuxWorkerChannel::new(mux.clone(), conn.id);
    assert!(channel.is_async());

    let cfg = QuClassiConfig::new(5, 2).unwrap();
    let pairs = sample_pairs(&cfg, 6);
    let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();

    // blocking path
    let fids = channel.execute(&cfg, &pairs).unwrap();
    assert_eq!(fids, want);

    // async path (the one the outbox dispatcher uses)
    let (tx, rx) = mpsc::channel();
    channel.execute_async(
        &cfg,
        &pairs,
        Box::new(move |res| {
            let _ = tx.send(res);
        }),
    );
    let fids = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
    assert_eq!(fids, want);

    // a worker-side validation error comes back typed over the wire
    let err = mux.call(conn.id, bin::OP_EXECUTE, bin::encode_jobs(&[])).unwrap_err();
    assert!(matches!(err, DqError::Protocol(ref m) if m.contains("empty")), "{err}");

    mux.shutdown();
    worker.stop();
}

#[test]
fn old_json_worker_interops_with_a_new_manager() {
    let _serial = gauge_guard();
    let manager = Manager::new(ManagerConfig::default());
    let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // A worker predating the binary plane: framed-JSON RPC only. The
    // manager's mux dial-back must fail its handshake cleanly and fall
    // back to the JSON channel.
    let worker_srv = {
        let handler = |op: &str, params: &Value| -> Result<Value, DqError> {
            match op {
                "execute" => {
                    let n = params.req_arr("circuits")?.len();
                    Ok(Value::obj().with("fids", vec![0.25f32; n].as_slice()))
                }
                other => Err(DqError::Protocol(format!("unexpected {other}"))),
            }
        };
        RpcServer::serve("127.0.0.1:0", Arc::new(handler)).unwrap()
    };
    let reg = RpcClient::connect(addr.as_str(), Duration::from_secs(5)).unwrap();
    let resp = reg
        .call(
            "register",
            Value::obj()
                .with("max_qubits", 5usize)
                .with("addr", worker_srv.local_addr().to_string())
                .with("cru", 0.0)
                .with("threads", 1usize),
        )
        .unwrap();
    assert!(resp.req_u64("worker_id").unwrap() >= 1);

    let client = RemoteClient::connect(&addr).unwrap();
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let fids = client.execute_bank(&cfg, &sample_pairs(&cfg, 4)).unwrap();
    assert_eq!(fids, vec![0.25; 4]);

    manager.shutdown();
}

#[test]
fn old_json_manager_interops_with_a_new_worker() {
    let _serial = gauge_guard();
    let mgr = fake_manager();
    let mut worker = qsim_worker(&mgr.local_addr().to_string());

    // A manager predating the mux plane dials the worker with the
    // framed-JSON client; the worker's dual-codec listener sniffs the
    // first frame and serves the legacy path on the same port.
    let json = RpcClient::connect(worker.listen_addr, Duration::from_secs(5)).unwrap();
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs = sample_pairs(&cfg, 3);
    let jobs: Vec<Value> = pairs
        .iter()
        .enumerate()
        .map(|(i, (thetas, data))| {
            dqulearn::coordinator::CircuitJob {
                id: i as u64,
                client: 0,
                bank: 0,
                index: i,
                config: cfg,
                thetas: thetas.clone(),
                data: data.clone(),
            }
            .to_wire()
        })
        .collect();
    let resp = json.call("execute", Value::obj().with("circuits", jobs)).unwrap();
    let fids = resp.req_f32_vec("fids").unwrap();
    assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());

    // …and the binary plane stays available on the very same socket.
    let mux = Mux::new(MuxConfig::default());
    let conn = mux.connect(worker.listen_addr).unwrap();
    assert_eq!(conn.negotiated.version, bin::BIN_VERSION);
    mux.shutdown();
    worker.stop();
}

// ---------------------------------------------------------------------------
// in-place reconnect (DESIGN.md §19): kill the socket, not the worker
// ---------------------------------------------------------------------------

/// A TCP proxy with a kill switch. [`FlakyProxy::sever`] hard-closes the
/// live downstream↔upstream socket pair — the peer processes stay
/// healthy, only the link dies — and the listener keeps accepting, so a
/// redialing mux reconnects through the same address. This is the
/// network flap the reconnect suite injects.
struct FlakyProxy {
    addr: SocketAddr,
    live: Arc<Mutex<Vec<TcpStream>>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

fn proxy_pump(mut from: TcpStream, mut to: TcpStream) {
    let mut buf = [0u8; 16 * 1024];
    loop {
        match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    let _ = to.shutdown(Shutdown::Both);
    let _ = from.shutdown(Shutdown::Both);
}

impl FlakyProxy {
    fn start(upstream: SocketAddr) -> FlakyProxy {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        listener.set_nonblocking(true).unwrap();
        let live: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let (live2, stop2) = (live.clone(), stop.clone());
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((down, _peer)) => {
                        let Ok(up) = TcpStream::connect(upstream) else { continue };
                        let _ = down.set_nodelay(true);
                        let _ = up.set_nodelay(true);
                        let (Ok(d2), Ok(u2)) = (down.try_clone(), up.try_clone()) else {
                            continue;
                        };
                        {
                            let mut g = live2.lock().unwrap_or_else(|e| e.into_inner());
                            if let (Ok(d3), Ok(u3)) = (down.try_clone(), up.try_clone()) {
                                g.push(d3);
                                g.push(u3);
                            }
                        }
                        std::thread::spawn(move || proxy_pump(down, u2));
                        std::thread::spawn(move || proxy_pump(up, d2));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        FlakyProxy { addr, live, stop, accept_thread: Some(accept_thread) }
    }

    /// Tear down every live proxied socket pair (both directions).
    fn sever(&self) {
        let mut g = self.live.lock().unwrap_or_else(|e| e.into_inner());
        for s in g.drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for FlakyProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.sever();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Mux-level reconnect: requests issued across repeated link kills all
/// complete exactly once on the same connection id — the dead set never
/// grows because the connection never actually dies.
#[test]
fn mux_connection_heals_in_place_through_a_flaky_link() {
    let _serial = gauge_guard();

    /// op 7 echoes inline; op 30 echoes after a nap on a deferred
    /// thread, so severs land while requests are genuinely in flight.
    struct SlowEcho;

    impl MuxService for SlowEcho {
        fn handle(&self, op: u32, payload: &[u8]) -> Result<Vec<u8>, DqError> {
            match op {
                7 => Ok(payload.to_vec()),
                30 => {
                    std::thread::sleep(Duration::from_millis(40));
                    Ok(payload.to_vec())
                }
                _ => Err(DqError::Protocol(format!("unknown op {op}"))),
            }
        }

        fn defer(&self, op: u32) -> bool {
            op == 30
        }
    }

    let server = MuxServer::serve("127.0.0.1:0", Arc::new(SlowEcho)).unwrap();
    let proxy = FlakyProxy::start(server.local_addr());
    let mux = Mux::new(MuxConfig::default());
    let conn = mux.connect(proxy.addr).unwrap();
    assert_eq!(
        conn.negotiated.features & bin::FEAT_RESUME,
        bin::FEAT_RESUME,
        "resume must be negotiated for in-place reconnect"
    );

    const N: usize = 20;
    let (tx, rx) = mpsc::channel::<(usize, Result<Vec<u8>, DqError>)>();
    for i in 0..N {
        let tx = tx.clone();
        mux.request(
            conn.id,
            30,
            vec![i as u8; 8],
            Box::new(move |res| {
                let _ = tx.send((i, res));
            }),
        );
        if i % 5 == 4 {
            proxy.sever(); // mid-stream link kill, requests in flight
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(tx);

    let mut seen = vec![false; N];
    for _ in 0..N {
        let (i, res) = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(res.unwrap(), vec![i as u8; 8], "request {i} corrupted across reconnect");
        assert!(!seen[i], "duplicate completion for request {i}");
        seen[i] = true;
    }

    // The connection healed in place: same id, never in the dead set,
    // and still answering.
    assert!(!mux.is_dead(conn.id), "flapped connection must not be torn down");
    assert_eq!(mux.dead_len(), 0, "in-place revival must not populate the dead set");
    assert_eq!(mux.call(conn.id, 7, b"still alive".to_vec()).unwrap(), b"still alive");

    mux.shutdown();
}

/// A mux worker endpoint that records how many times each circuit
/// (keyed by its unique `thetas[0]` marker) executed, and serializes
/// batches so a bank spans real wall-clock time.
#[derive(Default)]
struct CountingWorker {
    counts: Mutex<HashMap<u32, u32>>,
}

impl MuxService for CountingWorker {
    fn handle(&self, op: u32, payload: &[u8]) -> Result<Vec<u8>, DqError> {
        if op != bin::OP_EXECUTE {
            return Err(DqError::Protocol(format!("unknown op {op}")));
        }
        let jobs = bin::decode_jobs(payload)?;
        // hold the lock across the nap: batches serialize, so the bank
        // stays in flight long enough for severs to land mid-bank
        let mut counts = self.counts.lock().unwrap_or_else(|e| e.into_inner());
        std::thread::sleep(Duration::from_millis(25));
        let mut fids = Vec::with_capacity(jobs.len());
        for job in &jobs {
            *counts.entry(job.thetas[0].to_bits()).or_insert(0) += 1;
            fids.push(job.thetas[0]);
        }
        Ok(bin::encode_fids(&fids))
    }

    fn defer(&self, op: u32) -> bool {
        op == bin::OP_EXECUTE // executes block; keep them off the park thread
    }
}

/// The tentpole acceptance test: sever the manager→worker socket
/// repeatedly mid-bank (the worker process stays healthy). The mux must
/// heal the link in place — no re-registration, no `WorkerLost` bank
/// failure, every circuit executed exactly once, partial fidelities
/// streamed in order with zero `bank_status` polls on the wire.
#[test]
fn severed_worker_socket_heals_in_place() {
    let _serial = gauge_guard();
    let manager = Manager::new(ManagerConfig {
        heartbeat_period: 1000.0, // evictor effectively off: flaps, not death
        max_batch: 2,
        ..Default::default()
    });
    let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // The "worker": a counting mux endpoint behind the flaky proxy. The
    // manager dials the proxy address back, so severing the proxy kills
    // exactly the manager→worker socket.
    let worker = Arc::new(CountingWorker::default());
    let worker_park = MuxServer::serve("127.0.0.1:0", worker.clone()).unwrap();
    let proxy = FlakyProxy::start(worker_park.local_addr());

    let reg = RpcClient::connect(addr.as_str(), Duration::from_secs(5)).unwrap();
    let resp = reg
        .call(
            "register",
            Value::obj()
                .with("max_qubits", 5usize)
                .with("addr", proxy.addr.to_string())
                .with("cru", 0.0)
                .with("threads", 1usize),
        )
        .unwrap();
    assert!(resp.req_u64("worker_id").unwrap() >= 1);
    assert_eq!(manager.worker_count(), 1);

    let client = RemoteClient::connect(&addr).unwrap();
    assert!(client.is_binary());
    let session = client.session().unwrap();
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    // Each circuit carries a unique marker in thetas[0]; the counting
    // worker echoes it as the fidelity, so the final vector doubles as
    // a routing/ordering audit.
    let marker = |i: usize| (i as f32 + 1.0) / 64.0;
    let pairs: Vec<CircuitPair> = (0..24)
        .map(|i| {
            let mut thetas = vec![0.0f32; cfg.n_params()];
            thetas[0] = marker(i);
            (thetas, vec![0.5f32; cfg.n_features()])
        })
        .collect();
    let handle = session.submit(cfg, &pairs).unwrap();

    // Kill the socket (not the worker) several times mid-bank, at
    // staggered offsets, checking invariants between flaps.
    let mut last_completed = 0usize;
    for nap_ms in [45u64, 60, 75, 90] {
        std::thread::sleep(Duration::from_millis(nap_ms));
        proxy.sever();
        assert_eq!(manager.worker_count(), 1, "flap must not evict the worker");
        let st = handle.try_poll().unwrap();
        assert!(
            st.completed >= last_completed,
            "completion count went backwards: {} -> {}",
            last_completed,
            st.completed
        );
        last_completed = st.completed;
        // streamed partials carry the right marker at the right index
        for (i, f) in st.partial_fids.iter().enumerate() {
            if let Some(f) = f {
                assert_eq!(*f, marker(i), "streamed fidelity out of order at index {i}");
            }
        }
    }

    // The bank completes without WorkerLost, in submission order.
    let fids = handle.wait().unwrap();
    assert_eq!(fids, (0..24).map(marker).collect::<Vec<f32>>());

    // Exactly-once execution: every marker ran once, nothing twice.
    {
        let counts = worker.counts.lock().unwrap();
        assert_eq!(counts.len(), 24, "circuits lost or never executed");
        for (key, n) in counts.iter() {
            assert_eq!(*n, 1, "circuit {key:#x} executed {n} times (exactly-once violated)");
        }
    }

    // No re-registration, no eviction, no requeue-on-WorkerLost; and
    // every progress observation came from the push stream, not polls.
    assert_eq!(manager.worker_count(), 1);
    let stats = manager.stats();
    assert_eq!(stats.evictions, 0, "flaps must not evict");
    assert_eq!(stats.requeues, 0, "flaps must not trigger WorkerLost requeues");
    assert_eq!(client.status_polls(), 0, "binary plane must not poll bank_status");

    manager.shutdown();
}

/// Push-stream protocol on a healthy link: a submitted bank streams its
/// partial fidelities; `try_poll` answers locally and the wire sees
/// zero `bank_status` calls.
#[test]
fn partial_fidelities_stream_without_status_polls() {
    let _serial = gauge_guard();
    let manager = Manager::new(ManagerConfig { heartbeat_period: 0.5, ..Default::default() });
    let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let mut worker = qsim_worker(&addr);

    let client = RemoteClient::connect(&addr).unwrap();
    assert!(client.is_binary());
    let session = client.session().unwrap();
    let cfg = QuClassiConfig::new(5, 2).unwrap();
    let pairs = sample_pairs(&cfg, 8);
    let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();

    let handle = session.submit(cfg, &pairs).unwrap();
    // Poll aggressively while the bank runs: every answer must come
    // from the locally accumulated push events.
    let mut last = 0usize;
    loop {
        let st = handle.try_poll().unwrap();
        assert!(st.completed >= last, "completed went backwards");
        assert_eq!(st.total, 8);
        last = st.completed;
        if !st.pending {
            assert_eq!(st.completed, 8, "terminal bank must report all circuits");
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(handle.wait_timeout(Duration::from_secs(30)).unwrap(), want);
    assert_eq!(client.status_polls(), 0, "push-negotiated plane must never poll");

    worker.stop();
    manager.shutdown();
}
