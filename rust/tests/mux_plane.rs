//! Integration tests for the multiplexed cluster plane (DESIGN.md §17):
//! many in-flight requests on a fixed transport-thread budget, liveness
//! teardown, backpressure, and JSON↔binary cross-version interop in
//! both directions (old JSON worker × new manager, old JSON manager ×
//! new worker).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::{serve_manager, MuxWorkerChannel, RemoteClient};
use dqulearn::coordinator::{Manager, ManagerConfig, WorkerChannel};
use dqulearn::model::exec::{CircuitExecutor, CircuitPair, QsimExecutor};
use dqulearn::net::mux::transport_thread_count;
use dqulearn::net::{Mux, MuxConfig, MuxServer, RpcClient, RpcServer};
use dqulearn::wire::{bin, Value};
use dqulearn::worker::{WorkerHandle, WorkerOptions};
use dqulearn::DqError;

/// The transport-thread gauge is process-wide, so tests that create mux
/// planes serialize on this lock to keep the arithmetic honest.
static GAUGE_LOCK: Mutex<()> = Mutex::new(());

fn gauge_guard() -> std::sync::MutexGuard<'static, ()> {
    GAUGE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// A peer that completes the mux handshake and then swallows every
/// byte without ever answering — the shape of a hung remote worker.
fn silent_mux_peer() -> (SocketAddr, JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let thread = std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            let mut hello = [0u8; 6];
            if s.read_exact(&mut hello).is_err() {
                return;
            }
            let reply = [
                dqulearn::net::mux::MAGIC[0],
                dqulearn::net::mux::MAGIC[1],
                dqulearn::net::mux::MAGIC[2],
                dqulearn::net::mux::MAGIC[3],
                bin::BIN_VERSION,
                bin::FEAT_BIN_EXECUTE,
            ];
            let _ = s.write_all(&reply);
            let mut sink = [0u8; 4096];
            while matches!(s.read(&mut sink), Ok(n) if n > 0) {}
        }
    });
    (addr, thread)
}

/// Stand-in manager endpoint so a real [`WorkerHandle`] can register.
fn fake_manager() -> RpcServer {
    let handler = |op: &str, _params: &Value| -> Result<Value, DqError> {
        match op {
            "register" => Ok(Value::obj().with("worker_id", 1u64)),
            "heartbeat" => Ok(Value::obj()),
            other => Err(DqError::Protocol(format!("unexpected {other}"))),
        }
    };
    RpcServer::serve("127.0.0.1:0", Arc::new(handler)).unwrap()
}

fn qsim_worker(manager_addr: &str) -> WorkerHandle {
    WorkerHandle::start(
        manager_addr,
        WorkerOptions {
            max_qubits: 5,
            artifact_dir: "/nonexistent".into(), // force the qsim backend
            heartbeat_period: 0.5,
            listen: "127.0.0.1:0".to_string(),
            threads: 1,
        },
    )
    .unwrap()
}

fn sample_pairs(cfg: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
    (0..n)
        .map(|i| {
            let x = 0.1 + 0.05 * i as f32;
            (vec![x; cfg.n_params()], vec![1.0 - x; cfg.n_features()])
        })
        .collect()
}

#[test]
fn hundreds_of_inflight_requests_share_three_transport_threads() {
    let _serial = gauge_guard();
    let base = transport_thread_count();

    // Echo service: varint(op) then the payload back.
    let service = Arc::new(|op: u32, payload: &[u8]| -> Result<Vec<u8>, DqError> {
        let mut out = Vec::with_capacity(payload.len() + 5);
        bin::put_varint(&mut out, u64::from(op));
        out.extend_from_slice(payload);
        Ok(out)
    });
    let mut server = MuxServer::serve("127.0.0.1:0", service).unwrap();
    let mux = Mux::new(MuxConfig::default());

    let conns: Vec<u64> = (0..8)
        .map(|_| {
            let conn = mux.connect(server.local_addr()).unwrap();
            assert_eq!(conn.negotiated.version, bin::BIN_VERSION);
            assert_eq!(conn.negotiated.features, bin::FEAT_BIN_EXECUTE);
            conn.id
        })
        .collect();

    const N: usize = 400;
    let (tx, rx) = mpsc::channel::<(usize, Result<Vec<u8>, DqError>)>();
    for i in 0..N {
        let op = (i % 9 + 1) as u32;
        let payload = (i as u64).to_le_bytes().to_vec();
        let tx = tx.clone();
        mux.request(
            conns[i % conns.len()],
            op,
            payload,
            Box::new(move |res| {
                let _ = tx.send((i, res));
            }),
        );
    }
    drop(tx);

    let mut seen = vec![false; N];
    for _ in 0..N {
        let (i, res) = rx.recv_timeout(Duration::from_secs(20)).unwrap();
        let bytes = res.unwrap();
        let mut c = bin::Cur::new(&bytes);
        assert_eq!(c.take_varint().unwrap(), (i % 9 + 1) as u64);
        assert_eq!(c.take(8).unwrap(), (i as u64).to_le_bytes());
        c.done().unwrap();
        assert!(!seen[i], "duplicate completion for request {i}");
        seen[i] = true;
    }

    // 400 in-flight requests over 8 connections cost exactly one event
    // loop + one completion runner + one server park — never a thread
    // per connection or per request.
    assert!(
        transport_thread_count() <= base + 3,
        "transport grew past 3 threads: {} -> {}",
        base,
        transport_thread_count()
    );

    mux.shutdown();
    server.shutdown();
    assert_eq!(transport_thread_count(), base, "transport threads leaked");
}

#[test]
fn idle_timeout_fails_pending_and_marks_the_connection_dead() {
    let _serial = gauge_guard();
    let (addr, peer) = silent_mux_peer();
    let mux = Mux::new(MuxConfig {
        ping_interval: Duration::from_millis(20),
        idle_timeout: Duration::from_millis(300),
        ..MuxConfig::default()
    });
    let conn = mux.connect(addr).unwrap();

    let (tx, rx) = mpsc::channel();
    mux.request(
        conn.id,
        bin::OP_EXECUTE,
        b"never answered".to_vec(),
        Box::new(move |res| {
            let _ = tx.send(res);
        }),
    );
    // The peer swallows the request (and the pings) without replying,
    // so the idle timer is the only way out.
    let res = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    match res {
        Err(DqError::WorkerLost(msg)) => assert!(msg.contains("idle"), "unexpected msg: {msg}"),
        other => panic!("expected WorkerLost(idle), got {other:?}"),
    }
    assert!(mux.is_dead(conn.id));

    // Requests after teardown fail fast, without touching the network.
    let err = mux.call(conn.id, bin::OP_EXECUTE, Vec::new()).unwrap_err();
    assert!(matches!(err, DqError::WorkerLost(_)), "{err}");

    mux.shutdown();
    let _ = peer.join();
}

#[test]
fn backpressure_rejects_the_request_over_the_inflight_cap() {
    let _serial = gauge_guard();
    let (addr, peer) = silent_mux_peer();
    let mux = Mux::new(MuxConfig {
        max_inflight: 4,
        ping_interval: Duration::from_secs(30),
        idle_timeout: Duration::from_secs(60),
        ..MuxConfig::default()
    });
    let conn = mux.connect(addr).unwrap();

    // Five requests against a cap of four: the peer never answers, so
    // pending never drains and the fifth must bounce immediately.
    let (tx, rx) = mpsc::channel::<(usize, Result<Vec<u8>, DqError>)>();
    for i in 0..5 {
        let tx = tx.clone();
        mux.request(
            conn.id,
            bin::OP_EXECUTE,
            vec![0u8; 64],
            Box::new(move |res| {
                let _ = tx.send((i, res));
            }),
        );
    }
    let (i, res) = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(i, 4, "only the over-cap request may complete");
    match res {
        Err(DqError::Io(msg)) => assert!(msg.contains("backpressure"), "unexpected msg: {msg}"),
        other => panic!("expected Io(backpressure), got {other:?}"),
    }

    mux.shutdown();
    let _ = peer.join();
}

#[test]
fn mux_worker_channel_executes_against_a_real_worker() {
    let _serial = gauge_guard();
    let mgr = fake_manager();
    let mut worker = qsim_worker(&mgr.local_addr().to_string());

    let mux = Mux::new(MuxConfig::default());
    let conn = mux.connect(worker.listen_addr).unwrap();
    let channel = MuxWorkerChannel::new(mux.clone(), conn.id);
    assert!(channel.is_async());

    let cfg = QuClassiConfig::new(5, 2).unwrap();
    let pairs = sample_pairs(&cfg, 6);
    let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();

    // blocking path
    let fids = channel.execute(&cfg, &pairs).unwrap();
    assert_eq!(fids, want);

    // async path (the one the outbox dispatcher uses)
    let (tx, rx) = mpsc::channel();
    channel.execute_async(
        &cfg,
        &pairs,
        Box::new(move |res| {
            let _ = tx.send(res);
        }),
    );
    let fids = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
    assert_eq!(fids, want);

    // a worker-side validation error comes back typed over the wire
    let err = mux.call(conn.id, bin::OP_EXECUTE, bin::encode_jobs(&[])).unwrap_err();
    assert!(matches!(err, DqError::Protocol(ref m) if m.contains("empty")), "{err}");

    mux.shutdown();
    worker.stop();
}

#[test]
fn old_json_worker_interops_with_a_new_manager() {
    let _serial = gauge_guard();
    let manager = Manager::new(ManagerConfig::default());
    let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // A worker predating the binary plane: framed-JSON RPC only. The
    // manager's mux dial-back must fail its handshake cleanly and fall
    // back to the JSON channel.
    let worker_srv = {
        let handler = |op: &str, params: &Value| -> Result<Value, DqError> {
            match op {
                "execute" => {
                    let n = params.req_arr("circuits")?.len();
                    Ok(Value::obj().with("fids", vec![0.25f32; n].as_slice()))
                }
                other => Err(DqError::Protocol(format!("unexpected {other}"))),
            }
        };
        RpcServer::serve("127.0.0.1:0", Arc::new(handler)).unwrap()
    };
    let reg = RpcClient::connect(addr.as_str(), Duration::from_secs(5)).unwrap();
    let resp = reg
        .call(
            "register",
            Value::obj()
                .with("max_qubits", 5usize)
                .with("addr", worker_srv.local_addr().to_string())
                .with("cru", 0.0)
                .with("threads", 1usize),
        )
        .unwrap();
    assert!(resp.req_u64("worker_id").unwrap() >= 1);

    let client = RemoteClient::connect(&addr).unwrap();
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let fids = client.execute_bank(&cfg, &sample_pairs(&cfg, 4)).unwrap();
    assert_eq!(fids, vec![0.25; 4]);

    manager.shutdown();
}

#[test]
fn old_json_manager_interops_with_a_new_worker() {
    let _serial = gauge_guard();
    let mgr = fake_manager();
    let mut worker = qsim_worker(&mgr.local_addr().to_string());

    // A manager predating the mux plane dials the worker with the
    // framed-JSON client; the worker's dual-codec listener sniffs the
    // first frame and serves the legacy path on the same port.
    let json = RpcClient::connect(worker.listen_addr, Duration::from_secs(5)).unwrap();
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs = sample_pairs(&cfg, 3);
    let jobs: Vec<Value> = pairs
        .iter()
        .enumerate()
        .map(|(i, (thetas, data))| {
            dqulearn::coordinator::CircuitJob {
                id: i as u64,
                client: 0,
                bank: 0,
                index: i,
                config: cfg,
                thetas: thetas.clone(),
                data: data.clone(),
            }
            .to_wire()
        })
        .collect();
    let resp = json.call("execute", Value::obj().with("circuits", jobs)).unwrap();
    let fids = resp.req_f32_vec("fids").unwrap();
    assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());

    // …and the binary plane stays available on the very same socket.
    let mux = Mux::new(MuxConfig::default());
    let conn = mux.connect(worker.listen_addr).unwrap();
    assert_eq!(conn.negotiated.version, bin::BIN_VERSION);
    mux.shutdown();
    worker.stop();
}
