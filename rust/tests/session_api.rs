//! Integration tests for the typed session API over real TCP: bank
//! cancellation, monotonic progress polling, and typed RPC error paths
//! (a malformed worker payload must surface `DqError::Protocol`, never
//! hang a client).

use std::sync::Arc;
use std::time::Duration;

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::serve_manager;
use dqulearn::cluster::RemoteClient;
use dqulearn::coordinator::{Manager, ManagerConfig, WorkerChannel, WorkerProfile};
use dqulearn::error::DqError;
use dqulearn::model::exec::{CircuitExecutor, CircuitPair, QsimExecutor};
use dqulearn::net::{RpcHandler, RpcServer};
use dqulearn::util::Rng;
use dqulearn::wire::Value;

/// Simulator-backed channel that pauses per dispatch, so tests can
/// observe (and cancel) half-completed banks deterministically.
struct SlowChannel {
    delay: Duration,
}

impl WorkerChannel for SlowChannel {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        std::thread::sleep(self.delay);
        QsimExecutor.execute_bank(config, pairs)
    }
}

fn pairs_for(config: &QuClassiConfig, n: usize, seed: u64) -> Vec<CircuitPair> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (
                (0..config.n_params()).map(|_| rng.f32()).collect(),
                (0..config.n_features()).map(|_| rng.f32()).collect(),
            )
        })
        .collect()
}

/// Acceptance: a client cancels a half-completed bank over TCP; the
/// manager requeues nothing from it, releases its reservations, and a
/// concurrent tenant's bank completes with exact parity against
/// `QsimExecutor`.
#[test]
fn cancel_half_completed_bank_over_tcp() {
    let manager = Manager::new(ManagerConfig { max_batch: 1, ..Default::default() });
    // One slow 5-qubit worker: circuits complete one at a time, so the
    // bank is observably in progress when the cancel lands.
    manager.register(
        WorkerProfile::new(5),
        Arc::new(SlowChannel { delay: Duration::from_millis(15) }),
    );
    let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let client = RemoteClient::connect(&addr).unwrap();
    let tenant_a = client.session().unwrap();
    let tenant_b = client.session().unwrap();
    assert_ne!(tenant_a.id(), tenant_b.id());

    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let doomed_pairs = pairs_for(&cfg, 12, 1);
    let doomed = tenant_a.submit(cfg, &doomed_pairs).unwrap();
    // the concurrent tenant's bank queues behind tenant A's
    let keep_pairs = pairs_for(&cfg, 4, 2);
    let keep = tenant_b.submit(cfg, &keep_pairs).unwrap();

    // Poll (over TCP) until the bank is genuinely half-done.
    loop {
        let st = doomed.try_poll().unwrap();
        assert_eq!(st.total, 12);
        if st.completed >= 3 {
            break;
        }
        std::thread::sleep(Duration::from_millis(3));
    }
    let drained = doomed.cancel().unwrap();
    assert!(drained > 0, "expected queued circuits to drain, got {drained}");
    // cancel is idempotent
    assert_eq!(doomed.cancel().unwrap(), 0);
    assert!(matches!(doomed.wait_timeout(Duration::from_secs(10)), Err(DqError::Cancelled(_))));

    // The concurrent tenant is unaffected: exact parity with local sim.
    let fids = keep.wait().unwrap();
    assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &keep_pairs).unwrap());

    // Nothing from the cancelled bank was requeued, exactly one bank was
    // recorded cancelled, and every reservation drains back to idle.
    let stats = client.manager_stats().unwrap();
    assert_eq!(stats.req_u64("requeues").unwrap(), 0);
    assert_eq!(stats.req_u64("cancelled").unwrap(), 1);
    assert_eq!(stats.req_u64("queue").unwrap(), 0);
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        if manager.available_qubits() == 5 {
            break; // all reservations released
        }
        assert!(std::time::Instant::now() < deadline, "reservations never released");
        std::thread::sleep(Duration::from_millis(5));
    }
    manager.shutdown();
}

/// Acceptance: `BankHandle::try_poll()` observes monotonically
/// non-decreasing completion counts while a bank runs — here through the
/// full TCP `bank_status` path, partial fidelities included.
#[test]
fn try_poll_is_monotonic_over_tcp() {
    let manager = Manager::new(ManagerConfig { max_batch: 2, ..Default::default() });
    manager.register(
        WorkerProfile::new(5),
        Arc::new(SlowChannel { delay: Duration::from_millis(10) }),
    );
    let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
    let client = RemoteClient::connect(&server.local_addr().to_string()).unwrap();
    let session = client.session().unwrap();

    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs = pairs_for(&cfg, 14, 3);
    let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
    let handle = session.submit(cfg, &pairs).unwrap();

    let mut last = 0usize;
    let mut observed_partial = false;
    loop {
        let st = handle.try_poll().unwrap();
        assert!(
            st.completed >= last,
            "completion count went backwards: {} < {last}",
            st.completed
        );
        assert_eq!(st.total, 14);
        let done = st.partial_fids.iter().filter(|f| f.is_some()).count();
        assert_eq!(done, st.completed, "partial_fids disagree with completed count");
        // every partial fidelity already equals the local simulation
        for (i, f) in st.partial_fids.iter().enumerate() {
            if let Some(f) = f {
                assert!((f - want[i]).abs() < 1e-6, "circuit {i} fid diverged mid-bank");
            }
        }
        if st.pending && st.completed > 0 {
            observed_partial = true;
        }
        last = st.completed;
        if !st.pending {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(observed_partial, "never caught the bank in a partial state");
    assert_eq!(handle.wait().unwrap(), want);
    manager.shutdown();
}

/// A fake worker whose `execute` always answers with a single fidelity,
/// regardless of how many circuits were sent (malformed short payload).
fn short_fids_worker() -> RpcServer {
    let handler: Arc<dyn RpcHandler> =
        Arc::new(|op: &str, _params: &Value| -> Result<Value, DqError> {
            match op {
                "execute" => Ok(Value::obj().with("fids", [0.25f32].as_slice())),
                "ping" => Ok(Value::obj().with("pong", true)),
                other => Err(DqError::Protocol(format!("unexpected {other}"))),
            }
        });
    RpcServer::serve("127.0.0.1:0", handler).unwrap()
}

/// Satellite: a worker returning a malformed/short `fids` payload must
/// surface `DqError::Protocol` to the waiting client — not a hang, and
/// not a requeue loop.
#[test]
fn malformed_worker_payload_surfaces_protocol_error() {
    let manager = Manager::new(ManagerConfig::default());
    let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // Register the fake worker through the real registration RPC, so the
    // manager reaches it over the genuine wire path.
    let fake = short_fids_worker();
    let reg = dqulearn::net::RpcClient::connect(addr.as_str(), Duration::from_secs(2)).unwrap();
    let resp = reg
        .call(
            "register",
            Value::obj()
                .with("max_qubits", 5usize)
                .with("addr", fake.local_addr().to_string())
                .with("cru", 0.0f64)
                .with("threads", 1usize),
        )
        .unwrap();
    assert!(resp.req_u64("worker_id").unwrap() > 0);

    let client = RemoteClient::connect(&addr).unwrap();
    let session = client.session().unwrap();
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs = pairs_for(&cfg, 3, 4);
    let handle = session.submit(cfg, &pairs).unwrap();
    match handle.wait_timeout(Duration::from_secs(20)) {
        Err(DqError::Protocol(msg)) => {
            assert!(msg.contains("3 circuits"), "unexpected message: {msg}")
        }
        other => panic!("expected DqError::Protocol, got {other:?}"),
    }
    manager.shutdown();
}

/// Typed errors round-trip the envelope for every client-facing op.
#[test]
fn rpc_ops_return_typed_errors() {
    let manager = Manager::new(ManagerConfig::default());
    let server = serve_manager(manager.clone(), "127.0.0.1:0").unwrap();
    let rpc =
        dqulearn::net::RpcClient::connect(server.local_addr(), Duration::from_secs(2)).unwrap();

    // bank_status on an unknown bank: Protocol (typed, remote-raised)
    let err = rpc.call("bank_status", Value::obj().with("bank", 999u64)).unwrap_err();
    assert!(matches!(err, DqError::Protocol(_)), "{err}");

    // cancel_bank is idempotent even for unknown banks
    let resp = rpc.call("cancel_bank", Value::obj().with("bank", 999u64)).unwrap();
    assert_eq!(resp.req_usize("drained").unwrap(), 0);

    // submit_bank with a malformed payload: Protocol
    let err = rpc.call("submit_bank", Value::obj().with("client", 1u64)).unwrap_err();
    assert!(matches!(err, DqError::Protocol(_)), "{err}");

    // submit_bank with a bad arity: Arity round-trips
    let bad = dqulearn::cluster::SubmitRequest {
        client: 1,
        config: QuClassiConfig::new(5, 1).unwrap(),
        pairs: vec![(vec![0.0; 2], vec![0.0; 4])], // theta arity wrong
    };
    let err = rpc.call("submit_bank", bad.to_wire()).unwrap_err();
    assert!(matches!(err, DqError::Arity(_)), "{err}");

    // wait_bank with an explicit timeout on a bank that can never finish
    // (no workers): Timeout round-trips
    let ok = dqulearn::cluster::SubmitRequest {
        client: 1,
        config: QuClassiConfig::new(5, 1).unwrap(),
        pairs: vec![(vec![0.0; 4], vec![0.0; 4])],
    };
    let resp = rpc.call("submit_bank", ok.to_wire()).unwrap();
    let bank = dqulearn::cluster::SubmitResponse::from_wire(&resp).unwrap().bank;
    let err = rpc
        .call("wait_bank", Value::obj().with("bank", bank).with("timeout_ms", 50u64))
        .unwrap_err();
    assert!(matches!(err, DqError::Timeout(_)), "{err}");

    manager.shutdown();
}
