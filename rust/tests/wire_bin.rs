//! Property tests for the `wire/bin` binary codec (DESIGN.md §17).
//!
//! Every typed codec round-trips over randomized proto values drawn
//! from the in-repo `testlib` generators, and every decoder rejects
//! malformed input: truncated buffers, trailing garbage, invalid tag
//! bytes, and (at the mux frame layer) arbitrary single-bit flips.
//! The generators deliberately cover the full shape space the JSON
//! codecs accept, so "rejected by one codec ⇔ rejected by the other"
//! stays an enforced invariant, not a doc comment.

use std::collections::BTreeMap;

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::proto::{SubmitRequest, SubmitResponse};
use dqulearn::coordinator::{BankStatus, CircuitJob, ManagerStats, TenantStats};
use dqulearn::net::mux;
use dqulearn::testlib::forall;
use dqulearn::util::stats::{WaitHistogram, WAIT_HIST_BUCKETS};
use dqulearn::util::Rng;
use dqulearn::wire::bin;
use dqulearn::DqError;

// ---------------------------------------------------------------------------
// generators over proto values
// ---------------------------------------------------------------------------

fn gen_config(rng: &mut Rng) -> QuClassiConfig {
    let qubits = [3, 5, 7, 9][rng.index(4)];
    let layers = 1 + rng.index(3);
    QuClassiConfig::new(qubits, layers).unwrap()
}

fn gen_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f32() * 4.0 - 2.0).collect()
}

fn gen_submit_request(rng: &mut Rng) -> SubmitRequest {
    let config = gen_config(rng);
    let n = rng.index(5);
    let pairs = (0..n)
        .map(|_| (gen_f32s(rng, config.n_params()), gen_f32s(rng, config.n_features())))
        .collect();
    SubmitRequest { client: rng.next_u64(), config, pairs }
}

fn gen_bank_status(rng: &mut Rng) -> BankStatus {
    let total = rng.index(9);
    let fids: Vec<Option<f32>> =
        (0..total).map(|_| if rng.f64() < 0.5 { Some(rng.f32()) } else { None }).collect();
    let completed = fids.iter().filter(|f| f.is_some()).count();
    BankStatus {
        pending: completed < total,
        completed,
        total,
        partial_fids: fids,
        recovered: rng.f64() < 0.2,
    }
}

fn gen_tenant_stats(rng: &mut Rng) -> TenantStats {
    let mut counts = [0u64; WAIT_HIST_BUCKETS];
    for c in counts.iter_mut() {
        *c = rng.next_u64() >> 40;
    }
    TenantStats {
        submitted: rng.next_u64() >> 8,
        dispatched: rng.next_u64() >> 8,
        completed: rng.next_u64() >> 8,
        lost: rng.next_u64() >> 32,
        stolen: rng.next_u64() >> 32,
        wait_total_s: rng.range_f64(0.0, 1e6),
        wait_max_s: rng.range_f64(0.0, 1e3),
        wait_hist: WaitHistogram::from_counts(&counts).unwrap(),
    }
}

fn gen_manager_stats(rng: &mut Rng) -> ManagerStats {
    let mut per_tenant = BTreeMap::new();
    for _ in 0..rng.index(5) {
        per_tenant.insert(rng.next_u64() >> 16, gen_tenant_stats(rng));
    }
    ManagerStats {
        submitted: rng.next_u64() >> 8,
        completed: rng.next_u64() >> 8,
        dispatches: rng.next_u64() >> 8,
        requeues: rng.next_u64() >> 32,
        evictions: rng.next_u64() >> 32,
        cancelled: rng.next_u64() >> 32,
        steals: rng.next_u64() >> 32,
        pruned_tenants: rng.next_u64() >> 48,
        retired: gen_tenant_stats(rng),
        per_tenant,
    }
}

fn gen_job(rng: &mut Rng) -> CircuitJob {
    let config = gen_config(rng);
    CircuitJob {
        id: rng.next_u64() >> 8,
        client: rng.next_u64() >> 16,
        bank: rng.next_u64() >> 16,
        index: rng.index(1 << 16),
        config,
        thetas: gen_f32s(rng, config.n_params()),
        data: gen_f32s(rng, config.n_features()),
    }
}

fn gen_string(rng: &mut Rng) -> String {
    const CHARS: &[char] = &['a', 'b', ' ', '0', ':', 'é', '∑', '\n'];
    (0..rng.index(24)).map(|_| CHARS[rng.index(CHARS.len())]).collect()
}

/// Every strict prefix of a top-level encoding must fail to decode —
/// the codecs never accept a torn buffer as a shorter valid value.
fn assert_prefixes_fail<T>(bytes: &[u8], decode: impl Fn(&[u8]) -> Result<T, DqError>) {
    for cut in [0, 1, bytes.len() / 2, bytes.len().saturating_sub(1)] {
        if cut < bytes.len() {
            assert!(decode(&bytes[..cut]).is_err(), "prefix of {cut} bytes decoded");
        }
    }
}

fn eq_dbg<T: std::fmt::Debug>(a: &T, b: &T) -> Result<(), String> {
    let (a, b) = (format!("{a:?}"), format!("{b:?}"));
    if a == b {
        Ok(())
    } else {
        Err(format!("round trip changed the value:\n  sent {a}\n  got  {b}"))
    }
}

// ---------------------------------------------------------------------------
// round trips
// ---------------------------------------------------------------------------

#[test]
fn submit_request_round_trips() {
    forall("bin-submit-request", 0xB1D0, 128, gen_submit_request, |req| {
        let bytes = bin::encode_submit_request(req);
        let back = bin::decode_submit_request(&bytes).map_err(|e| e.to_string())?;
        if back != *req {
            return Err("round trip changed the request".into());
        }
        assert_prefixes_fail(&bytes, bin::decode_submit_request);
        Ok(())
    });
}

#[test]
fn submit_response_round_trips() {
    let gen = |rng: &mut Rng| SubmitResponse { bank: rng.next_u64(), total: rng.index(1 << 20) };
    forall("bin-submit-response", 0xB1D1, 128, gen, |resp| {
        let bytes = bin::encode_submit_response(resp);
        let back = bin::decode_submit_response(&bytes).map_err(|e| e.to_string())?;
        if back != *resp {
            return Err("round trip changed the response".into());
        }
        assert_prefixes_fail(&bytes, bin::decode_submit_response);
        Ok(())
    });
}

#[test]
fn bank_status_round_trips() {
    forall("bin-bank-status", 0xB1D2, 128, gen_bank_status, |status| {
        let bytes = bin::encode_bank_status(status);
        let back = bin::decode_bank_status(&bytes).map_err(|e| e.to_string())?;
        if back != *status {
            return Err("round trip changed the status".into());
        }
        assert_prefixes_fail(&bytes, bin::decode_bank_status);
        Ok(())
    });
}

#[test]
fn tenant_stats_round_trips() {
    let gen = |rng: &mut Rng| (rng.next_u64(), gen_tenant_stats(rng));
    forall("bin-tenant-stats", 0xB1D3, 128, gen, |(client, stats)| {
        let bytes = bin::encode_tenant_stats(*client, stats);
        let (c2, back) = bin::decode_tenant_stats(&bytes).map_err(|e| e.to_string())?;
        if c2 != *client {
            return Err("round trip changed the client id".into());
        }
        eq_dbg(stats, &back)?;
        assert_prefixes_fail(&bytes, bin::decode_tenant_stats);
        Ok(())
    });
}

#[test]
fn manager_stats_round_trips() {
    forall("bin-manager-stats", 0xB1D4, 64, gen_manager_stats, |stats| {
        let bytes = bin::encode_manager_stats(stats);
        let back = bin::decode_manager_stats(&bytes).map_err(|e| e.to_string())?;
        eq_dbg(stats, &back)?;
        assert_prefixes_fail(&bytes, bin::decode_manager_stats);
        Ok(())
    });
}

#[test]
fn jobs_round_trip() {
    let gen = |rng: &mut Rng| -> Vec<CircuitJob> {
        (0..rng.index(5)).map(|_| gen_job(rng)).collect()
    };
    forall("bin-jobs", 0xB1D5, 96, gen, |jobs| {
        let bytes = bin::encode_jobs(jobs);
        let back = bin::decode_jobs(&bytes).map_err(|e| e.to_string())?;
        if back != *jobs {
            return Err("round trip changed the batch".into());
        }
        assert_prefixes_fail(&bytes, bin::decode_jobs);
        Ok(())
    });
}

#[test]
fn fids_round_trip() {
    let gen = |rng: &mut Rng| gen_f32s(rng, rng.index(64));
    forall("bin-fids", 0xB1D6, 128, gen, |fids| {
        let bytes = bin::encode_fids(fids);
        let back = bin::decode_fids(&bytes).map_err(|e| e.to_string())?;
        if back != *fids {
            return Err("round trip changed the fidelities".into());
        }
        assert_prefixes_fail(&bytes, bin::decode_fids);
        Ok(())
    });
}

#[test]
fn errors_round_trip_with_arbitrary_messages() {
    let gen = |rng: &mut Rng| {
        let msg = gen_string(rng);
        match rng.index(7) {
            0 => DqError::Unschedulable(msg),
            1 => DqError::WorkerLost(msg),
            2 => DqError::Timeout(msg),
            3 => DqError::Cancelled(msg),
            4 => DqError::Protocol(msg),
            5 => DqError::Arity(msg),
            _ => DqError::Io(msg),
        }
    };
    forall("bin-error", 0xB1D7, 128, gen, |e| {
        let bytes = bin::encode_error(e);
        let back = bin::decode_error(&bytes).map_err(|x| x.to_string())?;
        if back != *e {
            return Err("round trip changed the error".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// malformed payloads
// ---------------------------------------------------------------------------

#[test]
fn trailing_garbage_is_rejected_by_every_codec() {
    fn rejects_trailing<T>(mut bytes: Vec<u8>, decode: impl Fn(&[u8]) -> Result<T, DqError>) {
        assert!(decode(&bytes).is_ok(), "encoding not self-consistent");
        bytes.push(0x5a);
        assert!(decode(&bytes).is_err(), "codec accepted trailing garbage");
    }

    let mut rng = Rng::new(0xB1D8);
    rejects_trailing(
        bin::encode_submit_request(&gen_submit_request(&mut rng)),
        bin::decode_submit_request,
    );
    rejects_trailing(
        bin::encode_submit_response(&SubmitResponse { bank: 9, total: 4 }),
        bin::decode_submit_response,
    );
    rejects_trailing(bin::encode_bank_status(&gen_bank_status(&mut rng)), bin::decode_bank_status);
    rejects_trailing(
        bin::encode_manager_stats(&gen_manager_stats(&mut rng)),
        bin::decode_manager_stats,
    );
    rejects_trailing(bin::encode_jobs(&[gen_job(&mut rng)]), bin::decode_jobs);
    rejects_trailing(bin::encode_fids(&gen_f32s(&mut rng, 7)), bin::decode_fids);
    rejects_trailing(bin::encode_error(&DqError::Io("x".into())), bin::decode_error);
}

#[test]
fn invalid_tag_bytes_are_rejected() {
    // bool byte other than 0/1 in BankStatus.pending
    let mut rng = Rng::new(0xB1D9);
    let mut bytes = bin::encode_bank_status(&gen_bank_status(&mut rng));
    bytes[0] = 7;
    assert!(bin::decode_bank_status(&bytes).is_err());

    // Option<f32> tag other than 0/1
    let status = BankStatus {
        pending: true,
        completed: 0,
        total: 1,
        partial_fids: vec![None],
        recovered: false,
    };
    let mut bytes = bin::encode_bank_status(&status);
    // layout: pending, completed, total, count, tag — tag is byte 4
    assert_eq!(bytes[4], 0);
    bytes[4] = 2;
    assert!(bin::decode_bank_status(&bytes).is_err());
}

#[test]
fn wrong_histogram_bucket_count_is_rejected() {
    let mut rng = Rng::new(0xB1DA);
    let stats = gen_tenant_stats(&mut rng);
    let good = bin::encode_tenant_stats(3, &stats);
    assert!(bin::decode_tenant_stats(&good).is_ok());

    // Re-encode by hand with one bucket too few: the decoder must
    // reject the count before reading any bucket.
    let mut bad = Vec::new();
    bin::put_varint(&mut bad, 3);
    for v in [stats.submitted, stats.dispatched, stats.completed, stats.lost, stats.stolen] {
        bin::put_varint(&mut bad, v);
    }
    bin::put_f64(&mut bad, stats.wait_total_s);
    bin::put_f64(&mut bad, stats.wait_max_s);
    bin::put_varint(&mut bad, (WAIT_HIST_BUCKETS - 1) as u64);
    for _ in 0..WAIT_HIST_BUCKETS - 1 {
        bin::put_varint(&mut bad, 0);
    }
    assert!(bin::decode_tenant_stats(&bad).is_err());
}

#[test]
fn job_arity_violations_are_rejected_as_arity_errors() {
    let mut rng = Rng::new(0xB1DB);
    let mut job = gen_job(&mut rng);
    job.thetas.push(0.0); // one theta too many for the config
    let bytes = bin::encode_jobs(&[job]);
    match bin::decode_jobs(&bytes) {
        Err(DqError::Arity(_)) => {}
        other => panic!("expected Arity error, got {other:?}"),
    }

    let mut job = gen_job(&mut rng);
    job.data.pop(); // one feature short
    let bytes = bin::encode_jobs(&[job]);
    assert!(matches!(bin::decode_jobs(&bytes), Err(DqError::Arity(_))));
}

// ---------------------------------------------------------------------------
// frame layer: truncation and bit flips
// ---------------------------------------------------------------------------

#[test]
fn frame_truncation_waits_and_bit_flips_never_yield_the_original() {
    let gen = |rng: &mut Rng| {
        let kind = [mux::KIND_REQ, mux::KIND_OK, mux::KIND_ERR][rng.index(3)];
        let corr = rng.next_u64() >> 16;
        let op = (rng.next_u64() & 0xffff) as u32;
        let payload: Vec<u8> = (0..rng.index(48)).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        let flip_at = rng.next_u64();
        (kind, corr, op, payload, flip_at)
    };
    forall("mux-frame-corruption", 0xF7A3, 96, gen, |(kind, corr, op, payload, flip_at)| {
        let wire = mux::encode_frame(*kind, *corr, *op, payload);
        let original = mux::Frame {
            kind: *kind,
            corr: *corr,
            op: if *kind == mux::KIND_REQ { *op } else { 0 },
            payload: payload.clone(),
        };

        // the intact frame parses back exactly, consuming the buffer
        let mut buf = wire.clone();
        match mux::take_frame(&mut buf) {
            Ok(Some(f)) if f == original && buf.is_empty() => {}
            other => return Err(format!("intact frame misparsed: {other:?}")),
        }

        // any strict prefix means "need more bytes", never a frame
        for cut in [0, 4, 8, wire.len() - 1] {
            if cut < wire.len() {
                let mut buf = wire[..cut].to_vec();
                match mux::take_frame(&mut buf) {
                    Ok(None) => {}
                    other => return Err(format!("truncated@{cut} gave {other:?}")),
                }
            }
        }

        // one flipped bit anywhere must not reproduce the original:
        // body flips fail the CRC; length-prefix flips change what the
        // CRC covers or stall waiting for bytes that never come.
        let bit = (*flip_at as usize) % (wire.len() * 8);
        let mut corrupt = wire.clone();
        corrupt[bit / 8] ^= 1 << (bit % 8);
        match mux::take_frame(&mut corrupt) {
            Ok(Some(f)) if f == original => {
                Err(format!("bit {bit} flipped but the original frame decoded"))
            }
            _ => Ok(()),
        }
    });
}

#[test]
fn every_bit_of_a_request_frame_is_covered() {
    // Exhaustive single-bit sweep over one representative REQ frame
    // (the randomized property above samples; this nails every bit).
    let wire = mux::encode_frame(mux::KIND_REQ, 42, bin::OP_EXECUTE, b"payload-bytes");
    let original = mux::take_frame(&mut wire.clone()).unwrap().unwrap();
    for bit in 0..wire.len() * 8 {
        let mut corrupt = wire.clone();
        corrupt[bit / 8] ^= 1 << (bit % 8);
        match mux::take_frame(&mut corrupt) {
            Ok(Some(f)) => assert_ne!(f, original, "bit {bit} undetected"),
            Ok(None) | Err(_) => {}
        }
    }
}
