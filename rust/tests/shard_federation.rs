//! Integration tests for the sharded co-Manager + principal federation
//! (DESIGN.md §18): real backends behind the unified [`ClusterClient`]
//! surface — heterogeneous agents under one principal, registration
//! rebalancing, shard-striped session routing, and tenant-weight
//! durability across a sharded journal recovery.

use std::sync::Arc;
use std::time::Duration;

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cluster::{ClusterClient, InProcCluster, Principal};
use dqulearn::coordinator::{
    Journal, JournalConfig, ManagerConfig, ShardConfig, ShardManager, WorkerChannel, WorkerProfile,
};
use dqulearn::error::DqError;
use dqulearn::model::exec::{CircuitPair, QsimExecutor};
use dqulearn::model::CircuitExecutor;
use dqulearn::util::Rng;

/// Worker channel backed by the reference simulator.
struct SimChannel;

impl WorkerChannel for SimChannel {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        QsimExecutor.execute_bank(config, pairs)
    }
}

fn pairs_for(config: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
    let mut rng = Rng::new(23);
    (0..n)
        .map(|_| {
            (
                (0..config.n_params()).map(|_| rng.f32()).collect(),
                (0..config.n_features()).map(|_| rng.f32()).collect(),
            )
        })
        .collect()
}

/// One principal over two *different* backend shapes (an in-proc cluster
/// and a sharded pool): tenants spread across both, every bank computes
/// the reference result, and the merged stats account for all of it.
#[test]
fn principal_federates_heterogeneous_real_backends() {
    let inproc = InProcCluster::builder().workers(&[12, 12]).build().unwrap();
    let sm = ShardManager::new(ShardConfig { shards: 2, ..ShardConfig::default() });
    for _ in 0..2 {
        sm.register(WorkerProfile::new(12).threads(2), Arc::new(SimChannel));
    }
    let sm_handle = sm.clone();
    let principal = Principal::new(vec![
        ("inproc".to_string(), Arc::new(inproc) as Arc<dyn ClusterClient>),
        ("sharded".to_string(), Arc::new(sm) as Arc<dyn ClusterClient>),
    ]);
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs = pairs_for(&cfg, 4);
    let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
    for _ in 0..6 {
        let session = principal.session();
        let fids = session.execute(cfg, &pairs).unwrap();
        assert_eq!(fids, want, "federated execution diverged from the reference");
    }
    let stats = principal.stats();
    assert_eq!(stats.submitted, 24);
    assert_eq!(stats.completed, 24);
    assert_eq!(principal.worker_count(), 4);
    assert_eq!(principal.failovers(), 0);
    assert!(principal.health().iter().all(|&h| h));
    // round-robin binding must have routed tenants to both agents
    assert!(sm_handle.stats().completed > 0, "sharded agent never served a tenant");
    principal.shutdown();
}

/// Worker registration through the principal lands on the agent with the
/// fewest workers — the federation-level analog of least-populated shard
/// placement.
#[test]
fn principal_registration_lands_on_emptiest_agent() {
    let inproc = InProcCluster::builder().workers(&[12, 12]).build().unwrap();
    let sm = ShardManager::new(ShardConfig { shards: 2, ..ShardConfig::default() });
    let sm_handle = sm.clone();
    let principal = Principal::new(vec![
        ("busy".to_string(), Arc::new(inproc) as Arc<dyn ClusterClient>),
        ("empty".to_string(), Arc::new(sm) as Arc<dyn ClusterClient>),
    ]);
    // The bare sharded pool has 0 workers; both registrations must land
    // there (0 then 1 workers — still fewer than the in-proc agent's 2).
    principal.register(WorkerProfile::new(12), Arc::new(SimChannel)).unwrap();
    principal.register(WorkerProfile::new(12), Arc::new(SimChannel)).unwrap();
    assert_eq!(sm_handle.worker_count(), 2, "registrations did not rebalance");
    assert_eq!(principal.worker_count(), 4);
    principal.shutdown();
}

/// Sessions minted through the trait surface stripe over shards exactly
/// like the inherent API: client ids cover every residue class mod N.
#[test]
fn sharded_sessions_stripe_over_shards() {
    let sm = ShardManager::new(ShardConfig { shards: 2, ..ShardConfig::default() });
    let mut seen = std::collections::HashSet::new();
    for _ in 0..4 {
        seen.insert(ClusterClient::session(&sm).unwrap().id() % 2);
    }
    assert_eq!(seen.len(), 2, "sessions did not spread over both shards");
    sm.shutdown();
}

/// The whole federation is drivable through `&dyn ClusterClient` — the
/// API-unification claim of this layer, principal included.
#[test]
fn cluster_client_covers_principal_over_sharded_pool() {
    let sm = ShardManager::new(ShardConfig { shards: 2, ..ShardConfig::default() });
    for _ in 0..2 {
        sm.register(WorkerProfile::new(12).threads(2), Arc::new(SimChannel));
    }
    let principal =
        Principal::new(vec![("pool".to_string(), Arc::new(sm) as Arc<dyn ClusterClient>)]);
    let cluster: &dyn ClusterClient = &principal;
    assert!(cluster.describe().contains("principal"));
    let session = cluster.session().unwrap();
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs = pairs_for(&cfg, 2);
    assert_eq!(session.execute(cfg, &pairs).unwrap().len(), 2);
    assert_eq!(cluster.stats().unwrap().completed, 2);
    cluster.shutdown();
}

/// Tenant WRR weights journal to the owning shard's segment only and
/// survive a sharded kill-and-replay recovery (DESIGN.md §16 + §18).
#[test]
fn tenant_weights_survive_sharded_recovery() {
    let path =
        std::env::temp_dir().join(format!("dq_fed_weights_{}.log", std::process::id()));
    let seg = |i: usize| {
        let mut p = path.as_os_str().to_owned();
        p.push(format!(".shard{i}"));
        std::path::PathBuf::from(p)
    };
    for i in 0..2 {
        let _ = std::fs::remove_file(seg(i));
    }
    let mk = || ShardConfig {
        shards: 2,
        manager: ManagerConfig { journal: Some(JournalConfig::new(&path)), ..Default::default() },
        ..ShardConfig::default()
    };
    let sm = ShardManager::new(mk());
    for _ in 0..2 {
        sm.register(WorkerProfile::new(12).threads(2), Arc::new(SimChannel));
    }
    // One tenant per shard; the shard-1 tenant gets a WRR weight of 4.
    let c0 = sm.shard(0).new_client();
    let c1 = sm.shard(1).new_client();
    assert_eq!(c0 % 2, 0);
    assert_eq!(c1 % 2, 1);
    sm.set_tenant_weight(c1, 4);
    let cfg = QuClassiConfig::new(5, 1).unwrap();
    let pairs = pairs_for(&cfg, 3);
    for &c in &[c0, c1] {
        let bank = sm.submit_bank(c, cfg, &pairs).unwrap();
        assert_eq!(sm.wait_bank_timeout(bank, Duration::from_secs(30)).unwrap().len(), 3);
    }
    sm.shutdown();
    drop(sm);

    // The weight lives in the owning shard's segment, and only there.
    let (j1, s1) = Journal::recover(&JournalConfig::new(seg(1))).unwrap();
    assert_eq!(s1.weights.get(&c1), Some(&4), "weight lost from shard 1's journal");
    drop(j1);
    let (j0, s0) = Journal::recover(&JournalConfig::new(seg(0))).unwrap();
    assert!(s0.weights.is_empty(), "weight leaked into shard 0's journal");
    drop(j0);

    // A recovered incarnation keeps serving the striped id spaces.
    let (sm2, report) = ShardManager::recover(mk()).unwrap();
    assert_eq!(report.truncated_bytes, 0);
    for _ in 0..2 {
        sm2.register(WorkerProfile::new(12).threads(2), Arc::new(SimChannel));
    }
    let bank = sm2.submit_bank(c1, cfg, &pairs).unwrap();
    assert_eq!(sm2.wait_bank_timeout(bank, Duration::from_secs(30)).unwrap().len(), 3);
    sm2.shutdown();
    for i in 0..2 {
        let _ = std::fs::remove_file(seg(i));
    }
}
