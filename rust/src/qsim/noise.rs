//! Optional noise modeling (extension beyond the paper).
//!
//! The paper's Discussion lists noise-awareness as future work ("our
//! system does not take noise into account when scheduling"). We provide
//! a trajectory-method depolarizing + readout-error model so (a) the
//! noise-aware scheduler ablation has a substrate and (b) accuracy-vs-
//! noise curves can be produced.

use super::gates::Gate;
use super::state::State;
use crate::util::Rng;

/// Per-gate depolarizing probabilities + readout flip probability.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NoiseModel {
    /// Depolarizing probability after each single-qubit gate.
    pub p1: f64,
    /// Depolarizing probability after each two/three-qubit gate (applied
    /// to each operand qubit independently).
    pub p2: f64,
    /// Probability a measured bit is flipped at readout.
    pub readout: f64,
}

impl NoiseModel {
    /// The identity noise model (no error channels).
    pub const NOISELESS: NoiseModel = NoiseModel { p1: 0.0, p2: 0.0, readout: 0.0 };

    /// Typical NISQ-era magnitudes (superconducting-like).
    pub fn nisq() -> NoiseModel {
        NoiseModel { p1: 0.001, p2: 0.01, readout: 0.02 }
    }

    /// True when every channel probability is zero.
    pub fn is_noiseless(&self) -> bool {
        self.p1 == 0.0 && self.p2 == 0.0 && self.readout == 0.0
    }

    /// Apply stochastic Pauli noise after `gate` (trajectory method: with
    /// probability p, apply a uniformly random Pauli to the operand).
    pub fn apply_after(&self, state: &mut State, gate: &Gate, rng: &mut Rng) {
        if self.is_noiseless() {
            return;
        }
        let qubits = gate.qubits();
        let p = if qubits.len() == 1 { self.p1 } else { self.p2 };
        if p == 0.0 {
            return;
        }
        for q in qubits {
            if rng.f64() < p {
                match rng.index(3) {
                    0 => {
                        // X = Ry(pi) * Rz(pi) up to global phase; use dense X
                        state.apply_1q(
                            &[
                                [super::C64::ZERO, super::C64::ONE],
                                [super::C64::ONE, super::C64::ZERO],
                            ],
                            q,
                        );
                    }
                    1 => {
                        // Y
                        state.apply_1q(
                            &[
                                [super::C64::ZERO, super::C64::new(0.0, -1.0)],
                                [super::C64::new(0.0, 1.0), super::C64::ZERO],
                            ],
                            q,
                        );
                    }
                    _ => {
                        // Z
                        state.apply_1q(
                            &[
                                [super::C64::ONE, super::C64::ZERO],
                                [super::C64::ZERO, super::C64::new(-1.0, 0.0)],
                            ],
                            q,
                        );
                    }
                }
            }
        }
    }

    /// Corrupt a sampled probability with readout error: a bit read as 0
    /// stays 0 with prob (1 - readout), and a 1 flips to 0 with prob
    /// readout — in expectation p0' = p0 (1 - r) + (1 - p0) r.
    pub fn corrupt_prob_zero(&self, p0: f64) -> f64 {
        p0 * (1.0 - self.readout) + (1.0 - p0) * self.readout
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_is_identity() {
        let mut s = State::zero(3);
        let before = s.clone();
        let mut rng = Rng::new(1);
        NoiseModel::NOISELESS.apply_after(&mut s, &Gate::H { q: 0 }, &mut rng);
        assert_eq!(s, before);
        assert_eq!(NoiseModel::NOISELESS.corrupt_prob_zero(0.9), 0.9);
    }

    #[test]
    fn noise_preserves_normalization() {
        let mut s = State::zero(4);
        s.apply_h(0);
        s.apply_h(2);
        let nm = NoiseModel { p1: 1.0, p2: 1.0, readout: 0.0 }; // always inject
        let mut rng = Rng::new(2);
        for g in [Gate::H { q: 1 }, Gate::Cx { control: 0, target: 3 }] {
            s.apply_gate(&g);
            nm.apply_after(&mut s, &g, &mut rng);
            assert!((s.norm_sq() - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn readout_error_shrinks_contrast() {
        let nm = NoiseModel { p1: 0.0, p2: 0.0, readout: 0.1 };
        assert!((nm.corrupt_prob_zero(1.0) - 0.9).abs() < 1e-12);
        assert!((nm.corrupt_prob_zero(0.0) - 0.1).abs() < 1e-12);
        assert!((nm.corrupt_prob_zero(0.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn trajectory_noise_decoheres_on_average() {
        // Averaged over many trajectories, a noisy |+> state's swap-test
        // style P0 drifts toward 0.5 relative to noiseless.
        let nm = NoiseModel { p1: 0.5, p2: 0.5, readout: 0.0 };
        let mut rng = Rng::new(3);
        let mut acc = 0.0;
        let trials = 300;
        for _ in 0..trials {
            let mut s = State::zero(1);
            let g = Gate::Ry { q: 0, theta: 0.4 }; // P0 ~ cos^2(0.2) ~ 0.9605
            s.apply_gate(&g);
            nm.apply_after(&mut s, &g, &mut rng);
            acc += s.prob_zero(0);
        }
        let mean = acc / trials as f64;
        let clean = (0.2f64).cos().powi(2);
        assert!(mean < clean - 0.05, "mean={mean} clean={clean}");
    }
}
