//! Compiled-circuit pipeline: parameter-slotted fusion plans, cheap
//! rebinding, and a config-keyed plan cache (DESIGN.md §15).
//!
//! A QuClassi circuit's *structure* — which gates act on which qubits, in
//! which order — depends only on its `QuClassiConfig`; the `(thetas,
//! data)` pair only changes rotation angles. The seed executor rebuilt
//! the `Vec<Gate>` and re-ran the O(gates²) fusion scan for every single
//! circuit. This module splits that work:
//!
//! 1. **Template** ([`CircuitTemplate`]): gates with parameter *slots*
//!    ([`Slot::Theta`] / [`Slot::Data`]) instead of concrete angles.
//! 2. **Plan** ([`CompiledProgram::compile`]): the backward-scan fusion
//!    pass, run once per template. Each fused op records only *which
//!    template gates* feed its product — no matrices yet. Fusion widens
//!    up to 3-qubit (8x8) blocks; CSWAP stays a barrier.
//! 3. **Bind** ([`CompiledProgram::bind`] / [`CompiledProgram::rebind`]):
//!    resolve slots against one `(thetas, data)` pair and fold the small
//!    2x2/4x4/8x8 matrix products. Per circuit this is a few thousand
//!    complex multiplies — the plan scan and the gate-list allocation are
//!    never repeated.
//! 4. **Cache** ([`PlanCache`]): a small LRU keyed by config so every
//!    executor (and every worker in the fleet) compiles each config once
//!    per process.
//!
//! Determinism: [`CompiledProgram::bind`] is implemented as skeleton
//! allocation + [`CompiledProgram::rebind`], and `rebind` recomputes
//! every matrix entry from scratch in factor order — so a cache-hit
//! rebind is bitwise identical to a cold compile-and-bind, and the
//! serial/parallel executors stay bitwise interchangeable.

use std::sync::{Arc, Mutex};

use super::complex::C64;
use super::fusion::{classify, lift_to_pair, mat2_mul, mat4_mul, Kind};
use super::gates::{self, Gate, Mat2, Mat4, Mat8};
use super::state::State;

/// Where a template gate's rotation angle comes from at bind time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    /// `thetas[i]` — a trainable parameter.
    Theta(usize),
    /// `data[i]` — an encoder angle.
    Data(usize),
    /// Fixed at compile time (H, CX, CSWAP, or a frozen angle).
    Fixed,
}

/// A gate whose angle is resolved from a parameter slot at bind time.
/// For slotted gates the embedded angle is a placeholder.
#[derive(Debug, Clone, PartialEq)]
pub struct TemplateGate {
    /// The gate shape (operands; angle ignored unless [`Slot::Fixed`]).
    pub gate: Gate,
    /// Angle source.
    pub slot: Slot,
}

impl TemplateGate {
    /// Resolve the concrete gate for one `(thetas, data)` pair.
    pub fn resolve(&self, thetas: &[f32], data: &[f32]) -> Gate {
        match self.slot {
            Slot::Fixed => self.gate.clone(),
            Slot::Theta(i) => self.gate.with_theta(thetas[i] as f64),
            Slot::Data(i) => self.gate.with_theta(data[i] as f64),
        }
    }
}

/// A parameter-slotted circuit: the reusable structure shared by every
/// `(thetas, data)` pair under one configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct CircuitTemplate {
    /// Width of the statevector the template runs on.
    pub n_qubits: usize,
    /// Slotted gates in application order.
    pub gates: Vec<TemplateGate>,
}

impl CircuitTemplate {
    /// Wrap a concrete gate list as an all-[`Slot::Fixed`] template
    /// (lets ad-hoc gate lists reuse the compiled kernels).
    pub fn from_gates(n_qubits: usize, gate_list: &[Gate]) -> CircuitTemplate {
        CircuitTemplate {
            n_qubits,
            gates: gate_list
                .iter()
                .map(|g| TemplateGate { gate: g.clone(), slot: Slot::Fixed })
                .collect(),
        }
    }

    /// Materialize the concrete gate list for one pair (the seed
    /// `build_quclassi` output, reproduced from the template).
    pub fn instantiate(&self, thetas: &[f32], data: &[f32]) -> Vec<Gate> {
        self.gates.iter().map(|tg| tg.resolve(thetas, data)).collect()
    }
}

/// One step of the fusion plan: either a fused product or a gate applied
/// through normal dispatch.
#[derive(Debug, Clone, PartialEq)]
enum PlanOp {
    /// Product over the sorted support `qs` (1..=3 qubits) of the
    /// template gates `factors` (indices, application order).
    Fused { qs: Vec<usize>, factors: Vec<usize> },
    /// Unfusable gate (CSWAP): applied directly, acts as a barrier.
    Apply { gate_idx: usize },
}

fn op_support(op: &PlanOp, template: &[TemplateGate]) -> Vec<usize> {
    match op {
        PlanOp::Fused { qs, .. } => qs.clone(),
        PlanOp::Apply { gate_idx } => template[*gate_idx].gate.qubits(),
    }
}

fn disjoint(a: &[usize], b: &[usize]) -> bool {
    a.iter().all(|q| !b.contains(q))
}

fn subset(a: &[usize], b: &[usize]) -> bool {
    a.iter().all(|q| b.contains(q))
}

fn sorted_union(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut u: Vec<usize> = a.to_vec();
    for q in b {
        if !u.contains(q) {
            u.push(*q);
        }
    }
    u.sort_unstable();
    u
}

/// What the backward scan decided to do with an earlier op.
enum Scan {
    Skip,
    Stop,
    MergeInPlace,
    Absorb(Vec<usize>),
}

/// Merge template gate `gi` into the plan. Backward-scan rules mirror
/// [`super::fusion`], widened to `max_block` qubits: ops on disjoint
/// supports commute past; a gate whose support is contained in an
/// earlier fused op joins it in place; a support-growing merge removes
/// the earlier op and re-emits the union at the tail — legal only when
/// every op between the merge site and the tail is disjoint from the
/// *union* (otherwise the move would reorder non-commuting ops).
fn push_gate(ops: &mut Vec<PlanOp>, template: &[TemplateGate], gi: usize, max_block: usize) {
    let g = &template[gi].gate;
    if matches!(g, Gate::Cswap { .. }) {
        ops.push(PlanOp::Apply { gate_idx: gi });
        return;
    }
    let mut support = g.qubits();
    support.sort_unstable();
    let mut factors = vec![gi];
    let mut i = ops.len();
    while i > 0 {
        i -= 1;
        let decision = {
            let oqs = op_support(&ops[i], template);
            if disjoint(&oqs, &support) {
                Scan::Skip
            } else {
                match &ops[i] {
                    PlanOp::Apply { .. } => Scan::Stop,
                    PlanOp::Fused { qs, .. } if subset(&support, qs) => Scan::MergeInPlace,
                    PlanOp::Fused { qs, .. } => {
                        let union = sorted_union(qs, &support);
                        let tail_clear = ops[i + 1..]
                            .iter()
                            .all(|o| disjoint(&op_support(o, template), &union));
                        if union.len() <= max_block && tail_clear {
                            Scan::Absorb(union)
                        } else {
                            Scan::Stop
                        }
                    }
                }
            }
        };
        match decision {
            Scan::Skip => continue,
            Scan::Stop => break,
            Scan::MergeInPlace => {
                if let PlanOp::Fused { factors: f, .. } = &mut ops[i] {
                    f.append(&mut factors);
                }
                return;
            }
            Scan::Absorb(union) => {
                if let PlanOp::Fused { factors: mut f, .. } = ops.remove(i) {
                    f.append(&mut factors);
                    factors = f;
                }
                support = union;
            }
        }
    }
    ops.push(PlanOp::Fused { qs: support, factors });
}

/// Plan + template: the per-config compilation product. Compile once,
/// bind per `(thetas, data)` pair.
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledProgram {
    template: CircuitTemplate,
    ops: Vec<PlanOp>,
    max_block: usize,
}

/// Plan shape counters (for benches, logs, and tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanStats {
    /// Template gates the plan was compiled from.
    pub gates_in: usize,
    /// Ops in the compiled plan.
    pub ops_out: usize,
    /// Fused ops over a 3-qubit support (8x8 blocks).
    pub blocks3: usize,
}

impl CompiledProgram {
    /// Compile with the default block width (3-qubit fused blocks).
    pub fn compile(template: CircuitTemplate) -> CompiledProgram {
        Self::compile_with(template, 3)
    }

    /// Compile with an explicit block-width cap (`max_block` in 1..=3;
    /// `2` reproduces the pairwise fusion of [`super::fusion::fuse`]).
    pub fn compile_with(template: CircuitTemplate, max_block: usize) -> CompiledProgram {
        assert!((1..=3).contains(&max_block), "max_block must be 1..=3");
        let mut ops = Vec::with_capacity(template.gates.len());
        for gi in 0..template.gates.len() {
            push_gate(&mut ops, &template.gates, gi, max_block);
        }
        CompiledProgram { template, ops, max_block }
    }

    /// The template this program was compiled from.
    pub fn template(&self) -> &CircuitTemplate {
        &self.template
    }

    /// The block-width cap the plan was compiled with.
    pub fn max_block(&self) -> usize {
        self.max_block
    }

    /// Plan shape counters.
    pub fn stats(&self) -> PlanStats {
        PlanStats {
            gates_in: self.template.gates.len(),
            ops_out: self.ops.len(),
            blocks3: self
                .ops
                .iter()
                .filter(|o| matches!(o, PlanOp::Fused { qs, .. } if qs.len() == 3))
                .count(),
        }
    }

    /// Allocate a bound-program skeleton (identity matrices, placeholder
    /// gates). [`Self::rebind`] fills it in; [`Self::bind`] is exactly
    /// skeleton + rebind, so the two paths cannot diverge.
    pub fn bind_skeleton(&self) -> BoundProgram {
        let ops = self
            .ops
            .iter()
            .map(|op| match op {
                PlanOp::Apply { gate_idx } => {
                    BoundOp::Apply { gate: self.template.gates[*gate_idx].gate.clone() }
                }
                PlanOp::Fused { qs, .. } => match qs.len() {
                    1 => BoundOp::Single { q: qs[0], m: identity2() },
                    2 => BoundOp::Pair { q0: qs[0], q1: qs[1], m: identity4() },
                    _ => BoundOp::Block {
                        qs: [qs[0], qs[1], qs[2]],
                        m: Box::new(identity8()),
                    },
                },
            })
            .collect();
        BoundProgram { n_qubits: self.template.n_qubits, ops }
    }

    /// Bind one `(thetas, data)` pair: resolve every slot and fold the
    /// fused matrix products. Never re-runs the plan scan.
    pub fn bind(&self, thetas: &[f32], data: &[f32]) -> BoundProgram {
        let mut bound = self.bind_skeleton();
        self.rebind(&mut bound, thetas, data);
        bound
    }

    /// Recompute a previously bound program in place for a new pair —
    /// the zero-allocation hot path for serial bank execution.
    pub fn rebind(&self, bound: &mut BoundProgram, thetas: &[f32], data: &[f32]) {
        debug_assert_eq!(bound.ops.len(), self.ops.len(), "skeleton/plan mismatch");
        for (op, slot) in self.ops.iter().zip(bound.ops.iter_mut()) {
            match (op, slot) {
                (PlanOp::Apply { gate_idx }, BoundOp::Apply { gate }) => {
                    *gate = self.template.gates[*gate_idx].resolve(thetas, data);
                }
                (PlanOp::Fused { qs, factors }, BoundOp::Single { m, .. }) => {
                    *m = fold_single(&self.template.gates, factors, thetas, data);
                    debug_assert_eq!(qs.len(), 1);
                }
                (PlanOp::Fused { qs, factors }, BoundOp::Pair { m, .. }) => {
                    *m = fold_pair(&self.template.gates, factors, qs, thetas, data);
                }
                (PlanOp::Fused { qs, factors }, BoundOp::Block { m, .. }) => {
                    fold_block(&self.template.gates, factors, qs, thetas, data, m);
                }
                _ => unreachable!("bound op shape diverged from plan"),
            }
        }
    }
}

fn identity2() -> Mat2 {
    [[C64::ONE, C64::ZERO], [C64::ZERO, C64::ONE]]
}

fn identity4() -> Mat4 {
    let mut m = [[C64::ZERO; 4]; 4];
    for (i, row) in m.iter_mut().enumerate() {
        row[i] = C64::ONE;
    }
    m
}

fn identity8() -> Mat8 {
    let mut m = [[C64::ZERO; 8]; 8];
    for (i, row) in m.iter_mut().enumerate() {
        row[i] = C64::ONE;
    }
    m
}

fn fold_single(
    template: &[TemplateGate],
    factors: &[usize],
    thetas: &[f32],
    data: &[f32],
) -> Mat2 {
    let mut acc = identity2();
    for &gi in factors {
        match classify(&template[gi].resolve(thetas, data)) {
            Kind::One(_, m) => acc = mat2_mul(&m, &acc),
            _ => unreachable!("non-1q factor in a single-qubit fused op"),
        }
    }
    acc
}

fn fold_pair(
    template: &[TemplateGate],
    factors: &[usize],
    qs: &[usize],
    thetas: &[f32],
    data: &[f32],
) -> Mat4 {
    let mut acc = identity4();
    for &gi in factors {
        match classify(&template[gi].resolve(thetas, data)) {
            Kind::One(q, m) => {
                let slot = if q == qs[0] { 0 } else { 1 };
                acc = mat4_mul(&lift_to_pair(&m, slot), &acc);
            }
            Kind::Two(a, _, m) => {
                // Matrix index is 2*b(a) + b(b); reindex when the operand
                // order disagrees with the sorted support.
                let m_ab = if a == qs[0] { m } else { gates::swap_pair_order(&m) };
                acc = mat4_mul(&m_ab, &acc);
            }
            Kind::Other => unreachable!("barrier gate in a fused op"),
        }
    }
    acc
}

fn fold_block(
    template: &[TemplateGate],
    factors: &[usize],
    qs: &[usize],
    thetas: &[f32],
    data: &[f32],
    acc: &mut Mat8,
) {
    *acc = identity8();
    let pos = |q: usize| qs.iter().position(|&x| x == q).expect("factor outside block support");
    for &gi in factors {
        match classify(&template[gi].resolve(thetas, data)) {
            Kind::One(q, m) => mul_lift1_left(acc, &m, pos(q)),
            Kind::Two(a, b, m) => mul_lift2_left(acc, &m, pos(a), pos(b)),
            Kind::Other => unreachable!("barrier gate in a fused op"),
        }
    }
}

/// `acc = LIFT(m) * acc` where the 1q matrix `m` targets block position
/// `p` (block row bit `2 - p`, matching [`State::apply_3q`] indexing).
/// Touches each row pair once — 2x2 work per column instead of an 8x8
/// general multiply.
fn mul_lift1_left(acc: &mut Mat8, m: &Mat2, p: usize) {
    let bit = 1usize << (2 - p);
    for r in 0..8 {
        if r & bit != 0 {
            continue;
        }
        let r1 = r | bit;
        for c in 0..8 {
            let a0 = acc[r][c];
            let a1 = acc[r1][c];
            acc[r][c] = m[0][0] * a0 + m[0][1] * a1;
            acc[r1][c] = m[1][0] * a0 + m[1][1] * a1;
        }
    }
}

/// `acc = LIFT(m) * acc` where the 2q matrix `m`'s operands sit at block
/// positions `p0` (more significant pair bit) and `p1`.
fn mul_lift2_left(acc: &mut Mat8, m: &Mat4, p0: usize, p1: usize) {
    let b0 = 1usize << (2 - p0);
    let b1 = 1usize << (2 - p1);
    for r in 0..8 {
        if r & b0 != 0 || r & b1 != 0 {
            continue;
        }
        let rows = [r, r | b1, r | b0, r | b0 | b1];
        for c in 0..8 {
            let a = [acc[rows[0]][c], acc[rows[1]][c], acc[rows[2]][c], acc[rows[3]][c]];
            for (ri, &row) in rows.iter().enumerate() {
                let mut s = C64::ZERO;
                for (ci, &av) in a.iter().enumerate() {
                    s += m[ri][ci] * av;
                }
                acc[row][c] = s;
            }
        }
    }
}

/// One bound (angle-resolved) operation.
#[derive(Debug, Clone, PartialEq)]
pub enum BoundOp {
    /// Fused 2x2 product on one qubit.
    Single { q: usize, m: Mat2 },
    /// Fused 4x4 product on a sorted qubit pair.
    Pair { q0: usize, q1: usize, m: Mat4 },
    /// Fused 8x8 product on a sorted qubit triple (boxed: keeps the enum
    /// small for the common Single/Pair ops).
    Block { qs: [usize; 3], m: Box<Mat8> },
    /// Unfusable gate through normal dispatch (CSWAP).
    Apply { gate: Gate },
}

/// A fully bound circuit: matrices resolved, ready to apply.
#[derive(Debug, Clone, PartialEq)]
pub struct BoundProgram {
    n_qubits: usize,
    ops: Vec<BoundOp>,
}

impl BoundProgram {
    /// Statevector width.
    pub fn n_qubits(&self) -> usize {
        self.n_qubits
    }

    /// The bound op list (application order).
    pub fn ops(&self) -> &[BoundOp] {
        &self.ops
    }

    /// Apply the whole program to `state`.
    pub fn apply(&self, state: &mut State) {
        for op in &self.ops {
            match op {
                BoundOp::Single { q, m } => state.apply_1q(m, *q),
                BoundOp::Pair { q0, q1, m } => state.apply_2q(m, *q0, *q1),
                BoundOp::Block { qs, m } => state.apply_3q(m, qs[0], qs[1], qs[2]),
                BoundOp::Apply { gate } => state.apply_gate(gate),
            }
        }
    }

    /// Reset `scratch` to |0...0>, run the program, and read the
    /// swap-test fidelity — the per-circuit hot loop of the executors.
    pub fn fidelity_into(&self, scratch: &mut State) -> f64 {
        debug_assert_eq!(scratch.n_qubits(), self.n_qubits);
        scratch.reset_zero();
        self.apply(scratch);
        2.0 * scratch.prob_zero(0) - 1.0
    }

    /// [`Self::fidelity_into`] with a freshly allocated statevector.
    pub fn fidelity(&self) -> f64 {
        let mut st = State::zero(self.n_qubits);
        self.fidelity_into(&mut st)
    }
}

/// Cache observability counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that compiled a fresh program.
    pub misses: u64,
    /// Programs currently cached.
    pub len: usize,
}

struct CacheInner<K> {
    /// LRU order: least recent first, most recent last.
    entries: Vec<(K, Arc<CompiledProgram>)>,
    hits: u64,
    misses: u64,
}

/// A small LRU of compiled programs keyed by circuit configuration.
///
/// Sized for the handful of live configs a tenant mix produces (the
/// paper evaluates six); eviction only means a recompile, never an
/// incorrect result — a key resolves to a program compiled from that
/// key's template alone, so stale-entry invalidation cannot arise.
pub struct PlanCache<K> {
    cap: usize,
    inner: Mutex<CacheInner<K>>,
}

impl<K> std::fmt::Debug for PlanCache<K> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        f.debug_struct("PlanCache")
            .field("cap", &self.cap)
            .field("len", &inner.entries.len())
            .field("hits", &inner.hits)
            .field("misses", &inner.misses)
            .finish()
    }
}

impl<K: Clone + PartialEq> PlanCache<K> {
    /// Cache holding at most `cap` programs (clamped to at least 1).
    pub fn new(cap: usize) -> PlanCache<K> {
        PlanCache {
            cap: cap.max(1),
            inner: Mutex::new(CacheInner { entries: Vec::new(), hits: 0, misses: 0 }),
        }
    }

    /// Fetch the program for `key`, compiling (and caching) on miss.
    pub fn get_or_compile(
        &self,
        key: &K,
        compile: impl FnOnce() -> CompiledProgram,
    ) -> Arc<CompiledProgram> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(i) = inner.entries.iter().position(|(k, _)| k == key) {
            inner.hits += 1;
            // Refresh recency: move to the tail.
            let entry = inner.entries.remove(i);
            let prog = Arc::clone(&entry.1);
            inner.entries.push(entry);
            return prog;
        }
        inner.misses += 1;
        let prog = Arc::new(compile());
        inner.entries.push((key.clone(), Arc::clone(&prog)));
        if inner.entries.len() > self.cap {
            inner.entries.remove(0);
        }
        prog
    }

    /// Current hit/miss/occupancy counters.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        CacheStats { hits: inner.hits, misses: inner.misses, len: inner.entries.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_state(rng: &mut Rng, nq: usize) -> State {
        let mut amps: Vec<C64> =
            (0..1usize << nq).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let norm = amps.iter().map(|a| a.norm_sq()).sum::<f64>().sqrt();
        for a in &mut amps {
            *a = a.scale(1.0 / norm);
        }
        State::from_amps(amps)
    }

    fn assert_close(a: &State, b: &State, tol: f64) {
        for (x, y) in a.amps().iter().zip(b.amps().iter()) {
            assert!(
                (x.re - y.re).abs() < tol && (x.im - y.im).abs() < tol,
                "{x:?} != {y:?}"
            );
        }
    }

    fn check_parity(gate_list: &[Gate], nq: usize, seed: u64) {
        let template = CircuitTemplate::from_gates(nq, gate_list);
        let mut rng = Rng::new(seed);
        for max_block in [1usize, 2, 3] {
            let prog = CompiledProgram::compile_with(template.clone(), max_block);
            let bound = prog.bind(&[], &[]);
            for _ in 0..3 {
                let base = random_state(&mut rng, nq);
                let mut serial = base.clone();
                serial.run(gate_list);
                let mut compiled = base;
                bound.apply(&mut compiled);
                assert_close(&serial, &compiled, 1e-9);
            }
        }
    }

    #[test]
    fn fused_blocks_match_serial_walk() {
        check_parity(
            &[
                Gate::Ry { q: 0, theta: 0.4 },
                Gate::Rz { q: 1, theta: -0.9 },
                Gate::Ryy { q0: 0, q1: 1, theta: 0.7 },
                Gate::Cry { control: 2, target: 1, theta: 1.1 },
                Gate::H { q: 2 },
                Gate::Rzz { q0: 2, q1: 0, theta: -0.3 },
            ],
            3,
            11,
        );
    }

    #[test]
    fn cswap_stays_a_barrier() {
        check_parity(
            &[
                Gate::H { q: 0 },
                Gate::Ry { q: 1, theta: 0.8 },
                Gate::Cswap { control: 0, a: 1, b: 2 },
                Gate::H { q: 0 },
                Gate::Ry { q: 1, theta: -0.8 },
            ],
            3,
            13,
        );
    }

    #[test]
    fn three_qubit_chain_collapses_into_one_block() {
        // (0,1) then (1,2) share only qubit 1: pairwise fusion must keep
        // them apart, 3q fusion must merge them.
        let gate_list = vec![
            Gate::Ryy { q0: 0, q1: 1, theta: 0.3 },
            Gate::Ryy { q0: 1, q1: 2, theta: 0.5 },
            Gate::Crz { control: 0, target: 2, theta: -0.7 },
        ];
        let template = CircuitTemplate::from_gates(3, &gate_list);
        let pairwise = CompiledProgram::compile_with(template.clone(), 2);
        assert_eq!(pairwise.stats().ops_out, 3);
        assert_eq!(pairwise.stats().blocks3, 0);
        let blocked = CompiledProgram::compile_with(template, 3);
        assert_eq!(blocked.stats().ops_out, 1);
        assert_eq!(blocked.stats().blocks3, 1);
        check_parity(&gate_list, 3, 17);
    }

    #[test]
    fn support_growth_respects_intervening_ops() {
        // The Ryy(1,2) wants to absorb the earlier Single(1)-adjacent
        // pair, but H(3)... is disjoint; the blocker is Ry on qubit 2
        // *between* the pair ops in a way that intersects the union.
        let gate_list = vec![
            Gate::Ryy { q0: 0, q1: 1, theta: 0.4 },
            Gate::Cry { control: 1, target: 2, theta: 0.9 },
            Gate::Ryy { q0: 0, q1: 3, theta: -0.6 },
            Gate::Rzz { q0: 2, q1: 3, theta: 1.3 },
        ];
        check_parity(&gate_list, 4, 19);
    }

    #[test]
    fn random_circuits_compiled_parity() {
        let mut rng = Rng::new(29);
        for _ in 0..40 {
            let nq = 3 + rng.index(3);
            let n_gates = 1 + rng.index(18);
            let gate_list = random_gates(&mut rng, nq, n_gates);
            check_parity(&gate_list, nq, rng.next_u64());
        }
    }

    pub(crate) fn random_gates(rng: &mut Rng, nq: usize, n: usize) -> Vec<Gate> {
        (0..n)
            .map(|_| {
                let theta = rng.range_f64(-3.0, 3.0);
                let q = rng.index(nq);
                let mut q1 = rng.index(nq);
                while q1 == q {
                    q1 = rng.index(nq);
                }
                match rng.below(8) {
                    0 => Gate::H { q },
                    1 => Gate::Rx { q, theta },
                    2 => Gate::Ry { q, theta },
                    3 => Gate::Rz { q, theta },
                    4 => Gate::Ryy { q0: q, q1, theta },
                    5 => Gate::Rzz { q0: q, q1, theta },
                    6 => Gate::Cry { control: q, target: q1, theta },
                    _ => {
                        if nq >= 3 && rng.below(3) == 0 {
                            let mut q2 = rng.index(nq);
                            while q2 == q || q2 == q1 {
                                q2 = rng.index(nq);
                            }
                            Gate::Cswap { control: q, a: q1, b: q2 }
                        } else {
                            Gate::Crz { control: q, target: q1, theta }
                        }
                    }
                }
            })
            .collect()
    }

    #[test]
    fn rebind_is_bitwise_identical_to_fresh_bind() {
        let gate_list = vec![
            Gate::Ry { q: 0, theta: 0.0 },
            Gate::Ryy { q0: 0, q1: 1, theta: 0.0 },
            Gate::Cry { control: 1, target: 2, theta: 0.0 },
        ];
        let mut template = CircuitTemplate::from_gates(3, &gate_list);
        template.gates[0].slot = Slot::Theta(0);
        template.gates[1].slot = Slot::Theta(1);
        template.gates[2].slot = Slot::Data(0);
        let prog = CompiledProgram::compile(template);
        let mut reused = prog.bind(&[9.9, -9.9], &[9.9]);
        for pair in [([0.3f32, -0.7], [1.1f32]), ([2.0, 0.1], [-0.4]), ([0.0, 0.0], [0.0])] {
            prog.rebind(&mut reused, &pair.0, &pair.1);
            let fresh = prog.bind(&pair.0, &pair.1);
            assert_eq!(reused, fresh);
        }
    }

    #[test]
    fn template_slots_resolve_against_both_vectors() {
        let tg = TemplateGate { gate: Gate::Ry { q: 1, theta: 0.0 }, slot: Slot::Theta(2) };
        assert_eq!(tg.resolve(&[0.0, 0.0, 1.5], &[]), Gate::Ry { q: 1, theta: 1.5 });
        let dg = TemplateGate { gate: Gate::Rz { q: 2, theta: 0.0 }, slot: Slot::Data(0) };
        assert_eq!(dg.resolve(&[], &[-0.25]), Gate::Rz { q: 2, theta: -0.25 });
        let fixed = TemplateGate { gate: Gate::H { q: 0 }, slot: Slot::Fixed };
        assert_eq!(fixed.resolve(&[], &[]), Gate::H { q: 0 });
    }

    #[test]
    fn plan_cache_hits_and_evicts() {
        let cache: PlanCache<usize> = PlanCache::new(2);
        let compile_for = |nq: usize| {
            let gate_list = vec![Gate::H { q: 0 }];
            CompiledProgram::compile(CircuitTemplate::from_gates(nq, &gate_list))
        };
        let a = cache.get_or_compile(&3, || compile_for(3));
        let b = cache.get_or_compile(&3, || compile_for(3));
        assert!(Arc::ptr_eq(&a, &b), "cache hit must return the same program");
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.stats().misses, 1);
        cache.get_or_compile(&4, || compile_for(4));
        cache.get_or_compile(&5, || compile_for(5)); // evicts key 3 (LRU)
        assert_eq!(cache.stats().len, 2);
        let c = cache.get_or_compile(&3, || compile_for(3));
        assert!(!Arc::ptr_eq(&a, &c), "evicted key must recompile");
        assert_eq!(cache.stats().misses, 4);
    }

    #[test]
    fn lift_multiplies_match_general_embedding() {
        // LIFT checks via action on a random 3-qubit state: folding a
        // 1q/2q gate into an identity block and applying the block must
        // equal applying the gate directly.
        let mut rng = Rng::new(37);
        for _ in 0..20 {
            let base = random_state(&mut rng, 3);
            let theta = rng.range_f64(-3.0, 3.0);
            // 1q lift on each position
            for (p, q) in [(0usize, 0usize), (1, 1), (2, 2)] {
                let mut block = identity8();
                mul_lift1_left(&mut block, &gates::ry_matrix(theta), p);
                let mut via_block = base.clone();
                via_block.apply_3q(&block, 0, 1, 2);
                let mut direct = base.clone();
                direct.apply_1q(&gates::ry_matrix(theta), q);
                assert_close(&via_block, &direct, 1e-12);
            }
            // 2q lift on each ordered operand placement
            for (p0, p1) in [(0usize, 1usize), (1, 2), (0, 2), (1, 0), (2, 0), (2, 1)] {
                let mut block = identity8();
                mul_lift2_left(&mut block, &gates::cry_matrix(theta), p0, p1);
                let mut via_block = base.clone();
                via_block.apply_3q(&block, 0, 1, 2);
                let mut direct = base.clone();
                direct.apply_gate(&Gate::Cry { control: p0, target: p1, theta });
                assert_close(&via_block, &direct, 1e-12);
            }
        }
    }

    #[test]
    fn instantiate_round_trips_fixed_gates() {
        let gate_list = vec![
            Gate::H { q: 0 },
            Gate::Cry { control: 0, target: 1, theta: 0.5 },
            Gate::Cswap { control: 0, a: 1, b: 2 },
        ];
        let template = CircuitTemplate::from_gates(3, &gate_list);
        assert_eq!(template.instantiate(&[], &[]), gate_list);
    }
}
