//! From-scratch statevector quantum simulator (the "quantum worker"
//! device substrate).
//!
//! The paper's quantum workers are Qiskit simulators; ours are (a) the
//! AOT-compiled JAX/Pallas artifacts executed via PJRT (`runtime/`) and
//! (b) this pure-Rust simulator, which serves as the fallback executor
//! for circuit shapes without an artifact, the cross-check oracle for the
//! PJRT path, and the shot-sampling backend (the artifacts compute exact
//! expectations; sampled measurement lives here).
//!
//! Conventions match `python/compile/kernels/ref.py` exactly: big-endian
//! qubit indexing (qubit 0 = most significant index bit), identical gate
//! definitions, identical QuClassi register layout.
//!
//! Two execution paths exist on top of [`state::State`]: the serial
//! gate-by-gate walk ([`State::run`]) and the fused path
//! ([`fusion::fuse`] + [`FusedProgram::apply`]), which coalesces runs of
//! adjacent one/two-qubit gates into single matrices. [`shots::run_shots`]
//! builds on the fused path to fan measurement shots across an internal
//! thread pool with deterministic per-chunk RNG streams (DESIGN.md §11).

pub mod complex;
pub mod fusion;
pub mod gates;
pub mod measure;
pub mod noise;
pub mod shots;
pub mod state;

pub use complex::C64;
pub use fusion::{fuse, FusedOp, FusedProgram};
pub use measure::{sample_shots, swap_test_fidelity};
pub use noise::NoiseModel;
pub use shots::run_shots;
pub use state::State;
