//! From-scratch statevector quantum simulator (the "quantum worker"
//! device substrate).
//!
//! The paper's quantum workers are Qiskit simulators; ours are (a) the
//! AOT-compiled JAX/Pallas artifacts executed via PJRT (`runtime/`) and
//! (b) this pure-Rust simulator, which serves as the fallback executor
//! for circuit shapes without an artifact, the cross-check oracle for the
//! PJRT path, and the shot-sampling backend (the artifacts compute exact
//! expectations; sampled measurement lives here).
//!
//! Conventions match `python/compile/kernels/ref.py` exactly: big-endian
//! qubit indexing (qubit 0 = most significant index bit), identical gate
//! definitions, identical QuClassi register layout.
//!
//! Three execution paths exist on top of [`state::State`]: the serial
//! gate-by-gate walk ([`State::run`]), the fused path ([`fusion::fuse`]
//! + [`FusedProgram::apply`]), which coalesces runs of adjacent
//! one/two-qubit gates into single matrices, and the compiled path
//! ([`compile::CompiledProgram`]), which runs the fusion plan once per
//! circuit *structure*, widens fusion to 3-qubit (8x8) blocks, and
//! rebinds parameters per circuit without re-planning — cached per
//! config via [`compile::PlanCache`] (DESIGN.md §15). The executors
//! (`model::exec`, `worker::backend`) all route through the compiled
//! path; [`shots::run_shots`] compiles once and fans measurement shots
//! across an internal thread pool with deterministic per-chunk RNG
//! streams (DESIGN.md §11).

pub mod compile;
pub mod complex;
pub mod fusion;
pub mod gates;
pub mod measure;
pub mod noise;
pub mod shots;
pub mod state;

pub use compile::{
    BoundOp, BoundProgram, CacheStats, CircuitTemplate, CompiledProgram, PlanCache, PlanStats,
    Slot, TemplateGate,
};
pub use complex::C64;
pub use fusion::{fuse, FusedOp, FusedProgram};
pub use measure::{sample_shots, swap_test_fidelity};
pub use noise::NoiseModel;
pub use shots::{run_shots, sample_state};
pub use state::State;
