//! Statevector storage and gate application.
//!
//! Big-endian qubit indexing: the amplitude index of basis state
//! `|b_0 b_1 ... b_{q-1}>` is `sum_k b_k * 2^(q-1-k)` — identical to the
//! Python oracle. Gate application walks the amplitude array with bit
//! strides; specialized fast paths exist for the gates on the training
//! hot path (Ry/Rz/H/CSWAP), with the generic dense 2x2/4x4 path as the
//! reference for everything else.

use super::complex::C64;
use super::gates::{self, Gate, Mat2, Mat4};

/// A statevector over `n_qubits`.
#[derive(Debug, Clone, PartialEq)]
pub struct State {
    n_qubits: usize,
    amps: Vec<C64>,
}

impl State {
    /// |0...0>
    pub fn zero(n_qubits: usize) -> State {
        assert!(n_qubits <= 26, "statevector would exceed memory");
        let mut amps = vec![C64::ZERO; 1 << n_qubits];
        amps[0] = C64::ONE;
        State { n_qubits, amps }
    }

    /// Construct from raw amplitudes (must be a power-of-two length).
    pub fn from_amps(amps: Vec<C64>) -> State {
        assert!(amps.len().is_power_of_two() && !amps.is_empty());
        let n_qubits = amps.len().trailing_zeros() as usize;
        State { n_qubits, amps }
    }

    /// Number of qubits.
    pub fn n_qubits(&self) -> usize {
        self.n_qubits
    }

    /// The raw amplitude array (length `2^n_qubits`).
    pub fn amps(&self) -> &[C64] {
        &self.amps
    }

    /// Sum of |amp|^2 (1.0 for a normalized state).
    pub fn norm_sq(&self) -> f64 {
        self.amps.iter().map(|a| a.norm_sq()).sum()
    }

    /// Stride of `qubit` in the amplitude index (big-endian).
    #[inline]
    fn stride(&self, qubit: usize) -> usize {
        debug_assert!(qubit < self.n_qubits);
        1 << (self.n_qubits - 1 - qubit)
    }

    /// Apply a dense single-qubit matrix.
    pub fn apply_1q(&mut self, m: &Mat2, qubit: usize) {
        let stride = self.stride(qubit);
        let n = self.amps.len();
        let mut base = 0;
        while base < n {
            for off in 0..stride {
                let i0 = base + off;
                let i1 = i0 + stride;
                let a0 = self.amps[i0];
                let a1 = self.amps[i1];
                self.amps[i0] = m[0][0] * a0 + m[0][1] * a1;
                self.amps[i1] = m[1][0] * a0 + m[1][1] * a1;
            }
            base += stride * 2;
        }
    }

    /// Apply a dense two-qubit matrix to the ordered pair (q0, q1).
    /// The matrix row/column index is `2*b(q0) + b(q1)`.
    ///
    /// Base indices (both pair bits clear) are enumerated directly with
    /// three nested strided loops — `2^(n-2)` iterations instead of the
    /// `2^n` filtered scan of [`State::apply_2q_masked`] — visiting the
    /// same bases in the same ascending order, so results are bitwise
    /// identical to the masked scan.
    pub fn apply_2q(&mut self, m: &Mat4, q0: usize, q1: usize) {
        assert_ne!(q0, q1);
        // Normalize so s0 > s1 (q0 more significant in the pair index).
        let (s0, s1, m_owned);
        if q0 < q1 {
            s0 = self.stride(q0);
            s1 = self.stride(q1);
            m_owned = *m;
        } else {
            s0 = self.stride(q1);
            s1 = self.stride(q0);
            m_owned = gates::swap_pair_order(m);
        }
        let m = &m_owned;
        let n = self.amps.len();
        // b0 walks regions with the s0 bit clear; b1 walks s1-clear
        // sub-regions; the innermost range is a contiguous run of
        // low-order offsets (cache-friendly unit stride).
        let mut b0 = 0;
        while b0 < n {
            let mut b1 = b0;
            while b1 < b0 + s0 {
                for base in b1..b1 + s1 {
                    let i00 = base;
                    let i01 = base | s1;
                    let i10 = base | s0;
                    let i11 = base | s0 | s1;
                    let a = [self.amps[i00], self.amps[i01], self.amps[i10], self.amps[i11]];
                    for (r, &idx) in [i00, i01, i10, i11].iter().enumerate() {
                        let mut acc = C64::ZERO;
                        for (c, &ac) in a.iter().enumerate() {
                            acc += m[r][c] * ac;
                        }
                        self.amps[idx] = acc;
                    }
                }
                b1 += 2 * s1;
            }
            b0 += 2 * s0;
        }
    }

    /// The seed implementation of [`State::apply_2q`]: scan all `2^n`
    /// indices and mask-filter for clear pair bits. Kept as the kernel
    /// oracle for tests and the ablation baseline for `micro_qsim`.
    pub fn apply_2q_masked(&mut self, m: &Mat4, q0: usize, q1: usize) {
        assert_ne!(q0, q1);
        let (s0, s1, m_owned);
        if q0 < q1 {
            s0 = self.stride(q0);
            s1 = self.stride(q1);
            m_owned = *m;
        } else {
            s0 = self.stride(q1);
            s1 = self.stride(q0);
            m_owned = gates::swap_pair_order(m);
        }
        let m = &m_owned;
        let n = self.amps.len();
        // Enumerate all indices with both pair bits clear.
        let mut i = 0;
        while i < n {
            if (i & s0) == 0 && (i & s1) == 0 {
                let i00 = i;
                let i01 = i | s1;
                let i10 = i | s0;
                let i11 = i | s0 | s1;
                let a = [self.amps[i00], self.amps[i01], self.amps[i10], self.amps[i11]];
                for (r, &idx) in [i00, i01, i10, i11].iter().enumerate() {
                    let mut acc = C64::ZERO;
                    for (c, &ac) in a.iter().enumerate() {
                        acc += m[r][c] * ac;
                    }
                    self.amps[idx] = acc;
                }
            }
            i += 1;
        }
    }

    /// Apply a dense three-qubit matrix to the sorted triple
    /// `q0 < q1 < q2`. The matrix row/column index is
    /// `4*b(q0) + 2*b(q1) + b(q2)` — the fused-block convention of
    /// `qsim::compile`. Enumerates the `2^(n-3)` base indices directly
    /// with the same cache-blocked loop layout as [`State::apply_2q`]
    /// (reference: `python/compile/kernels/statevector.py`).
    pub fn apply_3q(&mut self, m: &gates::Mat8, q0: usize, q1: usize, q2: usize) {
        assert!(q0 < q1 && q1 < q2, "apply_3q expects sorted distinct qubits");
        let s0 = self.stride(q0);
        let s1 = self.stride(q1);
        let s2 = self.stride(q2);
        let n = self.amps.len();
        let mut b0 = 0;
        while b0 < n {
            let mut b1 = b0;
            while b1 < b0 + s0 {
                let mut b2 = b1;
                while b2 < b1 + s1 {
                    for base in b2..b2 + s2 {
                        let idx = [
                            base,
                            base | s2,
                            base | s1,
                            base | s1 | s2,
                            base | s0,
                            base | s0 | s2,
                            base | s0 | s1,
                            base | s0 | s1 | s2,
                        ];
                        let mut a = [C64::ZERO; 8];
                        for (k, &i) in idx.iter().enumerate() {
                            a[k] = self.amps[i];
                        }
                        for (r, &i) in idx.iter().enumerate() {
                            let mut acc = C64::ZERO;
                            for (c, &ac) in a.iter().enumerate() {
                                acc += m[r][c] * ac;
                            }
                            self.amps[i] = acc;
                        }
                    }
                    b2 += 2 * s2;
                }
                b1 += 2 * s1;
            }
            b0 += 2 * s0;
        }
    }

    /// Reset to |0...0> in place (no reallocation) — bitwise identical
    /// to a fresh [`State::zero`] of the same width. The scratch-state
    /// reset of the compiled executor hot loop.
    pub fn reset_zero(&mut self) {
        for a in &mut self.amps {
            *a = C64::ZERO;
        }
        self.amps[0] = C64::ONE;
    }

    /// Fast path: Ry (real 2x2 rotation).
    pub fn apply_ry(&mut self, theta: f64, qubit: usize) {
        let c = (theta / 2.0).cos();
        let s = (theta / 2.0).sin();
        let stride = self.stride(qubit);
        let n = self.amps.len();
        let mut base = 0;
        while base < n {
            for off in 0..stride {
                let i0 = base + off;
                let i1 = i0 + stride;
                let a0 = self.amps[i0];
                let a1 = self.amps[i1];
                self.amps[i0] = C64::new(c * a0.re - s * a1.re, c * a0.im - s * a1.im);
                self.amps[i1] = C64::new(s * a0.re + c * a1.re, s * a0.im + c * a1.im);
            }
            base += stride * 2;
        }
    }

    /// Fast path: Rz (diagonal phases).
    pub fn apply_rz(&mut self, theta: f64, qubit: usize) {
        let em = C64::cis(-theta / 2.0);
        let ep = C64::cis(theta / 2.0);
        let stride = self.stride(qubit);
        let n = self.amps.len();
        let mut base = 0;
        while base < n {
            for off in 0..stride {
                let i0 = base + off;
                let i1 = i0 + stride;
                self.amps[i0] *= em;
                self.amps[i1] *= ep;
            }
            base += stride * 2;
        }
    }

    /// Fast path: Hadamard.
    pub fn apply_h(&mut self, qubit: usize) {
        let inv = gates::INV_SQRT2;
        let stride = self.stride(qubit);
        let n = self.amps.len();
        let mut base = 0;
        while base < n {
            for off in 0..stride {
                let i0 = base + off;
                let i1 = i0 + stride;
                let a0 = self.amps[i0];
                let a1 = self.amps[i1];
                self.amps[i0] = (a0 + a1).scale(inv);
                self.amps[i1] = (a0 - a1).scale(inv);
            }
            base += stride * 2;
        }
    }

    /// Fast path: CSWAP via amplitude swaps where the control bit is set.
    pub fn apply_cswap(&mut self, control: usize, a: usize, b: usize) {
        assert!(control != a && control != b && a != b);
        let sc = self.stride(control);
        let sa = self.stride(a);
        let sb = self.stride(b);
        let n = self.amps.len();
        for i in 0..n {
            // visit each swapped pair once: control set, bit_a=1, bit_b=0
            if (i & sc) != 0 && (i & sa) != 0 && (i & sb) == 0 {
                let j = (i & !sa) | sb;
                self.amps.swap(i, j);
            }
        }
    }

    /// Apply any IR gate (dispatches to fast paths where available).
    pub fn apply_gate(&mut self, g: &Gate) {
        match *g {
            Gate::H { q } => self.apply_h(q),
            Gate::Rx { q, theta } => self.apply_1q(&gates::rx_matrix(theta), q),
            Gate::Ry { q, theta } => self.apply_ry(theta, q),
            Gate::Rz { q, theta } => self.apply_rz(theta, q),
            Gate::Ryy { q0, q1, theta } => self.apply_2q(&gates::ryy_matrix(theta), q0, q1),
            Gate::Rzz { q0, q1, theta } => self.apply_2q(&gates::rzz_matrix(theta), q0, q1),
            Gate::Cry { control, target, theta } => {
                self.apply_2q(&gates::cry_matrix(theta), control, target)
            }
            Gate::Crz { control, target, theta } => {
                self.apply_2q(&gates::crz_matrix(theta), control, target)
            }
            Gate::Cx { control, target } => self.apply_2q(&gates::cx_matrix(), control, target),
            Gate::Cswap { control, a, b } => self.apply_cswap(control, a, b),
        }
    }

    /// Run a gate sequence.
    pub fn run(&mut self, gates: &[Gate]) {
        for g in gates {
            self.apply_gate(g);
        }
    }

    /// Probability that `qubit` measures |0>.
    pub fn prob_zero(&self, qubit: usize) -> f64 {
        let stride = self.stride(qubit);
        let mut p = 0.0;
        let n = self.amps.len();
        let mut base = 0;
        while base < n {
            for off in 0..stride {
                p += self.amps[base + off].norm_sq();
            }
            base += stride * 2;
        }
        p
    }

    /// |<self|other>|^2 (exact state fidelity; for tests).
    pub fn overlap_sq(&self, other: &State) -> f64 {
        assert_eq!(self.n_qubits, other.n_qubits);
        let mut acc = C64::ZERO;
        for (a, b) in self.amps.iter().zip(other.amps.iter()) {
            acc += a.conj() * *b;
        }
        acc.norm_sq()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_state(rng: &mut Rng, nq: usize) -> State {
        let mut amps: Vec<C64> =
            (0..1usize << nq).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let norm = amps.iter().map(|a| a.norm_sq()).sum::<f64>().sqrt();
        for a in &mut amps {
            *a = a.scale(1.0 / norm);
        }
        State::from_amps(amps)
    }

    #[test]
    fn zero_state_is_normalized() {
        let s = State::zero(5);
        assert_eq!(s.amps()[0], C64::ONE);
        assert!((s.norm_sq() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fast_paths_match_dense() {
        let mut rng = Rng::new(17);
        for nq in 2..=5 {
            for q in 0..nq {
                let base = random_state(&mut rng, nq);
                let theta = rng.range_f64(-3.0, 3.0);

                let mut fast = base.clone();
                fast.apply_ry(theta, q);
                let mut dense = base.clone();
                dense.apply_1q(&gates::ry_matrix(theta), q);
                assert_states_eq(&fast, &dense);

                let mut fast = base.clone();
                fast.apply_rz(theta, q);
                let mut dense = base.clone();
                dense.apply_1q(&gates::rz_matrix(theta), q);
                assert_states_eq(&fast, &dense);

                let mut fast = base.clone();
                fast.apply_h(q);
                let mut dense = base.clone();
                dense.apply_1q(&gates::h_matrix(), q);
                assert_states_eq(&fast, &dense);
            }
        }
    }

    fn assert_states_eq(a: &State, b: &State) {
        for (x, y) in a.amps().iter().zip(b.amps().iter()) {
            assert!((x.re - y.re).abs() < 1e-12 && (x.im - y.im).abs() < 1e-12, "{x:?} != {y:?}");
        }
    }

    #[test]
    fn gates_preserve_norm() {
        let mut rng = Rng::new(23);
        let gates_list = vec![
            Gate::H { q: 1 },
            Gate::Rx { q: 0, theta: 0.3 },
            Gate::Ry { q: 2, theta: -1.0 },
            Gate::Rz { q: 3, theta: 2.2 },
            Gate::Ryy { q0: 0, q1: 2, theta: 0.9 },
            Gate::Rzz { q0: 1, q1: 3, theta: -0.4 },
            Gate::Cry { control: 0, target: 3, theta: 1.4 },
            Gate::Crz { control: 3, target: 0, theta: -2.0 },
            Gate::Cx { control: 1, target: 2 },
            Gate::Cswap { control: 0, a: 1, b: 3 },
        ];
        let mut s = random_state(&mut rng, 4);
        for g in &gates_list {
            s.apply_gate(g);
            assert!((s.norm_sq() - 1.0).abs() < 1e-10, "{g:?} broke normalization");
        }
    }

    #[test]
    fn cx_truth_table() {
        // |10> --CX(0,1)--> |11>
        let mut s = State::zero(2);
        s.apply_1q(&gates::ry_matrix(std::f64::consts::PI), 0); // |0> -> |1>
        s.apply_gate(&Gate::Cx { control: 0, target: 1 });
        assert!((s.amps()[3].norm_sq() - 1.0).abs() < 1e-12); // |11>
    }

    #[test]
    fn cswap_truth_table() {
        // |1;01> --CSWAP(0;1,2)--> |1;10>
        let mut s = State::zero(3);
        s.apply_1q(&gates::ry_matrix(std::f64::consts::PI), 0);
        s.apply_1q(&gates::ry_matrix(std::f64::consts::PI), 2);
        // state |101> = index 5
        assert!((s.amps()[5].norm_sq() - 1.0).abs() < 1e-12);
        s.apply_cswap(0, 1, 2);
        // -> |110> = index 6
        assert!((s.amps()[6].norm_sq() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cswap_ignores_control_zero() {
        let mut s = State::zero(3);
        s.apply_1q(&gates::ry_matrix(std::f64::consts::PI), 2); // |001>
        let before = s.clone();
        s.apply_cswap(0, 1, 2);
        assert_states_eq(&s, &before);
    }

    #[test]
    fn blocked_apply_2q_bitwise_matches_masked_scan() {
        let mut rng = Rng::new(53);
        for nq in 2..=6 {
            for _ in 0..8 {
                let q0 = rng.index(nq);
                let mut q1 = rng.index(nq);
                while q1 == q0 {
                    q1 = rng.index(nq);
                }
                let theta = rng.range_f64(-3.0, 3.0);
                let m = gates::ryy_matrix(theta);
                let base = random_state(&mut rng, nq);
                let mut blocked = base.clone();
                blocked.apply_2q(&m, q0, q1);
                let mut masked = base;
                masked.apply_2q_masked(&m, q0, q1);
                // bitwise: the loop layouts visit identical bases in
                // identical order with identical arithmetic
                assert_eq!(blocked, masked, "nq={nq} q0={q0} q1={q1}");
            }
        }
    }

    #[test]
    fn apply_3q_matches_composed_small_gates() {
        // A block built as kron-lifts of CRY(0,1) then Rzz(1,2) must act
        // like applying the two gates in sequence.
        let mut rng = Rng::new(59);
        for _ in 0..6 {
            let (ta, tb) = (rng.range_f64(-3.0, 3.0), rng.range_f64(-3.0, 3.0));
            let base = random_state(&mut rng, 5);
            // build the 8x8 by probing basis columns through the 2q ops
            let mut block = [[C64::ZERO; 8]; 8];
            for col in 0..8 {
                let mut amps = vec![C64::ZERO; 8];
                amps[col] = C64::ONE;
                let mut probe = State::from_amps(amps);
                probe.apply_2q(&gates::cry_matrix(ta), 0, 1);
                probe.apply_2q(&gates::rzz_matrix(tb), 1, 2);
                for (r, row) in block.iter_mut().enumerate() {
                    row[col] = probe.amps()[r];
                }
            }
            // apply on non-adjacent qubits of a larger register too
            for (q0, q1, q2) in [(0usize, 1usize, 2usize), (1, 3, 4), (0, 2, 4)] {
                let mut via_block = base.clone();
                via_block.apply_3q(&block, q0, q1, q2);
                let mut direct = base.clone();
                direct.apply_2q(&gates::cry_matrix(ta), q0, q1);
                direct.apply_2q(&gates::rzz_matrix(tb), q1, q2);
                for (x, y) in via_block.amps().iter().zip(direct.amps().iter()) {
                    assert!(
                        (x.re - y.re).abs() < 1e-12 && (x.im - y.im).abs() < 1e-12,
                        "({q0},{q1},{q2}): {x:?} != {y:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn reset_zero_equals_fresh_state() {
        let mut rng = Rng::new(61);
        let mut s = random_state(&mut rng, 4);
        s.apply_h(2);
        s.reset_zero();
        assert_eq!(s, State::zero(4));
    }

    #[test]
    fn two_qubit_reversed_operands() {
        // CRY(control=2, target=0) == dense with swapped pair order.
        let mut rng = Rng::new(31);
        let base = random_state(&mut rng, 3);
        let theta = 0.77;
        let mut a = base.clone();
        a.apply_gate(&Gate::Cry { control: 2, target: 0, theta });
        let mut b = base.clone();
        b.apply_2q(&gates::swap_pair_order(&gates::cry_matrix(theta)), 0, 2);
        assert_states_eq(&a, &b);
    }

    #[test]
    fn prob_zero_basis() {
        let mut s = State::zero(3);
        assert!((s.prob_zero(0) - 1.0).abs() < 1e-12);
        s.apply_1q(&gates::ry_matrix(std::f64::consts::PI), 0);
        assert!(s.prob_zero(0).abs() < 1e-12);
        assert!((s.prob_zero(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rotation_composition() {
        // Ry(a) then Ry(b) == Ry(a + b)
        let mut rng = Rng::new(41);
        let base = random_state(&mut rng, 2);
        let (a, b) = (0.6, -1.3);
        let mut s1 = base.clone();
        s1.apply_ry(a, 1);
        s1.apply_ry(b, 1);
        let mut s2 = base.clone();
        s2.apply_ry(a + b, 1);
        assert_states_eq(&s1, &s2);
    }

    #[test]
    fn overlap_of_identical_states_is_one() {
        let mut rng = Rng::new(43);
        let s = random_state(&mut rng, 4);
        assert!((s.overlap_sq(&s) - 1.0).abs() < 1e-10);
    }
}
