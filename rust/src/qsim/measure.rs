//! Measurement: exact expectations, shot sampling, swap-test readout.

use super::state::State;
use crate::util::Rng;

/// Exact swap-test fidelity readout: `2 * P(ancilla=0) - 1`, where the
/// ancilla is qubit 0 (the QuClassi layout).
pub fn swap_test_fidelity(state: &State) -> f64 {
    2.0 * state.prob_zero(0) - 1.0
}

/// Shot-sampled estimate of P(qubit = |0>).
///
/// The AOT artifacts return exact expectations (infinite-shot limit);
/// this models the finite-shot noise a real quantum backend would have —
/// used by the shot-ablation bench.
pub fn sample_prob_zero(state: &State, qubit: usize, shots: usize, rng: &mut Rng) -> f64 {
    let p = state.prob_zero(qubit);
    let mut zeros = 0usize;
    for _ in 0..shots {
        if rng.f64() < p {
            zeros += 1;
        }
    }
    zeros as f64 / shots as f64
}

/// Shot-sampled swap-test fidelity.
pub fn sample_swap_test_fidelity(state: &State, shots: usize, rng: &mut Rng) -> f64 {
    2.0 * sample_prob_zero(state, 0, shots, rng) - 1.0
}

/// Sample full computational-basis measurement outcomes (indices).
///
/// Delegates to the CDF helpers in [`super::shots`] — one shared
/// inverse-CDF implementation, using `partition_point` rather than a
/// `partial_cmp().unwrap()` comparator that could panic on NaN.
pub fn sample_shots(state: &State, shots: usize, rng: &mut Rng) -> Vec<usize> {
    let (cdf, total) = super::shots::cumulative(state);
    let mut out = Vec::with_capacity(shots);
    super::shots::sample_into(&cdf, total, shots, rng, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qsim::gates;

    #[test]
    fn swap_test_on_zero_state() {
        // H on ancilla of |000..>, no CSWAP effect, H again -> P0 = 1.
        let mut s = State::zero(3);
        s.apply_h(0);
        s.apply_h(0);
        assert!((swap_test_fidelity(&s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sampled_prob_converges() {
        let mut s = State::zero(2);
        s.apply_1q(&gates::ry_matrix(std::f64::consts::FRAC_PI_2), 0); // P0 = cos^2(pi/4) = 0.5
        let mut rng = Rng::new(3);
        let est = sample_prob_zero(&s, 0, 100_000, &mut rng);
        assert!((est - 0.5).abs() < 0.01, "est={est}");
    }

    #[test]
    fn shot_histogram_matches_distribution() {
        let mut s = State::zero(2);
        s.apply_h(0);
        s.apply_h(1); // uniform over 4 outcomes
        let mut rng = Rng::new(5);
        let shots = sample_shots(&s, 40_000, &mut rng);
        let mut counts = [0usize; 4];
        for idx in shots {
            counts[idx] += 1;
        }
        for c in counts {
            let frac = c as f64 / 40_000.0;
            assert!((frac - 0.25).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn sampled_fidelity_tracks_exact() {
        let mut s = State::zero(3);
        s.apply_ry(0.9, 1);
        s.apply_h(0);
        s.apply_cswap(0, 1, 2);
        s.apply_h(0);
        let exact = swap_test_fidelity(&s);
        let mut rng = Rng::new(7);
        let est = sample_swap_test_fidelity(&s, 200_000, &mut rng);
        assert!((est - exact).abs() < 0.01, "exact={exact} est={est}");
    }
}
