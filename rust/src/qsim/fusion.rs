//! Gate fusion: coalesce runs of adjacent one/two-qubit gates into fused
//! matrices before statevector application (DESIGN.md §11).
//!
//! A QuClassi circuit applies long runs of small gates to the same one or
//! two qubits (`Ry·Rz` encoders, `Ryy·Rzz` and `CRY·CRZ` layer pairs).
//! Applying each gate separately walks the full amplitude array once per
//! gate; fusing a run into a single 2x2 or 4x4 product walks it once per
//! *run*. The pass is purely local and preserves the circuit's unitary
//! action exactly (up to float re-association — parity is asserted to
//! 1e-9 in `rust/tests/parallel_parity.rs`).
//!
//! Fusion rules, scanning the emitted ops backwards from each new gate:
//!
//! * gates on disjoint qubit sets commute, so the scan skips them;
//! * a 1q gate merges into an earlier [`FusedOp::Single`] on the same
//!   qubit, or lifts into an earlier [`FusedOp::Pair`] containing it;
//! * a 2q gate composes with an earlier `Pair` on the same (unordered)
//!   qubit pair — reindexed via [`gates::swap_pair_order`] when the
//!   operand order differs — and absorbs earlier `Single`s on either of
//!   its operands;
//! * the three-qubit `CSWAP` never fuses; it is a [`FusedOp::Barrier`]
//!   that blocks merges across it on its qubits.
//!
//! The same pass feeds the serial executor (`simulate_fidelity_fused`)
//! and the parallel shot engine ([`super::shots`]), which fuses once and
//! re-applies the plan on every worker thread.

use super::complex::C64;
use super::gates::{self, Gate, Mat2, Mat4};
use super::state::State;

/// One fused operation: a coalesced matrix or an unfusable gate.
#[derive(Debug, Clone, PartialEq)]
pub enum FusedOp {
    /// Product of a run of single-qubit gates on `q`.
    Single {
        /// Target qubit.
        q: usize,
        /// Accumulated 2x2 unitary (later gates multiplied on the left).
        m: Mat2,
    },
    /// Product of a run of one/two-qubit gates supported on `{q0, q1}`.
    /// Matrix row/column index is `2*b(q0) + b(q1)` — the same convention
    /// as [`State::apply_2q`].
    Pair {
        /// First (more significant) operand of the pair index.
        q0: usize,
        /// Second operand of the pair index.
        q1: usize,
        /// Accumulated 4x4 unitary.
        m: Mat4,
    },
    /// A gate that does not fuse (CSWAP); applied through the normal
    /// dispatch and acting as a fusion barrier on its qubits.
    Barrier(Gate),
}

impl FusedOp {
    /// Does this op act on `q`?
    pub fn touches(&self, q: usize) -> bool {
        match self {
            FusedOp::Single { q: sq, .. } => *sq == q,
            FusedOp::Pair { q0, q1, .. } => *q0 == q || *q1 == q,
            FusedOp::Barrier(g) => g.qubits().contains(&q),
        }
    }
}

/// A fused circuit: the op list plus provenance counters.
#[derive(Debug, Clone, PartialEq)]
pub struct FusedProgram {
    /// Fused operations in application order.
    pub ops: Vec<FusedOp>,
    /// Number of IR gates the program was fused from.
    pub gates_in: usize,
}

impl FusedProgram {
    /// Apply the whole program to `state`.
    pub fn apply(&self, state: &mut State) {
        for op in &self.ops {
            match op {
                FusedOp::Single { q, m } => state.apply_1q(m, *q),
                FusedOp::Pair { q0, q1, m } => state.apply_2q(m, *q0, *q1),
                FusedOp::Barrier(g) => state.apply_gate(g),
            }
        }
    }

    /// Number of fused operations.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when the program contains no operations.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Gates eliminated by fusion (`gates_in - len`).
    pub fn fused_away(&self) -> usize {
        self.gates_in.saturating_sub(self.ops.len())
    }
}

/// `a * b` for 2x2 complex matrices.
pub fn mat2_mul(a: &Mat2, b: &Mat2) -> Mat2 {
    let mut out = [[C64::ZERO; 2]; 2];
    for (i, row) in out.iter_mut().enumerate() {
        for (j, cell) in row.iter_mut().enumerate() {
            for k in 0..2 {
                *cell += a[i][k] * b[k][j];
            }
        }
    }
    out
}

/// `a * b` for 4x4 complex matrices.
pub fn mat4_mul(a: &Mat4, b: &Mat4) -> Mat4 {
    let mut out = [[C64::ZERO; 4]; 4];
    for (i, row) in out.iter_mut().enumerate() {
        for (j, cell) in row.iter_mut().enumerate() {
            for k in 0..4 {
                *cell += a[i][k] * b[k][j];
            }
        }
    }
    out
}

/// Lift a 1q matrix onto a pair: `slot = 0` targets `q0` (the more
/// significant pair-index bit, `kron(m, I)`), `slot = 1` targets `q1`
/// (`kron(I, m)`). Shared with the compiled pipeline (`super::compile`).
pub(crate) fn lift_to_pair(m: &Mat2, slot: usize) -> Mat4 {
    debug_assert!(slot < 2);
    let mut out = [[C64::ZERO; 4]; 4];
    for r0 in 0..2 {
        for r1 in 0..2 {
            for c0 in 0..2 {
                for c1 in 0..2 {
                    let v = if slot == 0 {
                        if r1 == c1 { m[r0][c0] } else { C64::ZERO }
                    } else if r0 == c0 {
                        m[r1][c1]
                    } else {
                        C64::ZERO
                    };
                    out[2 * r0 + r1][2 * c0 + c1] = v;
                }
            }
        }
    }
    out
}

/// A gate classified for fusion: its dense matrix plus operand order.
/// Shared with the compiled pipeline (`super::compile`).
pub(crate) enum Kind {
    /// 1q gate: (qubit, 2x2 matrix).
    One(usize, Mat2),
    /// 2q gate: (first operand, second operand, 4x4 matrix indexed
    /// `2*b(first) + b(second)`).
    Two(usize, usize, Mat4),
    /// Unfusable (CSWAP).
    Other,
}

pub(crate) fn classify(g: &Gate) -> Kind {
    match *g {
        Gate::H { q } => Kind::One(q, gates::h_matrix()),
        Gate::Rx { q, theta } => Kind::One(q, gates::rx_matrix(theta)),
        Gate::Ry { q, theta } => Kind::One(q, gates::ry_matrix(theta)),
        Gate::Rz { q, theta } => Kind::One(q, gates::rz_matrix(theta)),
        Gate::Ryy { q0, q1, theta } => Kind::Two(q0, q1, gates::ryy_matrix(theta)),
        Gate::Rzz { q0, q1, theta } => Kind::Two(q0, q1, gates::rzz_matrix(theta)),
        Gate::Cry { control, target, theta } => Kind::Two(control, target, gates::cry_matrix(theta)),
        Gate::Crz { control, target, theta } => Kind::Two(control, target, gates::crz_matrix(theta)),
        Gate::Cx { control, target } => Kind::Two(control, target, gates::cx_matrix()),
        Gate::Cswap { .. } => Kind::Other,
    }
}

/// Fuse a gate list into a [`FusedProgram`].
pub fn fuse(gate_list: &[Gate]) -> FusedProgram {
    let mut ops: Vec<FusedOp> = Vec::with_capacity(gate_list.len());
    for g in gate_list {
        match classify(g) {
            Kind::One(q, m) => push_one(&mut ops, q, m),
            Kind::Two(a, b, m) => push_two(&mut ops, a, b, m),
            Kind::Other => ops.push(FusedOp::Barrier(g.clone())),
        }
    }
    FusedProgram { ops, gates_in: gate_list.len() }
}

/// Merge a 1q gate into the op list. Scan invariant: every op passed
/// over is disjoint from `q`, so the new gate commutes back to its merge
/// partner.
fn push_one(ops: &mut Vec<FusedOp>, q: usize, m: Mat2) {
    for i in (0..ops.len()).rev() {
        if !ops[i].touches(q) {
            continue;
        }
        match &mut ops[i] {
            FusedOp::Single { m: pm, .. } => {
                *pm = mat2_mul(&m, pm);
                return;
            }
            FusedOp::Pair { q0, m: pm, .. } => {
                let slot = if *q0 == q { 0 } else { 1 };
                *pm = mat4_mul(&lift_to_pair(&m, slot), pm);
                return;
            }
            FusedOp::Barrier(_) => break,
        }
    }
    ops.push(FusedOp::Single { q, m });
}

/// Merge a 2q gate on `(a, b)` (matrix index `2*b(a) + b(b)`) into the op
/// list, absorbing earlier `Single`s on either operand and composing with
/// an earlier `Pair` on the same qubit pair. Scan invariant: every op
/// passed over (or removed) leaves the region between the merge site and
/// the list tail disjoint from `{a, b}`.
fn push_two(ops: &mut Vec<FusedOp>, a: usize, b: usize, m: Mat4) {
    let mut acc = m;
    let mut i = ops.len();
    while i > 0 {
        i -= 1;
        if !ops[i].touches(a) && !ops[i].touches(b) {
            continue;
        }
        let absorbed = match &ops[i] {
            FusedOp::Single { q, m: sm } => {
                // The earlier single acts first: multiply on the right.
                let slot = if *q == a { 0 } else { 1 };
                acc = mat4_mul(&acc, &lift_to_pair(sm, slot));
                true
            }
            FusedOp::Pair { q0, q1, m: pm }
                if (*q0 == a && *q1 == b) || (*q0 == b && *q1 == a) =>
            {
                let pm_ab = if *q0 == a { *pm } else { gates::swap_pair_order(pm) };
                acc = mat4_mul(&acc, &pm_ab);
                true
            }
            // Partially overlapping pair or a barrier: stop scanning.
            _ => false,
        };
        if absorbed {
            ops.remove(i);
        } else {
            break;
        }
    }
    ops.push(FusedOp::Pair { q0: a, q1: b, m: acc });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::{build_quclassi, QuClassiConfig};
    use crate::util::Rng;

    fn random_state(rng: &mut Rng, nq: usize) -> State {
        let mut amps: Vec<C64> =
            (0..1usize << nq).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let norm = amps.iter().map(|a| a.norm_sq()).sum::<f64>().sqrt();
        for a in &mut amps {
            *a = a.scale(1.0 / norm);
        }
        State::from_amps(amps)
    }

    fn assert_equivalent(gate_list: &[Gate], nq: usize, seed: u64) {
        let program = fuse(gate_list);
        let mut rng = Rng::new(seed);
        for _ in 0..4 {
            let base = random_state(&mut rng, nq);
            let mut serial = base.clone();
            serial.run(gate_list);
            let mut fused = base;
            program.apply(&mut fused);
            for (x, y) in serial.amps().iter().zip(fused.amps().iter()) {
                assert!(
                    (x.re - y.re).abs() < 1e-9 && (x.im - y.im).abs() < 1e-9,
                    "fused program diverges: {x:?} vs {y:?}"
                );
            }
        }
    }

    #[test]
    fn single_qubit_runs_collapse_to_one_op() {
        let gate_list = vec![
            Gate::Ry { q: 1, theta: 0.3 },
            Gate::Rz { q: 1, theta: -0.7 },
            Gate::H { q: 1 },
            Gate::Rx { q: 1, theta: 1.1 },
        ];
        let program = fuse(&gate_list);
        assert_eq!(program.len(), 1);
        assert_eq!(program.fused_away(), 3);
        assert_equivalent(&gate_list, 2, 11);
    }

    #[test]
    fn fusion_commutes_through_disjoint_gates() {
        let gate_list = vec![
            Gate::Ry { q: 0, theta: 0.5 },
            Gate::Ry { q: 2, theta: 0.9 }, // disjoint: scan passes it
            Gate::Rz { q: 0, theta: -0.4 },
        ];
        let program = fuse(&gate_list);
        assert_eq!(program.len(), 2);
        assert_equivalent(&gate_list, 3, 13);
    }

    #[test]
    fn pair_absorbs_singles_and_composes() {
        let gate_list = vec![
            Gate::Ry { q: 0, theta: 0.2 },
            Gate::Rz { q: 1, theta: 0.4 },
            Gate::Ryy { q0: 0, q1: 1, theta: 0.6 },
            Gate::Rzz { q0: 0, q1: 1, theta: -0.8 },
            Gate::Cry { control: 1, target: 0, theta: 1.2 }, // reversed operands
        ];
        let program = fuse(&gate_list);
        assert_eq!(program.len(), 1);
        assert!(matches!(program.ops[0], FusedOp::Pair { .. }));
        assert_equivalent(&gate_list, 2, 17);
    }

    #[test]
    fn late_single_lifts_into_pair() {
        let gate_list = vec![
            Gate::Cx { control: 0, target: 1 },
            Gate::Ry { q: 1, theta: 0.9 },
            Gate::H { q: 0 },
        ];
        let program = fuse(&gate_list);
        assert_eq!(program.len(), 1);
        assert_equivalent(&gate_list, 2, 19);
    }

    #[test]
    fn cswap_is_a_barrier() {
        let gate_list = vec![
            Gate::H { q: 0 },
            Gate::Cswap { control: 0, a: 1, b: 2 },
            Gate::H { q: 0 },
        ];
        let program = fuse(&gate_list);
        assert_eq!(program.len(), 3);
        assert_equivalent(&gate_list, 3, 23);
    }

    #[test]
    fn partial_pair_overlap_blocks_merge() {
        // (0,1) then (1,2): share qubit 1 but are different pairs.
        let gate_list = vec![
            Gate::Ryy { q0: 0, q1: 1, theta: 0.3 },
            Gate::Ryy { q0: 1, q1: 2, theta: 0.5 },
        ];
        let program = fuse(&gate_list);
        assert_eq!(program.len(), 2);
        assert_equivalent(&gate_list, 3, 29);
    }

    #[test]
    fn quclassi_circuits_fuse_and_stay_equivalent() {
        let mut rng = Rng::new(5);
        for cfg in QuClassiConfig::paper_configs() {
            let thetas: Vec<f32> =
                (0..cfg.n_params()).map(|_| rng.range_f64(-3.0, 3.0) as f32).collect();
            let data: Vec<f32> =
                (0..cfg.n_features()).map(|_| rng.range_f64(-3.0, 3.0) as f32).collect();
            let gate_list = build_quclassi(&cfg, &thetas, &data);
            let program = fuse(&gate_list);
            assert!(
                program.len() < gate_list.len(),
                "no fusion on {cfg:?}: {} ops from {} gates",
                program.len(),
                gate_list.len()
            );
            assert_equivalent(&gate_list, cfg.qubits, 31 + cfg.qubits as u64);
        }
    }

    #[test]
    fn empty_program() {
        let program = fuse(&[]);
        assert!(program.is_empty());
        let mut st = State::zero(2);
        program.apply(&mut st);
        assert_eq!(st, State::zero(2));
    }

    #[test]
    fn lift_matches_manual_kron() {
        // lift(H, slot 0) acting on |10> (pair index 2) must equal
        // H on q0 ⊗ I: amplitude spread over indices 0 and 2.
        let h = gates::h_matrix();
        let l0 = lift_to_pair(&h, 0);
        // column 2 of kron(H, I): entries at rows 0 and 2 are 1/sqrt2, -1/sqrt2.
        assert!((l0[0][2].re - gates::INV_SQRT2).abs() < 1e-12);
        assert!((l0[2][2].re + gates::INV_SQRT2).abs() < 1e-12);
        assert_eq!(l0[1][2], C64::ZERO);
        let l1 = lift_to_pair(&h, 1);
        // column 1 of kron(I, H): rows 0 and 1.
        assert!((l1[0][1].re - gates::INV_SQRT2).abs() < 1e-12);
        assert!((l1[1][1].re + gates::INV_SQRT2).abs() < 1e-12);
        assert_eq!(l1[2][1], C64::ZERO);
    }

    #[test]
    fn matmul_identity() {
        let h = gates::h_matrix();
        let hh = mat2_mul(&h, &h);
        assert!((hh[0][0].re - 1.0).abs() < 1e-12);
        assert!(hh[0][1].abs() < 1e-12);
        let cx = gates::cx_matrix();
        let cc = mat4_mul(&cx, &cx);
        for (i, row) in cc.iter().enumerate() {
            for (j, cell) in row.iter().enumerate() {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((cell.re - want).abs() < 1e-12 && cell.im.abs() < 1e-12);
            }
        }
    }
}
