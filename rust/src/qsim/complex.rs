//! Minimal complex arithmetic (std-only substrate for `num-complex`).

use std::ops::{Add, AddAssign, Mul, MulAssign, Neg, Sub};

/// Complex number over f64.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    /// 0 + 0i.
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
    /// 1 + 0i.
    pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };
    /// 0 + 1i.
    pub const I: C64 = C64 { re: 0.0, im: 1.0 };

    /// Complex number from real and imaginary parts.
    pub fn new(re: f64, im: f64) -> C64 {
        C64 { re, im }
    }

    /// Purely real complex number.
    pub fn from_re(re: f64) -> C64 {
        C64 { re, im: 0.0 }
    }

    /// e^{i theta}
    pub fn cis(theta: f64) -> C64 {
        C64 { re: theta.cos(), im: theta.sin() }
    }

    /// Complex conjugate.
    pub fn conj(self) -> C64 {
        C64 { re: self.re, im: -self.im }
    }

    /// |z|^2
    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// |z|
    pub fn abs(self) -> f64 {
        self.norm_sq().sqrt()
    }

    /// Multiply by a real scalar.
    pub fn scale(self, s: f64) -> C64 {
        C64 { re: self.re * s, im: self.im * s }
    }
}

impl Add for C64 {
    type Output = C64;
    fn add(self, o: C64) -> C64 {
        C64 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl AddAssign for C64 {
    fn add_assign(&mut self, o: C64) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl Sub for C64 {
    type Output = C64;
    fn sub(self, o: C64) -> C64 {
        C64 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for C64 {
    type Output = C64;
    fn mul(self, o: C64) -> C64 {
        C64 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl MulAssign for C64 {
    fn mul_assign(&mut self, o: C64) {
        *self = *self * o;
    }
}

impl Neg for C64 {
    type Output = C64;
    fn neg(self) -> C64 {
        C64 { re: -self.re, im: -self.im }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_identities() {
        let z = C64::new(3.0, -4.0);
        assert_eq!(z + C64::ZERO, z);
        assert_eq!(z * C64::ONE, z);
        assert_eq!(z * C64::I, C64::new(4.0, 3.0));
        assert_eq!(z.abs(), 5.0);
        assert_eq!(z.conj(), C64::new(3.0, 4.0));
        assert_eq!((z * z.conj()).re, 25.0);
    }

    #[test]
    fn cis_is_unit() {
        for k in 0..16 {
            let z = C64::cis(k as f64 * 0.5);
            assert!((z.norm_sq() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn euler_identity() {
        let z = C64::cis(std::f64::consts::PI);
        assert!((z.re + 1.0).abs() < 1e-12);
        assert!(z.im.abs() < 1e-12);
    }
}
