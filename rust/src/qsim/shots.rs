//! Parallel shot execution: fan measurement shots out across an internal
//! worker-thread pool (DESIGN.md §11).
//!
//! A *shot* is one end-to-end execution of a circuit followed by a full
//! computational-basis measurement — the unit a real quantum backend
//! bills by and the unit the paper's circuits-per-second metric counts.
//! [`run_shots`] compiles the circuit once ([`super::compile`], fused
//! blocks + blocked kernels), simulates the statevector once, then fans
//! the sampling work out over the shared scoped-thread pool
//! ([`crate::util::pool`]), with every thread reading the same
//! cumulative distribution.
//!
//! Determinism: shots are partitioned into fixed-size chunks
//! ([`SHOT_CHUNK`]) and every chunk derives its own RNG stream from
//! `(seed, chunk index)` — the chunk layout does not depend on the thread
//! count, so the returned outcome sequence is bitwise identical for any
//! `threads` value (asserted in `rust/tests/parallel_parity.rs`).

use super::compile::{CircuitTemplate, CompiledProgram};
use super::gates::Gate;
use super::state::State;
use crate::util::{pool, Rng};

/// Shots per work unit; fixed so results are independent of `threads`.
pub const SHOT_CHUNK: usize = 1024;

/// Execute `n_shots` measurement shots of `gate_list` on `threads` pool
/// threads; returns one basis-state index per shot, in a deterministic
/// order that depends only on `seed` (never on `threads`).
///
/// `threads = 0` or `1` runs serially on the calling thread; the serial
/// path and the pooled path produce identical output.
pub fn run_shots(
    n_qubits: usize,
    gate_list: &[Gate],
    n_shots: usize,
    threads: usize,
    seed: u64,
) -> Vec<usize> {
    if n_shots == 0 {
        return Vec::new();
    }
    // Compile (fused blocks + blocked kernels) and simulate exactly
    // once; pool threads share the read-only cumulative distribution
    // and sample disjoint chunks.
    let program = CompiledProgram::compile(CircuitTemplate::from_gates(n_qubits, gate_list));
    let mut st = State::zero(n_qubits);
    program.bind(&[], &[]).apply(&mut st);
    sample_state(&st, n_shots, threads, seed)
}

/// Sample `n_shots` computational-basis outcomes from an already
/// evolved state, fanned over `threads` pool threads with the same
/// chunked deterministic RNG streams as [`run_shots`].
pub fn sample_state(st: &State, n_shots: usize, threads: usize, seed: u64) -> Vec<usize> {
    if n_shots == 0 {
        return Vec::new();
    }
    let (cdf, total) = cumulative(st);
    let n_chunks = n_shots.div_ceil(SHOT_CHUNK);
    let chunks = pool::parallel_indexed(n_chunks, threads, |c| {
        let range = chunk_range(c, n_shots);
        let mut out = Vec::with_capacity(range.len());
        sample_into(&cdf, total, range.len(), &mut chunk_rng(seed, c), &mut out);
        out
    });
    let mut out = Vec::with_capacity(n_shots);
    for chunk in chunks {
        out.extend(chunk);
    }
    out
}

/// Histogram of outcome counts over all `2^n_qubits` basis states.
pub fn histogram(outcomes: &[usize], n_qubits: usize) -> Vec<usize> {
    let mut counts = vec![0usize; 1 << n_qubits];
    for &o in outcomes {
        counts[o] += 1;
    }
    counts
}

/// Shot-estimated probability that `qubit` reads |0> (big-endian
/// indexing, matching [`State::prob_zero`]).
pub fn prob_zero_estimate(outcomes: &[usize], n_qubits: usize, qubit: usize) -> f64 {
    assert!(qubit < n_qubits);
    let mask = 1usize << (n_qubits - 1 - qubit);
    let zeros = outcomes.iter().filter(|&&o| o & mask == 0).count();
    zeros as f64 / outcomes.len().max(1) as f64
}

/// The shot index range covered by chunk `c`.
fn chunk_range(c: usize, n_shots: usize) -> std::ops::Range<usize> {
    let lo = c * SHOT_CHUNK;
    lo..((c + 1) * SHOT_CHUNK).min(n_shots)
}

/// Stable per-chunk RNG stream: depends on `(seed, chunk)` only.
fn chunk_rng(seed: u64, chunk: usize) -> Rng {
    // Golden-ratio stride keeps neighboring chunk seeds far apart before
    // the Rng's own SplitMix64 expansion decorrelates them fully.
    Rng::new(seed.wrapping_add((chunk as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)))
}

/// Cumulative measurement distribution of a state (plus its total, which
/// is ~1.0 but guarded against float drift). Shared with
/// [`super::measure::sample_shots`] so there is exactly one CDF builder.
pub(crate) fn cumulative(state: &State) -> (Vec<f64>, f64) {
    let mut cdf = Vec::with_capacity(state.amps().len());
    let mut acc = 0.0;
    for a in state.amps() {
        acc += a.norm_sq();
        cdf.push(acc);
    }
    (cdf, acc)
}

/// Inverse-CDF sampling of `count` outcomes into `out`. Uses
/// `partition_point` (total-order comparison on already-accumulated
/// prefix sums), so it cannot panic on NaN the way a
/// `partial_cmp().unwrap()` comparator would.
pub(crate) fn sample_into(
    cdf: &[f64],
    total: f64,
    count: usize,
    rng: &mut Rng,
    out: &mut Vec<usize>,
) {
    for _ in 0..count {
        let u = rng.f64() * total;
        out.push(cdf.partition_point(|&c| c <= u).min(cdf.len() - 1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bell_pair() -> Vec<Gate> {
        vec![Gate::H { q: 0 }, Gate::Cx { control: 0, target: 1 }]
    }

    #[test]
    fn outcome_count_matches_shots() {
        for shots in [1usize, 7, SHOT_CHUNK, SHOT_CHUNK + 1, 3 * SHOT_CHUNK + 5] {
            let out = run_shots(2, &bell_pair(), shots, 2, 42);
            assert_eq!(out.len(), shots);
        }
        assert!(run_shots(2, &bell_pair(), 0, 4, 1).is_empty());
    }

    #[test]
    fn thread_count_does_not_change_outcomes() {
        let shots = 2 * SHOT_CHUNK + 137;
        let serial = run_shots(3, &bell_pair(), shots, 1, 7);
        for threads in [2usize, 3, 4, 8] {
            let pooled = run_shots(3, &bell_pair(), shots, threads, 7);
            assert_eq!(serial, pooled, "threads={threads} diverged");
        }
    }

    #[test]
    fn bell_state_only_produces_correlated_outcomes() {
        let out = run_shots(2, &bell_pair(), 4 * SHOT_CHUNK, 4, 3);
        let counts = histogram(&out, 2);
        // |00> and |11> only, roughly balanced.
        assert_eq!(counts[1], 0);
        assert_eq!(counts[2], 0);
        let frac = counts[0] as f64 / out.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn frequencies_converge_to_exact_distribution() {
        let gate_list = vec![Gate::Ry { q: 0, theta: 0.9 }, Gate::H { q: 1 }];
        let mut st = State::zero(2);
        st.run(&gate_list);
        let exact: Vec<f64> = st.amps().iter().map(|a| a.norm_sq()).collect();
        let shots = 200_000;
        let out = run_shots(2, &gate_list, shots, 4, 11);
        let counts = histogram(&out, 2);
        for (i, &c) in counts.iter().enumerate() {
            let frac = c as f64 / shots as f64;
            assert!((frac - exact[i]).abs() < 0.01, "state {i}: {frac} vs {}", exact[i]);
        }
    }

    #[test]
    fn prob_zero_estimate_tracks_state() {
        let gate_list = vec![Gate::Ry { q: 1, theta: 1.1 }];
        let mut st = State::zero(2);
        st.run(&gate_list);
        let out = run_shots(2, &gate_list, 100_000, 2, 13);
        let est = prob_zero_estimate(&out, 2, 1);
        assert!((est - st.prob_zero(1)).abs() < 0.01);
        // untouched qubit always reads |0>
        assert_eq!(prob_zero_estimate(&out, 2, 0), 1.0);
    }

    #[test]
    fn different_seeds_differ() {
        let a = run_shots(2, &bell_pair(), SHOT_CHUNK, 2, 1);
        let b = run_shots(2, &bell_pair(), SHOT_CHUNK, 2, 2);
        assert_ne!(a, b);
    }
}
