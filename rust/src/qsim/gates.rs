//! Gate set: IR enum + matrix constructors.
//!
//! The [`Gate`] enum is the circuit IR shared by the whole stack
//! (builder, wire protocol, simulator). Matrix constructors mirror
//! `python/compile/kernels/ref.py` exactly.

use super::complex::C64;
use crate::wire::Value;

/// A quantum gate instance (operands + parameter).
#[derive(Debug, Clone, PartialEq)]
pub enum Gate {
    /// Hadamard.
    H { q: usize },
    /// Rotation around X.
    Rx { q: usize, theta: f64 },
    /// Rotation around Y.
    Ry { q: usize, theta: f64 },
    /// Rotation around Z.
    Rz { q: usize, theta: f64 },
    /// Two-qubit YY rotation.
    Ryy { q0: usize, q1: usize, theta: f64 },
    /// Two-qubit ZZ rotation.
    Rzz { q0: usize, q1: usize, theta: f64 },
    /// Controlled Ry.
    Cry { control: usize, target: usize, theta: f64 },
    /// Controlled Rz.
    Crz { control: usize, target: usize, theta: f64 },
    /// Controlled NOT.
    Cx { control: usize, target: usize },
    /// Fredkin (controlled swap).
    Cswap { control: usize, a: usize, b: usize },
}

impl Gate {
    /// Qubits this gate touches.
    pub fn qubits(&self) -> Vec<usize> {
        match *self {
            Gate::H { q } | Gate::Rx { q, .. } | Gate::Ry { q, .. } | Gate::Rz { q, .. } => vec![q],
            Gate::Ryy { q0, q1, .. } | Gate::Rzz { q0, q1, .. } => vec![q0, q1],
            Gate::Cry { control, target, .. } | Gate::Crz { control, target, .. } => {
                vec![control, target]
            }
            Gate::Cx { control, target } => vec![control, target],
            Gate::Cswap { control, a, b } => vec![control, a, b],
        }
    }

    /// The rotation angle, if parameterized.
    pub fn theta(&self) -> Option<f64> {
        match *self {
            Gate::Rx { theta, .. }
            | Gate::Ry { theta, .. }
            | Gate::Rz { theta, .. }
            | Gate::Ryy { theta, .. }
            | Gate::Rzz { theta, .. }
            | Gate::Cry { theta, .. }
            | Gate::Crz { theta, .. } => Some(theta),
            _ => None,
        }
    }

    /// Replace the rotation angle (no-op for unparameterized gates).
    pub fn with_theta(&self, new: f64) -> Gate {
        let mut g = self.clone();
        match &mut g {
            Gate::Rx { theta, .. }
            | Gate::Ry { theta, .. }
            | Gate::Rz { theta, .. }
            | Gate::Ryy { theta, .. }
            | Gate::Rzz { theta, .. }
            | Gate::Cry { theta, .. }
            | Gate::Crz { theta, .. } => *theta = new,
            _ => {}
        }
        g
    }

    /// Is this a controlled rotation (needs the 4-term shift rule)?
    pub fn is_controlled_rotation(&self) -> bool {
        matches!(self, Gate::Cry { .. } | Gate::Crz { .. })
    }

    /// Wire encoding: `[name, operands..., theta?]`.
    pub fn to_wire(&self) -> Value {
        let mut arr: Vec<Value> = Vec::new();
        let name = match self {
            Gate::H { .. } => "h",
            Gate::Rx { .. } => "rx",
            Gate::Ry { .. } => "ry",
            Gate::Rz { .. } => "rz",
            Gate::Ryy { .. } => "ryy",
            Gate::Rzz { .. } => "rzz",
            Gate::Cry { .. } => "cry",
            Gate::Crz { .. } => "crz",
            Gate::Cx { .. } => "cx",
            Gate::Cswap { .. } => "cswap",
        };
        arr.push(Value::Str(name.to_string()));
        for q in self.qubits() {
            arr.push(Value::Num(q as f64));
        }
        if let Some(t) = self.theta() {
            arr.push(Value::Num(t));
        }
        Value::Arr(arr)
    }

    /// Decode the wire encoding.
    pub fn from_wire(v: &Value) -> Result<Gate, String> {
        let arr = v.as_arr().ok_or("gate must be an array")?;
        let name = arr.first().and_then(Value::as_str).ok_or("gate missing name")?;
        let num = |i: usize| -> Result<usize, String> {
            arr.get(i).and_then(Value::as_usize).ok_or_else(|| format!("gate {name}: bad operand {i}"))
        };
        let fnum = |i: usize| -> Result<f64, String> {
            arr.get(i).and_then(Value::as_f64).ok_or_else(|| format!("gate {name}: bad angle"))
        };
        Ok(match name {
            "h" => Gate::H { q: num(1)? },
            "rx" => Gate::Rx { q: num(1)?, theta: fnum(2)? },
            "ry" => Gate::Ry { q: num(1)?, theta: fnum(2)? },
            "rz" => Gate::Rz { q: num(1)?, theta: fnum(2)? },
            "ryy" => Gate::Ryy { q0: num(1)?, q1: num(2)?, theta: fnum(3)? },
            "rzz" => Gate::Rzz { q0: num(1)?, q1: num(2)?, theta: fnum(3)? },
            "cry" => Gate::Cry { control: num(1)?, target: num(2)?, theta: fnum(3)? },
            "crz" => Gate::Crz { control: num(1)?, target: num(2)?, theta: fnum(3)? },
            "cx" => Gate::Cx { control: num(1)?, target: num(2)? },
            "cswap" => Gate::Cswap { control: num(1)?, a: num(2)?, b: num(3)? },
            other => return Err(format!("unknown gate '{other}'")),
        })
    }
}

/// 1/sqrt(2) — the Hadamard normalization.
pub const INV_SQRT2: f64 = std::f64::consts::FRAC_1_SQRT_2;

/// 2x2 matrix in row-major order.
pub type Mat2 = [[C64; 2]; 2];
/// 4x4 matrix in row-major order; index = 2*b(q0) + b(q1).
pub type Mat4 = [[C64; 4]; 4];
/// 8x8 matrix in row-major order; index = 4*b(q0) + 2*b(q1) + b(q2)
/// with q0 < q1 < q2 (the fused 3-qubit block of `qsim::compile`).
pub type Mat8 = [[C64; 8]; 8];

/// Hadamard matrix.
pub fn h_matrix() -> Mat2 {
    let s = C64::from_re(INV_SQRT2);
    [[s, s], [s, -s]]
}

/// Rx(theta) rotation matrix.
pub fn rx_matrix(theta: f64) -> Mat2 {
    let c = C64::from_re((theta / 2.0).cos());
    let mis = C64::new(0.0, -(theta / 2.0).sin());
    [[c, mis], [mis, c]]
}

/// Ry(theta) rotation matrix.
pub fn ry_matrix(theta: f64) -> Mat2 {
    let c = C64::from_re((theta / 2.0).cos());
    let s = C64::from_re((theta / 2.0).sin());
    [[c, -s], [s, c]]
}

/// Rz(theta) rotation matrix.
pub fn rz_matrix(theta: f64) -> Mat2 {
    let em = C64::cis(-theta / 2.0);
    let ep = C64::cis(theta / 2.0);
    [[em, C64::ZERO], [C64::ZERO, ep]]
}

/// Ryy(theta) two-qubit rotation matrix.
pub fn ryy_matrix(theta: f64) -> Mat4 {
    let c = C64::from_re((theta / 2.0).cos());
    let is = C64::new(0.0, (theta / 2.0).sin());
    let z = C64::ZERO;
    [
        [c, z, z, is],
        [z, c, -is, z],
        [z, -is, c, z],
        [is, z, z, c],
    ]
}

/// Rzz(theta) two-qubit rotation matrix.
pub fn rzz_matrix(theta: f64) -> Mat4 {
    let em = C64::cis(-theta / 2.0);
    let ep = C64::cis(theta / 2.0);
    let z = C64::ZERO;
    [
        [em, z, z, z],
        [z, ep, z, z],
        [z, z, ep, z],
        [z, z, z, em],
    ]
}

/// CRY with control = first index of the pair.
pub fn cry_matrix(theta: f64) -> Mat4 {
    let c = C64::from_re((theta / 2.0).cos());
    let s = C64::from_re((theta / 2.0).sin());
    let o = C64::ONE;
    let z = C64::ZERO;
    [
        [o, z, z, z],
        [z, o, z, z],
        [z, z, c, -s],
        [z, z, s, c],
    ]
}

/// CRZ with control = first index of the pair.
pub fn crz_matrix(theta: f64) -> Mat4 {
    let em = C64::cis(-theta / 2.0);
    let ep = C64::cis(theta / 2.0);
    let o = C64::ONE;
    let z = C64::ZERO;
    [
        [o, z, z, z],
        [z, o, z, z],
        [z, z, em, z],
        [z, z, z, ep],
    ]
}

/// Controlled-NOT matrix (control = first index of the pair).
pub fn cx_matrix() -> Mat4 {
    let o = C64::ONE;
    let z = C64::ZERO;
    [
        [o, z, z, z],
        [z, o, z, z],
        [z, z, z, o],
        [z, z, o, z],
    ]
}

/// Reindex a pair matrix from (a, b) ordering to (b, a) ordering.
pub fn swap_pair_order(m: &Mat4) -> Mat4 {
    const PERM: [usize; 4] = [0, 2, 1, 3];
    let mut out = [[C64::ZERO; 4]; 4];
    for (i, pi) in PERM.iter().enumerate() {
        for (j, pj) in PERM.iter().enumerate() {
            out[i][j] = m[*pi][*pj];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_unitary2(m: &Mat2) -> bool {
        // m * m^dagger == I
        let mut prod = [[C64::ZERO; 2]; 2];
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    prod[i][j] += m[i][k] * m[j][k].conj();
                }
            }
        }
        (0..2).all(|i| {
            (0..2).all(|j| {
                let want = if i == j { 1.0 } else { 0.0 };
                (prod[i][j].re - want).abs() < 1e-12 && prod[i][j].im.abs() < 1e-12
            })
        })
    }

    fn is_unitary4(m: &Mat4) -> bool {
        let mut prod = [[C64::ZERO; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                for k in 0..4 {
                    prod[i][j] += m[i][k] * m[j][k].conj();
                }
            }
        }
        (0..4).all(|i| {
            (0..4).all(|j| {
                let want = if i == j { 1.0 } else { 0.0 };
                (prod[i][j].re - want).abs() < 1e-12 && prod[i][j].im.abs() < 1e-12
            })
        })
    }

    #[test]
    fn all_matrices_unitary() {
        for theta in [-2.1, -0.5, 0.0, 0.7, 3.9] {
            assert!(is_unitary2(&rx_matrix(theta)));
            assert!(is_unitary2(&ry_matrix(theta)));
            assert!(is_unitary2(&rz_matrix(theta)));
            assert!(is_unitary4(&ryy_matrix(theta)));
            assert!(is_unitary4(&rzz_matrix(theta)));
            assert!(is_unitary4(&cry_matrix(theta)));
            assert!(is_unitary4(&crz_matrix(theta)));
        }
        assert!(is_unitary2(&h_matrix()));
        assert!(is_unitary4(&cx_matrix()));
    }

    #[test]
    fn zero_angle_is_identity() {
        let m = ry_matrix(0.0);
        assert_eq!(m[0][0], C64::ONE);
        assert_eq!(m[0][1], C64::ZERO);
        let m4 = cry_matrix(0.0);
        assert_eq!(m4[2][2], C64::ONE);
        assert_eq!(m4[3][3], C64::ONE);
    }

    #[test]
    fn wire_round_trip_all_gates() {
        let gates = vec![
            Gate::H { q: 0 },
            Gate::Rx { q: 1, theta: 0.5 },
            Gate::Ry { q: 2, theta: -1.25 },
            Gate::Rz { q: 0, theta: 3.0 },
            Gate::Ryy { q0: 1, q1: 2, theta: 0.75 },
            Gate::Rzz { q0: 0, q1: 3, theta: -0.5 },
            Gate::Cry { control: 1, target: 2, theta: 1.0 },
            Gate::Crz { control: 2, target: 1, theta: 2.0 },
            Gate::Cx { control: 0, target: 1 },
            Gate::Cswap { control: 0, a: 1, b: 3 },
        ];
        for g in gates {
            let w = g.to_wire();
            let back = Gate::from_wire(&w).unwrap();
            assert_eq!(g, back);
        }
    }

    #[test]
    fn from_wire_rejects_garbage() {
        assert!(Gate::from_wire(&Value::Null).is_err());
        assert!(Gate::from_wire(&Value::Arr(vec![Value::Str("bogus".into())])).is_err());
        assert!(Gate::from_wire(&Value::Arr(vec![Value::Str("ry".into())])).is_err());
    }

    #[test]
    fn theta_replacement() {
        let g = Gate::Cry { control: 1, target: 2, theta: 0.5 };
        let g2 = g.with_theta(1.5);
        assert_eq!(g2.theta(), Some(1.5));
        assert!(g2.is_controlled_rotation());
        assert_eq!(Gate::H { q: 0 }.with_theta(9.0), Gate::H { q: 0 });
    }

    #[test]
    fn pair_order_swap_involutive() {
        let m = cry_matrix(0.8);
        let back = swap_pair_order(&swap_pair_order(&m));
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(m[i][j], back[i][j]);
            }
        }
    }
}
