//! `dqulearn` — leader entrypoint and CLI.
//!
//! Subcommands cover the full deployment surface:
//! `manager` / `worker` run the distributed system over TCP; `train` runs
//! a client (against a remote manager or an in-proc cluster); `bench-fig`
//! regenerates the paper's figures through the DES; `accuracy` reproduces
//! the §IV-B table; `info` inspects artifacts.

use dqulearn::circuit::QuClassiConfig;
use dqulearn::cli::{App, CommandSpec, Parsed};
use dqulearn::cluster::{serve_manager, InProcCluster, RemoteClient};
use dqulearn::coordinator::{Manager, ManagerConfig};
use dqulearn::data::Dataset;
use dqulearn::env::{scenarios, Calibration};
use dqulearn::model::exec::{CircuitExecutor, QsimExecutor};
use dqulearn::model::optimizer::Optimizer;
use dqulearn::model::quclassi::LossKind;
use dqulearn::model::{QuClassiModel, TrainConfig, Trainer};
use dqulearn::runtime::{Manifest, PjrtEngine};
use dqulearn::util::{logging, Rng};
use dqulearn::worker::{WorkerHandle, WorkerOptions};

fn app() -> App {
    App {
        name: "dqulearn",
        version: env!("CARGO_PKG_VERSION"),
        about: "distributed quantum learning with co-management (DQuLearn reproduction)",
        commands: vec![
            CommandSpec::new("manager", "run the co-Manager service")
                .opt_default("listen", "listen address", "127.0.0.1:7001")
                .opt_default("heartbeat", "heartbeat period seconds", "5")
                .opt_default("max-batch", "max circuits per dispatch", "32"),
            CommandSpec::new("worker", "run a quantum worker")
                .opt_default("manager", "manager address", "127.0.0.1:7001")
                .opt_default("qubits", "max qubits (MR)", "5")
                .opt_default("artifacts", "AOT artifact directory", "artifacts")
                .opt_default("heartbeat", "heartbeat period seconds", "5")
                .opt_default("listen", "worker listen address", "127.0.0.1:0")
                .opt_default("threads", "simulator thread budget (0 = auto-detect)", "0"),
            CommandSpec::new("train", "train a QuClassi classifier")
                .opt("manager", "remote manager address (else in-proc)")
                .opt_default("in-proc", "in-proc worker qubit list", "5,5")
                .opt_default("pair", "digit pair a,b", "3,9")
                .opt_default("qubits", "circuit width (5 or 7)", "5")
                .opt_default("layers", "variational layers (1-3)", "1")
                .opt_default("epochs", "training epochs", "10")
                .opt_default("lr", "learning rate", "0.05")
                .opt_default("samples", "examples per class", "20")
                .opt_default("seed", "random seed", "42")
                .opt_default("artifacts", "AOT artifact directory", "artifacts")
                .flag("classical", "co-train the conv+dense front")
                .flag("qsim", "force the Rust simulator backend"),
            CommandSpec::new("bench-fig", "regenerate a paper figure via the DES")
                .opt_default("fig", "figure number (3, 4, 5, or 6)", "3")
                .opt_default("seed", "simulation seed", "7"),
            CommandSpec::new("accuracy", "reproduce the accuracy comparison (§IV-B)")
                .opt_default("epochs", "training epochs", "15")
                .opt_default("samples", "examples per class", "20")
                .opt_default("seed", "random seed", "42"),
            CommandSpec::new("info", "inspect AOT artifacts")
                .opt_default("artifacts", "AOT artifact directory", "artifacts"),
        ],
    }
}

fn main() {
    if let Ok(level) = std::env::var("DQULEARN_LOG") {
        if let Some(l) = logging::Level::from_str_loose(&level) {
            logging::set_level(l);
        }
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match app().parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let result = match parsed.command.as_str() {
        "manager" => cmd_manager(&parsed),
        "worker" => cmd_worker(&parsed),
        "train" => cmd_train(&parsed),
        "bench-fig" => cmd_bench_fig(&parsed),
        "accuracy" => cmd_accuracy(&parsed),
        "info" => cmd_info(&parsed),
        other => Err(format!("unhandled command {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_manager(p: &Parsed) -> Result<(), String> {
    let listen = p.get_or("listen", "127.0.0.1:7001");
    let heartbeat = p.get_f64("heartbeat").map_err(|e| e.to_string())?.unwrap_or(5.0);
    let max_batch = p.get_usize("max-batch").map_err(|e| e.to_string())?.unwrap_or(32);
    let manager = Manager::new(ManagerConfig {
        heartbeat_period: heartbeat,
        max_batch,
        ..Default::default()
    });
    let server = serve_manager(manager, &listen).map_err(|e| e.to_string())?;
    println!("co-manager listening on {}", server.local_addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_worker(p: &Parsed) -> Result<(), String> {
    let opts = WorkerOptions {
        max_qubits: p.get_usize("qubits").map_err(|e| e.to_string())?.unwrap_or(5),
        artifact_dir: p.get_or("artifacts", "artifacts").into(),
        heartbeat_period: p.get_f64("heartbeat").map_err(|e| e.to_string())?.unwrap_or(5.0),
        listen: p.get_or("listen", "127.0.0.1:0"),
        threads: p.get_usize("threads").map_err(|e| e.to_string())?.unwrap_or(0),
    };
    let manager = p.get_or("manager", "127.0.0.1:7001");
    let handle = WorkerHandle::start(&manager, opts)?;
    println!("worker w{} serving on {}", handle.worker_id, handle.listen_addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn parse_pair(p: &Parsed) -> Result<(u8, u8), String> {
    let pair = p.get_or("pair", "3,9");
    let parts: Vec<&str> = pair.split(',').collect();
    if parts.len() != 2 {
        return Err(format!("--pair must be 'a,b', got '{pair}'"));
    }
    let a = parts[0].trim().parse::<u8>().map_err(|e| e.to_string())?;
    let b = parts[1].trim().parse::<u8>().map_err(|e| e.to_string())?;
    Ok((a, b))
}

fn cmd_train(p: &Parsed) -> Result<(), String> {
    let (a, b) = parse_pair(p)?;
    let qubits = p.get_usize("qubits").map_err(|e| e.to_string())?.unwrap_or(5);
    let layers = p.get_usize("layers").map_err(|e| e.to_string())?.unwrap_or(1);
    let epochs = p.get_usize("epochs").map_err(|e| e.to_string())?.unwrap_or(10);
    let samples = p.get_usize("samples").map_err(|e| e.to_string())?.unwrap_or(20);
    let lr = p.get_f64("lr").map_err(|e| e.to_string())?.unwrap_or(0.05) as f32;
    let seed = p.get_usize("seed").map_err(|e| e.to_string())?.unwrap_or(42) as u64;
    let config = QuClassiConfig::new(qubits, layers)?;
    let dataset = Dataset::binary_pair(None, a, b, samples, seed);

    let exec: Box<dyn CircuitExecutor> = if let Some(addr) = p.get("manager") {
        Box::new(RemoteClient::connect(addr)?)
    } else if p.has_flag("qsim") {
        Box::new(QsimExecutor)
    } else {
        let worker_qubits = p
            .get_usize_list("in-proc")
            .map_err(|e| e.to_string())?
            .unwrap_or(vec![5, 5]);
        let mut builder = InProcCluster::builder().workers(&worker_qubits);
        let artifacts = p.get_or("artifacts", "artifacts");
        if std::path::Path::new(&artifacts).join("manifest.json").exists() {
            builder = builder.artifacts(artifacts);
        }
        Box::new(builder.build()?)
    };
    println!(
        "training {a}-vs-{b} (q={qubits}, l={layers}) on {} for {epochs} epochs",
        exec.describe()
    );

    let mut model = QuClassiModel::new(config, &mut Rng::new(seed));
    let trainer = Trainer::new(TrainConfig {
        epochs,
        optimizer: Optimizer::adam(lr),
        train_classical: p.has_flag("classical"),
        classical_lr_scale: 0.1,
        seed,
        early_stop_acc: None,
            loss: LossKind::Discriminative,
    });
    let report = trainer.train(&mut model, &dataset, exec.as_ref())?;
    for e in &report.epochs {
        println!(
            "epoch {:>3}: loss {:.4}  acc {:.3}  circuits {:>6}  {:.2}s",
            e.epoch, e.mean_loss, e.train_accuracy, e.circuits, e.wall_seconds
        );
    }
    println!(
        "final: train acc {:.3}, test acc {:.3}, {} circuits in {:.2}s ({:.1} circuits/s)",
        report.final_train_accuracy(),
        report.test_accuracy,
        report.total_circuits,
        report.total_seconds,
        report.circuits_per_second()
    );
    Ok(())
}

fn cmd_bench_fig(p: &Parsed) -> Result<(), String> {
    let fig = p.get_usize("fig").map_err(|e| e.to_string())?.unwrap_or(3);
    let seed = p.get_usize("seed").map_err(|e| e.to_string())?.unwrap_or(7) as u64;
    let calib = Calibration::qiskit_like();
    match fig {
        3 | 4 => {
            let qubits = if fig == 3 { 5 } else { 7 };
            let rows = scenarios::ibmq_figure(qubits, &calib, seed);
            print_figure_rows(&format!("Figure {fig}: {qubits}-qubit IBM-Q (uncontrolled)"), &rows);
        }
        5 => {
            let rows = scenarios::gcp_one_client_figure(5, &calib, seed);
            print_figure_rows("Figure 5: 5-qubit controlled environment (one client)", &rows);
        }
        6 => {
            let rows = scenarios::multi_tenant_figure(&calib, seed);
            println!("Figure 6: multi-tenant system (4 clients; workers 5/10/15/20 qubits)");
            println!(
                "{:<8} {:>9} {:>14} {:>14} {:>10} {:>10}",
                "job", "circuits", "single(s)", "multi(s)", "red.%", "cps gain"
            );
            for r in &rows {
                println!(
                    "{:<8} {:>9} {:>14.1} {:>14.1} {:>10.1} {:>9.2}x",
                    r.label,
                    r.circuits,
                    r.single_runtime,
                    r.multi_runtime,
                    r.runtime_reduction_pct(),
                    r.cps_gain()
                );
            }
        }
        other => return Err(format!("unknown figure {other} (expected 3-6)")),
    }
    Ok(())
}

fn print_figure_rows(title: &str, rows: &[scenarios::FigureRow]) {
    println!("{title}");
    println!(
        "{:>6} {:>8} {:>9} {:>12} {:>12}",
        "layers", "workers", "circuits", "runtime(s)", "circ/s"
    );
    for r in rows {
        println!(
            "{:>6} {:>8} {:>9} {:>12.1} {:>12.2}",
            r.layers, r.workers, r.circuits, r.runtime, r.cps
        );
    }
}

fn cmd_accuracy(p: &Parsed) -> Result<(), String> {
    let epochs = p.get_usize("epochs").map_err(|e| e.to_string())?.unwrap_or(15);
    let samples = p.get_usize("samples").map_err(|e| e.to_string())?.unwrap_or(20);
    let seed = p.get_usize("seed").map_err(|e| e.to_string())?.unwrap_or(42) as u64;
    println!("accuracy comparison (distributed 2-worker vs non-distributed), {epochs} epochs");
    println!("{:>6} {:>14} {:>14} {:>8}", "pair", "distributed", "baseline", "delta");
    for (a, b) in [(3u8, 9u8), (3, 8), (3, 6), (1, 5)] {
        let config = QuClassiConfig::new(5, 1)?;
        let dataset = Dataset::binary_pair(None, a, b, samples, seed);
        let tc = TrainConfig {
            epochs,
            optimizer: Optimizer::adam(0.05),
            train_classical: true,
            classical_lr_scale: 0.1,
            seed,
            early_stop_acc: None,
            loss: LossKind::Discriminative,
        };
        // distributed: 2 in-proc workers
        let cluster = InProcCluster::builder().workers(&[5, 5]).build()?;
        let mut m_dist = QuClassiModel::new(config, &mut Rng::new(seed));
        let dist = Trainer::new(tc.clone()).train(&mut m_dist, &dataset, &cluster)?;
        cluster.shutdown();
        // baseline: local simulator
        let mut m_base = QuClassiModel::new(config, &mut Rng::new(seed));
        let base = Trainer::new(tc).train(&mut m_base, &dataset, &QsimExecutor)?;
        println!(
            "{:>3}/{:<3} {:>13.1}% {:>13.1}% {:>7.2}%",
            a,
            b,
            dist.test_accuracy * 100.0,
            base.test_accuracy * 100.0,
            (dist.test_accuracy - base.test_accuracy).abs() * 100.0
        );
    }
    Ok(())
}

fn cmd_info(p: &Parsed) -> Result<(), String> {
    let dir = p.get_or("artifacts", "artifacts");
    let manifest = Manifest::load(std::path::Path::new(&dir))?;
    println!("artifacts in {dir}:");
    for a in &manifest.artifacts {
        println!(
            "  {:<16} q={} l={} P={:>2} D={} batch={} file={}",
            a.name,
            a.config.qubits,
            a.config.layers,
            a.n_params,
            a.n_features,
            a.batch,
            a.path.display()
        );
    }
    // smoke-compile one artifact to prove the runtime path works
    let engine = PjrtEngine::load(std::path::Path::new(&dir))?;
    let cfg = manifest.artifacts[0].config;
    let fids = engine.execute(
        &cfg,
        &[(vec![0.3; cfg.n_params()], vec![0.7; cfg.n_features()])],
    )?;
    println!("pjrt smoke execution ok: fid = {:.6}", fids[0]);
    engine.shutdown();
    Ok(())
}
