//! Workload assignment (Algorithm 2 lines 14-20).
//!
//! For a pending circuit with demand `D`: collect workers with `AR > D`
//! into the Candidates set, sort ascending by latest `CRU`, return the
//! first. The paper's linear scan is O(W); a binary-heap variant
//! (`SchedulerKind::Heap`) is provided as an ablation (DESIGN.md §10) —
//! identical selection, O(log W) amortized when the candidate predicate
//! is stable between calls.

use super::registry::{Registry, WorkerId};

/// Scheduler implementation choice (ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// The paper's algorithm: filter + sort by CRU each call.
    LinearScan,
    /// Min-heap over (CRU, AR) rebuilt lazily.
    Heap,
}

/// Select the best worker for a circuit of `demand` qubits, or `None`
/// when no worker currently qualifies (caller backs off until capacity
/// frees up).
///
/// Tie-break: equal CRU falls back to more available qubits, then lower
/// id — deterministic selection makes the DES reproducible.
pub fn select_worker(registry: &Registry, demand: usize) -> Option<WorkerId> {
    // Candidates: AR > D (strict, as the paper writes it).
    let mut best: Option<(f64, std::cmp::Reverse<usize>, WorkerId)> = None;
    for w in registry.workers() {
        if w.available() > demand {
            let key = (w.cru, std::cmp::Reverse(w.available()), w.id);
            if best.is_none()
                || (key.0, key.1, key.2) < (best.unwrap().0, best.unwrap().1, best.unwrap().2)
            {
                best = Some(key);
            }
        }
    }
    best.map(|(_, _, id)| id)
}

/// Select with a relaxed predicate `AR >= D` — used when *no* worker in
/// the whole system has `AR > D` capacity (e.g. a 5-qubit circuit on a
/// 5-qubit worker, the paper's own 5Q-worker experiments), where the
/// strict rule would deadlock.
pub fn select_worker_relaxed(registry: &Registry, demand: usize) -> Option<WorkerId> {
    let mut best: Option<(f64, std::cmp::Reverse<usize>, WorkerId)> = None;
    for w in registry.workers() {
        if w.available() >= demand {
            let key = (w.cru, std::cmp::Reverse(w.available()), w.id);
            if best.is_none()
                || (key.0, key.1, key.2) < (best.unwrap().0, best.unwrap().1, best.unwrap().2)
            {
                best = Some(key);
            }
        }
    }
    best.map(|(_, _, id)| id)
}

/// Two-phase selection used by the manager: strict Algorithm-2 rule
/// first, relaxed exact-fit second. Returns `None` only when the circuit
/// cannot currently be placed anywhere.
pub fn select(registry: &Registry, demand: usize) -> Option<WorkerId> {
    select_worker(registry, demand).or_else(|| select_worker_relaxed(registry, demand))
}

/// Would this circuit *ever* fit on the current worker set?
pub fn can_ever_fit(registry: &Registry, demand: usize) -> bool {
    registry.workers().any(|w| w.max_qubits >= demand)
}

/// Selection through an explicit binary heap of candidates — semantically
/// identical to [`select`], kept as the ablation comparator benched in
/// `micro_scheduler` (the paper's linear scan wins at W <= dozens).
pub fn select_with(kind: SchedulerKind, registry: &Registry, demand: usize) -> Option<WorkerId> {
    match kind {
        SchedulerKind::LinearScan => select(registry, demand),
        SchedulerKind::Heap => {
            use std::cmp::Reverse;
            use std::collections::BinaryHeap;
            let mut heap: BinaryHeap<Reverse<(u64, Reverse<usize>, WorkerId)>> = registry
                .workers()
                .filter(|w| w.available() > demand)
                .map(|w| Reverse((f64_key(w.cru), Reverse(w.available()), w.id)))
                .collect();
            if heap.is_empty() {
                heap = registry
                    .workers()
                    .filter(|w| w.available() >= demand)
                    .map(|w| Reverse((f64_key(w.cru), Reverse(w.available()), w.id)))
                    .collect();
            }
            heap.pop().map(|Reverse((_, _, id))| id)
        }
    }
}

/// Order-preserving integer key for a non-negative f64 (CRU is in [0, 1]).
fn f64_key(x: f64) -> u64 {
    (x.max(0.0) * 1e12) as u64
}

/// The pool's noise eligibility bound under `alpha`: a worker qualifies
/// for noise-aware placement — and, since PR 10, for *stealing* work —
/// only if its noise is ≤ `lo + (1 - alpha)·(hi - lo)` over the
/// registered fleet (plus an epsilon so the cleanest worker always
/// qualifies). `None` when the registry is empty. Shared by
/// [`select_noise_aware`], `Manager::steal_for`, and the DES mirror so
/// the placement and steal policies can never drift apart.
pub fn noise_cutoff(registry: &Registry, alpha: f64) -> Option<f64> {
    let alpha = alpha.clamp(0.0, 1.0);
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for w in registry.workers() {
        lo = lo.min(w.noise);
        hi = hi.max(w.noise);
    }
    if !lo.is_finite() {
        return None;
    }
    Some(lo + (1.0 - alpha) * (hi - lo) + 1e-12)
}

/// Noise-aware selection (extension — the paper's Discussion lists
/// noise-awareness as future work).
///
/// `alpha` gates which workers are *eligible*: a worker qualifies only if
/// its noise is within `(1 - alpha)` of the pool's noise range above the
/// cleanest worker. `alpha = 0` admits everyone (the paper's CRU-only
/// rule); `alpha = 1` admits only least-noise workers — circuits then
/// WAIT for clean backends instead of spilling onto noisy ones (the
/// fidelity/latency trade-off quantified in `ablation_noise`). Within
/// the eligible set, ranking is Algorithm 2's CRU-ascending.
pub fn select_noise_aware(registry: &Registry, demand: usize, alpha: f64) -> Option<WorkerId> {
    let cutoff = noise_cutoff(registry, alpha)?;
    let mut best: Option<(u64, std::cmp::Reverse<usize>, WorkerId)> = None;
    let pass = |strict: bool, best: &mut Option<(u64, std::cmp::Reverse<usize>, WorkerId)>| {
        for w in registry.workers() {
            let fits = if strict { w.available() > demand } else { w.available() >= demand };
            if fits && w.noise <= cutoff {
                let key = (f64_key(w.cru), std::cmp::Reverse(w.available()), w.id);
                if best.is_none() || key < best.unwrap() {
                    *best = Some(key);
                }
            }
        }
    };
    pass(true, &mut best);
    if best.is_none() {
        pass(false, &mut best);
    }
    best.map(|(_, _, id)| id)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry_with(workers: &[(usize, f64)]) -> (Registry, Vec<WorkerId>) {
        let mut r = Registry::new(5.0);
        let ids = workers.iter().map(|&(mq, cru)| r.register(mq, cru, 0.0)).collect();
        (r, ids)
    }

    #[test]
    fn filters_by_available_qubits() {
        let (mut r, ids) = registry_with(&[(5, 0.1), (20, 0.9)]);
        // 7-qubit demand: only the 20-qubit worker qualifies
        assert_eq!(select_worker(&r, 7), Some(ids[1]));
        // occupy 15 of the big worker -> nothing has AR > 7
        r.reserve(ids[1], 1, 15).unwrap();
        assert_eq!(select_worker(&r, 7), None);
    }

    #[test]
    fn sorts_candidates_by_cru_ascending() {
        let (r, ids) = registry_with(&[(20, 0.8), (20, 0.2), (20, 0.5)]);
        assert_eq!(select_worker(&r, 5), Some(ids[1]));
    }

    #[test]
    fn tie_break_prefers_more_available() {
        let (mut r, ids) = registry_with(&[(10, 0.5), (20, 0.5)]);
        assert_eq!(select_worker(&r, 5), Some(ids[1]));
        r.reserve(ids[1], 1, 14).unwrap(); // 20-q worker now has 6 available
        assert_eq!(select_worker(&r, 5), Some(ids[0]));
    }

    #[test]
    fn relaxed_allows_exact_fit() {
        let (r, ids) = registry_with(&[(5, 0.1)]);
        // strict rule: AR(5) > 5 is false
        assert_eq!(select_worker(&r, 5), None);
        // relaxed rule: AR(5) >= 5 -> the paper's own 5Q/5-qubit-worker runs
        assert_eq!(select_worker_relaxed(&r, 5), Some(ids[0]));
        assert_eq!(select(&r, 5), Some(ids[0]));
    }

    #[test]
    fn can_ever_fit_checks_max_not_available() {
        let (mut r, ids) = registry_with(&[(7, 0.0)]);
        r.reserve(ids[0], 1, 7).unwrap();
        assert!(can_ever_fit(&r, 7)); // busy now, but it can fit later
        assert!(!can_ever_fit(&r, 9));
    }

    #[test]
    fn empty_registry_selects_nothing() {
        let r = Registry::new(5.0);
        assert_eq!(select(&r, 5), None);
        assert!(!can_ever_fit(&r, 5));
    }

    #[test]
    fn noise_aware_gates_candidates() {
        let mut r = Registry::new(5.0);
        let clean = r.register_with_noise(10, 0.9, 0.0, 0.0); // busy but clean
        let noisy = r.register_with_noise(10, 0.0, 0.05, 0.0); // idle but noisy
        // alpha = 0: paper rule, lowest CRU wins -> the noisy worker
        assert_eq!(select_noise_aware(&r, 5, 0.0), Some(noisy));
        // alpha = 1: only least-noise workers eligible -> the clean one
        assert_eq!(select_noise_aware(&r, 5, 1.0), Some(clean));
    }

    #[test]
    fn noise_aware_waits_instead_of_spilling() {
        let mut r = Registry::new(5.0);
        let clean = r.register_with_noise(5, 0.0, 0.0, 0.0);
        let _noisy = r.register_with_noise(5, 0.0, 0.05, 0.0);
        r.reserve(clean, 1, 5).unwrap(); // clean worker fully busy
        // strict alpha: nothing eligible -> None (circuit waits)
        assert_eq!(select_noise_aware(&r, 5, 1.0), None);
        // paper rule would spill to the noisy worker
        assert!(select(&r, 5).is_some());
    }

    #[test]
    fn noise_aware_uniform_pool_equals_paper_rule() {
        let (mut r, _ids) = registry_with(&[(10, 0.8), (10, 0.2), (10, 0.5)]);
        for alpha in [0.0, 0.5, 1.0] {
            assert_eq!(select_noise_aware(&r, 5, alpha), select(&r, 5));
        }
        let _ = &mut r;
    }

    #[test]
    fn multi_tenant_packing() {
        // A 20-qubit worker can host four 5-qubit circuits concurrently
        // (the paper's multi-tenant scenario).
        let (mut r, ids) = registry_with(&[(20, 0.0)]);
        for job in 0..3 {
            let w = select(&r, 5).unwrap();
            assert_eq!(w, ids[0]);
            r.reserve(w, job, 5).unwrap();
        }
        // fourth circuit: AR = 5, strict fails, relaxed succeeds
        let w = select(&r, 5).unwrap();
        r.reserve(w, 3, 5).unwrap();
        assert_eq!(r.get(ids[0]).unwrap().available(), 0);
        assert_eq!(select(&r, 5), None);
    }
}
