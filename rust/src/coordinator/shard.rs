//! Sharded co-Manager (DESIGN.md §18): N independent [`Manager`] shards
//! behind one facade, for deployments where a single registry lock and
//! event condvar become the ceiling ("millions of users", ROADMAP).
//!
//! Every shard is a full co-Manager — its own admission queue, registry,
//! outbox directory, stats, assigner/liveness threads, and journal
//! *segment* (`<path>.shard<i>`). Nothing is shared between shards on
//! the hot path: a submit, dispatch, completion, or steal on shard 0
//! never touches shard 1's locks.
//!
//! **Routing is arithmetic, not state.** Shard `i` of `n` allocates
//! bank/client/worker ids congruent to `i` modulo `n` (id striping,
//! [`Manager::with_clock_striped`]), so `id % n` recovers the owning
//! shard for any id without a routing table — and the same function is
//! mirrored by the discrete-event simulation for deterministic replay
//! (`env/sim.rs`).
//!
//! **Cross-shard work stealing** engages only when a shard's own pool is
//! idle: a broker thread watches for thief shards with an empty queue
//! and free qubits, carves a WRR-fair batch out of the deepest-backlog
//! sibling ([`Manager::export_batch`] — WAL'd and accounted on the
//! victim, where the bank lives), executes it on the thief's pool
//! ([`Manager::run_foreign`]), and routes the outcome back through the
//! victim's normal completion path ([`Manager::finish_exported`]).
//! Failures re-queue on the victim; a crash mid-export recovers
//! conservatively (the batch counts as in-flight, so its bank fails
//! `WorkerLost` — same rule as home-shard in-flight work).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Weak};
use std::time::Duration;

use super::bankstore::BankStatus;
use super::journal::JournalConfig;
use super::manager::{Manager, ManagerConfig, ManagerStats, RecoveryReport, WorkerChannel};
use super::registry::{WorkerId, WorkerProfile, WorkerState};
use super::session::{ClientSession, SessionOps};
use crate::circuit::QuClassiConfig;
use crate::error::DqError;
use crate::model::exec::CircuitPair;
use crate::util::{Clock, SystemClock};

/// Sharded-manager tuning knobs.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Number of shards (clamped to >= 1; 1 is an unsharded manager
    /// behind the same facade).
    pub shards: usize,
    /// Per-shard manager config. With [`ManagerConfig::journal`] set,
    /// shard `i` journals to `<path>.shard<i>` — independent segments,
    /// recovered independently.
    pub manager: ManagerConfig,
    /// Cross-shard steal broker poll period. The broker only *observes*
    /// (queue depths, free qubits); all real work happens on transient
    /// steal threads, so a short tick costs little.
    pub steal_tick: Duration,
    /// Max concurrent cross-shard foreign executions (caps transient
    /// steal threads). `0` disables cross-shard stealing entirely.
    pub max_foreign: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: 1,
            manager: ManagerConfig::default(),
            steal_tick: Duration::from_millis(2),
            max_foreign: 8,
        }
    }
}

/// Per-shard journal segment config: `<path>.shard<i>`.
fn shard_journal(jc: &JournalConfig, i: usize) -> JournalConfig {
    let mut out = jc.clone();
    let mut path = jc.path.as_os_str().to_owned();
    path.push(format!(".shard{i}"));
    out.path = path.into();
    out
}

struct ShardInner {
    shards: Vec<Manager>,
    cfg: ShardConfig,
    /// Round-robin cursors (registration spread / session spread).
    rr_worker: AtomicU64,
    rr_client: AtomicU64,
    /// Batches moved between shards by the broker (the per-shard
    /// `ManagerStats::steals` counters include these on the victim).
    cross_steals: AtomicU64,
    /// Transient foreign executions in flight (bounded by
    /// `ShardConfig::max_foreign`).
    active_foreign: AtomicU64,
    stop: AtomicBool,
}

impl Drop for ShardInner {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// N co-Manager shards behind the [`Manager`]-shaped API. Cheap to
/// clone (shared state). See the module docs for the sharding model.
#[derive(Clone)]
pub struct ShardManager {
    inner: Arc<ShardInner>,
}

impl ShardManager {
    /// Start a sharded co-Manager on the system clock.
    pub fn new(cfg: ShardConfig) -> ShardManager {
        Self::with_clock(cfg, Arc::new(SystemClock::new()))
    }

    /// Start a sharded co-Manager on an explicit clock. Fresh journal
    /// segments are created per shard when journaling is configured.
    pub fn with_clock(mut cfg: ShardConfig, clock: Arc<dyn Clock>) -> ShardManager {
        cfg.shards = cfg.shards.max(1);
        let n = cfg.shards;
        let shards = (0..n)
            .map(|i| {
                let mut mc = cfg.manager.clone();
                if let Some(jc) = &cfg.manager.journal {
                    mc.journal = Some(shard_journal(jc, i));
                }
                Manager::with_clock_striped(mc, clock.clone(), (i as u64, n as u64))
            })
            .collect();
        Self::build(shards, cfg)
    }

    /// Restart a sharded co-Manager from its journal segments
    /// (`<path>.shard<i>`, all of which must exist — recover with the
    /// same shard count the previous incarnation ran). Reports are
    /// aggregated across shards.
    pub fn recover(cfg: ShardConfig) -> Result<(ShardManager, RecoveryReport), DqError> {
        Self::recover_with_clock(cfg, Arc::new(SystemClock::new()))
    }

    /// [`ShardManager::recover`] on an explicit clock.
    pub fn recover_with_clock(
        mut cfg: ShardConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<(ShardManager, RecoveryReport), DqError> {
        cfg.shards = cfg.shards.max(1);
        let n = cfg.shards;
        let Some(jc) = cfg.manager.journal.clone() else {
            return Err(DqError::Protocol(
                "ShardManager::recover requires ManagerConfig::journal".to_string(),
            ));
        };
        let mut shards = Vec::with_capacity(n);
        let mut report = RecoveryReport::default();
        for i in 0..n {
            let mut mc = cfg.manager.clone();
            mc.journal = Some(shard_journal(&jc, i));
            let (m, r) =
                Manager::recover_striped(mc, clock.clone(), (i as u64, n as u64))?;
            report.records += r.records;
            report.truncated_bytes += r.truncated_bytes;
            report.banks_restored += r.banks_restored;
            report.banks_failed += r.banks_failed;
            report.circuits_readmitted += r.circuits_readmitted;
            report.cancelled_ids += r.cancelled_ids;
            shards.push(m);
        }
        Ok((Self::build(shards, cfg), report))
    }

    fn build(shards: Vec<Manager>, cfg: ShardConfig) -> ShardManager {
        let sm = ShardManager {
            inner: Arc::new(ShardInner {
                shards,
                cfg,
                rr_worker: AtomicU64::new(0),
                rr_client: AtomicU64::new(0),
                cross_steals: AtomicU64::new(0),
                active_foreign: AtomicU64::new(0),
                stop: AtomicBool::new(false),
            }),
        };
        if sm.inner.cfg.shards > 1
            && sm.inner.cfg.max_foreign > 0
            && sm.inner.cfg.manager.steal
        {
            let weak = Arc::downgrade(&sm.inner);
            std::thread::Builder::new()
                .name("xshard-broker".into())
                .spawn(move || ShardManager::broker_thread(weak))
                .expect("spawn cross-shard broker");
        }
        sm
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// Direct handle onto one shard (tests, admin tooling).
    pub fn shard(&self, i: usize) -> &Manager {
        &self.inner.shards[i]
    }

    /// Owning shard of any striped id (bank, client, or worker).
    fn route(&self, id: u64) -> &Manager {
        &self.inner.shards[(id % self.inner.shards.len() as u64) as usize]
    }

    /// Batches moved between shards by the steal broker.
    pub fn cross_steals(&self) -> u64 {
        self.inner.cross_steals.load(Ordering::Relaxed)
    }

    // ------------------------------------------------------------------
    // Manager-shaped API (routing by id stripe)
    // ------------------------------------------------------------------

    /// Open a typed client session. The tenant is pinned to one shard
    /// (round-robin over shards at allocation; the striped client id
    /// routes every later call back to it).
    pub fn session(&self) -> ClientSession {
        let client = self.new_client();
        ClientSession::new(Arc::new(self.clone()), client)
    }

    /// Allocate a raw client id on the next shard in round-robin order
    /// (prefer [`ShardManager::session`]).
    pub fn new_client(&self) -> u64 {
        let n = self.inner.shards.len() as u64;
        let i = self.inner.rr_client.fetch_add(1, Ordering::Relaxed) % n;
        self.inner.shards[i as usize].new_client()
    }

    /// Register a worker on the least-populated shard (keeps per-shard
    /// pools balanced under heterogeneous join order). The striped
    /// worker id routes heartbeats back.
    pub fn register(&self, profile: WorkerProfile, channel: Arc<dyn WorkerChannel>) -> WorkerId {
        let mut best = self.inner.rr_worker.fetch_add(1, Ordering::Relaxed) as usize
            % self.inner.shards.len();
        let mut best_count = usize::MAX;
        for (i, m) in self.inner.shards.iter().enumerate() {
            let c = m.worker_count();
            if c < best_count {
                best_count = c;
                best = i;
            }
        }
        self.inner.shards[best].register(profile, channel)
    }

    /// Heartbeat, routed to the worker's owning shard.
    pub fn heartbeat(&self, worker: WorkerId, cru: f64) -> Result<(), DqError> {
        self.route(worker).heartbeat(worker, cru)
    }

    /// Submit a bank on the client's owning shard.
    pub fn submit_bank(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError> {
        self.route(client).submit_bank(client, config, pairs)
    }

    /// Consuming wait, routed by bank id.
    pub fn wait_bank(&self, bank: u64) -> Result<Vec<f32>, DqError> {
        self.route(bank).wait_bank(bank)
    }

    /// Timed wait, routed by bank id.
    pub fn wait_bank_timeout(&self, bank: u64, timeout: Duration) -> Result<Vec<f32>, DqError> {
        self.route(bank).wait_bank_timeout(bank, timeout)
    }

    /// Non-blocking bank snapshot, routed by bank id.
    pub fn bank_status(&self, bank: u64) -> Option<BankStatus> {
        self.route(bank).bank_status(bank)
    }

    /// Cancellation tombstone check, routed by bank id.
    pub fn bank_cancelled(&self, bank: u64) -> bool {
        self.route(bank).bank_cancelled(bank)
    }

    /// Progress watcher registration on the bank's owning shard (the
    /// binary plane's `subscribe_bank`; events stream from that shard's
    /// bank store exactly as in the single-shard manager).
    pub fn watch_bank(&self, bank: u64, w: super::bankstore::BankWatcher) -> bool {
        self.route(bank).watch_bank(bank, w)
    }

    /// Cancel a bank on its owning shard.
    pub fn cancel_bank(&self, bank: u64) -> usize {
        self.route(bank).cancel_bank(bank)
    }

    /// Set a tenant's WRR weight on its owning shard (durable there,
    /// like [`Manager::set_tenant_weight`]).
    pub fn set_tenant_weight(&self, client: u64, weight: u32) {
        self.route(client).set_tenant_weight(client, weight)
    }

    /// Aggregate counters across shards. Id striping keeps per-tenant
    /// key spaces disjoint, so the merge never collides two tenants; a
    /// batch stolen cross-shard is counted once, on its home (victim)
    /// shard.
    pub fn stats(&self) -> ManagerStats {
        let mut out = ManagerStats::default();
        for m in &self.inner.shards {
            let s = m.stats();
            out.submitted += s.submitted;
            out.completed += s.completed;
            out.dispatches += s.dispatches;
            out.requeues += s.requeues;
            out.evictions += s.evictions;
            out.cancelled += s.cancelled;
            out.steals += s.steals;
            out.pruned_tenants += s.pruned_tenants;
            out.retired.merge(&s.retired);
            for (client, t) in s.per_tenant {
                out.per_tenant.entry(client).or_default().merge(&t);
            }
        }
        out
    }

    /// Every worker across all shards.
    pub fn worker_states(&self) -> Vec<WorkerState> {
        self.inner.shards.iter().flat_map(|m| m.worker_states()).collect()
    }

    /// Live workers across all shards.
    pub fn worker_count(&self) -> usize {
        self.inner.shards.iter().map(|m| m.worker_count()).sum()
    }

    /// Pending circuits across all shards.
    pub fn queue_len(&self) -> usize {
        self.inner.shards.iter().map(|m| m.queue_len()).sum()
    }

    /// Free qubits across all shards.
    pub fn available_qubits(&self) -> usize {
        self.inner.shards.iter().map(|m| m.available_qubits()).sum()
    }

    /// Stop the broker and shut every shard down.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        for m in &self.inner.shards {
            m.shutdown();
        }
    }

    // ------------------------------------------------------------------
    // cross-shard steal broker
    // ------------------------------------------------------------------

    /// Broker loop: for each *idle* thief shard (empty queue, live
    /// workers, free qubits) move one batch per tick from the
    /// deepest-backlog sibling. Execution happens on a transient thread
    /// so a slow foreign batch never blocks the broker's next scan.
    fn broker_thread(weak: Weak<ShardInner>) {
        loop {
            let Some(inner) = weak.upgrade() else { return };
            if inner.stop.load(Ordering::Relaxed) {
                return;
            }
            let tick = inner.cfg.steal_tick;
            let sm = ShardManager { inner };
            sm.broker_pass();
            drop(sm);
            std::thread::sleep(tick.max(Duration::from_micros(100)));
        }
    }

    /// One broker scan (separated out for deterministic tests).
    pub(crate) fn broker_pass(&self) {
        let n = self.inner.shards.len();
        for thief_idx in 0..n {
            if self.inner.active_foreign.load(Ordering::Relaxed)
                >= self.inner.cfg.max_foreign as u64
            {
                return;
            }
            let thief = &self.inner.shards[thief_idx];
            // Idle means this shard's own pool has nothing to do: its
            // queue is empty but it has live capacity. Cross-shard
            // stealing never competes with home-shard work.
            if thief.queue_len() != 0
                || thief.worker_count() == 0
                || thief.available_qubits() == 0
            {
                continue;
            }
            // Deepest-backlog sibling first (mirrors the in-shard
            // victim order, DESIGN.md §14).
            let victim_idx = (0..n)
                .filter(|&i| i != thief_idx)
                .map(|i| (self.inner.shards[i].queue_len(), i))
                .filter(|&(depth, _)| depth > 0)
                .max_by_key(|&(depth, _)| depth)
                .map(|(_, i)| i);
            let Some(victim_idx) = victim_idx else { continue };
            let avail = thief.available_qubits();
            let exported =
                self.inner.shards[victim_idx].export_batch(&|demand| demand <= avail);
            let Some((config, jobs, pairs, demand)) = exported else { continue };
            self.inner.cross_steals.fetch_add(1, Ordering::Relaxed);
            self.inner.active_foreign.fetch_add(1, Ordering::Relaxed);
            crate::log_debug!(
                "shard",
                "shard {thief_idx} stole a {}-circuit batch from shard {victim_idx}",
                jobs.len()
            );
            let thief = thief.clone();
            let victim = self.inner.shards[victim_idx].clone();
            let inner = self.inner.clone();
            let spawned = std::thread::Builder::new()
                .name("xshard-steal".into())
                .spawn(move || {
                    let res = thief.run_foreign(&config, &pairs, demand);
                    victim.finish_exported(jobs, res);
                    inner.active_foreign.fetch_sub(1, Ordering::Relaxed);
                });
            if let Err(e) = spawned {
                // Spawn failure drops the closure (and the exported
                // jobs with it): the batch stays in-flight on the
                // victim until its bank's wait timeout reaps it.
                // Thread-spawn failure is an OS-resource emergency;
                // surfacing it beats building a return path for it.
                crate::log_warn!("shard", "cross-shard steal thread failed to spawn: {e}");
                self.inner.active_foreign.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }
}

impl SessionOps for ShardManager {
    fn submit(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError> {
        self.submit_bank(client, config, pairs)
    }

    fn wait(&self, bank: u64, timeout: Option<Duration>) -> Result<Vec<f32>, DqError> {
        match timeout {
            Some(t) => self.wait_bank_timeout(bank, t),
            None => self.wait_bank(bank),
        }
    }

    fn status(&self, bank: u64) -> Result<BankStatus, DqError> {
        self.bank_status(bank).ok_or_else(|| {
            if self.bank_cancelled(bank) {
                DqError::Cancelled(format!("bank {bank} cancelled"))
            } else {
                DqError::Protocol(format!("unknown bank {bank}"))
            }
        })
    }

    fn cancel(&self, bank: u64) -> Result<usize, DqError> {
        Ok(self.cancel_bank(bank))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::exec::QsimExecutor;
    use crate::model::CircuitExecutor;

    struct SimChannel;

    impl WorkerChannel for SimChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            QsimExecutor.execute_bank(config, pairs)
        }
    }

    fn pairs_for(config: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
        let mut rng = crate::util::Rng::new(11);
        (0..n)
            .map(|_| {
                (
                    (0..config.n_params()).map(|_| rng.f32()).collect(),
                    (0..config.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn ids_stripe_by_shard() {
        let sm = ShardManager::new(ShardConfig { shards: 4, ..ShardConfig::default() });
        for _ in 0..8 {
            let w = sm.register(WorkerProfile::new(8), Arc::new(SimChannel));
            // worker ids route back to some shard that knows them
            assert!(sm.heartbeat(w, 0.1).is_ok());
        }
        let mut seen_shards = std::collections::HashSet::new();
        for _ in 0..8 {
            let c = sm.new_client();
            seen_shards.insert(c % 4);
        }
        assert_eq!(seen_shards.len(), 4, "clients must spread over all shards");
        sm.shutdown();
    }

    #[test]
    fn sharded_execute_round_trips() {
        let sm = ShardManager::new(ShardConfig { shards: 2, ..ShardConfig::default() });
        for _ in 0..2 {
            sm.register(WorkerProfile::new(12).threads(2), Arc::new(SimChannel));
        }
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 6);
        for _ in 0..4 {
            let session = sm.session();
            let fids = session.execute(cfg, &pairs).unwrap();
            assert_eq!(fids.len(), 6);
            assert!(fids.iter().all(|f| (0.0..=1.0).contains(f)));
        }
        let stats = sm.stats();
        assert_eq!(stats.submitted, 24);
        assert_eq!(stats.completed, 24);
        sm.shutdown();
    }

    #[test]
    fn cross_shard_steal_drains_a_workerless_shard() {
        // Shard with no workers must still complete its tenants' work
        // via the broker exporting to the sibling that has the pool.
        let sm = ShardManager::new(ShardConfig {
            shards: 2,
            steal_tick: Duration::from_millis(1),
            ..ShardConfig::default()
        });
        // Both workers land on distinct shards (least-populated rule) —
        // pin them onto shard 0 by registering through it directly.
        sm.shard(0).register(WorkerProfile::new(12).threads(2), Arc::new(SimChannel));
        sm.shard(0).register(WorkerProfile::new(12).threads(2), Arc::new(SimChannel));
        assert_eq!(sm.shard(1).worker_count(), 0);
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 4);
        // A client owned by shard 1 (id ≡ 1 mod 2).
        let client = sm.shard(1).new_client();
        assert_eq!(client % 2, 1);
        let bank = sm.submit_bank(client, cfg, &pairs).unwrap();
        let fids = sm.wait_bank_timeout(bank, Duration::from_secs(30)).unwrap();
        assert_eq!(fids.len(), 4);
        assert!(sm.cross_steals() >= 1, "completion required a cross-shard steal");
        let stats = sm.stats();
        assert_eq!(stats.submitted, 4);
        assert_eq!(stats.completed, 4);
        sm.shutdown();
    }
}
