//! The quantum-classical **co-Manager** (paper §III-C, Algorithm 2) —
//! DQuLearn's system contribution.
//!
//! Four management modules, exactly as the paper delineates:
//!
//! 1. **co-Manager Initialization** — [`registry::Registry`] tracks each
//!    worker's maximum (`MR`), occupied (`OR`) and available (`AR`)
//!    qubits plus classical resource usage (`CRU`).
//! 2. **Quantum Worker Registration** — dynamic joins at runtime
//!    ([`manager::Manager::register_worker`]).
//! 3. **Periodic Worker Management** — heartbeats update `OR`/`AR`/`CRU`;
//!    three missed heartbeats evict the worker and its in-flight circuits
//!    are re-queued ([`registry::Registry::evict_stale`]).
//! 4. **Workload Assignment** — for each pending circuit, filter workers
//!    with `AR > demand`, sort ascending by `CRU`, pick the least loaded
//!    ([`scheduler`]).

pub mod bankstore;
pub mod job;
pub mod manager;
pub mod registry;
pub mod scheduler;

pub use job::{CircuitJob, JobId};
pub use manager::{Manager, ManagerConfig, WorkerChannel};
pub use registry::{Registry, WorkerId, WorkerState};
pub use scheduler::{select_worker, SchedulerKind};
