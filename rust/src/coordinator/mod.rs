//! The quantum-classical **co-Manager** (paper §III-C, Algorithm 2) —
//! DQuLearn's system contribution.
//!
//! Four management modules, exactly as the paper delineates:
//!
//! 1. **co-Manager Initialization** — [`registry::Registry`] tracks each
//!    worker's maximum (`MR`), occupied (`OR`) and available (`AR`)
//!    qubits plus classical resource usage (`CRU`).
//! 2. **Quantum Worker Registration** — dynamic joins at runtime
//!    ([`manager::Manager::register`] with a [`registry::WorkerProfile`]).
//! 3. **Periodic Worker Management** — heartbeats update `OR`/`AR`/`CRU`;
//!    three missed heartbeats evict the worker and its in-flight circuits
//!    are re-queued ([`registry::Registry::evict_stale`]).
//! 4. **Workload Assignment** — for each pending circuit, filter workers
//!    with `AR > demand`, sort ascending by `CRU`, pick the least loaded
//!    ([`scheduler`]).
//!
//! Clients drive the manager through the typed session layer
//! ([`session::ClientSession`] → [`session::BankHandle`] futures backed
//! by [`bankstore::BankStore`]); every fallible API returns
//! [`crate::error::DqError`].
//!
//! The dispatch path is event-driven and sharded (DESIGN.md §13):
//! tenant-fair admission lives in [`admission::AdmissionQueue`] (one
//! sub-queue per client, weighted round-robin drain), and every worker
//! owns a private outbox dispatcher thread, so a slow worker never
//! blocks dispatch to a fast one and a flooding tenant never starves a
//! light one. Idle workers steal compatible queued batches from
//! backed-up siblings (DESIGN.md §14) — reservations move atomically
//! under the registry lock, the owning tenant keeps its wait/dispatch
//! accounting, and `ManagerConfig::steal = false` pins batches to
//! their assigned worker when placement policy must win.
//!
//! Durability (DESIGN.md §16): with `ManagerConfig::journal` set, every
//! bank lifecycle transition is written ahead to an append-only
//! checksummed log ([`journal::Journal`]) and a restarted
//! [`manager::Manager::recover`] replays it — never-dispatched circuits
//! are re-admitted, in-flight work fails with
//! [`crate::DqError::WorkerLost`], cancelled ids stay tombstoned, and no
//! circuit ever executes twice across the restart
//! (`tests/journal_recovery.rs`).

pub mod admission;
pub mod bankstore;
pub mod job;
pub mod journal;
pub mod manager;
mod outbox;
pub mod registry;
pub mod scheduler;
pub mod session;
pub mod shard;

pub use admission::AdmissionQueue;
pub use bankstore::{BankEvent, BankStatus, BankWatcher};
pub use job::{CircuitJob, JobId};
pub use journal::{Journal, JournalConfig, SyncPolicy};
pub use manager::{
    Manager, ManagerConfig, ManagerStats, RecoveryReport, TenantStats, WorkerChannel,
};
pub use registry::{Registry, WorkerId, WorkerProfile, WorkerState};
pub use scheduler::{select_worker, SchedulerKind};
pub use session::{BankHandle, ClientSession, SessionOps};
pub use shard::{ShardConfig, ShardManager};
