//! Per-worker outboxes: one dispatcher thread per registered worker,
//! draining that worker's dedicated batch queue.
//!
//! The original manager spawned execution threads from the scheduler
//! loop itself, coupling every tenant's dispatch latency to every
//! worker's spawn and RPC cost. An [`Outbox`] makes the isolation
//! structural: the assigner enqueues a batch and returns immediately
//! (microseconds); the worker's own dispatcher thread picks batches up
//! in FIFO order and runs each `WorkerChannel::execute` on a transient
//! execution thread, so batches holding concurrent reservations on a
//! big worker genuinely overlap, and a stalled worker delays only its
//! own queue — never dispatch to its neighbors (DESIGN.md §13).
//!
//! Lifecycle: spawned at registration, stopped at eviction or manager
//! shutdown. A stopped outbox's unsent batches are *not* executed; the
//! eviction path re-queues them through the registry's orphaned
//! reservations. The dispatcher exits promptly on stop; executions it
//! already spawned finish independently, and their stale results are
//! absorbed by the bank store's duplicate-completion guard plus the
//! manager's landed-count accounting.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::job::CircuitJob;
use super::manager::{Manager, WeakManager, WorkerChannel};
use super::registry::WorkerId;
use crate::circuit::QuClassiConfig;

/// One dispatch unit: same-config circuits executed as a single job on
/// the worker (one qubit reservation, keyed by the head job).
pub(crate) struct Batch {
    pub config: QuClassiConfig,
    pub jobs: Vec<CircuitJob>,
}

/// A worker's dispatch queue plus its dedicated dispatcher thread.
pub(crate) struct Outbox {
    worker: WorkerId,
    channel: Arc<dyn WorkerChannel>,
    queue: Mutex<VecDeque<Batch>>,
    cv: Condvar,
    stop: AtomicBool,
}

/// Backstop poll period for the stop flag; enqueues wake the dispatcher
/// immediately via the condvar, so this bounds only shutdown latency.
const STOP_POLL: Duration = Duration::from_millis(100);

impl Outbox {
    /// Create the outbox and start its dispatcher thread. The thread
    /// holds only a weak manager handle (upgraded per iteration for
    /// completion routing) and exits when the outbox (eviction) or the
    /// manager (shutdown, or last user handle dropped) stops.
    pub fn spawn(
        worker: WorkerId,
        channel: Arc<dyn WorkerChannel>,
        manager: Manager,
    ) -> Arc<Outbox> {
        let outbox = Arc::new(Outbox {
            worker,
            channel,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let ob = outbox.clone();
        let weak = manager.downgrade();
        drop(manager); // the dispatcher must not pin the manager's state
        std::thread::Builder::new()
            .name(format!("outbox-w{worker}"))
            .spawn(move || ob.run(weak))
            .expect("spawn outbox dispatcher");
        outbox
    }

    /// Queue a batch for dispatch and wake the dispatcher. O(1); never
    /// blocks on the worker. When the outbox has already been stopped
    /// (eviction raced the assigner) the batch is handed back untouched
    /// for the caller to re-queue; the stop flag is checked under the
    /// queue lock, so an `Ok` means the batch was enqueued strictly
    /// before the stop and is covered by the evictor's in-flight
    /// reclaim.
    pub fn enqueue(&self, batch: Batch) -> Result<(), Batch> {
        let mut q = self.queue.lock().expect("outbox poisoned");
        if self.stop.load(Ordering::Relaxed) {
            return Err(batch);
        }
        q.push_back(batch);
        drop(q);
        self.cv.notify_all();
        Ok(())
    }

    /// Stop the dispatcher (eviction / shutdown). Idempotent; unsent
    /// batches stay queued for the evictor's orphan re-queue pass. The
    /// flag is set under the queue lock so it serializes with
    /// [`Outbox::enqueue`]'s check.
    pub fn stop(&self) {
        let q = self.queue.lock().expect("outbox poisoned");
        self.stop.store(true, Ordering::Relaxed);
        drop(q);
        self.cv.notify_all();
    }

    fn stopped(&self, manager: &Manager) -> bool {
        self.stop.load(Ordering::Relaxed) || manager.is_stopped()
    }

    fn run(&self, weak: WeakManager) {
        loop {
            // One strong handle per iteration: the dispatcher pins the
            // manager for at most one park window, so a manager dropped
            // without shutdown() still gets to run its Drop.
            let Some(manager) = weak.upgrade() else { return };
            if self.stopped(&manager) {
                return;
            }
            let batch = {
                let mut q = self.queue.lock().expect("outbox poisoned");
                if q.is_empty() {
                    let (guard, _) = self.cv.wait_timeout(q, STOP_POLL).expect("outbox wait");
                    q = guard;
                }
                if self.stopped(&manager) {
                    return;
                }
                q.pop_front()
            };
            if let Some(batch) = batch {
                // Every queued batch holds its own qubit reservation —
                // multi-tenant packing onto a big worker promises
                // *concurrent* execution, so the dispatcher must never
                // serialize one batch behind another. Execution runs on
                // a transient thread per batch; outstanding batches per
                // worker are bounded by its capacity / demand, so the
                // spawn rate is bounded by the worker's own completion
                // rate, and the assigner never pays spawn or RPC
                // latency.
                let m = manager.clone();
                let channel = self.channel.clone();
                let worker = self.worker;
                std::thread::Builder::new()
                    .name(format!("exec-w{worker}"))
                    .spawn(move || m.run_batch(worker, channel.as_ref(), batch))
                    .expect("spawn batch execution");
            }
        }
    }
}
