//! Per-worker outboxes: one dispatcher thread per registered worker,
//! draining that worker's dedicated batch queue — plus work stealing
//! between outboxes through the manager-held [`OutboxDirectory`].
//!
//! The original manager spawned execution threads from the scheduler
//! loop itself, coupling every tenant's dispatch latency to every
//! worker's spawn and RPC cost. An [`Outbox`] makes the isolation
//! structural: the assigner enqueues a batch and returns immediately
//! (microseconds); the worker's own dispatcher thread picks batches up
//! in FIFO order and runs each `WorkerChannel::execute` on a transient
//! execution thread, so a stalled worker delays only its own queue —
//! never dispatch to its neighbors (DESIGN.md §13).
//!
//! In-channel concurrency is bounded by the worker's registered thread
//! budget (`WorkerProfile::threads`): handing a worker more concurrent
//! batches than it has execution threads only moves the backlog inside
//! the worker, where the manager can neither observe, steal, nor
//! re-queue it. Batches beyond the budget wait in the outbox queue,
//! where an idle sibling's dispatcher can steal them (the qubit
//! reservation still caps how many batches bind to a worker at all).
//!
//! Stealing (DESIGN.md §14): a dispatcher that finds its own queue
//! empty with a free channel slot asks the manager for a compatible
//! batch queued on a sibling — `Manager::steal_for` scans the
//! [`OutboxDirectory`] deepest-queue-first under the registry lock,
//! removes the batch from the victim's queue, and moves its qubit
//! reservation to the thief in the same lock hold, so eviction can
//! never observe a half-moved batch.
//!
//! Lifecycle: spawned at registration, stopped at eviction or manager
//! shutdown. A stopped outbox's unsent batches are *not* executed; the
//! eviction path re-queues them through the registry's orphaned
//! reservations. The dispatcher exits promptly on stop; executions it
//! already spawned finish independently, and their stale results are
//! absorbed by the bank store's duplicate-completion guard plus the
//! manager's landed-count accounting.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::job::{CircuitJob, JobId};
use super::manager::{Manager, WeakManager, WorkerChannel};
use super::registry::WorkerId;
use crate::circuit::QuClassiConfig;

/// One dispatch unit: same-config circuits executed as a single job on
/// the worker (one qubit reservation, keyed by the head job). The
/// admission timestamps ride along so queue-wait accounting is measured
/// when the batch actually reaches a worker channel — the measured wait
/// covers outbox residency and survives a steal.
pub(crate) struct Batch {
    pub config: QuClassiConfig,
    pub jobs: Vec<CircuitJob>,
    /// Per-job admission stamps (same order as `jobs`).
    pub enqueued: Vec<Instant>,
}

impl Batch {
    /// Qubit demand of the batch's single reservation.
    pub fn demand(&self) -> usize {
        self.config.qubit_demand()
    }

    /// The reservation key (head job id).
    pub fn key(&self) -> JobId {
        self.jobs[0].id
    }
}

/// Queue state behind the outbox lock: pending batches plus the count
/// of batches currently handed to the worker channel.
struct OutboxState {
    batches: VecDeque<Batch>,
    /// Batches executing on transient threads right now (bounded by
    /// `Outbox::slots`).
    in_channel: usize,
}

/// A worker's dispatch queue plus its dedicated dispatcher thread.
pub(crate) struct Outbox {
    worker: WorkerId,
    channel: Arc<dyn WorkerChannel>,
    /// In-channel concurrency budget (the worker's thread budget, >= 1).
    slots: usize,
    state: Mutex<OutboxState>,
    cv: Condvar,
    stop: AtomicBool,
}

/// Backstop poll period for the stop flag; enqueues, completions, and
/// steal nudges wake the dispatcher immediately via the condvar, so this
/// bounds only shutdown latency and missed-nudge steal retries.
const STOP_POLL: Duration = Duration::from_millis(100);

impl Outbox {
    /// Create the outbox and start its dispatcher thread. The thread
    /// holds only a weak manager handle (upgraded per iteration for
    /// completion routing) and exits when the outbox (eviction) or the
    /// manager (shutdown, or last user handle dropped) stops.
    pub fn spawn(
        worker: WorkerId,
        channel: Arc<dyn WorkerChannel>,
        slots: usize,
        manager: Manager,
    ) -> Arc<Outbox> {
        let outbox = Arc::new(Outbox {
            worker,
            channel,
            slots: slots.max(1),
            state: Mutex::new(OutboxState { batches: VecDeque::new(), in_channel: 0 }),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let ob = outbox.clone();
        let weak = manager.downgrade();
        drop(manager); // the dispatcher must not pin the manager's state
        std::thread::Builder::new()
            .name(format!("outbox-w{worker}"))
            .spawn(move || ob.run(weak))
            .expect("spawn outbox dispatcher");
        outbox
    }

    /// Queue a batch for dispatch and wake the dispatcher. O(1); never
    /// blocks on the worker. `Ok(surplus)` reports whether the batch
    /// parked behind a saturated channel (`surplus == true` means steal
    /// candidates now exist, so the manager nudges idle siblings). When
    /// the outbox has already been stopped (eviction raced the assigner)
    /// the batch is handed back untouched for the caller to re-queue;
    /// the stop flag is checked under the queue lock, so an `Ok` means
    /// the batch was enqueued strictly before the stop and is covered by
    /// the evictor's in-flight reclaim.
    pub fn enqueue(&self, batch: Batch) -> Result<bool, Batch> {
        let mut st = self.state.lock().expect("outbox poisoned");
        if self.stop.load(Ordering::Relaxed) {
            return Err(batch);
        }
        st.batches.push_back(batch);
        let surplus = st.in_channel >= self.slots;
        drop(st);
        self.cv.notify_all();
        Ok(surplus)
    }

    /// Stop the dispatcher (eviction / shutdown). Idempotent; unsent
    /// batches stay queued for the evictor's orphan re-queue pass. The
    /// flag is set under the queue lock so it serializes with
    /// [`Outbox::enqueue`]'s check.
    pub fn stop(&self) {
        let st = self.state.lock().expect("outbox poisoned");
        self.stop.store(true, Ordering::Relaxed);
        drop(st);
        self.cv.notify_all();
    }

    /// Remove and return the oldest *queued* batch satisfying `fits`.
    /// In-channel batches are never stolen — once `execute` has been
    /// called, results may arrive, and moving the batch would execute
    /// its circuits twice. Callers hold the registry lock (the manager's
    /// steal path; DESIGN.md §14 lock order), which serializes the
    /// removal with eviction's orphan snapshot.
    pub fn steal_where(&self, fits: impl Fn(&Batch) -> bool) -> Option<Batch> {
        let mut st = self.state.lock().expect("outbox poisoned");
        let idx = st.batches.iter().position(fits)?;
        st.batches.remove(idx)
    }

    /// Batches queued (not yet in-channel) — the stealable depth.
    pub fn queue_depth(&self) -> usize {
        self.state.lock().expect("outbox poisoned").batches.len()
    }

    /// The worker channel behind this outbox (cross-shard steal: a
    /// sibling shard executes an exported batch directly on the channel,
    /// bypassing the queue — the reservation it holds is its own).
    pub fn channel(&self) -> Arc<dyn WorkerChannel> {
        self.channel.clone()
    }

    /// Wake the dispatcher without queueing anything (steal opportunity
    /// appeared on a sibling).
    pub fn nudge(&self) {
        self.cv.notify_all();
    }

    fn stopped(&self, manager: &Manager) -> bool {
        self.stop.load(Ordering::Relaxed) || manager.is_stopped()
    }

    /// Hand one batch to the worker channel. The caller must already
    /// have charged a channel slot (`in_channel`); the completion
    /// releases it and re-wakes the dispatcher.
    ///
    /// An async channel (the mux plane) is enqueue-and-notify: the
    /// dispatch bookkeeping runs here on the dispatcher thread, the
    /// channel call returns immediately, and the completion callback —
    /// arriving on a mux transport thread — routes the outcome. A
    /// blocking channel gets the historical behavior: a transient
    /// execution thread parks on the call for its whole round trip.
    fn execute(me: &Arc<Outbox>, manager: &Manager, batch: Batch) {
        if me.channel.is_async() {
            let (config, jobs, pairs) = manager.begin_batch(batch);
            let me2 = me.clone();
            let weak = manager.downgrade();
            let worker = me.worker;
            me.channel.execute_async(
                &config,
                &pairs,
                Box::new(move |res| {
                    // A failed upgrade means the manager is gone
                    // (shutdown); the outcome has nowhere to land.
                    if let Some(m) = weak.upgrade() {
                        m.finish_batch(worker, jobs, res);
                    }
                    let mut st = me2.state.lock().expect("outbox poisoned");
                    st.in_channel -= 1;
                    drop(st);
                    me2.cv.notify_all();
                }),
            );
            return;
        }
        let me = me.clone();
        let m = manager.clone();
        std::thread::Builder::new()
            .name(format!("exec-w{}", me.worker))
            .spawn(move || {
                m.run_batch(me.worker, me.channel.as_ref(), batch);
                let mut st = me.state.lock().expect("outbox poisoned");
                st.in_channel -= 1;
                drop(st);
                me.cv.notify_all();
            })
            .expect("spawn batch execution");
    }

    fn run(self: Arc<Self>, weak: WeakManager) {
        loop {
            // One strong handle per iteration: the dispatcher pins the
            // manager for at most one park window, so a manager dropped
            // without shutdown() still gets to run its Drop.
            let Some(manager) = weak.upgrade() else { return };
            if self.stopped(&manager) {
                return;
            }
            // Own queue first, slots permitting. `idle` means a slot is
            // free but there is nothing local to run — the steal case.
            let (batch, idle) = {
                let mut st = self.state.lock().expect("outbox poisoned");
                if st.in_channel < self.slots {
                    match st.batches.pop_front() {
                        Some(b) => {
                            st.in_channel += 1;
                            (Some(b), false)
                        }
                        None => (None, true),
                    }
                } else {
                    (None, false)
                }
            };
            if let Some(batch) = batch {
                Self::execute(&self, &manager, batch);
                continue;
            }
            if idle {
                // Empty queue + free slot: try to relieve a backed-up
                // sibling. On success, loop around and try again — a
                // thief drains as fast as its own slots free up.
                if let Some(batch) = manager.steal_for(self.worker) {
                    let mut st = self.state.lock().expect("outbox poisoned");
                    st.in_channel += 1;
                    drop(st);
                    Self::execute(&self, &manager, batch);
                    continue;
                }
            }
            // Park until an enqueue, a completion, a nudge, or the stop
            // poll. Re-check runnable work under the lock so an event
            // that landed between the scan above and here is never
            // slept through.
            let st = self.state.lock().expect("outbox poisoned");
            if self.stopped(&manager) {
                return;
            }
            if st.in_channel < self.slots && !st.batches.is_empty() {
                continue;
            }
            let _ = self.cv.wait_timeout(st, STOP_POLL).expect("outbox wait");
        }
    }
}

/// The manager's directory of live outboxes — the structure a thief
/// scans for victims. Owned by the manager behind its own mutex, taken
/// either alone or directly inside the registry lock (DESIGN.md §14).
pub(crate) struct OutboxDirectory {
    map: HashMap<WorkerId, Arc<Outbox>>,
}

impl Default for OutboxDirectory {
    fn default() -> OutboxDirectory {
        OutboxDirectory::new()
    }
}

impl OutboxDirectory {
    pub fn new() -> OutboxDirectory {
        OutboxDirectory { map: HashMap::new() }
    }

    pub fn insert(&mut self, id: WorkerId, outbox: Arc<Outbox>) {
        self.map.insert(id, outbox);
    }

    pub fn remove(&mut self, id: WorkerId) -> Option<Arc<Outbox>> {
        self.map.remove(&id)
    }

    pub fn get(&self, id: WorkerId) -> Option<Arc<Outbox>> {
        self.map.get(&id).cloned()
    }

    /// Every live outbox (shutdown sweep).
    pub fn all(&self) -> Vec<Arc<Outbox>> {
        self.map.values().cloned().collect()
    }

    /// Steal candidates for `thief`: siblings with a non-empty queue,
    /// deepest queue first (ties broken by lowest worker id), so the
    /// most backed-up victim is relieved first and victim selection is
    /// deterministic.
    pub fn victims(&self, thief: WorkerId) -> Vec<(WorkerId, Arc<Outbox>)> {
        let mut v: Vec<(usize, WorkerId, Arc<Outbox>)> = self
            .map
            .iter()
            .filter(|(id, _)| **id != thief)
            .map(|(id, ob)| (ob.queue_depth(), *id, ob.clone()))
            .filter(|(depth, _, _)| *depth > 0)
            .collect();
        v.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        v.into_iter().map(|(_, id, ob)| (id, ob)).collect()
    }

    /// Wake every dispatcher except `busy`'s (a surplus batch appeared
    /// there — idle siblings should attempt a steal).
    pub fn nudge_siblings(&self, busy: WorkerId) {
        for (id, ob) in &self.map {
            if *id != busy {
                ob.nudge();
            }
        }
    }
}
