//! Circuit jobs: the co-Manager's unit of distribution.

use crate::circuit::QuClassiConfig;
use crate::error::DqError;
use crate::wire::Value;

/// Globally unique circuit identifier.
pub type JobId = u64;

/// One independent circuit submitted by a client: a (theta, data) pair
/// under a configuration, tagged with its bank for result routing.
#[derive(Debug, Clone, PartialEq)]
pub struct CircuitJob {
    pub id: JobId,
    pub client: u64,
    pub bank: u64,
    /// Position of this circuit inside its bank.
    pub index: usize,
    pub config: QuClassiConfig,
    pub thetas: Vec<f32>,
    pub data: Vec<f32>,
}

impl CircuitJob {
    /// Qubit demand as seen by Algorithm 2 (`D_{c_i}`).
    pub fn demand(&self) -> usize {
        self.config.qubit_demand()
    }

    /// Wire encoding of the job (manager→worker `execute` payload).
    pub fn to_wire(&self) -> Value {
        Value::obj()
            .with("id", self.id)
            .with("client", self.client)
            .with("bank", self.bank)
            .with("index", self.index)
            .with("qubits", self.config.qubits)
            .with("layers", self.config.layers)
            .with("thetas", self.thetas.as_slice())
            .with("data", self.data.as_slice())
    }

    /// Decode the wire encoding, validating arities against the config.
    /// Missing/malformed fields surface as [`DqError::Protocol`]; length
    /// mismatches as [`DqError::Arity`].
    pub fn from_wire(v: &Value) -> Result<CircuitJob, DqError> {
        let config = QuClassiConfig::new(v.req_usize("qubits")?, v.req_usize("layers")?)?;
        let thetas = v.req_f32_vec("thetas")?;
        let data = v.req_f32_vec("data")?;
        if thetas.len() != config.n_params() {
            return Err(DqError::Arity(format!(
                "job theta arity {} != {}",
                thetas.len(),
                config.n_params()
            )));
        }
        if data.len() != config.n_features() {
            return Err(DqError::Arity(format!(
                "job data arity {} != {}",
                data.len(),
                config.n_features()
            )));
        }
        Ok(CircuitJob {
            id: v.req_u64("id")?,
            client: v.req_u64("client")?,
            bank: v.req_u64("bank")?,
            index: v.req_usize("index")?,
            config,
            thetas,
            data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_job() -> CircuitJob {
        CircuitJob {
            id: 7,
            client: 1,
            bank: 3,
            index: 2,
            config: QuClassiConfig::new(5, 1).unwrap(),
            thetas: vec![0.1, 0.2, 0.3, 0.4],
            data: vec![1.0, 1.1, 1.2, 1.3],
        }
    }

    #[test]
    fn wire_round_trip() {
        let j = sample_job();
        let back = CircuitJob::from_wire(&j.to_wire()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn demand_equals_config_qubits() {
        assert_eq!(sample_job().demand(), 5);
    }

    #[test]
    fn rejects_arity_mismatch() {
        let mut w = sample_job().to_wire();
        w.set("thetas", vec![0.1f32, 0.2].as_slice());
        assert!(matches!(CircuitJob::from_wire(&w), Err(DqError::Arity(_))));
    }
}
