//! Write-ahead bank journal (DESIGN.md §16): an append-only, checksummed
//! log of every bank lifecycle transition, replayed by
//! [`super::manager::Manager::recover`] so a restarted co-Manager loses
//! no bank and re-executes no circuit.
//!
//! The file is a magic header followed by length-prefixed frames:
//! `[u32 payload_len][u32 crc32][payload]`, all little-endian. A record
//! is written *before* the in-memory transition it describes (and, for
//! dispatch, before the batch reaches a worker channel), so the log is a
//! true WAL: "no `Dispatched` record" implies "this circuit never
//! executed", which is what makes post-crash re-admission safe.
//!
//! Durability model: every append reaches the file (and the OS page
//! cache) immediately via `write_all`, so a *process* crash — the
//! kill-and-replay suite in `tests/journal_recovery.rs` — loses at most
//! the record being written when the process died (a torn tail, which
//! replay truncates). The [`SyncPolicy`] knob only governs *machine*
//! crashes: `Always` fsyncs per append, `Batch` every
//! [`BATCH_SYNC_EVERY`] appends plus on flush/compaction/shutdown,
//! `Never` leaves fsync to the OS.
//!
//! Compaction: [`Journal::compact`] writes a single [`Record::Snapshot`]
//! to a temp file, fsyncs it, and atomically renames it over the
//! journal, so the log stays bounded under churn (resolved and cancelled
//! banks fall away; the cancelled-id *set* is carried in every snapshot
//! — the tombstone invariant of DESIGN.md §12 survives compaction).

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::error::DqError;
use crate::model::exec::CircuitPair;

/// File magic: identifies (and versions) the journal format.
pub const MAGIC: &[u8; 8] = b"DQJRNL01";

/// Upper bound on one record's payload; anything larger in a length
/// prefix is treated as corruption (truncate point), not an allocation.
const MAX_RECORD: u32 = 1 << 28;

/// `SyncPolicy::Batch` fsyncs once per this many appends.
pub const BATCH_SYNC_EVERY: u32 = 64;

/// When the journal calls `fsync` (machine-crash durability; see the
/// module docs — process-crash durability never depends on this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    /// Never fsync explicitly; the OS flushes on its own schedule.
    Never,
    /// Fsync every [`BATCH_SYNC_EVERY`] appends and on flush/compaction.
    Batch,
    /// Fsync after every append (slowest, strongest).
    Always,
}

/// Journal knob for [`super::manager::ManagerConfig::journal`].
#[derive(Debug, Clone)]
pub struct JournalConfig {
    /// Journal file path. Compaction writes `<path>.tmp` next to it.
    pub path: PathBuf,
    /// Fsync policy (default [`SyncPolicy::Batch`]).
    pub sync: SyncPolicy,
    /// Compaction trigger: the liveness thread snapshots+compacts once
    /// the file exceeds this many bytes (default 4 MiB).
    pub compact_bytes: u64,
}

impl JournalConfig {
    /// Journal at `path` with the default policy (`Batch`, 4 MiB).
    pub fn new(path: impl Into<PathBuf>) -> JournalConfig {
        JournalConfig { path: path.into(), sync: SyncPolicy::Batch, compact_bytes: 4 << 20 }
    }

    /// Set the fsync policy.
    pub fn sync(mut self, sync: SyncPolicy) -> JournalConfig {
        self.sync = sync;
        self
    }

    /// Set the compaction threshold in bytes.
    pub fn compact_bytes(mut self, bytes: u64) -> JournalConfig {
        self.compact_bytes = bytes;
        self
    }
}

/// A `(bank, circuit index)` pair naming one circuit in dispatch-shaped
/// records.
pub type Member = (u64, u32);

/// One journal record. Field order in the binary encoding matches the
/// declaration order here; see `tests/journal_recovery.rs` for the
/// round-trip/corruption suite.
#[derive(Debug, Clone, PartialEq)]
pub enum Record {
    /// A bank entered the system (written before the bank opens).
    Submitted {
        /// Bank id.
        bank: u64,
        /// Owning tenant.
        client: u64,
        /// Circuit width (odd, >= 3).
        qubits: u32,
        /// Variational layers (1..=3).
        layers: u32,
        /// FNV-1a digest of `pairs` — verified at decode, so payload
        /// corruption that survives the CRC still truncates replay.
        digest: u64,
        /// The circuit payloads (theta/data per circuit, in bank order).
        pairs: Vec<CircuitPair>,
    },
    /// A batch is about to reach a worker channel (written *before*
    /// `execute`, so an executed circuit always has this record).
    Dispatched {
        /// Circuits in the batch.
        members: Vec<Member>,
    },
    /// A batch's results arrived (written before the in-memory credit).
    Completed {
        /// `(bank, index, fidelity)` per circuit.
        results: Vec<(u64, u32, f32)>,
    },
    /// In-flight circuits went back to the pending queue (failed
    /// dispatch or worker eviction).
    Requeued {
        /// Circuits returned to the queue.
        members: Vec<Member>,
    },
    /// A bank was cancelled (the id is a tombstone forever).
    Cancelled {
        /// Bank id.
        bank: u64,
    },
    /// A whole bank failed (unschedulable, worker protocol violation).
    Failed {
        /// Bank id.
        bank: u64,
        /// The failure waiters observe.
        error: DqError,
    },
    /// A bank left the store (consumed by a wait, or swept at clean
    /// shutdown) — replay drops it.
    Resolved {
        /// Bank id.
        bank: u64,
    },
    /// A full-state checkpoint; replay restarts from it (compaction).
    Snapshot(Snapshot),
    /// An operator set a tenant's WRR weight (written before the queue
    /// mutation, so a recovered manager resumes the same fairness
    /// shares). Weight 1 (the default) acts as a release tombstone.
    TenantWeight {
        /// Tenant whose weight changed.
        client: u64,
        /// The new weight (clamped to >= 1 by the admission queue).
        weight: u32,
    },
}

/// A checkpoint of the manager's durable state (see [`Record::Snapshot`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Next bank id to allocate (ids never reuse across restarts).
    pub next_bank: u64,
    /// Next client id to allocate.
    pub next_client: u64,
    /// Every bank id ever cancelled (the tombstone set — survives
    /// compaction by design; DESIGN.md §12/§16).
    pub cancelled: Vec<u64>,
    /// Live (resident, non-cancelled) banks.
    pub banks: Vec<SnapBank>,
    /// Non-default tenant WRR weights (`(client, weight)`), so fairness
    /// policy survives compaction. Default-weight tenants are absent.
    pub weights: Vec<(u64, u32)>,
}

/// One live bank inside a [`Snapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct SnapBank {
    /// Bank id.
    pub bank: u64,
    /// Owning tenant.
    pub client: u64,
    /// Circuit width.
    pub qubits: u32,
    /// Variational layers.
    pub layers: u32,
    /// True when this bank was itself restored by a recovery.
    pub recovered: bool,
    /// The bank-level failure, if any.
    pub failed: Option<DqError>,
    /// Per-circuit state, in bank order.
    pub circuits: Vec<CircuitState>,
}

/// Replay state of a single circuit.
#[derive(Debug, Clone, PartialEq)]
pub enum CircuitState {
    /// Completed with this fidelity.
    Done(f32),
    /// Waiting in the admission queue; the payload re-admits it.
    Pending(CircuitPair),
    /// Handed to a worker channel; recovery must NOT re-run it (it may
    /// have executed), so its bank fails with `WorkerLost`.
    InFlight(CircuitPair),
    /// Accounted to a failed bank — nothing left to do.
    Gone,
}

/// Everything a replay reconstructed from the log.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveredState {
    /// Live banks by id (insertion order = submission order).
    pub banks: BTreeMap<u64, ReplayBank>,
    /// The cancelled-id tombstone set.
    pub cancelled: BTreeSet<u64>,
    /// Highest bank id ever observed (next allocation starts above it).
    pub max_bank: u64,
    /// Highest client id ever observed.
    pub max_client: u64,
    /// Records successfully replayed.
    pub records: u64,
    /// Bytes truncated off the tail (torn/corrupt records).
    pub truncated_bytes: u64,
    /// Non-default tenant WRR weights replayed from `TenantWeight`
    /// records and snapshots (weight-1 writes act as removals).
    pub weights: BTreeMap<u64, u32>,
}

/// One bank's replayed lifecycle state.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayBank {
    /// Owning tenant.
    pub client: u64,
    /// Circuit width.
    pub qubits: u32,
    /// Variational layers.
    pub layers: u32,
    /// True when the bank had already survived an earlier recovery.
    pub recovered: bool,
    /// Bank-level failure replayed from a `Failed` record.
    pub failed: Option<DqError>,
    /// Per-circuit state, in bank order.
    pub circuits: Vec<CircuitState>,
}

impl RecoveredState {
    /// Apply one record in log order. Transitions are monotone per
    /// circuit — `Done` is terminal, `Dispatched` only moves `Pending`
    /// forward, `Requeued` only moves `InFlight` back — so replaying a
    /// log whose tail interleaves racing writers (completion vs.
    /// eviction requeue) converges to the same state the live manager
    /// reached.
    pub fn apply(&mut self, rec: Record) {
        match rec {
            Record::Submitted { bank, client, qubits, layers, digest: _, pairs } => {
                self.max_bank = self.max_bank.max(bank);
                self.max_client = self.max_client.max(client);
                if self.cancelled.contains(&bank) {
                    return;
                }
                self.banks.insert(
                    bank,
                    ReplayBank {
                        client,
                        qubits,
                        layers,
                        recovered: false,
                        failed: None,
                        circuits: pairs.into_iter().map(CircuitState::Pending).collect(),
                    },
                );
            }
            Record::Dispatched { members } => {
                for (bank, idx) in members {
                    self.transition(bank, idx, |c| match c {
                        CircuitState::Pending(p) => CircuitState::InFlight(p),
                        other => other,
                    });
                }
            }
            Record::Completed { results } => {
                for (bank, idx, fid) in results {
                    self.transition(bank, idx, |c| match c {
                        // first result wins, like the live store
                        done @ CircuitState::Done(_) => done,
                        _ => CircuitState::Done(fid),
                    });
                }
            }
            Record::Requeued { members } => {
                for (bank, idx) in members {
                    self.transition(bank, idx, |c| match c {
                        CircuitState::InFlight(p) => CircuitState::Pending(p),
                        other => other,
                    });
                }
            }
            Record::Cancelled { bank } => {
                self.cancelled.insert(bank);
                self.banks.remove(&bank);
            }
            Record::Failed { bank, error } => {
                if let Some(b) = self.banks.get_mut(&bank) {
                    if b.failed.is_none() {
                        b.failed = Some(error);
                    }
                    for c in b.circuits.iter_mut() {
                        if matches!(c, CircuitState::Pending(_) | CircuitState::InFlight(_)) {
                            *c = CircuitState::Gone;
                        }
                    }
                }
            }
            Record::Resolved { bank } => {
                self.banks.remove(&bank);
            }
            Record::TenantWeight { client, weight } => {
                self.max_client = self.max_client.max(client);
                if weight <= 1 {
                    self.weights.remove(&client);
                } else {
                    self.weights.insert(client, weight);
                }
            }
            Record::Snapshot(s) => {
                self.banks.clear();
                self.cancelled.clear();
                self.weights.clear();
                self.weights.extend(s.weights);
                self.max_bank = self.max_bank.max(s.next_bank.saturating_sub(1));
                self.max_client = self.max_client.max(s.next_client.saturating_sub(1));
                self.cancelled.extend(s.cancelled);
                for sb in s.banks {
                    self.max_bank = self.max_bank.max(sb.bank);
                    self.max_client = self.max_client.max(sb.client);
                    self.banks.insert(
                        sb.bank,
                        ReplayBank {
                            client: sb.client,
                            qubits: sb.qubits,
                            layers: sb.layers,
                            recovered: sb.recovered,
                            failed: sb.failed,
                            circuits: sb.circuits,
                        },
                    );
                }
            }
        }
    }

    fn transition(&mut self, bank: u64, idx: u32, f: impl FnOnce(CircuitState) -> CircuitState) {
        if let Some(b) = self.banks.get_mut(&bank) {
            if let Some(c) = b.circuits.get_mut(idx as usize) {
                let cur = std::mem::replace(c, CircuitState::Gone);
                *c = f(cur);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// binary codec
// ---------------------------------------------------------------------------

const TAG_SUBMITTED: u8 = 1;
const TAG_DISPATCHED: u8 = 2;
const TAG_COMPLETED: u8 = 3;
const TAG_REQUEUED: u8 = 4;
const TAG_CANCELLED: u8 = 5;
const TAG_FAILED: u8 = 6;
const TAG_RESOLVED: u8 = 7;
const TAG_SNAPSHOT: u8 = 8;
const TAG_TENANT_WEIGHT: u8 = 9;

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    put_u32(buf, v.len() as u32);
    for x in v {
        put_f32(buf, *x);
    }
}

fn put_pair(buf: &mut Vec<u8>, p: &CircuitPair) {
    put_f32s(buf, &p.0);
    put_f32s(buf, &p.1);
}

fn put_error(buf: &mut Vec<u8>, e: &DqError) {
    put_str(buf, e.kind());
    put_str(buf, e.message());
}

fn put_members(buf: &mut Vec<u8>, members: &[Member]) {
    put_u32(buf, members.len() as u32);
    for (bank, idx) in members {
        put_u64(buf, *bank);
        put_u32(buf, *idx);
    }
}

/// Bounded-read decode cursor; every accessor fails (instead of
/// panicking) on short input, so a torn or corrupt payload becomes a
/// truncate point, never a crash.
struct Cur<'a> {
    b: &'a [u8],
    at: usize,
}

type DecResult<T> = Result<T, String>;

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> DecResult<&'a [u8]> {
        if self.b.len() - self.at < n {
            return Err(format!("short payload: want {n} bytes at {}", self.at));
        }
        let s = &self.b[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> DecResult<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> DecResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> DecResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> DecResult<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Length-prefixed count, sanity-bounded by the bytes that could
    /// actually hold `elem_size`-byte elements.
    fn count(&mut self, elem_size: usize) -> DecResult<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_size.max(1)) > self.b.len() - self.at {
            return Err(format!("implausible count {n} at {}", self.at));
        }
        Ok(n)
    }

    fn str_(&mut self) -> DecResult<String> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("bad utf8: {e}"))
    }

    fn f32s(&mut self) -> DecResult<Vec<f32>> {
        let n = self.count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    fn pair(&mut self) -> DecResult<CircuitPair> {
        Ok((self.f32s()?, self.f32s()?))
    }

    fn error(&mut self) -> DecResult<DqError> {
        let kind = self.str_()?;
        let msg = self.str_()?;
        Ok(match kind.as_str() {
            "unschedulable" => DqError::Unschedulable(msg),
            "worker_lost" => DqError::WorkerLost(msg),
            "timeout" => DqError::Timeout(msg),
            "cancelled" => DqError::Cancelled(msg),
            "arity" => DqError::Arity(msg),
            "io" => DqError::Io(msg),
            _ => DqError::Protocol(msg),
        })
    }

    fn members(&mut self) -> DecResult<Vec<Member>> {
        let n = self.count(12)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push((self.u64()?, self.u32()?));
        }
        Ok(v)
    }

    fn done(&self) -> DecResult<()> {
        if self.at != self.b.len() {
            return Err(format!("{} trailing bytes", self.b.len() - self.at));
        }
        Ok(())
    }
}

impl Record {
    /// Binary payload (the frame's CRC covers exactly these bytes).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(32);
        match self {
            Record::Submitted { bank, client, qubits, layers, digest, pairs } => {
                put_u8(&mut buf, TAG_SUBMITTED);
                put_u64(&mut buf, *bank);
                put_u64(&mut buf, *client);
                put_u32(&mut buf, *qubits);
                put_u32(&mut buf, *layers);
                put_u64(&mut buf, *digest);
                put_u32(&mut buf, pairs.len() as u32);
                for p in pairs {
                    put_pair(&mut buf, p);
                }
            }
            Record::Dispatched { members } => {
                put_u8(&mut buf, TAG_DISPATCHED);
                put_members(&mut buf, members);
            }
            Record::Completed { results } => {
                put_u8(&mut buf, TAG_COMPLETED);
                put_u32(&mut buf, results.len() as u32);
                for (bank, idx, fid) in results {
                    put_u64(&mut buf, *bank);
                    put_u32(&mut buf, *idx);
                    put_f32(&mut buf, *fid);
                }
            }
            Record::Requeued { members } => {
                put_u8(&mut buf, TAG_REQUEUED);
                put_members(&mut buf, members);
            }
            Record::Cancelled { bank } => {
                put_u8(&mut buf, TAG_CANCELLED);
                put_u64(&mut buf, *bank);
            }
            Record::Failed { bank, error } => {
                put_u8(&mut buf, TAG_FAILED);
                put_u64(&mut buf, *bank);
                put_error(&mut buf, error);
            }
            Record::Resolved { bank } => {
                put_u8(&mut buf, TAG_RESOLVED);
                put_u64(&mut buf, *bank);
            }
            Record::Snapshot(s) => {
                put_u8(&mut buf, TAG_SNAPSHOT);
                put_u64(&mut buf, s.next_bank);
                put_u64(&mut buf, s.next_client);
                put_u32(&mut buf, s.cancelled.len() as u32);
                for id in &s.cancelled {
                    put_u64(&mut buf, *id);
                }
                put_u32(&mut buf, s.banks.len() as u32);
                for b in &s.banks {
                    put_u64(&mut buf, b.bank);
                    put_u64(&mut buf, b.client);
                    put_u32(&mut buf, b.qubits);
                    put_u32(&mut buf, b.layers);
                    put_u8(&mut buf, b.recovered as u8);
                    match &b.failed {
                        Some(e) => {
                            put_u8(&mut buf, 1);
                            put_error(&mut buf, e);
                        }
                        None => put_u8(&mut buf, 0),
                    }
                    put_u32(&mut buf, b.circuits.len() as u32);
                    for c in &b.circuits {
                        match c {
                            CircuitState::Done(f) => {
                                put_u8(&mut buf, 0);
                                put_f32(&mut buf, *f);
                            }
                            CircuitState::Pending(p) => {
                                put_u8(&mut buf, 1);
                                put_pair(&mut buf, p);
                            }
                            CircuitState::InFlight(p) => {
                                put_u8(&mut buf, 2);
                                put_pair(&mut buf, p);
                            }
                            CircuitState::Gone => put_u8(&mut buf, 3),
                        }
                    }
                }
                put_u32(&mut buf, s.weights.len() as u32);
                for (client, weight) in &s.weights {
                    put_u64(&mut buf, *client);
                    put_u32(&mut buf, *weight);
                }
            }
            Record::TenantWeight { client, weight } => {
                put_u8(&mut buf, TAG_TENANT_WEIGHT);
                put_u64(&mut buf, *client);
                put_u32(&mut buf, *weight);
            }
        }
        buf
    }

    /// Decode one payload; any structural problem (short buffer, bad
    /// tag, digest mismatch, trailing bytes) is an error — replay treats
    /// it as a truncate point.
    pub fn decode(payload: &[u8]) -> DecResult<Record> {
        let mut c = Cur { b: payload, at: 0 };
        let rec = match c.u8()? {
            TAG_SUBMITTED => {
                let bank = c.u64()?;
                let client = c.u64()?;
                let qubits = c.u32()?;
                let layers = c.u32()?;
                let digest = c.u64()?;
                let n = c.count(8)?;
                let mut pairs = Vec::with_capacity(n);
                for _ in 0..n {
                    pairs.push(c.pair()?);
                }
                if payload_digest(&pairs) != digest {
                    return Err(format!("bank {bank}: payload digest mismatch"));
                }
                Record::Submitted { bank, client, qubits, layers, digest, pairs }
            }
            TAG_DISPATCHED => Record::Dispatched { members: c.members()? },
            TAG_COMPLETED => {
                let n = c.count(16)?;
                let mut results = Vec::with_capacity(n);
                for _ in 0..n {
                    results.push((c.u64()?, c.u32()?, c.f32()?));
                }
                Record::Completed { results }
            }
            TAG_REQUEUED => Record::Requeued { members: c.members()? },
            TAG_CANCELLED => Record::Cancelled { bank: c.u64()? },
            TAG_FAILED => Record::Failed { bank: c.u64()?, error: c.error()? },
            TAG_RESOLVED => Record::Resolved { bank: c.u64()? },
            TAG_SNAPSHOT => {
                let next_bank = c.u64()?;
                let next_client = c.u64()?;
                let nc = c.count(8)?;
                let mut cancelled = Vec::with_capacity(nc);
                for _ in 0..nc {
                    cancelled.push(c.u64()?);
                }
                let nb = c.count(26)?;
                let mut banks = Vec::with_capacity(nb);
                for _ in 0..nb {
                    let bank = c.u64()?;
                    let client = c.u64()?;
                    let qubits = c.u32()?;
                    let layers = c.u32()?;
                    let recovered = c.u8()? != 0;
                    let failed = match c.u8()? {
                        0 => None,
                        _ => Some(c.error()?),
                    };
                    let ncirc = c.count(1)?;
                    let mut circuits = Vec::with_capacity(ncirc);
                    for _ in 0..ncirc {
                        circuits.push(match c.u8()? {
                            0 => CircuitState::Done(c.f32()?),
                            1 => CircuitState::Pending(c.pair()?),
                            2 => CircuitState::InFlight(c.pair()?),
                            3 => CircuitState::Gone,
                            t => return Err(format!("bad circuit-state tag {t}")),
                        });
                    }
                    banks.push(SnapBank { bank, client, qubits, layers, recovered, failed, circuits });
                }
                // Weights trail the snapshot; pre-weight snapshots (older
                // journals) simply end here, so their absence is legal.
                let mut weights = Vec::new();
                if c.done().is_err() {
                    let nw = c.count(12)?;
                    for _ in 0..nw {
                        weights.push((c.u64()?, c.u32()?));
                    }
                }
                Record::Snapshot(Snapshot { next_bank, next_client, cancelled, banks, weights })
            }
            TAG_TENANT_WEIGHT => Record::TenantWeight { client: c.u64()?, weight: c.u32()? },
            t => return Err(format!("bad record tag {t}")),
        };
        c.done()?;
        Ok(rec)
    }
}

/// CRC-32 (IEEE 802.3), table-driven; covers each frame's payload.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// FNV-1a digest of a bank's circuit payloads (stored in `Submitted`
/// records, re-verified at decode).
pub fn payload_digest(pairs: &[CircuitPair]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |h: u64, bytes: &[u8]| -> u64 {
        let mut h = h;
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
        h
    };
    for (thetas, data) in pairs {
        for v in thetas {
            h = eat(h, &v.to_bits().to_le_bytes());
        }
        h = eat(h, &[0xA5]);
        for v in data {
            h = eat(h, &v.to_bits().to_le_bytes());
        }
        h = eat(h, &[0x5A]);
    }
    h
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

// ---------------------------------------------------------------------------
// the journal file
// ---------------------------------------------------------------------------

/// Group-commit coordinator for [`SyncPolicy::Always`] (DESIGN.md §16):
/// an appender writes its record under the journal mutex, *releases* the
/// mutex, then commits its ticket here — and concurrent committers
/// coalesce onto one leader's `sync_data`, so N submitters pay roughly
/// one fsync between them instead of N serialized ones.
#[derive(Debug)]
struct Committer {
    /// A clone of the journal's file handle (refreshed on compaction,
    /// which swaps the inode). Locked only around the fsync itself.
    file: Mutex<File>,
    state: Mutex<CommitState>,
    cv: Condvar,
    /// Leader fsyncs performed (the amortization gauge: the micro bench
    /// reports fsyncs-per-append under concurrent submitters).
    syncs: AtomicU64,
}

#[derive(Debug, Default)]
struct CommitState {
    /// File length after the latest append (the fsync high-water mark).
    written: u64,
    /// File length known durable.
    synced: u64,
    /// A leader is inside `sync_data` right now.
    syncing: bool,
}

impl Committer {
    fn new(file: File, durable: u64) -> Committer {
        Committer {
            file: Mutex::new(file),
            state: Mutex::new(CommitState { written: durable, synced: durable, syncing: false }),
            cv: Condvar::new(),
            syncs: AtomicU64::new(0),
        }
    }

    /// Block until at least `seq` bytes of the file are durable,
    /// becoming the fsync leader if nobody already is.
    fn commit(&self, seq: u64) -> Result<(), DqError> {
        let mut st = self.state.lock().expect("committer poisoned");
        loop {
            if st.synced >= seq {
                return Ok(());
            }
            if st.syncing {
                st = self.cv.wait(st).expect("committer wait");
                continue;
            }
            // Leader: sync everything written so far, not just our own
            // record — followers that arrived meanwhile ride along.
            st.syncing = true;
            let target = st.written;
            drop(st);
            let res = self.file.lock().expect("committer file poisoned").sync_data();
            self.syncs.fetch_add(1, Ordering::Relaxed);
            st = self.state.lock().expect("committer poisoned");
            st.syncing = false;
            if let Err(e) = res {
                self.cv.notify_all();
                return Err(e.into());
            }
            if target > st.synced {
                st.synced = target;
            }
            self.cv.notify_all();
        }
    }
}

/// A pending durability claim from [`Journal::append_async`]: the
/// record's bytes are already in the file; [`CommitTicket::commit`]
/// blocks until they are fsynced, coalescing with concurrent committers.
/// Commit *after* releasing the journal mutex — that release is the
/// whole point of the two-phase append.
#[derive(Debug)]
pub struct CommitTicket {
    committer: Arc<Committer>,
    seq: u64,
}

impl CommitTicket {
    /// Wait until this append is durable (leader-coalesced fsync).
    pub fn commit(self) -> Result<(), DqError> {
        self.committer.commit(self.seq)
    }
}

/// An open write-ahead journal (one per manager; behind the manager's
/// innermost `journal` mutex — DESIGN.md §16 lock order).
#[derive(Debug)]
pub struct Journal {
    cfg: JournalConfig,
    file: File,
    bytes: u64,
    appends: u32,
    dirty: bool,
    committer: Arc<Committer>,
}

impl Journal {
    /// Create a *fresh* journal, truncating anything at the path. Used
    /// by `Manager::new`/`with_clock`; to resume from existing records,
    /// use [`Journal::recover`] (via `Manager::recover`).
    pub fn create(cfg: &JournalConfig) -> Result<Journal, DqError> {
        let mut file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&cfg.path)?;
        file.write_all(MAGIC)?;
        file.sync_data()?;
        let bytes = MAGIC.len() as u64;
        let committer = Arc::new(Committer::new(file.try_clone()?, bytes));
        Ok(Journal { cfg: cfg.clone(), file, bytes, appends: 0, dirty: false, committer })
    }

    /// Open (creating if absent) and replay the journal at `cfg.path`:
    /// frames replay in order until the first short, checksum-failing,
    /// or undecodable record; everything from that point on is a torn
    /// tail and is truncated off, leaving the file ready for appends.
    /// Replaying the same file repeatedly (recover → recover → recover)
    /// yields the same state — recovery itself appends nothing.
    pub fn recover(cfg: &JournalConfig) -> Result<(Journal, RecoveredState), DqError> {
        let mut file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(&cfg.path)?;
        let mut data = Vec::new();
        file.read_to_end(&mut data)?;
        let mut state = RecoveredState::default();
        let mut good: usize = 0;
        if data.len() >= MAGIC.len() && &data[..MAGIC.len()] == MAGIC {
            good = MAGIC.len();
            loop {
                let rest = &data[good..];
                if rest.len() < 8 {
                    break;
                }
                let len = u32::from_le_bytes(rest[0..4].try_into().unwrap());
                let crc = u32::from_le_bytes(rest[4..8].try_into().unwrap());
                if len > MAX_RECORD {
                    break;
                }
                let len = len as usize;
                if rest.len() < 8 + len {
                    break;
                }
                let payload = &rest[8..8 + len];
                if crc32(payload) != crc {
                    break;
                }
                let Ok(rec) = Record::decode(payload) else { break };
                state.apply(rec);
                state.records += 1;
                good += 8 + len;
            }
        } else if !MAGIC.starts_with(&data[..data.len().min(MAGIC.len())]) {
            // A full bad header is some other file — refuse to clobber
            // it. (A short prefix of MAGIC is a torn first write of our
            // own header: start over below.)
            return Err(DqError::Io(format!(
                "{}: not a DQuLearn journal (bad magic)",
                cfg.path.display()
            )));
        }
        state.truncated_bytes = (data.len() - good) as u64;
        if good < MAGIC.len() {
            file.set_len(0)?;
            file.seek(SeekFrom::Start(0))?;
            file.write_all(MAGIC)?;
            good = MAGIC.len();
        } else if state.truncated_bytes > 0 {
            file.set_len(good as u64)?;
            file.seek(SeekFrom::Start(good as u64))?;
        } else {
            file.seek(SeekFrom::End(0))?;
        }
        // Make the truncation itself durable before new appends land
        // after it.
        file.sync_data()?;
        let bytes = good as u64;
        let committer = Arc::new(Committer::new(file.try_clone()?, bytes));
        let journal =
            Journal { cfg: cfg.clone(), file, bytes, appends: 0, dirty: false, committer };
        Ok((journal, state))
    }

    /// Append one record and make it durable per [`SyncPolicy`]. Under
    /// `Always` this commits inline — callers that can drop the journal
    /// lock first should use [`Journal::append_async`] so concurrent
    /// appends group-commit instead of serializing their fsyncs.
    pub fn append(&mut self, rec: &Record) -> Result<(), DqError> {
        match self.append_async(rec)? {
            Some(ticket) => ticket.commit(),
            None => Ok(()),
        }
    }

    /// Two-phase append. The bytes reach the file immediately
    /// (process-crash durability); under [`SyncPolicy::Always`] the
    /// fsync is deferred to the returned ticket so the caller can
    /// release the journal mutex first and coalesce with concurrent
    /// committers (DESIGN.md §16). `Batch`/`Never` behave exactly as
    /// [`Journal::append`] and return no ticket.
    pub fn append_async(&mut self, rec: &Record) -> Result<Option<CommitTicket>, DqError> {
        let payload = rec.encode();
        debug_assert!((payload.len() as u64) < MAX_RECORD as u64);
        let mut buf = Vec::with_capacity(payload.len() + 8);
        put_u32(&mut buf, payload.len() as u32);
        put_u32(&mut buf, crc32(&payload));
        buf.extend_from_slice(&payload);
        self.file.write_all(&buf)?;
        self.bytes += buf.len() as u64;
        self.dirty = true;
        self.appends = self.appends.wrapping_add(1);
        match self.cfg.sync {
            SyncPolicy::Always => {
                self.committer.state.lock().expect("committer poisoned").written = self.bytes;
                Ok(Some(CommitTicket { committer: self.committer.clone(), seq: self.bytes }))
            }
            SyncPolicy::Batch if self.appends % BATCH_SYNC_EVERY == 0 => {
                self.flush()?;
                Ok(None)
            }
            _ => Ok(None),
        }
    }

    /// Leader fsyncs the group-commit path has performed so far — the
    /// amortization gauge (fsyncs-per-append) for benches and tests.
    pub fn sync_count(&self) -> u64 {
        self.committer.syncs.load(Ordering::Relaxed)
    }

    /// Fsync pending appends (no-op when clean).
    pub fn flush(&mut self) -> Result<(), DqError> {
        if self.dirty {
            self.file.sync_data()?;
            self.dirty = false;
        }
        Ok(())
    }

    /// Current file length in bytes.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// True once the file exceeds the configured compaction threshold.
    pub fn should_compact(&self) -> bool {
        self.bytes > self.cfg.compact_bytes
    }

    /// Replace the log with a single snapshot record: written to
    /// `<path>.tmp`, fsynced, then atomically renamed over the journal —
    /// a crash at any point leaves either the old log or the new one,
    /// never a mix. Appends continue on the renamed file.
    pub fn compact(&mut self, snap: Snapshot) -> Result<(), DqError> {
        let tmp = tmp_path(&self.cfg.path);
        let mut f = OpenOptions::new().create(true).write(true).truncate(true).open(&tmp)?;
        f.write_all(MAGIC)?;
        let payload = Record::Snapshot(snap).encode();
        let mut buf = Vec::with_capacity(payload.len() + 8);
        put_u32(&mut buf, payload.len() as u32);
        put_u32(&mut buf, crc32(&payload));
        buf.extend_from_slice(&payload);
        f.write_all(&buf)?;
        f.sync_data()?;
        std::fs::rename(&tmp, &self.cfg.path)?;
        // Renaming keeps the inode: `f` now addresses the journal path,
        // positioned at its end — keep appending through it.
        self.file = f;
        self.bytes = (MAGIC.len() + buf.len()) as u64;
        self.appends = 0;
        self.dirty = false;
        // The committer's handle still points at the replaced inode:
        // swap in a fresh one. Outstanding tickets keep the old
        // committer (their records were subsumed by the fsynced
        // snapshot, and sync_data on the old fd stays valid).
        self.committer = Arc::new(Committer::new(self.file.try_clone()?, self.bytes));
        // Best effort: make the rename itself durable.
        if let Some(dir) = self.cfg.path.parent() {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("dq_journal_unit_{}_{name}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn group_commit_coalesces_concurrent_always_appends() {
        let path = tdir("group_commit");
        let cfg = JournalConfig::new(&path).sync(SyncPolicy::Always);
        let journal = Arc::new(Mutex::new(Journal::create(&cfg).unwrap()));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let j = journal.clone();
                std::thread::spawn(move || {
                    for i in 0..20u64 {
                        // Two-phase: append under the lock, commit off it
                        // — the manager's journal_append discipline.
                        let ticket = j
                            .lock()
                            .unwrap()
                            .append_async(&Record::Resolved { bank: t * 1000 + i })
                            .unwrap()
                            .expect("Always must return a ticket");
                        ticket.commit().unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let syncs = journal.lock().unwrap().sync_count();
        // Leader-coalesced commits can never fsync more than once per
        // append; under contention they fsync far less (the bench's
        // "always16" row measures the amortization).
        assert!((1..=160).contains(&syncs), "{syncs} fsyncs for 160 appends");
        drop(journal);
        let (_, state) = Journal::recover(&cfg).unwrap();
        assert_eq!(state.records, 160, "every committed append must replay");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_inline_still_durable_under_always() {
        let path = tdir("always_inline");
        let cfg = JournalConfig::new(&path).sync(SyncPolicy::Always);
        let mut j = Journal::create(&cfg).unwrap();
        j.append(&Record::Resolved { bank: 1 }).unwrap();
        j.append(&Record::Resolved { bank: 2 }).unwrap();
        assert_eq!(j.sync_count(), 2, "uncontended Always commits fsync once each");
        drop(j);
        let (_, state) = Journal::recover(&cfg).unwrap();
        assert_eq!(state.records, 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_and_recover_round_trip() {
        let path = tdir("roundtrip");
        let cfg = JournalConfig::new(&path).sync(SyncPolicy::Never);
        let mut j = Journal::create(&cfg).unwrap();
        let pairs = vec![(vec![0.1, 0.2], vec![0.3, 0.4])];
        j.append(&Record::Submitted {
            bank: 1,
            client: 7,
            qubits: 5,
            layers: 1,
            digest: payload_digest(&pairs),
            pairs,
        })
        .unwrap();
        j.append(&Record::Dispatched { members: vec![(1, 0)] }).unwrap();
        j.append(&Record::Completed { results: vec![(1, 0, 0.9)] }).unwrap();
        j.flush().unwrap();
        drop(j);
        let (_j2, state) = Journal::recover(&cfg).unwrap();
        assert_eq!(state.records, 3);
        assert_eq!(state.truncated_bytes, 0);
        let b = &state.banks[&1];
        assert_eq!(b.client, 7);
        assert_eq!(b.circuits, vec![CircuitState::Done(0.9)]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_preserves_state_and_shrinks_file() {
        let path = tdir("compact");
        let cfg = JournalConfig::new(&path).sync(SyncPolicy::Never);
        let mut j = Journal::create(&cfg).unwrap();
        for bank in 1..=50u64 {
            let pairs = vec![(vec![bank as f32], vec![0.0])];
            j.append(&Record::Submitted {
                bank,
                client: 1,
                qubits: 5,
                layers: 1,
                digest: payload_digest(&pairs),
                pairs,
            })
            .unwrap();
            j.append(&Record::Resolved { bank }).unwrap();
        }
        let before = j.bytes();
        j.compact(Snapshot {
            next_bank: 51,
            next_client: 2,
            cancelled: vec![13],
            banks: vec![],
            weights: vec![(7, 4)],
        })
        .unwrap();
        assert!(j.bytes() < before);
        // the journal keeps accepting appends after the rename
        j.append(&Record::Cancelled { bank: 51 }).unwrap();
        drop(j);
        let (_j2, state) = Journal::recover(&cfg).unwrap();
        assert_eq!(state.max_bank, 50);
        assert!(state.cancelled.contains(&13), "tombstone must survive compaction");
        assert!(state.cancelled.contains(&51));
        assert!(state.banks.is_empty());
        assert_eq!(state.weights.get(&7), Some(&4), "weights must survive compaction");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn foreign_file_is_refused() {
        let path = tdir("foreign");
        std::fs::write(&path, b"definitely not a journal").unwrap();
        let cfg = JournalConfig::new(&path);
        assert!(matches!(Journal::recover(&cfg), Err(DqError::Io(_))));
        let _ = std::fs::remove_file(&path);
    }
}
