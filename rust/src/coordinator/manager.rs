//! The co-Manager service: queueing, Algorithm-2 assignment, dispatch,
//! result routing, liveness, and multi-client bookkeeping.
//!
//! Transport-agnostic: workers are reached through the [`WorkerChannel`]
//! trait (TCP RPC in distributed mode, direct calls in `--in-proc` mode);
//! clients interact through typed [`super::session::ClientSession`]
//! handles obtained from [`Manager::session`] (wrapped by the RPC server
//! in `cluster::tcp` for remote clients).
//!
//! Lock order (outermost first): `queue` → `registry` → `in_flight` →
//! `batches` → `stats`. The `channels` map is never locked while any of
//! those are held.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::bankstore::{BankStatus, BankStore};
use super::job::{CircuitJob, JobId};
use super::registry::{Registry, WorkerId, WorkerProfile};
use super::scheduler;
use super::session::ClientSession;
use crate::circuit::QuClassiConfig;
use crate::error::DqError;
use crate::model::exec::CircuitPair;
use crate::util::{Clock, SystemClock};

/// How the manager reaches a worker's executor.
pub trait WorkerChannel: Send + Sync {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError>;
}

/// Manager tuning knobs.
#[derive(Debug, Clone)]
pub struct ManagerConfig {
    /// Heartbeat period in seconds (paper: 5 s; configurable).
    pub heartbeat_period: f64,
    /// Max circuits packed into one dispatch to a worker (the artifact
    /// batch is 32; 1 reproduces the paper's per-circuit assignment).
    pub max_batch: usize,
    /// Circuits dispatched per worker thread: a worker that registered
    /// `T` execution threads receives batches of up to
    /// `min(max_batch, T * batch_per_thread)` circuits, so the dispatch
    /// size tracks the worker's real parallelism (DESIGN.md §11).
    pub batch_per_thread: usize,
    /// Pending-queue backpressure limit (submits block above this).
    pub max_queue: usize,
    /// Bank wait timeout.
    pub wait_timeout: Duration,
    /// Noise-aware selection weight (extension §10): `Some(alpha)` ranks
    /// candidates by `alpha * noise + (1-alpha) * CRU`; `None` is the
    /// paper's CRU-only rule.
    pub noise_aware_alpha: Option<f64>,
}

impl Default for ManagerConfig {
    fn default() -> Self {
        ManagerConfig {
            heartbeat_period: 5.0,
            max_batch: 32,
            batch_per_thread: 32,
            max_queue: 100_000,
            wait_timeout: Duration::from_secs(600),
            noise_aware_alpha: None,
        }
    }
}

/// Aggregate counters.
#[derive(Debug, Clone, Default)]
pub struct ManagerStats {
    pub submitted: u64,
    pub completed: u64,
    pub dispatches: u64,
    pub requeues: u64,
    pub evictions: u64,
    /// Banks cancelled by clients.
    pub cancelled: u64,
}

struct Inner {
    cfg: ManagerConfig,
    clock: Arc<dyn Clock>,
    registry: Mutex<Registry>,
    queue: Mutex<VecDeque<CircuitJob>>,
    /// Signaled on: new work, capacity freed, shutdown.
    work_cv: Condvar,
    /// Signaled when queue length drops (backpressure release).
    space_cv: Condvar,
    banks: BankStore,
    channels: Mutex<HashMap<WorkerId, Arc<dyn WorkerChannel>>>,
    in_flight: Mutex<HashMap<JobId, CircuitJob>>,
    /// Dispatch batches keyed by their qubit-reservation id (the head
    /// job), for eviction-time re-queueing of whole batches.
    batches: Mutex<HashMap<JobId, Vec<JobId>>>,
    stats: Mutex<ManagerStats>,
    next_bank: AtomicU64,
    next_job: AtomicU64,
    next_client: AtomicU64,
    stop: AtomicBool,
}

/// The co-Manager. Cheap to clone (shared state).
#[derive(Clone)]
pub struct Manager {
    inner: Arc<Inner>,
}

impl Manager {
    /// Start a co-Manager on the system clock.
    pub fn new(cfg: ManagerConfig) -> Manager {
        Self::with_clock(cfg, Arc::new(SystemClock::new()))
    }

    /// Start a co-Manager on an explicit clock (virtual time in tests).
    pub fn with_clock(cfg: ManagerConfig, clock: Arc<dyn Clock>) -> Manager {
        let m = Manager {
            inner: Arc::new(Inner {
                cfg,
                clock,
                registry: Mutex::new(Registry::new(5.0)),
                queue: Mutex::new(VecDeque::new()),
                work_cv: Condvar::new(),
                space_cv: Condvar::new(),
                banks: BankStore::new(),
                channels: Mutex::new(HashMap::new()),
                in_flight: Mutex::new(HashMap::new()),
                batches: Mutex::new(HashMap::new()),
                stats: Mutex::new(ManagerStats::default()),
                next_bank: AtomicU64::new(1),
                next_job: AtomicU64::new(1),
                next_client: AtomicU64::new(1),
                stop: AtomicBool::new(false),
            }),
        };
        {
            let mut reg = m.inner.registry.lock().unwrap();
            reg.heartbeat_period = m.inner.cfg.heartbeat_period;
        }
        // Scheduler loop.
        let m2 = m.clone();
        std::thread::Builder::new()
            .name("co-manager".into())
            .spawn(move || m2.scheduler_loop())
            .expect("spawn co-manager");
        m
    }

    // ------------------------------------------------------------------
    // worker-facing API
    // ------------------------------------------------------------------

    /// Quantum Worker Registration (Algorithm 2 lines 2-6) from a typed
    /// [`WorkerProfile`] — the single registration entry point.
    pub fn register(&self, profile: WorkerProfile, channel: Arc<dyn WorkerChannel>) -> WorkerId {
        let now = self.inner.clock.now();
        let id = self.inner.registry.lock().unwrap().register_profile(&profile, now);
        self.inner.channels.lock().unwrap().insert(id, channel);
        self.inner.work_cv.notify_all();
        id
    }

    /// Registration with only qubit capacity and a CRU sample.
    #[deprecated(since = "0.2.0", note = "use Manager::register with a WorkerProfile")]
    pub fn register_worker(
        &self,
        max_qubits: usize,
        cru: f64,
        channel: Arc<dyn WorkerChannel>,
    ) -> WorkerId {
        self.register(WorkerProfile::new(max_qubits).cru(cru), channel)
    }

    /// Registration with a reported noise estimate (extension §10).
    #[deprecated(since = "0.2.0", note = "use Manager::register with a WorkerProfile")]
    pub fn register_worker_profile(
        &self,
        max_qubits: usize,
        cru: f64,
        noise: f64,
        channel: Arc<dyn WorkerChannel>,
    ) -> WorkerId {
        self.register(WorkerProfile::new(max_qubits).cru(cru).noise(noise), channel)
    }

    /// Full registration: noise estimate plus the worker's execution
    /// thread budget.
    #[deprecated(since = "0.2.0", note = "use Manager::register with a WorkerProfile")]
    pub fn register_worker_full(
        &self,
        max_qubits: usize,
        cru: f64,
        noise: f64,
        threads: usize,
        channel: Arc<dyn WorkerChannel>,
    ) -> WorkerId {
        self.register(
            WorkerProfile::new(max_qubits).cru(cru).noise(noise).threads(threads),
            channel,
        )
    }

    /// Periodic heartbeat (Algorithm 2 lines 7-11): liveness + CRU. The
    /// manager's own reserve/release bookkeeping remains authoritative
    /// for occupied qubits (worker self-reports race with in-pipe RPCs).
    /// An evicted or never-registered worker gets [`DqError::WorkerLost`]
    /// and should re-register.
    pub fn heartbeat(&self, worker: WorkerId, cru: f64) -> Result<(), DqError> {
        let now = self.inner.clock.now();
        self.inner.registry.lock().unwrap().heartbeat(worker, cru, now)
    }

    // ------------------------------------------------------------------
    // client-facing API
    // ------------------------------------------------------------------

    /// Open a typed client session (multi-tenant): the session owns its
    /// client id and hands out [`super::session::BankHandle`] futures.
    pub fn session(&self) -> ClientSession {
        let client = self.new_client();
        ClientSession::new(Arc::new(self.clone()), client)
    }

    /// Allocate a raw client id (prefer [`Manager::session`]).
    pub fn new_client(&self) -> u64 {
        self.inner.next_client.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit a bank of circuits; returns the bank id immediately.
    /// Blocks when the pending queue is above the backpressure limit.
    /// (Primitive under [`ClientSession::submit`].)
    pub fn submit_bank(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError> {
        if pairs.is_empty() {
            return Err(DqError::Arity("empty bank".to_string()));
        }
        for (t, d) in pairs {
            if t.len() != config.n_params() || d.len() != config.n_features() {
                return Err(DqError::Arity(format!(
                    "bank arity mismatch: theta {} (want {}), data {} (want {})",
                    t.len(),
                    config.n_params(),
                    d.len(),
                    config.n_features()
                )));
            }
        }
        let bank = self.inner.next_bank.fetch_add(1, Ordering::Relaxed);
        self.inner.banks.open(bank, pairs.len());

        // Backpressure: wait for queue space.
        let mut q = self.inner.queue.lock().unwrap();
        while q.len() + pairs.len() > self.inner.cfg.max_queue {
            if self.inner.stop.load(Ordering::Relaxed) {
                return Err(DqError::Cancelled("manager stopped".to_string()));
            }
            let (guard, _) = self
                .inner
                .space_cv
                .wait_timeout(q, Duration::from_millis(100))
                .unwrap();
            q = guard;
        }
        for (index, (thetas, data)) in pairs.iter().enumerate() {
            let id = self.inner.next_job.fetch_add(1, Ordering::Relaxed);
            q.push_back(CircuitJob {
                id,
                client,
                bank,
                index,
                config,
                thetas: thetas.clone(),
                data: data.clone(),
            });
        }
        self.inner.stats.lock().unwrap().submitted += pairs.len() as u64;
        drop(q);
        self.inner.work_cv.notify_all();
        Ok(bank)
    }

    /// Block until a bank completes (default timeout). This is the
    /// *consuming* wait path ([`super::session::BankHandle::wait`] and
    /// the `execute_bank` conveniences): a timeout here leaves the caller
    /// no way to retry, poll, or cancel, so the zombie bank is reaped
    /// (cancelled) before the [`DqError::Timeout`] is returned — its
    /// queued circuits drain and its state does not leak in a
    /// long-running multi-tenant manager.
    pub fn wait_bank(&self, bank: u64) -> Result<Vec<f32>, DqError> {
        match self.inner.banks.wait(bank, self.inner.cfg.wait_timeout) {
            Err(e @ DqError::Timeout(_)) => {
                self.cancel_bank(bank);
                Err(e)
            }
            other => other,
        }
    }

    /// Block until a bank completes, up to an explicit deadline. Unlike
    /// [`Manager::wait_bank`], a timeout leaves the bank resident: the
    /// caller holds a handle and can retry, poll, or escalate to
    /// `cancel` — abandoning it without cancelling leaks the bank.
    pub fn wait_bank_timeout(&self, bank: u64, timeout: Duration) -> Result<Vec<f32>, DqError> {
        self.inner.banks.wait(bank, timeout)
    }

    /// Non-blocking progress snapshot of a bank (None once waited out).
    pub fn bank_status(&self, bank: u64) -> Option<BankStatus> {
        self.inner.banks.status(bank)
    }

    /// True when the bank was ever cancelled — outlives the tombstone, so
    /// status/poll paths can answer [`DqError::Cancelled`] (not "unknown
    /// bank") after the GC.
    pub fn bank_cancelled(&self, bank: u64) -> bool {
        self.inner.banks.is_cancelled(bank)
    }

    /// Cancel a bank: drains its queued circuits (releasing backpressure),
    /// marks in-flight results discard-on-arrival, and wakes any waiter
    /// with [`DqError::Cancelled`]. Idempotent; returns the number of
    /// queued circuits drained.
    ///
    /// The cancelled bank's tombstone lives only as long as it has
    /// results still in flight (discard-on-arrival needs it); once the
    /// last one resolves it is garbage-collected, so cancel-without-wait
    /// does not leak. [`super::session::BankHandle`] keeps reporting
    /// `Cancelled` after the GC.
    pub fn cancel_bank(&self, bank: u64) -> usize {
        let mut q = self.inner.queue.lock().unwrap();
        let before = q.len();
        q.retain(|j| j.bank != bank);
        let drained = before - q.len();
        drop(q);
        if self.inner.banks.cancel(bank) {
            self.inner.stats.lock().unwrap().cancelled += 1;
        }
        // GC immediately when nothing is in flight (the check and the
        // discard serialize against dispatch completion on `in_flight`).
        let in_flight = self.inner.in_flight.lock().unwrap();
        self.gc_cancelled_banks(&[bank], &in_flight);
        drop(in_flight);
        // Queued work disappeared: release blocked submitters; nothing new
        // became schedulable, so the work_cv stays quiet.
        self.inner.space_cv.notify_all();
        drained
    }

    /// Drop tombstones of cancelled banks that have no in-flight work
    /// left. Callers hold the `in_flight` lock, so the emptiness check
    /// and the discard are atomic w.r.t. result arrival.
    fn gc_cancelled_banks(&self, banks: &[u64], in_flight: &HashMap<JobId, CircuitJob>) {
        for &bank in banks {
            if self.inner.banks.is_cancelled(bank)
                && !in_flight.values().any(|j| j.bank == bank)
            {
                self.inner.banks.discard(bank);
            }
        }
    }

    /// Convenience: submit + wait.
    pub fn execute_bank(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let bank = self.submit_bank(client, config, pairs)?;
        self.wait_bank(bank)
    }

    /// Snapshot of the aggregate counters.
    pub fn stats(&self) -> ManagerStats {
        self.inner.stats.lock().unwrap().clone()
    }

    /// Number of registered (live) workers.
    pub fn worker_count(&self) -> usize {
        self.inner.registry.lock().unwrap().len()
    }

    /// Circuits currently pending assignment.
    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    /// Total available (unreserved) qubits across the pool.
    pub fn available_qubits(&self) -> usize {
        self.inner.registry.lock().unwrap().total_available()
    }

    /// Stop the scheduler loop and wake all waiters.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.work_cv.notify_all();
        self.inner.space_cv.notify_all();
    }

    // ------------------------------------------------------------------
    // scheduler loop (Algorithm 2 line 14-20 + dispatch)
    // ------------------------------------------------------------------

    fn scheduler_loop(&self) {
        while !self.inner.stop.load(Ordering::Relaxed) {
            // Liveness pass: evict stale workers, re-queue their circuits.
            self.evict_and_requeue();

            // Take the next schedulable batch.
            let batch = self.next_assignment();
            match batch {
                Some((worker, config, jobs)) => self.dispatch(worker, config, jobs),
                None => {
                    // Nothing schedulable: wait for work/capacity.
                    let q = self.inner.queue.lock().unwrap();
                    let _ = self
                        .inner
                        .work_cv
                        .wait_timeout(q, Duration::from_millis(20))
                        .unwrap();
                }
            }
        }
    }

    fn evict_and_requeue(&self) {
        let now = self.inner.clock.now();
        let evicted = self.inner.registry.lock().unwrap().evict_stale(now);
        if evicted.is_empty() {
            return;
        }
        // Prune channels first, on their own — taking the channels lock
        // while queue/in_flight/stats are held would be the reverse of the
        // dispatch path's nesting (lock-order hazard).
        {
            let mut channels = self.inner.channels.lock().unwrap();
            for (wid, _) in &evicted {
                channels.remove(wid);
            }
        }
        let mut q = self.inner.queue.lock().unwrap();
        let mut in_flight = self.inner.in_flight.lock().unwrap();
        let mut batches = self.inner.batches.lock().unwrap();
        let mut stats = self.inner.stats.lock().unwrap();
        for (_wid, orphan_keys) in evicted {
            stats.evictions += 1;
            for key in orphan_keys {
                // each orphaned reservation is a whole dispatch batch
                let members = batches.remove(&key).unwrap_or_else(|| vec![key]);
                for job_id in members {
                    if let Some(job) = in_flight.remove(&job_id) {
                        stats.requeues += 1;
                        q.push_front(job);
                    }
                }
            }
        }
        drop(stats);
        drop(batches);
        drop(in_flight);
        drop(q);
        self.inner.work_cv.notify_all();
    }

    /// Pick the next circuit and worker per Algorithm 2; greedily extend
    /// the assignment with same-config circuits into one dispatch batch
    /// (`max_batch = 1` reproduces the paper's per-circuit behavior).
    ///
    /// Capacity semantics: a batch executes as ONE unit on the worker
    /// (one PJRT program / one sequential backend job), so it reserves
    /// its `demand` qubits once — concurrent *batches* on a big worker
    /// are what multi-tenant packing schedules.
    ///
    /// Unschedulable head-of-line circuits fail their bank and the loop
    /// continues with the remaining queue immediately, instead of
    /// stalling schedulable work until the next scheduler tick.
    #[allow(clippy::type_complexity)]
    fn next_assignment(&self) -> Option<(WorkerId, QuClassiConfig, Vec<CircuitJob>)> {
        loop {
            let mut q = self.inner.queue.lock().unwrap();
            if q.is_empty() {
                return None;
            }
            let mut reg = self.inner.registry.lock().unwrap();

            // Head-of-line circuit picks the worker (paper semantics)...
            let head = q.front().unwrap();
            let demand = head.demand();
            // An empty pool is not a failure: workers may still join
            // (dynamic registration); park the queue until one does.
            if reg.is_empty() {
                return None;
            }
            if !scheduler::can_ever_fit(&reg, demand) {
                // Unschedulable on the current pool: fail its whole bank
                // (every sibling shares the config, hence the demand).
                let bank = q.pop_front().unwrap().bank;
                q.retain(|j| j.bank != bank);
                drop(reg);
                drop(q);
                self.inner.banks.fail(
                    bank,
                    DqError::Unschedulable(format!(
                        "circuit needs {demand} qubits; no worker that large"
                    )),
                );
                self.inner.space_cv.notify_all();
                continue;
            }
            let worker = match self.inner.cfg.noise_aware_alpha {
                Some(alpha) => scheduler::select_noise_aware(&reg, demand, alpha)?,
                None => scheduler::select(&reg, demand)?,
            };
            let config = head.config;

            // ...then pack same-config circuits into the batch, sized by
            // the worker's registered thread budget so one dispatch
            // saturates its backend pool without starving co-tenants
            // (DESIGN.md §11).
            let worker_threads = reg.get(worker).map(|w| w.threads).unwrap_or(1);
            let batch_limit = self
                .inner
                .cfg
                .max_batch
                .min(worker_threads.saturating_mul(self.inner.cfg.batch_per_thread))
                .max(1);
            let jobs = Self::pack_batch(&mut q, config, batch_limit);
            debug_assert!(!jobs.is_empty());
            // One reservation for the whole batch, keyed by the head job.
            let key = jobs[0].id;
            reg.reserve(worker, key, demand).expect("capacity checked");
            let mut in_flight = self.inner.in_flight.lock().unwrap();
            for j in &jobs {
                in_flight.insert(j.id, j.clone());
            }
            let mut batches = self.inner.batches.lock().unwrap();
            batches.insert(key, jobs.iter().map(|j| j.id).collect());
            drop(batches);
            drop(in_flight);
            drop(reg);
            drop(q);
            self.inner.space_cv.notify_all();
            return Some((worker, config, jobs));
        }
    }

    /// Take up to `limit` circuits of `config` from the queue head. The
    /// contiguous same-config prefix is popped directly (the common,
    /// homogeneous-queue case costs O(batch)); only when interleaved
    /// tenants break the run does one drain/partition pass scan the rest —
    /// O(n) total, replacing the old `VecDeque::remove`-in-a-scan that was
    /// O(n²) (see `benches/micro_queue.rs`).
    fn pack_batch(
        q: &mut VecDeque<CircuitJob>,
        config: QuClassiConfig,
        limit: usize,
    ) -> Vec<CircuitJob> {
        let mut jobs = Vec::with_capacity(limit.min(q.len()));
        while jobs.len() < limit && q.front().is_some_and(|j| j.config == config) {
            jobs.push(q.pop_front().unwrap());
        }
        if jobs.len() < limit && q.iter().any(|j| j.config == config) {
            let mut rest = VecDeque::with_capacity(q.len());
            while let Some(job) = q.pop_front() {
                if jobs.len() < limit && job.config == config {
                    jobs.push(job);
                } else {
                    rest.push_back(job);
                }
            }
            *q = rest;
        }
        jobs
    }

    /// Send one batch to a worker on a dispatch thread; completion updates
    /// the registry, bank store, and wakes the scheduler.
    fn dispatch(&self, worker: WorkerId, config: QuClassiConfig, jobs: Vec<CircuitJob>) {
        let channel = match self.inner.channels.lock().unwrap().get(&worker) {
            Some(c) => c.clone(),
            None => {
                // Worker vanished between selection and dispatch: re-queue.
                self.requeue(worker, jobs);
                return;
            }
        };
        self.inner.stats.lock().unwrap().dispatches += 1;
        let m = self.clone();
        std::thread::Builder::new()
            .name(format!("dispatch-w{worker}"))
            .spawn(move || {
                let pairs: Vec<CircuitPair> =
                    jobs.iter().map(|j| (j.thetas.clone(), j.data.clone())).collect();
                match channel.execute(&config, &pairs) {
                    Ok(fids) if fids.len() != jobs.len() => {
                        // A short/overlong fids payload is a protocol
                        // violation: the per-circuit mapping is unknown, so
                        // fail every bank in the batch rather than guess
                        // (or hang a waiting client).
                        let err = DqError::Protocol(format!(
                            "worker w{worker} returned {} fids for {} circuits",
                            fids.len(),
                            jobs.len()
                        ));
                        crate::log_warn!("manager", "{err}");
                        m.abandon_batch(worker, &jobs, err);
                    }
                    Ok(fids) => {
                        // Order matters: bump the completion counter before
                        // banks.complete() can wake a waiting client, so a
                        // stats read right after wait_bank() is consistent.
                        m.inner.stats.lock().unwrap().completed += jobs.len() as u64;
                        let key = jobs[0].id;
                        let mut reg = m.inner.registry.lock().unwrap();
                        let mut in_flight = m.inner.in_flight.lock().unwrap();
                        reg.release(worker, key);
                        m.inner.batches.lock().unwrap().remove(&key);
                        for (job, fid) in jobs.iter().zip(fids.iter()) {
                            in_flight.remove(&job.id);
                            m.inner.banks.complete(job.bank, job.index, *fid);
                        }
                        m.gc_cancelled_banks(&distinct_banks(&jobs), &in_flight);
                        drop(in_flight);
                        drop(reg);
                        m.inner.work_cv.notify_all();
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "manager",
                            "dispatch to w{worker} failed ({e}); re-queueing {} circuits",
                            jobs.len()
                        );
                        m.requeue(worker, jobs);
                    }
                }
            })
            .expect("spawn dispatch");
    }

    /// Drop a batch whose results are unusable: release the reservation,
    /// clear in-flight records, and fail every bank it touched
    /// (cancelled banks just have their tombstones GC'd).
    fn abandon_batch(&self, worker: WorkerId, jobs: &[CircuitJob], err: DqError) {
        let mut reg = self.inner.registry.lock().unwrap();
        let mut in_flight = self.inner.in_flight.lock().unwrap();
        if let Some(first) = jobs.first() {
            reg.release(worker, first.id);
            self.inner.batches.lock().unwrap().remove(&first.id);
        }
        for job in jobs {
            in_flight.remove(&job.id);
        }
        let banks = distinct_banks(jobs);
        self.gc_cancelled_banks(&banks, &in_flight);
        drop(in_flight);
        drop(reg);
        for bank in banks {
            // no-op for cancelled banks (fail never overrides a cancel)
            self.inner.banks.fail(bank, err.clone());
        }
        self.inner.work_cv.notify_all();
    }

    fn requeue(&self, worker: WorkerId, jobs: Vec<CircuitJob>) {
        let mut q = self.inner.queue.lock().unwrap();
        let mut reg = self.inner.registry.lock().unwrap();
        let mut in_flight = self.inner.in_flight.lock().unwrap();
        if let Some(first) = jobs.first() {
            reg.release(worker, first.id);
            self.inner.batches.lock().unwrap().remove(&first.id);
        }
        let banks = distinct_banks(&jobs);
        let mut stats = self.inner.stats.lock().unwrap();
        for job in jobs {
            in_flight.remove(&job.id);
            // Never resurrect a cancelled bank's work: its queued jobs
            // were drained at cancel time, so a failed/evicted batch is
            // simply dropped.
            if self.inner.banks.is_cancelled(job.bank) {
                continue;
            }
            stats.requeues += 1;
            q.push_front(job);
        }
        drop(stats);
        self.gc_cancelled_banks(&banks, &in_flight);
        drop(in_flight);
        drop(reg);
        drop(q);
        self.inner.work_cv.notify_all();
    }
}

/// The distinct bank ids appearing in a batch.
fn distinct_banks(jobs: &[CircuitJob]) -> Vec<u64> {
    let mut banks: Vec<u64> = jobs.iter().map(|j| j.bank).collect();
    banks.sort_unstable();
    banks.dedup();
    banks
}

impl Drop for Inner {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::exec::QsimExecutor;
    use crate::model::CircuitExecutor;

    /// Worker channel backed by the local simulator.
    struct SimChannel;

    impl WorkerChannel for SimChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            QsimExecutor.execute_bank(config, pairs)
        }
    }

    /// A channel that always fails (fault injection).
    struct FlakyChannel {
        fail_first: std::sync::atomic::AtomicU32,
    }

    impl WorkerChannel for FlakyChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            if self.fail_first.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                if v > 0 {
                    Some(v - 1)
                } else {
                    None
                }
            }).is_ok()
            {
                return Err(DqError::Io("injected fault".to_string()));
            }
            QsimExecutor.execute_bank(config, pairs)
        }
    }

    /// A channel that pauses per batch — lets tests observe in-progress
    /// banks deterministically.
    struct SlowChannel {
        delay: Duration,
    }

    impl WorkerChannel for SlowChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            std::thread::sleep(self.delay);
            QsimExecutor.execute_bank(config, pairs)
        }
    }

    /// A channel that sleeps, then fails every batch (eviction-path
    /// fault injection).
    struct SlowFailChannel {
        delay: Duration,
    }

    impl WorkerChannel for SlowFailChannel {
        fn execute(
            &self,
            _config: &QuClassiConfig,
            _pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            std::thread::sleep(self.delay);
            Err(DqError::Io("injected fault".to_string()))
        }
    }

    /// A channel that returns one fidelity too few (protocol violation).
    struct ShortChannel;

    impl WorkerChannel for ShortChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            let mut fids = QsimExecutor.execute_bank(config, pairs)?;
            fids.pop();
            Ok(fids)
        }
    }

    fn pairs_for(config: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
        let mut rng = crate::util::Rng::new(9);
        (0..n)
            .map(|_| {
                (
                    (0..config.n_params()).map(|_| rng.f32()).collect(),
                    (0..config.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn single_worker_end_to_end() {
        let m = Manager::new(ManagerConfig::default());
        m.register(WorkerProfile::new(5).cru(0.1), Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 10);
        let session = m.session();
        let fids = session.execute(cfg, &pairs).unwrap();
        assert_eq!(fids.len(), 10);
        // results must match direct simulation exactly
        let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(fids, want);
        assert_eq!(m.stats().completed, 10);
        m.shutdown();
    }

    #[test]
    fn deprecated_register_shims_still_work() {
        let m = Manager::new(ManagerConfig::default());
        #[allow(deprecated)]
        {
            m.register_worker(5, 0.1, Arc::new(SimChannel));
            m.register_worker_profile(5, 0.1, 0.0, Arc::new(SimChannel));
            m.register_worker_full(5, 0.1, 0.0, 2, Arc::new(SimChannel));
        }
        assert_eq!(m.worker_count(), 3);
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 6);
        let fids = m.session().execute(cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        m.shutdown();
    }

    #[test]
    fn multiple_workers_share_load() {
        let m = Manager::new(ManagerConfig { max_batch: 2, ..Default::default() });
        for _ in 0..4 {
            m.register(WorkerProfile::new(5), Arc::new(SimChannel));
        }
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let pairs = pairs_for(&cfg, 30);
        let fids = m.session().execute(cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        assert!(m.stats().dispatches >= 15); // 30 circuits / batch 2
        m.shutdown();
    }

    #[test]
    fn batches_are_sized_by_worker_thread_budget() {
        // max_batch is large; the 2-thread worker's budget (2 * 3 = 6)
        // caps each dispatch instead.
        let m = Manager::new(ManagerConfig {
            max_batch: 100,
            batch_per_thread: 3,
            ..Default::default()
        });
        m.register(WorkerProfile::new(5).threads(2), Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 30);
        let fids = m.session().execute(cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        assert!(m.stats().dispatches >= 5, "expected >= 30/6 dispatches");
        m.shutdown();
    }

    #[test]
    fn oversized_circuit_fails_cleanly() {
        let m = Manager::new(ManagerConfig::default());
        m.register(WorkerProfile::new(5), Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(7, 1).unwrap(); // needs 7 > 5
        let pairs = pairs_for(&cfg, 2);
        let err = m.session().execute(cfg, &pairs).unwrap_err();
        assert!(matches!(&err, DqError::Unschedulable(m) if m.contains("no worker")), "{err}");
        m.shutdown();
    }

    #[test]
    fn unschedulable_bank_does_not_stall_schedulable_work() {
        // Head-of-line: an oversized bank in front of a schedulable one
        // must fail fast while the schedulable bank completes in the same
        // scheduler pass (satellite fix: loop instead of bail to the next
        // 20 ms tick).
        let m = Manager::new(ManagerConfig::default());
        m.register(WorkerProfile::new(5), Arc::new(SimChannel));
        let cfg_big = QuClassiConfig::new(9, 1).unwrap();
        let cfg_ok = QuClassiConfig::new(5, 1).unwrap();
        let session = m.session();
        let doomed = session.submit(cfg_big, &pairs_for(&cfg_big, 4)).unwrap();
        let viable = session.submit(cfg_ok, &pairs_for(&cfg_ok, 4)).unwrap();
        assert!(matches!(doomed.wait(), Err(DqError::Unschedulable(_))));
        let fids = viable.wait().unwrap();
        assert_eq!(fids.len(), 4);
        m.shutdown();
    }

    #[test]
    fn dispatch_failure_requeues_and_recovers() {
        let m = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
        m.register(
            WorkerProfile::new(5),
            Arc::new(FlakyChannel { fail_first: std::sync::atomic::AtomicU32::new(2) }),
        );
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 8);
        let fids = m.session().execute(cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        assert!(m.stats().requeues > 0);
        m.shutdown();
    }

    #[test]
    fn short_fids_payload_fails_bank_with_protocol_error() {
        let m = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
        m.register(WorkerProfile::new(5), Arc::new(ShortChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 4);
        let err = m.session().execute(cfg, &pairs).unwrap_err();
        assert!(matches!(err, DqError::Protocol(_)), "{err}");
        // the batch reservation must have been released
        assert_eq!(m.available_qubits(), 5);
        m.shutdown();
    }

    #[test]
    fn concurrent_clients_multi_tenant() {
        // A 20-qubit and a 5-qubit worker; two clients with different
        // configs submit concurrently (the paper's multi-tenant setting).
        let m = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
        m.register(WorkerProfile::new(20).cru(0.2), Arc::new(SimChannel));
        m.register(WorkerProfile::new(5).cru(0.1), Arc::new(SimChannel));
        let m1 = m.clone();
        let t1 = std::thread::spawn(move || {
            let cfg = QuClassiConfig::new(5, 1).unwrap();
            let pairs = pairs_for(&cfg, 20);
            let fids = m1.session().execute(cfg, &pairs).unwrap();
            assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        });
        let m2 = m.clone();
        let t2 = std::thread::spawn(move || {
            let cfg = QuClassiConfig::new(7, 2).unwrap();
            let pairs = pairs_for(&cfg, 20);
            let fids = m2.session().execute(cfg, &pairs).unwrap();
            assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        });
        t1.join().unwrap();
        t2.join().unwrap();
        assert_eq!(m.stats().completed, 40);
        m.shutdown();
    }

    #[test]
    fn no_worker_keeps_bank_pending_until_one_joins() {
        let m = Manager::new(ManagerConfig::default());
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 3);
        let session = m.session();
        let handle = session.submit(cfg, &pairs).unwrap();
        // register a worker shortly after; dynamic join must drain it
        let m2 = m.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            m2.register(WorkerProfile::new(5), Arc::new(SimChannel));
        });
        let fids = handle.wait().unwrap();
        assert_eq!(fids.len(), 3);
        m.shutdown();
    }

    #[test]
    fn empty_bank_rejected() {
        let m = Manager::new(ManagerConfig::default());
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        assert!(matches!(m.submit_bank(1, cfg, &[]), Err(DqError::Arity(_))));
        assert!(matches!(m.session().submit(cfg, &[]), Err(DqError::Arity(_))));
        m.shutdown();
    }

    #[test]
    fn cancel_drains_queue_and_discards_in_flight() {
        // One slow 5-qubit worker, batch size 1: circuits complete one at
        // a time, so the bank is observably half-done when we cancel.
        let m = Manager::new(ManagerConfig { max_batch: 1, ..Default::default() });
        m.register(
            WorkerProfile::new(5),
            Arc::new(SlowChannel { delay: Duration::from_millis(25) }),
        );
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 12);
        let session = m.session();
        let handle = session.submit(cfg, &pairs).unwrap();
        // wait for partial progress
        loop {
            let st = handle.try_poll().unwrap();
            if st.completed >= 2 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        handle.cancel().unwrap();
        assert_eq!(m.queue_len(), 0, "queued circuits must drain on cancel");
        assert!(matches!(handle.wait_timeout(Duration::from_secs(5)), Err(DqError::Cancelled(_))));
        let requeues = m.stats().requeues;
        assert_eq!(requeues, 0, "cancel must not requeue anything");
        assert_eq!(m.stats().cancelled, 1);
        // the worker finishes its in-flight circuit and frees up: a new
        // bank from another tenant completes with exact parity.
        let other = m.session();
        let pairs2 = pairs_for(&cfg, 3);
        let fids = other.execute(cfg, &pairs2).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs2).unwrap());
        m.shutdown();
    }

    #[test]
    fn cancel_with_nothing_in_flight_still_reports_cancelled() {
        // No workers: every circuit stays queued, so cancel GCs the
        // tombstone immediately. Late observers must still see the
        // cancellation — never an "unknown bank" Protocol error that
        // depends on GC timing.
        let m = Manager::new(ManagerConfig::default());
        let session = m.session();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let handle = session.submit(cfg, &pairs_for(&cfg, 4)).unwrap();
        assert_eq!(handle.cancel().unwrap(), 4);
        assert_eq!(m.queue_len(), 0);
        assert!(matches!(handle.try_poll(), Err(DqError::Cancelled(_))));
        assert!(matches!(
            handle.wait_timeout(Duration::from_secs(1)),
            Err(DqError::Cancelled(_))
        ));
        assert!(matches!(handle.wait(), Err(DqError::Cancelled(_))));
        m.shutdown();
    }

    #[test]
    fn consuming_wait_timeout_reaps_the_bank() {
        // The default-timeout wait consumes the handle, so a timeout
        // leaves no way to retry or cancel — the manager must reap the
        // zombie bank instead of leaking it.
        let m = Manager::new(ManagerConfig {
            wait_timeout: Duration::from_millis(30),
            ..Default::default()
        });
        let session = m.session();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let handle = session.submit(cfg, &pairs_for(&cfg, 3)).unwrap(); // no workers
        let bank = handle.id();
        assert!(matches!(handle.wait(), Err(DqError::Timeout(_))));
        assert_eq!(m.queue_len(), 0, "queued circuits must drain on reap");
        assert!(m.bank_status(bank).is_none(), "bank state must not leak");
        assert!(m.bank_cancelled(bank));
        assert_eq!(m.stats().cancelled, 1);
        m.shutdown();
    }

    #[test]
    fn failed_dispatch_after_cancel_and_wait_does_not_resurrect() {
        // Waiting out a cancellation removes the tombstone while a batch
        // is still on the worker; when that dispatch then fails, the
        // cancelled bank's jobs must be dropped (the persistent
        // cancelled-id record), never requeued and re-executed.
        let m = Manager::new(ManagerConfig { max_batch: 1, ..Default::default() });
        m.register(
            WorkerProfile::new(5),
            Arc::new(SlowFailChannel { delay: Duration::from_millis(60) }),
        );
        let session = m.session();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let handle = session.submit(cfg, &pairs_for(&cfg, 2)).unwrap();
        std::thread::sleep(Duration::from_millis(20)); // let one batch dispatch
        handle.cancel().unwrap();
        assert!(matches!(
            handle.wait_timeout(Duration::from_secs(1)),
            Err(DqError::Cancelled(_))
        ));
        std::thread::sleep(Duration::from_millis(100)); // in-flight dispatch fails
        assert_eq!(m.stats().requeues, 0, "cancelled work must not be requeued");
        assert_eq!(m.queue_len(), 0);
        m.shutdown();
    }

    #[test]
    fn try_poll_counts_are_monotonic() {
        let m = Manager::new(ManagerConfig { max_batch: 2, ..Default::default() });
        m.register(
            WorkerProfile::new(5),
            Arc::new(SlowChannel { delay: Duration::from_millis(5) }),
        );
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 10);
        let session = m.session();
        let handle = session.submit(cfg, &pairs).unwrap();
        let mut last = 0usize;
        loop {
            let st = handle.try_poll().unwrap();
            assert!(st.completed >= last, "completion went backwards: {} < {last}", st.completed);
            assert_eq!(st.total, 10);
            assert_eq!(
                st.partial_fids.iter().filter(|f| f.is_some()).count(),
                st.completed,
                "partial_fids must agree with the completion count"
            );
            last = st.completed;
            if !st.pending {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(last, 10);
        let fids = handle.wait().unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        m.shutdown();
    }

    #[test]
    fn pack_batch_is_order_preserving_across_configs() {
        let cfg_a = QuClassiConfig::new(5, 1).unwrap();
        let cfg_b = QuClassiConfig::new(7, 1).unwrap();
        let mk = |id: u64, config: QuClassiConfig| CircuitJob {
            id,
            client: 1,
            bank: 1,
            index: id as usize,
            config,
            thetas: vec![0.0; config.n_params()],
            data: vec![0.0; config.n_features()],
        };
        let mut q: VecDeque<CircuitJob> = [
            mk(1, cfg_a),
            mk(2, cfg_b),
            mk(3, cfg_a),
            mk(4, cfg_b),
            mk(5, cfg_a),
        ]
        .into_iter()
        .collect();
        let jobs = Manager::pack_batch(&mut q, cfg_a, 2);
        assert_eq!(jobs.iter().map(|j| j.id).collect::<Vec<_>>(), vec![1, 3]);
        // the remainder keeps its relative order
        assert_eq!(q.iter().map(|j| j.id).collect::<Vec<_>>(), vec![2, 4, 5]);
    }
}
