//! The co-Manager service: queueing, Algorithm-2 assignment, dispatch,
//! result routing, liveness, and multi-client bookkeeping.
//!
//! Transport-agnostic: workers are reached through the [`WorkerChannel`]
//! trait (TCP RPC in distributed mode, direct calls in `--in-proc` mode);
//! clients interact through [`Manager`] methods (wrapped by the RPC
//! server in `cluster::tcp`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::bankstore::BankStore;
use super::job::{CircuitJob, JobId};
use super::registry::{Registry, WorkerId};
use super::scheduler;
use crate::circuit::QuClassiConfig;
use crate::model::exec::CircuitPair;
use crate::util::{Clock, SystemClock};

/// How the manager reaches a worker's executor.
pub trait WorkerChannel: Send + Sync {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, String>;
}

/// Manager tuning knobs.
#[derive(Debug, Clone)]
pub struct ManagerConfig {
    /// Heartbeat period in seconds (paper: 5 s; configurable).
    pub heartbeat_period: f64,
    /// Max circuits packed into one dispatch to a worker (the artifact
    /// batch is 32; 1 reproduces the paper's per-circuit assignment).
    pub max_batch: usize,
    /// Circuits dispatched per worker thread: a worker that registered
    /// `T` execution threads receives batches of up to
    /// `min(max_batch, T * batch_per_thread)` circuits, so the dispatch
    /// size tracks the worker's real parallelism (DESIGN.md §11).
    pub batch_per_thread: usize,
    /// Pending-queue backpressure limit (submits block above this).
    pub max_queue: usize,
    /// Bank wait timeout.
    pub wait_timeout: Duration,
    /// Noise-aware selection weight (extension §10): `Some(alpha)` ranks
    /// candidates by `alpha * noise + (1-alpha) * CRU`; `None` is the
    /// paper's CRU-only rule.
    pub noise_aware_alpha: Option<f64>,
}

impl Default for ManagerConfig {
    fn default() -> Self {
        ManagerConfig {
            heartbeat_period: 5.0,
            max_batch: 32,
            batch_per_thread: 32,
            max_queue: 100_000,
            wait_timeout: Duration::from_secs(600),
            noise_aware_alpha: None,
        }
    }
}

/// Aggregate counters.
#[derive(Debug, Clone, Default)]
pub struct ManagerStats {
    pub submitted: u64,
    pub completed: u64,
    pub dispatches: u64,
    pub requeues: u64,
    pub evictions: u64,
}

struct Inner {
    cfg: ManagerConfig,
    clock: Arc<dyn Clock>,
    registry: Mutex<Registry>,
    queue: Mutex<VecDeque<CircuitJob>>,
    /// Signaled on: new work, capacity freed, shutdown.
    work_cv: Condvar,
    /// Signaled when queue length drops (backpressure release).
    space_cv: Condvar,
    banks: BankStore,
    channels: Mutex<HashMap<WorkerId, Arc<dyn WorkerChannel>>>,
    in_flight: Mutex<HashMap<JobId, CircuitJob>>,
    /// Dispatch batches keyed by their qubit-reservation id (the head
    /// job), for eviction-time re-queueing of whole batches.
    batches: Mutex<HashMap<JobId, Vec<JobId>>>,
    stats: Mutex<ManagerStats>,
    next_bank: AtomicU64,
    next_job: AtomicU64,
    next_client: AtomicU64,
    stop: AtomicBool,
}

/// The co-Manager. Cheap to clone (shared state).
#[derive(Clone)]
pub struct Manager {
    inner: Arc<Inner>,
}

impl Manager {
    /// Start a co-Manager on the system clock.
    pub fn new(cfg: ManagerConfig) -> Manager {
        Self::with_clock(cfg, Arc::new(SystemClock::new()))
    }

    /// Start a co-Manager on an explicit clock (virtual time in tests).
    pub fn with_clock(cfg: ManagerConfig, clock: Arc<dyn Clock>) -> Manager {
        let m = Manager {
            inner: Arc::new(Inner {
                cfg,
                clock,
                registry: Mutex::new(Registry::new(5.0)),
                queue: Mutex::new(VecDeque::new()),
                work_cv: Condvar::new(),
                space_cv: Condvar::new(),
                banks: BankStore::new(),
                channels: Mutex::new(HashMap::new()),
                in_flight: Mutex::new(HashMap::new()),
                batches: Mutex::new(HashMap::new()),
                stats: Mutex::new(ManagerStats::default()),
                next_bank: AtomicU64::new(1),
                next_job: AtomicU64::new(1),
                next_client: AtomicU64::new(1),
                stop: AtomicBool::new(false),
            }),
        };
        {
            let mut reg = m.inner.registry.lock().unwrap();
            reg.heartbeat_period = m.inner.cfg.heartbeat_period;
        }
        // Scheduler loop.
        let m2 = m.clone();
        std::thread::Builder::new()
            .name("co-manager".into())
            .spawn(move || m2.scheduler_loop())
            .expect("spawn co-manager");
        m
    }

    // ------------------------------------------------------------------
    // worker-facing API
    // ------------------------------------------------------------------

    /// Quantum Worker Registration (Algorithm 2 lines 2-6).
    pub fn register_worker(
        &self,
        max_qubits: usize,
        cru: f64,
        channel: Arc<dyn WorkerChannel>,
    ) -> WorkerId {
        self.register_worker_profile(max_qubits, cru, 0.0, channel)
    }

    /// Registration with a reported noise estimate (extension §10).
    pub fn register_worker_profile(
        &self,
        max_qubits: usize,
        cru: f64,
        noise: f64,
        channel: Arc<dyn WorkerChannel>,
    ) -> WorkerId {
        self.register_worker_full(max_qubits, cru, noise, 1, channel)
    }

    /// Full registration: noise estimate plus the worker's execution
    /// thread budget, which sizes dispatch batches (DESIGN.md §11).
    pub fn register_worker_full(
        &self,
        max_qubits: usize,
        cru: f64,
        noise: f64,
        threads: usize,
        channel: Arc<dyn WorkerChannel>,
    ) -> WorkerId {
        let now = self.inner.clock.now();
        let id = self
            .inner
            .registry
            .lock()
            .unwrap()
            .register_full(max_qubits, cru, noise, threads, now);
        self.inner.channels.lock().unwrap().insert(id, channel);
        self.inner.work_cv.notify_all();
        id
    }

    /// Periodic heartbeat (Algorithm 2 lines 7-11): liveness + CRU. The
    /// manager's own reserve/release bookkeeping remains authoritative
    /// for occupied qubits (worker self-reports race with in-pipe RPCs).
    pub fn heartbeat(&self, worker: WorkerId, cru: f64) -> Result<(), String> {
        let now = self.inner.clock.now();
        self.inner.registry.lock().unwrap().heartbeat(worker, cru, now)
    }

    // ------------------------------------------------------------------
    // client-facing API
    // ------------------------------------------------------------------

    /// Allocate a client id (multi-tenant session).
    pub fn new_client(&self) -> u64 {
        self.inner.next_client.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit a bank of circuits; returns the bank id immediately.
    /// Blocks when the pending queue is above the backpressure limit.
    pub fn submit_bank(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, String> {
        if pairs.is_empty() {
            return Err("empty bank".to_string());
        }
        for (t, d) in pairs {
            if t.len() != config.n_params() || d.len() != config.n_features() {
                return Err("bank arity mismatch".to_string());
            }
        }
        let bank = self.inner.next_bank.fetch_add(1, Ordering::Relaxed);
        self.inner.banks.open(bank, pairs.len());

        // Backpressure: wait for queue space.
        let mut q = self.inner.queue.lock().unwrap();
        while q.len() + pairs.len() > self.inner.cfg.max_queue {
            if self.inner.stop.load(Ordering::Relaxed) {
                return Err("manager stopped".to_string());
            }
            let (guard, _) = self
                .inner
                .space_cv
                .wait_timeout(q, Duration::from_millis(100))
                .unwrap();
            q = guard;
        }
        for (index, (thetas, data)) in pairs.iter().enumerate() {
            let id = self.inner.next_job.fetch_add(1, Ordering::Relaxed);
            q.push_back(CircuitJob {
                id,
                client,
                bank,
                index,
                config,
                thetas: thetas.clone(),
                data: data.clone(),
            });
        }
        self.inner.stats.lock().unwrap().submitted += pairs.len() as u64;
        drop(q);
        self.inner.work_cv.notify_all();
        Ok(bank)
    }

    /// Block until a bank completes.
    pub fn wait_bank(&self, bank: u64) -> Result<Vec<f32>, String> {
        self.inner.banks.wait(bank, self.inner.cfg.wait_timeout)
    }

    /// Convenience: submit + wait.
    pub fn execute_bank(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, String> {
        let bank = self.submit_bank(client, config, pairs)?;
        self.wait_bank(bank)
    }

    /// Snapshot of the aggregate counters.
    pub fn stats(&self) -> ManagerStats {
        self.inner.stats.lock().unwrap().clone()
    }

    /// Number of registered (live) workers.
    pub fn worker_count(&self) -> usize {
        self.inner.registry.lock().unwrap().len()
    }

    /// Circuits currently pending assignment.
    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    /// Stop the scheduler loop and wake all waiters.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.work_cv.notify_all();
        self.inner.space_cv.notify_all();
    }

    // ------------------------------------------------------------------
    // scheduler loop (Algorithm 2 line 14-20 + dispatch)
    // ------------------------------------------------------------------

    fn scheduler_loop(&self) {
        while !self.inner.stop.load(Ordering::Relaxed) {
            // Liveness pass: evict stale workers, re-queue their circuits.
            self.evict_and_requeue();

            // Take the next schedulable batch.
            let batch = self.next_assignment();
            match batch {
                Some((worker, config, jobs)) => self.dispatch(worker, config, jobs),
                None => {
                    // Nothing schedulable: wait for work/capacity.
                    let q = self.inner.queue.lock().unwrap();
                    let _ = self
                        .inner
                        .work_cv
                        .wait_timeout(q, Duration::from_millis(20))
                        .unwrap();
                }
            }
        }
    }

    fn evict_and_requeue(&self) {
        let now = self.inner.clock.now();
        let evicted = self.inner.registry.lock().unwrap().evict_stale(now);
        if evicted.is_empty() {
            return;
        }
        let mut in_flight = self.inner.in_flight.lock().unwrap();
        let mut q = self.inner.queue.lock().unwrap();
        let mut stats = self.inner.stats.lock().unwrap();
        let mut batches = self.inner.batches.lock().unwrap();
        for (wid, orphan_keys) in evicted {
            stats.evictions += 1;
            self.inner.channels.lock().unwrap().remove(&wid);
            for key in orphan_keys {
                // each orphaned reservation is a whole dispatch batch
                let members = batches.remove(&key).unwrap_or_else(|| vec![key]);
                for job_id in members {
                    if let Some(job) = in_flight.remove(&job_id) {
                        stats.requeues += 1;
                        q.push_front(job);
                    }
                }
            }
        }
        drop(batches);
        drop(q);
        self.inner.work_cv.notify_all();
    }

    /// Pick the next circuit and worker per Algorithm 2; greedily extend
    /// the assignment with same-config circuits into one dispatch batch
    /// (`max_batch = 1` reproduces the paper's per-circuit behavior).
    ///
    /// Capacity semantics: a batch executes as ONE unit on the worker
    /// (one PJRT program / one sequential backend job), so it reserves
    /// its `demand` qubits once — concurrent *batches* on a big worker
    /// are what multi-tenant packing schedules.
    #[allow(clippy::type_complexity)]
    fn next_assignment(&self) -> Option<(WorkerId, QuClassiConfig, Vec<CircuitJob>)> {
        let mut q = self.inner.queue.lock().unwrap();
        if q.is_empty() {
            return None;
        }
        let mut reg = self.inner.registry.lock().unwrap();

        // Head-of-line circuit picks the worker (paper semantics)...
        let head = q.front().unwrap();
        let demand = head.demand();
        // An empty pool is not a failure: workers may still join
        // (dynamic registration); park the queue until one does.
        if reg.is_empty() {
            return None;
        }
        if !scheduler::can_ever_fit(&reg, demand) {
            // Unschedulable on the current pool: fail its whole bank.
            let job = q.pop_front().unwrap();
            drop(reg);
            drop(q);
            self.inner.banks.fail(
                job.bank,
                format!("circuit needs {demand} qubits; no worker that large"),
            );
            self.inner.space_cv.notify_all();
            return self.next_assignment_retry();
        }
        let worker = match self.inner.cfg.noise_aware_alpha {
            Some(alpha) => scheduler::select_noise_aware(&reg, demand, alpha)?,
            None => scheduler::select(&reg, demand)?,
        };
        let config = head.config;

        // ...then pack same-config circuits into the batch, sized by the
        // worker's registered thread budget so one dispatch saturates its
        // backend pool without starving co-tenants (DESIGN.md §11).
        let worker_threads = reg.get(worker).map(|w| w.threads).unwrap_or(1);
        let batch_limit = self
            .inner
            .cfg
            .max_batch
            .min(worker_threads.saturating_mul(self.inner.cfg.batch_per_thread))
            .max(1);
        let mut jobs = Vec::new();
        let mut scanned = 0;
        while scanned < q.len() && jobs.len() < batch_limit {
            if q[scanned].config == config {
                jobs.push(q.remove(scanned).unwrap());
            } else {
                scanned += 1;
            }
        }
        debug_assert!(!jobs.is_empty());
        // One reservation for the whole batch, keyed by the head job.
        let key = jobs[0].id;
        reg.reserve(worker, key, demand).expect("capacity checked");
        let mut in_flight = self.inner.in_flight.lock().unwrap();
        for j in &jobs {
            in_flight.insert(j.id, j.clone());
        }
        drop(in_flight);
        self.inner
            .batches
            .lock()
            .unwrap()
            .insert(key, jobs.iter().map(|j| j.id).collect());
        drop(reg);
        drop(q);
        self.inner.space_cv.notify_all();
        Some((worker, config, jobs))
    }

    fn next_assignment_retry(&self) -> Option<(WorkerId, QuClassiConfig, Vec<CircuitJob>)> {
        // Bounded retry after failing a bank, to avoid recursion depth.
        None
    }

    /// Send one batch to a worker on a dispatch thread; completion updates
    /// the registry, bank store, and wakes the scheduler.
    fn dispatch(&self, worker: WorkerId, config: QuClassiConfig, jobs: Vec<CircuitJob>) {
        let channel = match self.inner.channels.lock().unwrap().get(&worker) {
            Some(c) => c.clone(),
            None => {
                // Worker vanished between selection and dispatch: re-queue.
                self.requeue(worker, jobs);
                return;
            }
        };
        self.inner.stats.lock().unwrap().dispatches += 1;
        let m = self.clone();
        std::thread::Builder::new()
            .name(format!("dispatch-w{worker}"))
            .spawn(move || {
                let pairs: Vec<CircuitPair> =
                    jobs.iter().map(|j| (j.thetas.clone(), j.data.clone())).collect();
                match channel.execute(&config, &pairs) {
                    Ok(fids) => {
                        // Order matters: bump the completion counter before
                        // banks.complete() can wake a waiting client, so a
                        // stats read right after wait_bank() is consistent.
                        m.inner.stats.lock().unwrap().completed += jobs.len() as u64;
                        let key = jobs[0].id;
                        let mut reg = m.inner.registry.lock().unwrap();
                        let mut in_flight = m.inner.in_flight.lock().unwrap();
                        reg.release(worker, key);
                        m.inner.batches.lock().unwrap().remove(&key);
                        for (job, fid) in jobs.iter().zip(fids.iter()) {
                            in_flight.remove(&job.id);
                            m.inner.banks.complete(job.bank, job.index, *fid);
                        }
                        drop(in_flight);
                        drop(reg);
                        m.inner.work_cv.notify_all();
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "manager",
                            "dispatch to w{worker} failed ({e}); re-queueing {} circuits",
                            jobs.len()
                        );
                        m.requeue(worker, jobs);
                    }
                }
            })
            .expect("spawn dispatch");
    }

    fn requeue(&self, worker: WorkerId, jobs: Vec<CircuitJob>) {
        let mut reg = self.inner.registry.lock().unwrap();
        let mut in_flight = self.inner.in_flight.lock().unwrap();
        let mut q = self.inner.queue.lock().unwrap();
        let mut stats = self.inner.stats.lock().unwrap();
        if let Some(first) = jobs.first() {
            reg.release(worker, first.id);
            self.inner.batches.lock().unwrap().remove(&first.id);
        }
        for job in jobs {
            in_flight.remove(&job.id);
            stats.requeues += 1;
            q.push_front(job);
        }
        drop(q);
        drop(in_flight);
        drop(reg);
        self.inner.work_cv.notify_all();
    }
}

impl Drop for Inner {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::exec::QsimExecutor;
    use crate::model::CircuitExecutor;

    /// Worker channel backed by the local simulator.
    struct SimChannel;

    impl WorkerChannel for SimChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, String> {
            QsimExecutor.execute_bank(config, pairs)
        }
    }

    /// A channel that always fails (fault injection).
    struct FlakyChannel {
        fail_first: std::sync::atomic::AtomicU32,
    }

    impl WorkerChannel for FlakyChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, String> {
            if self.fail_first.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                if v > 0 {
                    Some(v - 1)
                } else {
                    None
                }
            }).is_ok()
            {
                return Err("injected fault".to_string());
            }
            QsimExecutor.execute_bank(config, pairs)
        }
    }

    fn pairs_for(config: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
        let mut rng = crate::util::Rng::new(9);
        (0..n)
            .map(|_| {
                (
                    (0..config.n_params()).map(|_| rng.f32()).collect(),
                    (0..config.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn single_worker_end_to_end() {
        let m = Manager::new(ManagerConfig::default());
        m.register_worker(5, 0.1, Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 10);
        let client = m.new_client();
        let fids = m.execute_bank(client, cfg, &pairs).unwrap();
        assert_eq!(fids.len(), 10);
        // results must match direct simulation exactly
        let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(fids, want);
        assert_eq!(m.stats().completed, 10);
        m.shutdown();
    }

    #[test]
    fn multiple_workers_share_load() {
        let m = Manager::new(ManagerConfig { max_batch: 2, ..Default::default() });
        for _ in 0..4 {
            m.register_worker(5, 0.0, Arc::new(SimChannel));
        }
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let pairs = pairs_for(&cfg, 30);
        let fids = m.execute_bank(m.new_client(), cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        assert!(m.stats().dispatches >= 15); // 30 circuits / batch 2
        m.shutdown();
    }

    #[test]
    fn batches_are_sized_by_worker_thread_budget() {
        // max_batch is large; the 2-thread worker's budget (2 * 3 = 6)
        // caps each dispatch instead.
        let m = Manager::new(ManagerConfig {
            max_batch: 100,
            batch_per_thread: 3,
            ..Default::default()
        });
        m.register_worker_full(5, 0.0, 0.0, 2, Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 30);
        let fids = m.execute_bank(m.new_client(), cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        assert!(m.stats().dispatches >= 5, "expected >= 30/6 dispatches");
        m.shutdown();
    }

    #[test]
    fn oversized_circuit_fails_cleanly() {
        let m = Manager::new(ManagerConfig::default());
        m.register_worker(5, 0.0, Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(7, 1).unwrap(); // needs 7 > 5
        let pairs = pairs_for(&cfg, 2);
        let err = m.execute_bank(m.new_client(), cfg, &pairs).unwrap_err();
        assert!(err.contains("no worker"), "{err}");
        m.shutdown();
    }

    #[test]
    fn dispatch_failure_requeues_and_recovers() {
        let m = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
        m.register_worker(
            5,
            0.0,
            Arc::new(FlakyChannel { fail_first: std::sync::atomic::AtomicU32::new(2) }),
        );
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 8);
        let fids = m.execute_bank(m.new_client(), cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        assert!(m.stats().requeues > 0);
        m.shutdown();
    }

    #[test]
    fn concurrent_clients_multi_tenant() {
        // A 20-qubit and a 5-qubit worker; two clients with different
        // configs submit concurrently (the paper's multi-tenant setting).
        let m = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
        m.register_worker(20, 0.2, Arc::new(SimChannel));
        m.register_worker(5, 0.1, Arc::new(SimChannel));
        let m1 = m.clone();
        let t1 = std::thread::spawn(move || {
            let cfg = QuClassiConfig::new(5, 1).unwrap();
            let pairs = pairs_for(&cfg, 20);
            let fids = m1.execute_bank(m1.new_client(), cfg, &pairs).unwrap();
            assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        });
        let m2 = m.clone();
        let t2 = std::thread::spawn(move || {
            let cfg = QuClassiConfig::new(7, 2).unwrap();
            let pairs = pairs_for(&cfg, 20);
            let fids = m2.execute_bank(m2.new_client(), cfg, &pairs).unwrap();
            assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        });
        t1.join().unwrap();
        t2.join().unwrap();
        assert_eq!(m.stats().completed, 40);
        m.shutdown();
    }

    #[test]
    fn no_worker_keeps_bank_pending_until_one_joins() {
        let m = Manager::new(ManagerConfig::default());
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 3);
        let bank = m.submit_bank(m.new_client(), cfg, &pairs).unwrap();
        // register a worker shortly after; dynamic join must drain it
        let m2 = m.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            m2.register_worker(5, 0.0, Arc::new(SimChannel));
        });
        let fids = m.wait_bank(bank).unwrap();
        assert_eq!(fids.len(), 3);
        m.shutdown();
    }

    #[test]
    fn empty_bank_rejected() {
        let m = Manager::new(ManagerConfig::default());
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        assert!(m.submit_bank(1, cfg, &[]).is_err());
        m.shutdown();
    }
}
