//! The co-Manager service: tenant-fair queueing, Algorithm-2 assignment,
//! event-driven dispatch through per-worker outboxes, result routing,
//! liveness, and multi-client bookkeeping.
//!
//! Transport-agnostic: workers are reached through the [`WorkerChannel`]
//! trait (TCP RPC in distributed mode, direct calls in `--in-proc` mode);
//! clients interact through typed [`super::session::ClientSession`]
//! handles obtained from [`Manager::session`] (wrapped by the RPC server
//! in `cluster::tcp` for remote clients).
//!
//! Threading model (DESIGN.md §13): one *assigner* thread runs the
//! Algorithm-2 loop and parks on an event-sequence condvar — submits,
//! completions, heartbeats, and registrations bump the sequence and wake
//! it, so a schedulable circuit is dispatched in microseconds instead of
//! "up to the next 20 ms tick". One *liveness* thread owns the periodic
//! eviction pass (the only place the old tick survives). Each registered
//! worker owns an outbox dispatcher thread (`coordinator/outbox.rs`)
//! draining its private batch queue, so a slow worker never delays
//! dispatch to a fast one.
//!
//! Lock order (outermost first): `queue` → `registry` → `in_flight` →
//! `batches` → `stats`. The `outboxes` directory is taken either alone
//! or directly inside `registry`; an outbox's internal queue lock sits
//! between `outboxes` and `in_flight` (the steal path holds `registry`
//! → `outboxes` → one outbox queue → `stats`; DESIGN.md §14); the
//! `events` counter is a leaf — taken momentarily with nothing else
//! held. The `journal` mutex is the innermost leaf of all (after
//! `stats`): appends on the hot path take it alone, and compaction
//! takes it last under `queue` → `in_flight` so the snapshot is a
//! consistent cut (DESIGN.md §16).

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::admission::AdmissionQueue;
use super::bankstore::{BankStatus, BankStore};
use super::job::{CircuitJob, JobId};
use super::journal::{
    payload_digest, CircuitState, Journal, JournalConfig, Record, RecoveredState, SnapBank,
    Snapshot,
};
use super::outbox::{Batch, Outbox, OutboxDirectory};
use super::registry::{Registry, WorkerId, WorkerProfile, WorkerState};
use super::scheduler;
use super::session::ClientSession;
use crate::circuit::QuClassiConfig;
use crate::error::DqError;
use crate::model::exec::CircuitPair;
use crate::util::stats::WaitHistogram;
use crate::util::{Clock, SystemClock};

/// How the manager reaches a worker's executor.
pub trait WorkerChannel: Send + Sync {
    fn execute(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError>;

    /// Does this channel complete asynchronously? `true` lets an outbox
    /// dispatcher enqueue-and-notify through
    /// [`WorkerChannel::execute_async`] instead of parking a transient
    /// execution thread per in-flight batch (the mux plane).
    fn is_async(&self) -> bool {
        false
    }

    /// Asynchronous execute: `done` is invoked exactly once with the
    /// outcome, possibly on a transport thread. The default adapts the
    /// blocking [`WorkerChannel::execute`] inline, so synchronous
    /// channels implement nothing — callers must consult
    /// [`WorkerChannel::is_async`] before relying on a prompt return.
    fn execute_async(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
        done: Box<dyn FnOnce(Result<Vec<f32>, DqError>) + Send + 'static>,
    ) {
        done(self.execute(config, pairs));
    }
}

/// Manager tuning knobs.
#[derive(Debug, Clone)]
pub struct ManagerConfig {
    /// Heartbeat period in seconds (paper: 5 s; configurable).
    pub heartbeat_period: f64,
    /// Max circuits packed into one dispatch to a worker (the artifact
    /// batch is 32; 1 reproduces the paper's per-circuit assignment).
    pub max_batch: usize,
    /// Circuits dispatched per worker thread: a worker that registered
    /// `T` execution threads receives batches of up to
    /// `min(max_batch, T * batch_per_thread)` circuits, so the dispatch
    /// size tracks the worker's real parallelism (DESIGN.md §11).
    pub batch_per_thread: usize,
    /// Pending-queue backpressure limit (submits block above this).
    pub max_queue: usize,
    /// Bank wait timeout.
    pub wait_timeout: Duration,
    /// Noise-aware selection weight (extension §10): `Some(alpha)` ranks
    /// candidates by `alpha * noise + (1-alpha) * CRU`; `None` is the
    /// paper's CRU-only rule.
    pub noise_aware_alpha: Option<f64>,
    /// Liveness/eviction pass period. This is the *only* timer left in
    /// the manager: dispatch is event-driven, the tick exists solely to
    /// notice workers whose heartbeats stopped (DESIGN.md §13).
    pub eviction_tick: Duration,
    /// Work stealing between outboxes (DESIGN.md §14): an idle worker's
    /// dispatcher may take a compatible batch still *queued* (not yet on
    /// the wire) in a sibling's outbox, moving its qubit reservation in
    /// the same registry-lock hold. `false` pins every batch to the
    /// worker it was assigned to — useful when selection policy (e.g.
    /// noise-aware placement) must never be bypassed by load balancing,
    /// and for isolating policies under test.
    pub steal: bool,
    /// Bounded per-tenant stats retention: quiescent tenants (submitted
    /// == completed) outside the top-`max_tenant_stats` by submitted are
    /// folded into [`ManagerStats::retired`]. The prune pass engages
    /// with hysteresis at 1.5x this value (so the map is hard-bounded by
    /// `cap + cap/2` plus any active tenants). `0` disables pruning.
    pub max_tenant_stats: usize,
    /// Durable write-ahead bank journal (DESIGN.md §16): `Some(cfg)`
    /// logs every bank lifecycle transition to `cfg.path` so
    /// [`Manager::recover`] can replay the manager's durable state after
    /// a crash; `None` (the default) keeps all state in memory.
    pub journal: Option<JournalConfig>,
}

impl Default for ManagerConfig {
    fn default() -> Self {
        ManagerConfig {
            heartbeat_period: 5.0,
            max_batch: 32,
            batch_per_thread: 32,
            max_queue: 100_000,
            wait_timeout: Duration::from_secs(600),
            noise_aware_alpha: None,
            eviction_tick: Duration::from_millis(20),
            steal: true,
            max_tenant_stats: 1024,
            journal: None,
        }
    }
}

/// What [`Manager::recover`] reconstructed from the journal.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveryReport {
    /// Journal records replayed.
    pub records: u64,
    /// Bytes truncated off the journal tail (torn/corrupt records).
    pub truncated_bytes: u64,
    /// Banks restored into the store (including failed ones).
    pub banks_restored: u64,
    /// Restored banks that came back failed (in-flight work lost to the
    /// crash fails with [`DqError::WorkerLost`]; clients resubmit).
    pub banks_failed: u64,
    /// Circuits re-admitted to the pending queue (never dispatched
    /// before the crash, so re-running them cannot double-execute).
    pub circuits_readmitted: u64,
    /// Cancelled-bank tombstone ids restored.
    pub cancelled_ids: u64,
}

/// Per-tenant counters (multi-tenant observability: who is submitting,
/// how fast their circuits dispatch, and how long they queue).
#[derive(Debug, Clone, Default)]
pub struct TenantStats {
    /// Circuits this tenant submitted.
    pub submitted: u64,
    /// Circuits handed to a worker channel on this tenant's behalf
    /// (counted at channel hand-off, so a batch re-dispatched after an
    /// eviction counts each attempt).
    pub dispatched: u64,
    /// Circuits completed for this tenant.
    pub completed: u64,
    /// Circuits that will never complete: drained by a cancel, failed
    /// as unschedulable, or abandoned after a protocol violation.
    /// Together with `completed` this accounts for every submitted
    /// circuit's final fate, which is what lets retention pruning
    /// recognize cancel-heavy churn tenants as quiescent.
    pub lost: u64,
    /// Circuits of this tenant moved between workers by a steal (the
    /// counters land on the batch's owner, not the thief).
    pub stolen: u64,
    /// Total seconds this tenant's circuits spent queued before reaching
    /// a worker channel (mean wait = `wait_total_s / dispatched`);
    /// includes outbox residency and survives steals.
    pub wait_total_s: f64,
    /// Longest single queue wait observed, in seconds.
    pub wait_max_s: f64,
    /// Fixed 8-bucket log-scale histogram of the same queue waits, so
    /// the manager answers per-tenant p50/p90 directly (serialized over
    /// the TCP `stats` op).
    pub wait_hist: WaitHistogram,
}

impl TenantStats {
    /// Fold another tenant's counters into this one (retention pruning:
    /// [`ManagerStats::retired`]).
    pub fn merge(&mut self, other: &TenantStats) {
        self.submitted += other.submitted;
        self.dispatched += other.dispatched;
        self.completed += other.completed;
        self.lost += other.lost;
        self.stolen += other.stolen;
        self.wait_total_s += other.wait_total_s;
        if other.wait_max_s > self.wait_max_s {
            self.wait_max_s = other.wait_max_s;
        }
        self.wait_hist.merge(&other.wait_hist);
    }
}

/// Aggregate counters.
#[derive(Debug, Clone, Default)]
pub struct ManagerStats {
    pub submitted: u64,
    pub completed: u64,
    pub dispatches: u64,
    pub requeues: u64,
    pub evictions: u64,
    /// Banks cancelled by clients.
    pub cancelled: u64,
    /// Batches moved from a backed-up worker's outbox to an idle sibling
    /// (work stealing, DESIGN.md §14).
    pub steals: u64,
    /// Tenants folded into [`ManagerStats::retired`] by bounded
    /// retention (`ManagerConfig::max_tenant_stats`).
    pub pruned_tenants: u64,
    /// Aggregate of all pruned tenants' counters — nothing is lost when
    /// a quiescent tenant's entry is retired, only de-individualized.
    pub retired: TenantStats,
    /// Per-tenant dispatch and queue-wait counters, keyed by client id.
    /// Bounded: above `ManagerConfig::max_tenant_stats` entries,
    /// quiescent tenants outside the top-N by submitted are merged into
    /// [`ManagerStats::retired`], so client-churn-heavy deployments
    /// cannot grow this map (or the TCP `stats` payload) without bound.
    pub per_tenant: BTreeMap<u64, TenantStats>,
}

impl ManagerStats {
    /// Bounded per-tenant retention (see [`ManagerStats::per_tenant`]).
    /// Tenants with work still queued or in flight (submitted >
    /// completed) are never pruned mid-flight; a pruned tenant that
    /// submits again simply starts a fresh entry (its history stays in
    /// `retired`).
    ///
    /// Hysteresis: the pass engages only once the map exceeds 1.5x the
    /// cap and then prunes back down toward `cap`, so the O(n log n)
    /// ranking runs once per ~cap/2 tenant arrivals — never on every
    /// stats update while the map hovers at the boundary (this runs
    /// under the stats lock on the dispatch hot path).
    fn prune_tenants(&mut self, cap: usize) {
        if cap == 0 || self.per_tenant.len() <= cap + cap / 2 {
            return;
        }
        let mut ranked: Vec<(u64, u64)> = self
            .per_tenant
            .iter()
            .map(|(client, t)| (t.submitted, *client))
            .collect();
        ranked.sort_unstable_by(|a, b| b.cmp(a));
        let keep: std::collections::HashSet<u64> =
            ranked.iter().take(cap).map(|&(_, client)| client).collect();
        let victims: Vec<u64> = self
            .per_tenant
            .iter()
            .filter(|(client, t)| {
                // Quiescent: every submitted circuit reached a final
                // fate — completed, or lost to cancel/unschedulable/
                // protocol failure — so no counter can move again.
                !keep.contains(*client) && t.completed + t.lost >= t.submitted
            })
            .map(|(client, _)| *client)
            .collect();
        for client in victims {
            if let Some(t) = self.per_tenant.remove(&client) {
                self.retired.merge(&t);
                self.pruned_tenants += 1;
            }
        }
    }
}

struct Inner {
    cfg: ManagerConfig,
    clock: Arc<dyn Clock>,
    registry: Mutex<Registry>,
    /// Tenant-fair pending queue (per-client sub-queues, WRR drain).
    queue: Mutex<AdmissionQueue>,
    /// Scheduling-event sequence number; every submit, completion,
    /// heartbeat, registration, requeue, and shutdown bumps it under its
    /// own lock and notifies `work_cv`, so the assigner never misses a
    /// wakeup between scan and park.
    events: Mutex<u64>,
    /// Signaled on every event-sequence bump (assigner wakeup).
    work_cv: Condvar,
    /// Signaled when queue length drops (backpressure release); paired
    /// with the `queue` mutex.
    space_cv: Condvar,
    banks: BankStore,
    /// Directory of per-worker dispatch queues + dispatcher threads —
    /// also the structure a stealing dispatcher scans for victims.
    /// Inserted under the `registry` lock at registration (so a
    /// selectable worker always has an outbox); removed (and stopped) at
    /// eviction.
    outboxes: Mutex<OutboxDirectory>,
    in_flight: Mutex<HashMap<JobId, CircuitJob>>,
    /// Dispatch batches keyed by their qubit-reservation id (the head
    /// job), for eviction-time re-queueing of whole batches.
    batches: Mutex<HashMap<JobId, Vec<JobId>>>,
    stats: Mutex<ManagerStats>,
    /// Write-ahead bank journal (innermost lock; `None` = not durable).
    journal: Option<Mutex<Journal>>,
    next_bank: AtomicU64,
    next_job: AtomicU64,
    next_client: AtomicU64,
    /// Id striping `(offset, stride)` for sharded deployments
    /// (DESIGN.md §18): bank/client/worker ids allocate congruent to
    /// `offset` modulo `stride`, so `id % stride` routes any id back to
    /// the shard that owns it. `(0, 1)` — the default — is the
    /// unsharded identity.
    stripe: (u64, u64),
    stop: AtomicBool,
}

/// Lock the journal, recovering from mutex poisoning. A panic while a
/// journal op was mid-append leaves the file in whatever prefix state the
/// write reached — exactly what crash recovery's tail-truncation already
/// handles — so later appends and a clean `recover()` must keep working
/// instead of cascading `PoisonError` panics (same policy as
/// `PlanCache`).
fn journal_lock(j: &Mutex<Journal>) -> std::sync::MutexGuard<'_, Journal> {
    j.lock().unwrap_or_else(|e| e.into_inner())
}

/// The co-Manager. Cheap to clone (shared state).
#[derive(Clone)]
pub struct Manager {
    inner: Arc<Inner>,
}

/// Weak handle held by manager-owned threads (assigner, liveness, outbox
/// dispatchers). Upgraded once per loop iteration, so the threads pin
/// the manager's state only while actively working or parked within one
/// bounded window — dropping the last user-held [`Manager`] lets
/// [`Inner`] drop (which sets `stop`), the next upgrade fails, and every
/// background thread exits instead of leaking.
pub(crate) struct WeakManager {
    inner: std::sync::Weak<Inner>,
}

impl WeakManager {
    /// A strong handle for one loop iteration, or `None` once every
    /// user-held clone is gone.
    pub(crate) fn upgrade(&self) -> Option<Manager> {
        self.inner.upgrade().map(|inner| Manager { inner })
    }
}

/// Backstop for the assigner's park: events drive every wakeup on the
/// hot path, so this only bounds how long the assigner pins a manager
/// that was dropped without `shutdown()` before its next upgrade check.
const ASSIGNER_BACKSTOP: Duration = Duration::from_millis(100);

/// Sentinel worker id for batches executing on a *sibling shard's*
/// worker (cross-shard steal, DESIGN.md §18). No registry ever
/// allocates it, and [`Registry::release`] on an unknown worker is a
/// no-op, so routing a foreign outcome through [`Manager::finish_batch`]
/// under this id runs only the in-flight/batch/bank bookkeeping.
pub(crate) const FOREIGN_WORKER: WorkerId = u64::MAX;

/// Smallest id `>= min` congruent to `off` modulo `stride` (id striping
/// for sharded managers; `stride <= 1` is the unsharded identity).
fn first_in_stripe(min: u64, off: u64, stride: u64) -> u64 {
    if stride <= 1 {
        return min;
    }
    min + (off % stride + stride - min % stride) % stride
}

impl Manager {
    /// Start a co-Manager on the system clock.
    pub fn new(cfg: ManagerConfig) -> Manager {
        Self::with_clock(cfg, Arc::new(SystemClock::new()))
    }

    /// Start a co-Manager on an explicit clock (virtual time in tests).
    /// With [`ManagerConfig::journal`] set this starts a *fresh* journal
    /// (truncating any previous one); use [`Manager::recover`] to resume
    /// from existing records instead.
    pub fn with_clock(cfg: ManagerConfig, clock: Arc<dyn Clock>) -> Manager {
        Self::with_clock_striped(cfg, clock, (0, 1))
    }

    /// [`Manager::with_clock`] with id striping: shard `off` of `stride`
    /// allocates bank/client/worker ids congruent to `off` modulo
    /// `stride`, so sibling shards' id spaces never collide and
    /// `id % stride` is the shard-routing function
    /// ([`super::shard::ShardManager`]).
    pub(crate) fn with_clock_striped(
        cfg: ManagerConfig,
        clock: Arc<dyn Clock>,
        stripe: (u64, u64),
    ) -> Manager {
        let journal = cfg
            .journal
            .as_ref()
            .map(|jc| Mutex::new(Journal::create(jc).expect("create bank journal")));
        Manager::build(cfg, clock, journal, stripe)
    }

    /// Restart a co-Manager from its journal: replays the log at
    /// `cfg.journal` (required) into a consistent [`BankStore`] and
    /// admission queue — circuits never dispatched are re-admitted and
    /// will execute on the new incarnation's workers; banks with work
    /// in flight at the crash fail with [`DqError::WorkerLost`] (a
    /// dispatched circuit may have executed, so it is never re-run);
    /// completed-but-unconsumed banks keep their results for late
    /// waiters; cancelled ids stay tombstoned. Restored banks report
    /// `recovered: true` in their [`BankStatus`]. Torn tail records are
    /// truncated; a path holding something other than a journal is
    /// refused ([`DqError::Io`]).
    ///
    /// Worker registrations are deliberately NOT durable: workers
    /// re-register/re-heartbeat against the new incarnation (DESIGN.md
    /// §16), which is also what re-dispatches the re-admitted circuits.
    pub fn recover(cfg: ManagerConfig) -> Result<(Manager, RecoveryReport), DqError> {
        Self::recover_with_clock(cfg, Arc::new(SystemClock::new()))
    }

    /// [`Manager::recover`] on an explicit clock (virtual time in tests).
    pub fn recover_with_clock(
        cfg: ManagerConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<(Manager, RecoveryReport), DqError> {
        Self::recover_striped(cfg, clock, (0, 1))
    }

    /// [`Manager::recover_with_clock`] with id striping (see
    /// [`Manager::with_clock_striped`]): allocation resumes at the first
    /// id above everything the journal saw that also lands in this
    /// shard's stripe.
    pub(crate) fn recover_striped(
        cfg: ManagerConfig,
        clock: Arc<dyn Clock>,
        stripe: (u64, u64),
    ) -> Result<(Manager, RecoveryReport), DqError> {
        let Some(jc) = cfg.journal.clone() else {
            return Err(DqError::Protocol(
                "Manager::recover requires ManagerConfig::journal".to_string(),
            ));
        };
        let (journal, state) = Journal::recover(&jc)?;
        let m = Manager::build(cfg, clock, Some(Mutex::new(journal)), stripe);
        let report = m.restore(state);
        Ok((m, report))
    }

    fn build(
        cfg: ManagerConfig,
        clock: Arc<dyn Clock>,
        journal: Option<Mutex<Journal>>,
        stripe: (u64, u64),
    ) -> Manager {
        let stride = stripe.1.max(1);
        let off = stripe.0 % stride;
        let m = Manager {
            inner: Arc::new(Inner {
                cfg,
                clock,
                registry: Mutex::new(Registry::new(5.0)),
                queue: Mutex::new(AdmissionQueue::new()),
                events: Mutex::new(0),
                work_cv: Condvar::new(),
                space_cv: Condvar::new(),
                banks: BankStore::new(),
                outboxes: Mutex::new(OutboxDirectory::new()),
                in_flight: Mutex::new(HashMap::new()),
                batches: Mutex::new(HashMap::new()),
                stats: Mutex::new(ManagerStats::default()),
                journal,
                next_bank: AtomicU64::new(first_in_stripe(1, off, stride)),
                next_job: AtomicU64::new(1),
                next_client: AtomicU64::new(first_in_stripe(1, off, stride)),
                stripe: (off, stride),
                stop: AtomicBool::new(false),
            }),
        };
        {
            let mut reg = m.inner.registry.lock().unwrap();
            reg.heartbeat_period = m.inner.cfg.heartbeat_period;
            reg.set_stripe(off, stride);
        }
        // Assigner: the event-driven Algorithm-2 loop. Both threads hold
        // weak handles so an un-shutdown manager can still be dropped.
        let weak = m.downgrade();
        std::thread::Builder::new()
            .name("co-manager-assign".into())
            .spawn(move || Manager::assigner_thread(weak))
            .expect("spawn co-manager assigner");
        // Liveness: periodic eviction pass (the only remaining timer).
        let weak = m.downgrade();
        std::thread::Builder::new()
            .name("co-manager-live".into())
            .spawn(move || Manager::liveness_thread(weak))
            .expect("spawn co-manager liveness");
        m
    }

    /// Weak handle for a manager-owned thread (see [`WeakManager`]).
    pub(crate) fn downgrade(&self) -> WeakManager {
        WeakManager { inner: Arc::downgrade(&self.inner) }
    }

    /// Bump the scheduling-event sequence and wake the assigner. Callers
    /// must hold no other manager lock (`events` is a leaf).
    fn signal_event(&self) {
        let mut seq = self.inner.events.lock().unwrap();
        *seq = seq.wrapping_add(1);
        drop(seq);
        self.inner.work_cv.notify_all();
    }

    /// True once [`Manager::shutdown`] ran (outbox threads poll this).
    pub(crate) fn is_stopped(&self) -> bool {
        self.inner.stop.load(Ordering::Relaxed)
    }

    // ------------------------------------------------------------------
    // durable journal (DESIGN.md §16)
    // ------------------------------------------------------------------

    fn journaling(&self) -> bool {
        self.inner.journal.is_some()
    }

    /// Leader fsyncs issued by the journal's group committer so far
    /// (`None` without a journal). Under `SyncPolicy::Always` with
    /// concurrent submitters this sits well below the append count —
    /// the amortization gauge the coordinator-scale bench reports.
    pub fn journal_syncs(&self) -> Option<u64> {
        self.inner.journal.as_ref().map(|j| journal_lock(j).sync_count())
    }

    /// Best-effort journal append for paths that must not fail the
    /// operation they ride on (dispatch, completion, requeue): an I/O
    /// error degrades durability, not availability, and is logged.
    ///
    /// Two-phase under `SyncPolicy::Always`: the record is written under
    /// the journal mutex, but the fsync happens *after* the mutex drops
    /// — concurrent appenders coalesce onto one group commit instead of
    /// serializing their fsyncs (DESIGN.md §16).
    fn journal_append(&self, rec: Record) {
        if let Some(j) = &self.inner.journal {
            match journal_lock(j).append_async(&rec) {
                Ok(None) => {}
                Ok(Some(ticket)) => {
                    if let Err(e) = ticket.commit() {
                        crate::log_warn!("manager", "journal commit failed: {e}");
                    }
                }
                Err(e) => crate::log_warn!("manager", "journal append failed: {e}"),
            }
        }
    }

    /// Journal append for the submit path, where an append failure must
    /// reject the submission — accepting a bank the journal never saw
    /// would silently drop it at the next recovery. Same two-phase
    /// group-commit discipline as [`Manager::journal_append`].
    fn try_journal_append(&self, rec: Record) -> Result<(), DqError> {
        if let Some(j) = &self.inner.journal {
            let ticket = journal_lock(j).append_async(&rec)?;
            if let Some(t) = ticket {
                t.commit()?;
            }
        }
        Ok(())
    }

    /// A consuming wait removes the bank from the store on every
    /// non-timeout outcome (results delivered, failure delivered, or
    /// cancellation observed) — mirror that removal durably. Unknown
    /// banks no-op at replay, and a `Resolved` on a cancelled bank is
    /// harmless (the tombstone id set is what cancellation relies on).
    fn journal_wait_outcome(&self, bank: u64, res: &Result<Vec<f32>, DqError>) {
        if self.journaling() && !matches!(res, Err(DqError::Timeout(_))) {
            self.journal_append(Record::Resolved { bank });
        }
    }

    /// Replay a recovered journal state into the live structures (see
    /// [`Manager::recover`] for the disposition rules).
    fn restore(&self, state: RecoveredState) -> RecoveryReport {
        let mut report = RecoveryReport {
            records: state.records,
            truncated_bytes: state.truncated_bytes,
            cancelled_ids: state.cancelled.len() as u64,
            ..RecoveryReport::default()
        };
        // Ids never reuse across incarnations: allocation resumes above
        // everything the journal ever saw, re-aligned to this shard's
        // stripe (a journal written unsharded replays fine into shard
        // `off` of `stride` — only future allocations are striped).
        let (off, stride) = self.inner.stripe;
        self.inner
            .next_bank
            .store(first_in_stripe(state.max_bank + 1, off, stride), Ordering::Relaxed);
        self.inner
            .next_client
            .store(first_in_stripe(state.max_client + 1, off, stride), Ordering::Relaxed);
        self.inner.banks.restore_cancelled(state.cancelled.iter().copied());
        {
            // WRR policy resumes before any re-admitted work queues, so
            // the very first post-recovery service cycle is already fair.
            let mut q = self.inner.queue.lock().unwrap();
            for (&client, &weight) in &state.weights {
                q.set_weight(client, weight);
            }
        }
        for (bank, rb) in state.banks {
            if state.cancelled.contains(&bank) {
                continue;
            }
            let mut fids: Vec<Option<f32>> = Vec::with_capacity(rb.circuits.len());
            let mut pending: Vec<(usize, CircuitPair)> = Vec::new();
            let mut lost_in_flight = false;
            let mut gone = false;
            for (index, c) in rb.circuits.into_iter().enumerate() {
                match c {
                    CircuitState::Done(f) => fids.push(Some(f)),
                    CircuitState::Pending(p) => {
                        fids.push(None);
                        pending.push((index, p));
                    }
                    CircuitState::InFlight(_) => {
                        fids.push(None);
                        lost_in_flight = true;
                    }
                    CircuitState::Gone => {
                        fids.push(None);
                        gone = true;
                    }
                }
            }
            // Disposition: a replayed failure wins; otherwise any
            // circuit that reached a worker channel poisons the bank
            // (it may have executed — re-running it would double-count
            // a training contribution), and its pending siblings are
            // not re-admitted either since the waiter already fails.
            let failed = match rb.failed {
                Some(e) => Some(e),
                None if lost_in_flight => Some(DqError::WorkerLost(format!(
                    "bank {bank}: in-flight work lost in a manager crash; resubmit"
                ))),
                None if gone => {
                    Some(DqError::Protocol(format!("bank {bank}: journal gap")))
                }
                None => None,
            };
            let readmit = failed.is_none() && !pending.is_empty();
            if failed.is_some() {
                report.banks_failed += 1;
            }
            report.banks_restored += 1;
            self.inner.banks.restore(bank, fids, rb.client, rb.qubits, rb.layers, failed);
            if !readmit {
                continue;
            }
            let config = match QuClassiConfig::new(rb.qubits as usize, rb.layers as usize) {
                Ok(c) => c,
                Err(e) => {
                    let err = DqError::Protocol(format!("bank {bank}: bad replayed config: {e}"));
                    self.journal_append(Record::Failed { bank, error: err.clone() });
                    self.inner.banks.fail(bank, err);
                    report.banks_failed += 1;
                    continue;
                }
            };
            let jobs: Vec<CircuitJob> = pending
                .into_iter()
                .map(|(index, (thetas, data))| CircuitJob {
                    id: self.inner.next_job.fetch_add(1, Ordering::Relaxed),
                    client: rb.client,
                    bank,
                    index,
                    config,
                    thetas,
                    data,
                })
                .collect();
            report.circuits_readmitted += jobs.len() as u64;
            self.inner.queue.lock().unwrap().push_bank(rb.client, jobs);
        }
        // Re-admitted work is schedulable as soon as workers register.
        self.signal_event();
        report
    }

    /// Rewrite the journal as a single snapshot record (atomic tmp-file
    /// + rename), bounding its size under churn. Returns false (leaving
    /// the old log intact) when no journal is configured or the rewrite
    /// failed. Runs under `queue` → `in_flight` so the snapshot is a
    /// consistent cut: nothing moves between queue, flight, and store
    /// while it is taken.
    pub fn compact_journal(&self) -> bool {
        let Some(journal) = &self.inner.journal else {
            return false;
        };
        let q = self.inner.queue.lock().unwrap();
        let in_flight = self.inner.in_flight.lock().unwrap();
        let mut outstanding: HashMap<(u64, u32), (bool, CircuitPair)> = HashMap::new();
        for job in q.jobs() {
            outstanding.insert(
                (job.bank, job.index as u32),
                (false, (job.thetas.clone(), job.data.clone())),
            );
        }
        for job in in_flight.values() {
            outstanding.insert(
                (job.bank, job.index as u32),
                (true, (job.thetas.clone(), job.data.clone())),
            );
        }
        let mut banks = Vec::new();
        for snap in self.inner.banks.snapshot() {
            if snap.cancelled {
                // Resident tombstones carry no replayable work; the id
                // itself is preserved in the snapshot's cancelled set.
                continue;
            }
            let circuits = snap
                .fids
                .iter()
                .enumerate()
                .map(|(index, f)| match f {
                    Some(fid) => CircuitState::Done(*fid),
                    None => match outstanding.get(&(snap.bank, index as u32)) {
                        Some((true, p)) => CircuitState::InFlight(p.clone()),
                        Some((false, p)) => CircuitState::Pending(p.clone()),
                        None => CircuitState::Gone,
                    },
                })
                .collect();
            banks.push(SnapBank {
                bank: snap.bank,
                client: snap.client,
                qubits: snap.qubits,
                layers: snap.layers,
                recovered: snap.recovered,
                failed: snap.failed,
                circuits,
            });
        }
        let snap = Snapshot {
            next_bank: self.inner.next_bank.load(Ordering::Relaxed),
            next_client: self.inner.next_client.load(Ordering::Relaxed),
            cancelled: self.inner.banks.cancelled_ids(),
            banks,
            weights: q.weights(),
        };
        let res = journal_lock(journal).compact(snap);
        drop(in_flight);
        drop(q);
        match res {
            Ok(()) => true,
            Err(e) => {
                crate::log_warn!("manager", "journal compaction failed: {e}");
                false
            }
        }
    }

    /// Compact once the journal passed its size threshold (called from
    /// the liveness tick).
    fn maybe_compact_journal(&self) {
        let due = match &self.inner.journal {
            Some(j) => journal_lock(j).should_compact(),
            None => return,
        };
        if due {
            self.compact_journal();
        }
    }

    // ------------------------------------------------------------------
    // worker-facing API
    // ------------------------------------------------------------------

    /// Quantum Worker Registration (Algorithm 2 lines 2-6) from a typed
    /// [`WorkerProfile`] — the single registration entry point. The
    /// worker's outbox dispatcher starts here; registration is an
    /// assignment event (pending circuits dispatch immediately).
    pub fn register(&self, profile: WorkerProfile, channel: Arc<dyn WorkerChannel>) -> WorkerId {
        let now = self.inner.clock.now();
        {
            // The outbox is inserted under the registry lock so the
            // assigner can never select a worker whose outbox does not
            // exist yet (registry → outboxes nesting, DESIGN.md §13).
            // The worker's thread budget bounds how many batches its
            // outbox hands to the channel concurrently; surplus batches
            // stay queued where siblings can steal them (DESIGN.md §14).
            let mut reg = self.inner.registry.lock().unwrap();
            let id = reg.register_profile(&profile, now);
            let outbox = Outbox::spawn(id, channel, profile.threads.max(1), self.clone());
            self.inner.outboxes.lock().unwrap().insert(id, outbox);
            drop(reg);
            self.signal_event();
            id
        }
    }

    /// Periodic heartbeat (Algorithm 2 lines 7-11): liveness + CRU. The
    /// manager's own reserve/release bookkeeping remains authoritative
    /// for occupied qubits (worker self-reports race with in-pipe RPCs).
    /// An evicted or never-registered worker gets [`DqError::WorkerLost`]
    /// and should re-register. A fresh CRU sample can change Algorithm
    /// 2's ranking, so a successful heartbeat wakes the assigner.
    pub fn heartbeat(&self, worker: WorkerId, cru: f64) -> Result<(), DqError> {
        let now = self.inner.clock.now();
        self.inner.registry.lock().unwrap().heartbeat(worker, cru, now)?;
        self.signal_event();
        Ok(())
    }

    // ------------------------------------------------------------------
    // client-facing API
    // ------------------------------------------------------------------

    /// Open a typed client session (multi-tenant): the session owns its
    /// client id and hands out [`super::session::BankHandle`] futures.
    pub fn session(&self) -> ClientSession {
        let client = self.new_client();
        ClientSession::new(Arc::new(self.clone()), client)
    }

    /// Allocate a raw client id (prefer [`Manager::session`]).
    pub fn new_client(&self) -> u64 {
        self.inner.next_client.fetch_add(self.inner.stripe.1, Ordering::Relaxed)
    }

    /// Set a tenant's weighted-round-robin weight (batches per service
    /// cycle; default 1, clamped to >= 1). A weight-`w` tenant takes `w`
    /// consecutive dispatch batches per admission cycle — heavier tenants
    /// drain faster without ever starving lighter ones. Non-default
    /// weights persist until reset; setting a tenant back to 1 releases
    /// its weight entry (bounded state under client churn).
    ///
    /// Weights are durable: with a journal configured, the change is
    /// logged (WAL-before-effect, like every other transition) so a
    /// recovered manager resumes the same WRR shares instead of
    /// resetting every tenant to the default.
    pub fn set_tenant_weight(&self, client: u64, weight: u32) {
        self.journal_append(Record::TenantWeight { client, weight: weight.max(1) });
        self.inner.queue.lock().unwrap().set_weight(client, weight);
    }

    /// Submit a bank of circuits; returns the bank id immediately.
    /// Blocks when the pending queue is above the backpressure limit.
    /// (Primitive under [`ClientSession::submit`].)
    pub fn submit_bank(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError> {
        // Fail fast after shutdown: the assigner and outboxes are gone
        // and the pending-bank failure sweep has already run, so a bank
        // opened now could only hang until its wait timeout.
        if self.inner.stop.load(Ordering::Relaxed) {
            return Err(DqError::Cancelled("manager stopped".to_string()));
        }
        if pairs.is_empty() {
            return Err(DqError::Arity("empty bank".to_string()));
        }
        for (t, d) in pairs {
            if t.len() != config.n_params() || d.len() != config.n_features() {
                return Err(DqError::Arity(format!(
                    "bank arity mismatch: theta {} (want {}), data {} (want {})",
                    t.len(),
                    config.n_params(),
                    d.len(),
                    config.n_features()
                )));
            }
        }
        let bank = self.inner.next_bank.fetch_add(self.inner.stripe.1, Ordering::Relaxed);
        // WAL: the bank is durable before it is visible anywhere —
        // rejecting the submit on an append failure beats accepting a
        // bank the next recovery would silently drop.
        if self.journaling() {
            self.try_journal_append(Record::Submitted {
                bank,
                client,
                qubits: config.qubits as u32,
                layers: config.layers as u32,
                digest: payload_digest(pairs),
                pairs: pairs.to_vec(),
            })?;
        }
        self.inner.banks.open_for(
            bank,
            pairs.len(),
            client,
            config.qubits as u32,
            config.layers as u32,
        );

        // Backpressure: wait for queue space.
        let mut q = self.inner.queue.lock().unwrap();
        while q.len() + pairs.len() > self.inner.cfg.max_queue {
            if self.inner.stop.load(Ordering::Relaxed) {
                return Err(DqError::Cancelled("manager stopped".to_string()));
            }
            let (guard, _) = self
                .inner
                .space_cv
                .wait_timeout(q, Duration::from_millis(100))
                .unwrap();
            q = guard;
        }
        let jobs: Vec<CircuitJob> = pairs
            .iter()
            .enumerate()
            .map(|(index, (thetas, data))| CircuitJob {
                id: self.inner.next_job.fetch_add(1, Ordering::Relaxed),
                client,
                bank,
                index,
                config,
                thetas: thetas.clone(),
                data: data.clone(),
            })
            .collect();
        q.push_bank(client, jobs);
        {
            let mut stats = self.inner.stats.lock().unwrap();
            stats.submitted += pairs.len() as u64;
            stats.per_tenant.entry(client).or_default().submitted += pairs.len() as u64;
            stats.prune_tenants(self.inner.cfg.max_tenant_stats);
        }
        drop(q);
        self.signal_event();
        // Close the shutdown race: if stop landed after the entry check,
        // the pending-bank failure sweep may already have run without
        // seeing this bank — reap it here so the caller gets an error
        // now instead of a waiter hanging until its timeout.
        if self.inner.stop.load(Ordering::Relaxed) {
            self.cancel_bank(bank);
            return Err(DqError::Cancelled("manager stopped".to_string()));
        }
        Ok(bank)
    }

    /// Block until a bank completes (default timeout). This is the
    /// *consuming* wait path ([`super::session::BankHandle::wait`] and
    /// the `execute_bank` conveniences): a timeout here leaves the caller
    /// no way to retry, poll, or cancel, so the zombie bank is reaped
    /// (cancelled) before the [`DqError::Timeout`] is returned — its
    /// queued circuits drain and its state does not leak in a
    /// long-running multi-tenant manager.
    pub fn wait_bank(&self, bank: u64) -> Result<Vec<f32>, DqError> {
        let res = self.inner.banks.wait(bank, self.inner.cfg.wait_timeout);
        self.journal_wait_outcome(bank, &res);
        match res {
            Err(e @ DqError::Timeout(_)) => {
                self.cancel_bank(bank);
                Err(e)
            }
            other => other,
        }
    }

    /// Block until a bank completes, up to an explicit deadline. Unlike
    /// [`Manager::wait_bank`], a timeout leaves the bank resident: the
    /// caller holds a handle and can retry, poll, or escalate to
    /// `cancel` — abandoning it without cancelling leaks the bank.
    pub fn wait_bank_timeout(&self, bank: u64, timeout: Duration) -> Result<Vec<f32>, DqError> {
        let res = self.inner.banks.wait(bank, timeout);
        self.journal_wait_outcome(bank, &res);
        res
    }

    /// Non-blocking progress snapshot of a bank (None once waited out).
    pub fn bank_status(&self, bank: u64) -> Option<BankStatus> {
        self.inner.banks.status(bank)
    }

    /// Register a progress watcher on a bank: already-landed fidelities
    /// replay immediately, then every completion (and the terminal
    /// outcome) fires as it happens. `false` for a bank the store has
    /// never seen. Backs the binary plane's `subscribe_bank` push stream
    /// (DESIGN.md §19).
    pub fn watch_bank(&self, bank: u64, w: super::bankstore::BankWatcher) -> bool {
        self.inner.banks.watch(bank, w)
    }

    /// True when the bank was ever cancelled — outlives the tombstone, so
    /// status/poll paths can answer [`DqError::Cancelled`] (not "unknown
    /// bank") after the GC.
    pub fn bank_cancelled(&self, bank: u64) -> bool {
        self.inner.banks.is_cancelled(bank)
    }

    /// Cancel a bank: drains its queued circuits (releasing backpressure),
    /// marks in-flight results discard-on-arrival, and wakes any waiter
    /// with [`DqError::Cancelled`]. Idempotent; returns the number of
    /// queued circuits drained.
    ///
    /// The cancelled bank's tombstone lives only as long as it has
    /// results still in flight (discard-on-arrival needs it); once the
    /// last one resolves it is garbage-collected, so cancel-without-wait
    /// does not leak. [`super::session::BankHandle`] keeps reporting
    /// `Cancelled` after the GC.
    pub fn cancel_bank(&self, bank: u64) -> usize {
        // WAL-first: the tombstone is durable before any in-memory
        // effect, so a crash mid-cancel can only *under*-cancel (the
        // client retries), never resurrect a cancelled bank. Gated on
        // residency so garbage ids from remote clients don't grow the
        // log (mirroring BankStore::cancel's own no-op rule).
        if self.journaling()
            && !self.inner.banks.is_cancelled(bank)
            && self.inner.banks.status(bank).is_some()
        {
            self.journal_append(Record::Cancelled { bank });
        }
        let mut q = self.inner.queue.lock().unwrap();
        let (drained, owner) = q.drain_bank(bank);
        drop(q);
        {
            let mut stats = self.inner.stats.lock().unwrap();
            if self.inner.banks.cancel(bank) {
                stats.cancelled += 1;
            }
            // Drained circuits can never complete: credit the tenant's
            // `lost` counter so cancel-heavy churn stays prunable.
            if let Some(client) = owner {
                if drained > 0 {
                    stats.per_tenant.entry(client).or_default().lost += drained as u64;
                    stats.prune_tenants(self.inner.cfg.max_tenant_stats);
                }
            }
        }
        // GC immediately when nothing is in flight (the check and the
        // discard serialize against dispatch completion on `in_flight`).
        let in_flight = self.inner.in_flight.lock().unwrap();
        self.gc_cancelled_banks(&[bank], &in_flight);
        drop(in_flight);
        // Queued work disappeared: release blocked submitters; nothing new
        // became schedulable, so the assigner stays parked.
        self.inner.space_cv.notify_all();
        drained
    }

    /// Drop tombstones of cancelled banks that have no in-flight work
    /// left. Callers hold the `in_flight` lock, so the emptiness check
    /// and the discard are atomic w.r.t. result arrival.
    fn gc_cancelled_banks(&self, banks: &[u64], in_flight: &HashMap<JobId, CircuitJob>) {
        for &bank in banks {
            if self.inner.banks.is_cancelled(bank)
                && !in_flight.values().any(|j| j.bank == bank)
            {
                self.inner.banks.discard(bank);
            }
        }
    }

    /// Convenience: submit + wait.
    pub fn execute_bank(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        let bank = self.submit_bank(client, config, pairs)?;
        self.wait_bank(bank)
    }

    /// Snapshot of the aggregate counters.
    pub fn stats(&self) -> ManagerStats {
        self.inner.stats.lock().unwrap().clone()
    }

    /// Snapshot of every registered worker's state (occupancy audits:
    /// `occupied <= max_qubits` must hold at all times, including across
    /// reservation transfers — see `tests/steal_audit.rs`).
    pub fn worker_states(&self) -> Vec<WorkerState> {
        self.inner.registry.lock().unwrap().workers().cloned().collect()
    }

    /// Number of registered (live) workers.
    pub fn worker_count(&self) -> usize {
        self.inner.registry.lock().unwrap().len()
    }

    /// Circuits currently pending assignment (across all tenants).
    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    /// Total available (unreserved) qubits across the pool.
    pub fn available_qubits(&self) -> usize {
        self.inner.registry.lock().unwrap().total_available()
    }

    /// Stop the assigner, liveness, and outbox threads; wake all waiters.
    /// Banks still awaiting results are failed with
    /// [`DqError::Cancelled`]: batches stranded in stopped outboxes (or
    /// never assigned) can no longer complete, and a blocked
    /// [`Manager::wait_bank`] must not hang until its timeout on them.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.signal_event();
        self.inner.space_cv.notify_all();
        let outboxes = self.inner.outboxes.lock().unwrap().all();
        for ob in outboxes {
            ob.stop();
        }
        // Clean shutdown resolves every still-pending bank durably and
        // fsyncs before the in-memory failure sweep below: a recover()
        // after this re-admits nothing (idempotent restart). Banks that
        // completed but were never waited out are deliberately NOT
        // resolved — their results survive into the next incarnation.
        if self.journaling() {
            for bank in self.inner.banks.pending_banks() {
                self.journal_append(Record::Resolved { bank });
            }
            if let Some(j) = &self.inner.journal {
                if let Err(e) = journal_lock(j).flush() {
                    crate::log_warn!("manager", "journal flush at shutdown failed: {e}");
                }
            }
        }
        self.inner.banks.fail_pending(DqError::Cancelled("manager stopped".to_string()));
    }

    // ------------------------------------------------------------------
    // assigner loop (Algorithm 2 line 14-20 + dispatch)
    // ------------------------------------------------------------------

    /// Event-driven assignment: drain every currently-schedulable batch,
    /// then park until the event sequence moves. The sequence is read
    /// *after* the drain, so an event that lands between "queue looked
    /// empty" and "about to park" is never lost — the assigner re-scans
    /// instead of sleeping on stale state. The strong handle is
    /// re-acquired each iteration ([`WeakManager`]), so the thread exits
    /// once the manager is stopped or dropped.
    fn assigner_thread(weak: WeakManager) {
        let mut seen: u64 = 0;
        loop {
            let Some(m) = weak.upgrade() else { return };
            if m.inner.stop.load(Ordering::Relaxed) {
                return;
            }
            while let Some((worker, config, jobs, stamps)) = m.next_assignment() {
                m.dispatch(worker, config, jobs, stamps);
            }
            let mut seq = m.inner.events.lock().unwrap();
            if *seq == seen {
                let (guard, _) = m
                    .inner
                    .work_cv
                    .wait_timeout(seq, ASSIGNER_BACKSTOP)
                    .unwrap();
                seq = guard;
            }
            seen = *seq;
        }
    }

    /// Periodic liveness pass: evict stale workers and re-queue their
    /// circuits. This thread owns the only timer in the manager — the
    /// dispatch path never waits on it. The tick sleeps in small steps
    /// without pinning the manager, so both shutdown and drop release
    /// the thread within milliseconds even under a long eviction tick.
    fn liveness_thread(weak: WeakManager) {
        loop {
            let tick = {
                let Some(m) = weak.upgrade() else { return };
                if m.inner.stop.load(Ordering::Relaxed) {
                    return;
                }
                m.evict_and_requeue();
                m.maybe_compact_journal();
                m.inner.cfg.eviction_tick
            };
            let mut slept = Duration::ZERO;
            while slept < tick {
                let step = Duration::from_millis(20).min(tick - slept);
                std::thread::sleep(step);
                slept += step;
                match weak.upgrade() {
                    Some(m) => {
                        if m.inner.stop.load(Ordering::Relaxed) {
                            return;
                        }
                    }
                    None => return,
                }
            }
        }
    }

    fn evict_and_requeue(&self) {
        let now = self.inner.clock.now();
        let evicted = self.inner.registry.lock().unwrap().evict_stale(now);
        if evicted.is_empty() {
            return;
        }
        // Stop and drop the evicted workers' outboxes first, on their
        // own: their dispatcher threads exit after (at most) the batch
        // already executing; unsent batches are re-queued below through
        // the orphaned reservations.
        {
            let mut outboxes = self.inner.outboxes.lock().unwrap();
            for (wid, _) in &evicted {
                if let Some(ob) = outboxes.remove(*wid) {
                    ob.stop();
                }
            }
        }
        let mut q = self.inner.queue.lock().unwrap();
        let mut in_flight = self.inner.in_flight.lock().unwrap();
        let mut batches = self.inner.batches.lock().unwrap();
        let mut stats = self.inner.stats.lock().unwrap();
        let mut orphans: Vec<CircuitJob> = Vec::new();
        let mut touched_banks: Vec<u64> = Vec::new();
        for (_wid, orphan_keys) in evicted {
            stats.evictions += 1;
            for key in orphan_keys {
                // each orphaned reservation is a whole dispatch batch
                let members = batches.remove(&key).unwrap_or_else(|| vec![key]);
                for job_id in members {
                    if let Some(job) = in_flight.remove(&job_id) {
                        touched_banks.push(job.bank);
                        // Never resurrect cancelled work (the dropped
                        // circuit is lost — keeps the tenant prunable).
                        if self.inner.banks.is_cancelled(job.bank) {
                            stats.per_tenant.entry(job.client).or_default().lost += 1;
                            continue;
                        }
                        stats.requeues += 1;
                        orphans.push(job);
                    }
                }
            }
        }
        drop(stats);
        drop(batches);
        if !orphans.is_empty() {
            // WAL before the re-queue: replay moves these circuits back
            // to pending, so a crash right after eviction re-admits them
            // instead of failing their banks as in-flight-lost.
            self.journal_append(Record::Requeued {
                members: orphans.iter().map(|j| (j.bank, j.index as u32)).collect(),
            });
        }
        q.requeue_front(orphans);
        touched_banks.sort_unstable();
        touched_banks.dedup();
        self.gc_cancelled_banks(&touched_banks, &in_flight);
        drop(in_flight);
        drop(q);
        self.signal_event();
    }

    /// Pick the next circuit and worker per Algorithm 2, tenant-fairly:
    /// probe each tenant's head-of-line circuit in weighted-round-robin
    /// service order and take a same-config batch from the first tenant
    /// whose head can be placed (`max_batch = 1` reproduces the paper's
    /// per-circuit behavior). A tenant whose head cannot be placed right
    /// now is skipped, never blocking the tenants behind it.
    ///
    /// Capacity semantics: a batch executes as ONE unit on the worker
    /// (one PJRT program / one sequential backend job), so it reserves
    /// its `demand` qubits once — concurrent *batches* on a big worker
    /// are what multi-tenant packing schedules.
    ///
    /// Unschedulable head-of-line circuits fail their bank and the loop
    /// continues with the remaining queue immediately, instead of
    /// stalling schedulable work.
    #[allow(clippy::type_complexity)]
    fn next_assignment(
        &self,
    ) -> Option<(WorkerId, QuClassiConfig, Vec<CircuitJob>, Vec<Instant>)> {
        loop {
            let mut q = self.inner.queue.lock().unwrap();
            if q.is_empty() {
                return None;
            }
            let mut reg = self.inner.registry.lock().unwrap();
            // An empty pool is not a failure: workers may still join
            // (dynamic registration); park the queue until one does.
            if reg.is_empty() {
                return None;
            }
            // (client, bank, demand) of an unschedulable head-of-line
            let mut unschedulable: Option<(u64, u64, usize)> = None;
            let mut pick: Option<(u64, WorkerId, QuClassiConfig, usize)> = None;
            for client in q.service_order() {
                let Some(head) = q.head_of(client) else { continue };
                let demand = head.demand();
                if !scheduler::can_ever_fit(&reg, demand) {
                    // Unschedulable on the current pool: fail its whole
                    // bank (every sibling shares the config, hence the
                    // demand).
                    unschedulable = Some((client, head.bank, demand));
                    break;
                }
                let selected = match self.inner.cfg.noise_aware_alpha {
                    Some(alpha) => scheduler::select_noise_aware(&reg, demand, alpha),
                    None => scheduler::select(&reg, demand),
                };
                if let Some(worker) = selected {
                    pick = Some((client, worker, head.config, demand));
                    break;
                }
            }
            if let Some((client, bank, demand)) = unschedulable {
                let (drained, _) = q.drain_bank(bank);
                drop(reg);
                drop(q);
                if drained > 0 {
                    // The failed bank's circuits never reach a worker:
                    // account them as lost (quiescence for pruning).
                    let mut stats = self.inner.stats.lock().unwrap();
                    stats.per_tenant.entry(client).or_default().lost += drained as u64;
                    stats.prune_tenants(self.inner.cfg.max_tenant_stats);
                }
                let err = DqError::Unschedulable(format!(
                    "circuit needs {demand} qubits; no worker that large"
                ));
                // WAL the failure so recovery does not re-admit a bank
                // that already failed as unschedulable.
                self.journal_append(Record::Failed { bank, error: err.clone() });
                self.inner.banks.fail(bank, err);
                self.inner.space_cv.notify_all();
                continue;
            }
            let (client, worker, config, demand) = pick?;
            // Pack same-config circuits from this tenant into the batch,
            // sized by the worker's registered thread budget so one
            // dispatch saturates its backend pool without starving
            // co-tenants (DESIGN.md §11).
            let worker_threads = reg.get(worker).map(|w| w.threads).unwrap_or(1);
            let batch_limit = self
                .inner
                .cfg
                .max_batch
                .min(worker_threads.saturating_mul(self.inner.cfg.batch_per_thread))
                .max(1);
            let (jobs, stamps) = q.take_batch(client, config, batch_limit);
            debug_assert!(!jobs.is_empty());
            // One reservation for the whole batch, keyed by the head job;
            // the registry lock is held from selection through the
            // reservation, so eviction cannot invalidate the pick.
            let key = jobs[0].id;
            reg.reserve(worker, key, demand).expect("capacity checked");
            let mut in_flight = self.inner.in_flight.lock().unwrap();
            for j in &jobs {
                in_flight.insert(j.id, j.clone());
            }
            let mut batches = self.inner.batches.lock().unwrap();
            batches.insert(key, jobs.iter().map(|j| j.id).collect());
            drop(batches);
            drop(in_flight);
            drop(reg);
            drop(q);
            self.inner.space_cv.notify_all();
            return Some((worker, config, jobs, stamps));
        }
    }

    /// Hand one batch to its worker's outbox (O(1), never blocks on the
    /// worker). Dispatch and queue-wait counters are *not* recorded
    /// here: the batch carries its admission stamps, and
    /// [`Manager::run_batch`] accounts them at the moment the batch
    /// reaches a worker channel — which may be a different worker
    /// entirely once a sibling steals it (DESIGN.md §14).
    fn dispatch(
        &self,
        worker: WorkerId,
        config: QuClassiConfig,
        jobs: Vec<CircuitJob>,
        stamps: Vec<Instant>,
    ) {
        let outbox = self.inner.outboxes.lock().unwrap().get(worker);
        let Some(ob) = outbox else {
            // Worker evicted between selection and dispatch: re-queue (a
            // no-op for jobs the evictor already reclaimed).
            self.requeue(worker, jobs);
            return;
        };
        match ob.enqueue(Batch { config, jobs, enqueued: stamps }) {
            Ok(surplus) => {
                if surplus && self.inner.cfg.steal {
                    // The batch parked behind a saturated channel: wake
                    // idle siblings so one of them can steal it instead
                    // of letting it wait out the victim's backlog.
                    self.inner.outboxes.lock().unwrap().nudge_siblings(worker);
                }
            }
            Err(batch) => self.requeue(worker, batch.jobs),
        }
    }

    /// Work stealing (DESIGN.md §14): called by an idle worker's
    /// dispatcher; finds a compatible batch still queued on a sibling's
    /// outbox, atomically moves its qubit reservation from the victim to
    /// the thief, and hands the batch over for local execution.
    ///
    /// The whole scan → queue-removal → reservation-transfer runs under
    /// one registry-lock hold, so it serializes against both the
    /// assigner (selection + reservation) and the evictor
    /// (`Registry::evict_stale`): a steal either completes before an
    /// eviction snapshot (the moved key is no longer in the victim's
    /// active set, so the evictor will not re-queue it) or observes the
    /// victim already gone and leaves its batches to the orphan
    /// re-queue pass. Eviction can never see a half-moved batch, and a
    /// circuit can never be both stolen and orphan-requeued.
    pub(crate) fn steal_for(&self, thief: WorkerId) -> Option<Batch> {
        if !self.inner.cfg.steal || self.inner.stop.load(Ordering::Relaxed) {
            return None;
        }
        let mut reg = self.inner.registry.lock().unwrap();
        let thief_avail = reg.get(thief)?.available();
        if thief_avail == 0 {
            return None;
        }
        // Noise-aware placement composes with stealing: a worker the
        // assigner would refuse under `noise_aware_alpha` must not
        // acquire the same circuits through the steal side door. Same
        // cutoff as `select_noise_aware`, computed under the same
        // registry-lock hold (PR 5's documented bypass, closed).
        if let Some(alpha) = self.inner.cfg.noise_aware_alpha {
            let thief_noise = reg.get(thief)?.noise;
            match scheduler::noise_cutoff(&reg, alpha) {
                Some(cutoff) if thief_noise <= cutoff => {}
                _ => return None,
            }
        }
        let victims = self.inner.outboxes.lock().unwrap().victims(thief);
        for (victim, ob) in victims {
            // Eviction raced us between the directory snapshot and here:
            // the orphan re-queue pass owns that worker's batches now.
            if reg.get(victim).is_none() {
                continue;
            }
            let Some(batch) = ob.steal_where(|b| b.demand() <= thief_avail) else {
                continue;
            };
            let key = batch.key();
            let demand = batch.demand();
            reg.transfer(victim, thief, key, demand)
                .expect("steal capacity checked under the registry lock");
            let client = batch.jobs[0].client;
            {
                let mut stats = self.inner.stats.lock().unwrap();
                stats.steals += 1;
                stats.per_tenant.entry(client).or_default().stolen += batch.jobs.len() as u64;
            }
            // Debug level: steals are hot-path under skewed load.
            crate::log_debug!(
                "manager",
                "w{thief} stole a {}-circuit batch from w{victim}",
                batch.jobs.len()
            );
            return Some(batch);
        }
        None
    }

    /// Cross-shard steal, victim side (DESIGN.md §18): carve the next
    /// WRR-fair batch whose qubit demand satisfies `fits` out of this
    /// shard's *pending* queue and account it exactly like a local
    /// dispatch — WAL `Dispatched`, in-flight/batch bookkeeping, steal
    /// and dispatch/queue-wait counters — so bank routing, cancel GC,
    /// and crash recovery treat it identically to home-shard work. The
    /// exported batch holds no registry reservation here (the thief
    /// shard reserves on its own pool), so this shard's evictor can
    /// never reclaim it; its outcome must come back through
    /// [`Manager::finish_exported`].
    #[allow(clippy::type_complexity)]
    pub(crate) fn export_batch(
        &self,
        fits: &dyn Fn(usize) -> bool,
    ) -> Option<(QuClassiConfig, Vec<CircuitJob>, Vec<CircuitPair>, usize)> {
        if self.inner.stop.load(Ordering::Relaxed) {
            return None;
        }
        let (config, jobs, stamps, demand) = {
            let mut q = self.inner.queue.lock().unwrap();
            if q.is_empty() {
                return None;
            }
            let mut pick: Option<(u64, QuClassiConfig, usize)> = None;
            for client in q.service_order() {
                let Some(head) = q.head_of(client) else { continue };
                let demand = head.demand();
                if fits(demand) {
                    pick = Some((client, head.config, demand));
                    break;
                }
            }
            let (client, config, demand) = pick?;
            let (jobs, stamps) = q.take_batch(client, config, self.inner.cfg.max_batch.max(1));
            debug_assert!(!jobs.is_empty());
            let key = jobs[0].id;
            let mut in_flight = self.inner.in_flight.lock().unwrap();
            for j in &jobs {
                in_flight.insert(j.id, j.clone());
            }
            self.inner
                .batches
                .lock()
                .unwrap()
                .insert(key, jobs.iter().map(|j| j.id).collect());
            drop(in_flight);
            drop(q);
            (config, jobs, stamps, demand)
        };
        // Queued work left the shard: release blocked submitters.
        self.inner.space_cv.notify_all();
        {
            let mut stats = self.inner.stats.lock().unwrap();
            stats.steals += 1;
            stats.per_tenant.entry(jobs[0].client).or_default().stolen += jobs.len() as u64;
        }
        let (config, jobs, pairs) =
            self.begin_batch(Batch { config, jobs, enqueued: stamps });
        Some((config, jobs, pairs, demand))
    }

    /// Cross-shard steal, result import: route a foreign execution's
    /// outcome for a batch carved by [`Manager::export_batch`] through
    /// this shard's normal completion path. [`FOREIGN_WORKER`] never
    /// matches a registry entry (release on it is a no-op), so a failed
    /// foreign run re-queues the circuits here — on their home shard —
    /// exactly like a failed local dispatch.
    pub(crate) fn finish_exported(&self, jobs: Vec<CircuitJob>, res: Result<Vec<f32>, DqError>) {
        self.finish_batch(FOREIGN_WORKER, jobs, res);
    }

    /// Cross-shard steal, thief side: execute a sibling shard's exported
    /// batch on this shard's own pool. The qubit reservation is keyed by
    /// a *locally* allocated job id (sibling shards number their own
    /// jobs, so a foreign key could collide), held across the
    /// synchronous channel call, and released before returning. An
    /// eviction racing the call reclaims the reservation as an orphan
    /// with no batch members — harmless, and the trailing release
    /// no-ops.
    pub(crate) fn run_foreign(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
        demand: usize,
    ) -> Result<Vec<f32>, DqError> {
        let key = self.inner.next_job.fetch_add(1, Ordering::Relaxed);
        let (worker, channel) = {
            let mut reg = self.inner.registry.lock().unwrap();
            let selected = match self.inner.cfg.noise_aware_alpha {
                Some(alpha) => scheduler::select_noise_aware(&reg, demand, alpha),
                None => scheduler::select(&reg, demand),
            };
            let Some(worker) = selected else {
                return Err(DqError::Unschedulable(format!(
                    "foreign batch needs {demand} qubits; none available on this shard"
                )));
            };
            reg.reserve(worker, key, demand).expect("capacity checked under the lock");
            // outboxes nests directly inside registry (DESIGN.md §13).
            let channel = self.inner.outboxes.lock().unwrap().get(worker).map(|ob| ob.channel());
            match channel {
                Some(c) => (worker, c),
                None => {
                    reg.release(worker, key);
                    return Err(DqError::WorkerLost(format!(
                        "worker w{worker} lost its outbox mid-steal"
                    )));
                }
            }
        };
        let res = channel.execute(config, pairs);
        self.inner.registry.lock().unwrap().release(worker, key);
        // Capacity freed on this shard: wake its assigner.
        self.signal_event();
        res
    }

    /// Execute one batch on the calling thread (an outbox execution
    /// thread) and route the outcome: results into the bank store, short
    /// payloads into a protocol failure, transport errors into a
    /// re-queue.
    pub(crate) fn run_batch(&self, worker: WorkerId, channel: &dyn WorkerChannel, batch: Batch) {
        let (config, jobs, pairs) = self.begin_batch(batch);
        let res = channel.execute(&config, &pairs);
        self.finish_batch(worker, jobs, res);
    }

    /// First half of [`Manager::run_batch`]: WAL the dispatch, account
    /// dispatch/queue-wait stats, and build the wire payload. Split out
    /// so an async channel (the mux plane) can run the channel call
    /// enqueue-and-notify and feed the eventual outcome back through
    /// [`Manager::finish_batch`] from a transport thread.
    pub(crate) fn begin_batch(
        &self,
        batch: Batch,
    ) -> (QuClassiConfig, Vec<CircuitJob>, Vec<CircuitPair>) {
        let Batch { config, jobs, enqueued } = batch;
        // WAL: the Dispatched record precedes the channel call, so "no
        // Dispatched record in the journal" implies "this circuit never
        // executed" — the invariant that makes post-crash re-admission
        // safe (no circuit can ever run twice across a restart).
        self.journal_append(Record::Dispatched {
            members: jobs.iter().map(|j| (j.bank, j.index as u32)).collect(),
        });
        // Dispatch + queue-wait accounting happens here — the moment the
        // batch reaches a worker channel — so the measured wait covers
        // outbox residency and survives a steal (the admission stamps
        // ride inside the batch). A batch is drawn from a single
        // tenant's sub-queue, so `jobs[0].client` keys the owner: a
        // stolen batch's counters land on the tenant that submitted it,
        // not on the thief.
        {
            let now = Instant::now();
            let mut stats = self.inner.stats.lock().unwrap();
            stats.dispatches += 1;
            let tenant = stats.per_tenant.entry(jobs[0].client).or_default();
            tenant.dispatched += jobs.len() as u64;
            for stamp in &enqueued {
                let s = now.saturating_duration_since(*stamp).as_secs_f64();
                tenant.wait_total_s += s;
                if s > tenant.wait_max_s {
                    tenant.wait_max_s = s;
                }
                tenant.wait_hist.record(s);
            }
        }
        let pairs: Vec<CircuitPair> =
            jobs.iter().map(|j| (j.thetas.clone(), j.data.clone())).collect();
        (config, jobs, pairs)
    }

    /// Second half of [`Manager::run_batch`]: route one channel outcome
    /// for a batch that went through [`Manager::begin_batch`]. Runs on
    /// whatever thread the channel completes on.
    pub(crate) fn finish_batch(
        &self,
        worker: WorkerId,
        jobs: Vec<CircuitJob>,
        res: Result<Vec<f32>, DqError>,
    ) {
        match res {
            Ok(fids) if fids.len() != jobs.len() => {
                // A short/overlong fids payload is a protocol violation:
                // the per-circuit mapping is unknown, so fail every bank
                // in the batch rather than guess (or hang a waiting
                // client).
                let err = DqError::Protocol(format!(
                    "worker w{worker} returned {} fids for {} circuits",
                    fids.len(),
                    jobs.len()
                ));
                crate::log_warn!("manager", "{err}");
                self.abandon_batch(worker, &jobs, err);
            }
            Ok(fids) => {
                // WAL before the in-memory credit: a crash after this
                // append replays the results; a crash before it leaves
                // the circuits in-flight (bank fails WorkerLost) — in
                // neither case is a result lost after a client saw it.
                self.journal_append(Record::Completed {
                    results: jobs
                        .iter()
                        .zip(fids.iter())
                        .map(|(j, f)| (j.bank, j.index as u32, *f))
                        .collect(),
                });
                let key = jobs[0].id;
                let mut reg = self.inner.registry.lock().unwrap();
                let mut in_flight = self.inner.in_flight.lock().unwrap();
                reg.release(worker, key);
                self.inner.batches.lock().unwrap().remove(&key);
                // Only jobs still present in the in-flight map are
                // credited to this dispatch: a missing entry means the
                // evictor reclaimed the job (stalled-heartbeat race) and
                // the re-dispatch accounts for it instead, keeping
                // completed == submitted. Fidelities are recorded for
                // the whole batch regardless — first result wins, the
                // bank store ignores duplicates.
                let mut landed: u64 = 0;
                for job in &jobs {
                    if in_flight.remove(&job.id).is_some() {
                        landed += 1;
                    }
                }
                {
                    // Order matters: bump the completion counter before
                    // banks.complete() can wake a waiting client, so a
                    // stats read right after wait_bank() is consistent.
                    let mut stats = self.inner.stats.lock().unwrap();
                    stats.completed += landed;
                    stats.per_tenant.entry(jobs[0].client).or_default().completed += landed;
                    // Completion can turn a tenant quiescent: prune here
                    // too so churn-heavy deployments stay bounded even
                    // between submits.
                    stats.prune_tenants(self.inner.cfg.max_tenant_stats);
                }
                for (job, fid) in jobs.iter().zip(fids.iter()) {
                    self.inner.banks.complete(job.bank, job.index, *fid);
                }
                self.gc_cancelled_banks(&distinct_banks(&jobs), &in_flight);
                drop(in_flight);
                drop(reg);
                // Capacity freed: wake the assigner.
                self.signal_event();
            }
            Err(e) => {
                crate::log_warn!(
                    "manager",
                    "dispatch to w{worker} failed ({e}); re-queueing {} circuits",
                    jobs.len()
                );
                self.requeue(worker, jobs);
            }
        }
    }

    /// Drop a batch whose results are unusable: release the reservation,
    /// clear in-flight records, and fail every bank it touched
    /// (cancelled banks just have their tombstones GC'd).
    fn abandon_batch(&self, worker: WorkerId, jobs: &[CircuitJob], err: DqError) {
        let mut reg = self.inner.registry.lock().unwrap();
        let mut in_flight = self.inner.in_flight.lock().unwrap();
        if let Some(first) = jobs.first() {
            reg.release(worker, first.id);
            self.inner.batches.lock().unwrap().remove(&first.id);
        }
        let mut lost: u64 = 0;
        for job in jobs {
            // Only circuits this batch still owned are lost here; ones
            // the evictor already reclaimed will re-execute elsewhere.
            if in_flight.remove(&job.id).is_some() {
                lost += 1;
            }
        }
        if lost > 0 {
            let mut stats = self.inner.stats.lock().unwrap();
            stats.per_tenant.entry(jobs[0].client).or_default().lost += lost;
            stats.prune_tenants(self.inner.cfg.max_tenant_stats);
        }
        let banks = distinct_banks(jobs);
        self.gc_cancelled_banks(&banks, &in_flight);
        drop(in_flight);
        drop(reg);
        for bank in banks {
            self.journal_append(Record::Failed { bank, error: err.clone() });
            // no-op for cancelled banks (fail never overrides a cancel)
            self.inner.banks.fail(bank, err.clone());
        }
        self.signal_event();
    }

    fn requeue(&self, worker: WorkerId, jobs: Vec<CircuitJob>) {
        let mut q = self.inner.queue.lock().unwrap();
        let mut reg = self.inner.registry.lock().unwrap();
        let mut in_flight = self.inner.in_flight.lock().unwrap();
        if let Some(first) = jobs.first() {
            reg.release(worker, first.id);
            self.inner.batches.lock().unwrap().remove(&first.id);
        }
        let banks = distinct_banks(&jobs);
        let mut stats = self.inner.stats.lock().unwrap();
        let mut keep: Vec<CircuitJob> = Vec::with_capacity(jobs.len());
        for job in jobs {
            // A missing in-flight entry means the evictor raced us and
            // already reclaimed (and re-queued) this job — re-adding our
            // copy would execute the circuit twice and inflate every
            // counter it touches.
            if in_flight.remove(&job.id).is_none() {
                continue;
            }
            // Never resurrect a cancelled bank's work: its queued jobs
            // were drained at cancel time, so a failed/evicted batch is
            // simply dropped — and the circuit is lost, which keeps the
            // tenant prunable.
            if self.inner.banks.is_cancelled(job.bank) {
                stats.per_tenant.entry(job.client).or_default().lost += 1;
                continue;
            }
            stats.requeues += 1;
            keep.push(job);
        }
        drop(stats);
        if !keep.is_empty() {
            // WAL before the re-queue (same reasoning as the evictor's
            // orphan pass): these circuits never executed, so replay may
            // safely re-admit them.
            self.journal_append(Record::Requeued {
                members: keep.iter().map(|j| (j.bank, j.index as u32)).collect(),
            });
        }
        q.requeue_front(keep);
        self.gc_cancelled_banks(&banks, &in_flight);
        drop(in_flight);
        drop(reg);
        drop(q);
        self.signal_event();
    }
}

/// The distinct bank ids appearing in a batch.
fn distinct_banks(jobs: &[CircuitJob]) -> Vec<u64> {
    let mut banks: Vec<u64> = jobs.iter().map(|j| j.bank).collect();
    banks.sort_unstable();
    banks.dedup();
    banks
}

impl Drop for Inner {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::exec::QsimExecutor;
    use crate::model::CircuitExecutor;

    /// Worker channel backed by the local simulator.
    struct SimChannel;

    impl WorkerChannel for SimChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            QsimExecutor.execute_bank(config, pairs)
        }
    }

    /// A channel that always fails (fault injection).
    struct FlakyChannel {
        fail_first: std::sync::atomic::AtomicU32,
    }

    impl WorkerChannel for FlakyChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            if self.fail_first.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                if v > 0 {
                    Some(v - 1)
                } else {
                    None
                }
            }).is_ok()
            {
                return Err(DqError::Io("injected fault".to_string()));
            }
            QsimExecutor.execute_bank(config, pairs)
        }
    }

    /// A channel that pauses per batch — lets tests observe in-progress
    /// banks deterministically.
    struct SlowChannel {
        delay: Duration,
    }

    impl WorkerChannel for SlowChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            std::thread::sleep(self.delay);
            QsimExecutor.execute_bank(config, pairs)
        }
    }

    /// A channel that sleeps, then fails every batch (eviction-path
    /// fault injection).
    struct SlowFailChannel {
        delay: Duration,
    }

    impl WorkerChannel for SlowFailChannel {
        fn execute(
            &self,
            _config: &QuClassiConfig,
            _pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            std::thread::sleep(self.delay);
            Err(DqError::Io("injected fault".to_string()))
        }
    }

    /// A channel that returns one fidelity too few (protocol violation).
    struct ShortChannel;

    impl WorkerChannel for ShortChannel {
        fn execute(
            &self,
            config: &QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<Vec<f32>, DqError> {
            let mut fids = QsimExecutor.execute_bank(config, pairs)?;
            fids.pop();
            Ok(fids)
        }
    }

    fn pairs_for(config: &QuClassiConfig, n: usize) -> Vec<CircuitPair> {
        let mut rng = crate::util::Rng::new(9);
        (0..n)
            .map(|_| {
                (
                    (0..config.n_params()).map(|_| rng.f32()).collect(),
                    (0..config.n_features()).map(|_| rng.f32()).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn single_worker_end_to_end() {
        let m = Manager::new(ManagerConfig::default());
        m.register(WorkerProfile::new(5).cru(0.1), Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 10);
        let session = m.session();
        let fids = session.execute(cfg, &pairs).unwrap();
        assert_eq!(fids.len(), 10);
        // results must match direct simulation exactly
        let want = QsimExecutor.execute_bank(&cfg, &pairs).unwrap();
        assert_eq!(fids, want);
        assert_eq!(m.stats().completed, 10);
        m.shutdown();
    }

    #[test]
    fn multiple_workers_share_load() {
        let m = Manager::new(ManagerConfig { max_batch: 2, ..Default::default() });
        for _ in 0..4 {
            m.register(WorkerProfile::new(5), Arc::new(SimChannel));
        }
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let pairs = pairs_for(&cfg, 30);
        let fids = m.session().execute(cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        assert!(m.stats().dispatches >= 15); // 30 circuits / batch 2
        m.shutdown();
    }

    #[test]
    fn batches_are_sized_by_worker_thread_budget() {
        // max_batch is large; the 2-thread worker's budget (2 * 3 = 6)
        // caps each dispatch instead.
        let m = Manager::new(ManagerConfig {
            max_batch: 100,
            batch_per_thread: 3,
            ..Default::default()
        });
        m.register(WorkerProfile::new(5).threads(2), Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 30);
        let fids = m.session().execute(cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        assert!(m.stats().dispatches >= 5, "expected >= 30/6 dispatches");
        m.shutdown();
    }

    #[test]
    fn oversized_circuit_fails_cleanly() {
        let m = Manager::new(ManagerConfig::default());
        m.register(WorkerProfile::new(5), Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(7, 1).unwrap(); // needs 7 > 5
        let pairs = pairs_for(&cfg, 2);
        let err = m.session().execute(cfg, &pairs).unwrap_err();
        assert!(matches!(&err, DqError::Unschedulable(m) if m.contains("no worker")), "{err}");
        m.shutdown();
    }

    #[test]
    fn unschedulable_bank_does_not_stall_schedulable_work() {
        // Head-of-line: an oversized bank in front of a schedulable one
        // must fail fast while the schedulable bank completes in the same
        // assignment pass.
        let m = Manager::new(ManagerConfig::default());
        m.register(WorkerProfile::new(5), Arc::new(SimChannel));
        let cfg_big = QuClassiConfig::new(9, 1).unwrap();
        let cfg_ok = QuClassiConfig::new(5, 1).unwrap();
        let session = m.session();
        let doomed = session.submit(cfg_big, &pairs_for(&cfg_big, 4)).unwrap();
        let viable = session.submit(cfg_ok, &pairs_for(&cfg_ok, 4)).unwrap();
        assert!(matches!(doomed.wait(), Err(DqError::Unschedulable(_))));
        let fids = viable.wait().unwrap();
        assert_eq!(fids.len(), 4);
        m.shutdown();
    }

    #[test]
    fn dispatch_failure_requeues_and_recovers() {
        let m = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
        m.register(
            WorkerProfile::new(5),
            Arc::new(FlakyChannel { fail_first: std::sync::atomic::AtomicU32::new(2) }),
        );
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 8);
        let fids = m.session().execute(cfg, &pairs).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        assert!(m.stats().requeues > 0);
        m.shutdown();
    }

    #[test]
    fn short_fids_payload_fails_bank_with_protocol_error() {
        let m = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
        m.register(WorkerProfile::new(5), Arc::new(ShortChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 4);
        let err = m.session().execute(cfg, &pairs).unwrap_err();
        assert!(matches!(err, DqError::Protocol(_)), "{err}");
        // the batch reservation must have been released
        assert_eq!(m.available_qubits(), 5);
        m.shutdown();
    }

    #[test]
    fn concurrent_clients_multi_tenant() {
        // A 20-qubit and a 5-qubit worker; two clients with different
        // configs submit concurrently (the paper's multi-tenant setting).
        let m = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
        m.register(WorkerProfile::new(20).cru(0.2), Arc::new(SimChannel));
        m.register(WorkerProfile::new(5).cru(0.1), Arc::new(SimChannel));
        let m1 = m.clone();
        let t1 = std::thread::spawn(move || {
            let cfg = QuClassiConfig::new(5, 1).unwrap();
            let pairs = pairs_for(&cfg, 20);
            let fids = m1.session().execute(cfg, &pairs).unwrap();
            assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        });
        let m2 = m.clone();
        let t2 = std::thread::spawn(move || {
            let cfg = QuClassiConfig::new(7, 2).unwrap();
            let pairs = pairs_for(&cfg, 20);
            let fids = m2.session().execute(cfg, &pairs).unwrap();
            assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        });
        t1.join().unwrap();
        t2.join().unwrap();
        assert_eq!(m.stats().completed, 40);
        m.shutdown();
    }

    #[test]
    fn per_tenant_stats_track_dispatch_and_wait() {
        let m = Manager::new(ManagerConfig { max_batch: 4, ..Default::default() });
        m.register(WorkerProfile::new(5), Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let a = m.session();
        let b = m.session();
        let fa = a.execute(cfg, &pairs_for(&cfg, 8)).unwrap();
        let fb = b.execute(cfg, &pairs_for(&cfg, 4)).unwrap();
        assert_eq!((fa.len(), fb.len()), (8, 4));
        let stats = m.stats();
        let ta = &stats.per_tenant[&a.id()];
        let tb = &stats.per_tenant[&b.id()];
        assert_eq!((ta.submitted, ta.dispatched, ta.completed), (8, 8, 8));
        assert_eq!((tb.submitted, tb.dispatched, tb.completed), (4, 4, 4));
        assert!(ta.wait_total_s >= 0.0 && ta.wait_max_s >= 0.0);
        // the wait histogram sees exactly the dispatched circuits
        assert_eq!(ta.wait_hist.total(), 8);
        assert_eq!(tb.wait_hist.total(), 4);
        assert!(ta.wait_hist.p90().is_finite());
        m.shutdown();
    }

    #[test]
    fn prune_tenants_folds_quiescent_into_retired() {
        let mut stats = ManagerStats::default();
        for client in 1..=10u64 {
            stats.per_tenant.insert(
                client,
                TenantStats {
                    submitted: client,
                    dispatched: client,
                    completed: client, // quiescent
                    ..Default::default()
                },
            );
        }
        // client 11 is mid-flight: never pruned regardless of rank
        stats
            .per_tenant
            .insert(11, TenantStats { submitted: 1, completed: 0, ..Default::default() });
        // client 12 cancelled everything: completed 0 but every circuit
        // accounted lost -> quiescent, prunable
        stats.per_tenant.insert(
            12,
            TenantStats { submitted: 5, completed: 2, lost: 3, ..Default::default() },
        );
        stats.prune_tenants(4);
        // top-4 by submitted (10, 9, 8, 7) survive, plus the active
        // client 11; the cancel-churn client 12 (submitted 5) is now
        // quiescent through `lost` and prunes with clients 1-6
        assert_eq!(stats.per_tenant.len(), 5);
        for keep in [7u64, 8, 9, 10, 11] {
            assert!(stats.per_tenant.contains_key(&keep), "dropped tenant {keep}");
        }
        assert_eq!(stats.pruned_tenants, 7);
        assert_eq!(stats.retired.submitted, (1..=6).sum::<u64>() + 5);
        assert_eq!(stats.retired.completed + stats.retired.lost, stats.retired.submitted);
        // idempotent at or under the hysteresis threshold
        stats.prune_tenants(4);
        assert_eq!(stats.pruned_tenants, 7);
        // cap 0 disables pruning entirely
        let mut unbounded = ManagerStats::default();
        for client in 1..=10u64 {
            unbounded.per_tenant.insert(client, TenantStats::default());
        }
        unbounded.prune_tenants(0);
        assert_eq!(unbounded.per_tenant.len(), 10);
    }

    /// In-module steal smoke test (the full audit lives in
    /// `tests/steal_audit.rs`): a slow worker's surplus drains through a
    /// fast sibling and the steals counter moves.
    #[test]
    fn steals_move_surplus_to_idle_sibling() {
        let m = Manager::new(ManagerConfig { max_batch: 2, ..Default::default() });
        m.register(
            WorkerProfile::new(20).cru(0.0),
            Arc::new(SlowChannel { delay: Duration::from_millis(10) }),
        );
        m.register(WorkerProfile::new(20).cru(0.5), Arc::new(SimChannel));
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 24);
        let fids = m.session().execute(cfg, &pairs).unwrap();
        assert_eq!(fids.len(), 24);
        let stats = m.stats();
        assert!(stats.steals > 0, "no steals despite a 10 ms slow worker: {stats:?}");
        assert_eq!(stats.completed, 24);
        m.shutdown();
    }

    #[test]
    fn no_worker_keeps_bank_pending_until_one_joins() {
        let m = Manager::new(ManagerConfig::default());
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 3);
        let session = m.session();
        let handle = session.submit(cfg, &pairs).unwrap();
        // register a worker shortly after; dynamic join must drain it
        let m2 = m.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            m2.register(WorkerProfile::new(5), Arc::new(SimChannel));
        });
        let fids = handle.wait().unwrap();
        assert_eq!(fids.len(), 3);
        m.shutdown();
    }

    #[test]
    fn empty_bank_rejected() {
        let m = Manager::new(ManagerConfig::default());
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        assert!(matches!(m.submit_bank(1, cfg, &[]), Err(DqError::Arity(_))));
        assert!(matches!(m.session().submit(cfg, &[]), Err(DqError::Arity(_))));
        m.shutdown();
    }

    #[test]
    fn cancel_drains_queue_and_discards_in_flight() {
        // One slow 5-qubit worker, batch size 1: circuits complete one at
        // a time, so the bank is observably half-done when we cancel.
        let m = Manager::new(ManagerConfig { max_batch: 1, ..Default::default() });
        m.register(
            WorkerProfile::new(5),
            Arc::new(SlowChannel { delay: Duration::from_millis(25) }),
        );
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 12);
        let session = m.session();
        let handle = session.submit(cfg, &pairs).unwrap();
        // wait for partial progress
        loop {
            let st = handle.try_poll().unwrap();
            if st.completed >= 2 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        handle.cancel().unwrap();
        assert_eq!(m.queue_len(), 0, "queued circuits must drain on cancel");
        assert!(matches!(handle.wait_timeout(Duration::from_secs(5)), Err(DqError::Cancelled(_))));
        let requeues = m.stats().requeues;
        assert_eq!(requeues, 0, "cancel must not requeue anything");
        assert_eq!(m.stats().cancelled, 1);
        // the worker finishes its in-flight circuit and frees up: a new
        // bank from another tenant completes with exact parity.
        let other = m.session();
        let pairs2 = pairs_for(&cfg, 3);
        let fids = other.execute(cfg, &pairs2).unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs2).unwrap());
        m.shutdown();
    }

    #[test]
    fn cancel_with_nothing_in_flight_still_reports_cancelled() {
        // No workers: every circuit stays queued, so cancel GCs the
        // tombstone immediately. Late observers must still see the
        // cancellation — never an "unknown bank" Protocol error that
        // depends on GC timing.
        let m = Manager::new(ManagerConfig::default());
        let session = m.session();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let handle = session.submit(cfg, &pairs_for(&cfg, 4)).unwrap();
        assert_eq!(handle.cancel().unwrap(), 4);
        assert_eq!(m.queue_len(), 0);
        // drained circuits are accounted as lost, so a cancel-only
        // tenant is quiescent for retention pruning
        let t = &m.stats().per_tenant[&session.id()];
        assert_eq!((t.submitted, t.completed, t.lost), (4, 0, 4));
        assert!(matches!(handle.try_poll(), Err(DqError::Cancelled(_))));
        assert!(matches!(
            handle.wait_timeout(Duration::from_secs(1)),
            Err(DqError::Cancelled(_))
        ));
        assert!(matches!(handle.wait(), Err(DqError::Cancelled(_))));
        m.shutdown();
    }

    #[test]
    fn consuming_wait_timeout_reaps_the_bank() {
        // The default-timeout wait consumes the handle, so a timeout
        // leaves no way to retry or cancel — the manager must reap the
        // zombie bank instead of leaking it.
        let m = Manager::new(ManagerConfig {
            wait_timeout: Duration::from_millis(30),
            ..Default::default()
        });
        let session = m.session();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let handle = session.submit(cfg, &pairs_for(&cfg, 3)).unwrap(); // no workers
        let bank = handle.id();
        assert!(matches!(handle.wait(), Err(DqError::Timeout(_))));
        assert_eq!(m.queue_len(), 0, "queued circuits must drain on reap");
        assert!(m.bank_status(bank).is_none(), "bank state must not leak");
        assert!(m.bank_cancelled(bank));
        assert_eq!(m.stats().cancelled, 1);
        m.shutdown();
    }

    #[test]
    fn failed_dispatch_after_cancel_and_wait_does_not_resurrect() {
        // Waiting out a cancellation removes the tombstone while a batch
        // is still on the worker; when that dispatch then fails, the
        // cancelled bank's jobs must be dropped (the persistent
        // cancelled-id record), never requeued and re-executed.
        let m = Manager::new(ManagerConfig { max_batch: 1, ..Default::default() });
        m.register(
            WorkerProfile::new(5),
            Arc::new(SlowFailChannel { delay: Duration::from_millis(60) }),
        );
        let session = m.session();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let handle = session.submit(cfg, &pairs_for(&cfg, 2)).unwrap();
        std::thread::sleep(Duration::from_millis(20)); // let one batch dispatch
        handle.cancel().unwrap();
        assert!(matches!(
            handle.wait_timeout(Duration::from_secs(1)),
            Err(DqError::Cancelled(_))
        ));
        std::thread::sleep(Duration::from_millis(100)); // in-flight dispatch fails
        assert_eq!(m.stats().requeues, 0, "cancelled work must not be requeued");
        assert_eq!(m.queue_len(), 0);
        m.shutdown();
    }

    #[test]
    fn try_poll_counts_are_monotonic() {
        let m = Manager::new(ManagerConfig { max_batch: 2, ..Default::default() });
        m.register(
            WorkerProfile::new(5),
            Arc::new(SlowChannel { delay: Duration::from_millis(5) }),
        );
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 10);
        let session = m.session();
        let handle = session.submit(cfg, &pairs).unwrap();
        let mut last = 0usize;
        loop {
            let st = handle.try_poll().unwrap();
            assert!(st.completed >= last, "completion went backwards: {} < {last}", st.completed);
            assert_eq!(st.total, 10);
            assert_eq!(
                st.partial_fids.iter().filter(|f| f.is_some()).count(),
                st.completed,
                "partial_fids must agree with the completion count"
            );
            last = st.completed;
            if !st.pending {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(last, 10);
        let fids = handle.wait().unwrap();
        assert_eq!(fids, QsimExecutor.execute_bank(&cfg, &pairs).unwrap());
        m.shutdown();
    }

    /// Regression (PR 10 satellite): a panic while the journal mutex is
    /// held must not poison every later append/flush/compact into a
    /// panic cascade — `journal_lock` recovers the guard, the same
    /// policy the plan cache uses. The on-disk file is at worst a clean
    /// prefix (recovery's tail truncation owns torn records), so
    /// continuing to append is safe.
    #[test]
    fn journal_survives_mutex_poisoning() {
        let path =
            std::env::temp_dir().join(format!("dqulearn_poison_{}.journal", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let jc = JournalConfig::new(&path);
        let m = Manager::new(ManagerConfig { journal: Some(jc.clone()), ..Default::default() });
        let client = m.new_client();
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = pairs_for(&cfg, 2);
        let _a = m.submit_bank(client, cfg, &pairs).unwrap();

        // Poison the journal mutex the way a panicking append would:
        // panic on a thread that holds the lock.
        let held = m.clone();
        let _ = std::thread::spawn(move || {
            let j = held.inner.journal.as_ref().expect("journaling on");
            let _guard = j.lock().unwrap();
            panic!("injected panic mid-append");
        })
        .join();
        assert!(
            m.inner.journal.as_ref().unwrap().lock().is_err(),
            "mutex must actually be poisoned for this regression to bite"
        );

        // Later appends still work: the submit path (append-or-reject)
        // and cancel's WAL-first tombstone both cross the journal lock.
        let b = m.submit_bank(client, cfg, &pairs).unwrap();
        m.cancel_bank(b);
        m.shutdown(); // resolves pendings + flushes through the same lock
        drop(m);

        // And recovery stays clean: the tombstone appended *after* the
        // poisoning survived to disk.
        let (m2, _report) =
            Manager::recover(ManagerConfig { journal: Some(jc), ..Default::default() }).unwrap();
        assert!(m2.bank_cancelled(b), "append after mutex poisoning was lost");
        m2.shutdown();
        let _ = std::fs::remove_file(&path);
    }
}
