//! Worker registry: MR / OR / AR / CRU bookkeeping + liveness
//! (Algorithm 2 lines 1-13).

use std::collections::BTreeMap;

use super::job::JobId;
use crate::error::DqError;

/// Worker identifier assigned at registration (`w_1, w_2, ...`).
pub type WorkerId = u64;

/// Registration-time description of a worker — the single typed entry
/// point that replaced the telescoping `register_worker*` variants.
///
/// Construct with [`WorkerProfile::new`] and chain the optional setters;
/// every field beyond `max_qubits` defaults sensibly, so future fields
/// can be added without breaking call sites:
///
/// ```
/// use dqulearn::coordinator::WorkerProfile;
/// let profile = WorkerProfile::new(20).cru(0.1).noise(0.02).threads(4);
/// assert_eq!(profile.max_qubits, 20);
/// ```
#[derive(Debug, Clone, PartialEq)]
#[non_exhaustive]
pub struct WorkerProfile {
    /// `MR` — advertised maximum qubits.
    pub max_qubits: usize,
    /// Initial classical-resource-usage sample in [0, 1].
    pub cru: f64,
    /// Estimated gate-error level in [0, 1] (extension §10; 0 = ideal).
    pub noise: f64,
    /// Execution thread budget (>= 1); sizes dispatch batches
    /// (DESIGN.md §11).
    pub threads: usize,
}

impl WorkerProfile {
    /// Profile for a worker advertising `max_qubits`; everything else at
    /// its default (idle, noiseless, serial backend).
    pub fn new(max_qubits: usize) -> WorkerProfile {
        WorkerProfile { max_qubits, cru: 0.0, noise: 0.0, threads: 1 }
    }

    /// Initial CRU sample.
    pub fn cru(mut self, cru: f64) -> WorkerProfile {
        self.cru = cru;
        self
    }

    /// Reported noise estimate (extension §10).
    pub fn noise(mut self, noise: f64) -> WorkerProfile {
        self.noise = noise;
        self
    }

    /// Execution thread budget (clamped to >= 1 at registration).
    pub fn threads(mut self, threads: usize) -> WorkerProfile {
        self.threads = threads;
        self
    }
}

impl Default for WorkerProfile {
    fn default() -> WorkerProfile {
        WorkerProfile::new(5)
    }
}

/// Per-worker runtime state.
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub id: WorkerId,
    /// `MR_{w_i}` — maximum qubits, reported by the worker itself.
    pub max_qubits: usize,
    /// `OR_{w_i}` — occupied qubits (sum of active circuit demands).
    pub occupied: usize,
    /// `CRU_{w_i}(t)` — latest classical resource usage sample in [0, 1].
    pub cru: f64,
    /// Clock time of the last heartbeat (or registration).
    pub last_heartbeat: f64,
    /// `AC_{w_i}` — active circuits with their demands.
    pub active: BTreeMap<JobId, usize>,
    /// Estimated gate-error level of this worker in [0, 1] (extension:
    /// the paper's future-work noise-aware scheduling; 0 = ideal).
    pub noise: f64,
    /// Execution thread budget reported at registration (>= 1): how many
    /// circuits the worker's backend runs concurrently. The manager
    /// sizes dispatch batches by it (DESIGN.md §11).
    pub threads: usize,
}

impl WorkerState {
    /// `AR_{w_i} = MR_{w_i} - OR_{w_i}` (Algorithm 2 line 10).
    pub fn available(&self) -> usize {
        self.max_qubits.saturating_sub(self.occupied)
    }
}

/// The active worker set `W` with liveness tracking.
#[derive(Debug)]
pub struct Registry {
    workers: BTreeMap<WorkerId, WorkerState>,
    next_id: WorkerId,
    /// Worker-id allocation stride (id striping for sharded managers,
    /// DESIGN.md §18): shard `off` of `stride` hands out ids congruent
    /// to `off` modulo `stride`. 1 — the default — is unsharded.
    id_stride: u64,
    /// Heartbeat period in seconds (paper: 5 s, configurable).
    pub heartbeat_period: f64,
    /// Heartbeats missed before eviction (paper: 3).
    pub max_missed: u32,
}

impl Registry {
    /// Empty registry with the given heartbeat period (seconds).
    pub fn new(heartbeat_period: f64) -> Registry {
        Registry {
            workers: BTreeMap::new(),
            next_id: 1,
            id_stride: 1,
            heartbeat_period,
            max_missed: 3,
        }
    }

    /// Stripe worker-id allocation: ids become congruent to `off`
    /// modulo `stride`. Call before any registration (the manager does,
    /// at build time); ids already handed out are not re-aligned.
    pub fn set_stripe(&mut self, off: u64, stride: u64) {
        let stride = stride.max(1);
        let off = off % stride;
        self.id_stride = stride;
        if stride > 1 {
            self.next_id = self.next_id
                + (off % stride + stride - self.next_id % stride) % stride;
        }
    }

    /// New Worker Registration (Algorithm 2 lines 2-6): OR = 0,
    /// AR = MR, record CRU.
    pub fn register(&mut self, max_qubits: usize, cru: f64, now: f64) -> WorkerId {
        self.register_profile(&WorkerProfile::new(max_qubits).cru(cru), now)
    }

    /// Registration with a reported noise estimate (extension §10).
    pub fn register_with_noise(
        &mut self,
        max_qubits: usize,
        cru: f64,
        noise: f64,
        now: f64,
    ) -> WorkerId {
        self.register_profile(&WorkerProfile::new(max_qubits).cru(cru).noise(noise), now)
    }

    /// Registration from a typed [`WorkerProfile`] (the thread budget is
    /// clamped to >= 1).
    pub fn register_profile(&mut self, profile: &WorkerProfile, now: f64) -> WorkerId {
        let id = self.next_id;
        self.next_id += self.id_stride;
        let threads = profile.threads.max(1);
        self.workers.insert(
            id,
            WorkerState {
                id,
                max_qubits: profile.max_qubits,
                occupied: 0,
                cru: profile.cru,
                last_heartbeat: now,
                active: BTreeMap::new(),
                noise: profile.noise,
                threads,
            },
        );
        crate::log_info!(
            "registry",
            "worker w{id} joined (MR={}, CRU={:.2}, threads={threads})",
            profile.max_qubits,
            profile.cru
        );
        id
    }

    /// Periodic heartbeat — liveness + CRU refresh.
    ///
    /// Used by the live manager, whose own reserve/release bookkeeping is
    /// authoritative for `OR` (a worker's self-report can race with
    /// circuits in the RPC pipe).
    pub fn heartbeat(&mut self, id: WorkerId, cru: f64, now: f64) -> Result<(), DqError> {
        let w = self
            .workers
            .get_mut(&id)
            .ok_or_else(|| DqError::WorkerLost(format!("unknown worker w{id}")))?;
        w.cru = cru;
        w.last_heartbeat = now;
        Ok(())
    }

    /// Paper-faithful periodic heartbeat (Algorithm 2 lines 7-11):
    /// recompute `OR` from the reported active set, refresh CRU and
    /// liveness. Used by the discrete-event simulation, where worker
    /// reports cannot race with dispatches.
    pub fn heartbeat_recompute(
        &mut self,
        id: WorkerId,
        active: &[(JobId, usize)],
        cru: f64,
        now: f64,
    ) -> Result<(), DqError> {
        let w = self
            .workers
            .get_mut(&id)
            .ok_or_else(|| DqError::WorkerLost(format!("unknown worker w{id}")))?;
        w.active = active.iter().copied().collect();
        w.occupied = w.active.values().sum();
        w.cru = cru;
        w.last_heartbeat = now;
        Ok(())
    }

    /// Eviction (Algorithm 2 lines 12-13): drop workers whose heartbeat
    /// is older than `max_missed` periods; returns (worker, orphaned jobs)
    /// so in-flight circuits can be re-queued.
    pub fn evict_stale(&mut self, now: f64) -> Vec<(WorkerId, Vec<JobId>)> {
        let deadline = self.max_missed as f64 * self.heartbeat_period;
        let stale: Vec<WorkerId> = self
            .workers
            .values()
            .filter(|w| now - w.last_heartbeat > deadline)
            .map(|w| w.id)
            .collect();
        stale
            .into_iter()
            .map(|id| {
                let w = self.workers.remove(&id).expect("stale id present");
                crate::log_warn!(
                    "registry",
                    "worker w{id} lost ({} active circuits re-queued)",
                    w.active.len()
                );
                (id, w.active.keys().copied().collect())
            })
            .collect()
    }

    /// Reserve capacity for an assignment (manager-side OR accounting
    /// between heartbeats).
    pub fn reserve(&mut self, id: WorkerId, job: JobId, demand: usize) -> Result<(), DqError> {
        let w = self
            .workers
            .get_mut(&id)
            .ok_or_else(|| DqError::WorkerLost(format!("unknown worker w{id}")))?;
        if w.available() < demand {
            return Err(DqError::Unschedulable(format!(
                "worker w{id} has {} available qubits, need {demand}",
                w.available()
            )));
        }
        w.occupied += demand;
        w.active.insert(job, demand);
        Ok(())
    }

    /// Move an active reservation from one worker to another (work
    /// stealing). Checks both ends first and mutates only when the whole
    /// move can succeed, so a failure leaves no side effects; the
    /// manager holds the registry lock across the call, which is what
    /// makes the release-on-victim + reserve-on-thief pair atomic with
    /// respect to eviction and assignment (DESIGN.md §14).
    pub fn transfer(
        &mut self,
        from: WorkerId,
        to: WorkerId,
        job: JobId,
        demand: usize,
    ) -> Result<(), DqError> {
        let donor_demand = self
            .workers
            .get(&from)
            .and_then(|w| w.active.get(&job).copied())
            .ok_or_else(|| {
                DqError::WorkerLost(format!("no reservation for job {job} on worker w{from}"))
            })?;
        if donor_demand != demand {
            return Err(DqError::Protocol(format!(
                "reservation {job} demand mismatch: holds {donor_demand}, caller says {demand}"
            )));
        }
        let thief = self
            .workers
            .get(&to)
            .ok_or_else(|| DqError::WorkerLost(format!("unknown worker w{to}")))?;
        if thief.available() < demand {
            return Err(DqError::Unschedulable(format!(
                "worker w{to} has {} available qubits, need {demand}",
                thief.available()
            )));
        }
        self.release(from, job);
        self.reserve(to, job, demand).expect("transfer capacity checked");
        Ok(())
    }

    /// Release capacity when a circuit completes.
    pub fn release(&mut self, id: WorkerId, job: JobId) {
        if let Some(w) = self.workers.get_mut(&id) {
            if let Some(demand) = w.active.remove(&job) {
                w.occupied = w.occupied.saturating_sub(demand);
            }
        }
    }

    /// Look up one worker's state.
    pub fn get(&self, id: WorkerId) -> Option<&WorkerState> {
        self.workers.get(&id)
    }

    /// Iterate over all registered workers (ascending id).
    pub fn workers(&self) -> impl Iterator<Item = &WorkerState> {
        self.workers.values()
    }

    /// Number of registered workers.
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    /// True when no workers are registered.
    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Total available qubits across the system (for backpressure hints).
    pub fn total_available(&self) -> usize {
        self.workers.values().map(|w| w.available()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_initializes_per_paper() {
        let mut r = Registry::new(5.0);
        let id = r.register(10, 0.3, 0.0);
        let w = r.get(id).unwrap();
        assert_eq!(w.occupied, 0); // OR = 0
        assert_eq!(w.available(), 10); // AR = MR
        assert_eq!(w.cru, 0.3);
    }

    #[test]
    fn ids_are_sequential() {
        let mut r = Registry::new(5.0);
        assert_eq!(r.register(5, 0.0, 0.0), 1);
        assert_eq!(r.register(7, 0.0, 0.0), 2);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn heartbeat_recomputes_occupancy() {
        let mut r = Registry::new(5.0);
        let id = r.register(10, 0.0, 0.0);
        r.heartbeat_recompute(id, &[(100, 5), (101, 3)], 0.7, 4.0).unwrap();
        let w = r.get(id).unwrap();
        assert_eq!(w.occupied, 8);
        assert_eq!(w.available(), 2);
        assert_eq!(w.cru, 0.7);
        assert_eq!(w.last_heartbeat, 4.0);
    }

    #[test]
    fn heartbeat_unknown_worker_errors() {
        let mut r = Registry::new(5.0);
        assert!(r.heartbeat(99, 0.0, 0.0).is_err());
        assert!(r.heartbeat_recompute(99, &[], 0.0, 0.0).is_err());
    }

    #[test]
    fn eviction_after_three_missed_periods() {
        let mut r = Registry::new(5.0);
        let a = r.register(5, 0.0, 0.0);
        let b = r.register(7, 0.0, 0.0);
        r.reserve(a, 42, 5).unwrap();
        // at t=14.9 nothing is stale (3 * 5 = 15s deadline)
        assert!(r.evict_stale(14.9).is_empty());
        // b heartbeats, a does not
        r.heartbeat(b, 0.1, 14.0).unwrap();
        let evicted = r.evict_stale(15.1);
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].0, a);
        assert_eq!(evicted[0].1, vec![42]); // orphaned job returned
        assert!(r.get(a).is_none());
        assert!(r.get(b).is_some());
    }

    #[test]
    fn reserve_release_cycle() {
        let mut r = Registry::new(5.0);
        let id = r.register(10, 0.0, 0.0);
        r.reserve(id, 1, 7).unwrap();
        assert_eq!(r.get(id).unwrap().available(), 3);
        // second reservation exceeding AR fails
        assert!(r.reserve(id, 2, 5).is_err());
        r.release(id, 1);
        assert_eq!(r.get(id).unwrap().available(), 10);
        // double release is harmless
        r.release(id, 1);
        assert_eq!(r.get(id).unwrap().available(), 10);
    }

    #[test]
    fn transfer_moves_reservation_atomically() {
        let mut r = Registry::new(5.0);
        let a = r.register(10, 0.0, 0.0);
        let b = r.register(10, 0.0, 0.0);
        r.reserve(a, 7, 5).unwrap();
        r.transfer(a, b, 7, 5).unwrap();
        assert_eq!(r.get(a).unwrap().available(), 10);
        assert_eq!(r.get(b).unwrap().available(), 5);
        assert!(r.get(b).unwrap().active.contains_key(&7));
        assert!(!r.get(a).unwrap().active.contains_key(&7));
        // releasing on the thief frees its capacity
        r.release(b, 7);
        assert_eq!(r.get(b).unwrap().available(), 10);
    }

    #[test]
    fn transfer_failures_leave_no_side_effects() {
        let mut r = Registry::new(5.0);
        let a = r.register(10, 0.0, 0.0);
        let b = r.register(5, 0.0, 0.0);
        r.reserve(a, 1, 7).unwrap();
        r.reserve(b, 2, 3).unwrap();
        // thief lacks capacity: 5 - 3 = 2 < 7
        assert!(matches!(r.transfer(a, b, 1, 7), Err(DqError::Unschedulable(_))));
        assert_eq!(r.get(a).unwrap().available(), 3);
        assert_eq!(r.get(b).unwrap().available(), 2);
        // unknown reservation / evicted donor
        assert!(matches!(r.transfer(a, b, 99, 3), Err(DqError::WorkerLost(_))));
        // demand mismatch is a protocol error
        assert!(matches!(r.transfer(a, b, 1, 6), Err(DqError::Protocol(_))));
        // unknown thief
        assert!(matches!(r.transfer(a, 42, 1, 7), Err(DqError::WorkerLost(_))));
        assert_eq!(r.get(a).unwrap().available(), 3, "failed transfers must not mutate");
    }

    #[test]
    fn thread_budget_recorded_and_clamped() {
        let mut r = Registry::new(5.0);
        let a = r.register(5, 0.0, 0.0);
        assert_eq!(r.get(a).unwrap().threads, 1); // default budget
        let b = r.register_profile(&WorkerProfile::new(20).threads(4), 0.0);
        assert_eq!(r.get(b).unwrap().threads, 4);
        let c = r.register_profile(&WorkerProfile::new(5).threads(0), 0.0);
        assert_eq!(r.get(c).unwrap().threads, 1); // clamped
    }

    #[test]
    fn profile_builder_defaults() {
        let p = WorkerProfile::default();
        assert_eq!((p.max_qubits, p.cru, p.noise, p.threads), (5, 0.0, 0.0, 1));
        let p = WorkerProfile::new(7).noise(0.1);
        assert_eq!((p.max_qubits, p.noise, p.threads), (7, 0.1, 1));
    }

    #[test]
    fn unknown_worker_is_worker_lost() {
        let mut r = Registry::new(5.0);
        assert!(matches!(r.heartbeat(9, 0.0, 0.0), Err(DqError::WorkerLost(_))));
        assert!(matches!(r.reserve(9, 1, 5), Err(DqError::WorkerLost(_))));
    }

    #[test]
    fn total_available_sums() {
        let mut r = Registry::new(5.0);
        let a = r.register(5, 0.0, 0.0);
        r.register(20, 0.0, 0.0);
        r.reserve(a, 9, 5).unwrap();
        assert_eq!(r.total_available(), 20);
    }
}
