//! Tenant-fair admission: the co-Manager's pending queue, sharded per
//! client and drained by weighted round-robin.
//!
//! The original manager funneled every tenant through one global FIFO,
//! so a tenant flooding 10k circuits made every other tenant wait behind
//! the whole backlog (head-of-line starvation — exactly the single-tenant
//! pathology the paper's Fig. 6 argues against). [`AdmissionQueue`] keeps
//! one sub-queue per client id and serves them in weighted round-robin
//! order: each assignment takes one *batch* from the tenant at the
//! cursor, tenants with weight `w` get `w` consecutive batches per
//! cycle, and a tenant's backlog depth never delays another tenant's
//! head-of-line circuit (DESIGN.md §13).
//!
//! Queue-wait accounting rides along: every job is stamped on admission
//! and [`AdmissionQueue::take_batch`] hands the stamps out with the
//! jobs. The manager carries them inside the dispatch batch and measures
//! the wait only when the batch reaches a worker channel, so the
//! accounting covers outbox residency and survives a steal — a batch
//! that waits in a stalled worker's outbox and is then stolen by a
//! sibling still charges its full queue time to the owning tenant
//! (DESIGN.md §14).

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use super::job::CircuitJob;
use crate::circuit::QuClassiConfig;

/// Default weighted-round-robin weight (batches per service cycle).
pub const DEFAULT_WEIGHT: u32 = 1;

/// One pending circuit plus its admission timestamp.
#[derive(Debug, Clone)]
struct QueuedJob {
    job: CircuitJob,
    enqueued: Instant,
}

/// One tenant's sub-queue.
#[derive(Debug, Default)]
struct TenantQueue {
    jobs: VecDeque<QueuedJob>,
    /// WRR weight: batches this tenant may take per service cycle.
    weight: u32,
    /// Batches taken in the current service cycle.
    served: u32,
}

/// The sharded pending queue. Not internally synchronized — the manager
/// wraps it in the mutex that `work_cv`/`space_cv` pair with, exactly
/// where the single `VecDeque` used to live (lock order unchanged).
#[derive(Debug, Default)]
pub struct AdmissionQueue {
    tenants: HashMap<u64, TenantQueue>,
    /// Clients with a non-empty sub-queue, in service order; the front is
    /// the WRR cursor.
    rr: VecDeque<u64>,
    /// Persisted weights for currently-empty tenants (set_weight before
    /// first submit, or between banks).
    weights: HashMap<u64, u32>,
    /// Total queued circuits across all tenants.
    len: usize,
}

impl AdmissionQueue {
    /// Empty queue.
    pub fn new() -> AdmissionQueue {
        AdmissionQueue::default()
    }

    /// Circuits pending across all tenants.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no circuits are pending.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set a tenant's WRR weight (clamped to >= 1). Takes effect from the
    /// tenant's next service cycle. Setting a tenant back to the default
    /// weight *releases* its persisted entry, so per-tenant weight state
    /// cannot grow unboundedly with client churn — non-default weights
    /// are deliberate operator policy and persist until reset.
    pub fn set_weight(&mut self, client: u64, weight: u32) {
        let w = weight.max(1);
        if w == DEFAULT_WEIGHT {
            self.weights.remove(&client);
        } else {
            self.weights.insert(client, w);
        }
        if let Some(tq) = self.tenants.get_mut(&client) {
            tq.weight = w;
        }
    }

    /// Every non-default tenant weight, sorted by client id (journal
    /// snapshots persist WRR policy through this; default-weight tenants
    /// have no entry by the release invariant of [`Self::set_weight`]).
    pub fn weights(&self) -> Vec<(u64, u32)> {
        let mut v: Vec<(u64, u32)> = self.weights.iter().map(|(&c, &w)| (c, w)).collect();
        v.sort_unstable();
        v
    }

    /// Append a tenant's jobs (one submitted bank, already stamped with
    /// the client id) to its sub-queue.
    pub fn push_bank(&mut self, client: u64, jobs: Vec<CircuitJob>) {
        if jobs.is_empty() {
            return;
        }
        let now = Instant::now();
        let was_empty = self.tenants.get(&client).map_or(true, |t| t.jobs.is_empty());
        let weight = self.weights.get(&client).copied().unwrap_or(DEFAULT_WEIGHT);
        let tq = self.tenants.entry(client).or_insert_with(|| TenantQueue {
            jobs: VecDeque::new(),
            weight,
            served: 0,
        });
        self.len += jobs.len();
        for job in jobs {
            tq.jobs.push_back(QueuedJob { job, enqueued: now });
        }
        if was_empty {
            self.rr.push_back(client);
        }
    }

    /// Re-queue jobs at the *front* of their owners' sub-queues (eviction
    /// and failed-dispatch recovery): relative order within each tenant
    /// is preserved, and the wait clock restarts at re-queue time.
    pub fn requeue_front(&mut self, jobs: Vec<CircuitJob>) {
        let now = Instant::now();
        for job in jobs.into_iter().rev() {
            let client = job.client;
            let was_empty = self.tenants.get(&client).map_or(true, |t| t.jobs.is_empty());
            let weight = self.weights.get(&client).copied().unwrap_or(DEFAULT_WEIGHT);
            let tq = self.tenants.entry(client).or_insert_with(|| TenantQueue {
                jobs: VecDeque::new(),
                weight,
                served: 0,
            });
            tq.jobs.push_front(QueuedJob { job, enqueued: now });
            self.len += 1;
            if was_empty {
                self.rr.push_back(client);
            }
        }
    }

    /// Clients in current service order: the WRR cursor first. The
    /// assigner probes heads in this order, so a tenant whose head cannot
    /// be placed right now never blocks the tenants behind it.
    pub fn service_order(&self) -> Vec<u64> {
        self.rr.iter().copied().collect()
    }

    /// This tenant's head-of-line circuit.
    pub fn head_of(&self, client: u64) -> Option<&CircuitJob> {
        let tq = self.tenants.get(&client)?;
        tq.jobs.front().map(|qj| &qj.job)
    }

    /// Take up to `limit` same-`config` circuits from this tenant's queue
    /// head and charge one WRR credit: a tenant that exhausted its weight
    /// (or emptied its queue) rotates to the back of the service order.
    /// Returns the jobs plus their admission stamps (the wait itself is
    /// measured by the manager when the batch reaches a worker channel,
    /// so it survives outbox residency and steals).
    ///
    /// The contiguous same-config prefix pops directly (the common,
    /// homogeneous case is O(batch)); only when the tenant interleaves
    /// configs does one drain/partition pass scan its sub-queue — O(n) in
    /// *that tenant's* backlog, never in the global queue (see
    /// `benches/micro_queue.rs` for the O(n²) packer this replaced).
    pub fn take_batch(
        &mut self,
        client: u64,
        config: QuClassiConfig,
        limit: usize,
    ) -> (Vec<CircuitJob>, Vec<Instant>) {
        let Some(tq) = self.tenants.get_mut(&client) else {
            return (Vec::new(), Vec::new());
        };
        let limit = limit.max(1);
        let mut taken: Vec<QueuedJob> = Vec::with_capacity(limit.min(tq.jobs.len()));
        while taken.len() < limit && tq.jobs.front().is_some_and(|qj| qj.job.config == config) {
            taken.push(tq.jobs.pop_front().unwrap());
        }
        if taken.len() < limit && tq.jobs.iter().any(|qj| qj.job.config == config) {
            let mut rest = VecDeque::with_capacity(tq.jobs.len());
            while let Some(qj) = tq.jobs.pop_front() {
                if taken.len() < limit && qj.job.config == config {
                    taken.push(qj);
                } else {
                    rest.push_back(qj);
                }
            }
            tq.jobs = rest;
        }
        self.len -= taken.len();

        // Charge the WRR credit and advance the cursor when this tenant's
        // cycle allowance is spent or its queue drained.
        tq.served += 1;
        let exhausted = tq.served >= tq.weight.max(1);
        let drained = tq.jobs.is_empty();
        if drained {
            self.tenants.remove(&client);
            self.rr.retain(|&c| c != client);
        } else if exhausted {
            tq.served = 0;
            if self.rr.front() == Some(&client) {
                self.rr.rotate_left(1);
            } else {
                // client served out of cursor order: move it to the back
                self.rr.retain(|&c| c != client);
                self.rr.push_back(client);
            }
        }

        let mut jobs = Vec::with_capacity(taken.len());
        let mut stamps = Vec::with_capacity(taken.len());
        for qj in taken {
            stamps.push(qj.enqueued);
            jobs.push(qj.job);
        }
        (jobs, stamps)
    }

    /// Every queued circuit, in no particular order (journal compaction
    /// snapshots the pending set through this without draining it).
    pub fn jobs(&self) -> impl Iterator<Item = &CircuitJob> {
        self.tenants.values().flat_map(|tq| tq.jobs.iter().map(|qj| &qj.job))
    }

    /// Remove every queued circuit of `bank` (cancel / unschedulable
    /// paths); returns how many were drained plus the owning tenant (a
    /// bank's circuits all belong to one client), so the manager can
    /// credit the tenant's `lost` counter and retention pruning still
    /// recognizes cancel-heavy churn tenants as quiescent.
    pub fn drain_bank(&mut self, bank: u64) -> (usize, Option<u64>) {
        let mut drained = 0;
        let mut owner = None;
        let mut emptied: Vec<u64> = Vec::new();
        for (&client, tq) in self.tenants.iter_mut() {
            let before = tq.jobs.len();
            tq.jobs.retain(|qj| qj.job.bank != bank);
            if before > tq.jobs.len() {
                drained += before - tq.jobs.len();
                owner = Some(client);
            }
            if tq.jobs.is_empty() {
                emptied.push(client);
            }
        }
        for client in emptied {
            self.tenants.remove(&client);
            self.rr.retain(|&c| c != client);
        }
        self.len -= drained;
        (drained, owner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(client: u64, bank: u64, id: u64, config: QuClassiConfig) -> CircuitJob {
        CircuitJob {
            id,
            client,
            bank,
            index: id as usize,
            config,
            thetas: vec![0.0; config.n_params()],
            data: vec![0.0; config.n_features()],
        }
    }

    fn cfg5() -> QuClassiConfig {
        QuClassiConfig::new(5, 1).unwrap()
    }

    #[test]
    fn round_robin_interleaves_tenants() {
        let mut q = AdmissionQueue::new();
        let c = cfg5();
        q.push_bank(1, (0..4).map(|i| job(1, 1, i, c)).collect());
        q.push_bank(2, (10..14).map(|i| job(2, 2, i, c)).collect());
        assert_eq!(q.len(), 8);
        // batches of 2 alternate between tenants
        let order: Vec<u64> = (0..4)
            .map(|_| {
                let client = q.service_order()[0];
                let (jobs, waits) = q.take_batch(client, c, 2);
                assert_eq!(jobs.len(), 2);
                assert_eq!(waits.len(), 2);
                client
            })
            .collect();
        assert_eq!(order, vec![1, 2, 1, 2]);
        assert!(q.is_empty());
    }

    #[test]
    fn weight_gives_consecutive_batches() {
        let mut q = AdmissionQueue::new();
        let c = cfg5();
        q.set_weight(1, 2);
        q.push_bank(1, (0..6).map(|i| job(1, 1, i, c)).collect());
        q.push_bank(2, (10..16).map(|i| job(2, 2, i, c)).collect());
        let order: Vec<u64> = (0..6)
            .map(|_| {
                let client = q.service_order()[0];
                q.take_batch(client, c, 2);
                client
            })
            .collect();
        // tenant 1 (weight 2) takes two batches per cycle, tenant 2 one;
        // tenant 1 drains at its third batch, then tenant 2 finishes
        assert_eq!(order, vec![1, 1, 2, 1, 2, 2]);
    }

    #[test]
    fn take_batch_is_order_preserving_across_configs() {
        // Mixed-config tenant: same-config jobs pack in order, the
        // remainder keeps its relative order (the old manager pack_batch
        // invariant, now per tenant).
        let ca = cfg5();
        let cb = QuClassiConfig::new(7, 1).unwrap();
        let mut q = AdmissionQueue::new();
        q.push_bank(
            1,
            vec![job(1, 1, 1, ca), job(1, 1, 2, cb), job(1, 1, 3, ca), job(1, 1, 4, cb), job(1, 1, 5, ca)],
        );
        let (jobs, _) = q.take_batch(1, ca, 2);
        assert_eq!(jobs.iter().map(|j| j.id).collect::<Vec<_>>(), vec![1, 3]);
        let mut rest = Vec::new();
        while let Some(h) = q.head_of(1) {
            let c = h.config;
            let (js, _) = q.take_batch(1, c, 1);
            rest.extend(js.into_iter().map(|j| j.id));
        }
        assert_eq!(rest, vec![2, 4, 5]);
        assert!(q.is_empty());
    }

    #[test]
    fn drain_bank_removes_only_that_bank() {
        let mut q = AdmissionQueue::new();
        let c = cfg5();
        q.push_bank(1, (0..3).map(|i| job(1, 1, i, c)).collect());
        q.push_bank(1, (10..12).map(|i| job(1, 2, i, c)).collect());
        q.push_bank(2, (20..22).map(|i| job(2, 3, i, c)).collect());
        assert_eq!(q.drain_bank(1), (3, Some(1)));
        assert_eq!(q.len(), 4);
        assert_eq!(q.head_of(1).unwrap().bank, 2);
        assert_eq!(q.drain_bank(2), (2, Some(1)));
        assert_eq!(q.drain_bank(2), (0, None)); // idempotent
        // tenant 1 fully drained: dropped from the service order
        assert_eq!(q.service_order(), vec![2]);
    }

    #[test]
    fn resetting_weight_to_default_releases_state() {
        let mut q = AdmissionQueue::new();
        q.set_weight(1, 4);
        q.set_weight(2, 7);
        assert_eq!(q.weights.len(), 2);
        q.set_weight(1, DEFAULT_WEIGHT);
        assert_eq!(q.weights.len(), 1);
        q.set_weight(2, 0); // clamps to the default -> also released
        assert!(q.weights.is_empty());
    }

    #[test]
    fn requeue_front_restores_head_position() {
        let mut q = AdmissionQueue::new();
        let c = cfg5();
        q.push_bank(1, (0..4).map(|i| job(1, 1, i, c)).collect());
        let (taken, _) = q.take_batch(1, c, 2);
        assert_eq!(taken.iter().map(|j| j.id).collect::<Vec<_>>(), vec![0, 1]);
        q.requeue_front(taken);
        // requeued jobs are back at the head, in their original order
        let (again, _) = q.take_batch(1, c, 4);
        assert_eq!(again.iter().map(|j| j.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(q.is_empty());
        // requeue into an empty queue re-registers the tenant
        q.requeue_front(again);
        assert_eq!(q.len(), 4);
        assert_eq!(q.service_order(), vec![1]);
    }

    #[test]
    fn empty_tenant_take_is_empty() {
        let mut q = AdmissionQueue::new();
        let (jobs, waits) = q.take_batch(9, cfg5(), 4);
        assert!(jobs.is_empty() && waits.is_empty());
    }
}
