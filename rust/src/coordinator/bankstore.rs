//! Result collection: banks of circuits submitted by clients, filled in
//! as workers complete them, observed through [`BankStatus`] snapshots,
//! awaited (or cancelled) by clients holding a
//! [`super::session::BankHandle`].

use std::collections::{HashMap, HashSet};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::error::DqError;

/// One submitted bank awaiting its fidelities.
#[derive(Debug)]
struct BankState {
    fids: Vec<Option<f32>>,
    remaining: usize,
    failed: Option<DqError>,
    /// Owning tenant (journal snapshots re-admit under this id).
    client: u64,
    /// Circuit width, carried for journal snapshots.
    qubits: u32,
    /// Variational layers, carried for journal snapshots.
    layers: u32,
    /// True when this bank was restored by `Manager::recover` rather
    /// than submitted in this incarnation (surfaced via [`BankStatus`]).
    recovered: bool,
}

/// One bank lifecycle event, streamed to registered [`BankWatcher`]s
/// (the payload behind the binary plane's `subscribe_bank` pushes).
#[derive(Debug, Clone, PartialEq)]
pub enum BankEvent {
    /// Circuit `index` finished with fidelity `fid`; `remaining`
    /// circuits are still outstanding after it.
    Fid { index: usize, fid: f32, remaining: usize },
    /// Every circuit completed; the watcher is deregistered.
    Done,
    /// The bank failed; the watcher is deregistered.
    Failed(DqError),
    /// The bank was cancelled; the watcher is deregistered.
    Cancelled,
}

/// A bank progress observer. Invoked **under the store lock**, so a
/// watcher must be cheap and must never call back into the store — the
/// push plane's watchers only append an encoded frame to a
/// per-connection outbound queue.
pub type BankWatcher = Box<dyn Fn(&BankEvent) + Send>;

/// The store's contents behind one lock: resident banks plus the ids of
/// every bank that was ever cancelled. Cancellation must outlive the
/// bank's residency — in-flight results can arrive, dispatches can fail,
/// and waiters can show up after the tombstone is garbage-collected, and
/// all of them must still observe "cancelled" (discard / no requeue /
/// `DqError::Cancelled`), never a resurrected bank or a GC-timing-
/// dependent `Protocol` error. The set costs 8 bytes per cancelled bank
/// for the store's lifetime.
#[derive(Default)]
struct Store {
    banks: HashMap<u64, BankState>,
    cancelled: HashSet<u64>,
    watchers: HashMap<u64, Vec<BankWatcher>>,
}

impl std::fmt::Debug for Store {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Store")
            .field("banks", &self.banks)
            .field("cancelled", &self.cancelled)
            .field("watchers", &self.watchers.len())
            .finish()
    }
}

impl Store {
    /// Fire an event at a bank's watchers (under the store lock).
    fn notify_watchers(&self, bank: u64, ev: &BankEvent) {
        if let Some(ws) = self.watchers.get(&bank) {
            for w in ws {
                w(ev);
            }
        }
    }

    /// Fire a terminal event and drop the bank's watchers.
    fn close_watchers(&mut self, bank: u64, ev: &BankEvent) {
        if let Some(ws) = self.watchers.remove(&bank) {
            for w in ws {
                w(ev);
            }
        }
    }
}

/// Point-in-time snapshot of a bank's progress (the `try_poll` payload).
#[derive(Debug, Clone, PartialEq)]
pub struct BankStatus {
    /// True while results are still outstanding (and the bank has neither
    /// failed nor been cancelled).
    pub pending: bool,
    /// Circuits completed so far.
    pub completed: usize,
    /// Circuits in the bank.
    pub total: usize,
    /// Per-circuit completion: `Some(fid)` once circuit `i` finished.
    /// Lets a training loop stream partial fidelities before the bank
    /// closes.
    pub partial_fids: Vec<Option<f32>>,
    /// True when the bank was replayed from the journal by
    /// `Manager::recover` — sessions can tell a replayed bank (whose
    /// in-flight work may have been failed with `WorkerLost`) from one
    /// submitted to the current manager incarnation.
    pub recovered: bool,
}

/// One resident bank as captured for a journal snapshot
/// (compaction); `None` entries in `fids` are resolved to
/// pending/in-flight by the manager, which knows where each
/// outstanding circuit currently lives.
#[derive(Debug, Clone, PartialEq)]
pub struct BankSnap {
    /// Bank id.
    pub bank: u64,
    /// Owning tenant.
    pub client: u64,
    /// Circuit width.
    pub qubits: u32,
    /// Variational layers.
    pub layers: u32,
    /// True when this bank was itself restored by a recovery.
    pub recovered: bool,
    /// True when the bank is cancelled (resident only as a tombstone).
    pub cancelled: bool,
    /// Bank-level failure, if any.
    pub failed: Option<DqError>,
    /// Per-circuit completion.
    pub fids: Vec<Option<f32>>,
}

/// Thread-safe store of in-flight banks.
#[derive(Debug, Default)]
pub struct BankStore {
    inner: Mutex<Store>,
    cv: Condvar,
}

impl BankStore {
    /// Empty store.
    pub fn new() -> BankStore {
        BankStore::default()
    }

    /// Open a new bank expecting `size` results.
    pub fn open(&self, bank: u64, size: usize) {
        self.open_for(bank, size, 0, 0, 0);
    }

    /// Open a new bank carrying its tenant and circuit shape, so a
    /// journal snapshot taken later can re-describe it faithfully.
    pub fn open_for(&self, bank: u64, size: usize, client: u64, qubits: u32, layers: u32) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        let prev = g.banks.insert(
            bank,
            BankState {
                fids: vec![None; size],
                remaining: size,
                failed: None,
                client,
                qubits,
                layers,
                recovered: false,
            },
        );
        debug_assert!(prev.is_none(), "bank id reuse");
    }

    /// Re-create a bank from journal replay: already-completed circuits
    /// keep their fidelities, a replayed failure is preserved, and the
    /// bank is flagged `recovered`. Unlike [`BankStore::open_for`] this
    /// may re-create a bank whose results are already all present (a
    /// completed-but-unconsumed bank surviving a restart) — waiters are
    /// notified so such a bank resolves immediately.
    pub fn restore(
        &self,
        bank: u64,
        fids: Vec<Option<f32>>,
        client: u64,
        qubits: u32,
        layers: u32,
        failed: Option<DqError>,
    ) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        let remaining = fids.iter().filter(|f| f.is_none()).count();
        let prev = g.banks.insert(
            bank,
            BankState { fids, remaining, failed, client, qubits, layers, recovered: true },
        );
        debug_assert!(prev.is_none(), "bank id reuse during restore");
        drop(g);
        self.cv.notify_all();
    }

    /// Re-seed the cancelled-id tombstone set from journal replay. The
    /// ids survive compaction exactly as they survive GC (DESIGN.md
    /// §12): a late `try_poll`/`wait` after recovery still observes
    /// `Cancelled`, never `Protocol`.
    pub fn restore_cancelled<I: IntoIterator<Item = u64>>(&self, ids: I) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        g.cancelled.extend(ids);
    }

    /// Ids of resident banks still awaiting results (not failed, not
    /// cancelled) — the set `Manager::shutdown` sweeps into `Resolved`
    /// journal records so a clean shutdown + recover re-admits nothing.
    pub fn pending_banks(&self) -> Vec<u64> {
        let g = self.inner.lock().expect("bankstore poisoned");
        g.banks
            .iter()
            .filter(|(bank, b)| {
                b.remaining > 0 && b.failed.is_none() && !g.cancelled.contains(*bank)
            })
            .map(|(bank, _)| *bank)
            .collect()
    }

    /// Every resident bank, as journal-snapshot material.
    pub fn snapshot(&self) -> Vec<BankSnap> {
        let g = self.inner.lock().expect("bankstore poisoned");
        g.banks
            .iter()
            .map(|(&bank, b)| BankSnap {
                bank,
                client: b.client,
                qubits: b.qubits,
                layers: b.layers,
                recovered: b.recovered,
                cancelled: g.cancelled.contains(&bank),
                failed: b.failed.clone(),
                fids: b.fids.clone(),
            })
            .collect()
    }

    /// Every bank id ever cancelled (sorted, for deterministic snapshot
    /// encoding).
    pub fn cancelled_ids(&self) -> Vec<u64> {
        let g = self.inner.lock().expect("bankstore poisoned");
        let mut ids: Vec<u64> = g.cancelled.iter().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Record one completed circuit. Results for unknown or cancelled
    /// banks are discarded (discard-on-arrival).
    pub fn complete(&self, bank: u64, index: usize, fid: f32) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        if g.cancelled.contains(&bank) {
            return;
        }
        let remaining = {
            let Store { banks, watchers, .. } = &mut *g;
            match banks.get_mut(&bank) {
                Some(b) if b.fids[index].is_none() => {
                    b.fids[index] = Some(fid);
                    b.remaining -= 1;
                    if let Some(ws) = watchers.get(&bank) {
                        let ev = BankEvent::Fid { index, fid, remaining: b.remaining };
                        for w in ws {
                            w(&ev);
                        }
                    }
                    Some(b.remaining)
                }
                _ => None,
            }
        };
        if remaining == Some(0) {
            g.close_watchers(bank, &BankEvent::Done);
            self.cv.notify_all();
        }
    }

    /// Mark a whole bank as failed (e.g. unschedulable circuit, worker
    /// protocol violation); waiters observe the error. Never overrides a
    /// cancellation.
    pub fn fail(&self, bank: u64, reason: DqError) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        if g.cancelled.contains(&bank) {
            return;
        }
        let mut resident = false;
        if let Some(b) = g.banks.get_mut(&bank) {
            resident = true;
            if b.failed.is_none() {
                b.failed = Some(reason.clone());
            }
        }
        if resident {
            g.close_watchers(bank, &BankEvent::Failed(reason));
            self.cv.notify_all();
        }
    }

    /// Fail every bank still awaiting results (manager shutdown): blocked
    /// waiters wake with the reason instead of hanging until their wait
    /// timeout on work that can no longer arrive. Completed banks keep
    /// their results for late waiters; failed and cancelled banks keep
    /// their original outcome.
    pub fn fail_pending(&self, reason: DqError) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        let mut swept: Vec<u64> = Vec::new();
        {
            let Store { banks, cancelled, .. } = &mut *g;
            for (bank, b) in banks.iter_mut() {
                if b.remaining > 0 && b.failed.is_none() && !cancelled.contains(bank) {
                    b.failed = Some(reason.clone());
                    swept.push(*bank);
                }
            }
        }
        for bank in swept {
            g.close_watchers(bank, &BankEvent::Failed(reason.clone()));
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Cancel a bank: its id is recorded for the store's lifetime (so
    /// in-flight results are discarded on arrival and late waiters always
    /// observe `Cancelled`, even after the tombstone is GC'd) and the
    /// tombstone stays resident while results remain in flight. Returns
    /// true only on the first cancellation of a *resident* bank (false
    /// when the bank is unknown — already waited out — or already
    /// cancelled), so garbage ids from remote clients don't grow the set.
    pub fn cancel(&self, bank: u64) -> bool {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        if !g.banks.contains_key(&bank) {
            return false;
        }
        let first = g.cancelled.insert(bank);
        g.close_watchers(bank, &BankEvent::Cancelled);
        self.cv.notify_all();
        first
    }

    /// Block until the bank completes (or fails / is cancelled / times
    /// out); removes it.
    pub fn wait(&self, bank: u64, timeout: Duration) -> Result<Vec<f32>, DqError> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().expect("bankstore poisoned");
        loop {
            if g.cancelled.contains(&bank) {
                g.banks.remove(&bank);
                return Err(DqError::Cancelled(format!("bank {bank} cancelled")));
            }
            match g.banks.get(&bank) {
                None => return Err(DqError::Protocol(format!("unknown bank {bank}"))),
                Some(b) if b.failed.is_some() => {
                    let reason = b.failed.clone().unwrap();
                    g.banks.remove(&bank);
                    return Err(reason);
                }
                Some(b) if b.remaining == 0 => {
                    let b = g.banks.remove(&bank).unwrap();
                    return Ok(b.fids.into_iter().map(|f| f.unwrap()).collect());
                }
                Some(_) => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        // The bank stays resident: a timed-out wait can be
                        // retried, polled, or escalated to cancel().
                        return Err(DqError::Timeout(format!("bank {bank} timed out")));
                    }
                    let (guard, _t) = self
                        .cv
                        .wait_timeout(g, deadline - now)
                        .expect("bankstore poisoned");
                    g = guard;
                }
            }
        }
    }

    /// Register a progress watcher on a bank. Returns false for a bank
    /// the store has never seen (nothing to watch). Registration is
    /// race-free against concurrent results: fidelities that already
    /// landed are *replayed* to the watcher (in index order, with the
    /// historical `remaining` countdown), and a bank that is already
    /// terminal fires `Done`/`Failed`/`Cancelled` immediately instead
    /// of registering. The watcher runs under the store lock — see
    /// [`BankWatcher`].
    pub fn watch(&self, bank: u64, w: BankWatcher) -> bool {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        if g.cancelled.contains(&bank) {
            w(&BankEvent::Cancelled);
            return true;
        }
        let Some(b) = g.banks.get(&bank) else {
            return false;
        };
        let mut remaining = b.fids.len();
        for (index, f) in b.fids.iter().enumerate() {
            if let Some(fid) = f {
                remaining -= 1;
                // replay in index order with a strictly decreasing
                // countdown ending at the bank's current `remaining`
                w(&BankEvent::Fid { index, fid: *fid, remaining });
            }
        }
        if let Some(e) = &b.failed {
            w(&BankEvent::Failed(e.clone()));
        } else if b.remaining == 0 {
            w(&BankEvent::Done);
        } else {
            g.watchers.entry(bank).or_default().push(w);
        }
        true
    }

    /// Number of live watchers on a bank (test observability).
    #[doc(hidden)]
    pub fn watcher_count(&self, bank: u64) -> usize {
        let g = self.inner.lock().expect("bankstore poisoned");
        g.watchers.get(&bank).map_or(0, |ws| ws.len())
    }

    /// True when the bank has ever been cancelled (outlives residency —
    /// see [`BankStore::cancel`]).
    pub fn is_cancelled(&self, bank: u64) -> bool {
        let g = self.inner.lock().expect("bankstore poisoned");
        g.cancelled.contains(&bank)
    }

    /// Drop a bank's state outright (tombstone GC once its last in-flight
    /// result has resolved). The cancelled-id record survives; no-op for
    /// unknown banks.
    pub fn discard(&self, bank: u64) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        g.banks.remove(&bank);
        g.watchers.remove(&bank);
        // wake any waiter so it observes the removal instead of blocking
        self.cv.notify_all();
    }

    /// Snapshot of a bank's progress, if it is still resident.
    pub fn status(&self, bank: u64) -> Option<BankStatus> {
        let g = self.inner.lock().expect("bankstore poisoned");
        g.banks.get(&bank).map(|b| BankStatus {
            pending: b.remaining > 0 && b.failed.is_none() && !g.cancelled.contains(&bank),
            completed: b.fids.len() - b.remaining,
            total: b.fids.len(),
            partial_fids: b.fids.clone(),
            recovered: b.recovered,
        })
    }

    /// Progress of a bank: (done, total), if it exists.
    pub fn progress(&self, bank: u64) -> Option<(usize, usize)> {
        self.status(bank).map(|s| (s.completed, s.total))
    }

    /// Number of banks currently open.
    pub fn in_flight(&self) -> usize {
        self.inner.lock().expect("bankstore poisoned").banks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn complete_then_wait() {
        let s = BankStore::new();
        s.open(1, 3);
        s.complete(1, 0, 0.1);
        s.complete(1, 2, 0.3);
        s.complete(1, 1, 0.2);
        let fids = s.wait(1, Duration::from_millis(100)).unwrap();
        assert_eq!(fids, vec![0.1, 0.2, 0.3]);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn wait_blocks_until_completion() {
        let s = Arc::new(BankStore::new());
        s.open(5, 2);
        let s2 = s.clone();
        let t = std::thread::spawn(move || s2.wait(5, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(30));
        s.complete(5, 1, 0.9);
        s.complete(5, 0, 0.8);
        assert_eq!(t.join().unwrap().unwrap(), vec![0.8, 0.9]);
    }

    #[test]
    fn timeout_leaves_bank_resident_for_retry() {
        let s = BankStore::new();
        s.open(2, 1);
        let err = s.wait(2, Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, DqError::Timeout(_)), "{err}");
        // the bank survives the timeout: progress is still observable,
        // a straggler result still lands, and a retried wait succeeds
        assert_eq!(s.progress(2), Some((0, 1)));
        s.complete(2, 0, 0.4);
        assert_eq!(s.wait(2, Duration::from_millis(20)).unwrap(), vec![0.4]);
    }

    #[test]
    fn discard_drops_tombstone_but_cancellation_survives() {
        let s = BankStore::new();
        s.open(9, 2);
        s.cancel(9);
        assert!(s.is_cancelled(9));
        s.discard(9);
        assert_eq!(s.in_flight(), 0);
        s.discard(9); // idempotent
        // The cancelled record outlives the tombstone: a late waiter
        // observes Cancelled (never an "unknown bank" Protocol error
        // whose occurrence would depend on GC timing), late results are
        // still discarded, and a late requeue still sees is_cancelled.
        assert!(s.is_cancelled(9));
        assert!(matches!(s.wait(9, Duration::from_millis(10)), Err(DqError::Cancelled(_))));
        s.complete(9, 0, 0.5);
        assert_eq!(s.in_flight(), 0, "post-GC result must not resurrect the bank");
    }

    #[test]
    fn fail_pending_spares_completed_and_cancelled_banks() {
        let s = BankStore::new();
        s.open(11, 1); // completes before the failure sweep
        s.complete(11, 0, 0.7);
        s.open(12, 2); // still pending
        s.open(13, 1); // cancelled
        s.cancel(13);
        s.fail_pending(DqError::Cancelled("manager stopped".into()));
        assert_eq!(s.wait(11, Duration::from_millis(20)).unwrap(), vec![0.7]);
        let err = s.wait(12, Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, DqError::Cancelled(_)), "{err}");
        assert!(matches!(s.wait(13, Duration::from_millis(20)), Err(DqError::Cancelled(_))));
    }

    #[test]
    fn failure_propagates_typed() {
        let s = BankStore::new();
        s.open(3, 2);
        s.fail(3, DqError::Unschedulable("no capacity".into()));
        let err = s.wait(3, Duration::from_millis(100)).unwrap_err();
        assert_eq!(err, DqError::Unschedulable("no capacity".into()));
    }

    #[test]
    fn duplicate_completion_ignored() {
        let s = BankStore::new();
        s.open(4, 2);
        s.complete(4, 0, 0.5);
        s.complete(4, 0, 0.6); // ignored
        assert_eq!(s.progress(4), Some((1, 2)));
        s.complete(4, 1, 0.7);
        assert_eq!(s.wait(4, Duration::from_millis(50)).unwrap(), vec![0.5, 0.7]);
    }

    #[test]
    fn unknown_bank_errors() {
        let s = BankStore::new();
        assert!(matches!(s.wait(42, Duration::from_millis(10)), Err(DqError::Protocol(_))));
    }

    #[test]
    fn cancel_discards_results_on_arrival() {
        let s = BankStore::new();
        s.open(6, 3);
        s.complete(6, 0, 0.1);
        assert!(s.cancel(6));
        // a straggler result arrives from a worker after cancellation
        s.complete(6, 1, 0.2);
        let st = s.status(6).unwrap();
        assert!(!st.pending);
        assert_eq!(st.completed, 1, "post-cancel result must be discarded");
        assert!(matches!(s.wait(6, Duration::from_millis(50)), Err(DqError::Cancelled(_))));
        assert_eq!(s.in_flight(), 0);
        assert!(!s.cancel(6), "cancel after wait is a no-op");
    }

    #[test]
    fn cancel_wakes_blocked_waiter() {
        let s = Arc::new(BankStore::new());
        s.open(7, 2);
        let s2 = s.clone();
        let t = std::thread::spawn(move || s2.wait(7, Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(30));
        s.cancel(7);
        assert!(matches!(t.join().unwrap(), Err(DqError::Cancelled(_))));
    }

    #[test]
    fn restore_marks_recovered_and_completes_immediately_when_full() {
        let s = BankStore::new();
        s.restore(21, vec![Some(0.1), Some(0.2)], 7, 5, 1, None);
        let st = s.status(21).unwrap();
        assert!(st.recovered && !st.pending);
        assert_eq!(s.wait(21, Duration::from_millis(20)).unwrap(), vec![0.1, 0.2]);
        // partially-complete restore stays pending and accepts results
        s.restore(22, vec![Some(0.3), None], 7, 5, 1, None);
        assert!(s.status(22).unwrap().pending);
        s.complete(22, 1, 0.4);
        assert_eq!(s.wait(22, Duration::from_millis(20)).unwrap(), vec![0.3, 0.4]);
        // freshly-opened banks are not recovered
        s.open(23, 1);
        assert!(!s.status(23).unwrap().recovered);
    }

    #[test]
    fn restored_tombstones_behave_like_live_cancellations() {
        let s = BankStore::new();
        s.restore_cancelled([31, 32]);
        assert!(s.is_cancelled(31));
        assert!(matches!(s.wait(31, Duration::from_millis(10)), Err(DqError::Cancelled(_))));
        s.complete(32, 0, 0.5); // discarded, never resurrects
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn pending_banks_excludes_done_failed_and_cancelled() {
        let s = BankStore::new();
        s.open(41, 1); // stays pending
        s.open(42, 1); // completes
        s.complete(42, 0, 0.9);
        s.open(43, 1); // fails
        s.fail(43, DqError::Protocol("boom".into()));
        s.open(44, 1); // cancelled
        s.cancel(44);
        assert_eq!(s.pending_banks(), vec![41]);
        let snaps = s.snapshot();
        assert_eq!(snaps.len(), 4);
        let by_bank = |id: u64| snaps.iter().find(|b| b.bank == id).unwrap();
        assert!(by_bank(44).cancelled && !by_bank(41).cancelled);
        assert_eq!(by_bank(42).fids, vec![Some(0.9)]);
        assert!(by_bank(43).failed.is_some());
        assert_eq!(s.cancelled_ids(), vec![44]);
    }

    fn recording_watcher() -> (BankWatcher, Arc<Mutex<Vec<BankEvent>>>) {
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = log.clone();
        (Box::new(move |ev: &BankEvent| log2.lock().unwrap().push(ev.clone())), log)
    }

    #[test]
    fn watcher_streams_fids_in_order_then_done() {
        let s = BankStore::new();
        s.open(50, 3);
        let (w, log) = recording_watcher();
        assert!(s.watch(50, w));
        s.complete(50, 1, 0.1);
        s.complete(50, 0, 0.2);
        s.complete(50, 2, 0.3);
        let got = log.lock().unwrap().clone();
        assert_eq!(
            got,
            vec![
                BankEvent::Fid { index: 1, fid: 0.1, remaining: 2 },
                BankEvent::Fid { index: 0, fid: 0.2, remaining: 1 },
                BankEvent::Fid { index: 2, fid: 0.3, remaining: 0 },
                BankEvent::Done,
            ]
        );
        assert_eq!(s.watcher_count(50), 0, "Done deregisters the watcher");
        // a straggler duplicate never re-fires
        s.complete(50, 1, 0.9);
        assert_eq!(log.lock().unwrap().len(), 4);
    }

    #[test]
    fn watcher_replays_partials_present_at_registration() {
        let s = BankStore::new();
        s.open(51, 3);
        s.complete(51, 2, 0.9);
        s.complete(51, 0, 0.7);
        let (w, log) = recording_watcher();
        assert!(s.watch(51, w));
        assert_eq!(
            log.lock().unwrap().clone(),
            vec![
                BankEvent::Fid { index: 0, fid: 0.7, remaining: 2 },
                BankEvent::Fid { index: 2, fid: 0.9, remaining: 1 },
            ]
        );
        // an already-complete bank fires Done immediately, no registration
        let s2 = BankStore::new();
        s2.open(52, 1);
        s2.complete(52, 0, 0.5);
        let (w2, log2) = recording_watcher();
        assert!(s2.watch(52, w2));
        assert_eq!(
            log2.lock().unwrap().clone(),
            vec![BankEvent::Fid { index: 0, fid: 0.5, remaining: 0 }, BankEvent::Done]
        );
        assert_eq!(s2.watcher_count(52), 0);
    }

    #[test]
    fn watcher_observes_failure_cancellation_and_sweeps() {
        let s = BankStore::new();
        s.open(53, 2);
        let (w, log) = recording_watcher();
        s.watch(53, w);
        s.fail(53, DqError::WorkerLost("gone".into()));
        assert_eq!(
            log.lock().unwrap().clone(),
            vec![BankEvent::Failed(DqError::WorkerLost("gone".into()))]
        );
        assert_eq!(s.watcher_count(53), 0);

        s.open(54, 2);
        let (w, log) = recording_watcher();
        s.watch(54, w);
        s.cancel(54);
        assert_eq!(log.lock().unwrap().clone(), vec![BankEvent::Cancelled]);

        s.open(55, 2);
        let (w, log) = recording_watcher();
        s.watch(55, w);
        s.fail_pending(DqError::Cancelled("manager stopped".into()));
        assert_eq!(
            log.lock().unwrap().clone(),
            vec![BankEvent::Failed(DqError::Cancelled("manager stopped".into()))]
        );

        // watching a cancelled-but-GC'd bank still observes Cancelled;
        // a never-seen bank is unwatchable
        s.discard(54);
        let (w, log) = recording_watcher();
        assert!(s.watch(54, w));
        assert_eq!(log.lock().unwrap().clone(), vec![BankEvent::Cancelled]);
        let (w, _) = recording_watcher();
        assert!(!s.watch(9999, w));
    }

    #[test]
    fn status_exposes_partial_fids() {
        let s = BankStore::new();
        s.open(8, 3);
        s.complete(8, 2, 0.9);
        let st = s.status(8).unwrap();
        assert!(st.pending);
        assert_eq!((st.completed, st.total), (1, 3));
        assert_eq!(st.partial_fids, vec![None, None, Some(0.9)]);
        assert_eq!(s.status(99), None);
    }
}
