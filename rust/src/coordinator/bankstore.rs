//! Result collection: banks of circuits submitted by clients, filled in
//! as workers complete them, awaited by blocking clients.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// One submitted bank awaiting its fidelities.
#[derive(Debug)]
struct BankState {
    fids: Vec<Option<f32>>,
    remaining: usize,
    failed: Option<String>,
}

/// Thread-safe store of in-flight banks.
#[derive(Debug, Default)]
pub struct BankStore {
    inner: Mutex<HashMap<u64, BankState>>,
    cv: Condvar,
}

impl BankStore {
    /// Empty store.
    pub fn new() -> BankStore {
        BankStore::default()
    }

    /// Open a new bank expecting `size` results.
    pub fn open(&self, bank: u64, size: usize) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        let prev = g.insert(bank, BankState { fids: vec![None; size], remaining: size, failed: None });
        debug_assert!(prev.is_none(), "bank id reuse");
    }

    /// Record one completed circuit.
    pub fn complete(&self, bank: u64, index: usize, fid: f32) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        if let Some(b) = g.get_mut(&bank) {
            if b.fids[index].is_none() {
                b.fids[index] = Some(fid);
                b.remaining -= 1;
                if b.remaining == 0 {
                    self.cv.notify_all();
                }
            }
        }
    }

    /// Mark a whole bank as failed (e.g. unschedulable circuit).
    pub fn fail(&self, bank: u64, reason: String) {
        let mut g = self.inner.lock().expect("bankstore poisoned");
        if let Some(b) = g.get_mut(&bank) {
            b.failed = Some(reason);
            self.cv.notify_all();
        }
    }

    /// Block until the bank completes (or fails / times out); removes it.
    pub fn wait(&self, bank: u64, timeout: Duration) -> Result<Vec<f32>, String> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().expect("bankstore poisoned");
        loop {
            match g.get(&bank) {
                None => return Err(format!("unknown bank {bank}")),
                Some(b) if b.failed.is_some() => {
                    let reason = b.failed.clone().unwrap();
                    g.remove(&bank);
                    return Err(reason);
                }
                Some(b) if b.remaining == 0 => {
                    let b = g.remove(&bank).unwrap();
                    return Ok(b.fids.into_iter().map(|f| f.unwrap()).collect());
                }
                Some(_) => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        g.remove(&bank);
                        return Err(format!("bank {bank} timed out"));
                    }
                    let (guard, _t) = self
                        .cv
                        .wait_timeout(g, deadline - now)
                        .expect("bankstore poisoned");
                    g = guard;
                }
            }
        }
    }

    /// Progress of a bank: (done, total), if it exists.
    pub fn progress(&self, bank: u64) -> Option<(usize, usize)> {
        let g = self.inner.lock().expect("bankstore poisoned");
        g.get(&bank).map(|b| (b.fids.len() - b.remaining, b.fids.len()))
    }

    /// Number of banks currently open.
    pub fn in_flight(&self) -> usize {
        self.inner.lock().expect("bankstore poisoned").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn complete_then_wait() {
        let s = BankStore::new();
        s.open(1, 3);
        s.complete(1, 0, 0.1);
        s.complete(1, 2, 0.3);
        s.complete(1, 1, 0.2);
        let fids = s.wait(1, Duration::from_millis(100)).unwrap();
        assert_eq!(fids, vec![0.1, 0.2, 0.3]);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn wait_blocks_until_completion() {
        let s = Arc::new(BankStore::new());
        s.open(5, 2);
        let s2 = s.clone();
        let t = std::thread::spawn(move || s2.wait(5, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(30));
        s.complete(5, 1, 0.9);
        s.complete(5, 0, 0.8);
        assert_eq!(t.join().unwrap().unwrap(), vec![0.8, 0.9]);
    }

    #[test]
    fn timeout_reported() {
        let s = BankStore::new();
        s.open(2, 1);
        let err = s.wait(2, Duration::from_millis(20)).unwrap_err();
        assert!(err.contains("timed out"));
    }

    #[test]
    fn failure_propagates() {
        let s = BankStore::new();
        s.open(3, 2);
        s.fail(3, "no capacity".into());
        let err = s.wait(3, Duration::from_millis(100)).unwrap_err();
        assert!(err.contains("no capacity"));
    }

    #[test]
    fn duplicate_completion_ignored() {
        let s = BankStore::new();
        s.open(4, 2);
        s.complete(4, 0, 0.5);
        s.complete(4, 0, 0.6); // ignored
        assert_eq!(s.progress(4), Some((1, 2)));
        s.complete(4, 1, 0.7);
        assert_eq!(s.wait(4, Duration::from_millis(50)).unwrap(), vec![0.5, 0.7]);
    }

    #[test]
    fn unknown_bank_errors() {
        let s = BankStore::new();
        assert!(s.wait(42, Duration::from_millis(10)).is_err());
    }
}
