//! Typed client sessions: [`ClientSession`] owns a tenant's client id
//! and hands out [`BankHandle`] futures for submitted banks.
//!
//! The same two types front both deployments — [`SessionOps`] is
//! implemented by [`super::Manager`] (direct calls, `--in-proc` mode) and
//! by `cluster::tcp::RemoteClient`'s RPC stub — so a training loop that
//! overlaps classical optimization with in-flight quantum banks is
//! deployment-agnostic:
//!
//! ```no_run
//! use dqulearn::coordinator::{Manager, ManagerConfig};
//! use dqulearn::circuit::QuClassiConfig;
//! let manager = Manager::new(ManagerConfig::default());
//! let session = manager.session();
//! let cfg = QuClassiConfig::new(5, 1).unwrap();
//! let handle = session.submit(cfg, &[(vec![0.1; 4], vec![0.2; 4])]).unwrap();
//! while handle.try_poll().unwrap().pending {
//!     /* overlap classical work; stream handle.try_poll().partial_fids */
//! }
//! let fids = handle.wait().unwrap();
//! # let _ = fids;
//! ```

use std::sync::Arc;
use std::time::Duration;

use super::bankstore::BankStatus;
use super::manager::Manager;
use crate::circuit::QuClassiConfig;
use crate::error::DqError;
use crate::model::exec::{CircuitExecutor, CircuitPair};

/// Transport-level bank operations a session is built over. Implemented
/// by [`Manager`] (direct) and the TCP remote stub.
pub trait SessionOps: Send + Sync {
    /// Enqueue a bank; returns its id.
    fn submit(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError>;
    /// Block until the bank resolves. `None` uses the manager's
    /// configured wait timeout.
    fn wait(&self, bank: u64, timeout: Option<Duration>) -> Result<Vec<f32>, DqError>;
    /// Non-blocking progress snapshot.
    fn status(&self, bank: u64) -> Result<BankStatus, DqError>;
    /// Cancel the bank; returns the number of queued circuits drained.
    fn cancel(&self, bank: u64) -> Result<usize, DqError>;
}

impl SessionOps for Manager {
    fn submit(
        &self,
        client: u64,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<u64, DqError> {
        self.submit_bank(client, config, pairs)
    }

    fn wait(&self, bank: u64, timeout: Option<Duration>) -> Result<Vec<f32>, DqError> {
        match timeout {
            Some(t) => self.wait_bank_timeout(bank, t),
            None => self.wait_bank(bank),
        }
    }

    fn status(&self, bank: u64) -> Result<BankStatus, DqError> {
        self.bank_status(bank).ok_or_else(|| {
            if self.bank_cancelled(bank) {
                DqError::Cancelled(format!("bank {bank} cancelled"))
            } else {
                DqError::Protocol(format!("unknown bank {bank}"))
            }
        })
    }

    fn cancel(&self, bank: u64) -> Result<usize, DqError> {
        Ok(self.cancel_bank(bank))
    }
}

/// One tenant's handle onto the co-Manager (or a remote one). Obtained
/// from `Manager::session()` / `RemoteClient::session()` /
/// `InProcCluster::session()`.
#[derive(Clone)]
pub struct ClientSession {
    ops: Arc<dyn SessionOps>,
    client: u64,
}

impl ClientSession {
    /// Wrap a transport with an already-allocated client id. (Library
    /// entry points call this for you.)
    pub fn new(ops: Arc<dyn SessionOps>, client: u64) -> ClientSession {
        ClientSession { ops, client }
    }

    /// The session's client id (the manager's multi-tenant key).
    pub fn id(&self) -> u64 {
        self.client
    }

    /// The transport behind this session (the principal manager re-wraps
    /// an agent's session under its own routing ops; `cluster::principal`).
    pub(crate) fn ops(&self) -> Arc<dyn SessionOps> {
        self.ops.clone()
    }

    /// Submit a bank of circuits; returns a [`BankHandle`] future
    /// immediately (blocks only on queue backpressure).
    pub fn submit(
        &self,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<BankHandle, DqError> {
        let bank = self.ops.submit(self.client, config, pairs)?;
        Ok(BankHandle { ops: self.ops.clone(), bank, total: pairs.len() })
    }

    /// Convenience: submit + wait.
    pub fn execute(
        &self,
        config: QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        self.submit(config, pairs)?.wait()
    }
}

/// A session is itself a [`CircuitExecutor`], so the Trainer and every
/// example run on the session API without code changes.
impl CircuitExecutor for ClientSession {
    fn execute_bank(
        &self,
        config: &QuClassiConfig,
        pairs: &[CircuitPair],
    ) -> Result<Vec<f32>, DqError> {
        self.execute(*config, pairs)
    }

    fn describe(&self) -> String {
        format!("client session #{}", self.client)
    }
}

/// Future for one submitted bank: poll it, stream partial fidelities,
/// cancel it, or block for the full result vector.
pub struct BankHandle {
    ops: Arc<dyn SessionOps>,
    bank: u64,
    total: usize,
}

impl BankHandle {
    /// The bank id (stable across the wire).
    pub fn id(&self) -> u64 {
        self.bank
    }

    /// Number of circuits in the bank.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Block until every circuit completes; consumes the handle and
    /// returns fidelities in submission order. Fails with the bank's
    /// typed error ([`DqError::Cancelled`], [`DqError::Unschedulable`],
    /// [`DqError::Timeout`], ...). On [`DqError::Timeout`] the manager
    /// reaps (cancels) the bank — the consumed handle leaves no way to
    /// retry, so the bank must not outlive this call.
    pub fn wait(self) -> Result<Vec<f32>, DqError> {
        self.ops.wait(self.bank, None)
    }

    /// [`BankHandle::wait`] with an explicit deadline. Borrows the handle
    /// so a timed-out wait can be retried or escalated to `cancel`; the
    /// bank stays resident across the timeout (cancel it rather than
    /// abandon it).
    pub fn wait_timeout(&self, timeout: Duration) -> Result<Vec<f32>, DqError> {
        self.ops.wait(self.bank, Some(timeout))
    }

    /// Non-blocking snapshot: completed/total counts and per-circuit
    /// partial fidelities. Completion counts are monotonically
    /// non-decreasing across calls while the bank runs.
    ///
    /// On a push-negotiated binary connection this answers from the
    /// locally streamed `subscribe_bank` events — no `bank_status`
    /// round trip (DESIGN.md §19); in-process and JSON sessions poll the
    /// manager as before.
    pub fn try_poll(&self) -> Result<BankStatus, DqError> {
        self.ops.status(self.bank)
    }

    /// Cancel the bank: queued circuits are drained (backpressure
    /// released), in-flight results are discarded on arrival, and any
    /// waiter wakes with [`DqError::Cancelled`]. Idempotent; returns the
    /// number of queued circuits drained.
    pub fn cancel(&self) -> Result<usize, DqError> {
        self.ops.cancel(self.bank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;
    use std::sync::Mutex;

    /// Scripted transport: every bank completes instantly with 0.5s.
    struct FakeOps {
        cancelled: Mutex<Vec<u64>>,
        sizes: Mutex<HashMap<u64, usize>>,
        next: Mutex<u64>,
    }

    impl FakeOps {
        fn new() -> FakeOps {
            FakeOps {
                cancelled: Mutex::new(Vec::new()),
                sizes: Mutex::new(HashMap::new()),
                next: Mutex::new(1),
            }
        }
    }

    impl SessionOps for FakeOps {
        fn submit(
            &self,
            _client: u64,
            _config: QuClassiConfig,
            pairs: &[CircuitPair],
        ) -> Result<u64, DqError> {
            let mut next = self.next.lock().unwrap();
            let bank = *next;
            *next += 1;
            self.sizes.lock().unwrap().insert(bank, pairs.len());
            Ok(bank)
        }

        fn wait(&self, bank: u64, _timeout: Option<Duration>) -> Result<Vec<f32>, DqError> {
            if self.cancelled.lock().unwrap().contains(&bank) {
                return Err(DqError::Cancelled(format!("bank {bank} cancelled")));
            }
            let n = self.sizes.lock().unwrap()[&bank];
            Ok(vec![0.5; n])
        }

        fn status(&self, bank: u64) -> Result<BankStatus, DqError> {
            let n = self.sizes.lock().unwrap()[&bank];
            Ok(BankStatus {
                pending: false,
                completed: n,
                total: n,
                partial_fids: vec![Some(0.5); n],
                recovered: false,
            })
        }

        fn cancel(&self, bank: u64) -> Result<usize, DqError> {
            self.cancelled.lock().unwrap().push(bank);
            Ok(0)
        }
    }

    #[test]
    fn session_routes_through_ops() {
        let session = ClientSession::new(Arc::new(FakeOps::new()), 7);
        assert_eq!(session.id(), 7);
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let pairs = vec![(vec![0.0; 4], vec![0.0; 4]); 3];
        let handle = session.submit(cfg, &pairs).unwrap();
        assert_eq!(handle.total(), 3);
        let st = handle.try_poll().unwrap();
        assert_eq!((st.completed, st.total), (3, 3));
        assert_eq!(handle.wait().unwrap(), vec![0.5; 3]);
    }

    #[test]
    fn cancelled_handle_waits_cancelled() {
        let session = ClientSession::new(Arc::new(FakeOps::new()), 1);
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let handle = session.submit(cfg, &[(vec![0.0; 4], vec![0.0; 4])]).unwrap();
        handle.cancel().unwrap();
        assert!(matches!(handle.wait(), Err(DqError::Cancelled(_))));
    }

    #[test]
    fn session_is_an_executor() {
        let session = ClientSession::new(Arc::new(FakeOps::new()), 2);
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let fids = session.execute_bank(&cfg, &[(vec![0.0; 4], vec![0.0; 4])]).unwrap();
        assert_eq!(fids, vec![0.5]);
        assert!(session.describe().contains("#2"));
    }
}
