//! Dynamically-typed JSON value model with ergonomic accessors.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is canonical
/// (deterministic key order), which keeps golden tests and hashes stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics when self is not an object.
    pub fn with(mut self, key: &str, val: impl Into<Value>) -> Value {
        match &mut self {
            Value::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Value::with on non-object"),
        }
        self
    }

    pub fn set(&mut self, key: &str, val: impl Into<Value>) {
        match self {
            Value::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Value::set on non-object"),
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(x) if x.fract() == 0.0 && x.abs() <= i64::MAX as f64 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required typed field accessors for protocol decoding — produce a
    /// descriptive error instead of an Option.
    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key).and_then(Value::as_f64).ok_or_else(|| format!("missing/invalid f64 field '{key}'"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, String> {
        self.get(key).and_then(Value::as_u64).ok_or_else(|| format!("missing/invalid u64 field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        self.req_u64(key).map(|x| x as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key).and_then(Value::as_str).ok_or_else(|| format!("missing/invalid string field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Value], String> {
        self.get(key).and_then(Value::as_arr).ok_or_else(|| format!("missing/invalid array field '{key}'"))
    }

    /// Decode an array of numbers into f32s.
    pub fn req_f32_vec(&self, key: &str) -> Result<Vec<f32>, String> {
        let arr = self.req_arr(key)?;
        arr.iter()
            .map(|v| v.as_f64().map(|x| x as f32).ok_or_else(|| format!("non-number in '{key}'")))
            .collect()
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Value {
        Value::Num(x)
    }
}

impl From<f32> for Value {
    fn from(x: f32) -> Value {
        Value::Num(x as f64)
    }
}

impl From<u64> for Value {
    fn from(x: u64) -> Value {
        Value::Num(x as f64)
    }
}

impl From<usize> for Value {
    fn from(x: usize) -> Value {
        Value::Num(x as f64)
    }
}

impl From<i64> for Value {
    fn from(x: i64) -> Value {
        Value::Num(x as f64)
    }
}

impl From<u32> for Value {
    fn from(x: u32) -> Value {
        Value::Num(x as f64)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}

impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(xs: Vec<T>) -> Value {
        Value::Arr(xs.into_iter().map(Into::into).collect())
    }
}

impl From<&[f32]> for Value {
    fn from(xs: &[f32]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", super::json::to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_accessors() {
        let v = Value::obj()
            .with("name", "w1")
            .with("qubits", 10u64)
            .with("busy", true)
            .with("load", 0.25f64)
            .with("tags", vec!["a", "b"]);
        assert_eq!(v.req_str("name").unwrap(), "w1");
        assert_eq!(v.req_u64("qubits").unwrap(), 10);
        assert_eq!(v.get("busy").unwrap().as_bool(), Some(true));
        assert_eq!(v.req_f64("load").unwrap(), 0.25);
        assert_eq!(v.req_arr("tags").unwrap().len(), 2);
    }

    #[test]
    fn missing_field_reports_name() {
        let v = Value::obj();
        let err = v.req_str("worker_id").unwrap_err();
        assert!(err.contains("worker_id"));
    }

    #[test]
    fn numeric_coercions() {
        assert_eq!(Value::Num(3.0).as_u64(), Some(3));
        assert_eq!(Value::Num(3.5).as_u64(), None);
        assert_eq!(Value::Num(-1.0).as_u64(), None);
        assert_eq!(Value::Num(-1.0).as_i64(), Some(-1));
    }

    #[test]
    fn f32_vec_round_trip() {
        let xs = vec![1.5f32, -2.25, 0.0];
        let v = Value::obj().with("xs", xs.as_slice());
        assert_eq!(v.req_f32_vec("xs").unwrap(), xs);
    }
}
