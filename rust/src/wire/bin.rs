//! `wire/bin` — the compact binary codec behind [`crate::cluster::proto`]
//! (DESIGN.md §17).
//!
//! JSON (`wire/json`) remains the debug and compatibility path; this
//! module is the negotiated fast path the mux transport (`net/mux`)
//! carries once both peers advertise [`BIN_VERSION`] in the connect
//! handshake. The encoding is deliberately boring:
//!
//! * unsigned integers are LEB128 varints (≤ 10 bytes, canonicalness
//!   not required on decode);
//! * `f32`/`f64` are raw little-endian IEEE bits — circuit parameter
//!   vectors, the dominant payload, become a `memcpy` instead of a
//!   float↔decimal round-trip;
//! * strings and vectors are length-prefixed (varint count, then raw
//!   elements);
//! * op *names* never travel: the mux frame carries an interned op id
//!   (see [`op_id`] / [`op_name`]).
//!
//! Decoding is strict and pure: every read is bounds-checked through
//! [`Cur`], oversized counts are rejected before allocation, and each
//! top-level `decode_*` requires the buffer to be fully consumed
//! ([`Cur::done`]) so trailing garbage is a [`DqError::Protocol`], not
//! a silent success. Field-level semantic checks mirror the JSON
//! codecs exactly (config validation, circuit arity, histogram bucket
//! count), so a value rejected by one codec is rejected by the other.

use std::collections::BTreeMap;

use crate::circuit::QuClassiConfig;
use crate::cluster::proto::{SubmitRequest, SubmitResponse};
use crate::coordinator::bankstore::BankEvent;
use crate::coordinator::{BankStatus, CircuitJob, ManagerStats, TenantStats};
use crate::error::DqError;
use crate::util::stats::{WaitHistogram, WAIT_HIST_BUCKETS};

/// Binary wire-format version advertised in the mux handshake. Peers
/// speak `min(theirs, ours)`; version 0 (or no handshake at all) means
/// framed JSON.
pub const BIN_VERSION: u8 = 1;

/// Feature bit: the peer accepts binary-encoded `execute` payloads
/// ([`encode_jobs`] / [`encode_fids`]).
pub const FEAT_BIN_EXECUTE: u8 = 0x01;

/// Feature bit: the peer understands unsolicited `KIND_PUSH` frames —
/// the server may stream [`encode_bank_event`] payloads on a
/// correlation id opened with `subscribe_bank`.
pub const FEAT_PUSH: u8 = 0x02;

/// Feature bit: the peer supports resumable sessions. A dialer that
/// negotiated this sends `attach` (correlation id 0) as its first
/// request; after a transport drop it re-dials and re-attaches with
/// the same token, resuming the server-side session in place.
pub const FEAT_RESUME: u8 = 0x04;

/// Every feature bit this build implements (the hello's advertisement;
/// [`negotiate`](crate::net::mux) intersects it with the peer's).
pub const FEAT_ALL: u8 = FEAT_BIN_EXECUTE | FEAT_PUSH | FEAT_RESUME;

/// Interned op-name table: the string ops of the JSON envelope, as mux
/// frame op ids. Ids are append-only wire contract — never renumber.
const OP_TABLE: &[(u32, &str)] = &[
    (1, "execute"),
    (2, "ping"),
    (3, "register"),
    (4, "heartbeat"),
    (5, "new_client"),
    (6, "submit_bank"),
    (7, "wait_bank"),
    (8, "bank_status"),
    (9, "cancel_bank"),
    (10, "stats"),
    (11, "attach"),
    (12, "subscribe_bank"),
];

/// The interned id for `execute` (manager→worker batch dispatch).
pub const OP_EXECUTE: u32 = 1;
/// Interned id for `new_client` (client→manager, empty payload →
/// [`encode_u64`] client id).
pub const OP_NEW_CLIENT: u32 = 5;
/// Interned id for `submit_bank` ([`encode_submit_request`] →
/// [`encode_submit_response`]).
pub const OP_SUBMIT_BANK: u32 = 6;
/// Interned id for `wait_bank` ([`encode_wait_request`] →
/// [`encode_fids`]).
pub const OP_WAIT_BANK: u32 = 7;
/// Interned id for `bank_status` ([`encode_u64`] bank id →
/// [`encode_bank_status`]).
pub const OP_BANK_STATUS: u32 = 8;
/// Interned id for `cancel_bank` ([`encode_u64`] bank id →
/// [`encode_u64`] drained count).
pub const OP_CANCEL_BANK: u32 = 9;
/// Interned id for `stats` (empty payload → [`encode_pool_stats`]).
pub const OP_STATS: u32 = 10;
/// Interned id for `attach` ([`encode_attach_request`] →
/// [`encode_attach_ok`]; always correlation id 0, always the first
/// request on a [`FEAT_RESUME`] connection).
pub const OP_ATTACH: u32 = 11;
/// Interned id for `subscribe_bank` ([`encode_u64`] bank id; the reply
/// is a *stream* of `KIND_PUSH` [`encode_bank_event`] frames on the
/// request's correlation id, closed by a final OK/ERR).
pub const OP_SUBSCRIBE_BANK: u32 = 12;

/// Interned id for an op name, if the table knows it.
pub fn op_id(name: &str) -> Option<u32> {
    OP_TABLE.iter().find(|(_, n)| *n == name).map(|(i, _)| *i)
}

/// Op name for an interned id, if the table knows it.
pub fn op_name(id: u32) -> Option<&'static str> {
    OP_TABLE.iter().find(|(i, _)| *i == id).map(|(_, n)| *n)
}

// ---------------------------------------------------------------------------
// primitives: encode
// ---------------------------------------------------------------------------

/// Append a LEB128 varint.
pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Append a bool as one byte (0/1).
pub fn put_bool(buf: &mut Vec<u8>, b: bool) {
    buf.push(u8::from(b));
}

/// Append raw little-endian `f32` bits.
pub fn put_f32(buf: &mut Vec<u8>, x: f32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

/// Append raw little-endian `f64` bits.
pub fn put_f64(buf: &mut Vec<u8>, x: f64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

/// Append a varint-length-prefixed UTF-8 string.
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_varint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

/// Append a varint-count-prefixed raw-LE `f32` vector.
pub fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    put_varint(buf, xs.len() as u64);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

// ---------------------------------------------------------------------------
// primitives: decode
// ---------------------------------------------------------------------------

fn proto(msg: impl Into<String>) -> DqError {
    DqError::Protocol(msg.into())
}

/// Bounds-checked read cursor over an encoded buffer. Every accessor
/// returns [`DqError::Protocol`] on underrun; nothing panics and no
/// count is trusted before the bytes it describes are proven present.
pub struct Cur<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    pub fn new(data: &'a [u8]) -> Cur<'a> {
        Cur { data, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    /// Take exactly `n` bytes or fail.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], DqError> {
        if n > self.remaining() {
            return Err(proto(format!("bin: short buffer (need {n}, have {})", self.remaining())));
        }
        let out = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Read a LEB128 varint (≤ 10 bytes, overflow-checked).
    pub fn take_varint(&mut self) -> Result<u64, DqError> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let byte = self.take(1)?[0];
            let bits = u64::from(byte & 0x7f);
            if shift == 63 && bits > 1 {
                return Err(proto("bin: varint overflows u64"));
            }
            v |= bits << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(proto("bin: varint longer than 10 bytes"))
    }

    /// Read a varint that must fit a `usize`.
    pub fn take_len(&mut self) -> Result<usize, DqError> {
        usize::try_from(self.take_varint()?).map_err(|_| proto("bin: length exceeds usize"))
    }

    /// Read a one-byte bool; any value other than 0/1 is malformed.
    pub fn take_bool(&mut self) -> Result<bool, DqError> {
        match self.take(1)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(proto(format!("bin: invalid bool byte {b:#04x}"))),
        }
    }

    /// Read raw little-endian `f32` bits.
    pub fn take_f32(&mut self) -> Result<f32, DqError> {
        let raw = self.take(4)?;
        Ok(f32::from_le_bytes([raw[0], raw[1], raw[2], raw[3]]))
    }

    /// Read raw little-endian `f64` bits.
    pub fn take_f64(&mut self) -> Result<f64, DqError> {
        let raw = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(raw);
        Ok(f64::from_le_bytes(b))
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn take_str(&mut self) -> Result<String, DqError> {
        let n = self.take_len()?;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| proto("bin: invalid UTF-8 in string"))
    }

    /// Read a count-prefixed raw-LE `f32` vector. The count is checked
    /// against the remaining bytes *before* any allocation, so a
    /// corrupted length can't balloon memory.
    pub fn take_f32s(&mut self) -> Result<Vec<f32>, DqError> {
        let n = self.take_len()?;
        let bytes = n.checked_mul(4).ok_or_else(|| proto("bin: f32 vector length overflow"))?;
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Require the buffer fully consumed (top-level decode postcondition).
    pub fn done(&self) -> Result<(), DqError> {
        if self.remaining() != 0 {
            return Err(proto(format!("bin: {} trailing bytes after payload", self.remaining())));
        }
        Ok(())
    }
}

fn read_config(c: &mut Cur<'_>) -> Result<QuClassiConfig, DqError> {
    Ok(QuClassiConfig::new(c.take_len()?, c.take_len()?)?)
}

fn put_config(buf: &mut Vec<u8>, config: &QuClassiConfig) {
    put_varint(buf, config.qubits as u64);
    put_varint(buf, config.layers as u64);
}

// ---------------------------------------------------------------------------
// typed codecs: one binary peer per cluster/proto JSON codec
// ---------------------------------------------------------------------------

/// Encode a [`SubmitRequest`]: `client, qubits, layers, n_pairs,
/// (thetas, data)*`.
pub fn encode_submit_request(r: &SubmitRequest) -> Vec<u8> {
    let body: usize = r.pairs.iter().map(|(t, d)| 4 * (t.len() + d.len()) + 4).sum();
    let mut buf = Vec::with_capacity(16 + body);
    put_varint(&mut buf, r.client);
    put_config(&mut buf, &r.config);
    put_varint(&mut buf, r.pairs.len() as u64);
    for (thetas, data) in &r.pairs {
        put_f32s(&mut buf, thetas);
        put_f32s(&mut buf, data);
    }
    buf
}

/// Decode a [`SubmitRequest`]; mirrors the JSON codec's config check.
pub fn decode_submit_request(bytes: &[u8]) -> Result<SubmitRequest, DqError> {
    let mut c = Cur::new(bytes);
    let client = c.take_varint()?;
    let config = read_config(&mut c)?;
    let n = c.take_len()?;
    let mut pairs = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        pairs.push((c.take_f32s()?, c.take_f32s()?));
    }
    c.done()?;
    Ok(SubmitRequest { client, config, pairs })
}

/// Encode a [`SubmitResponse`]: `bank, total`.
pub fn encode_submit_response(r: &SubmitResponse) -> Vec<u8> {
    let mut buf = Vec::with_capacity(12);
    put_varint(&mut buf, r.bank);
    put_varint(&mut buf, r.total as u64);
    buf
}

/// Decode a [`SubmitResponse`].
pub fn decode_submit_response(bytes: &[u8]) -> Result<SubmitResponse, DqError> {
    let mut c = Cur::new(bytes);
    let resp = SubmitResponse { bank: c.take_varint()?, total: c.take_len()? };
    c.done()?;
    Ok(resp)
}

/// Encode a [`BankStatus`]: `pending, completed, total, n_fids,
/// (tag, f32?)*, recovered`.
pub fn encode_bank_status(s: &BankStatus) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + 5 * s.partial_fids.len());
    put_bool(&mut buf, s.pending);
    put_varint(&mut buf, s.completed as u64);
    put_varint(&mut buf, s.total as u64);
    put_varint(&mut buf, s.partial_fids.len() as u64);
    for f in &s.partial_fids {
        match f {
            None => buf.push(0),
            Some(x) => {
                buf.push(1);
                put_f32(&mut buf, *x);
            }
        }
    }
    put_bool(&mut buf, s.recovered);
    buf
}

/// Decode a [`BankStatus`].
pub fn decode_bank_status(bytes: &[u8]) -> Result<BankStatus, DqError> {
    let mut c = Cur::new(bytes);
    let pending = c.take_bool()?;
    let completed = c.take_len()?;
    let total = c.take_len()?;
    let n = c.take_len()?;
    let mut partial_fids = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        partial_fids.push(match c.take(1)?[0] {
            0 => None,
            1 => Some(c.take_f32()?),
            t => return Err(proto(format!("bin: invalid option tag {t:#04x}"))),
        });
    }
    let recovered = c.take_bool()?;
    c.done()?;
    Ok(BankStatus { pending, completed, total, partial_fids, recovered })
}

fn put_tenant_stats(buf: &mut Vec<u8>, client: u64, t: &TenantStats) {
    put_varint(buf, client);
    put_varint(buf, t.submitted);
    put_varint(buf, t.dispatched);
    put_varint(buf, t.completed);
    put_varint(buf, t.lost);
    put_varint(buf, t.stolen);
    put_f64(buf, t.wait_total_s);
    put_f64(buf, t.wait_max_s);
    put_varint(buf, WAIT_HIST_BUCKETS as u64);
    for n in t.wait_hist.counts() {
        put_varint(buf, *n);
    }
}

fn read_tenant_stats(c: &mut Cur<'_>) -> Result<(u64, TenantStats), DqError> {
    let client = c.take_varint()?;
    let submitted = c.take_varint()?;
    let dispatched = c.take_varint()?;
    let completed = c.take_varint()?;
    let lost = c.take_varint()?;
    let stolen = c.take_varint()?;
    let wait_total_s = c.take_f64()?;
    let wait_max_s = c.take_f64()?;
    let buckets = c.take_len()?;
    if buckets != WAIT_HIST_BUCKETS {
        return Err(proto(format!(
            "bin: wait_hist needs {WAIT_HIST_BUCKETS} buckets, got {buckets}"
        )));
    }
    let mut counts = [0u64; WAIT_HIST_BUCKETS];
    for n in counts.iter_mut() {
        *n = c.take_varint()?;
    }
    let wait_hist = match WaitHistogram::from_counts(&counts) {
        Some(h) => h,
        None => return Err(proto("bin: undecodable wait_hist")),
    };
    Ok((
        client,
        TenantStats {
            submitted,
            dispatched,
            completed,
            lost,
            stolen,
            wait_total_s,
            wait_max_s,
            wait_hist,
        },
    ))
}

/// Encode one tenant's counters (binary peer of
/// [`crate::cluster::proto::tenant_stats_to_wire`]).
pub fn encode_tenant_stats(client: u64, t: &TenantStats) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64);
    put_tenant_stats(&mut buf, client, t);
    buf
}

/// Decode one tenant's counters.
pub fn decode_tenant_stats(bytes: &[u8]) -> Result<(u64, TenantStats), DqError> {
    let mut c = Cur::new(bytes);
    let out = read_tenant_stats(&mut c)?;
    c.done()?;
    Ok(out)
}

fn put_manager_stats(buf: &mut Vec<u8>, s: &ManagerStats) {
    put_varint(buf, s.submitted);
    put_varint(buf, s.completed);
    put_varint(buf, s.dispatches);
    put_varint(buf, s.requeues);
    put_varint(buf, s.evictions);
    put_varint(buf, s.cancelled);
    put_varint(buf, s.steals);
    put_varint(buf, s.pruned_tenants);
    put_tenant_stats(buf, 0, &s.retired);
    put_varint(buf, s.per_tenant.len() as u64);
    for (client, t) in &s.per_tenant {
        put_tenant_stats(buf, *client, t);
    }
}

fn read_manager_stats(c: &mut Cur<'_>) -> Result<ManagerStats, DqError> {
    let submitted = c.take_varint()?;
    let completed = c.take_varint()?;
    let dispatches = c.take_varint()?;
    let requeues = c.take_varint()?;
    let evictions = c.take_varint()?;
    let cancelled = c.take_varint()?;
    let steals = c.take_varint()?;
    let pruned_tenants = c.take_varint()?;
    let retired = read_tenant_stats(c)?.1;
    let n = c.take_len()?;
    let mut per_tenant = BTreeMap::new();
    for _ in 0..n {
        let (client, t) = read_tenant_stats(c)?;
        per_tenant.insert(client, t);
    }
    Ok(ManagerStats {
        submitted,
        completed,
        dispatches,
        requeues,
        evictions,
        cancelled,
        steals,
        pruned_tenants,
        retired,
        per_tenant,
    })
}

/// Encode a [`ManagerStats`]: 8 aggregate counters, the retired
/// aggregate (client 0), then the per-tenant entries.
pub fn encode_manager_stats(s: &ManagerStats) -> Vec<u8> {
    let mut buf = Vec::with_capacity(96 + 64 * s.per_tenant.len());
    put_manager_stats(&mut buf, s);
    buf
}

/// Decode a [`ManagerStats`].
pub fn decode_manager_stats(bytes: &[u8]) -> Result<ManagerStats, DqError> {
    let mut c = Cur::new(bytes);
    let out = read_manager_stats(&mut c)?;
    c.done()?;
    Ok(out)
}

/// Encode the `stats` RPC response: [`ManagerStats`] plus the pool
/// gauges the JSON envelope carries alongside it (worker count, queue
/// depth).
pub fn encode_pool_stats(s: &ManagerStats, workers: u64, queue: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(112 + 64 * s.per_tenant.len());
    put_manager_stats(&mut buf, s);
    put_varint(&mut buf, workers);
    put_varint(&mut buf, queue);
    buf
}

/// Decode a `stats` response: `(stats, workers, queue_len)`.
pub fn decode_pool_stats(bytes: &[u8]) -> Result<(ManagerStats, u64, u64), DqError> {
    let mut c = Cur::new(bytes);
    let stats = read_manager_stats(&mut c)?;
    let workers = c.take_varint()?;
    let queue = c.take_varint()?;
    c.done()?;
    Ok((stats, workers, queue))
}

fn put_job(buf: &mut Vec<u8>, j: &CircuitJob) {
    put_varint(buf, j.id);
    put_varint(buf, j.client);
    put_varint(buf, j.bank);
    put_varint(buf, j.index as u64);
    put_config(buf, &j.config);
    put_f32s(buf, &j.thetas);
    put_f32s(buf, &j.data);
}

fn read_job(c: &mut Cur<'_>) -> Result<CircuitJob, DqError> {
    let id = c.take_varint()?;
    let client = c.take_varint()?;
    let bank = c.take_varint()?;
    let index = c.take_len()?;
    let config = read_config(c)?;
    let thetas = c.take_f32s()?;
    let data = c.take_f32s()?;
    if thetas.len() != config.n_params() {
        return Err(DqError::Arity(format!(
            "job theta arity {} != {}",
            thetas.len(),
            config.n_params()
        )));
    }
    if data.len() != config.n_features() {
        return Err(DqError::Arity(format!(
            "job data arity {} != {}",
            data.len(),
            config.n_features()
        )));
    }
    Ok(CircuitJob { id, client, bank, index, config, thetas, data })
}

/// Encode the manager→worker `execute` payload: a batch of
/// [`CircuitJob`]s (binary peer of the JSON `circuits` array).
pub fn encode_jobs(jobs: &[CircuitJob]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(
        8 + jobs.iter().map(|j| 24 + 4 * (j.thetas.len() + j.data.len())).sum::<usize>(),
    );
    put_varint(&mut buf, jobs.len() as u64);
    for j in jobs {
        put_job(&mut buf, j);
    }
    buf
}

/// Decode an `execute` payload, validating per-job arity (mirrors
/// [`CircuitJob::from_wire`]).
pub fn decode_jobs(bytes: &[u8]) -> Result<Vec<CircuitJob>, DqError> {
    let mut c = Cur::new(bytes);
    let n = c.take_len()?;
    let mut jobs = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        jobs.push(read_job(&mut c)?);
    }
    c.done()?;
    Ok(jobs)
}

/// Encode the worker→manager `execute` result: the fidelity batch.
pub fn encode_fids(fids: &[f32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + 4 * fids.len());
    put_f32s(&mut buf, fids);
    buf
}

/// Decode a fidelity batch.
pub fn decode_fids(bytes: &[u8]) -> Result<Vec<f32>, DqError> {
    let mut c = Cur::new(bytes);
    let fids = c.take_f32s()?;
    c.done()?;
    Ok(fids)
}

/// Encode a bare id/count payload (client ids, bank ids, drain counts —
/// the binary peer of the JSON envelope's single-field objects).
pub fn encode_u64(v: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(10);
    put_varint(&mut buf, v);
    buf
}

/// Decode a bare id/count payload.
pub fn decode_u64(bytes: &[u8]) -> Result<u64, DqError> {
    let mut c = Cur::new(bytes);
    let v = c.take_varint()?;
    c.done()?;
    Ok(v)
}

/// Encode a `wait_bank` request: the bank id plus an optional client
/// deadline in milliseconds (`None` defers to the manager's configured
/// wait timeout, exactly like the JSON envelope's absent `timeout_ms`).
pub fn encode_wait_request(bank: u64, timeout_ms: Option<u64>) -> Vec<u8> {
    let mut buf = Vec::with_capacity(22);
    put_varint(&mut buf, bank);
    put_bool(&mut buf, timeout_ms.is_some());
    if let Some(ms) = timeout_ms {
        put_varint(&mut buf, ms);
    }
    buf
}

/// Decode a `wait_bank` request: `(bank, timeout_ms)`.
pub fn decode_wait_request(bytes: &[u8]) -> Result<(u64, Option<u64>), DqError> {
    let mut c = Cur::new(bytes);
    let bank = c.take_varint()?;
    let timeout_ms = if c.take_bool()? { Some(c.take_varint()?) } else { None };
    c.done()?;
    Ok((bank, timeout_ms))
}

/// Encode an `attach` request: the session token granted by a previous
/// attachment, or 0 to open a fresh session.
pub fn encode_attach_request(token: u64) -> Vec<u8> {
    encode_u64(token)
}

/// Decode an `attach` request token.
pub fn decode_attach_request(bytes: &[u8]) -> Result<u64, DqError> {
    decode_u64(bytes)
}

/// Encode an `attach` reply: `(token, resumed, last_req_corr)`. When
/// `resumed` the server has the session and `last_req_corr` is the
/// highest request correlation id it received before the drop — the
/// dialer re-sends only retained frames *above* it (TCP delivered
/// requests in corr order, so the watermark is a complete receipt
/// record) and keeps waiting on the rest (their replies were parked).
pub fn encode_attach_ok(token: u64, resumed: bool, last_req_corr: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(22);
    put_varint(&mut buf, token);
    put_bool(&mut buf, resumed);
    put_varint(&mut buf, last_req_corr);
    buf
}

/// Decode an `attach` reply: `(token, resumed, last_req_corr)`.
pub fn decode_attach_ok(bytes: &[u8]) -> Result<(u64, bool, u64), DqError> {
    let mut c = Cur::new(bytes);
    let token = c.take_varint()?;
    let resumed = c.take_bool()?;
    let last_req_corr = c.take_varint()?;
    c.done()?;
    Ok((token, resumed, last_req_corr))
}

/// Encode a [`BankEvent`] push payload (`subscribe_bank` stream):
/// `tag, fields…` — `0` Fid, `1` Done, `2` Failed(error), `3` Cancelled.
pub fn encode_bank_event(ev: &BankEvent) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16);
    match ev {
        BankEvent::Fid { index, fid, remaining } => {
            buf.push(0);
            put_varint(&mut buf, *index as u64);
            put_f32(&mut buf, *fid);
            put_varint(&mut buf, *remaining as u64);
        }
        BankEvent::Done => buf.push(1),
        BankEvent::Failed(e) => {
            buf.push(2);
            buf.extend_from_slice(&encode_error(e));
        }
        BankEvent::Cancelled => buf.push(3),
    }
    buf
}

/// Decode a [`BankEvent`] push payload.
pub fn decode_bank_event(bytes: &[u8]) -> Result<BankEvent, DqError> {
    let mut c = Cur::new(bytes);
    let tag = c.take(1)?[0];
    let ev = match tag {
        0 => BankEvent::Fid {
            index: c.take_len()?,
            fid: c.take_f32()?,
            remaining: c.take_len()?,
        },
        1 => BankEvent::Done,
        2 => {
            let n = c.remaining();
            return Ok(BankEvent::Failed(decode_error(c.take(n)?)?));
        }
        3 => BankEvent::Cancelled,
        t => return Err(proto(format!("bin: unknown bank-event tag {t:#04x}"))),
    };
    c.done()?;
    Ok(ev)
}

/// Encode a [`DqError`] as `kind-tag, msg` (binary peer of
/// [`DqError::to_wire`]'s `{"kind","msg"}` object).
pub fn encode_error(e: &DqError) -> Vec<u8> {
    let tag: u8 = match e {
        DqError::Unschedulable(_) => 0,
        DqError::WorkerLost(_) => 1,
        DqError::Timeout(_) => 2,
        DqError::Cancelled(_) => 3,
        DqError::Protocol(_) => 4,
        DqError::Arity(_) => 5,
        DqError::Io(_) => 6,
    };
    let mut buf = Vec::with_capacity(2 + e.message().len());
    buf.push(tag);
    put_str(&mut buf, e.message());
    buf
}

/// Decode a [`DqError`]. An unknown kind tag decodes as
/// [`DqError::Protocol`] (nothing is dropped), mirroring the JSON path.
pub fn decode_error(bytes: &[u8]) -> Result<DqError, DqError> {
    let mut c = Cur::new(bytes);
    let tag = c.take(1)?[0];
    let msg = c.take_str()?;
    c.done()?;
    Ok(match tag {
        0 => DqError::Unschedulable(msg),
        1 => DqError::WorkerLost(msg),
        2 => DqError::Timeout(msg),
        3 => DqError::Cancelled(msg),
        4 => DqError::Protocol(msg),
        5 => DqError::Arity(msg),
        6 => DqError::Io(msg),
        t => DqError::Protocol(format!("undecodable error tag {t:#04x}: {msg}")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_round_trips_boundaries() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX - 1, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut c = Cur::new(&buf);
            assert_eq!(c.take_varint().unwrap(), v);
            c.done().unwrap();
        }
    }

    #[test]
    fn varint_overflow_rejected() {
        // 11 continuation bytes: longer than any u64 varint.
        let buf = [0x80u8; 11];
        assert!(Cur::new(&buf).take_varint().is_err());
        // 10 bytes whose top bits exceed 64: overflow.
        let buf = [0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x7f];
        assert!(Cur::new(&buf).take_varint().is_err());
    }

    #[test]
    fn attach_codecs_round_trip() {
        assert_eq!(decode_attach_request(&encode_attach_request(0)).unwrap(), 0);
        assert_eq!(decode_attach_request(&encode_attach_request(981)).unwrap(), 981);
        for (token, resumed, corr) in [(7u64, true, 41u64), (1, false, 0), (u64::MAX, true, 1 << 40)] {
            let wire = encode_attach_ok(token, resumed, corr);
            assert_eq!(decode_attach_ok(&wire).unwrap(), (token, resumed, corr));
        }
        assert!(decode_attach_ok(&[0]).is_err());
    }

    #[test]
    fn bank_event_codecs_round_trip() {
        let events = [
            BankEvent::Fid { index: 0, fid: 0.5, remaining: 7 },
            BankEvent::Fid { index: 300, fid: -1.0, remaining: 0 },
            BankEvent::Done,
            BankEvent::Failed(DqError::WorkerLost("w3 gone".into())),
            BankEvent::Cancelled,
        ];
        for ev in &events {
            let wire = encode_bank_event(ev);
            let back = decode_bank_event(&wire).unwrap();
            assert_eq!(format!("{back:?}"), format!("{ev:?}"));
        }
        // unknown tag and trailing garbage are both rejected
        assert!(decode_bank_event(&[9]).is_err());
        let mut wire = encode_bank_event(&BankEvent::Done);
        wire.push(0);
        assert!(decode_bank_event(&wire).is_err());
    }

    #[test]
    fn op_table_is_bijective() {
        for (id, name) in OP_TABLE {
            assert_eq!(op_id(name), Some(*id));
            assert_eq!(op_name(*id), Some(*name));
        }
        assert_eq!(op_id("no_such_op"), None);
        assert_eq!(op_name(0), None);
    }

    #[test]
    fn submit_request_round_trips() {
        let req = SubmitRequest {
            client: 3,
            config: QuClassiConfig::new(5, 2).unwrap(),
            pairs: vec![
                (vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6], vec![0.9; 4]),
                (vec![0.0; 6], vec![-1.5, 0.25, 0.0, 2.0]),
            ],
        };
        let bytes = encode_submit_request(&req);
        assert_eq!(decode_submit_request(&bytes).unwrap(), req);
        // trailing garbage is rejected, not ignored
        let mut long = bytes;
        long.push(0);
        assert!(decode_submit_request(&long).is_err());
    }

    #[test]
    fn error_round_trips_every_variant() {
        for e in [
            DqError::Unschedulable("u".into()),
            DqError::WorkerLost("w".into()),
            DqError::Timeout("t".into()),
            DqError::Cancelled("c".into()),
            DqError::Protocol("p".into()),
            DqError::Arity("a".into()),
            DqError::Io("i".into()),
        ] {
            assert_eq!(decode_error(&encode_error(&e)).unwrap(), e);
        }
        // unknown tag folds to Protocol, mirroring the JSON decoder
        let mut buf = vec![200u8];
        put_str(&mut buf, "future kind");
        assert!(matches!(decode_error(&buf).unwrap(), DqError::Protocol(_)));
    }

    #[test]
    fn u64_and_wait_request_round_trip() {
        for v in [0u64, 7, u64::MAX] {
            assert_eq!(decode_u64(&encode_u64(v)).unwrap(), v);
        }
        assert!(decode_u64(&[0x01, 0x00]).is_err()); // trailing byte

        for (bank, t) in [(1u64, None), (42, Some(0u64)), (u64::MAX, Some(600_000))] {
            assert_eq!(decode_wait_request(&encode_wait_request(bank, t)).unwrap(), (bank, t));
        }
    }

    #[test]
    fn pool_stats_round_trips() {
        let mut s = ManagerStats::default();
        s.submitted = 100;
        s.completed = 93;
        s.steals = 4;
        s.per_tenant.insert(3, TenantStats { submitted: 50, ..TenantStats::default() });
        let bytes = encode_pool_stats(&s, 8, 17);
        let (got, workers, queue) = decode_pool_stats(&bytes).unwrap();
        assert_eq!(got.submitted, 100);
        assert_eq!(got.per_tenant[&3].submitted, 50);
        assert_eq!((workers, queue), (8, 17));
        // plain manager stats still refuses the pool-gauge suffix
        assert!(decode_manager_stats(&bytes).is_err());
    }
}
