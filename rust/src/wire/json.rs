//! Strict JSON parser and writer for [`Value`].
//!
//! Supports the full grammar: nested containers, string escapes
//! (including `\uXXXX` with surrogate pairs), scientific-notation
//! numbers. Errors carry byte offsets.

use std::collections::BTreeMap;
use std::fmt;

use super::value::Value;

/// Parse error with byte offset into the input.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn parse_value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.parse_object(),
            Some(b'[') => self.parse_array(),
            Some(b'"') => Ok(Value::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Value::Bool(true)),
            Some(b'f') => self.parse_lit("false", Value::Bool(false)),
            Some(b'n') => self.parse_lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn parse_lit(&mut self, lit: &str, val: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("invalid literal (expected {lit})")))
        }
    }

    fn parse_object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.parse_value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn parse_array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn parse_string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.parse_hex4()?;
                        let ch = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.parse_hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(hi).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf-8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn parse_hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn parse_number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0x00..=0x7F => Some(1),
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

/// Serialize compactly (no whitespace). Canonical: object keys sorted.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(x) => write_number(*x, out),
        Value::Str(s) => write_string(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            if !items.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            if !map.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(x: f64, out: &mut String) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; encode as null like most tolerant writers.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // Shortest round-trip via Rust's float Display (which is exact).
        out.push_str(&format!("{x}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(s: &str) -> Value {
        let v = parse(s).unwrap();
        let re = parse(&to_string(&v)).unwrap();
        assert_eq!(v, re, "round trip changed value for {s}");
        v
    }

    #[test]
    fn scalars() {
        assert_eq!(round_trip("null"), Value::Null);
        assert_eq!(round_trip("true"), Value::Bool(true));
        assert_eq!(round_trip("false"), Value::Bool(false));
        assert_eq!(round_trip("42"), Value::Num(42.0));
        assert_eq!(round_trip("-3.25"), Value::Num(-3.25));
        assert_eq!(round_trip("1e3"), Value::Num(1000.0));
        assert_eq!(round_trip("2.5E-2"), Value::Num(0.025));
        assert_eq!(round_trip("\"hi\""), Value::Str("hi".into()));
    }

    #[test]
    fn containers() {
        let v = round_trip(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req_str("c").unwrap(), "x");
        round_trip("[]");
        round_trip("{}");
        round_trip("[[[]]]");
    }

    #[test]
    fn string_escapes() {
        let v = round_trip(r#""a\nb\t\"q\"\\A""#);
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\"\\A");
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn raw_utf8_pass_through() {
        let v = round_trip("\"héllo ✓\"");
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn errors_have_offsets() {
        let e = parse("{\"a\": }").unwrap_err();
        assert_eq!(e.offset, 6);
        assert!(parse("[1, 2").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn canonical_key_order() {
        let v = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(to_string(&v), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = parse(r#"{"a": [1, 2], "b": {"c": true}}"#).unwrap();
        let pretty = to_string_pretty(&v);
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn float_precision_round_trip() {
        for x in [0.1, 1.0 / 3.0, 1e-10, 123456.789, f64::MAX / 2.0] {
            let s = to_string(&Value::Num(x));
            let back = parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "precision lost for {x}");
        }
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
    }
}
