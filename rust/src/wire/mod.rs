//! Wire format substrate: a from-scratch JSON implementation and a
//! compact binary codec.
//!
//! The paper's manager↔worker channel is RPyC; ours is framed JSON over
//! TCP (see `net/`). JSON was chosen over a custom binary format because
//! the AOT pipeline already emits `manifest.json`, so one codec serves
//! both the RPC protocol and artifact metadata. The implementation is
//! complete: escapes, unicode, nested containers, and a strict parser
//! with byte-offset error reporting.
//!
//! [`bin`] is the negotiated fast path for the hot cluster ops (varint
//! ints, raw little-endian floats, interned op names); JSON remains the
//! debug/fallback codec and the interop path for old workers.

pub mod bin;
pub mod json;
pub mod value;

pub use json::{parse, to_string, to_string_pretty, JsonError};
pub use value::Value;
