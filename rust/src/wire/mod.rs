//! Wire format substrate: a from-scratch JSON implementation.
//!
//! The paper's manager↔worker channel is RPyC; ours is framed JSON over
//! TCP (see `net/`). JSON was chosen over a custom binary format because
//! the AOT pipeline already emits `manifest.json`, so one codec serves
//! both the RPC protocol and artifact metadata. The implementation is
//! complete: escapes, unicode, nested containers, and a strict parser
//! with byte-offset error reporting.

pub mod json;
pub mod value;

pub use json::{parse, to_string, to_string_pretty, JsonError};
pub use value::Value;
