//! Algorithm 1's circuit bank: parameter-shift circuit generation and
//! gradient assembly.
//!
//! For every trainable parameter θ_p the bank holds a +π/2 and a −π/2
//! shifted copy of the parameter vector (the paper's fwd/bck-shifted
//! circuits, Algorithm 1 lines 15–20). Controlled rotations (CRY/CRZ)
//! additionally get ±3π/2 entries because their generator has eigenvalues
//! {0, ±1/2}: the exact gradient is the four-term rule
//! `c₊·[f(θ+π/2) − f(θ−π/2)] − c₋·[f(θ+3π/2) − f(θ−3π/2)]`,
//! `c± = (√2 ± 1)/(4√2)`. Every entry is an independent circuit — the
//! distributable unit the co-Manager schedules.

use super::spec::QuClassiConfig;

const SQRT2: f64 = std::f64::consts::SQRT_2;
/// Two-term rule coefficient.
pub const C_TWO_TERM: f64 = 0.5;
/// Four-term rule coefficients.
pub const C_PLUS: f64 = (SQRT2 + 1.0) / (4.0 * SQRT2);
pub const C_MINUS: f64 = (SQRT2 - 1.0) / (4.0 * SQRT2);

/// Which shift an entry carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShiftKind {
    /// Unshifted parameters (the loss evaluation).
    Base,
    /// θ_p + π/2
    Plus(usize),
    /// θ_p − π/2
    Minus(usize),
    /// θ_p + 3π/2 (controlled rotations only)
    Plus3(usize),
    /// θ_p − 3π/2 (controlled rotations only)
    Minus3(usize),
}

/// One independent, distributable circuit: a shifted parameter vector.
#[derive(Debug, Clone)]
pub struct BankEntry {
    pub kind: ShiftKind,
    pub thetas: Vec<f32>,
}

/// The circuit bank for one (parameter vector, data point) gradient step.
#[derive(Debug, Clone)]
pub struct CircuitBank {
    pub config: QuClassiConfig,
    entries: Vec<BankEntry>,
    controlled: Vec<bool>,
}

impl CircuitBank {
    /// Build the bank for the given parameter vector.
    pub fn new(config: QuClassiConfig, thetas: &[f32]) -> CircuitBank {
        assert_eq!(thetas.len(), config.n_params());
        let controlled = config.controlled_param_mask();
        let mut entries = Vec::with_capacity(1 + 2 * thetas.len());
        entries.push(BankEntry { kind: ShiftKind::Base, thetas: thetas.to_vec() });
        let half_pi = std::f64::consts::FRAC_PI_2 as f32;
        for p in 0..thetas.len() {
            let mut plus = thetas.to_vec();
            plus[p] += half_pi;
            entries.push(BankEntry { kind: ShiftKind::Plus(p), thetas: plus });
            let mut minus = thetas.to_vec();
            minus[p] -= half_pi;
            entries.push(BankEntry { kind: ShiftKind::Minus(p), thetas: minus });
        }
        for (p, &is_ctrl) in controlled.iter().enumerate() {
            if is_ctrl {
                let mut plus3 = thetas.to_vec();
                plus3[p] += 3.0 * half_pi;
                entries.push(BankEntry { kind: ShiftKind::Plus3(p), thetas: plus3 });
                let mut minus3 = thetas.to_vec();
                minus3[p] -= 3.0 * half_pi;
                entries.push(BankEntry { kind: ShiftKind::Minus3(p), thetas: minus3 });
            }
        }
        CircuitBank { config, entries, controlled }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[BankEntry] {
        &self.entries
    }

    /// Assemble (fidelity, gradient) from per-entry fidelities, in the
    /// same order as [`CircuitBank::entries`].
    pub fn assemble(&self, fidelities: &[f32]) -> (f32, Vec<f32>) {
        assert_eq!(fidelities.len(), self.entries.len(), "fidelity arity");
        let n_p = self.config.n_params();
        let mut f_plus = vec![0.0f64; n_p];
        let mut f_minus = vec![0.0f64; n_p];
        let mut f_plus3 = vec![0.0f64; n_p];
        let mut f_minus3 = vec![0.0f64; n_p];
        let mut base = 0.0f64;
        for (e, &fid) in self.entries.iter().zip(fidelities.iter()) {
            let fid = fid as f64;
            match e.kind {
                ShiftKind::Base => base = fid,
                ShiftKind::Plus(p) => f_plus[p] = fid,
                ShiftKind::Minus(p) => f_minus[p] = fid,
                ShiftKind::Plus3(p) => f_plus3[p] = fid,
                ShiftKind::Minus3(p) => f_minus3[p] = fid,
            }
        }
        let grads = (0..n_p)
            .map(|p| {
                if self.controlled[p] {
                    (C_PLUS * (f_plus[p] - f_minus[p]) - C_MINUS * (f_plus3[p] - f_minus3[p]))
                        as f32
                } else {
                    (C_TWO_TERM * (f_plus[p] - f_minus[p])) as f32
                }
            })
            .collect();
        (base as f32, grads)
    }

    /// Expected bank size for a configuration: 1 + 2P + 2·(#controlled).
    pub fn expected_len(config: &QuClassiConfig) -> usize {
        let ctrl = config.controlled_param_mask().iter().filter(|&&c| c).count();
        1 + 2 * config.n_params() + 2 * ctrl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::builder::simulate_fidelity;
    use crate::util::Rng;

    #[test]
    fn bank_sizes_match_structure() {
        for cfg in QuClassiConfig::paper_configs() {
            let thetas = vec![0.1f32; cfg.n_params()];
            let bank = CircuitBank::new(cfg, &thetas);
            assert_eq!(bank.len(), CircuitBank::expected_len(&cfg));
        }
        // q5 l3: P=8, 2 controlled -> 1 + 16 + 4 = 21
        let cfg = QuClassiConfig::new(5, 3).unwrap();
        assert_eq!(CircuitBank::expected_len(&cfg), 21);
        // q5 l1: P=4, 0 controlled -> 9
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        assert_eq!(CircuitBank::expected_len(&cfg), 9);
    }

    #[test]
    fn shifts_touch_exactly_one_param() {
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let thetas: Vec<f32> = (0..cfg.n_params()).map(|i| i as f32 / 10.0).collect();
        let bank = CircuitBank::new(cfg, &thetas);
        for e in bank.entries() {
            let diff: Vec<usize> = e
                .thetas
                .iter()
                .zip(thetas.iter())
                .enumerate()
                .filter(|(_, (a, b))| a != b)
                .map(|(i, _)| i)
                .collect();
            match e.kind {
                ShiftKind::Base => assert!(diff.is_empty()),
                ShiftKind::Plus(p) | ShiftKind::Minus(p) | ShiftKind::Plus3(p)
                | ShiftKind::Minus3(p) => assert_eq!(diff, vec![p]),
            }
        }
    }

    /// Central test: bank gradients match finite differences of the
    /// simulator for every paper configuration, including layer 3 where
    /// the four-term rule is required.
    #[test]
    fn gradients_match_finite_difference() {
        for cfg in QuClassiConfig::paper_configs() {
            let mut rng = Rng::new(100 + cfg.qubits as u64 + cfg.layers as u64);
            let thetas: Vec<f32> =
                (0..cfg.n_params()).map(|_| rng.range_f64(-1.5, 1.5) as f32).collect();
            let data: Vec<f32> =
                (0..cfg.n_features()).map(|_| rng.range_f64(-1.5, 1.5) as f32).collect();
            let bank = CircuitBank::new(cfg, &thetas);
            let fids: Vec<f32> = bank
                .entries()
                .iter()
                .map(|e| simulate_fidelity(&cfg, &e.thetas, &data))
                .collect();
            let (fid0, grads) = bank.assemble(&fids);
            assert!(
                (fid0 - simulate_fidelity(&cfg, &thetas, &data)).abs() < 1e-6,
                "base fidelity mismatch"
            );
            let eps = 1e-3f32;
            for p in 0..cfg.n_params() {
                let mut tp = thetas.clone();
                tp[p] += eps;
                let mut tm = thetas.clone();
                tm[p] -= eps;
                let fd = (simulate_fidelity(&cfg, &tp, &data)
                    - simulate_fidelity(&cfg, &tm, &data))
                    / (2.0 * eps);
                assert!(
                    (grads[p] - fd).abs() < 5e-3,
                    "cfg {cfg:?} param {p}: shift {} vs fd {}",
                    grads[p],
                    fd
                );
            }
        }
    }

    #[test]
    fn two_term_rule_would_be_biased_for_controlled() {
        // Demonstrate the bias the 4-term rule fixes: for a layer-3
        // config, assemble with two-term coefficients only and check it
        // disagrees with finite differences on controlled params.
        let cfg = QuClassiConfig::new(5, 3).unwrap();
        let mut rng = Rng::new(55);
        let thetas: Vec<f32> =
            (0..cfg.n_params()).map(|_| rng.range_f64(-1.5, 1.5) as f32).collect();
        let data: Vec<f32> =
            (0..cfg.n_features()).map(|_| rng.range_f64(-1.5, 1.5) as f32).collect();
        let bank = CircuitBank::new(cfg, &thetas);
        let fids: Vec<f32> = bank
            .entries()
            .iter()
            .map(|e| simulate_fidelity(&cfg, &e.thetas, &data))
            .collect();
        // naive: grad = (f+ - f-)/2 for every param
        let mut fp = vec![0.0f32; 8];
        let mut fm = vec![0.0f32; 8];
        for (e, &f) in bank.entries().iter().zip(&fids) {
            match e.kind {
                ShiftKind::Plus(p) => fp[p] = f,
                ShiftKind::Minus(p) => fm[p] = f,
                _ => {}
            }
        }
        let eps = 1e-3f32;
        let mut max_bias = 0.0f32;
        for p in 6..8 {
            // the two controlled params
            let naive = (fp[p] - fm[p]) / 2.0;
            let mut tp = thetas.clone();
            tp[p] += eps;
            let mut tm = thetas.clone();
            tm[p] -= eps;
            let fd = (simulate_fidelity(&cfg, &tp, &data) - simulate_fidelity(&cfg, &tm, &data))
                / (2.0 * eps);
            max_bias = max_bias.max((naive - fd).abs());
        }
        // The exact rule passes at 5e-3 (previous test); the naive rule
        // should show visible bias on at least one controlled param for
        // this seed.
        assert!(max_bias > 5e-3, "expected visible two-term bias, got {max_bias}");
    }

    #[test]
    #[should_panic(expected = "fidelity arity")]
    fn assemble_checks_arity() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let bank = CircuitBank::new(cfg, &[0.0; 4]);
        let _ = bank.assemble(&[0.0; 3]);
    }
}
