//! Concrete QuClassi circuit construction (gate-list form).
//!
//! Produces the exact gate sequence the JAX/Pallas artifact computes, so
//! the Rust `qsim` fallback executor and the PJRT path are
//! interchangeable (verified in `rust/tests/parity_pjrt_qsim.rs`).

use std::sync::{Arc, OnceLock};

use super::spec::QuClassiConfig;
use crate::qsim::compile::{
    CacheStats, CircuitTemplate, CompiledProgram, PlanCache, Slot, TemplateGate,
};
use crate::qsim::gates::Gate;
use crate::qsim::State;

/// Build the full circuit for one (thetas, data) pair:
/// data encoding → variational layers → swap test.
pub fn build_quclassi(config: &QuClassiConfig, thetas: &[f32], data: &[f32]) -> Vec<Gate> {
    assert_eq!(thetas.len(), config.n_params(), "theta arity");
    assert_eq!(data.len(), config.n_features(), "data arity");
    let s = config.s();
    let state_qs = config.state_qubits();
    let data_qs = config.data_qubits();
    let mut gates = Vec::with_capacity(config.n_params() + config.n_features() + 2 * s + 2);

    // Data encoding: Ry(x_{2i}) Rz(x_{2i+1}) on data qubit i.
    for (i, &q) in data_qs.iter().enumerate() {
        gates.push(Gate::Ry { q, theta: data[2 * i] as f64 });
        gates.push(Gate::Rz { q, theta: data[2 * i + 1] as f64 });
    }

    // Layer 1: single-qubit unitary on each state qubit.
    let mut p = 0;
    for &q in &state_qs {
        gates.push(Gate::Ry { q, theta: thetas[p] as f64 });
        gates.push(Gate::Rz { q, theta: thetas[p + 1] as f64 });
        p += 2;
    }
    // Layer 2: dual-qubit unitary on adjacent pairs.
    if config.layers >= 2 {
        for i in 0..s - 1 {
            gates.push(Gate::Ryy { q0: state_qs[i], q1: state_qs[i + 1], theta: thetas[p] as f64 });
            gates.push(Gate::Rzz {
                q0: state_qs[i],
                q1: state_qs[i + 1],
                theta: thetas[p + 1] as f64,
            });
            p += 2;
        }
    }
    // Layer 3: entanglement unitary on adjacent pairs.
    if config.layers >= 3 {
        for i in 0..s - 1 {
            gates.push(Gate::Cry {
                control: state_qs[i],
                target: state_qs[i + 1],
                theta: thetas[p] as f64,
            });
            gates.push(Gate::Crz {
                control: state_qs[i],
                target: state_qs[i + 1],
                theta: thetas[p + 1] as f64,
            });
            p += 2;
        }
    }
    debug_assert_eq!(p, config.n_params());

    // Swap test.
    gates.push(Gate::H { q: 0 });
    for (sq, dq) in state_qs.iter().zip(data_qs.iter()) {
        gates.push(Gate::Cswap { control: 0, a: *sq, b: *dq });
    }
    gates.push(Gate::H { q: 0 });
    gates
}

/// Build the parameter-slotted template of [`build_quclassi`]: the same
/// gate sequence with [`Slot::Theta`]/[`Slot::Data`] markers instead of
/// concrete angles. The structure depends only on `config`, so one
/// template (and its compiled plan) serves every `(thetas, data)` pair —
/// `CircuitTemplate::instantiate` reproduces the seed gate list exactly.
pub fn build_quclassi_template(config: &QuClassiConfig) -> CircuitTemplate {
    let s = config.s();
    let state_qs = config.state_qubits();
    let data_qs = config.data_qubits();
    let mut gates = Vec::with_capacity(config.n_params() + config.n_features() + 2 * s + 2);
    let slotted = |gate: Gate, slot: Slot| TemplateGate { gate, slot };

    // Data encoding: Ry(x_{2i}) Rz(x_{2i+1}) on data qubit i.
    for (i, &q) in data_qs.iter().enumerate() {
        gates.push(slotted(Gate::Ry { q, theta: 0.0 }, Slot::Data(2 * i)));
        gates.push(slotted(Gate::Rz { q, theta: 0.0 }, Slot::Data(2 * i + 1)));
    }

    // Layer 1: single-qubit unitary on each state qubit.
    let mut p = 0;
    for &q in &state_qs {
        gates.push(slotted(Gate::Ry { q, theta: 0.0 }, Slot::Theta(p)));
        gates.push(slotted(Gate::Rz { q, theta: 0.0 }, Slot::Theta(p + 1)));
        p += 2;
    }
    // Layer 2: dual-qubit unitary on adjacent pairs.
    if config.layers >= 2 {
        for i in 0..s - 1 {
            gates.push(slotted(
                Gate::Ryy { q0: state_qs[i], q1: state_qs[i + 1], theta: 0.0 },
                Slot::Theta(p),
            ));
            gates.push(slotted(
                Gate::Rzz { q0: state_qs[i], q1: state_qs[i + 1], theta: 0.0 },
                Slot::Theta(p + 1),
            ));
            p += 2;
        }
    }
    // Layer 3: entanglement unitary on adjacent pairs.
    if config.layers >= 3 {
        for i in 0..s - 1 {
            gates.push(slotted(
                Gate::Cry { control: state_qs[i], target: state_qs[i + 1], theta: 0.0 },
                Slot::Theta(p),
            ));
            gates.push(slotted(
                Gate::Crz { control: state_qs[i], target: state_qs[i + 1], theta: 0.0 },
                Slot::Theta(p + 1),
            ));
            p += 2;
        }
    }
    debug_assert_eq!(p, config.n_params());

    // Swap test.
    gates.push(slotted(Gate::H { q: 0 }, Slot::Fixed));
    for (sq, dq) in state_qs.iter().zip(data_qs.iter()) {
        gates.push(slotted(Gate::Cswap { control: 0, a: *sq, b: *dq }, Slot::Fixed));
    }
    gates.push(slotted(Gate::H { q: 0 }, Slot::Fixed));
    CircuitTemplate { n_qubits: config.qubits, gates }
}

/// Process-wide plan cache keyed by config. Shared by every executor in
/// the process (`QsimExecutor` is a unit struct, so the cache cannot
/// live on the instance), which also means every in-process worker of a
/// cluster compiles each config exactly once.
fn quclassi_plan_cache() -> &'static PlanCache<QuClassiConfig> {
    static CACHE: OnceLock<PlanCache<QuClassiConfig>> = OnceLock::new();
    CACHE.get_or_init(|| PlanCache::new(16))
}

/// Compiled (3q-block fused, parameter-slotted) program for `config`,
/// from the process-wide plan cache — compile once, bind per pair.
pub fn compile_quclassi(config: &QuClassiConfig) -> Arc<CompiledProgram> {
    quclassi_plan_cache()
        .get_or_compile(config, || CompiledProgram::compile(build_quclassi_template(config)))
}

/// Hit/miss/occupancy counters of the process-wide QuClassi plan cache.
pub fn quclassi_plan_cache_stats() -> CacheStats {
    quclassi_plan_cache().stats()
}

/// [`simulate_fidelity`] through the compiled pipeline: cached plan +
/// parameter rebind + blocked kernels. Equal to the serial result up to
/// float re-association (parity asserted to 1e-6 in
/// `rust/tests/compiled_parity.rs`); the executor hot path.
pub fn simulate_fidelity_compiled(config: &QuClassiConfig, thetas: &[f32], data: &[f32]) -> f32 {
    let program = compile_quclassi(config);
    let bound = program.bind(thetas, data);
    bound.fidelity() as f32
}

/// Execute one QuClassi circuit on the Rust simulator and return the
/// swap-test fidelity estimate (exact expectation).
pub fn simulate_fidelity(config: &QuClassiConfig, thetas: &[f32], data: &[f32]) -> f32 {
    let gates = build_quclassi(config, thetas, data);
    let mut st = State::zero(config.qubits);
    st.run(&gates);
    (2.0 * st.prob_zero(0) - 1.0) as f32
}

/// [`simulate_fidelity`] through the gate-fusion pipeline
/// (`qsim::fusion`): adjacent one/two-qubit gates coalesce into fused
/// matrices before application. Equal to the serial result up to float
/// re-association (parity asserted in `rust/tests/parallel_parity.rs`).
pub fn simulate_fidelity_fused(config: &QuClassiConfig, thetas: &[f32], data: &[f32]) -> f32 {
    let gates = build_quclassi(config, thetas, data);
    let program = crate::qsim::fusion::fuse(&gates);
    let mut st = State::zero(config.qubits);
    program.apply(&mut st);
    (2.0 * st.prob_zero(0) - 1.0) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f64(-3.14, 3.14) as f32).collect()
    }

    #[test]
    fn gate_count_structure() {
        for cfg in QuClassiConfig::paper_configs() {
            let mut rng = Rng::new(cfg.qubits as u64 * 10 + cfg.layers as u64);
            let thetas = rand_vec(&mut rng, cfg.n_params());
            let data = rand_vec(&mut rng, cfg.n_features());
            let gates = build_quclassi(&cfg, &thetas, &data);
            let s = cfg.s();
            // encoding(2S) + params(P) + H + S cswaps + H
            assert_eq!(gates.len(), 2 * s + cfg.n_params() + s + 2);
        }
    }

    #[test]
    fn template_instantiates_to_seed_gate_list() {
        let mut rng = Rng::new(3);
        for cfg in QuClassiConfig::paper_configs() {
            let thetas = rand_vec(&mut rng, cfg.n_params());
            let data = rand_vec(&mut rng, cfg.n_features());
            let template = build_quclassi_template(&cfg);
            assert_eq!(
                template.instantiate(&thetas, &data),
                build_quclassi(&cfg, &thetas, &data),
                "{cfg:?}"
            );
        }
    }

    #[test]
    fn compiled_fidelity_matches_serial() {
        let mut rng = Rng::new(5);
        for cfg in QuClassiConfig::paper_configs() {
            for _ in 0..4 {
                let thetas = rand_vec(&mut rng, cfg.n_params());
                let data = rand_vec(&mut rng, cfg.n_features());
                let serial = simulate_fidelity(&cfg, &thetas, &data);
                let compiled = simulate_fidelity_compiled(&cfg, &thetas, &data);
                assert!(
                    (serial - compiled).abs() < 1e-6,
                    "{cfg:?}: serial={serial} compiled={compiled}"
                );
            }
        }
    }

    #[test]
    fn plan_cache_serves_repeat_configs() {
        let cfg = QuClassiConfig::new(5, 2).unwrap();
        let a = compile_quclassi(&cfg);
        let before = quclassi_plan_cache_stats();
        let b = compile_quclassi(&cfg);
        let after = quclassi_plan_cache_stats();
        assert!(std::sync::Arc::ptr_eq(&a, &b), "repeat config must hit the cache");
        assert!(after.hits > before.hits);
        assert!(after.len >= 1);
    }

    #[test]
    fn quclassi_plans_shrink_and_block() {
        // q7 l>=2 has a 3-wide state register whose layer gates all fuse
        // into a single 8x8 block; every config's plan is smaller than
        // its gate list.
        for cfg in QuClassiConfig::paper_configs() {
            let stats = compile_quclassi(&cfg).stats();
            assert!(
                stats.ops_out < stats.gates_in,
                "{cfg:?}: {} ops from {} gates",
                stats.ops_out,
                stats.gates_in
            );
            if cfg.qubits == 7 && cfg.layers >= 2 {
                assert!(stats.blocks3 >= 1, "{cfg:?} should form a 3q block");
            }
        }
    }

    #[test]
    fn fidelity_in_unit_interval() {
        let mut rng = Rng::new(7);
        for cfg in QuClassiConfig::paper_configs() {
            for _ in 0..10 {
                let f = simulate_fidelity(
                    &cfg,
                    &rand_vec(&mut rng, cfg.n_params()),
                    &rand_vec(&mut rng, cfg.n_features()),
                );
                assert!((-1e-6..=1.0 + 1e-6).contains(&(f as f64)), "fid {f}");
            }
        }
    }

    #[test]
    fn layer1_self_fidelity_is_one() {
        // state prep == data encoding for layer 1 -> |<a|b>|^2 = 1
        for q in [5, 7] {
            let cfg = QuClassiConfig::new(q, 1).unwrap();
            let mut rng = Rng::new(q as u64);
            let v = rand_vec(&mut rng, cfg.n_params());
            let f = simulate_fidelity(&cfg, &v, &v);
            assert!((f - 1.0).abs() < 1e-5, "fid {f}");
        }
    }

    #[test]
    fn layer1_symmetry() {
        let cfg = QuClassiConfig::new(5, 1).unwrap();
        let mut rng = Rng::new(9);
        let a = rand_vec(&mut rng, 4);
        let b = rand_vec(&mut rng, 4);
        let f_ab = simulate_fidelity(&cfg, &a, &b);
        let f_ba = simulate_fidelity(&cfg, &b, &a);
        assert!((f_ab - f_ba).abs() < 1e-5);
    }

    #[test]
    fn matches_analytic_single_qubit_overlap() {
        // q=3 layer-1: one state qubit Ry(t)Rz(p) vs data Ry(x)Rz(y).
        // fidelity = |<psi(t,p)|psi(x,y)>|^2 with both starting at |0>.
        let cfg = QuClassiConfig::new(3, 1).unwrap();
        let (t, p, x, y) = (0.7f32, -0.4f32, 1.2f32, 0.9f32);
        let got = simulate_fidelity(&cfg, &[t, p], &[x, y]) as f64;
        // closed form: |cos(t/2)cos(x/2) e^{i(p-y)/2·0} ... compute numerically
        // via direct 2-dim states instead:
        let psi = |a: f64, b: f64| -> (crate::qsim::C64, crate::qsim::C64) {
            // Ry(a) then Rz(b) on |0>: (cos(a/2) e^{-ib/2}, sin(a/2) e^{ib/2})
            (
                crate::qsim::C64::cis(-b / 2.0).scale((a / 2.0).cos()),
                crate::qsim::C64::cis(b / 2.0).scale((a / 2.0).sin()),
            )
        };
        let (a0, a1) = psi(t as f64, p as f64);
        let (b0, b1) = psi(x as f64, y as f64);
        let overlap = a0.conj() * b0 + a1.conj() * b1;
        let want = overlap.norm_sq();
        assert!((got - want).abs() < 1e-6, "got {got}, want {want}");
    }
}
