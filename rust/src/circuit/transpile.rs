//! Peephole circuit optimization (extension — the paper cites relaxed
//! peephole optimization [Liu et al., CGO'21] among the compiler work
//! its stack builds on).
//!
//! Local rewrites that preserve the circuit's unitary action:
//!
//! * merge adjacent same-axis rotations on the same operand(s):
//!   `Ry(a) Ry(b) -> Ry(a+b)` (likewise Rz/Rx/Ryy/Rzz/CRY/CRZ);
//! * drop rotations with angle ≡ 0 (mod 4π — the rotation period);
//! * cancel adjacent self-inverse pairs: `H H`, `CX CX`, `CSWAP CSWAP`.
//!
//! Rewrites only fire when the two gates are adjacent *on their operand
//! qubits* — an intervening gate on a disjoint qubit set does not block
//! merging (commutation through disjoint supports).
//!
//! The worker's qsim backend applies this before simulation; the QuClassi
//! circuits contain mergeable pairs whenever a data angle or parameter
//! lands on the same qubit axis twice.

use crate::qsim::gates::Gate;

const TWO_PI: f64 = 2.0 * std::f64::consts::PI;

/// Is `theta` equivalent to a no-op rotation (angle ≡ 0 mod 4π)?
fn is_noop_angle(theta: f64) -> bool {
    // Rotations have period 4π (they act on half angles); 2π flips the
    // global phase only, which is unobservable — treat 2π as no-op too.
    let r = theta.rem_euclid(TWO_PI);
    r.abs() < 1e-12 || (TWO_PI - r).abs() < 1e-12
}

/// Can `a` and `b` merge into one gate (same kind, same operands)?
fn mergeable(a: &Gate, b: &Gate) -> bool {
    use Gate::*;
    match (a, b) {
        (Rx { q: q1, .. }, Rx { q: q2, .. })
        | (Ry { q: q1, .. }, Ry { q: q2, .. })
        | (Rz { q: q1, .. }, Rz { q: q2, .. }) => q1 == q2,
        (Ryy { q0: a0, q1: a1, .. }, Ryy { q0: b0, q1: b1, .. })
        | (Rzz { q0: a0, q1: a1, .. }, Rzz { q0: b0, q1: b1, .. }) => a0 == b0 && a1 == b1,
        (
            Cry { control: c1, target: t1, .. },
            Cry { control: c2, target: t2, .. },
        )
        | (
            Crz { control: c1, target: t1, .. },
            Crz { control: c2, target: t2, .. },
        ) => c1 == c2 && t1 == t2,
        _ => false,
    }
}

/// Do two gates act on disjoint qubit sets (and therefore commute)?
fn disjoint(a: &Gate, b: &Gate) -> bool {
    let qa = a.qubits();
    b.qubits().iter().all(|q| !qa.contains(q))
}

/// Are `a` and `b` an adjacent self-inverse pair?
fn cancels(a: &Gate, b: &Gate) -> bool {
    use Gate::*;
    match (a, b) {
        (H { q: q1 }, H { q: q2 }) => q1 == q2,
        (Cx { control: c1, target: t1 }, Cx { control: c2, target: t2 }) => c1 == c2 && t1 == t2,
        (
            Cswap { control: c1, a: a1, b: b1 },
            Cswap { control: c2, a: a2, b: b2 },
        ) => c1 == c2 && a1 == a2 && b1 == b2,
        _ => false,
    }
}

/// One optimization pass; returns (rewritten gates, number of rewrites).
fn pass(gates: &[Gate]) -> (Vec<Gate>, usize) {
    let mut out: Vec<Gate> = Vec::with_capacity(gates.len());
    let mut rewrites = 0;
    'next: for g in gates {
        // Look backwards through `out` for a partner, stopping at the
        // first gate that shares a qubit without matching.
        for i in (0..out.len()).rev() {
            let prev = &out[i];
            if mergeable(prev, g) {
                let merged = prev.with_theta(prev.theta().unwrap() + g.theta().unwrap());
                rewrites += 1;
                if is_noop_angle(merged.theta().unwrap()) {
                    out.remove(i);
                } else {
                    out[i] = merged;
                }
                continue 'next;
            }
            if cancels(prev, g) {
                out.remove(i);
                rewrites += 1;
                continue 'next;
            }
            if !disjoint(prev, g) {
                break; // blocked: a non-commuting gate intervenes
            }
        }
        // No partner: keep, unless it is itself a no-op rotation.
        if g.theta().map(is_noop_angle).unwrap_or(false) {
            rewrites += 1;
            continue;
        }
        out.push(g.clone());
    }
    (out, rewrites)
}

/// Optimize until fixpoint; returns the rewritten circuit.
pub fn optimize(gates: &[Gate]) -> Vec<Gate> {
    let mut current = gates.to_vec();
    loop {
        let (next, rewrites) = pass(&current);
        if rewrites == 0 {
            return next;
        }
        current = next;
    }
}

/// Rewrite statistics for observability / the transpile bench.
pub fn optimize_with_stats(gates: &[Gate]) -> (Vec<Gate>, usize) {
    let before = gates.len();
    let out = optimize(gates);
    (out.clone(), before - out.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::{build_quclassi, QuClassiConfig};
    use crate::qsim::State;
    use crate::util::Rng;

    /// Equivalence oracle: both circuits act identically on random states.
    fn assert_equivalent(a: &[Gate], b: &[Gate], nq: usize) {
        let mut rng = Rng::new(99);
        for _ in 0..4 {
            let mut amps: Vec<crate::qsim::C64> = (0..1usize << nq)
                .map(|_| crate::qsim::C64::new(rng.normal(), rng.normal()))
                .collect();
            let norm = amps.iter().map(|x| x.norm_sq()).sum::<f64>().sqrt();
            for x in &mut amps {
                *x = x.scale(1.0 / norm);
            }
            let mut sa = State::from_amps(amps.clone());
            let mut sb = State::from_amps(amps);
            sa.run(a);
            sb.run(b);
            for (x, y) in sa.amps().iter().zip(sb.amps().iter()) {
                assert!(
                    (x.re - y.re).abs() < 1e-9 && (x.im - y.im).abs() < 1e-9,
                    "circuits diverge"
                );
            }
        }
    }

    #[test]
    fn merges_same_axis_rotations() {
        let gates = vec![Gate::Ry { q: 1, theta: 0.3 }, Gate::Ry { q: 1, theta: 0.4 }];
        let opt = optimize(&gates);
        assert_eq!(opt, vec![Gate::Ry { q: 1, theta: 0.7 }]);
        assert_equivalent(&gates, &opt, 2);
    }

    #[test]
    fn merge_through_disjoint_gate() {
        let gates = vec![
            Gate::Rz { q: 0, theta: 0.5 },
            Gate::Ry { q: 1, theta: 0.2 }, // disjoint: commutes past
            Gate::Rz { q: 0, theta: 0.25 },
        ];
        let opt = optimize(&gates);
        assert_eq!(opt.len(), 2);
        assert_equivalent(&gates, &opt, 2);
    }

    #[test]
    fn blocked_by_overlapping_gate() {
        let gates = vec![
            Gate::Ry { q: 0, theta: 0.5 },
            Gate::H { q: 0 }, // same qubit: blocks the merge
            Gate::Ry { q: 0, theta: 0.25 },
        ];
        let opt = optimize(&gates);
        assert_eq!(opt.len(), 3);
        assert_equivalent(&gates, &opt, 1);
    }

    #[test]
    fn cancels_double_h_and_cx() {
        let gates = vec![
            Gate::H { q: 0 },
            Gate::H { q: 0 },
            Gate::Cx { control: 0, target: 1 },
            Gate::Cx { control: 0, target: 1 },
        ];
        assert!(optimize(&gates).is_empty());
    }

    #[test]
    fn opposite_rotations_vanish() {
        let gates = vec![Gate::Cry { control: 0, target: 1, theta: 0.8 },
                         Gate::Cry { control: 0, target: 1, theta: -0.8 }];
        assert!(optimize(&gates).is_empty());
    }

    #[test]
    fn drops_zero_angle_gates() {
        let gates = vec![
            Gate::Ry { q: 0, theta: 0.0 },
            Gate::Rzz { q0: 0, q1: 1, theta: 2.0 * TWO_PI },
            Gate::Rz { q: 1, theta: 0.5 },
        ];
        let opt = optimize(&gates);
        assert_eq!(opt, vec![Gate::Rz { q: 1, theta: 0.5 }]);
    }

    #[test]
    fn quclassi_circuits_stay_equivalent() {
        // Property: for every paper config, the optimized circuit acts
        // identically to the original.
        let mut rng = Rng::new(3);
        for cfg in QuClassiConfig::paper_configs() {
            let thetas: Vec<f32> =
                (0..cfg.n_params()).map(|_| rng.range_f64(-3.0, 3.0) as f32).collect();
            let data: Vec<f32> =
                (0..cfg.n_features()).map(|_| rng.range_f64(-3.0, 3.0) as f32).collect();
            let gates = build_quclassi(&cfg, &thetas, &data);
            let opt = optimize(&gates);
            assert!(opt.len() <= gates.len());
            assert_equivalent(&gates, &opt, cfg.qubits);
        }
    }

    #[test]
    fn fixpoint_enables_cascades() {
        // Ry(a) Ry(-a) leaves H H adjacent -> everything vanishes.
        let gates = vec![
            Gate::H { q: 0 },
            Gate::Ry { q: 0, theta: 0.4 },
            Gate::Ry { q: 0, theta: -0.4 },
            Gate::H { q: 0 },
        ];
        assert!(optimize(&gates).is_empty());
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(8);
        let cfg = QuClassiConfig::new(7, 3).unwrap();
        let thetas: Vec<f32> = (0..cfg.n_params()).map(|_| rng.f32()).collect();
        let data: Vec<f32> = (0..cfg.n_features()).map(|_| rng.f32()).collect();
        let once = optimize(&build_quclassi(&cfg, &thetas, &data));
        let twice = optimize(&once);
        assert_eq!(once, twice);
    }
}
