//! Circuit IR, the QuClassi circuit builder, and parameter-shift banks.
//!
//! * [`spec`] — the (qubits, layers) configuration: register layout,
//!   parameter/feature counts (mirrors `python/compile/kernels/ref.py`).
//! * [`builder`] — concrete gate-list construction for one
//!   (theta, data) pair: data encoding → variational layers → swap test.
//! * [`bank`] — Algorithm 1's circuit bank: shifted parameter vectors for
//!   the parameter-shift rule and gradient assembly from the returned
//!   fidelities.

pub mod bank;
pub mod builder;
pub mod spec;
pub mod transpile;

pub use bank::{CircuitBank, ShiftKind};
pub use builder::{
    build_quclassi, build_quclassi_template, compile_quclassi, simulate_fidelity_compiled,
};
pub use spec::QuClassiConfig;
pub use transpile::optimize;
