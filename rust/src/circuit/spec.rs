//! QuClassi circuit configuration (mirrors `ref.quclassi_layout`).

use crate::wire::Value;

/// A (qubits, layers) configuration of the QuClassi variational circuit.
///
/// Register layout for `q` total qubits (q odd, >= 3):
/// qubit 0 = swap-test ancilla, qubits `1..=S` = variational state
/// register, qubits `S+1..=2S` = data register, with `S = (q-1)/2`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QuClassiConfig {
    pub qubits: usize,
    pub layers: usize,
}

impl QuClassiConfig {
    pub fn new(qubits: usize, layers: usize) -> Result<QuClassiConfig, String> {
        if qubits < 3 || qubits % 2 == 0 {
            return Err(format!("qubits must be odd and >= 3, got {qubits}"));
        }
        if !(1..=3).contains(&layers) {
            return Err(format!("layers must be 1..=3, got {layers}"));
        }
        Ok(QuClassiConfig { qubits, layers })
    }

    /// The six configurations evaluated by the paper.
    pub fn paper_configs() -> Vec<QuClassiConfig> {
        let mut v = Vec::new();
        for q in [5, 7] {
            for l in [1, 2, 3] {
                v.push(QuClassiConfig { qubits: q, layers: l });
            }
        }
        v
    }

    /// S — size of the state (and data) register.
    pub fn s(&self) -> usize {
        (self.qubits - 1) / 2
    }

    pub fn state_qubits(&self) -> Vec<usize> {
        (1..=self.s()).collect()
    }

    pub fn data_qubits(&self) -> Vec<usize> {
        (self.s() + 1..=2 * self.s()).collect()
    }

    /// Trainable parameter count.
    pub fn n_params(&self) -> usize {
        let s = self.s();
        let mut total = 2 * s;
        if self.layers >= 2 {
            total += 2 * (s - 1);
        }
        if self.layers >= 3 {
            total += 2 * (s - 1);
        }
        total
    }

    /// Input feature count (2 encoder angles per data qubit).
    pub fn n_features(&self) -> usize {
        2 * self.s()
    }

    /// Qubit demand as seen by the co-Manager scheduler.
    pub fn qubit_demand(&self) -> usize {
        self.qubits
    }

    /// True for parameter indices driven through CRY/CRZ (these need the
    /// four-term shift rule; see `bank`).
    pub fn controlled_param_mask(&self) -> Vec<bool> {
        let s = self.s();
        let mut mask = vec![false; self.n_params()];
        if self.layers >= 3 {
            let start = 2 * s + 2 * (s - 1);
            for m in mask.iter_mut().skip(start) {
                *m = true;
            }
        }
        mask
    }

    /// Artifact base name (matches `python/compile/model.py::config_meta`).
    pub fn artifact_name(&self) -> String {
        format!("quclassi_q{}_l{}", self.qubits, self.layers)
    }

    pub fn to_wire(&self) -> Value {
        Value::obj().with("qubits", self.qubits).with("layers", self.layers)
    }

    pub fn from_wire(v: &Value) -> Result<QuClassiConfig, String> {
        QuClassiConfig::new(v.req_usize("qubits")?, v.req_usize("layers")?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_param_counts() {
        // Matches python tests: q5 -> 4/6/8, q7 -> 6/10/14.
        let counts: Vec<usize> = QuClassiConfig::paper_configs()
            .iter()
            .map(|c| c.n_params())
            .collect();
        assert_eq!(counts, vec![4, 6, 8, 6, 10, 14]);
    }

    #[test]
    fn feature_counts() {
        assert_eq!(QuClassiConfig::new(5, 1).unwrap().n_features(), 4);
        assert_eq!(QuClassiConfig::new(7, 1).unwrap().n_features(), 6);
    }

    #[test]
    fn register_layout() {
        let c = QuClassiConfig::new(7, 2).unwrap();
        assert_eq!(c.s(), 3);
        assert_eq!(c.state_qubits(), vec![1, 2, 3]);
        assert_eq!(c.data_qubits(), vec![4, 5, 6]);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(QuClassiConfig::new(4, 1).is_err()); // even
        assert!(QuClassiConfig::new(1, 1).is_err()); // too small
        assert!(QuClassiConfig::new(5, 0).is_err());
        assert!(QuClassiConfig::new(5, 4).is_err());
    }

    #[test]
    fn controlled_mask_covers_layer3_only() {
        let c = QuClassiConfig::new(5, 3).unwrap();
        assert_eq!(c.controlled_param_mask(), vec![false, false, false, false, false, false, true, true]);
        let c2 = QuClassiConfig::new(5, 2).unwrap();
        assert!(c2.controlled_param_mask().iter().all(|&m| !m));
    }

    #[test]
    fn artifact_names() {
        assert_eq!(QuClassiConfig::new(7, 3).unwrap().artifact_name(), "quclassi_q7_l3");
    }

    #[test]
    fn wire_round_trip() {
        let c = QuClassiConfig::new(5, 2).unwrap();
        assert_eq!(QuClassiConfig::from_wire(&c.to_wire()).unwrap(), c);
    }
}
