//! Discrete-event simulation substrate.
//!
//! A minimal, deterministic event-heap simulator: events are closures
//! scheduled at virtual times; ties break by insertion order so runs are
//! exactly reproducible. The cloud-environment models (`env/`) replay the
//! *same co-Manager scheduler code* (`coordinator::{Registry, scheduler}`)
//! against calibrated service-time models to regenerate the paper's
//! figures — on this 1-core testbed, wall-clock multi-worker speedups
//! cannot be observed directly (DESIGN.md §3).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Event handler: receives the simulator (to schedule more events) and
/// the user state.
pub type Handler<S> = Box<dyn FnOnce(&mut Des<S>, &mut S)>;

/// Order-preserving total order for non-negative event times.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct TimeKey(u64);

impl TimeKey {
    fn of(t: f64) -> TimeKey {
        debug_assert!(t >= 0.0 && t.is_finite(), "bad event time {t}");
        TimeKey((t * 1e9) as u64)
    }

    fn secs(self) -> f64 {
        self.0 as f64 / 1e9
    }
}

/// The event-heap simulator.
pub struct Des<S> {
    now: f64,
    seq: u64,
    heap: BinaryHeap<Reverse<(TimeKey, u64, usize)>>,
    slots: Vec<Option<Handler<S>>>,
    executed: u64,
}

impl<S> Default for Des<S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S> Des<S> {
    pub fn new() -> Des<S> {
        Des { now: 0.0, seq: 0, heap: BinaryHeap::new(), slots: Vec::new(), executed: 0 }
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Schedule `f` to run `delay` seconds from now.
    pub fn schedule<F: FnOnce(&mut Des<S>, &mut S) + 'static>(&mut self, delay: f64, f: F) {
        self.schedule_at(self.now + delay.max(0.0), f)
    }

    /// Schedule `f` at absolute time `t` (clamped to now).
    pub fn schedule_at<F: FnOnce(&mut Des<S>, &mut S) + 'static>(&mut self, t: f64, f: F) {
        let t = t.max(self.now);
        let idx = self.slots.len();
        self.slots.push(Some(Box::new(f)));
        self.heap.push(Reverse((TimeKey::of(t), self.seq, idx)));
        self.seq += 1;
    }

    /// Run until the event queue drains; returns the final time.
    pub fn run(&mut self, state: &mut S) -> f64 {
        while self.step(state) {}
        self.now
    }

    /// Run while events exist and time <= t_end.
    pub fn run_until(&mut self, state: &mut S, t_end: f64) -> f64 {
        while let Some(Reverse((tk, _, _))) = self.heap.peek() {
            if tk.secs() > t_end {
                break;
            }
            self.step(state);
        }
        self.now = self.now.max(t_end.min(self.now + 0.0));
        self.now
    }

    /// Execute the next event; false when empty.
    pub fn step(&mut self, state: &mut S) -> bool {
        match self.heap.pop() {
            None => false,
            Some(Reverse((tk, _, idx))) => {
                self.now = tk.secs();
                if let Some(f) = self.slots[idx].take() {
                    self.executed += 1;
                    f(self, state);
                }
                true
            }
        }
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_run_in_time_order() {
        let mut des: Des<Vec<(f64, &str)>> = Des::new();
        des.schedule(3.0, |d, s| s.push((d.now(), "c")));
        des.schedule(1.0, |d, s| s.push((d.now(), "a")));
        des.schedule(2.0, |d, s| s.push((d.now(), "b")));
        let mut log = Vec::new();
        let end = des.run(&mut log);
        assert_eq!(log.iter().map(|(_, n)| *n).collect::<Vec<_>>(), vec!["a", "b", "c"]);
        assert!((end - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut des: Des<Vec<u32>> = Des::new();
        for i in 0..10u32 {
            des.schedule(1.0, move |_, s| s.push(i));
        }
        let mut log = Vec::new();
        des.run(&mut log);
        assert_eq!(log, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_schedule_more_events() {
        // a chain of events: each schedules the next until 5 deep
        let mut des: Des<Vec<f64>> = Des::new();
        fn chain(depth: u32, des: &mut Des<Vec<f64>>) {
            if depth == 0 {
                return;
            }
            des.schedule(1.0, move |d, s: &mut Vec<f64>| {
                s.push(d.now());
                chain(depth - 1, d);
            });
        }
        chain(5, &mut des);
        let mut log = Vec::new();
        des.run(&mut log);
        assert_eq!(log, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn run_until_stops_at_horizon() {
        let mut des: Des<Vec<f64>> = Des::new();
        for i in 1..=10 {
            des.schedule(i as f64, move |d, s: &mut Vec<f64>| s.push(d.now()));
        }
        let mut log = Vec::new();
        des.run_until(&mut log, 4.5);
        assert_eq!(log.len(), 4);
        assert_eq!(des.pending(), 6);
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut des: Des<Vec<(f64, u32)>> = Des::new();
            for i in 0..50u32 {
                let t = (i as f64 * 7919.0) % 13.0;
                des.schedule(t, move |d, s| s.push((d.now(), i)));
            }
            let mut log = Vec::new();
            des.run(&mut log);
            log
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn past_times_clamp_to_now() {
        let mut des: Des<Vec<f64>> = Des::new();
        des.schedule(5.0, |d, s: &mut Vec<f64>| {
            // schedule "in the past" — must fire at current time
            d.schedule_at(1.0, |d2, s2: &mut Vec<f64>| s2.push(d2.now()));
            s.push(d.now());
        });
        let mut log = Vec::new();
        des.run(&mut log);
        assert_eq!(log, vec![5.0, 5.0]);
    }
}
