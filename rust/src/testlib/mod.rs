//! Property-testing harness (std-only substrate for `proptest`).
//!
//! Generators over a seeded [`Rng`], a `forall` runner that reports the
//! failing seed + case number, and greedy shrinking for integer and
//! vector cases. Used by the coordinator invariants tests
//! (`rust/tests/proptest_coordinator.rs`) and by unit tests across
//! modules.

use crate::util::Rng;

/// Number of cases per property by default.
pub const DEFAULT_CASES: usize = 128;

/// A generator of values from randomness.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Uniform usize in [lo, hi] (inclusive).
pub fn usize_in(lo: usize, hi: usize) -> impl Gen<usize> {
    assert!(lo <= hi);
    move |rng: &mut Rng| lo + rng.index(hi - lo + 1)
}

/// Uniform f64 in [lo, hi).
pub fn f64_in(lo: f64, hi: f64) -> impl Gen<f64> {
    move |rng: &mut Rng| rng.range_f64(lo, hi)
}

/// Uniform f32 in [lo, hi).
pub fn f32_in(lo: f32, hi: f32) -> impl Gen<f32> {
    move |rng: &mut Rng| rng.range_f64(lo as f64, hi as f64) as f32
}

/// Vector with a length drawn from [min_len, max_len].
pub fn vec_of<T, G: Gen<T>>(inner: G, min_len: usize, max_len: usize) -> impl Gen<Vec<T>> {
    move |rng: &mut Rng| {
        let len = min_len + rng.index(max_len - min_len + 1);
        (0..len).map(|_| inner.generate(rng)).collect()
    }
}

/// One of the provided choices (cloned).
pub fn one_of<T: Clone>(choices: Vec<T>) -> impl Gen<T> {
    assert!(!choices.is_empty());
    move |rng: &mut Rng| choices[rng.index(choices.len())].clone()
}

/// Outcome of a property check over one case.
pub struct CaseFailure {
    pub case: usize,
    pub seed: u64,
    pub message: String,
}

/// Run `prop` against `cases` generated values; panics with the seed and
/// case index on the first failure. `prop` returns `Err(reason)` to fail.
pub fn forall<T: std::fmt::Debug, G: Gen<T>>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: G,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let value = gen.generate(&mut case_rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed}, case-seed {case_seed}):\n  \
                 input: {value:?}\n  reason: {msg}"
            );
        }
    }
}

/// Greedy shrinking for a vector-valued case: tries dropping chunks then
/// single elements while the property still fails, returning a (locally)
/// minimal counterexample.
pub fn shrink_vec<T: Clone>(
    mut failing: Vec<T>,
    still_fails: impl Fn(&[T]) -> bool,
) -> Vec<T> {
    debug_assert!(still_fails(&failing));
    // Pass 1: halve from either end.
    let mut chunk = failing.len() / 2;
    while chunk >= 1 {
        let mut i = 0;
        while i + chunk <= failing.len() {
            let mut candidate = failing.clone();
            candidate.drain(i..i + chunk);
            if still_fails(&candidate) {
                failing = candidate;
                // keep i where it is: the window now holds new elements
            } else {
                i += 1;
            }
        }
        chunk /= 2;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall("sum-commutes", 1, 64, vec_of(usize_in(0, 100), 0, 10), |xs| {
            let fwd: usize = xs.iter().sum();
            let bwd: usize = xs.iter().rev().sum();
            if fwd == bwd {
                Ok(())
            } else {
                Err("sum not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn forall_reports_failure_with_seed() {
        forall("always-fails", 2, 8, usize_in(0, 10), |_| Err("nope".into()));
    }

    #[test]
    fn forall_is_deterministic_per_seed() {
        // Collect generated values for two runs with the same seed.
        let collect = |seed: u64| {
            let mut seen = Vec::new();
            let mut rng = Rng::new(seed);
            for _ in 0..16 {
                let cs = rng.next_u64();
                let mut crng = Rng::new(cs);
                seen.push(usize_in(0, 1000).generate(&mut crng));
            }
            seen
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property "fails" iff the vec contains a 7.
        let failing: Vec<u32> = vec![1, 9, 7, 3, 7, 2, 8];
        let shrunk = shrink_vec(failing, |xs| xs.contains(&7));
        assert_eq!(shrunk, vec![7]);
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            let x = usize_in(3, 9).generate(&mut rng);
            assert!((3..=9).contains(&x));
            let f = f64_in(-1.0, 1.0).generate(&mut rng);
            assert!((-1.0..1.0).contains(&f));
        }
        let v = vec_of(usize_in(0, 1), 2, 5).generate(&mut rng);
        assert!((2..=5).contains(&v.len()));
    }

    #[test]
    fn one_of_covers_choices() {
        let mut rng = Rng::new(6);
        let gen = one_of(vec!["a", "b", "c"]);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..200 {
            seen.insert(gen.generate(&mut rng));
        }
        assert_eq!(seen.len(), 3);
    }
}
