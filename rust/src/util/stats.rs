//! Streaming and batch statistics used by metrics and the bench harness.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.mean += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch summary with exact percentiles (sorts a copy).
#[derive(Debug, Clone)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty slice");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut st = OnlineStats::new();
        for &x in samples {
            st.push(x);
        }
        Summary {
            count: sorted.len(),
            mean: st.mean(),
            std_dev: st.std_dev(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut st = OnlineStats::new();
        for &x in &xs {
            st.push(x);
        }
        assert!((st.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic dataset = 32/7
        assert!((st.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(st.min(), 2.0);
        assert_eq!(st.max(), 9.0);
    }

    #[test]
    fn merge_equals_concat() {
        let a_data = [1.0, 2.0, 3.0];
        let b_data = [10.0, 20.0, 30.0, 40.0];
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut whole = OnlineStats::new();
        for &x in &a_data {
            a.push(x);
            whole.push(x);
        }
        for &x in &b_data {
            b.push(x);
            whole.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.count(), 7);
    }

    #[test]
    fn percentiles() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&sorted, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.5) - 50.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }
}
